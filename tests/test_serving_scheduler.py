"""Eigensolver-as-a-service: scheduler coalescing, SLOs, warm restarts.

The serving contract under test:

* coalesced batches answer each query exactly as the batched session API
  (and independent solves) would — serving never changes the math;
* SLO machinery is typed and observable — deadline expiry, bounded-queue
  backpressure, cancellation each raise their own error and tick a metric;
* a killed-and-restarted server warms from the persistent store with ZERO
  format conversions, counter-verified;
* stale persisted artifacts (version / layout drift) are rejected with a
  warning and the session cold-rebuilds.
"""

import threading
import time

import numpy as np
import pytest

from repro.api import EigenSession, SolverConfig, prepare, session_cache_clear
from repro.serving import (
    DeadlineExceededError,
    EigenScheduler,
    QueryCancelledError,
    QueueFullError,
    SchedulerConfig,
    ServingError,
    SessionStore,
    UnknownMatrixError,
)
from repro.sparse import generate
from repro.sparse.formats import conversion_count

ITERS = 20
CFG = SolverConfig(reorth="full", backend="single")


@pytest.fixture(autouse=True)
def _fresh_cache():
    session_cache_clear()
    yield
    session_cache_clear()


@pytest.fixture(scope="module")
def csr():
    return generate("web", 384, 6.0, seed=3, values="normalized")


def _mk(csr, *, start=True, store=None, **knobs):
    knobs.setdefault("admission_window_s", 0.02)
    sched = EigenScheduler(SchedulerConfig(**knobs), store=store, start=start)
    key = sched.add_matrix(csr, name="m", config=CFG)
    return sched, key


# ---------------------------------------------------------------- coalescing


def test_coalesced_results_match_batched_and_independent(csr):
    queries = [{"k": k, "num_iters": ITERS, "reorth": "full"} for k in (2, 3, 4)]
    sched, key = _mk(csr, start=False)
    try:
        handles = [sched.submit(key, q) for q in queries]
        sched.start()
        got = [h.result(timeout=120.0) for h in handles]
    finally:
        sched.close()

    # One shared sweep served all three queries.
    stats = sched.stats()
    assert stats.groups == 1
    assert stats.grouped_queries == 3
    assert stats.batch_occupancy == pytest.approx(3.0)
    assert stats.coalesce_rate == pytest.approx(1.0)
    assert all(r.timings.get("amortized_over") == 3 for r in got)

    # Bit-identical to the batched session API on an equivalent session.
    ref_sess = prepare(csr, reorth="full", backend="single")
    for r, ref in zip(got, ref_sess.eigsh_many(queries)):
        assert r.k == ref.k
        np.testing.assert_array_equal(np.asarray(r.eigenvalues), np.asarray(ref.eigenvalues))

    # And numerically identical to fully independent solves.
    for q, r in zip(queries, got):
        solo = ref_sess.eigsh(**q)
        np.testing.assert_allclose(
            np.asarray(r.eigenvalues), np.asarray(solo.eigenvalues), rtol=1e-10
        )


def test_incompatible_queries_are_not_coalesced(csr):
    sched, key = _mk(csr, start=False)
    try:
        h1 = sched.submit(key, k=2, num_iters=ITERS, reorth="full")
        h2 = sched.submit(key, k=2, num_iters=ITERS, reorth="half")
        sched.start()
        r1, r2 = h1.result(timeout=120.0), h2.result(timeout=120.0)
    finally:
        sched.close()
    assert r1.k == r2.k == 2
    assert sched.stats().groups == 2  # different reorth => different sweeps
    assert sched.stats().coalesce_rate == 0.0


def test_group_key_predicate_matches_eigsh_many_rules(csr):
    sess = EigenSession(csr, CFG)
    a = sess.group_key({"k": 2, "num_iters": ITERS, "reorth": "full"})
    b = sess.group_key({"k": 6, "num_iters": 40, "reorth": "full"})
    c = sess.group_key({"k": 2, "num_iters": ITERS, "reorth": "half"})
    assert a is not None and a == b  # k/m differences still share a sweep
    assert a != c
    # Accuracy-driven auto-policy solves are never groupable.
    assert sess.group_key({"k": 2, "num_iters": ITERS, "policy": "auto"}) is None
    with pytest.raises(ValueError):
        sess.group_key({"k": 0, "num_iters": ITERS})


def test_queue_and_e2e_timing_split(csr):
    sched, key = _mk(csr)
    try:
        res = sched.submit(key, k=2, num_iters=ITERS, reorth="full").result(timeout=120.0)
    finally:
        sched.close()
    t = res.timings
    assert t["queue_s"] >= 0.0
    assert t["e2e_s"] == pytest.approx(t["queue_s"] + t["total_s"], abs=1e-12)
    assert res.queue_s == t["queue_s"]


# ----------------------------------------------------------------- SLO plane


def test_deadline_expiry_is_typed_and_counted(csr):
    sched, key = _mk(csr, start=False)
    try:
        h = sched.submit(key, k=2, num_iters=ITERS, deadline_s=0.02)
        time.sleep(0.1)  # let the deadline lapse while the dispatcher is off
        sched.start()
        with pytest.raises(DeadlineExceededError):
            h.result(timeout=30.0)
    finally:
        sched.close()
    assert sched.stats().rejected_deadline == 1
    assert sched.stats().completed == 0


def test_bounded_queue_backpressure(csr):
    sched, key = _mk(csr, start=False, max_queue=4)
    try:
        for _ in range(4):
            sched.submit(key, k=2, num_iters=ITERS)
        with pytest.raises(QueueFullError):
            sched.submit(key, k=2, num_iters=ITERS)
        assert sched.stats().rejected_full == 1
        assert sched.stats().queue_depth == 4
    finally:
        sched.close()


def test_cancellation_while_queued(csr):
    sched, key = _mk(csr, start=False)
    try:
        h = sched.submit(key, k=2, num_iters=ITERS)
        assert h.cancel() is True
        assert h.cancel() is True  # repeat cancel on a cancelled request: still cancelled
        assert h.cancelled()
        sched.start()
        with pytest.raises(QueryCancelledError):
            h.result(timeout=30.0)
    finally:
        sched.close()
    assert sched.stats().cancelled == 1


def test_invalid_query_rejected_synchronously(csr):
    sched, key = _mk(csr, start=False)
    try:
        with pytest.raises(ValueError):
            sched.submit(key, k=0, num_iters=ITERS)
        with pytest.raises(UnknownMatrixError):
            sched.submit("nope", k=2, num_iters=ITERS)
        assert sched.stats().queue_depth == 0  # nothing poisoned the queue
    finally:
        sched.close()


def test_close_fails_leftover_requests(csr):
    sched, key = _mk(csr, start=False)
    h = sched.submit(key, k=2, num_iters=ITERS)
    sched.close()
    with pytest.raises(ServingError):
        h.result(timeout=5.0)


# ------------------------------------------------------------- concurrency


def test_concurrent_submitters_all_served_correctly(csr):
    ref = prepare(csr, reorth="full", backend="single")
    expect = {k: np.asarray(ref.eigsh(k=k, num_iters=ITERS, reorth="full").eigenvalues)
              for k in (2, 3, 4)}
    results = {}
    errors = []
    sched, key = _mk(csr, admission_window_s=0.05, max_group=16)
    try:
        def client(tid):
            try:
                hs = [
                    (k, sched.submit(key, k=k, num_iters=ITERS, reorth="full"))
                    for k in (2, 3, 4)
                ]
                results[tid] = [(k, h.result(timeout=120.0)) for k, h in hs]
            except Exception as exc:  # surfaced below: the test thread must not die silently
                errors.append(exc)

        threads = [threading.Thread(target=client, args=(t,)) for t in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    finally:
        sched.close()

    assert not errors
    assert len(results) == 4
    for per_thread in results.values():
        for k, r in per_thread:
            assert r.k == k
            np.testing.assert_allclose(np.asarray(r.eigenvalues), expect[k], rtol=1e-10)
    stats = sched.stats()
    assert stats.completed == 12
    assert stats.groups < 12  # concurrency actually coalesced something
    assert stats.batch_occupancy > 1.0


# ------------------------------------------------------------ warm restarts


def test_warm_restart_round_trip_zero_conversions(csr, tmp_path):
    store = SessionStore(str(tmp_path))
    knobs = dict(store=store)

    with EigenScheduler(SchedulerConfig(), store=store) as s1:
        key = s1.add_matrix(csr, config=CFG)
        s1.submit(key, k=3, num_iters=ITERS).result(timeout=120.0)
        assert s1.stats().cold_builds == 1
    assert store.entries()  # close() persisted the session

    conv0 = conversion_count()
    with EigenScheduler(SchedulerConfig(), store=store) as s2:
        key2 = s2.add_matrix(csr, config=CFG)  # same layout config => warm hit
        assert s2.stats().warm_starts == 1
        assert s2.stats().cold_builds == 0
        res = s2.submit(key2, k=3, num_iters=ITERS).result(timeout=120.0)
    assert conversion_count() - conv0 == 0
    assert res.session_reuse  # served straight from the imported plan
    assert res.partition["spmv"]["conversions"] == 0

    # Same math after the restart as before it.
    ref = prepare(csr, reorth="full", backend="single").eigsh(
        k=3, num_iters=ITERS, reorth="full"
    )
    np.testing.assert_allclose(
        np.asarray(res.eigenvalues), np.asarray(ref.eigenvalues), rtol=1e-10
    )


def test_layout_config_change_misses_the_store(csr, tmp_path):
    store = SessionStore(str(tmp_path))
    with EigenScheduler(SchedulerConfig(), store=store) as s1:
        s1.add_matrix(csr, config=CFG)
    with EigenScheduler(SchedulerConfig(), store=store) as s2:
        s2.add_matrix(csr, config=SolverConfig(reorth="full", backend="auto"))
        assert s2.stats().warm_starts == 0  # layout fingerprint differs
        assert s2.stats().cold_builds == 1


def test_stale_persisted_state_rejected_then_cold_rebuild(csr):
    s1 = EigenSession(csr, CFG)
    s1.warmup()
    state = s1.export_state()
    assert state["plans"]

    # Version drift: a stale artifact must be refused, not trusted.
    stale = dict(state, repro_version="0.0.1")
    s2 = EigenSession(csr, CFG)
    with pytest.warns(UserWarning, match="stale persisted session rejected"):
        assert s2.import_plans(stale) == 0
    conv0 = conversion_count()
    r = s2.eigsh(k=2, num_iters=ITERS, reorth="full")
    assert conversion_count() - conv0 > 0  # cold rebuild actually happened
    assert r.k == 2

    # The untampered state imports cleanly and serves with zero conversions.
    s3 = EigenSession(csr, CFG)
    assert s3.import_plans(state) >= 1
    conv0 = conversion_count()
    r3 = s3.eigsh(k=2, num_iters=ITERS, reorth="full")
    assert conversion_count() - conv0 == 0
    np.testing.assert_array_equal(np.asarray(r3.eigenvalues), np.asarray(r.eigenvalues))


def test_session_pool_lru_eviction(csr):
    other = generate("road", 256, 3.0, seed=5, values="normalized")
    sched = EigenScheduler(SchedulerConfig(max_sessions=1), start=False)
    try:
        k1 = sched.add_matrix(csr, name="a", config=CFG)
        k2 = sched.add_matrix(other, name="b", config=CFG)
        assert sched.stats().sessions == 1
        sched.session(k2)
        with pytest.raises(UnknownMatrixError):
            sched.session(k1)
    finally:
        sched.close()
