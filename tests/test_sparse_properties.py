"""Hypothesis-driven property tests (optional ``[test]`` extra).

Skipped wholesale when ``hypothesis`` is absent; ``test_sparse.py`` runs the
same check bodies from a fixed seeded-random case list in that case.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from sparse_checks import check_nnz_balance, check_partition_spmv_equivalence  # noqa: E402


@given(
    n=st.integers(16, 300),
    deg=st.floats(1.0, 8.0),
    g=st.integers(1, 7),
)
@settings(max_examples=20, deadline=None)
def test_partition_spmv_equivalence(n, deg, g):
    check_partition_spmv_equivalence(n, deg, g)


@given(g=st.integers(1, 9))
@settings(max_examples=9, deadline=None)
def test_nnz_balance_property(g):
    check_nnz_balance(g)
