"""Disk-native out-of-core suite (ISSUE 10).

Covers the tentpole and its satellites end to end: the ``DiskCSR`` on-disk
format (round-trip, sampled fingerprint invalidation), the host-residency
contract of the lazily-staging ``ChunkedOperator`` (the pre-pin duplication
bugfix, regression-tested with tracemalloc), compressed bf16/fp8 staging
accuracy + counters, the chunk-cursor mid-step checkpoint (bit-identical
resume under an injected chunk I/O fault), mesh-sharded chunk residency
(subprocess, forced host devices — the test_sharding.py pattern), the
disk-pressure dispatch rule, session-cache invalidation for path inputs,
and the SessionStore's header-only pointer entries with fingerprint-checked
revival.
"""

import json
import os
import subprocess
import sys
import tracemalloc

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import eigsh, session_cache_clear
from repro.api.dispatch import select_backend
from repro.api.session import SolverConfig, get_session, prepare
from repro.core.operators import ChunkedOperator
from repro.kernels import make_engine
from repro.serving import SessionStore
from repro.sparse import (
    DiskCSR,
    diskcsr_fingerprint,
    generate,
    is_diskcsr,
    open_diskcsr,
    save_diskcsr,
)
from repro.testing import faults

K = 4
ITERS = 20
CHUNK_NNZ = 512  # several chunks for the 384-node web below (~2.3k nnz)


@pytest.fixture(autouse=True)
def _clean_slate():
    faults.reset()
    session_cache_clear()
    yield
    faults.reset()
    session_cache_clear()


@pytest.fixture(scope="module")
def web():
    return generate("web", 384, 6.0, seed=7, values="normalized")


@pytest.fixture(scope="module")
def disk(web, tmp_path_factory):
    path = tmp_path_factory.mktemp("diskcsr") / "web384"
    save_diskcsr(str(path), web)
    return open_diskcsr(path)


def _dense_ref(csr, x):
    import scipy.sparse as sp

    a = sp.csr_matrix(
        (np.asarray(csr.data, np.float64), csr.indices, csr.indptr), shape=csr.shape
    )
    return a @ np.asarray(x, np.float64)


def _ell_op(csr, **kw):
    eng = make_engine(csr=csr, format="ell", interpret=True)
    return ChunkedOperator(csr, chunk_nnz=CHUNK_NNZ, engine=eng, **kw)


# ----------------------------------------------------------- disk format


def test_diskcsr_roundtrip(web, disk):
    assert is_diskcsr(disk.path)
    assert disk.n == web.n and disk.nnz == web.nnz
    back = disk.to_csr()
    np.testing.assert_array_equal(back.indptr, web.indptr)
    np.testing.assert_array_equal(back.indices, web.indices)
    np.testing.assert_array_equal(back.data, web.data)
    assert disk.nbytes_on_disk() >= web.data.nbytes
    # the mapping is memmap-backed, not a heap copy
    assert isinstance(disk.data, np.memmap)


def test_diskcsr_open_rejects_other_dirs(tmp_path):
    assert not is_diskcsr(tmp_path)
    with pytest.raises((FileNotFoundError, ValueError)):
        open_diskcsr(tmp_path)


def test_diskcsr_fingerprint_stable_and_content_sensitive(web, tmp_path):
    p = tmp_path / "m"
    save_diskcsr(str(p), web)
    fp1 = diskcsr_fingerprint(p)
    assert fp1 == diskcsr_fingerprint(p)  # stable across calls / reopen
    # flip one payload byte: the sampled fingerprint must move
    data = p / "data.npy"
    raw = bytearray(data.read_bytes())
    raw[-1] ^= 0xFF
    data.write_bytes(bytes(raw))
    assert diskcsr_fingerprint(p) != fp1


def test_diskcsr_fingerprint_tracks_header(web, tmp_path):
    p = tmp_path / "m"
    save_diskcsr(str(p), web)
    fp1 = diskcsr_fingerprint(p)
    hdr = json.loads((p / "header.json").read_text())
    hdr["data_dtype"] = "float32"  # lie about the payload dtype
    (p / "header.json").write_text(json.dumps(hdr))
    assert diskcsr_fingerprint(p) != fp1


# ------------------------------------------- host-residency contract (bugfix)


def test_init_does_not_prepin_chunks():
    """The headline bugfix: construction must be O(n) metadata — no second
    pinned copy of the matrix payload (the old eager pre-pin doubled host
    memory before the first matvec)."""
    big = generate("web", 8192, 16.0, seed=5, values="normalized")
    payload = int(big.data.nbytes + big.indices.nbytes)
    eng = make_engine(csr=big, format="ell", interpret=True)
    tracemalloc.start()
    try:
        tracemalloc.reset_peak()
        op = ChunkedOperator(big, chunk_nnz=1 << 14, engine=eng)
        _, peak = tracemalloc.get_traced_memory()
    finally:
        tracemalloc.stop()
    assert op._pinned is None and op._csr is big
    # metadata only: far below one payload copy (the old bug pinned ~1x here)
    assert peak < payload // 4, (peak, payload)


def test_lazy_residency_bounded_by_stage_depth(web):
    op = _ell_op(web, stage_depth=1)
    assert op.num_chunks >= 3
    x = jnp.ones((web.n,), jnp.float64)
    y = op.matvec(x, accum_dtype=jnp.float64)
    np.testing.assert_allclose(np.asarray(y), _dense_ref(web, x), rtol=1e-6)
    st = op.staging_stats()
    assert st["max_resident"] <= op.stage_depth + 1
    assert st["transfers"] == op.num_chunks
    assert st["bytes_staged"] > 0 and st["bytes_plain"] > 0


def test_own_data_pins_then_frees_source(web):
    import dataclasses

    handed = dataclasses.replace(
        web, indptr=web.indptr.copy(), indices=web.indices.copy(), data=web.data.copy()
    )
    op = _ell_op(handed, own_data=True)
    assert op._csr is None and op._row_nnz is None  # source handed over
    assert op._pinned is not None and len(op._pinned) == op.num_chunks
    x = jnp.ones((web.n,), jnp.float64)
    np.testing.assert_allclose(
        np.asarray(op.matvec(x, accum_dtype=jnp.float64)),
        _dense_ref(web, x),
        rtol=1e-6,
    )
    # repeat sweeps convert nothing: the pin is the conversion
    before = op.staging["conversions"]
    op.matvec(x, accum_dtype=jnp.float64)
    assert op.staging["conversions"] == before


def test_conversions_tick_once_per_chunk_lifetime(web):
    op = _ell_op(web)
    x = jnp.ones((web.n,), jnp.float64)
    op.matvec(x, accum_dtype=jnp.float64)
    assert op.staging["conversions"] == op.num_chunks
    op.matvec(x, accum_dtype=jnp.float64)
    # lazy staging rebuilds host windows but the counter tracks conversions
    # of distinct chunks (the session's zero-conversion reuse contract)
    assert op.staging["conversions"] == op.num_chunks


# --------------------------------------------------------- compressed staging


@pytest.mark.parametrize("mode,rtol", [("f32", 1e-6), ("bf16", 8e-3), ("fp8", 8e-2)])
def test_staging_modes_accuracy(web, mode, rtol):
    op = _ell_op(web, staging=mode)
    assert op.staging_mode == mode
    x = jnp.ones((web.n,), jnp.float64)
    y = np.asarray(op.matvec(x, accum_dtype=jnp.float64))
    ref = _dense_ref(web, x)
    np.testing.assert_allclose(y, ref, rtol=rtol, atol=rtol * np.abs(ref).max())
    st = op.staging_stats()
    if mode == "f32":
        assert st["compression_ratio"] == pytest.approx(1.0)
    else:
        assert st["compression_ratio"] > 1.5  # narrow values + int16 deltas


def test_staging_auto_follows_storage_dtype(web):
    eng = make_engine(csr=web, format="ell", interpret=True)
    wide = ChunkedOperator(web, chunk_nnz=CHUNK_NNZ, engine=eng, staging="auto")
    assert wide.staging_mode == "f32"
    narrow = ChunkedOperator(
        web, chunk_nnz=CHUNK_NNZ, dtype=jnp.bfloat16, engine=eng, staging="auto"
    )
    assert narrow.staging_mode == "bf16"


def test_staging_env_pin_overrides_config(web, monkeypatch):
    """REPRO_CHUNK_STAGING pins the wire format for A/B runs and is part of
    the session identity: flipping it must rebuild, not serve the old plan."""
    kw = dict(policy="FFF", num_iters=ITERS, backend="chunked",
              format="ell", chunk_nnz=CHUNK_NNZ)
    monkeypatch.setenv("REPRO_CHUNK_STAGING", "bf16")
    pinned = eigsh(web, K, **kw)
    assert pinned.partition["spmv"]["staging"]["mode"] == "bf16"
    monkeypatch.delenv("REPRO_CHUNK_STAGING")
    unpinned = eigsh(web, K, **kw)  # no cache clear: the pin keys the cache
    assert unpinned.partition["spmv"]["staging"]["mode"] == "f32"
    assert not unpinned.session_reuse


def test_packed_staging_demotes_on_coo(web):
    op = ChunkedOperator(web, chunk_nnz=CHUNK_NNZ, staging="bf16")  # no engine: COO
    assert op.spmv_format == "coo" and op.staging_mode == "f32"
    x = jnp.ones((web.n,), jnp.float64)
    np.testing.assert_allclose(
        np.asarray(op.matvec(x, accum_dtype=jnp.float64)),
        _dense_ref(web, x),
        rtol=1e-6,
    )


def test_staging_mode_validation(web):
    with pytest.raises(ValueError, match="staging mode"):
        ChunkedOperator(web, staging="int4")


# --------------------------------------------------- disk-backed end to end


def test_disk_backed_matvec_matches_inram(web, disk):
    x = jnp.ones((web.n,), jnp.float64)
    y_ram = _ell_op(web).matvec(x, accum_dtype=jnp.float64)
    op = _ell_op(disk, staging="bf16")
    assert op.disk_backed and op.source_path == disk.path
    y_disk_packed = op.matvec(x, accum_dtype=jnp.float64)
    np.testing.assert_allclose(
        np.asarray(y_disk_packed), np.asarray(y_ram), rtol=8e-3, atol=8e-3
    )


def test_eigsh_accepts_path_and_matches_inram(web, disk):
    kw = dict(
        policy="FFF", num_iters=ITERS, backend="chunked", format="ell",
        chunk_nnz=CHUNK_NNZ,
    )
    ref = eigsh(web, K, **kw)
    session_cache_clear()
    res = eigsh(str(disk.path), K, **kw)  # a plain path is a valid input
    np.testing.assert_array_equal(
        np.asarray(ref.eigenvalues), np.asarray(res.eigenvalues)
    )
    assert res.partition["disk_backed"]
    st = res.partition["spmv"]["staging"]
    assert st["transfers"] > 0 and st["bytes_staged"] > 0
    assert st["effective_bandwidth_gbps"] >= 0.0
    assert st["mode"] == "f32" and st["compression_ratio"] == pytest.approx(1.0)


def test_eigsh_packed_staging_matches_f32(web):
    kw = dict(
        policy="FFF", num_iters=ITERS, backend="chunked", format="ell",
        chunk_nnz=CHUNK_NNZ,
    )
    r_f32 = eigsh(web, K, staging="f32", **kw)
    session_cache_clear()
    r_bf16 = eigsh(web, K, staging="bf16", **kw)
    np.testing.assert_allclose(
        np.asarray(r_f32.eigenvalues), np.asarray(r_bf16.eigenvalues), rtol=2e-2
    )
    st = r_bf16.partition["spmv"]["staging"]
    assert st["mode"] == "bf16"
    assert st["compression_ratio"] > 1.5


# -------------------------------------------- chunk-cursor checkpoint resume


def test_matvec_resume_bit_identical(web):
    op = _ell_op(web)
    assert op.num_chunks >= 3
    x = jnp.ones((web.n,), jnp.float64)
    partials = {}
    ref = op.matvec(
        x, accum_dtype=jnp.float64, on_chunk=lambda c, y: partials.__setitem__(c, y)
    )
    resumed = op.matvec(
        x, accum_dtype=jnp.float64, start_chunk=2, partial_y=partials[1]
    )
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(resumed))


def test_set_resume_consumed_by_one_matvec(web):
    op = _ell_op(web)
    x = jnp.ones((web.n,), jnp.float64)
    partials = {}
    ref = op.matvec(
        x, accum_dtype=jnp.float64, on_chunk=lambda c, y: partials.__setitem__(c, y)
    )
    op.set_resume(1, partials[0])
    np.testing.assert_array_equal(
        np.asarray(ref), np.asarray(op.matvec(x, accum_dtype=jnp.float64))
    )
    assert op._resume is None  # armed once, consumed once
    np.testing.assert_array_equal(
        np.asarray(ref), np.asarray(op.matvec(x, accum_dtype=jnp.float64))
    )


def test_chunk_io_fault_resume_bit_identical(web, tmp_path):
    """A chunk I/O fault mid-step must leave a chunk-cursor snapshot whose
    resume replays to bit-identical eigenpairs (satellite 3)."""
    kw = dict(
        policy="FFF", num_iters=ITERS, backend="chunked", format="ell",
        chunk_nnz=1024, seed=3,
    )
    ref = eigsh(web, K, **kw)
    session_cache_clear()
    with faults.inject("chunk_io_error@chunk=2"):
        with pytest.raises(OSError):
            eigsh(web, K, checkpoint_dir=str(tmp_path), **kw)
    session_cache_clear()
    res = eigsh(web, K, checkpoint_dir=str(tmp_path), **kw)
    np.testing.assert_array_equal(
        np.asarray(ref.eigenvalues), np.asarray(res.eigenvalues)
    )
    np.testing.assert_array_equal(
        np.asarray(ref.eigenvectors), np.asarray(res.eigenvectors)
    )


# ------------------------------------------------- dispatch: disk pressure


def test_dispatch_disk_pressure_forces_chunked():
    big = 1 << 30
    assert (
        select_backend(
            "auto", has_matrix=True, nnz=1000, disk_bytes=big, free_bytes=big
        )
        == "chunked"
    )
    # overrides even an explicit tol (restarted would materialize the mapping)
    assert (
        select_backend(
            "auto", has_matrix=True, nnz=1000, tol=1e-8, disk_bytes=big,
            free_bytes=big,
        )
        == "chunked"
    )


def test_dispatch_unknown_budget_is_conservative(monkeypatch):
    # platform can't report free memory: a disk mapping streams, full stop
    from repro.api import dispatch

    monkeypatch.setattr(dispatch, "host_available_bytes", lambda: None)
    assert (
        select_backend("auto", has_matrix=True, nnz=1000, disk_bytes=1) == "chunked"
    )


def test_dispatch_small_disk_matrix_falls_through():
    assert (
        select_backend(
            "auto", has_matrix=True, nnz=1000, tol=1e-8, disk_bytes=1 << 10,
            free_bytes=1 << 30,
        )
        == "restarted"
    )


# ------------------------------------------------ session cache + SessionStore


def test_session_cache_hits_and_invalidates_on_disk_change(web, tmp_path):
    p = tmp_path / "m"
    save_diskcsr(str(p), web)
    cfg = SolverConfig(backend="chunked", format="ell", chunk_nnz=CHUNK_NNZ)
    _, hit0 = get_session(str(p), cfg)
    assert not hit0
    _, hit1 = get_session(str(p), cfg)
    assert hit1  # same bytes, same layout: served from cache, O(1) I/O probe
    data = p / "data.npy"
    raw = bytearray(data.read_bytes())
    raw[-1] ^= 0xFF
    data.write_bytes(bytes(raw))
    _, hit2 = get_session(str(p), cfg)
    assert not hit2  # content moved under the path: fingerprint key misses


def test_store_persists_header_only_pointer(web, disk, tmp_path):
    session = prepare(
        disk, backend="chunked", format="ell", chunk_nnz=CHUNK_NNZ, num_iters=ITERS
    )
    store = SessionStore(str(tmp_path))
    path = store.save(session)
    assert path is not None and store.entries()
    # the entry is a POINTER: no O(nnz) payload copied into the store
    npz_bytes = (path / "plans.npz").stat().st_size
    assert npz_bytes < disk.nbytes_on_disk() // 4
    state = store.load_state(session)
    ref = state["matrix_ref"]
    assert ref["kind"] == "diskcsr" and ref["path"] == disk.path
    revived = SessionStore.revive_matrix(state)
    assert isinstance(revived, DiskCSR) and revived.n == web.n


def test_store_revive_rejects_changed_bytes(web, tmp_path):
    p = tmp_path / "m"
    save_diskcsr(str(p), web)
    session = prepare(
        open_diskcsr(p), backend="chunked", format="ell", chunk_nnz=CHUNK_NNZ,
        num_iters=ITERS,
    )
    store = SessionStore(str(tmp_path / "store"))
    store.save(session)
    state = store.load_state(session)
    data = p / "data.npy"
    raw = bytearray(data.read_bytes())
    raw[-1] ^= 0xFF
    data.write_bytes(bytes(raw))
    with pytest.warns(UserWarning, match="fingerprint mismatch"):
        assert SessionStore.revive_matrix(state) is None


def test_store_revive_rejects_missing_dir(web, disk, tmp_path):
    session = prepare(
        disk, backend="chunked", format="ell", chunk_nnz=CHUNK_NNZ, num_iters=ITERS
    )
    store = SessionStore(str(tmp_path))
    store.save(session)
    state = store.load_state(session)
    state["matrix_ref"]["path"] = str(tmp_path / "gone")
    with pytest.warns(UserWarning, match="no longer"):
        assert SessionStore.revive_matrix(state) is None


# ----------------------------------- packed staging through the auto ladder


def test_auto_ladder_escalates_off_narrow_staging_rung():
    """Fig.4-style harness (PR 5) over the out-of-core engine: with
    ``staging="auto"`` the BFF rung stages bf16-packed chunks, its
    *verified* f64 reconstruction residual misses tol, and ``policy="auto"``
    escalates to FFF whose f32 staging meets it (satellite 4)."""
    mat = generate("web", 512, 6.0, seed=11, values="normalized")
    res = eigsh(
        mat, 3, policy="auto", tol=1e-4, backend="chunked", format="ell",
        staging="auto", chunk_nnz=1024, num_iters=48,
    )
    trace = res.policy_escalations
    assert [a["policy"] for a in trace] == ["BFF", "FFF"]
    assert [a["converged"] for a in trace] == [False, True]
    assert all(a["residual_kind"] == "verified" for a in trace)
    assert trace[0]["max_residual"] > 1e-4 >= trace[1]["max_residual"]
    assert res.policy == "FFF"
    # the accepted rung's storage is f32, so auto staging shipped plain f32
    assert res.partition["spmv"]["staging"]["mode"] == "f32"


def test_packed_rung_floor_above_f32_rung():
    """The packed-staging analogue of the Fig.4 monotonicity check: a bf16
    staged solve's verified error floor sits above the f32 staged one on the
    same rung/budget."""
    mat = generate("web", 512, 6.0, seed=11, values="normalized")
    import scipy.sparse as sp

    a = sp.csr_matrix(
        (np.asarray(mat.data, np.float64), mat.indices, mat.indptr), shape=mat.shape
    )

    def floor(staging):
        session_cache_clear()
        r = eigsh(
            mat, 3, policy="FFF", backend="chunked", format="ell",
            staging=staging, chunk_nnz=1024, num_iters=48,
        )
        x = np.asarray(r.eigenvectors, np.float64)
        lam = np.asarray(r.eigenvalues, np.float64)
        resid = np.linalg.norm(a @ x - x * lam, axis=0)
        return float(np.max(resid / np.maximum(np.abs(lam), 1e-300)))

    assert floor("f32") < floor("bf16") < floor("fp8")


# ------------------------------------------- sharded chunk residency (PR 3)

_SHARD_SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp
from repro.core.operators import ChunkedOperator
from repro.kernels import make_engine
from repro.sparse import generate

csr = generate("web", 384, 6.0, seed=7, values="normalized")
a = sp.csr_matrix((np.asarray(csr.data, np.float64), csr.indices, csr.indptr), shape=csr.shape)
x = jnp.ones((csr.n,), jnp.float64)
ref = a @ np.ones((csr.n,), np.float64)
mesh = jax.make_mesh((8,), ("data",))
out = {}
for mode in ("f32", "bf16"):
    eng = make_engine(csr=csr, format="ell", interpret=True)
    op = ChunkedOperator(csr, chunk_nnz=2048, engine=eng, staging=mode,
                         mesh=mesh, axis="data")
    y = np.asarray(op.matvec(x, accum_dtype=jnp.float64))
    tol = 1e-6 if mode == "f32" else 8e-3
    out[mode] = bool(np.allclose(y, ref, rtol=tol, atol=tol))
    out[mode + "_chunks"] = int(op.num_chunks)
print("JSON:" + json.dumps(out))
"""


def test_sharded_chunk_residency_subprocess():
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_SCRIPT], capture_output=True, text=True,
        env=env, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("JSON:")][-1]
    out = json.loads(line[5:])
    assert out["f32"] and out["bf16"]
    assert out["f32_chunks"] >= 2
