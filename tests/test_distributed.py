"""Multi-device eigensolver tests (subprocess: 8 forced host devices).

The main pytest process keeps 1 device by contract (see conftest.py); these
tests re-exec python with XLA_FLAGS to get a fake 8-device mesh, the same
mechanism the multi-pod dry-run uses at 512.
"""

import json
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from jax.sharding import Mesh
from repro.sparse import generate
from repro.core import make_operator, FDF, FFF
from repro.core.eigensolver import topk_eigs
from repro.core.metrics import eigsh_reference, reconstruction_error

out = {}
csr = generate("web", 4096, 6.0, seed=3, values="unit")
ref_vals, _ = eigsh_reference(csr, 4)
devs = np.array(jax.devices())
out["num_devices"] = len(devs)

from repro.api import eigsh

for g in (2, 8):
    mesh = Mesh(devs[:g].reshape(g), ("data",))
    # Pin the segment-sum reference path so the kernel run below has an
    # independent baseline (format="auto" would also pick the kernels).
    r = eigsh(csr, 4, backend="distributed", mesh=mesh, policy=FDF,
              reorth="full", num_iters=24, seed=1, format="coo")
    out[f"vals_g{g}"] = np.asarray(r.eigenvalues, dtype=np.float64).tolist()
    op = make_operator(csr, "coo")
    out[f"recon_g{g}"] = reconstruction_error(op, r.eigenvalues, r.eigenvectors, accum_dtype=jnp.float64)

r1 = topk_eigs(make_operator(csr, "coo", dtype=jnp.float32), 4, policy=FDF,
               reorth="full", num_iters=24,
               v1=jnp.asarray(np.random.default_rng(1).standard_normal(csr.n)))
out["vals_single"] = np.asarray(r1.eigenvalues, dtype=np.float64).tolist()
out["vals_ref"] = ref_vals.tolist()

# eigsh frontend on the full mesh with format="auto": the hot loop must run a
# Pallas kernel format (never COO segment_sum) and report the decision.
mesh8 = Mesh(devs.reshape(len(devs)), ("data",))
rk = eigsh(csr, 4, backend="distributed", policy=FDF, reorth="full",
           num_iters=24, seed=1, mesh=mesh8)
out["kernel_spmv_format"] = list(rk.spmv_format)
out["kernel_partition_spmv"] = rk.partition["spmv"]["format"]
out["vals_kernel"] = np.asarray(rk.eigenvalues, dtype=np.float64).tolist()
print("JSON:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.pop("JAX_PLATFORMS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT], capture_output=True, text=True, env=env, timeout=900
    )
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-4000:]}"
    line = [l for l in proc.stdout.splitlines() if l.startswith("JSON:")][-1]
    return json.loads(line[len("JSON:"):])


def test_runs_on_8_devices(dist_results):
    assert dist_results["num_devices"] == 8


def test_sharded_matches_reference(dist_results):
    import numpy as np

    ref = np.array(dist_results["vals_ref"])
    for g in (2, 8):
        got = np.array(dist_results[f"vals_g{g}"])
        # top pairs converge tightly; trailing Ritz pairs to looser tol
        np.testing.assert_allclose(got[:2], ref[:2], rtol=1e-5)
        np.testing.assert_allclose(got, ref, rtol=1e-2)


def test_shard_count_invariance(dist_results):
    """G=2 and G=8 agree to reduction-order tolerance (paper's correctness
    criterion for the partition scheme)."""
    import numpy as np

    a = np.array(dist_results["vals_g2"])
    b = np.array(dist_results["vals_g8"])
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_reconstruction_quality(dist_results):
    assert dist_results["recon_g8"] < 1e-2


def test_distributed_spmv_is_kernel_backed(dist_results):
    """format="auto" on the distributed backend picks a Pallas kernel layout
    for every shard and reports it through EigenResult."""
    import numpy as np

    fmts = dist_results["kernel_spmv_format"]
    assert len(fmts) == 8
    assert all(f in ("ell", "bsr", "hybrid") for f in fmts)
    assert dist_results["kernel_partition_spmv"] == fmts[0]
    # same solver, same start vector: the kernel path must agree with the
    # independent segment-sum run (vals_g8 pins format="coo") to
    # reduction-order tolerance
    np.testing.assert_allclose(
        np.array(dist_results["vals_kernel"]),
        np.array(dist_results["vals_g8"]),
        rtol=1e-6,
    )
