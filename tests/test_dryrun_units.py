"""Unit tests for dry-run machinery that don't need 512 devices:
HLO collective parsing, CPU-artifact heuristic, cell planning, shape specs."""

import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, applicable, get_config, input_specs
from repro.launch.dryrun import (
    accum_steps_for,
    collective_bytes,
    cpu_upcast_artifact_bytes,
    estimate_param_count,
    plan_cell,
)


def test_collective_parser():
    hlo = """
      %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups=...
      %ar.1 = f32[1024]{0} all-reduce-start(%y), to_apply=%sum
      %rs = f32[64,32]{1,0} reduce-scatter(%z)
      %cp = bf16[16]{0} collective-permute(%w)
      %a2a = s8[4,4]{1,0} all-to-all(%v)
    """
    out = collective_bytes(hlo)
    assert out["counts"] == {"all-gather": 1, "all-reduce": 1, "reduce-scatter": 1,
                             "collective-permute": 1, "all-to-all": 1}
    assert out["bytes_by_op"]["all-gather"] == 8 * 128 * 2
    assert out["bytes_by_op"]["all-reduce"] == 1024 * 4 * 2  # ring factor 2
    assert out["bytes_by_op"]["all-to-all"] == 16


def test_cpu_artifact_heuristic():
    big = "999999,1024"  # ~4 GB in f32
    hlo = f"%a = bf16[{big}] p()\n%b = f32[{big}] convert(%a)\n%c = f32[2,2] p()"
    n = cpu_upcast_artifact_bytes(hlo)
    assert n == 0  # ndim < 3 excluded
    big3 = "64,4096,4096"
    hlo = f"%a = bf16[{big3}] p()\n%b = f32[{big3}] convert(%a)"
    assert cpu_upcast_artifact_bytes(hlo) == 64 * 4096 * 4096 * 4


def test_param_count_estimates_sane():
    # cross-check against known public sizes (loose bands)
    bands = {
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "phi3-medium-14b": (12e9, 16e9),
        "codeqwen1.5-7b": (6e9, 8.5e9),
        "qwen1.5-32b": (30e9, 36e9),
        "mixtral-8x7b": (44e9, 50e9),
        "arctic-480b": (430e9, 520e9),
        "qwen2-vl-72b": (68e9, 80e9),
        "mamba2-130m": (0.1e9, 0.2e9),
    }
    for arch, (lo, hi) in bands.items():
        n = estimate_param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9},{hi/1e9}]"


def test_plan_cell_decisions():
    # arctic training cannot fit AdamW on a pod -> adafactor + bf16
    cfg, opt, _ = plan_cell(get_config("arctic-480b"), SHAPES["train_4k"], 256)
    assert opt == "adafactor" and cfg.param_dtype == jnp.bfloat16
    # small model keeps AdamW f32
    cfg, opt, _ = plan_cell(get_config("qwen3-0.6b"), SHAPES["train_4k"], 256)
    assert opt == "adamw" and cfg.param_dtype == jnp.float32
    # big MHA decode gets an int8 cache
    cfg, opt, _ = plan_cell(get_config("qwen1.5-32b"), SHAPES["decode_32k"], 256)
    assert cfg.kv_cache_dtype == "int8" and cfg.param_dtype == jnp.bfloat16
    # GQA small-cache decode stays bf16
    cfg, opt, _ = plan_cell(get_config("mixtral-8x7b"), SHAPES["decode_32k"], 256)
    assert cfg.kv_cache_dtype == "bf16"


def test_accum_rules():
    assert accum_steps_for(get_config("qwen2-vl-72b"), SHAPES["train_4k"]) == 8
    assert accum_steps_for(get_config("phi3-medium-14b"), SHAPES["train_4k"]) == 4
    assert accum_steps_for(get_config("qwen3-0.6b"), SHAPES["train_4k"]) == 1
    assert accum_steps_for(get_config("qwen3-0.6b"), SHAPES["decode_32k"]) == 1


def test_applicability_matrix():
    cells = [(a, s) for a in ARCHS for s in SHAPES if applicable(a, s)]
    assert len(cells) == 33  # 10 archs x 4 shapes - 7 long_500k skips
    assert ("mamba2-130m", "long_500k") in cells
    assert ("qwen3-0.6b", "long_500k") not in cells


@pytest.mark.parametrize("arch", list(ARCHS))
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_build(arch, shape):
    if not applicable(arch, shape):
        pytest.skip("cell not applicable")
    cfg = get_config(arch)
    specs = input_specs(cfg, SHAPES[shape])
    assert "tokens" in specs
    if SHAPES[shape].mode == "decode":
        assert "state" in specs
        assert specs["tokens"].shape[1] == 1
    elif SHAPES[shape].mode == "train":
        assert "labels" in specs
