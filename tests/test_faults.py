"""Fault-matrix suite: every injection point x every engine.

Each case asserts the documented outcome of ``ISSUE`` section "robustness":
a typed error (``NumericalBreakdown`` / ``InjectedFault`` subclasses /
serving errors) under ``recovery="raise"``, or a converged result carrying
an explicit ``recovery_trail`` under ``recovery="auto"`` — never a hang,
never a NaN result.  Plus the checkpoint/resume round-trips (bit-identical
eigenvalues after a mid-solve crash) and the scheduler's retry / circuit
breaker / watchdog / dispatch-loop-guard behaviors.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.api import NumericalBreakdown, eigsh, session_cache_clear
from repro.api.coerce import coerce_input
from repro.api.result import EigenResult
from repro.serving import (
    EigenScheduler,
    SchedulerConfig,
    SchedulerCrashedError,
    ServingError,
    SessionUnhealthyError,
    SolveCheckpoint,
)
from repro.sparse import generate
from repro.testing import faults

K = 4
ITERS = 20


@pytest.fixture(autouse=True)
def _clean_slate():
    faults.reset()
    session_cache_clear()
    yield
    faults.reset()
    session_cache_clear()


@pytest.fixture(scope="module")
def web():
    return generate("web", 384, 6.0, seed=7, values="normalized")


@pytest.fixture(scope="module")
def small():
    return generate("web", 256, 6.0, seed=3, values="normalized")


def _trail_actions(res):
    return [t["action"] for t in (res.recovery_trail or [])]


# ---------------------------------------------------------------------------
# grammar + registry mechanics


def test_parse_fault_grammar():
    fs = faults.parse_fault("spmv_nan@iter=3,count=2")
    assert (fs.kind, fs.iteration, fs.count) == ("spmv_nan", 3, 2)
    assert faults.parse_fault("chunk_io_error@chunk=1").iteration == 1
    assert faults.parse_fault("solve_crash@cycle=4").iteration == 4
    assert faults.parse_fault("kernel_error").iteration is None


@pytest.mark.parametrize(
    "bad", ["frobnicate", "spmv_nan@iter", "spmv_nan@iter=x", "spmv_nan@depth=3"]
)
def test_parse_fault_rejects(bad):
    with pytest.raises(ValueError):
        faults.parse_fault(bad)


def test_inject_arms_and_disarms():
    assert faults.fault_spec("spmv_nan") is None
    with faults.inject("spmv_nan@iter=1") as fs:
        assert faults.fault_spec("spmv_nan") is fs
    assert faults.fault_spec("spmv_nan") is None


def test_fault_count_exhaustion():
    u = jnp.ones((4,), jnp.float32)
    with faults.inject("spmv_nan@iter=1,count=2") as fs:
        for _ in range(3):
            faults.tap_spmv(u, 1)  # host path: int step consumes directly
        assert fs.fired == 2  # third application was inert
        assert faults.fault_spec("spmv_nan") is None


def test_consume_lanczos_counts_per_launch():
    with faults.inject("spmv_nan@iter=1") as fs:
        key = faults.trace_key()
        assert key and key[0][0] == "spmv_nan"
        faults.consume_lanczos(key)
        assert fs.fired == 1
        assert faults.trace_key() is None  # exhausted -> clean key
    faults.consume_lanczos(None)  # no-op


def test_env_var_injection(monkeypatch, small):
    monkeypatch.setenv("REPRO_FAULT", "spmv_nan@iter=2")
    with pytest.raises(NumericalBreakdown) as ei:
        eigsh(small, K, policy="FFF", num_iters=ITERS, recovery="raise")
    assert ei.value.kind == "nonfinite"


# ---------------------------------------------------------------------------
# typed breakdowns, per engine (recovery="raise")

ENGINES = ["single", "restarted", "chunked", "distributed"]


def _solve(a, backend, **kw):
    kw.setdefault("policy", "FFF")
    kw.setdefault("num_iters", ITERS)
    if backend == "restarted":
        kw.setdefault("subspace", 12)
        kw.setdefault("tol", 1e-10)
        kw.pop("num_iters")
    if backend == "chunked":
        kw.setdefault("chunk_nnz", 1024)
    return eigsh(a, K, backend=backend, **kw)


@pytest.mark.parametrize("backend", ENGINES)
def test_spmv_nan_raises_typed(web, backend):
    with faults.inject("spmv_nan@iter=3"):
        with pytest.raises(NumericalBreakdown) as ei:
            _solve(web, backend, recovery="raise")
    exc = ei.value
    assert exc.kind == "nonfinite"
    assert exc.iteration == 3
    assert exc.policy  # names the policy it broke under


@pytest.mark.parametrize("backend", ENGINES)
def test_beta_collapse_raises_typed(web, backend):
    with faults.inject("beta_collapse@iter=2"):
        with pytest.raises(NumericalBreakdown) as ei:
            _solve(web, backend, recovery="raise")
    exc = ei.value
    assert exc.kind == "beta_underflow"
    assert exc.iteration == 2


def test_recovery_none_disables_probe(web):
    # The pre-robustness contract: no probe, the NaN flows into the result.
    with faults.inject("spmv_nan@iter=3"):
        res = _solve(web, "single", recovery="none")
    assert not np.all(np.isfinite(np.asarray(res.eigenvalues)))


# ---------------------------------------------------------------------------
# recovery="auto": documented escalation per failure class


@pytest.mark.parametrize("backend", ENGINES)
def test_auto_escalates_policy_on_nan(web, backend):
    with faults.inject("spmv_nan@iter=3"):
        res = _solve(web, backend, recovery="auto")
    assert "escalate_policy" in _trail_actions(res)
    step = next(t for t in res.recovery_trail if t["action"] == "escalate_policy")
    assert (step["from"], step["to"]) == ("FFF", "FCF")
    assert step["kind"] == "nonfinite"
    assert np.all(np.isfinite(np.asarray(res.eigenvalues)))


@pytest.mark.parametrize("backend", ["single", "restarted"])
def test_auto_reseeds_on_beta_collapse(web, backend):
    with faults.inject("beta_collapse@iter=2"):
        res = _solve(web, backend, recovery="auto")
    step = next(t for t in res.recovery_trail if t["action"] == "reseed")
    assert step["kind"] == "beta_underflow"
    assert step["from"] != step["to"]
    assert np.all(np.isfinite(np.asarray(res.eigenvalues)))


def test_kernel_error_raise_mode_propagates(web):
    with faults.inject("kernel_error"):
        with pytest.raises(faults.InjectedKernelError):
            _solve(web, "single", recovery="raise")


def test_auto_unfuses_on_kernel_error(web):
    with faults.inject("kernel_error"):
        res = _solve(web, "single", recovery="auto")
    assert "unfuse" in _trail_actions(res)
    assert np.all(np.isfinite(np.asarray(res.eigenvalues)))


def test_oom_raise_mode_propagates(web):
    with faults.inject("oom"):
        with pytest.raises(faults.InjectedOOMError):
            _solve(web, "single", recovery="raise")


def test_auto_falls_back_to_chunked_on_oom(web):
    with faults.inject("oom"):
        res = _solve(web, "single", recovery="auto")
    assert "fallback_chunked" in _trail_actions(res)
    assert res.backend == "chunked"
    assert np.all(np.isfinite(np.asarray(res.eigenvalues)))


def test_oom_on_chunked_has_no_fallback(web):
    # Already at the bottom of the memory ladder: the typed error surfaces.
    with faults.inject("oom@iter=0,count=99"):
        with pytest.raises(faults.InjectedOOMError):
            _solve(web, "chunked", recovery="auto")


def test_chunk_io_error_is_typed_oserror(web):
    with faults.inject("chunk_io_error@chunk=0"):
        with pytest.raises(OSError) as ei:
            _solve(web, "chunked", recovery="raise")
    assert isinstance(ei.value, faults.InjectedChunkIOError)


# ---------------------------------------------------------------------------
# checkpoint / resume round-trips


def test_restarted_checkpoint_resume_bit_identical(web, tmp_path):
    kw = dict(policy="FDF", backend="restarted", tol=1e-10, subspace=16, seed=3)
    ref = eigsh(web, K, **kw)
    session_cache_clear()
    with faults.inject("solve_crash@cycle=2"):
        with pytest.raises(faults.InjectedCrash):
            eigsh(web, K, checkpoint_dir=str(tmp_path), **kw)
    store = SolveCheckpoint(str(tmp_path))
    assert store.entries(), "crash must leave a resumable snapshot"
    session_cache_clear()
    res = eigsh(web, K, checkpoint_dir=str(tmp_path), **kw)
    np.testing.assert_array_equal(
        np.asarray(ref.eigenvalues), np.asarray(res.eigenvalues)
    )
    assert not store.entries(), "completed solve must clear its checkpoint"


def test_host_loop_checkpoint_resume_bit_identical(tmp_path):
    # The chunked engine's eager loop, interrupted mid-sweep: resume from the
    # last snapshot must replay to the exact same tridiagonalization.
    from repro.core.lanczos import lanczos_tridiag
    from repro.core.precision import FDF

    rng = np.random.default_rng(0)
    a = rng.standard_normal((48, 48))
    aj = jnp.asarray((a + a.T) / 2, jnp.float64)
    pol = FDF.effective()
    v1 = jnp.asarray(rng.standard_normal(48), jnp.float64)
    m, every = 16, 4

    def mv(v):
        return aj @ v.astype(jnp.float64)

    calls = {"n": 0}

    def mv_crash(v):
        calls["n"] += 1
        if calls["n"] == 11:  # after the i=7 snapshot, before the i=11 one
            raise RuntimeError("injected mid-sweep crash")
        return mv(v)

    ref = lanczos_tridiag(mv, v1, m, pol, reorth="full", jit=False)
    store = SolveCheckpoint(str(tmp_path))
    token = SolveCheckpoint.token("unit-fp", engine="lanczos", m=m)
    with pytest.raises(RuntimeError):
        lanczos_tridiag(
            mv_crash, v1, m, pol, reorth="full", jit=False,
            checkpoint=(store, token, every),
        )
    assert store.entries(), "crash must leave a resumable snapshot"
    res = lanczos_tridiag(
        mv, v1, m, pol, reorth="full", jit=False, checkpoint=(store, token, every)
    )
    assert not store.entries()
    np.testing.assert_array_equal(np.asarray(ref.alpha), np.asarray(res.alpha))
    np.testing.assert_array_equal(np.asarray(ref.beta), np.asarray(res.beta))
    np.testing.assert_array_equal(np.asarray(ref.basis), np.asarray(res.basis))


def test_checkpoint_token_excludes_budget_knobs():
    t1 = SolveCheckpoint.token("fp", backend="restarted", policy="FDF", k=4, m=16)
    t2 = SolveCheckpoint.token("fp", backend="restarted", policy="FDF", k=4, m=16)
    t3 = SolveCheckpoint.token("fp", backend="restarted", policy="FDF", k=4, m=32)
    assert t1 == t2 != t3


# ---------------------------------------------------------------------------
# scheduler: retries, circuit breaker, watchdog, dispatch-loop guard

SK = dict(k=4, num_iters=16)


def test_scheduler_retry_recovers(small):
    cfg = SchedulerConfig(max_retries=1, retry_backoff_s=0.01, watchdog_interval_s=0.1)
    with EigenScheduler(cfg) as s:
        key = s.add_matrix(small)
        with faults.inject("spmv_nan@iter=3"):
            h = s.submit(key, **SK)
            res = h.result(timeout=120.0)
        st = s.stats()
    assert res.k == 4
    assert st.retries == 1 and st.failed == 0


def test_scheduler_retry_budget_exhausts_typed(small):
    cfg = SchedulerConfig(max_retries=1, retry_backoff_s=0.01, watchdog_interval_s=0.1)
    with EigenScheduler(cfg) as s:
        key = s.add_matrix(small)
        with faults.inject("spmv_nan@iter=3,count=99"):
            h = s.submit(key, **SK)
            exc = h.exception(timeout=120.0)
        st = s.stats()
    assert isinstance(exc, NumericalBreakdown)
    assert st.retries == 1 and st.failed == 1


def test_scheduler_never_retries_bad_requests(small):
    cfg = SchedulerConfig(max_retries=3, retry_backoff_s=0.01, watchdog_interval_s=0.1)
    with EigenScheduler(cfg) as s:
        key = s.add_matrix(small)
        with pytest.raises(ValueError):
            s.submit(key, k=4, num_iters=2)  # m < k: a caller bug, not transient
        st = s.stats()
    assert st.retries == 0  # caller bugs are rejected, never retried


def test_scheduler_circuit_breaker_cycle(small):
    import time

    cfg = SchedulerConfig(
        breaker_threshold=2, breaker_cooldown_s=0.3, watchdog_interval_s=0.1
    )
    with EigenScheduler(cfg) as s:
        key = s.add_matrix(small)
        with faults.inject("spmv_nan@iter=3,count=99"):
            for _ in range(2):
                h = s.submit(key, **SK)
                assert h.exception(timeout=120.0) is not None
        deadline = time.monotonic() + 5.0
        while s.breaker_state(key) != "open" and time.monotonic() < deadline:
            time.sleep(0.01)
        assert s.breaker_state(key) == "open"
        with pytest.raises(SessionUnhealthyError):
            s.submit(key, **SK)
        time.sleep(0.35)  # cooldown: next submit is the half-open probe
        h = s.submit(key, **SK)
        res = h.result(timeout=120.0)
        st = s.stats()
        assert res.k == 4
        assert s.breaker_state(key) == "closed"
    assert st.breaker_trips == 1
    assert st.rejected_breaker == 1


@pytest.mark.filterwarnings("ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_scheduler_watchdog_fails_pending_typed(small):
    cfg = SchedulerConfig(watchdog_interval_s=0.05)
    s = EigenScheduler(cfg)
    try:
        key = s.add_matrix(small)
        with faults.inject("scheduler_crash"):
            h = s.submit(key, **SK)
            exc = h.exception(timeout=30.0)
        assert isinstance(exc, SchedulerCrashedError)
        with pytest.raises(SchedulerCrashedError):
            s.submit(key, **SK)
        assert s.stats().watchdog_trips == 1
        s.start()  # explicit restart recovers the scheduler
        h2 = s.submit(key, **SK)
        assert h2.result(timeout=120.0).k == 4
    finally:
        s.close()


def test_scheduler_dispatch_loop_survives_internal_bug(small):
    # Regression (issue satellite a): an exception escaping the dispatch
    # loop used to kill the thread and strand every pending handle.
    s = EigenScheduler(SchedulerConfig(watchdog_interval_s=0.1))
    try:
        key = s.add_matrix(small)
        orig, calls = s._dispatch, {"n": 0}

        def boom(group):
            if calls["n"] == 0:
                calls["n"] += 1
                raise RuntimeError("synthetic dispatch bug")
            return orig(group)

        s._dispatch = boom
        h = s.submit(key, **SK)
        exc = h.exception(timeout=30.0)
        assert isinstance(exc, ServingError)
        assert "internal dispatch failure" in str(exc)
        assert s._thread.is_alive(), "dispatch thread must survive the bug"
        h2 = s.submit(key, **SK)
        assert h2.result(timeout=120.0).k == 4
        assert s.stats().dispatch_errors == 1
    finally:
        s.close()


# ---------------------------------------------------------------------------
# input validation at coercion (fail fast, named error)


def test_nan_scipy_input_rejected():
    sp = pytest.importorskip("scipy.sparse")
    a = sp.identity(8, format="csr") * 1.0
    a.data[0] = np.nan
    with pytest.raises(ValueError, match="non-finite"):
        eigsh(a, 2, num_iters=6)


def test_nan_dense_input_rejected():
    a = np.eye(8)
    a[0, 0] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        eigsh(a, 2, num_iters=6)


def test_storage_overflow_rejected():
    a = np.eye(8) * 1e5  # > float16 max under HFF storage
    with pytest.raises(ValueError, match="overflows"):
        eigsh(a, 2, policy="HFF", num_iters=6)


def test_validation_kill_switch(monkeypatch):
    monkeypatch.setenv("REPRO_VALIDATE_INPUT", "0")
    a = np.eye(8)
    a[0, 0] = np.nan
    coerce_input(a)  # must not raise with validation off


def test_scheduler_rejects_bad_matrix_at_submit_time(small):
    sp = pytest.importorskip("scipy.sparse")
    a = sp.identity(32, format="csr") * 1.0
    a.data[0] = np.nan
    with EigenScheduler(SchedulerConfig(watchdog_interval_s=0.1)) as s:
        with pytest.raises(ValueError, match="non-finite"):
            s.add_matrix(a)


# ---------------------------------------------------------------------------
# result schema


def test_recovery_trail_roundtrips_through_dict(web):
    with faults.inject("spmv_nan@iter=3"):
        res = _solve(web, "single", recovery="auto")
    assert res.recovery_trail
    back = EigenResult.from_dict(res.to_dict())
    assert back.recovery_trail == res.recovery_trail
