"""Shared property-check bodies for the sparse/partition tests.

Used twice: ``test_sparse.py`` drives them from a fixed seeded-random case
list (no external deps), and ``test_sparse_properties.py`` drives them from
``hypothesis`` strategies when that optional dependency is installed.
"""

import jax.numpy as jnp
import numpy as np

from repro.core.partition import nnz_balanced_splits, partition_matrix
from repro.sparse import generate


def check_partition_spmv_equivalence(n: int, deg: float, g: int) -> None:
    """Property: the padded partitioned SpMV == the unpartitioned SpMV."""
    csr = generate("urand", n, deg, seed=n, values="uniform")
    n = csr.n
    pm = partition_matrix(csr, g, dtype=jnp.float64, nnz_align=8)
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal(n))
    xp = pm.pad_vector(x)  # (G, n_pad)
    x_full = xp.reshape(-1)  # padded-global layout
    ys = []
    for s in range(g):
        prod = pm.val[s] * jnp.take(x_full, pm.col[s])
        ys.append(jnp.asarray(np.asarray(jnp.zeros(pm.n_pad)).copy()).at[pm.row[s]].add(prod))
    y = pm.unpad_vector(jnp.stack(ys))
    want = csr.to_scipy() @ np.asarray(x)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-9, atol=1e-9)


def check_nnz_balance(g: int) -> None:
    """Property: every shard's nnz is within one max-row-degree of n_nnz/G."""
    csr = generate("web", 4096, 6.0, seed=11, values="unit")
    splits = nnz_balanced_splits(csr.indptr, g)
    per = np.diff(csr.indptr[splits])
    assert per.sum() == csr.nnz
    max_row = int(csr.row_nnz().max())
    assert per.max() - per.min() <= 2 * max_row + csr.nnz // g  # sane balance
    # tighter: each shard within target +- max row degree
    target = csr.nnz / g
    assert np.all(np.abs(per - target) <= max_row + 1)
