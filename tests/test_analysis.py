"""repro.analysis: one red fixture per rule + shipped-tree cleanliness.

Structure mirrors the subsystem's contract:

  * every rule (P001..P004, K001..K004, C001/C002, E001/E002) has a fixture
    that *fails* it — a checker that can't go red is decoration;
  * the shipped tree passes every pass with zero findings (the CI
    ``--strict`` gate, asserted here so a local pytest run sees the same
    truth);
  * the declared phase map agrees with the jaxpr-measured op counts for the
    paper rungs on all four engines, fused and unfused (the acceptance
    sweep).
"""

import os
from pathlib import Path

import pytest

import jax
import jax.numpy as jnp

from repro.analysis import RULES, Finding, is_suppressed, run_checks
from repro.analysis import concurrency, config_lint, kernel_check, precision_flow
from repro.analysis.findings import filter_suppressed, format_findings
from repro.core.precision import (
    FFF,
    POLICIES,
    assert_phase_count_parity,
    phase_op_counts,
)

REPO_ROOT = Path(__file__).resolve().parents[1]


# ----------------------------------------------------------------- findings


def test_rules_table_complete():
    assert set(RULES) == {
        "P001", "P002", "P003", "P004",
        "K001", "K002", "K003", "K004",
        "C001", "C002", "E001", "E002",
    }


def test_finding_rejects_unknown_rule():
    with pytest.raises(ValueError):
        Finding("Z999", "nope")


def test_suppression_comment():
    assert is_suppressed("x = 1  # repro: ignore[C001]", "C001")
    assert is_suppressed("x = 1  # repro: ignore[C001, E001]", "E001")
    assert not is_suppressed("x = 1  # repro: ignore[C001]", "C002")
    assert not is_suppressed("x = 1", "C001")
    fs = [Finding("C001", "m", file="f.py", line=1), Finding("C001", "m", file="f.py", line=2)]
    kept = filter_suppressed(fs, ["a = 1  # repro: ignore[C001]", "b = 2"])
    assert [f.line for f in kept] == [2]


# ------------------------------------------------------- precision red rules


def test_p001_red_undeclared_upcast():
    with jax.experimental.enable_x64():
        jx = jax.make_jaxpr(
            lambda x: (x.astype(jnp.float64) * 2.0).astype(jnp.float32)
        )(jax.ShapeDtypeStruct((8,), jnp.float32))
        fs = precision_flow.find_upcasts(jx, FFF.effective())
    assert [f.rule for f in fs] == ["P001"]
    assert "float64" in fs[0].message


def test_p002_red_double_rounding():
    jx = jax.make_jaxpr(
        lambda x: (x.astype(jnp.bfloat16).astype(jnp.float32) * 2.0)
    )(jax.ShapeDtypeStruct((8,), jnp.float32))
    fs = precision_flow.find_double_rounding(jx, FFF.effective())
    assert [f.rule for f in fs] == ["P002"]
    assert "bfloat16" in fs[0].message


def test_p003_red_phase_leak():
    jx = jax.make_jaxpr(
        lambda a, b: jnp.sum(a.astype(jnp.bfloat16) * b.astype(jnp.bfloat16))
    )(jax.ShapeDtypeStruct((64,), jnp.float32), jax.ShapeDtypeStruct((64,), jnp.float32))
    fs = precision_flow.find_phase_leaks(jx, FFF.effective(), "alpha_beta")
    assert any(f.rule == "P003" for f in fs)


def test_p003_green_declared_dtypes():
    jx = jax.make_jaxpr(lambda a, b: jnp.sum(a * b))(
        jax.ShapeDtypeStruct((64,), jnp.float32), jax.ShapeDtypeStruct((64,), jnp.float32)
    )
    assert precision_flow.find_phase_leaks(jx, FFF.effective(), "alpha_beta") == []


def test_p004_red_parity_divergence():
    with pytest.raises(AssertionError):
        assert_phase_count_parity(
            {"float32": 1_000}, {"float32": 1_000_000}, ratio=8.0
        )
    with pytest.raises(AssertionError):  # dtype present only in measured
        assert_phase_count_parity(
            {"float32": 1_000}, {"float32": 1_000, "float64": 1_000}, ratio=8.0
        )
    # green: within ratio
    assert_phase_count_parity({"float32": 1_000}, {"float32": 3_000}, ratio=8.0)


# -------------------------------------------------------- kernel red rules


def _dot_avals(n):
    a = jax.ShapeDtypeStruct((n,), jnp.float32)
    return (a, a)


def test_k001_red_indivisible_block():
    from repro.kernels.mixed_dot import mixed_dot_kernel_call

    fs = kernel_check.check_kernel_trace(
        lambda p, q: mixed_dot_kernel_call(p, q, block=4096, interpret=False),
        _dot_avals(8000),
        "mixed_dot",
    )
    assert [f.rule for f in fs] == ["K001"]


def test_k002_red_out_of_bounds_index_map():
    from jax.experimental import pallas as pl

    def bad_kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    def bad_call(x):
        return pl.pallas_call(
            bad_kernel,
            grid=(4,),
            in_specs=[pl.BlockSpec((8,), lambda i: (i + 1,))],  # off-by-one
            out_specs=pl.BlockSpec((8,), lambda i: (i,)),
            out_shape=jax.ShapeDtypeStruct((32,), jnp.float32),
            interpret=True,
        )(x)

    fs = kernel_check.check_kernel_trace(
        bad_call, (jax.ShapeDtypeStruct((32,), jnp.float32),), "mixed_dot"
    )
    assert any(f.rule == "K002" for f in fs)


def test_k003_red_vmem_budget():
    from repro.kernels.mixed_dot import mixed_dot_kernel_call

    fs = kernel_check.check_kernel_trace(
        lambda p, q: mixed_dot_kernel_call(p, q, block=4096, interpret=False),
        _dot_avals(8192),
        "mixed_dot",
        vmem_budget=1024,  # 1 KB: everything overflows
    )
    assert any(f.rule == "K003" for f in fs)


def test_k004_red_pinned_output_on_parallel_dim():
    from repro.kernels.mixed_dot import mixed_dot_kernel_call

    # The scalar accumulator output is pinned across the grid; declaring
    # dim 0 parallel must trip the race rule.
    fs = kernel_check.check_kernel_trace(
        lambda p, q: mixed_dot_kernel_call(p, q, block=4096, interpret=False),
        _dot_avals(8192),
        "mixed_dot",
        parallel_dims=frozenset({0}),
    )
    assert any(f.rule == "K004" for f in fs)


def test_k004_green_shipped_contracts():
    # The shipped contract table accepts every shipped kernel.
    fs = kernel_check.run()
    assert [str(f) for f in fs] == []


# --------------------------------------------------- concurrency red rules


_C001_SNIPPET = """
class Sched:
    _GUARDED_BY = {"_queue": "_cv"}

    def bad(self):
        self._queue.append(1)

    def good(self):
        with self._cv:
            self._queue.append(1)
"""


def test_c001_red_unguarded_mutation():
    fs = concurrency.check_source(_C001_SNIPPET, "sched.py")
    assert [(f.rule, f.line) for f in fs] == [("C001", 6)]


_C002_SNIPPET = """
class Sched:
    _GUARDED_BY = {}

    def inverted(self):
        with self._build_lock:
            with self._cv:
                pass

    def cross(self, sess):
        with self._cv:
            sess.eigsh_many([])
"""


def test_c002_red_lock_order_and_cross_object_call():
    fs = concurrency.check_source(_C002_SNIPPET, "sched.py")
    rules = [f.rule for f in fs]
    assert rules.count("C002") == 2


def test_c001_exemptions():
    snippet = """
class S:
    _GUARDED_BY = {"_q": "_lock"}

    def __init__(self):
        self._q = []

    def _drain_locked(self):
        self._q.clear()

    def drain(self):  # repro: holds[_lock]
        self._q.clear()

    def noted(self):
        self._q.clear()  # repro: ignore[C001]
"""
    assert concurrency.check_source(snippet, "s.py") == []


# -------------------------------------------------------- config red rules


def test_e001_red_raw_env_read():
    src = """
import os
a = os.environ.get("REPRO_SPMV_TUNE")
b = os.getenv("REPRO_FAULT")
c = os.environ["REPRO_ITER_UPDATE"]
os.environ["REPRO_SPMV_TUNE"] = "1"      # write: allowed
os.environ.setdefault("REPRO_FAULT", "") # write: allowed
d = os.environ.get("HOME")               # not a knob: allowed
"""
    fs = config_lint.find_raw_env_reads(src, "m.py")
    assert [f.rule for f in fs] == ["E001"] * 3
    assert [f.line for f in fs] == [3, 4, 5]


def test_e002_red_registry_readme_drift():
    fs = config_lint.check_readme_sync({"REPRO_A", "REPRO_B"}, "only REPRO_A and REPRO_GHOST")
    msgs = sorted(f.message for f in fs)
    assert len(fs) == 2 and all(f.rule == "E002" for f in fs)
    assert any("REPRO_B" in m for m in msgs)
    assert any("REPRO_GHOST" in m for m in msgs)


def test_env_registry_contract():
    from repro.configs import env as envcfg

    with pytest.raises(KeyError):
        envcfg.knob("REPRO_NOT_A_KNOB")
    assert envcfg.get_bool("REPRO_SPMV_TUNE") is False
    os.environ["REPRO_SPMV_TUNE"] = "on"
    try:
        assert envcfg.get_bool("REPRO_SPMV_TUNE") is True
    finally:
        del os.environ["REPRO_SPMV_TUNE"]
    assert envcfg.get_float("REPRO_ANALYSIS_VMEM_MB") == 16.0


# -------------------------------------------------- shipped-tree cleanliness


def test_shipped_tree_strict_clean_static_passes():
    """The AST/config/kernel passes are clean on the tree as shipped."""
    results = run_checks(
        ["kernels", "concurrency", "config"], repo_root=str(REPO_ROOT)
    )
    for name, findings in results.items():
        assert findings == [], f"{name}: {format_findings(findings)}"


@pytest.mark.parametrize("rung", precision_flow.RUNGS)
@pytest.mark.parametrize("engine", precision_flow.ENGINES)
@pytest.mark.parametrize("fused", [False, True])
def test_declared_phase_map_matches_measured(rung, engine, fused):
    """The acceptance sweep: measured ops_by_dtype agrees with the declared
    phase map for every paper rung on every engine, fused and unfused."""
    findings, measured = precision_flow.check_policy(
        POLICIES[rung], engine, fused=fused
    )
    assert findings == [], format_findings(findings)
    assert measured and all(v > 0 for v in measured.values())


def test_device_jacobi_ritz_accounting():
    """The reconciled model attributes the device-Jacobi sweep work (the
    divergence this PR fixed) — parity must hold with jacobi='device'."""
    findings, measured = precision_flow.check_policy(
        POLICIES["FDF"], "single", jacobi="device"
    )
    assert findings == [], format_findings(findings)
    # and the model actually grew: device ritz >> host ritz (projection only)
    host = phase_op_counts(POLICIES["FDF"], n=100, nnz=400, m=8, k=4, executed=True)
    dev = phase_op_counts(
        POLICIES["FDF"], n=100, nnz=400, m=8, k=4, executed=True, jacobi="device"
    )
    assert sum(dev.values()) > sum(host.values())


def test_session_measured_hook(tmp_path):
    """REPRO_PRECISION_MEASURE=1 surfaces jaxpr-measured counts in the
    partition audit, and they parity-match the executed-convention model."""
    from repro.api import eigsh
    from repro.sparse import generate

    os.environ["REPRO_PRECISION_MEASURE"] = "1"
    try:
        csr = generate("road", 100, 4.0, seed=1)
        res = eigsh(csr, k=3)
        prec = res.partition["spmv"]["precision"]
        measured = prec.get("ops_by_dtype_measured")
        assert measured and "error" not in measured
        assert all(isinstance(v, int) and v > 0 for v in measured.values())
        # Same float dtypes as the declared model counts.
        assert set(measured) == set(prec["ops_by_dtype"])
    finally:
        del os.environ["REPRO_PRECISION_MEASURE"]


# ---------------------------------------------------------------- CLI / CI


def test_cli_strict_clean_on_fast_passes(tmp_path, capsys):
    from repro.analysis.__main__ import main

    summary = tmp_path / "summary.md"
    rc = main(
        [
            "--check", "concurrency", "--check", "config",
            "--strict",
            "--repo-root", str(REPO_ROOT),
            "--summary-out", str(summary),
        ]
    )
    assert rc == 0
    out = capsys.readouterr().out
    assert "[concurrency] 0 finding(s)" in out
    assert "clean" in summary.read_text()


def test_cli_strict_fails_on_findings(tmp_path, capsys, monkeypatch):
    from repro.analysis.__main__ import main

    # A doctored tree: a serving module mutating a guarded field lock-free.
    bad_root = tmp_path / "tree"
    (bad_root / "src" / "repro" / "serving").mkdir(parents=True)
    (bad_root / "src" / "repro" / "serving" / "bad.py").write_text(
        _C001_SNIPPET, encoding="utf-8"
    )
    rc = main(["--check", "concurrency", "--strict", "--repo-root", str(bad_root)])
    assert rc == 1
    assert "C001" in capsys.readouterr().out


def test_cli_rejects_unknown_check():
    from repro.analysis.__main__ import main

    with pytest.raises(SystemExit):
        main(["--check", "nonsense"])
