"""Logical-axis sharding rule engine: resolution, priorities, fallbacks.

Mesh-dependent tests run in a subprocess with 8 forced host devices (same
pattern as test_distributed.py) to keep the main process at 1 device.
"""

import json
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.distributed.sharding import sharding_ctx, logical_spec
mesh = jax.make_mesh((2, 4), ("data", "model"))
out = {}
with sharding_ctx(mesh):
    # heads divide the model axis -> heads claim it
    out["heads_divisible"] = str(logical_spec((8, 64, 16, 128), ("batch", "cache_seq", "act_kv_heads", None)))
    # heads don't divide -> cache_seq falls back to 'model'
    out["heads_fallback"] = str(logical_spec((8, 64, 10, 128), ("batch", "cache_seq", "act_kv_heads", None)))
    # expert doesn't divide (mixtral: 8 experts on 4-wide axis is fine; use 3)
    out["expert_ok"] = str(logical_spec((8, 4096, 512), ("expert", "embed", "moe_mlp")))
    out["expert_fallback"] = str(logical_spec((3, 4096, 512), ("expert", "embed", "moe_mlp")))
    # batch=1 (long_500k decode) -> replicated, no crash
    out["batch_1"] = str(logical_spec((1, 524288), ("batch", None)))
    # each mesh axis used at most once per tensor
    out["no_double_use"] = str(logical_spec((8, 512, 512), ("batch", "mlp", "act_mlp")))
print("JSON:" + json.dumps(out))
"""


@pytest.fixture(scope="module")
def specs():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True, text=True,
                          env=env, timeout=300)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("JSON:")][-1]
    return json.loads(line[5:])


def test_heads_take_priority_over_cache_seq(specs):
    assert specs["heads_divisible"] == "PartitionSpec('data', None, 'model', None)"


def test_cache_seq_fallback_when_heads_dont_divide(specs):
    assert specs["heads_fallback"] == "PartitionSpec('data', 'model', None, None)"


def test_expert_parallel_and_fallback(specs):
    # 8 experts on 4-wide model axis -> expert parallel; embed gets 'data'
    assert specs["expert_ok"].startswith("PartitionSpec('model'")
    # 3 experts -> expert replicated, moe_mlp picks up 'model'
    assert specs["expert_fallback"] == "PartitionSpec(None, 'data', 'model')"


def test_batch_one_replicates(specs):
    assert specs["batch_1"] == "PartitionSpec(None, None)"


def test_mesh_axis_used_once(specs):
    spec = specs["no_double_use"]
    assert spec.count("'model'") == 1  # mlp and act_mlp cannot both take it


def test_no_mesh_is_noop():
    from repro.distributed.sharding import hint, logical_spec
    import jax.numpy as jnp

    x = jnp.zeros((4, 8))
    assert hint(x, "batch", None) is x
    from jax.sharding import PartitionSpec as P

    assert logical_spec((4, 8), ("batch", None)) == P()
