"""Compile-only Pallas lowering canary (``REPRO_PALLAS_LOWER_CHECK=1``).

Interpret-mode tests exercise kernel *semantics*; this module instead pushes
every Pallas entrypoint through ``jax.jit(...).lower(...).compile()`` so API
drift in new jax releases (pallas_call signature, BlockSpec semantics, mosaic
lowering) surfaces as a compile failure on the latest-stable canary CI legs —
before anyone bumps the pin.  Nothing here checks numerics and nothing runs
the kernels; off-TPU the entrypoints are lowered in their interpret
configuration (exactly what CPU CI executes), on TPU as real mosaic kernels.

Skipped entirely unless ``REPRO_PALLAS_LOWER_CHECK=1`` — lowering each kernel
is redundant with the semantic suite on the pinned leg and just adds wall
time there.
"""

import os

import pytest

if os.environ.get("REPRO_PALLAS_LOWER_CHECK", "").lower() not in ("1", "true", "on"):
    pytest.skip(
        "Pallas lowering canary disabled (set REPRO_PALLAS_LOWER_CHECK=1)",
        allow_module_level=True,
    )

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.lanczos_fused import spmv_ell_alpha_kernel_call
from repro.kernels.lanczos_update import lanczos_update_kernel_call
from repro.kernels.mixed_dot import mixed_dot_kernel_call
from repro.kernels.ops import default_interpret
from repro.kernels.spmv_bsr import spmv_bsr_kernel_call
from repro.kernels.spmv_ell import spmv_ell_kernel_call

INTERPRET = default_interpret()


def _compile(fn, *args, **static):
    """Trace, lower and compile the entrypoint; the executable is discarded."""
    jitted = jax.jit(functools.partial(fn, interpret=INTERPRET, **static))
    jitted.lower(*args).compile()


def test_lower_mixed_dot():
    a = jnp.ones((4096,), jnp.float32)
    _compile(mixed_dot_kernel_call, a, a, block=1024, accum_dtype=jnp.float32)


def test_lower_mixed_dot_compensated():
    a = jnp.ones((4096,), jnp.bfloat16)
    _compile(
        mixed_dot_kernel_call, a, a, block=1024, accum_dtype=jnp.float32, compensated=True
    )


def test_lower_lanczos_update():
    w = jnp.ones((4096,), jnp.float32)
    a = jnp.float32(0.25)
    _compile(lanczos_update_kernel_call, w, w, w, a, a, block=1024)


def test_lower_spmv_ell():
    val = jnp.ones((64, 128), jnp.float32)
    col = jnp.zeros((64, 128), jnp.int32)
    x = jnp.ones((64,), jnp.float32)
    _compile(spmv_ell_kernel_call, val, col, x, block_r=8, block_w=128)


def test_lower_spmv_bsr():
    bs, nbr, slots = 8, 4, 2
    val = jnp.ones((nbr, slots, bs, bs), jnp.float32)
    bcol = jnp.zeros((nbr, slots), jnp.int32)
    x = jnp.ones((nbr * bs,), jnp.float32)
    _compile(spmv_bsr_kernel_call, val, bcol, x, accum_dtype=jnp.float32)


def test_lower_spmv_ell_alpha():
    val = jnp.ones((64, 128), jnp.float32)
    col = jnp.zeros((64, 128), jnp.int32)
    x = jnp.ones((64,), jnp.float32)
    v = jnp.ones((64,), jnp.float32)
    _compile(spmv_ell_alpha_kernel_call, val, col, x, v, block_r=8, block_w=128)


def test_lowered_text_mentions_every_kernel():
    """The lowered module is a real artifact, not a folded constant: its
    StableHLO must still contain computation (sanity guard against jit
    constant-folding the whole call away)."""
    a = jnp.asarray(np.arange(2048, dtype=np.float32))
    jitted = jax.jit(
        functools.partial(mixed_dot_kernel_call, block=1024, interpret=INTERPRET)
    )
    text = jitted.lower(a, a).as_text()
    assert "func" in text and len(text) > 100
