"""End-to-end behaviour of the Top-K eigensolver (the paper's pipeline)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DDD,
    FDF,
    FFF,
    DenseOperator,
    ChunkedOperator,
    make_operator,
    topk_eigs,
)
from repro.core.jacobi import jacobi_eigh, jacobi_eigh_host
from repro.core.metrics import (
    eigsh_reference,
    pairwise_orthogonality_deg,
    reconstruction_error,
)


def test_jacobi_host_matches_numpy():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((24, 24))
    a = (a + a.T) / 2
    evals, evecs = jacobi_eigh_host(a)
    ref = np.linalg.eigvalsh(a)
    np.testing.assert_allclose(np.sort(evals), np.sort(ref), atol=1e-10)
    # eigenvector residual
    assert np.linalg.norm(a @ evecs - evecs @ np.diag(evals)) < 1e-9


def test_jacobi_jax_matches_host():
    rng = np.random.default_rng(1)
    a = rng.standard_normal((16, 16))
    a = (a + a.T) / 2
    ev_h, _ = jacobi_eigh_host(a)
    ev_j, w_j = jacobi_eigh(jnp.asarray(a))
    np.testing.assert_allclose(np.asarray(ev_j), ev_h, atol=1e-10)
    assert np.linalg.norm(a @ np.asarray(w_j) - np.asarray(w_j) @ np.diag(np.asarray(ev_j))) < 1e-8


def test_dense_operator_topk_exact():
    """On a small dense symmetric matrix with m=n, Lanczos+Jacobi is exact."""
    rng = np.random.default_rng(2)
    a = rng.standard_normal((64, 64))
    a = (a + a.T) / 2
    op = DenseOperator(jnp.asarray(a, dtype=jnp.float64))
    res = topk_eigs(op, 5, policy=DDD, reorth="full2", num_iters=64)
    ref = np.linalg.eigvalsh(a)
    ref = ref[np.argsort(-np.abs(ref))][:5]
    np.testing.assert_allclose(np.asarray(res.eigenvalues), ref, rtol=1e-8)


def test_topk_matches_arpack(web_csr):
    """Top eigenvalues agree with ARPACK (the paper's CPU baseline library)."""
    ref_vals, _ = eigsh_reference(web_csr, 4)
    op = make_operator(web_csr, "coo", dtype=jnp.float32)
    res = topk_eigs(op, 4, policy=FDF, reorth="full", num_iters=24)
    np.testing.assert_allclose(
        np.asarray(res.eigenvalues, dtype=np.float64), ref_vals, rtol=1e-4
    )


def test_reconstruction_error_and_orthogonality(web_csr):
    op = make_operator(web_csr, "coo", dtype=jnp.float32)
    res = topk_eigs(op, 4, policy=FDF, reorth="full", num_iters=24)
    err = reconstruction_error(op, res.eigenvalues, res.eigenvectors, accum_dtype=jnp.float64)
    assert err < 1e-3
    orth = pairwise_orthogonality_deg(res.eigenvectors)
    assert abs(orth - 90.0) < 0.1


def test_precision_ladder(web_csr):
    """Paper Fig. 4: DDD <= FDF << FFF in error; FDF close to DDD."""
    errs = {}
    for pol in (FFF, FDF, DDD):
        op = make_operator(web_csr, "coo", dtype=pol.storage)
        res = topk_eigs(op, 4, policy=pol, reorth="full", num_iters=24)
        errs[pol.name] = reconstruction_error(
            op, res.eigenvalues, res.eigenvectors, accum_dtype=jnp.float64
        )
    assert errs["DDD"] <= errs["FDF"] * 1.5 + 1e-12
    assert errs["FDF"] < errs["FFF"]  # the paper's 12x headline, qualitatively


def test_reorth_improves_orthogonality(web_csr):
    """Paper Fig. 3b: reorthogonalization improves pairwise angles."""
    op = make_operator(web_csr, "coo", dtype=jnp.float32)
    r_none = topk_eigs(op, 6, policy=FFF, reorth="none", num_iters=18)
    r_full = topk_eigs(op, 6, policy=FFF, reorth="full", num_iters=18)
    d_none = abs(pairwise_orthogonality_deg(r_none.eigenvectors) - 90)
    d_full = abs(pairwise_orthogonality_deg(r_full.eigenvectors) - 90)
    assert d_full <= d_none


def test_chunked_out_of_core_matches_incore(web_csr):
    """Out-of-core streaming SpMV gives the same spectrum as in-core."""
    op_ic = make_operator(web_csr, "coo", dtype=jnp.float32)
    op_oc = ChunkedOperator(web_csr, chunk_nnz=4096, dtype=jnp.float32)
    assert op_oc.num_chunks > 1
    v1 = jnp.ones((web_csr.n,), jnp.float64)
    r_ic = topk_eigs(op_ic, 3, policy=FDF, reorth="full", num_iters=12, v1=v1)
    r_oc = topk_eigs(op_oc, 3, policy=FDF, reorth="full", num_iters=12, v1=v1)
    np.testing.assert_allclose(
        np.asarray(r_ic.eigenvalues), np.asarray(r_oc.eigenvalues), rtol=1e-6
    )


def test_ell_impl_matches_coo(web_csr):
    v1 = jnp.ones((web_csr.n,), jnp.float64)
    r_coo = topk_eigs(
        make_operator(web_csr, "coo"), 3, policy=FFF, reorth="full", num_iters=9, v1=v1
    )
    r_ell = topk_eigs(
        make_operator(web_csr, "ell"), 3, policy=FFF, reorth="full", num_iters=9, v1=v1
    )
    np.testing.assert_allclose(
        np.asarray(r_coo.eigenvalues), np.asarray(r_ell.eigenvalues), rtol=1e-5
    )


def test_num_iters_improves_accuracy(norm_csr):
    op = make_operator(norm_csr, "coo")
    e = {}
    for m in (8, 32):
        r = topk_eigs(op, 8, policy=FDF, reorth="full", num_iters=m)
        e[m] = reconstruction_error(op, r.eigenvalues, r.eigenvectors, accum_dtype=jnp.float64)
    assert e[32] < e[8]


def test_thick_restart_matches_arpack_tightly(norm_csr):
    """Restarted solver reaches ARPACK-class residuals on crowded spectra
    where the paper's fixed-m solver is truncation-limited."""
    from repro.core.restarted import topk_eigs_restarted

    op = make_operator(norm_csr, "coo", dtype=jnp.float32)
    ref_vals, _ = eigsh_reference(norm_csr, 6)
    r = topk_eigs_restarted(op, 6, policy=FDF, m=20, tol=1e-7, max_restarts=40)
    np.testing.assert_allclose(
        np.asarray(r.eigenvalues, np.float64), ref_vals, rtol=1e-5, atol=1e-7
    )
    rec = reconstruction_error(op, r.eigenvalues, r.eigenvectors, accum_dtype=jnp.float64)
    assert rec < 1e-5  # the paper's headline accuracy bar


def test_thick_restart_bounded_memory(norm_csr):
    """Subspace never exceeds m vectors regardless of restarts."""
    from repro.core.restarted import topk_eigs_restarted

    op = make_operator(norm_csr, "coo", dtype=jnp.float32)
    r = topk_eigs_restarted(op, 4, policy=FDF, m=12, tol=1e-6, max_restarts=25)
    assert r.tridiag.basis.shape[0] == 12
    rec = reconstruction_error(op, r.eigenvalues, r.eigenvectors, accum_dtype=jnp.float64)
    assert rec < 1e-4


# --------------------------- fused Lanczos update ----------------------------


def test_fused_update_policy_gating():
    """Non-compensated policies may route through the fused Pallas kernel;
    compensated policies keep the reference reductions for beta.  Routing is
    plan-driven: with no measured plan the static mode table decides (unfused
    in interpret mode), so the fused record needs an explicit pin here."""
    from repro.core import FCF
    from repro.core.lanczos import fused_update_enabled, make_local_ops

    assert fused_update_enabled(FFF) and fused_update_enabled(FDF)
    assert not fused_update_enabled(FCF)
    assert make_local_ops(lambda x: x, FFF, fused=True).fused_update is not None
    # The policy gate wins over any pin or plan for compensated policies.
    assert make_local_ops(lambda x: x, FCF, fused=True).fused_update is None
    assert make_local_ops(lambda x: x, FCF).fused_update is None


def test_update_mode_table_default(monkeypatch):
    """With no plan and no env pins, interpret mode defaults to the unfused
    update (measured: the Pallas interpreter loses on per-step overhead)."""
    from repro.core.lanczos import make_local_ops, resolve_update_mode

    monkeypatch.delenv("REPRO_FUSED_LANCZOS", raising=False)
    monkeypatch.delenv("REPRO_ITER_UPDATE", raising=False)
    assert resolve_update_mode(FFF.effective()) == "unfused"
    ops = make_local_ops(lambda x: x, FFF)
    assert ops.fused_update is None and ops.fused_iteration is None


def test_fused_update_kill_switch(monkeypatch):
    from repro.core.lanczos import fused_update_enabled, make_local_ops

    monkeypatch.setenv("REPRO_FUSED_LANCZOS", "0")
    assert not fused_update_enabled(FFF)
    assert make_local_ops(lambda x: x, FFF).fused_update is None


@pytest.mark.parametrize("reorth", ["none", "half", "full"])
def test_fused_lanczos_matches_reference_loop(web_csr, reorth, monkeypatch):
    """Loop parity: the fused-kernel recurrence (and, with reorth='none',
    its fused norm) reproduces the unfused reference loop."""
    from repro.api import eigsh

    monkeypatch.setenv("REPRO_FUSED_LANCZOS", "1")  # force the fused update
    r_fused = eigsh(web_csr, 4, num_iters=12, policy="FFF", reorth=reorth, seed=3)
    monkeypatch.setenv("REPRO_FUSED_LANCZOS", "0")
    r_ref = eigsh(web_csr, 4, num_iters=12, policy="FFF", reorth=reorth, seed=3)
    np.testing.assert_allclose(
        np.asarray(r_fused.eigenvalues), np.asarray(r_ref.eigenvalues),
        rtol=2e-5, atol=2e-5,
    )


def test_fused_update_wired_into_loop(monkeypatch):
    """The jitted loop actually calls the kernel wrapper when permitted (the
    call is observed at trace time) and skips it when the policy forbids."""
    from repro.core import FCF
    from repro.core.lanczos import lanczos_tridiag
    from repro.kernels import ops as kops

    calls = []
    real = kops.lanczos_update

    def spy(*a, **k):
        calls.append(1)
        return real(*a, **k)

    monkeypatch.setattr(kops, "lanczos_update", spy)
    monkeypatch.setenv("REPRO_FUSED_LANCZOS", "1")  # force-enable: no plan here
    a = np.diag(np.arange(1.0, 17.0))
    mv = lambda x: jnp.asarray(a, x.dtype) @ x  # noqa: E731
    v1 = jnp.ones((16,), jnp.float32)
    lanczos_tridiag(mv, v1, 4, FFF, reorth="half", jit=False)
    assert calls  # fused path traced/executed
    calls.clear()
    lanczos_tridiag(mv, v1, 4, FCF, reorth="half", jit=False)
    assert not calls  # compensated policy keeps the reference recurrence
