"""Training substrate: optimizer, data, checkpointing, fault tolerance,
gradient compression, and the paper-integrated spectral monitor."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.common import split_tree
from repro.models.model import init_model, loss_fn
from repro.training import (
    CheckpointManager,
    DataConfig,
    OptConfig,
    TrainConfig,
    Trainer,
    data_stream,
    make_train_step,
    synthetic_batch,
)
from repro.training.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.training.compression import compress_tree, ef_compress_tree, init_ef_state
from repro.training.optimizer import adamw_update, init_opt_state


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = get_config("qwen3-0.6b", smoke=True)
    params, _ = split_tree(init_model(jax.random.PRNGKey(0), cfg))
    return cfg, params


def test_train_loss_decreases(tiny_setup):
    cfg, params = tiny_setup
    tc = TrainConfig(opt=OptConfig(peak_lr=3e-3, warmup_steps=5, decay_steps=40),
                     ckpt_every=100, ckpt_dir=tempfile.mkdtemp())
    tr = Trainer(cfg, tc, params)
    hist = tr.run(data_stream(cfg, DataConfig(batch=8, seq_len=64, seed=1)), num_steps=40,
                  log_fn=lambda *_: None)
    assert np.mean(hist[-5:]) < hist[0] - 0.5


def test_grad_accumulation_matches_full_batch(tiny_setup):
    """accum_steps=2 on batch 8 == accum_steps=1 on the same batch."""
    cfg, params = tiny_setup
    batch = synthetic_batch(cfg, DataConfig(batch=8, seq_len=32, seed=3), 0)
    tc1 = TrainConfig(accum_steps=1)
    tc2 = TrainConfig(accum_steps=2)
    s1 = make_train_step(cfg, tc1)
    s2 = make_train_step(cfg, tc2)
    p1, o1, m1 = s1(params, init_opt_state(params), batch)
    p2, o2, m2 = s2(params, init_opt_state(params), batch)
    # same data -> nearly identical update (microbatch loss averaging reorders sums)
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_checkpoint_roundtrip_and_retention(tiny_setup):
    cfg, params = tiny_setup
    d = tempfile.mkdtemp()
    mgr = CheckpointManager(d, keep_n=2)
    opt = init_opt_state(params)
    for s in (1, 2, 3):
        mgr.save(s, {"params": params, "opt": opt}, extra={"tag": s})
    assert latest_step(d) == 3
    assert not os.path.exists(os.path.join(d, "step_00000001"))  # retention
    step, tree, extra = mgr.restore_latest({"params": params, "opt": opt})
    assert step == 3 and extra["tag"] == 3
    for a, b in zip(jax.tree.leaves(tree["params"]), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_tmp_cleanup(tiny_setup):
    cfg, params = tiny_setup
    d = tempfile.mkdtemp()
    save_checkpoint(d, 7, {"p": params})
    assert latest_step(d) == 7
    assert not any(x.startswith(".tmp") for x in os.listdir(d))


def test_nan_rollback(tiny_setup):
    """Poisoned batch drives loss non-finite; trainer restores and continues."""
    cfg, params = tiny_setup
    tc = TrainConfig(opt=OptConfig(peak_lr=3e-3, warmup_steps=2, decay_steps=30),
                     ckpt_every=5, ckpt_dir=tempfile.mkdtemp(), async_ckpt=False)
    tr = Trainer(cfg, tc, params)

    # poison: monkeypatch step_fn to return nan once at call 7
    orig = tr.step_fn
    calls = {"n": 0}

    def sometimes_nan(p, o, b):
        calls["n"] += 1
        p2, o2, m = orig(p, o, b)
        if calls["n"] == 7:
            m = dict(m)
            m["loss"] = jnp.asarray(float("nan"))
        return p2, o2, m

    tr.step_fn = sometimes_nan
    hist = tr.run(data_stream(cfg, DataConfig(batch=4, seq_len=32, seed=2)), num_steps=12,
                  log_fn=lambda *_: None)
    assert tr.rollbacks == 1
    assert tr.step == 12
    assert all(np.isfinite(hist))


def test_resume_from_checkpoint(tiny_setup):
    cfg, params = tiny_setup
    d = tempfile.mkdtemp()
    tc = TrainConfig(opt=OptConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=20),
                     ckpt_every=5, ckpt_dir=d, async_ckpt=False)
    tr1 = Trainer(cfg, tc, params)
    tr1.run(data_stream(cfg, DataConfig(batch=4, seq_len=32, seed=4)), num_steps=10,
            log_fn=lambda *_: None)
    # "preemption": new trainer, same dir
    tr2 = Trainer(cfg, tc, params)
    assert tr2.try_resume()
    assert tr2.step == 10
    for a, b in zip(jax.tree.leaves(tr2.params), jax.tree.leaves(tr1.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compression_unbiased_and_bounded(tiny_setup):
    cfg, params = tiny_setup
    g = jax.tree.map(lambda p: jnp.asarray(np.random.default_rng(0).standard_normal(p.shape),
                                           jnp.float32), params)
    gq = compress_tree(g)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gq)):
        amax = float(jnp.abs(a).max())
        assert float(jnp.abs(a - b).max()) <= amax / 127.0 + 1e-6


def test_error_feedback_reduces_bias():
    x = jnp.asarray(np.random.default_rng(1).standard_normal(4096) * 1e-3, jnp.float32)
    g = {"w": x}
    ef = init_ef_state(g)
    total_sent = jnp.zeros_like(x)
    for _ in range(50):
        sent, ef = ef_compress_tree(g, ef)
        total_sent = total_sent + sent["w"]
    # over many steps the mean transmitted gradient converges to the truth
    err = float(jnp.abs(total_sent / 50 - x).max())
    q_err_single = float(jnp.abs(compress_tree(g)["w"] - x).max())
    assert err <= q_err_single


def test_spectral_monitor_hessian(tiny_setup):
    """Paper integration: Lanczos top-K on the HVP operator of a real model."""
    from repro.training.spectral import hessian_topk

    cfg, params = tiny_setup
    batch = synthetic_batch(cfg, DataConfig(batch=2, seq_len=16, seed=5), 0)
    evals = hessian_topk(params, cfg, batch, k=3, num_iters=8)
    assert evals.shape == (3,)
    assert np.all(np.isfinite(evals))
    assert abs(evals[0]) >= abs(evals[-1])  # |lambda| ordering


def test_serving_engine_generate(tiny_setup):
    from repro.serving import Engine, ServeConfig

    cfg, params = tiny_setup
    eng = Engine(cfg, params, ServeConfig(max_len=64))
    prompt = {"tokens": jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (2, 12)),
                                    jnp.int32)}
    toks, info = eng.generate(prompt, steps=5)
    assert toks.shape == (2, 5)
    assert info["token_logprobs"].shape == (2, 5)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.vocab)))
