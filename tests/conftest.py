"""Shared pytest config.

x64 is enabled process-wide so the paper's f64 compute policies (FDF/DDD)
are real f64 on this CPU container.  Device count stays 1 here — multi-device
tests spawn subprocesses with XLA_FLAGS (see test_distributed.py), and the
512-device dry-run is exercised via launch/dryrun.py only, per its contract.
"""

import jax

jax.config.update("jax_enable_x64", True)
# Lock the platform at 1 device NOW: repro.launch.dryrun sets
# XLA_FLAGS=--xla_force_host_platform_device_count=512 at import (its
# documented contract), and tests import its pure helpers.  Device count
# binds at first backend query, so this call makes later flag changes inert.
assert len(jax.devices()) >= 1

import numpy as np
import pytest

from repro.sparse import generate


@pytest.fixture(scope="session")
def web_csr():
    return generate("web", 2048, 8.0, seed=7, values="unit")


@pytest.fixture(scope="session")
def norm_csr():
    return generate("web", 2048, 8.0, seed=7, values="normalized")
