"""Plan/execute split: prepared sessions, fingerprint cache, eigsh_many."""

import json

import numpy as np
import pytest

import jax

from repro.api import (
    EigenResult,
    EigQuery,
    SolverConfig,
    config_fingerprint,
    eigsh,
    eigsh_many,
    matrix_fingerprint,
    prepare,
    session_cache_clear,
    session_cache_info,
)
from repro.api.session import policy_key
from repro.core import FDF, POLICIES
from repro.core.metrics import eigsh_reference
from repro.kernels.engine import get_tuner, tuner_probe_count
from repro.sparse import generate
from repro.sparse.formats import conversion_count

K = 4
ITERS = 24


@pytest.fixture(autouse=True)
def _fresh_cache():
    session_cache_clear()
    yield
    session_cache_clear()


@pytest.fixture()
def small_csr():
    return generate("web", 512, 6.0, seed=3, values="normalized")


# ------------------------------------------------------------- fingerprints


def test_matrix_fingerprint_tracks_content(small_csr):
    fp = matrix_fingerprint(small_csr)
    assert fp == matrix_fingerprint(small_csr)  # byte-identical -> same digest
    mutated = generate("web", 512, 6.0, seed=3, values="normalized")
    mutated.data[0] += 1.0
    assert matrix_fingerprint(mutated) != fp
    # dtype change alone changes the digest too
    retyped = generate("web", 512, 6.0, seed=3, values="normalized")
    retyped.data = retyped.data.astype(np.float32)
    assert matrix_fingerprint(retyped) != fp


def test_config_fingerprint_normalizes_policy():
    """Satellite bugfix: a PrecisionPolicy instance and its name must hash
    identically (resolve_policy normalization), and the hash must be stable
    across equal configs."""
    by_name = SolverConfig(policy="FDF")
    by_instance = SolverConfig(policy=FDF)
    assert config_fingerprint(by_name) == config_fingerprint(by_instance)
    assert policy_key("FDF") == policy_key(FDF)
    assert policy_key("fdf") == policy_key(FDF)
    # different dtype triples must not collide
    assert policy_key("FFF") != policy_key("FDF")
    assert config_fingerprint(SolverConfig(format="ell")) != config_fingerprint(
        SolverConfig(format="coo")
    )


def test_policy_instance_hits_name_keyed_session(small_csr):
    """eigsh(policy=<instance>) after eigsh(policy=<name>) must reuse the
    session AND its per-policy operator."""
    eigsh(small_csr, K, policy="FDF", num_iters=ITERS)
    c0 = conversion_count()
    res = eigsh(small_csr, K, policy=FDF, num_iters=ITERS)
    assert res.session_reuse
    assert conversion_count() == c0


# ---------------------------------------------------------- cache semantics


def test_byte_identical_recall_is_zero_conversion(small_csr):
    r1 = eigsh(small_csr, K, policy="FDF", num_iters=ITERS)
    assert not r1.session_reuse
    assert r1.partition["spmv"]["conversions"] >= 1
    c0, p0 = conversion_count(), tuner_probe_count()
    r2 = eigsh(small_csr, K, policy="FDF", num_iters=ITERS)
    assert r2.session_reuse
    assert conversion_count() == c0  # zero format conversions
    assert tuner_probe_count() == p0  # zero tuner probes
    assert r2.partition["spmv"]["conversions"] == 0
    assert r2.partition["spmv"]["tuner_probes"] == 0
    assert r2.timings["prepare_s"] == 0.0
    np.testing.assert_array_equal(np.asarray(r1.eigenvalues), np.asarray(r2.eigenvalues))


def test_tuned_session_reuses_probes(small_csr, tmp_path, monkeypatch):
    """With the measured autotuner on, the second call must not re-probe."""
    monkeypatch.setenv("REPRO_SPMV_TUNE", "1")
    monkeypatch.setenv("REPRO_SPMV_TUNE_BUDGET", "2")
    monkeypatch.setenv("REPRO_SPMV_TUNE_CACHE", str(tmp_path / "tune.json"))
    r1 = eigsh(small_csr, K, policy="FFF", format="ell", num_iters=ITERS)
    assert r1.partition["spmv"]["tiles_from"] in ("tuned", "table")
    probes = get_tuner().measure_count
    r2 = eigsh(small_csr, K, policy="FFF", format="ell", num_iters=ITERS)
    assert r2.session_reuse
    assert get_tuner().measure_count == probes
    assert r2.partition["spmv"]["tuner_probes"] == 0


def test_mutation_invalidates_session(small_csr):
    r1 = eigsh(small_csr, K, policy="FDF", num_iters=ITERS)
    small_csr.data[:4] *= 1.5
    r2 = eigsh(small_csr, K, policy="FDF", num_iters=ITERS)
    assert not r2.session_reuse
    assert r2.partition["spmv"]["conversions"] >= 1
    # and the answers legitimately differ (it IS a different matrix)
    assert not np.allclose(np.asarray(r1.eigenvalues), np.asarray(r2.eigenvalues))


def test_cached_session_does_not_alias_caller_buffers(small_csr):
    """Review regression: after caching, mutating the submitted CSR in place
    must not poison plans lazily built later under the ORIGINAL digest —
    a byte-identical re-submission must solve the original matrix."""
    from repro.api.session import get_session

    original = generate("web", 512, 6.0, seed=3, values="normalized")
    r0 = eigsh(small_csr, K, policy="FDF", num_iters=ITERS)  # caches the session
    small_csr.data *= 2.0  # caller mutates their buffer in place
    # Fresh CSR with the original bytes: hits the cached key; a NEW policy
    # (different storage dtype) forces a lazy build inside that session.
    sess, hit = get_session(original, SolverConfig())
    assert hit  # same digest -> the session built from small_csr's buffers
    r1 = sess.eigsh(K, policy="DDD", num_iters=ITERS)
    ref = eigsh(
        generate("web", 512, 6.0, seed=3, values="normalized"),
        K,
        policy="DDD",
        num_iters=ITERS,
        format="coo",
    )
    np.testing.assert_allclose(
        np.asarray(r1.eigenvalues, dtype=np.float64),
        np.asarray(ref.eigenvalues, dtype=np.float64),
        rtol=1e-8,
    )
    assert not np.allclose(
        np.asarray(r1.eigenvalues, dtype=np.float64),
        2.0 * np.asarray(r0.eigenvalues, dtype=np.float64),
    )


def test_layout_config_change_invalidates_session(small_csr):
    eigsh(small_csr, K, policy="FDF", format="coo", num_iters=ITERS)
    c0 = conversion_count()
    r2 = eigsh(small_csr, K, policy="FDF", format="ell", num_iters=ITERS)
    assert not r2.session_reuse
    assert conversion_count() > c0
    # per-query knobs (num_iters / tol / k) must NOT invalidate
    r3 = eigsh(small_csr, K - 1, policy="FDF", format="ell", num_iters=8)
    assert r3.session_reuse
    assert r3.iterations == 8


def test_cache_respects_limit_env(small_csr, monkeypatch):
    monkeypatch.setenv("REPRO_EIGSH_SESSION_CACHE", "0")
    session_cache_clear()
    eigsh(small_csr, K, num_iters=ITERS)
    assert session_cache_info()["size"] == 0
    r = eigsh(small_csr, K, num_iters=ITERS)
    assert not r.session_reuse  # caching disabled -> every call re-prepares


def test_cache_byte_budget_excludes_large_sessions(small_csr, monkeypatch):
    """A matrix bigger than the whole byte budget is served but never pinned
    (the out-of-core sizes the chunked backend targets must not accumulate)."""
    monkeypatch.setenv("REPRO_EIGSH_SESSION_CACHE_MB", "0.01")  # 10 kB budget
    session_cache_clear()
    eigsh(small_csr, K, num_iters=ITERS)  # ~300 kB of CSR arrays
    assert session_cache_info()["size"] == 0
    r = eigsh(small_csr, K, num_iters=ITERS)
    assert not r.session_reuse


def test_dense_inputs_are_cached_too(small_csr):
    dense = small_csr.toarray()
    eigsh(dense, K, num_iters=ITERS)
    r2 = eigsh(dense, K, num_iters=ITERS)
    assert r2.session_reuse
    assert r2.spmv_format == "dense"


# ------------------------------------------------------------ session API


def test_prepared_session_serves_queries(small_csr):
    sess = prepare(small_csr, reorth="full")
    assert sess.prepare_conversions >= 1
    c0 = conversion_count()
    r1 = sess.eigsh(K, num_iters=ITERS)
    r2 = sess.eigsh(K - 2, num_iters=ITERS)
    assert r1.session_reuse and r2.session_reuse
    assert conversion_count() == c0  # both executes: zero conversions
    vals, _ = eigsh_reference(small_csr, K)
    np.testing.assert_allclose(
        np.abs(np.asarray(r1.eigenvalues, dtype=np.float64)), np.abs(vals), rtol=1e-4
    )


def test_session_serves_multiple_policies(small_csr):
    """Different dtype triples build lazily, once each, inside one session."""
    sess = prepare(small_csr)
    sess.eigsh(K, policy="FFF", num_iters=ITERS)
    c0 = conversion_count()
    r = sess.eigsh(K, policy="FFF", num_iters=ITERS)  # same policy: reuse
    assert conversion_count() == c0 and r.session_reuse
    r64 = sess.eigsh(K, policy="DDD", num_iters=ITERS)  # new storage dtype: build
    assert not r64.session_reuse
    assert conversion_count() > c0
    c1 = conversion_count()
    sess.eigsh(K, policy="DDD", num_iters=ITERS)
    assert conversion_count() == c1  # now cached too


# -------------------------------------------------------------- eigsh_many


def test_eigsh_many_slices_match_independent_solves(small_csr):
    queries = [
        {"k": 2, "num_iters": ITERS},
        {"k": K, "num_iters": ITERS},
        {"k": 3, "num_iters": ITERS, "tol": 1e-3},
        EigQuery(k=K, num_iters=ITERS),
    ]
    # backend pinned: under "auto" the tol query would dispatch to the
    # restarted backend (its own group); here tol only defines the flags.
    sess = prepare(small_csr, reorth="full", backend="single")
    rs = sess.eigsh_many(queries)
    assert [r.k for r in rs] == [2, K, 3, K]
    # one shared sweep for the whole fixed-m group
    assert sess.stats["sweeps"] == 1
    ref = eigsh(small_csr, K, reorth="full", num_iters=ITERS)
    for r in rs:
        np.testing.assert_allclose(
            np.asarray(r.eigenvalues, dtype=np.float64),
            np.asarray(ref.eigenvalues, dtype=np.float64)[: r.k],
            rtol=1e-8,
        )
        assert r.eigenvectors.shape == (small_csr.n, r.k)
        assert r.residuals.shape == (r.k,)
        assert r.timings.get("amortized_over") == 4.0
    # per-query tol judged per query
    assert rs[2].tol == 1e-3


def test_eigsh_many_groups_by_policy(small_csr):
    sess = prepare(small_csr, reorth="full")
    rs = sess.eigsh_many(
        [
            {"k": 2, "policy": "FFF", "num_iters": ITERS},
            {"k": 3, "policy": "FDF", "num_iters": ITERS},
            {"k": 2, "policy": "FDF", "num_iters": ITERS},
        ]
    )
    assert sess.stats["sweeps"] == 2  # one per policy group
    assert rs[0].policy == "FFF" and rs[1].policy == "FDF"
    ref = eigsh(small_csr, 3, policy="FDF", reorth="full", num_iters=ITERS)
    np.testing.assert_allclose(
        np.asarray(rs[2].eigenvalues, dtype=np.float64),
        np.asarray(ref.eigenvalues, dtype=np.float64)[:2],
        rtol=1e-8,
    )


def test_eigsh_many_restarted_group(small_csr):
    sess = prepare(small_csr)
    rs = sess.eigsh_many(
        [{"k": 2, "tol": 1e-7, "subspace": 16}, {"k": K, "tol": 1e-6, "subspace": 16}]
    )
    assert all(r.backend == "restarted" for r in rs)
    assert sess.stats["sweeps"] == 1  # merged: one restarted run at k_max
    assert all(r.all_converged for r in rs)
    vals, _ = eigsh_reference(small_csr, K)
    np.testing.assert_allclose(
        np.abs(np.asarray(rs[1].eigenvalues, dtype=np.float64)), np.abs(vals), rtol=1e-5
    )


def test_eigsh_many_vmapped_multistart_dense(small_csr):
    dense = small_csr.toarray()
    sess = prepare(dense, reorth="full")
    rs = sess.eigsh_many([{"k": 3, "seed": s, "num_iters": ITERS} for s in range(3)])
    assert sess.stats["sweeps"] == 1  # one vmapped sweep for all three starts
    for s, r in enumerate(rs):
        ref = eigsh(dense, 3, reorth="full", num_iters=ITERS, seed=s)
        np.testing.assert_allclose(
            np.asarray(r.eigenvalues, dtype=np.float64),
            np.asarray(ref.eigenvalues, dtype=np.float64),
            rtol=1e-6,
        )


def test_module_level_eigsh_many(small_csr):
    rs = eigsh_many(small_csr, [2, K], reorth="full", num_iters=ITERS)
    assert [r.k for r in rs] == [2, K]
    rs2 = eigsh_many(small_csr, [2, K], reorth="full", num_iters=ITERS)
    assert all(r.session_reuse for r in rs2)  # second batch hits the cache


def test_eigsh_many_rejects_bad_query(small_csr):
    sess = prepare(small_csr)
    with pytest.raises(TypeError, match="EigQuery"):
        sess.eigsh_many(["nope"])
    with pytest.raises(ValueError, match="exceeds the operator dimension"):
        sess.eigsh_many([small_csr.n + 1])


# ---------------------------------------------------------- impl deprecation


def test_impl_maps_onto_format_with_deprecation(small_csr):
    with pytest.warns(DeprecationWarning, match="impl= is deprecated"):
        r = eigsh(small_csr, K, impl="ell", num_iters=ITERS, reorth="full")
    assert r.spmv_format == "ell"
    ref = eigsh(small_csr, K, format="ell", num_iters=ITERS, reorth="full")
    np.testing.assert_allclose(
        np.asarray(r.eigenvalues, dtype=np.float64),
        np.asarray(ref.eigenvalues, dtype=np.float64),
        rtol=1e-6,
    )
    with pytest.warns(DeprecationWarning):
        r_bsr = eigsh(small_csr, K, impl="bsr_kernel", num_iters=ITERS)
    assert r_bsr.spmv_format == "bsr"
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="unknown legacy impl"):
            eigsh(small_csr, K, impl="bogus")
    # an explicit format= wins over a deprecated impl=
    with pytest.warns(DeprecationWarning):
        r_fmt = eigsh(small_csr, K, impl="ell", format="coo", num_iters=ITERS)
    assert r_fmt.spmv_format == "coo"
    # impl="coo" is an explicit pin now (impl defaults to None), so it must
    # force the segment-sum path, not fall through to auto-selection
    with pytest.warns(DeprecationWarning):
        r_coo = eigsh(small_csr, K, impl="coo", num_iters=ITERS)
    assert r_coo.spmv_format == "coo"


def test_solver_config_has_no_impl_field():
    assert "impl" not in {f.name for f in __import__("dataclasses").fields(SolverConfig)}


# ------------------------------------------------------------ result dicts


def test_eigenresult_json_roundtrip(small_csr):
    res = eigsh(small_csr, K, policy="FDF", reorth="full", num_iters=ITERS, tol=1e-5)
    payload = json.dumps(res.to_dict())  # must be JSON-serializable as-is
    back = EigenResult.from_dict(json.loads(payload))
    np.testing.assert_allclose(
        np.asarray(back.eigenvalues, dtype=np.float64),
        np.asarray(res.eigenvalues, dtype=np.float64),
        rtol=1e-6,
    )
    np.testing.assert_allclose(
        np.asarray(back.eigenvectors, dtype=np.float64),
        np.asarray(res.eigenvectors, dtype=np.float64),
        rtol=1e-6,
    )
    np.testing.assert_array_equal(back.converged, res.converged)
    np.testing.assert_allclose(back.residuals, res.residuals)
    assert back.backend == res.backend
    assert back.policy == res.policy
    assert back.k == res.k and back.n == res.n
    assert back.tol == res.tol
    assert back.partition["spmv"]["format"] == res.partition["spmv"]["format"]
    assert back.timings["total_s"] == pytest.approx(res.timings["total_s"])
    assert back.session_reuse == res.session_reuse
    # dtypes restored
    assert np.asarray(back.eigenvalues).dtype == np.asarray(res.eigenvalues).dtype


def test_eigenresult_roundtrip_distributed(small_csr):
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()).reshape(len(jax.devices())), ("data",))
    res = eigsh(small_csr, K, mesh=mesh, num_iters=ITERS)
    back = EigenResult.from_dict(json.loads(json.dumps(res.to_dict())))
    assert back.spmv_format == tuple(res.spmv_format)
    assert back.num_devices == res.num_devices
    assert back.partition["num_shards"] == res.partition["num_shards"]


def test_bf16_result_roundtrips(small_csr):
    res = eigsh(small_csr, K, policy="BFF", num_iters=ITERS)
    back = EigenResult.from_dict(json.loads(json.dumps(res.to_dict())))
    assert np.asarray(back.eigenvectors).dtype == np.asarray(res.eigenvectors).dtype


# ----------------------------------------------------------- compat checks


def test_all_policies_still_resolve_through_sessions(small_csr):
    for name in POLICIES:
        r = eigsh(small_csr, 2, policy=name, num_iters=8)
        assert r.eigenvalues.shape == (2,)


def test_prepared_distributed_reuse(small_csr):
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()).reshape(len(jax.devices())), ("data",))
    sess = prepare(small_csr, mesh=mesh)
    c0 = conversion_count()
    r1 = sess.eigsh(K, num_iters=ITERS)
    r2 = sess.eigsh(2, num_iters=8)
    assert r1.backend == r2.backend == "distributed"
    assert conversion_count() == c0
    assert r1.session_reuse and r2.session_reuse
    assert r2.timings.get("convert_s") == 0.0  # plan reused: no conversion paid


def test_chunked_session_reuse(small_csr):
    sess = prepare(small_csr, backend="chunked", chunk_nnz=2048)
    r1 = sess.eigsh(3, num_iters=9)
    c0 = conversion_count()
    r2 = sess.eigsh(3, num_iters=9)
    assert conversion_count() == c0
    assert r1.partition["staging"]["conversions"] == r1.partition["num_chunks"]
    assert r2.partition["staging"]["conversions"] == r2.partition["num_chunks"]
    np.testing.assert_allclose(
        np.asarray(r1.eigenvalues, dtype=np.float64),
        np.asarray(r2.eigenvalues, dtype=np.float64),
    )
