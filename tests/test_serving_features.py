"""Serving features: int8 KV cache, dropless MoE serving, windowed caches."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.attention import AttnCache, quantize_kv
from repro.models.common import split_tree
from repro.models.model import decode_step, forward, init_decode_state, init_model, prefill


def _roundtrip_err(x):
    q, s = quantize_kv(x)
    back = q.astype(jnp.float32) * s[..., None]
    return float(jnp.abs(back - x.astype(jnp.float32)).max() / (jnp.abs(x).max() + 1e-9))


def test_kv_quantization_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, 4, 32)) * 3.0, jnp.bfloat16)
    assert _roundtrip_err(x) < 1.5 / 127  # within one quant step of amax


@pytest.mark.parametrize("arch", ["qwen1.5-32b", "mixtral-8x7b"])
def test_int8_cache_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
    params, _ = split_tree(init_model(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(1)
    B, S = 2, 31
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    st, _ = prefill(params, cfg8, batch, max_len=64)
    # int8 state template shape check
    caches = [v for v in jax.tree.leaves(st.layers) if hasattr(v, "dtype") and v.dtype == jnp.int8]
    assert caches, "int8 cache buffers expected"
    nxt = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    lgd, st2 = decode_step(params, cfg8, st, nxt)
    ref_cfg = dataclasses.replace(cfg, capacity_factor=100.0) if cfg.n_experts else cfg
    lgr, _ = forward(params, ref_cfg, {"tokens": jnp.concatenate([batch["tokens"], nxt], 1)})
    err = float(jnp.abs(lgd[:, : cfg.vocab] - lgr[:, -1, : cfg.vocab]).max())
    assert err < 0.15, err


def test_windowed_ring_cache_evicts_correctly():
    """SWA cache keeps exactly the last `window` positions through decode."""
    cfg = get_config("mixtral-8x7b", smoke=True)  # window=32
    params, _ = split_tree(init_model(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(2)
    B, S = 1, 40  # prompt longer than the window
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    st, _ = prefill(params, cfg, batch, max_len=64)
    cache = st.layers["moe_0"]
    assert cache.k.shape[-3] == cfg.window  # ring sized to the window
    # all slot positions are within the last `window` positions
    sp = np.asarray(cache.slot_pos)
    assert sp.min() >= S - cfg.window and sp.max() == S - 1
    # decode a few steps; the ring must keep advancing
    for i in range(3):
        tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
        _, st = decode_step(params, cfg, st, tok)
    sp = np.asarray(st.layers["moe_0"].slot_pos)
    assert sp.max() == S + 2 and sp.min() >= S + 3 - cfg.window


def test_moe_dropless_vs_training_capacity():
    """Serving MoE must route every token; training may drop at capacity."""
    from repro.models.mlp import init_moe, moe

    cfg = get_config("arctic-480b", smoke=True)
    cfg = dataclasses.replace(cfg, capacity_factor=0.5)  # force drops in training mode
    p, _ = split_tree(init_moe(jax.random.PRNGKey(3), cfg))
    x = jnp.asarray(np.random.default_rng(4).standard_normal((2, 64, cfg.d_model)), jnp.float32)
    y_train, _ = moe(p, cfg, x, dropless=False)
    y_serve, _ = moe(p, cfg, x, dropless=True)
    # dropped tokens return 0 from the MoE in training mode -> rows differ
    diff = jnp.abs(y_train - y_serve).max(axis=-1)
    assert float(diff.max()) > 0  # drops happened under cf=0.5
    # and serving output is nonzero for every token (all routed)
    assert float(jnp.abs(y_serve).max(axis=-1).min()) > 0


def test_adafactor_trains():
    from repro.training import DataConfig, OptConfig, TrainConfig, Trainer, data_stream

    import tempfile

    cfg = get_config("qwen3-0.6b", smoke=True)
    params, _ = split_tree(init_model(jax.random.PRNGKey(0), cfg))
    tc = TrainConfig(opt=OptConfig(peak_lr=1e-2, warmup_steps=5, decay_steps=40),
                     ckpt_every=100, ckpt_dir=tempfile.mkdtemp(), optimizer="adafactor")
    tr = Trainer(cfg, tc, params)
    hist = tr.run(data_stream(cfg, DataConfig(batch=8, seq_len=64, seed=1)), num_steps=40,
                  log_fn=lambda *_: None)
    assert np.mean(hist[-5:]) < hist[0] - 0.5
    # factored state is much smaller than AdamW's m+v
    import jax as _jax

    opt_elems = sum(int(np.prod(x.shape)) for x in _jax.tree.leaves(tr.opt_state[1:]))
    p_elems = sum(int(np.prod(x.shape)) for x in _jax.tree.leaves(tr.params))
    assert opt_elems < 0.5 * p_elems
