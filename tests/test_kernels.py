"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.lanczos_update import lanczos_update_kernel_call
from repro.kernels.mixed_dot import mixed_dot_kernel_call
from repro.kernels.spmv_ell import spmv_ell_kernel_call
from repro.sparse import generate, to_device_ell

DTYPES = [jnp.float32, jnp.bfloat16]
TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("n", [512, 2048])
@pytest.mark.parametrize("deg", [2.0, 10.0])
@pytest.mark.parametrize("dtype", DTYPES)
def test_spmv_ell_kernel_sweep(n, deg, dtype):
    csr = generate("urand", n, deg, seed=int(deg) + n, values="uniform")
    ell = to_device_ell(csr, dtype=dtype)
    x = jnp.asarray(np.random.default_rng(0).standard_normal(ell.val.shape[0]), dtype=dtype)
    # note: cols index into [0, n) but x padded len == rows_pad >= n: slice ok
    y_k = spmv_ell_kernel_call(ell.val, ell.col, x, interpret=True)
    y_r = ref.spmv_ell_ref(ell.val, ell.col, x)
    np.testing.assert_allclose(
        np.asarray(y_k, np.float64), np.asarray(y_r, np.float64),
        rtol=TOL[dtype], atol=TOL[dtype] * 10,
    )


@pytest.mark.parametrize("block_r,block_w", [(8, 128), (8, 512), (16, 256)])
def test_spmv_ell_block_shapes(block_r, block_w):
    csr = generate("web", 1024, 6.0, seed=1, values="uniform")
    ell = to_device_ell(csr, dtype=jnp.float32, row_tile=16, slot_tile=512)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(ell.val.shape[0]), jnp.float32)
    y_k = spmv_ell_kernel_call(
        ell.val, ell.col, x, block_r=block_r, block_w=block_w, interpret=True
    )
    y_r = ref.spmv_ell_ref(ell.val, ell.col, x)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize("n", [1024, 16384])
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("compensated", [False, True])
def test_mixed_dot_kernel_sweep(n, dtype, compensated):
    rng = np.random.default_rng(n)
    a = jnp.asarray(rng.standard_normal(n), dtype=dtype)
    b = jnp.asarray(rng.standard_normal(n), dtype=dtype)
    out = mixed_dot_kernel_call(a, b, compensated=compensated, interpret=True)
    got = float(out.sum())
    want = float(ref.mixed_dot_ref(a, b, accum_dtype=jnp.float64))
    assert abs(got - want) < TOL[dtype] * max(1.0, abs(want)) * 10


def test_mixed_dot_compensation_beats_naive_f32():
    """Neumaier compensation recovers accuracy on an adversarial sum."""
    n = 1 << 18
    rng = np.random.default_rng(9)
    big = rng.standard_normal(n // 2) * 1e4
    a_np = np.stack([big, -big], axis=1).reshape(-1) + rng.standard_normal(n) * 1e-3
    a = jnp.asarray(a_np, jnp.float32)
    one = jnp.ones_like(a)
    want = float(np.sum(a_np.astype(np.float64)))
    naive = float(
        mixed_dot_kernel_call(a, one, compensated=False, block=1024, interpret=True).sum()
    )
    comp = float(mixed_dot_kernel_call(a, one, compensated=True, block=1024, interpret=True).sum())
    assert abs(comp - want) <= abs(naive - want)


@pytest.mark.parametrize("n", [1024, 8192])
@pytest.mark.parametrize("dtype", DTYPES)
def test_lanczos_update_kernel_sweep(n, dtype):
    rng = np.random.default_rng(n + 1)
    w, v, vp = (jnp.asarray(rng.standard_normal(n), dtype=dtype) for _ in range(3))
    alpha, beta = jnp.float32(0.37), jnp.float32(1.21)
    u_k, n_k = lanczos_update_kernel_call(w, v, vp, alpha, beta, interpret=True)
    u_r, n_r = ref.lanczos_update_ref(w, v, vp, alpha, beta)
    np.testing.assert_allclose(
        np.asarray(u_k, np.float64), np.asarray(u_r, np.float64), rtol=TOL[dtype], atol=TOL[dtype]
    )
    assert abs(float(n_k[0]) - float(n_r)) < TOL[dtype] * max(1.0, float(n_r)) * 10


def test_ops_wrappers_dispatch(web_csr):
    """ops.py wrappers: kernel path (f32) and jnp fallback (f64) both correct."""
    ell = to_device_ell(web_csr, dtype=jnp.float32)
    x = jnp.asarray(np.random.default_rng(3).standard_normal(ell.val.shape[0]), jnp.float32)
    y32 = ops.spmv_ell(ell, x, accum_dtype=jnp.float32)
    y64 = ops.spmv_ell(ell, x[: ell.n_rows], accum_dtype=jnp.float64)
    np.testing.assert_allclose(
        np.asarray(y32, np.float64),
        np.asarray(y64, np.float64)[: y32.shape[0]],
        rtol=1e-4,
        atol=1e-4,
    )


@pytest.mark.parametrize("bs", [4, 8])
@pytest.mark.parametrize("kind", ["road", "urand"])
def test_spmv_bsr_kernel(bs, kind):
    from repro.kernels import ops
    from repro.kernels.spmv_bsr import blocked_ell_from_csr

    csr = generate(kind, 512, 3.0, seed=bs, values="uniform")
    blocked = blocked_ell_from_csr(csr, block_size=bs, dtype=jnp.float32)
    x = jnp.asarray(np.random.default_rng(7).standard_normal(csr.n), jnp.float32)
    y_k = ops.spmv_bsr(blocked, x, accum_dtype=jnp.float32, interpret=True)
    y_ref = csr.to_scipy() @ np.asarray(x, dtype=np.float64)
    np.testing.assert_allclose(np.asarray(y_k, np.float64), y_ref, rtol=2e-5, atol=1e-4)


def test_spmv_bsr_eigensolver_path():
    """Full Top-K solve through the MXU blocked-ELL SpMV engine.

    Uses a road-lattice matrix: block-local structure keeps the slot count
    (and hence the interpret-mode grid) small — the regime BSR targets.
    """
    from repro.core import FFF, make_operator, topk_eigs

    csr = generate("road", 484, 3.0, seed=11, values="normalized")
    v1 = jnp.ones((csr.n,), jnp.float64)
    r_coo = topk_eigs(make_operator(csr, "coo"), 3, policy=FFF, reorth="full",
                      num_iters=9, v1=v1)
    r_bsr = topk_eigs(make_operator(csr, "bsr_kernel"), 3, policy=FFF, reorth="full",
                      num_iters=9, v1=v1)
    np.testing.assert_allclose(
        np.asarray(r_coo.eigenvalues), np.asarray(r_bsr.eigenvalues), rtol=1e-4
    )


def test_lanczos_update_wrapper_pads_arbitrary_lengths():
    """ops.lanczos_update handles n not divisible by the kernel block
    (zero-padded lanes produce u=0 and leave the norm untouched)."""
    rng = np.random.default_rng(9)
    n = 5000  # 5000 % 4096 != 0
    w, v, vp = (jnp.asarray(rng.standard_normal(n), jnp.float32) for _ in range(3))
    alpha, beta = jnp.float32(0.37), jnp.float32(1.21)
    u, nrm = ops.lanczos_update(w, v, vp, alpha, beta, accum_dtype=jnp.float32)
    u_r, n_r = ref.lanczos_update_ref(w, v, vp, alpha, beta)
    assert u.shape == (n,)
    np.testing.assert_allclose(
        np.asarray(u, np.float64), np.asarray(u_r, np.float64), rtol=1e-5, atol=1e-5
    )
    assert abs(float(nrm) - float(n_r)) < 1e-2 * max(1.0, float(n_r))
