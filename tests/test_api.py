"""The unified ``eigsh`` frontend: coercion, dispatch, result schema, shims."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.api import (
    BACKENDS,
    CHUNKED_NNZ_THRESHOLD,
    EigenResult,
    SolverConfig,
    eigsh,
    resolve_policy,
    select_backend,
)
from repro.core import POLICIES, make_operator
from repro.core.metrics import eigsh_reference

K = 4
ITERS = 24


@pytest.fixture(scope="module")
def ref_vals(web_csr):
    vals, _ = eigsh_reference(web_csr, K)
    return vals


# ---------------------------------------------------------------- coercion


def _schema_check(res, n):
    assert isinstance(res, EigenResult)
    assert res.eigenvalues.shape == (K,)
    assert res.eigenvectors.shape == (n, K)
    assert res.residuals.shape == (K,)
    assert res.converged.shape == (K,)
    assert res.converged.dtype == bool
    assert res.backend in BACKENDS
    assert res.iterations >= K
    assert "total_s" in res.timings
    assert res.k == K and res.n == n


def test_accepts_all_input_forms(web_csr, ref_vals):
    """Dense / CSR / scipy-sparse / operator / callable give the same answer
    through an identical result schema."""
    n = web_csr.n
    sp = web_csr.to_scipy()
    inputs = {
        "csr": web_csr,
        "dense": web_csr.toarray(),
        "scipy": sp,
        "operator": make_operator(web_csr, "coo", dtype=jnp.float32),
        "callable": lambda x: sp @ np.asarray(x, dtype=np.float64),
    }
    for name, a in inputs.items():
        res = eigsh(a, K, policy="FDF", reorth="full", num_iters=ITERS,
                    n=n if name == "callable" else None)
        _schema_check(res, n)
        np.testing.assert_allclose(
            np.asarray(res.eigenvalues, dtype=np.float64), ref_vals, rtol=1e-4,
            err_msg=f"input form {name}",
        )


def test_scipy_linearoperator_input(web_csr, ref_vals):
    import scipy.sparse.linalg as spla

    lo = spla.aslinearoperator(web_csr.to_scipy())
    res = eigsh(lo, K, policy="FDF", reorth="full", num_iters=ITERS)
    np.testing.assert_allclose(
        np.asarray(res.eigenvalues, dtype=np.float64), ref_vals, rtol=1e-4
    )


def test_callable_without_n_rejected():
    with pytest.raises(ValueError, match="n="):
        eigsh(lambda x: x, 2)


def test_non_square_rejected():
    with pytest.raises(ValueError, match="square"):
        eigsh(np.zeros((4, 5)), 2)


def test_unknown_input_type_rejected():
    with pytest.raises(TypeError, match="does not understand"):
        eigsh(object(), 2)


# ---------------------------------------------------------------- dispatch


def test_auto_dispatch_decisions():
    # >1 device and an explicit matrix -> distributed (the paper's headline mode)
    assert select_backend("auto", has_matrix=True, nnz=10_000, device_count=4) == "distributed"
    # huge nnz, single device -> out-of-core chunked path
    assert (
        select_backend("auto", has_matrix=True, nnz=CHUNKED_NNZ_THRESHOLD, device_count=1)
        == "chunked"
    )
    # host-memory pressure alone also forces chunked
    assert (
        select_backend(
            "auto", has_matrix=True, nnz=1_000_000, device_count=1, free_bytes=1_000_000
        )
        == "chunked"
    )
    # a tolerance request -> restarted (fixed-m cannot promise residuals),
    # even when multiple devices are visible
    assert select_backend("auto", has_matrix=True, nnz=100, tol=1e-8, device_count=1) == "restarted"
    assert select_backend("auto", has_matrix=True, nnz=100, tol=1e-8, device_count=8) == "restarted"
    assert select_backend("auto", has_matrix=False, tol=1e-8) == "restarted"
    # default -> the paper's single-device pipeline
    assert select_backend("auto", has_matrix=True, nnz=100, device_count=1) == "single"
    assert select_backend("auto", has_matrix=False) == "single"


def test_explicit_backend_validation():
    with pytest.raises(ValueError, match="unknown backend"):
        select_backend("warp", has_matrix=True)
    # distributed / chunked need a host-side sparse matrix
    with pytest.raises(ValueError, match="host-side sparse matrix"):
        select_backend("distributed", has_matrix=False)
    with pytest.raises(ValueError, match="host-side sparse matrix"):
        select_backend("chunked", has_matrix=False)
    assert select_backend("single", has_matrix=False) == "single"


def test_single_process_auto_is_single(norm_csr):
    """In this 1-device container, auto must not pick distributed."""
    assert len(jax.devices()) == 1
    res = eigsh(norm_csr, K, policy="FDF", num_iters=ITERS)
    assert res.backend == "single"
    # The plan/execute split reports what the call paid in partition["spmv"]
    # on every backend (single included).
    assert res.partition["spmv"]["format"] == res.spmv_format


def test_chunked_backend_matches_single(norm_csr):
    v0 = jnp.ones((norm_csr.n,), jnp.float64)
    r_s = eigsh(norm_csr, K, backend="single", policy="FDF", reorth="full",
                num_iters=ITERS, v0=v0)
    r_c = eigsh(norm_csr, K, backend="chunked", chunk_nnz=4096, policy="FDF",
                reorth="full", num_iters=ITERS, v0=v0)
    assert r_c.backend == "chunked"
    np.testing.assert_allclose(
        np.asarray(r_s.eigenvalues), np.asarray(r_c.eigenvalues), rtol=1e-6
    )


# ---------------------------------------------------------------- policies


def test_string_policies_resolve(norm_csr):
    for name in POLICIES:
        assert resolve_policy(name).name == name
    res = eigsh(norm_csr, K, policy="FFF", num_iters=ITERS)
    assert res.policy == "FFF"


def test_unknown_policy_rejected():
    with pytest.raises(ValueError, match="unknown precision policy"):
        resolve_policy("XYZ")


def test_policy_instance_accepted(norm_csr):
    from repro.core import FDF

    res = eigsh(norm_csr, K, policy=FDF, num_iters=ITERS)
    assert res.policy == "FDF"  # x64 enabled in conftest, no downgrade


# ---------------------------------------------------------------- results


def test_residuals_shrink_with_num_iters(norm_csr):
    r8 = eigsh(norm_csr, K, policy="FDF", reorth="full", num_iters=8)
    r32 = eigsh(norm_csr, K, policy="FDF", reorth="full", num_iters=32)
    assert r32.residuals.max() < r8.residuals.max()
    assert r8.iterations == 8 and r32.iterations == 32


def test_converged_flags_consistent_with_tol(norm_csr):
    tol = 1e-6
    res = eigsh(norm_csr, K, policy="FDF", backend="single", reorth="full",
                num_iters=ITERS, tol=tol)
    lam = np.abs(np.asarray(res.eigenvalues, dtype=np.float64))
    np.testing.assert_array_equal(res.converged, res.residuals <= tol * lam)
    assert res.tol == tol


def test_restarted_backend_converges(web_csr, ref_vals):
    res = eigsh(web_csr, K, policy="FDF", tol=1e-7, subspace=16)
    assert res.backend == "restarted"
    assert res.all_converged
    assert res.restarts >= 1
    assert res.iterations > 16  # more than one cycle was needed
    np.testing.assert_allclose(
        np.asarray(res.eigenvalues, dtype=np.float64), ref_vals, rtol=1e-5, atol=1e-7
    )


def test_num_iters_budget_caps_restarts(norm_csr):
    res = eigsh(norm_csr, K, policy="FDF", backend="restarted", tol=1e-14,
                subspace=12, num_iters=20)
    # budget: first cycle 12 steps + one restart of (12 - 4) steps
    assert res.iterations <= 20
    assert not res.all_converged  # unreachable tol, budget respected
    # a budget that doesn't fit a second cycle must not overshoot
    res13 = eigsh(norm_csr, K, policy="FDF", backend="restarted", tol=1e-14,
                  subspace=12, num_iters=13)
    assert res13.iterations <= 13
    # a budget below the minimum viable subspace is an error, not an overshoot
    with pytest.raises(ValueError, match="num_iters"):
        eigsh(norm_csr, K, backend="restarted", tol=1e-8, num_iters=K + 1)


def test_unconverged_restarted_vectors_stay_consistent(norm_csr):
    """Exhausting the restart budget must still return eigenvectors in the
    coordinates of the final basis (unit norm, residuals matching the
    reported Ritz bounds to order of magnitude)."""
    res = eigsh(norm_csr, K, policy="FDF", backend="restarted", tol=1e-30,
                subspace=12, max_restarts=1)
    assert not res.all_converged
    x = np.asarray(res.eigenvectors, dtype=np.float64)
    norms = np.linalg.norm(x, axis=0)
    np.testing.assert_allclose(norms, 1.0, atol=1e-3)
    a = norm_csr.to_scipy()
    lam = np.asarray(res.eigenvalues, dtype=np.float64)
    true_resid = np.linalg.norm(a @ x - x * lam, axis=0)
    # the Ritz bound equals the true residual for an exact Krylov subspace
    np.testing.assert_allclose(true_resid, res.residuals, rtol=0.5, atol=1e-6)


def test_restarted_rejects_zero_max_restarts(norm_csr):
    with pytest.raises(ValueError, match="max_restarts"):
        eigsh(norm_csr, K, backend="restarted", tol=1e-8, max_restarts=0)


def test_restarted_without_tol_iterates_to_reported_default(norm_csr):
    """backend='restarted' with tol=None must iterate toward the same
    tolerance the converged flags are judged against — not a hardcoded one."""
    res = eigsh(norm_csr, K, backend="restarted", policy="FFF", subspace=16)
    assert res.tol == pytest.approx(float(np.sqrt(np.finfo(np.float32).eps)))
    np.testing.assert_array_equal(
        res.converged,
        res.residuals <= res.tol * np.abs(np.asarray(res.eigenvalues, dtype=np.float64)),
    )


def test_explicit_mesh_forces_distributed_under_auto(norm_csr):
    """mesh= must not be silently dropped when tol would pick restarted;
    and mesh + matrix-free input is a clear error."""
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()).reshape(1), ("data",))
    res = eigsh(norm_csr, K, mesh=mesh, tol=1e-6, num_iters=ITERS)
    assert res.backend == "distributed"
    with pytest.raises(ValueError, match="matrix-free"):
        eigsh(lambda x: x, 2, n=16, mesh=mesh)


def test_restarted_warns_on_ignored_reorth(norm_csr):
    with pytest.warns(UserWarning, match="ignored by the restarted backend"):
        eigsh(norm_csr, K, backend="restarted", tol=1e-6, reorth="none",
              subspace=16, max_restarts=2)


def test_reorth_default_is_per_backend():
    from repro.api.frontend import _resolve_reorth

    assert _resolve_reorth(None, "single") == "half"       # paper Alg. 1
    assert _resolve_reorth(None, "chunked") == "half"
    assert _resolve_reorth(None, "distributed") == "full"  # paper multi-GPU
    assert _resolve_reorth("none", "distributed") == "none"  # explicit wins


def test_scipy_style_unpack(norm_csr):
    w, v = eigsh(norm_csr, K, policy="FDF", num_iters=ITERS)
    assert w.shape == (K,) and v.shape == (norm_csr.n, K)


def test_solver_config_reusable(norm_csr):
    cfg = SolverConfig(policy="FFF", reorth="full", num_iters=ITERS)
    r1 = eigsh(norm_csr, K, config=cfg)
    r2 = eigsh(norm_csr, K, config=cfg)
    np.testing.assert_array_equal(np.asarray(r1.eigenvalues), np.asarray(r2.eigenvalues))
    assert r1.policy == "FFF"


def test_summary_renders(norm_csr):
    res = eigsh(norm_csr, K, policy="FDF", num_iters=ITERS)
    s = res.summary()
    assert "backend=single" in s and "policy=FDF" in s


# ---------------------------------------------------------------- shims


def test_topk_eigs_shim_deprecated(norm_csr):
    from repro.core import topk_eigs

    op = make_operator(norm_csr, "coo", dtype=jnp.float32)
    with pytest.warns(DeprecationWarning, match="eigsh"):
        old = topk_eigs(op, K, reorth="full", num_iters=ITERS)
    new = eigsh(op, K, policy="FDF", reorth="full", num_iters=ITERS)
    np.testing.assert_allclose(
        np.asarray(old.eigenvalues), np.asarray(new.eigenvalues), rtol=1e-6
    )
    assert old.wall_time_s > 0


def test_topk_eigs_restarted_shim_deprecated(norm_csr):
    from repro.core import topk_eigs_restarted

    op = make_operator(norm_csr, "coo", dtype=jnp.float32)
    with pytest.warns(DeprecationWarning, match="eigsh"):
        old = topk_eigs_restarted(op, K, m=16, tol=1e-6, max_restarts=20)
    assert old.eigenvalues.shape == (K,)
    assert old.tridiag.basis.shape[0] == 16  # bounded-memory contract intact
