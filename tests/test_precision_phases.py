"""Per-phase precision policies + the accuracy-driven ``policy="auto"``.

The Fig.4-style accuracy harness for ISSUE 5: a fixed seeded matrix is
solved under the paper's precision ladder and the measured eigenvalue error
and basis-orthogonality loss must be monotone FFF -> FCF -> FDF -> DDD
(f64 rungs skipped when x64 is unavailable); per-phase overrides that all
equal the compute dtype must reproduce the uniform policy bit-identically;
and ``policy="auto"`` must provably escalate (attempt trace asserted) and
land on a policy meeting ``tol``, with the f64-work reduction of a phase
split verified through the ``partition["spmv"]["precision"]`` audit
counters.

The ``compensated_sum`` property test runs from a fixed seeded case list so
the suite needs no optional dependencies; with ``hypothesis`` installed the
same check body is additionally driven from search strategies (the
``test_sparse.py`` fallback pattern).
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import eigsh, resolve_policy, session_cache_clear
from repro.core import PHASES, auto_ladder
from repro.core.lanczos import fused_update_enabled, make_local_ops
from repro.core.metrics import eigsh_reference, pairwise_orthogonality_deg
from repro.core.precision import DDD, FCF, FDF, FFF, compensated_sum, x64_enabled
from repro.api.session import policy_key
from repro.sparse import generate

K = 3
SUBSPACE = 12
RESTARTS = 30


@pytest.fixture(scope="module")
def mat():
    """The harness matrix: fixed seed, normalized spectrum (|lambda| <= 1)."""
    return generate("web", 512, 6.0, seed=11, values="normalized")


@pytest.fixture(autouse=True)
def _fresh_sessions():
    # Ladder solves must not inherit another test's cached per-policy plans
    # when the test asserts on build/audit counters.
    session_cache_clear()
    yield


def _ladder_rungs():
    """The Fig.4 ladder, f64 rungs dropped when x64 is unavailable (they
    would alias their f32 neighbours and break strict comparisons)."""
    rungs = [FFF, FCF]
    if x64_enabled():
        rungs += [FDF, DDD]
    return rungs


def _accuracy(csr, policy):
    """(eigenvalue error, orthogonality loss) of a to-the-policy's-floor
    solve — the restarted engine iterates until the residual stalls at the
    arithmetic's own limit, so the metrics measure PRECISION, not Krylov
    truncation (the fig4 methodology)."""
    ref_vals, _ = eigsh_reference(csr, K)
    r = eigsh(
        csr,
        K,
        policy=policy,
        backend="restarted",
        tol=1e-13,
        subspace=SUBSPACE,
        max_restarts=RESTARTS,
    )
    lam = np.asarray(r.eigenvalues, dtype=np.float64)
    ev_err = float(np.max(np.abs(lam - ref_vals) / np.maximum(np.abs(ref_vals), 1e-300)))
    orth_loss = abs(90.0 - pairwise_orthogonality_deg(r.eigenvectors))
    return ev_err, orth_loss


# ------------------------------ accuracy ladder -------------------------------


def test_accuracy_ladder_monotone(mat):
    """Fig. 4: eigenvalue error and orthogonality loss are monotone down the
    FFF -> FCF -> FDF -> DDD ladder (1.5x slack per step for f32-floor noise;
    the FFF -> DDD drop must be strict and large)."""
    errs, orths, names = [], [], []
    for pol in _ladder_rungs():
        ev_err, orth = _accuracy(mat, pol)
        errs.append(ev_err)
        orths.append(orth)
        names.append(pol.name)
    for i in range(len(errs) - 1):
        assert errs[i + 1] <= errs[i] * 1.5 + 1e-15, (names, errs)
        assert orths[i + 1] <= orths[i] * 1.5 + 1e-12, (names, orths)
    if x64_enabled():
        assert errs[-1] < errs[0] / 10, (names, errs)  # DDD floor << FFF floor


def test_bf16_rung_is_least_accurate(mat):
    """The TPU-native bf16 rung sits above the f32 rung in error — the
    bottom of the auto ladder is really the cheapest/least accurate."""
    from repro.core.precision import BFF

    err_b, _ = _accuracy(mat, BFF)
    err_f, _ = _accuracy(mat, FFF)
    assert err_f < err_b


# ------------------------- per-phase override semantics -----------------------


@pytest.mark.parametrize("base", [FFF, FDF])
def test_uniform_phase_overrides_bit_identical(mat, base):
    """Overriding every phase with the policy's own compute dtype must
    reproduce the uniform-policy results bit-identically (the overrides are
    inherit-from-compute, not a parallel arithmetic)."""
    pol = base.effective()
    cdt = jnp.dtype(pol.compute).name
    overridden = pol.with_phases(spmv=cdt, alpha_beta=cdt, reorth=cdt, ritz=cdt)
    assert overridden.is_uniform()
    r_uni = eigsh(mat, K, policy=pol, num_iters=16, reorth="full", backend="single")
    r_ovr = eigsh(mat, K, policy=overridden, num_iters=16, reorth="full", backend="single")
    assert (
        np.asarray(r_uni.eigenvalues).tobytes() == np.asarray(r_ovr.eigenvalues).tobytes()
    )
    assert (
        np.asarray(r_uni.eigenvectors).tobytes() == np.asarray(r_ovr.eigenvectors).tobytes()
    )
    np.testing.assert_array_equal(r_uni.residuals, r_ovr.residuals)


@pytest.mark.skipif(not x64_enabled(), reason="f64 phase split needs x64")
def test_reorth_f32_split_matches_fdf_with_less_f64_work(mat):
    """The acceptance split: reorth in f32 while alpha/beta accumulate in
    f64 must match full-FDF residuals within 10x while reducing f64-dtype
    operations (verified via the partition["spmv"]["precision"] audit)."""
    split = FDF.with_phases(reorth="f32")
    r_fdf = eigsh(mat, K, policy=FDF, num_iters=16, reorth="full", backend="single")
    r_split = eigsh(mat, K, policy=split, num_iters=16, reorth="full", backend="single")
    assert r_split.residuals.max() <= 10 * r_fdf.residuals.max() + 1e-300
    ops_fdf = r_fdf.partition["spmv"]["precision"]["ops_by_dtype"]
    ops_split = r_split.partition["spmv"]["precision"]["ops_by_dtype"]
    assert ops_split["float64"] < ops_fdf["float64"]
    assert ops_split.get("float32", 0) > 0  # the reorth work moved to f32
    # provenance: the executed phase map is surfaced on the result
    prec = r_split.partition["spmv"]["precision"]
    assert prec["phase_map"] == split.phase_map()
    assert prec["phase_map"]["reorth"] == "float32"
    assert prec["phase_map"]["alpha_beta"] == "float64"
    assert not prec["uniform"]


@pytest.mark.skipif(not x64_enabled(), reason="f64 phase split needs x64")
def test_alpha_beta_f64_upgrade_improves_fff(mat):
    """The converse split: FFF with only the alpha/beta reductions widened
    to f64 should not be less accurate than plain FFF (the wide-accumulator
    role of FDF at a fraction of its f64 work)."""
    upgraded = FFF.with_phases(alpha_beta="f64")
    err_fff, _ = _accuracy(mat, FFF)
    err_up, _ = _accuracy(mat, upgraded)
    assert err_up <= err_fff * 1.5 + 1e-15


def test_phase_split_runs_on_chunked_backend(mat):
    """Per-phase dtypes thread through the out-of-core engine too: a split
    policy on the chunked path agrees with the same split single-device."""
    split = FFF.with_phases(alpha_beta="f32", reorth="f32")  # uniform-equivalent
    v1 = jnp.ones((mat.n,), jnp.float64)
    r_s = eigsh(mat, 2, policy=split, num_iters=8, backend="single", v0=v1)
    r_c = eigsh(mat, 2, policy=split, num_iters=8, backend="chunked", chunk_nnz=2048, v0=v1)
    np.testing.assert_allclose(
        np.asarray(r_s.eigenvalues), np.asarray(r_c.eigenvalues), rtol=1e-5
    )


def test_fused_update_gating_respects_alpha_beta_phase():
    """A split alpha_beta dtype must disable the fused Pallas update (its
    fused norm runs in the recurrence dtype); other phase overrides keep it."""
    pol = FFF.effective()
    assert fused_update_enabled(pol)
    assert not fused_update_enabled(pol.with_phases(alpha_beta="f64" if x64_enabled() else "bf16"))
    assert fused_update_enabled(pol.with_phases(reorth="bf16"))
    split = pol.with_phases(alpha_beta="bf16")
    assert make_local_ops(lambda x: x, split).fused_update is None


def test_phase_split_shares_uniform_plan(mat):
    """A reorth/alpha_beta/ritz split changes per-query arithmetic only: it
    must reuse the uniform policy's built plan (the device operator depends
    on storage + spmv dtype alone), paying zero conversions."""
    eigsh(mat, 2, policy="FDF", num_iters=8)
    r2 = eigsh(mat, 2, policy=FDF.with_phases(reorth="f32"), num_iters=8)
    assert r2.session_reuse
    assert r2.partition["spmv"]["conversions"] == 0
    assert r2.partition["spmv"]["reused"]


def test_ritz_phase_honored_by_jax_jacobi(mat):
    """The device-Jacobi path must run phase-2 in the ritz dtype too (the
    audit's phase_map reports it as executed)."""
    split = FFF.with_phases(ritz="f64") if x64_enabled() else FFF
    r_jax = eigsh(mat, 2, policy=split, num_iters=8, jacobi="jax")
    r_host = eigsh(mat, 2, policy=split, num_iters=8, jacobi="host")
    np.testing.assert_allclose(
        np.asarray(r_jax.eigenvalues, np.float64),
        np.asarray(r_host.eigenvalues, np.float64),
        rtol=1e-4,
    )


# ------------------------------ resolve_policy -------------------------------


def test_resolve_policy_case_insensitive():
    assert resolve_policy("fdf").name == "FDF"
    assert resolve_policy("Bcf").name == "BCF"
    assert resolve_policy(" fff ").name == "FFF"


def test_resolve_policy_unknown_name_is_value_error():
    with pytest.raises(ValueError, match="unknown precision policy"):
        resolve_policy("FDX")


def test_resolve_policy_phase_override_mapping():
    p = resolve_policy({"base": "fdf", "reorth": "f32"})
    assert p.storage is FDF.storage and jnp.dtype(p.phase_dtype("reorth")) == jnp.float32
    assert jnp.dtype(p.phase_dtype("alpha_beta")) == jnp.dtype(jnp.float64)


def test_resolve_policy_unknown_phase_key_named_error():
    """A typo'd phase key must be a named ValueError listing the valid
    phases — never a raw KeyError."""
    with pytest.raises(ValueError, match="valid phases"):
        resolve_policy({"base": "FDF", "reorthh": "f32"})
    with pytest.raises(ValueError, match="valid phases"):
        FDF.with_phases(sppmv="f32")
    with pytest.raises(ValueError, match="valid phases"):
        FDF.phase_dtype("jacobi")


def test_resolve_policy_auto_is_mode_not_policy():
    with pytest.raises(ValueError, match="auto"):
        resolve_policy("auto")


def test_policy_key_is_phase_aware():
    """Session operator caching: overrides equal to compute key like the
    uniform policy (same plan); a real split keys differently."""
    cdt = jnp.dtype(FDF.effective().compute).name
    assert policy_key(FDF) == policy_key(FDF.with_phases(reorth=cdt))
    assert policy_key(FDF.with_phases(reorth="bf16")) != policy_key(FDF)
    assert set(PHASES) == {"spmv", "alpha_beta", "reorth", "ritz"}


# ------------------------------- policy="auto" --------------------------------


def test_auto_escalates_and_meets_tol(mat):
    """tol between the bf16 and f32 floors: auto must try BFF, measure it
    failing, escalate to FFF, and stop there with the trace recorded."""
    res = eigsh(mat, K, policy="auto", tol=1e-4, subspace=SUBSPACE, max_restarts=RESTARTS)
    trace = res.policy_escalations
    assert trace is not None and len(trace) == 2
    assert [a["policy"] for a in trace] == ["BFF", "FFF"]
    assert not trace[0]["converged"] and trace[1]["converged"]
    assert trace[0]["max_residual"] > 1e-4 >= trace[1]["max_residual"]
    assert trace[1]["residual_kind"] == "verified"
    assert res.policy == "FFF"
    # the attempt order is a prefix of the ladder
    ladder = list(auto_ladder())
    assert [a["policy"] for a in trace] == ladder[: len(trace)]


def test_auto_loose_tol_stops_at_first_rung(mat):
    res = eigsh(mat, K, policy="auto", tol=5e-2, subspace=SUBSPACE, max_restarts=RESTARTS)
    assert [a["policy"] for a in res.policy_escalations] == [auto_ladder()[0]]
    assert res.all_converged


@pytest.mark.skipif(not x64_enabled(), reason="the f64 rungs need x64")
def test_auto_reaches_f64_rung_for_tight_tol(mat):
    """tol below every f32-storage floor: the ladder must run to DDD, every
    earlier rung measured and rejected."""
    res = eigsh(mat, K, policy="auto", tol=1e-9, subspace=SUBSPACE, max_restarts=RESTARTS)
    trace = res.policy_escalations
    assert [a["policy"] for a in trace] == ["BFF", "FFF", "FCF", "FDF", "DDD"]
    assert [a["converged"] for a in trace] == [False, False, False, False, True]
    assert res.policy == "DDD"
    assert trace[-1]["max_residual"] <= 1e-9


def test_auto_ladder_capped_by_x64():
    rungs = auto_ladder()
    if x64_enabled():
        assert rungs == ("BFF", "FFF", "FCF", "FDF", "DDD")
    else:
        assert rungs == ("BFF", "FFF", "FCF")


def test_explicit_policy_has_no_escalations(mat):
    res = eigsh(mat, 2, policy="FFF", num_iters=8)
    assert res.policy_escalations is None


def test_auto_reuses_session_plans(mat):
    """The second auto solve reuses the per-policy operator plans the first
    one built (phase-aware policy_key): zero conversions, session_reuse."""
    eigsh(mat, K, policy="auto", tol=1e-4, subspace=SUBSPACE, max_restarts=RESTARTS)
    res2 = eigsh(mat, K, policy="auto", tol=1e-4, subspace=SUBSPACE, max_restarts=RESTARTS)
    assert res2.session_reuse
    assert res2.partition["spmv"]["conversions"] == 0
    assert res2.partition["spmv"]["tuner_probes"] == 0


def test_auto_result_roundtrips_to_json(mat):
    import json

    res = eigsh(mat, 2, policy="auto", tol=5e-2, subspace=SUBSPACE, max_restarts=RESTARTS)
    d = json.loads(json.dumps(res.to_dict()))
    from repro.api import EigenResult

    back = EigenResult.from_dict(d)
    assert back.policy_escalations == res.policy_escalations


# --------------------------- compensated_sum property -------------------------


def _cancellation_cases(num=20, seed=7):
    """Adversarial cancellation inputs: mixed-magnitude values paired with
    their negations plus a small survivor, shuffled — the naive sum loses
    the survivor to absorption, fsum never does."""
    rng = np.random.default_rng(seed)
    cases = []
    for _ in range(num):
        n = int(rng.integers(4, 400))
        base = (rng.standard_normal(n) * (10.0 ** rng.integers(0, 8, n))).astype(np.float32)
        vals = np.concatenate([base, -base, rng.standard_normal(3).astype(np.float32)])
        rng.shuffle(vals)
        cases.append(vals)
    return cases


def check_compensated_vs_fsum(vals_f32: np.ndarray) -> None:
    """compensated_sum must track math.fsum within a ~wide-accumulator bound
    and never be (meaningfully) worse than the naive sum."""
    vals_f32 = np.asarray(vals_f32, dtype=np.float32)
    ref = math.fsum(float(v) for v in vals_f32)  # exact in double
    got = float(compensated_sum(jnp.asarray(vals_f32), jnp.float32))
    naive = float(jnp.sum(jnp.asarray(vals_f32)))
    scale = float(np.sum(np.abs(vals_f32), dtype=np.float64))
    eps = float(np.finfo(np.float32).eps)
    slack = eps * scale + 1e-30
    assert abs(got - ref) <= abs(naive - ref) + 4 * slack
    assert abs(got - ref) <= 8 * slack  # ~2x-wider-accumulator bound


@pytest.mark.parametrize("case", range(len(_cancellation_cases())))
def test_compensated_sum_vs_fsum_seeded(case):
    check_compensated_vs_fsum(_cancellation_cases()[case])


def test_compensated_sum_vs_fsum_hypothesis():
    """Hypothesis-driven variant of the same check (skipped without the
    ``[test]`` extra; the seeded cases above always run)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    finite = st.floats(
        min_value=-1e8, max_value=1e8, allow_nan=False, allow_infinity=False, width=32
    )

    @settings(max_examples=25, deadline=None)
    @given(st.lists(finite, min_size=1, max_size=300), st.integers(0, 2**31 - 1))
    def prop(xs, seed):
        vals = np.asarray(xs, dtype=np.float32)
        # force cancellation structure: append the negation, shuffled
        rng = np.random.default_rng(seed)
        vals = np.concatenate([vals, -vals, np.float32([0.125])])
        rng.shuffle(vals)
        check_compensated_vs_fsum(vals)

    prop()
