"""SpmvEngine layer: format auto-selection, tiles, and kernel-backed solves.

Property-style coverage of the selector (synthetic block-diagonal -> BSR,
banded -> ELL, power-law -> COO) plus cross-format agreement against the
dense reference SpMV, the shard-local conversions, and the engine-driven
solver paths (single, chunked, and a 1-shard distributed run proving the
hot loop never calls ``segment_sum``).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
import scipy.sparse as sp

from repro.api import eigsh
from repro.core.distributed import solve_sharded
from repro.core.operators import ChunkedOperator, make_operator
from repro.core.partition import nnz_balanced_splits
from repro.kernels.engine import (
    SpmvEngine,
    TileConfig,
    choose_format,
    make_engine,
    matrix_stats,
    select_tiles,
    shard_stats,
)
from repro.sparse import generate
from repro.sparse.formats import (
    CSR,
    shard_to_blocked_ell,
    shard_to_ell,
    to_device_bsr,
)

ACCUM_TOL = {jnp.float32: 2e-5, jnp.float64: 1e-12}


def _csr_from_scipy(m) -> CSR:
    m = m.tocsr()
    m.sort_indices()
    return CSR(
        indptr=m.indptr.astype(np.int64),
        indices=m.indices.astype(np.int32),
        data=m.data.astype(np.float64),
        shape=m.shape,
    )


def block_diagonal_csr(n_blocks: int, bs: int = 8, seed: int = 0) -> CSR:
    """Dense symmetric (bs x bs) blocks on the diagonal: the BSR regime."""
    rng = np.random.default_rng(seed)
    blocks = [rng.random((bs, bs)) + 0.1 for _ in range(n_blocks)]
    a = sp.block_diag(blocks, format="csr")
    return _csr_from_scipy(((a + a.T) / 2).tocsr())


def banded_csr(n: int, bandwidth: int = 2, seed: int = 0) -> CSR:
    """Symmetric banded matrix (near-uniform rows): the ELL regime."""
    rng = np.random.default_rng(seed)
    diags = [rng.random(n - abs(o)) + 0.1 for o in range(-bandwidth, bandwidth + 1)]
    a = sp.diags(diags, range(-bandwidth, bandwidth + 1), format="csr")
    return _csr_from_scipy(((a + a.T) / 2).tocsr())


def powerlaw_csr(n: int = 1024, deg: float = 6.0, seed: int = 0) -> CSR:
    """Heavy-hub web graph (max row >> mean row): the COO regime."""
    return generate("web", n, deg, seed=seed, values="uniform")


# --------------------------- format auto-selection ---------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_selector_block_diagonal_picks_bsr(seed):
    csr = block_diagonal_csr(32, bs=8, seed=seed)
    stats = matrix_stats(csr, block_size=8)
    assert stats.block_fill > 0.5
    assert choose_format(stats) == "bsr"


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("bandwidth", [1, 3])
def test_selector_banded_picks_ell(seed, bandwidth):
    csr = banded_csr(512, bandwidth=bandwidth, seed=seed)
    stats = matrix_stats(csr)
    assert stats.ell_overhead <= 1.5  # near-uniform rows: padding is cheap
    assert choose_format(stats) == "ell"


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_selector_powerlaw_picks_hybrid(seed):
    """Hub rows blow the plain-ELL bound, but the quantile-capped split
    bounds the padding — power-law matrices now reach the kernel path."""
    csr = powerlaw_csr(seed=seed)
    stats = matrix_stats(csr)
    assert stats.ell_overhead > 3.0  # hub rows make ELL padding explode
    assert stats.hyb_overhead <= 3.0  # ...but the capped split bounds it
    assert 0 < stats.hyb_width < stats.max_row_nnz
    assert choose_format(stats) == "hybrid"


def hub_dense_csr(n: int = 400, hubs: int = 40, seed: int = 0) -> CSR:
    """>5% of rows fully dense: the hybrid quantile cap lands on the hub
    width itself, so even the capped split blows the padding bound."""
    rng = np.random.default_rng(seed)
    a = sp.lil_matrix((n, n))
    a[:hubs, :] = rng.random((hubs, n)) + 0.1
    a = ((a + a.T) / 2).tocsr()
    return _csr_from_scipy(a)


def test_selector_tail_dominated_picks_coo():
    """When >1-quantile of the rows are hubs the cap lands on the hub width
    itself: even the capped split blows the bound and COO wins.  (BSR is
    excluded: contiguous dense hub strips would legitimately pick it.)"""
    stats = matrix_stats(hub_dense_csr())
    assert stats.ell_overhead > 3.0
    assert stats.hyb_overhead > 3.0 or stats.hyb_tail_frac > 0.6
    assert choose_format(stats, allowed=("coo", "ell", "hybrid")) == "coo"


def test_selector_kernel_only_falls_back_to_ell():
    # A kernel-only path without the hybrid split: padding-heavy matrices
    # still get a correct (kernel) format rather than an error — with a
    # warning, since padded ELL on hub matrices costs O(n * max_row_nnz).
    stats = matrix_stats(powerlaw_csr())
    with pytest.warns(UserWarning, match="padding overhead"):
        assert choose_format(stats, allowed=("ell", "bsr")) == "ell"


def test_selector_kernel_only_prefers_hybrid_no_warning():
    """The distributed allow-list now contains the hub split: the power-law
    case that used to warn-and-pad resolves to hybrid silently."""
    import warnings as w

    stats = matrix_stats(powerlaw_csr())
    with w.catch_warnings():
        w.simplefilter("error")
        assert choose_format(stats, allowed=("ell", "bsr", "hybrid")) == "hybrid"


def test_selector_respects_allowed_and_thresholds():
    bd = matrix_stats(block_diagonal_csr(16))
    assert choose_format(bd, allowed=("coo", "ell")) == "ell"  # bsr excluded
    assert choose_format(bd, bsr_fill_factor=1e9) != "bsr"
    pl = matrix_stats(powerlaw_csr())
    assert choose_format(pl, ell_max_overhead=1e9) == "ell"


def test_make_engine_validates_format():
    csr = banded_csr(128)
    with pytest.raises(ValueError, match="unknown SpMV format"):
        make_engine(csr, "ellpack")
    with pytest.raises(ValueError, match="not supported"):
        make_engine(csr, "bsr", allowed=("coo", "ell"))


# ------------------------------- tile table ----------------------------------


def test_tile_table_scales_with_shape():
    small = select_tiles(512, 64, interpret=False)
    large = select_tiles(1 << 20, 4096, interpret=False)
    assert large.block_r >= small.block_r
    assert large.block_w >= small.block_w


def test_tile_table_16bit_sublane_minimum():
    t = select_tiles(512, 64, dtype=jnp.bfloat16, interpret=False)
    assert t.block_r >= 16


def test_tile_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_SPMV_TILES", "64,256,16")
    t = select_tiles(1 << 20, 4096, interpret=False)
    assert t == TileConfig(block_r=64, block_w=256, block_size=16)
    monkeypatch.setenv("REPRO_SPMV_TILES", "not,numbers")
    with pytest.raises(ValueError):
        select_tiles(64, 64)


# --------------------- cross-format SpMV agreement ---------------------------


@pytest.mark.parametrize(
    "make_csr",
    [
        lambda: block_diagonal_csr(24, seed=3),
        lambda: banded_csr(300, bandwidth=2, seed=3),
        lambda: powerlaw_csr(512, seed=3),
    ],
    ids=["blockdiag", "banded", "powerlaw"],
)
@pytest.mark.parametrize("fmt", ["coo", "ell", "bsr", "hybrid"])
@pytest.mark.parametrize("acc", [jnp.float32, jnp.float64])
def test_all_formats_match_dense_reference(make_csr, fmt, acc):
    csr = make_csr()
    dense = csr.toarray()
    x = np.random.default_rng(5).standard_normal(csr.n)
    engine = make_engine(csr, fmt, accum_dtype=acc)
    op = make_operator(csr, dtype=jnp.float64, engine=engine)
    y = np.asarray(op.matvec(jnp.asarray(x), accum_dtype=acc), dtype=np.float64)
    tol = ACCUM_TOL[acc]
    np.testing.assert_allclose(y, dense @ x, rtol=tol, atol=tol * 10)


def test_engine_spmv_accum_dtype_override():
    csr = banded_csr(256)
    engine = make_engine(csr, "ell", accum_dtype=jnp.float32)
    op = make_operator(csr, dtype=jnp.float32, engine=engine)
    y64 = op.matvec(jnp.ones(csr.n, jnp.float32), accum_dtype=jnp.float64)
    assert y64.dtype == jnp.float64


# ------------------------- shard-local conversions ---------------------------


@pytest.mark.parametrize("g", [2, 4])
def test_shard_to_ell_matches_dense(g):
    csr = powerlaw_csr(700, seed=7)
    dense = csr.toarray()
    x = np.random.default_rng(1).standard_normal(csr.n)
    splits = nnz_balanced_splits(csr.indptr, g)
    n_pad = int((splits[1:] - splits[:-1]).max())
    n_pad = -(-n_pad // 8) * 8
    val, col, stats = shard_to_ell(csr, splits, n_pad, dtype=jnp.float64, row_tile=8)
    assert val.shape[0] == g and stats["width_padded"] % 128 == 0
    xp = np.zeros(g * n_pad)
    for s in range(g):
        lo, hi = int(splits[s]), int(splits[s + 1])
        xp[s * n_pad : s * n_pad + hi - lo] = x[lo:hi]
    y = (np.asarray(val) * xp[np.asarray(col)]).sum(axis=2)
    got = np.concatenate(
        [y[s, : int(splits[s + 1] - splits[s])] for s in range(g)]
    )
    np.testing.assert_allclose(got, dense @ x, atol=1e-10)


@pytest.mark.parametrize("g", [2, 4])
def test_shard_to_blocked_ell_matches_dense(g):
    csr = block_diagonal_csr(40, bs=8, seed=2)
    dense = csr.toarray()
    x = np.random.default_rng(2).standard_normal(csr.n)
    splits = nnz_balanced_splits(csr.indptr, g)
    n_pad = int((splits[1:] - splits[:-1]).max())
    n_pad = -(-n_pad // 8) * 8
    val, bcol, stats = shard_to_blocked_ell(csr, splits, n_pad, block_size=8, dtype=jnp.float64)
    assert val.shape[:2] == (g, n_pad // 8)
    xp = np.zeros(g * n_pad)
    for s in range(g):
        lo, hi = int(splits[s]), int(splits[s + 1])
        xp[s * n_pad : s * n_pad + hi - lo] = x[lo:hi]
    xb = xp.reshape(-1, 8)
    parts = []
    for s in range(g):
        gathered = xb[np.asarray(bcol[s])]  # (nbr, slots, 8)
        ys = np.einsum("rsij,rsj->ri", np.asarray(val[s]), gathered).reshape(-1)
        parts.append(ys[: int(splits[s + 1] - splits[s])])
    np.testing.assert_allclose(np.concatenate(parts), dense @ x, atol=1e-10)


def test_shard_to_blocked_ell_requires_alignment():
    csr = block_diagonal_csr(8)
    splits = nnz_balanced_splits(csr.indptr, 2)
    with pytest.raises(ValueError, match="multiple of block_size"):
        shard_to_blocked_ell(csr, splits, n_pad=33, block_size=8)


def test_to_device_bsr_matches_legacy_tuple():
    from repro.kernels.spmv_bsr import blocked_ell_from_csr

    csr = generate("road", 484, 3.0, seed=11, values="uniform")
    bsr = to_device_bsr(csr, block_size=8, dtype=jnp.float32)
    val, bcol, n = blocked_ell_from_csr(csr, block_size=8, dtype=jnp.float32)
    assert n == bsr.n_rows
    np.testing.assert_array_equal(np.asarray(val), np.asarray(bsr.val))
    np.testing.assert_array_equal(np.asarray(bcol), np.asarray(bsr.bcol))


# --------------------------- solver integration ------------------------------


def test_eigsh_format_auto_surfaces_decision():
    road = generate("road", 900, 3.0, seed=1, values="normalized")
    r = eigsh(road, 3, num_iters=10)
    assert r.spmv_format == "ell"
    r_coo = eigsh(road, 3, num_iters=10, format="coo")
    assert r_coo.spmv_format == "coo"
    np.testing.assert_allclose(
        np.asarray(r.eigenvalues), np.asarray(r_coo.eigenvalues), rtol=1e-4
    )


def test_eigsh_format_bsr_on_block_structure():
    csr = block_diagonal_csr(48, bs=8, seed=4)
    r = eigsh(csr, 3, num_iters=9)
    assert r.spmv_format == "bsr"
    r_coo = eigsh(csr, 3, num_iters=9, format="coo")
    np.testing.assert_allclose(
        np.asarray(r.eigenvalues), np.asarray(r_coo.eigenvalues), rtol=1e-4
    )


def test_eigsh_format_validation():
    road = generate("road", 256, 3.0, seed=1, values="normalized")
    with pytest.raises(ValueError, match="unknown SpMV format"):
        eigsh(road, 2, format="ellpack")


def test_chunked_ell_staging_matches_coo():
    road = generate("road", 900, 3.0, seed=2, values="normalized")
    r_ell = eigsh(road, 3, backend="chunked", num_iters=9, chunk_nnz=800, format="ell")
    assert r_ell.spmv_format == "ell"
    r_coo = eigsh(road, 3, backend="chunked", num_iters=9, chunk_nnz=800, format="coo")
    np.testing.assert_allclose(
        np.asarray(r_ell.eigenvalues), np.asarray(r_coo.eigenvalues), rtol=1e-5
    )


def test_chunked_auto_guards_padded_memory():
    """The chunked backend exists under memory pressure: auto must not stage
    a padded ELL that dwarfs the COO triplets (width is 128-aligned, so very
    narrow rows lose), but keeps ELL when rows are wide enough to amortize."""
    narrow = generate("road", 900, 3.0, seed=2, values="normalized")  # ~5 nnz/row
    r_n = eigsh(narrow, 3, backend="chunked", num_iters=9, chunk_nnz=800)
    assert r_n.spmv_format == "coo"
    wide = banded_csr(400, bandwidth=30, seed=5)  # ~61 nnz/row: padding amortized
    r_w = eigsh(wide, 3, backend="chunked", num_iters=9, chunk_nnz=6000)
    assert r_w.spmv_format == "ell"


def test_chunked_rejects_bsr():
    csr = block_diagonal_csr(16)
    with pytest.raises(ValueError, match="not supported"):
        eigsh(csr, 2, backend="chunked", format="bsr")
    engine = make_engine(csr, "bsr")
    with pytest.raises(ValueError, match="per-chunk BSR"):
        ChunkedOperator(csr, engine=engine)


def test_chunked_ell_many_small_chunks_reference():
    csr = banded_csr(500, bandwidth=2, seed=9)
    engine = make_engine(csr, "ell", accum_dtype=jnp.float64)
    op = ChunkedOperator(csr, chunk_nnz=64, dtype=jnp.float64, engine=engine)
    assert op.num_chunks > 5
    x = np.random.default_rng(3).standard_normal(csr.n)
    y = np.asarray(op.matvec(jnp.asarray(x), accum_dtype=jnp.float64))
    np.testing.assert_allclose(y, csr.toarray() @ x, atol=1e-10)


def test_distributed_hot_loop_never_calls_segment_sum(monkeypatch):
    """1-shard distributed solve with segment_sum poisoned: the auto-selected
    kernel path (ELL here) must not touch the COO reference reduction."""
    from jax.sharding import Mesh

    road = generate("road", 400, 3.0, seed=3, values="normalized")
    baseline = solve_sharded(
        road, 3, Mesh(np.array(jax.devices()[:1]), ("data",)),
        num_iters=9, seed=1, spmv_format="coo",
    )

    def _poisoned(*a, **k):
        raise AssertionError("segment_sum reached the distributed hot loop")

    monkeypatch.setattr(jax.ops, "segment_sum", _poisoned)
    out = solve_sharded(
        road, 3, Mesh(np.array(jax.devices()[:1]), ("data",)),
        num_iters=9, seed=1, spmv_format="auto",
    )
    assert out.spmv_format == ("ell",)
    assert out.partition["spmv"]["format"] == "ell"
    np.testing.assert_allclose(
        np.asarray(out.eigenvalues), np.asarray(baseline.eigenvalues), rtol=1e-4
    )


def test_engine_is_jit_static():
    """SpmvEngine must be hashable/frozen so it can ride static jit args."""
    csr = banded_csr(128)
    e1 = make_engine(csr, "ell")
    e2 = dataclasses.replace(e1, accum_dtype=jnp.float64)
    assert hash(e1) != hash(e2) or e1 != e2
    assert isinstance(e1, SpmvEngine)


def test_forced_format_skips_block_census():
    """Explicit COO/ELL never pays the O(nnz log nnz) block-key sort."""
    csr = banded_csr(256)
    e = make_engine(csr, "ell")
    assert e.stats[0].n_blocks == 0  # census skipped
    assert make_engine(csr, "auto").stats[0].n_blocks > 0


# ------------------------------ hybrid format --------------------------------


def test_hybrid_container_bounds_padding():
    """Acceptance: on a hub-heavy matrix the built hybrid layout keeps
    padded-slots/nnz within the ELL bound plain ELL blew."""
    from repro.kernels.engine import ELL_MAX_OVERHEAD
    from repro.sparse.formats import to_device_hybrid

    csr = powerlaw_csr(seed=0)
    hyb = to_device_hybrid(csr, dtype=jnp.float64)
    ell_part_slots = hyb.ell_val.shape[0] * hyb.ell_val.shape[1]
    stored = ell_part_slots + hyb.tail_slots
    assert stored / csr.nnz <= ELL_MAX_OVERHEAD
    # and the plain-ELL layout would NOT have been bounded
    assert matrix_stats(csr).ell_overhead > ELL_MAX_OVERHEAD
    x = np.random.default_rng(0).standard_normal(csr.n)
    y = np.asarray(hyb.matvec(jnp.asarray(x), accum_dtype=jnp.float64))
    np.testing.assert_allclose(y, csr.toarray() @ x, atol=1e-10)


def test_eigsh_powerlaw_auto_runs_hybrid_kernel_path():
    """format="auto" on a hub matrix now reports 'hybrid' and matches the
    COO baseline (single-device)."""
    csr = powerlaw_csr(seed=1)
    r = eigsh(csr, 3, num_iters=10, seed=2)
    assert r.spmv_format == "hybrid"
    r_coo = eigsh(csr, 3, num_iters=10, seed=2, format="coo")
    np.testing.assert_allclose(
        np.asarray(r.eigenvalues), np.asarray(r_coo.eigenvalues), rtol=1e-4
    )


@pytest.mark.parametrize("g", [2, 4])
def test_shard_to_hybrid_matches_dense(g):
    from repro.sparse.formats import shard_to_hybrid

    csr = powerlaw_csr(700, seed=7)
    dense = csr.toarray()
    x = np.random.default_rng(1).standard_normal(csr.n)
    splits = nnz_balanced_splits(csr.indptr, g)
    n_pad = int((splits[1:] - splits[:-1]).max())
    n_pad = -(-n_pad // 8) * 8
    mats, stats = shard_to_hybrid(csr, splits, n_pad, dtype=jnp.float64, row_tile=8)
    val, col, trow, tcol, tval = (np.asarray(m) for m in mats)
    assert val.shape[0] == g and stats["tail_nnz"] > 0
    # realized padded-slots/nnz of the split stays bounded
    assert (val.size + stats["tail_nnz"]) / csr.nnz <= 3.0 * 2  # rows_pad inflation
    xp = np.zeros(g * n_pad)
    for s in range(g):
        lo, hi = int(splits[s]), int(splits[s + 1])
        xp[s * n_pad : s * n_pad + hi - lo] = x[lo:hi]
    got_parts = []
    for s in range(g):
        y = (val[s] * xp[col[s]]).sum(axis=1)
        np.add.at(y, trow[s], tval[s] * xp[tcol[s]])
        got_parts.append(y[: int(splits[s + 1] - splits[s])])
    np.testing.assert_allclose(np.concatenate(got_parts), dense @ x, atol=1e-10)


def test_distributed_powerlaw_auto_selects_hybrid():
    """Acceptance: the matrix class that used to trigger the padding-blowup
    warning on the kernel-only distributed path now runs hybrid, silently,
    and matches an independent COO baseline."""
    import warnings as w

    from jax.sharding import Mesh

    csr = powerlaw_csr(700, seed=7)
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    baseline = solve_sharded(csr, 3, mesh, num_iters=9, seed=1, spmv_format="coo")
    with w.catch_warnings():
        w.simplefilter("error")
        out = solve_sharded(csr, 3, mesh, num_iters=9, seed=1, spmv_format="auto")
    assert out.spmv_format == ("hybrid",)
    assert out.partition["spmv"]["format"] == "hybrid"
    assert out.partition["spmv"]["tail_nnz"] > 0
    np.testing.assert_allclose(
        np.asarray(out.eigenvalues), np.asarray(baseline.eigenvalues), rtol=1e-4
    )


def test_chunked_rejects_hybrid():
    csr = powerlaw_csr(512, seed=3)
    engine = make_engine(csr, "hybrid")
    with pytest.raises(ValueError, match="per-chunk HYBRID"):
        ChunkedOperator(csr, engine=engine)


# ------------------------------ tile autotuner -------------------------------


@pytest.fixture
def tune_env(tmp_path, monkeypatch):
    """Isolated tuner: fresh JSON cache path + enabled tuning."""
    import repro.kernels.engine as eng_mod

    cache = tmp_path / "spmv_tune.json"
    monkeypatch.setenv("REPRO_SPMV_TUNE_CACHE", str(cache))
    monkeypatch.setenv("REPRO_SPMV_TUNE", "1")
    monkeypatch.setenv("REPRO_SPMV_TUNE_BUDGET", "2")
    eng_mod._TUNER = None
    yield cache
    eng_mod._TUNER = None


def test_autotuner_disabled_is_static_table(monkeypatch):
    """Cold start with tuning off: behavior identical to the static table
    (interpret-mode large tiles preserved), provenance 'table'."""
    monkeypatch.delenv("REPRO_SPMV_TUNE", raising=False)
    csr = banded_csr(256)
    e = make_engine(csr, "ell")
    assert e.tiles_from == "table"
    assert e.tiles == TileConfig(block_r=512, block_w=2048)  # interpret tiles
    assert e.describe()["tiles_from"] == "table"


def test_autotuner_tunes_caches_and_persists(tune_env):
    import json

    import repro.kernels.engine as eng_mod

    csr = banded_csr(256)
    e1 = make_engine(csr, "ell")
    tuner = eng_mod.get_tuner()
    assert e1.tiles_from == "tuned"
    # two probe passes: SpMV tiles + the whole-iteration plan
    assert tuner.measure_count == 2
    assert tune_env.exists()
    payload = json.loads(tune_env.read_text())
    assert payload["version"] == 2 and len(payload["entries"]) == 2
    rec = next(r for r in payload["entries"].values() if r.get("kind") != "iteration")
    assert rec["block_r"] == e1.tiles.block_r and rec["block_w"] == e1.tiles.block_w
    assert rec["grid"] == eng_mod.grid_fingerprint()
    # same shape bucket: memoized, no second measurement
    e2 = make_engine(csr, "ell")
    assert tuner.measure_count == 2 and e2.tiles == e1.tiles


def test_autotuner_frozen_cache_is_deterministic(tune_env, monkeypatch):
    """A pre-written cache is authoritative: no measurement runs (probes are
    poisoned) and the pinned tiles come back verbatim."""
    import json

    import repro.kernels.engine as eng_mod

    # width is the *layout* width the engine probes: banded max_row 5 pads
    # to the 128-lane ELL tile.  Entries carry the live grid fingerprint —
    # unstamped or stale entries are (correctly) dropped and re-measured.
    key = eng_mod._tune_key("ell", jnp.float32, 256, 128, interpret=True)
    fp = eng_mod.grid_fingerprint()
    tune_env.write_text(
        json.dumps(
            {
                "version": 2,
                "entries": {
                    key: {"block_r": 128, "block_w": 1024, "grid": fp},
                    "iter|" + key: {
                        "kind": "iteration",
                        "update": "unfused",
                        "block_r": 128,
                        "block_w": 1024,
                        "block_size": 8,
                        "grid": fp,
                    },
                },
            }
        )
    )

    def _poisoned(*a, **k):
        raise AssertionError("a frozen tune cache must not re-measure")

    monkeypatch.setattr(eng_mod, "_measure_ell_tiles", _poisoned)
    monkeypatch.setattr(eng_mod, "_measure_iteration", _poisoned)
    e = make_engine(banded_csr(256), "ell")
    assert e.tiles_from == "tuned"
    assert (e.tiles.block_r, e.tiles.block_w) == (128, 1024)
    assert e.iteration_plan.update == "unfused" and e.iteration_plan.source == "tuned"


def test_autotuner_override_wins(tune_env, monkeypatch):
    monkeypatch.setenv("REPRO_SPMV_TILES", "64,256")
    e = make_engine(banded_csr(256), "ell")
    assert e.tiles_from == "override"
    assert (e.tiles.block_r, e.tiles.block_w) == (64, 256)


def test_autotuner_solve_end_to_end(tune_env):
    """A tuned engine still solves correctly and surfaces provenance."""
    road = generate("road", 400, 3.0, seed=3, values="normalized")
    r_t = eigsh(road, 3, num_iters=9, format="ell")
    assert r_t.spmv_format == "ell"
    r_ref = eigsh(road, 3, num_iters=9, format="coo")
    np.testing.assert_allclose(
        np.asarray(r_t.eigenvalues), np.asarray(r_ref.eigenvalues), rtol=1e-4
    )


# ------------------------- chunked double buffering --------------------------


def test_chunked_stages_each_chunk_once_per_instance():
    """Acceptance: host->device *conversion* happens once per chunk lifetime
    (lazily, on the first sweep — nothing is pre-pinned at construction),
    never per matvec; repeat matvecs are pure transfers."""
    road = generate("road", 900, 3.0, seed=2, values="normalized")
    engine = make_engine(road, "ell", accum_dtype=jnp.float64)
    op = ChunkedOperator(road, chunk_nnz=800, dtype=jnp.float64, engine=engine)
    assert op.num_chunks > 1
    assert op.staging["conversions"] == 0  # lazy: construction stages nothing
    x = jnp.asarray(np.random.default_rng(0).standard_normal(road.n))
    for _ in range(3):
        op.matvec(x, accum_dtype=jnp.float64).block_until_ready()
    assert op.staging["conversions"] == op.num_chunks  # first sweep only
    assert op.staging["transfers"] == 3 * op.num_chunks


@pytest.mark.parametrize("depth", [0, 1, 2])
def test_chunked_residency_bounded_by_stage_depth(depth):
    road = generate("road", 900, 3.0, seed=2, values="normalized")
    engine = make_engine(road, "ell", accum_dtype=jnp.float64)
    op = ChunkedOperator(
        road, chunk_nnz=500, dtype=jnp.float64, engine=engine, stage_depth=depth
    )
    x = jnp.asarray(np.random.default_rng(0).standard_normal(road.n))
    y = np.asarray(op.matvec(x, accum_dtype=jnp.float64))
    assert op.staging["max_resident"] <= depth + 1
    np.testing.assert_allclose(y, road.toarray() @ np.asarray(x), atol=1e-10)


def test_chunked_per_chunk_widths_cut_hub_padding():
    """Satellite bugfix: one hub row no longer inflates every chunk's ELL
    width — total padded slots drop vs the old global-width layout."""
    web = powerlaw_csr(512, seed=3)
    engine = make_engine(web, "ell", accum_dtype=jnp.float32)
    op = ChunkedOperator(web, chunk_nnz=400, dtype=jnp.float32, engine=engine)
    assert op.num_chunks > 2
    rows_pad = op._rows_pads[0]
    global_width = -(-int(web.row_nnz().max()) // 128) * 128
    global_slots = op.num_chunks * rows_pad * global_width
    assert op.padded_slots < global_slots
    assert len(set(op._widths)) > 1  # hub chunk is wide, the rest stay narrow
    x = np.random.default_rng(5).standard_normal(web.n)
    y = np.asarray(op.matvec(jnp.asarray(x, jnp.float64), accum_dtype=jnp.float64))
    np.testing.assert_allclose(y, web.toarray() @ x, rtol=1e-5, atol=1e-5)


def test_chunked_auto_judges_ell_on_per_chunk_layout():
    """The chunked selector judges ELL on the *realized per-chunk* padding:
    a hub matrix the global-max-row criterion would veto (16x overhead)
    reaches the kernel path once the chunking isolates the hub row."""
    rng = np.random.default_rng(5)
    n = 1000
    diags = [rng.random(n - abs(o)) + 0.1 for o in range(-30, 31)]
    a = sp.diags(diags, range(-30, 31), format="lil")
    a[0, :] = rng.random(n) + 0.1  # one hub row
    hub = _csr_from_scipy(((a + a.T) / 2).tocsr())
    assert matrix_stats(hub).ell_overhead > 10  # whole-matrix view says no
    r = eigsh(hub, 3, backend="chunked", num_iters=9, chunk_nnz=2000)
    assert r.spmv_format == "ell"  # per-chunk view: hub pays for its chunk only
    r_coo = eigsh(hub, 3, backend="chunked", num_iters=9, chunk_nnz=2000, format="coo")
    np.testing.assert_allclose(
        np.asarray(r.eigenvalues), np.asarray(r_coo.eigenvalues), rtol=1e-5
    )


def test_chunked_eigsh_surfaces_staging_stats():
    road = generate("road", 900, 3.0, seed=2, values="normalized")
    r = eigsh(road, 3, backend="chunked", num_iters=9, chunk_nnz=800, stage_depth=2)
    part = r.partition
    assert part is not None and part["stage_depth"] == 2
    st = part["staging"]
    assert st["conversions"] == part["num_chunks"]
    assert st["max_resident"] <= 3
    assert st["transfers"] >= part["num_chunks"]  # one stream per iteration
    assert part["spmv"]["format"] == r.spmv_format


def test_shard_stats_use_remapped_block_coordinates():
    """Block fill must describe the layout ``shard_to_blocked_ell`` builds
    (columns remapped to ``owner * n_pad + local``), not global coordinates:
    a non-block-aligned split genuinely shears the dense blocks of the second
    shard, and the selector must see that and avoid BSR there."""
    csr = block_diagonal_csr(32, bs=8, seed=1)
    aligned = shard_stats(csr, np.array([0, 96, csr.n], dtype=np.int64), block_size=8)
    assert min(s.block_fill for s in aligned) == pytest.approx(1.0)
    assert choose_format(aligned) == "bsr"
    unaligned = shard_stats(csr, np.array([0, 100, csr.n], dtype=np.int64), block_size=8)
    # Shard 1's local coordinates are shifted by 100 (== 4 mod 8): every
    # dense block straddles four local blocks, so the realized fill drops
    # well below the BSR crossover and the selector must fall back.
    assert min(s.block_fill for s in unaligned) < 0.5
    assert choose_format(unaligned) != "bsr"
