"""Model zoo tests: per-arch smoke (reduced configs), decode/forward
consistency (KV caches, ring buffers, recurrent states), and layer-level
oracles for chunked attention, RG-LRU and SSD."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.attention import _chunked_gqa
from repro.models.common import apply_rope, make_positions, split_tree
from repro.models.model import decode_step, forward, init_model, loss_fn, prefill

ALL_ARCHS = list(ARCHS)


def make_batch(cfg, B=2, S=64, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        P = 16
        batch["frames"] = jnp.asarray(rng.standard_normal((B, P, cfg.d_model)), jnp.float32)
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (B, S + P)), jnp.int32)
        batch["positions"] = jnp.broadcast_to(jnp.arange(S + P, dtype=jnp.int32), (3, B, S + P))
    return batch


@pytest.fixture(scope="module")
def smoke_models():
    out = {}
    for arch in ALL_ARCHS:
        cfg = get_config(arch, smoke=True)
        params, axes = split_tree(init_model(jax.random.PRNGKey(0), cfg))
        out[arch] = (cfg, params)
    return out


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_shapes_finite(smoke_models, arch):
    cfg, params = smoke_models[arch]
    batch = make_batch(cfg)
    logits, aux = forward(params, cfg, batch)
    s_expected = batch["labels"].shape[1] if cfg.family != "encdec" else batch["tokens"].shape[1]
    assert logits.shape == (2, s_expected, cfg.vocab_padded)
    assert bool(jnp.all(jnp.isfinite(logits[..., : cfg.vocab])))
    loss, m = loss_fn(params, cfg, batch)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(smoke_models, arch):
    """Cache/state correctness: prefill(S) + decode(t) == forward(S+1)."""
    cfg, params = smoke_models[arch]
    rng = np.random.default_rng(42)
    B, S = 2, 31  # odd length: exercises chunk padding + ring alignment

    if cfg.family == "encdec":
        frames = jnp.asarray(rng.standard_normal((B, 16, cfg.d_model)), jnp.float32)
        toks = jnp.asarray(rng.integers(1, cfg.vocab, (B, 3)), jnp.int32)
        state, lg = prefill(params, cfg, {"frames": frames, "tokens": toks[:, :1] * 0}, max_len=64)
        # decode two steps; compare against full forward each time
        cur = [jnp.zeros((B, 1), jnp.int32)]
        for i in range(2):
            nxt = toks[:, i : i + 1]
            lg_dec, state = decode_step(params, cfg, state, nxt)
            cur.append(nxt)
            full = jnp.concatenate(cur, axis=1)
            lg_ref, _ = forward(params, cfg, {"frames": frames, "tokens": full})
            np.testing.assert_allclose(
                np.asarray(lg_dec[:, : cfg.vocab]),
                np.asarray(lg_ref[:, -1, : cfg.vocab]),
                rtol=2e-2, atol=2e-2,
            )
        return

    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["frames"] = jnp.asarray(rng.standard_normal((B, 8, cfg.d_model)), jnp.float32)
    state, lg_prefill = prefill(params, cfg, batch, max_len=64)
    nxt = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    lg_dec, state = decode_step(params, cfg, state, nxt)

    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], nxt], axis=1)
    if cfg.family == "vlm":
        batch2.pop("positions", None)
    # serving is dropless; reference forward must be dropless too
    import dataclasses

    ref_cfg = dataclasses.replace(cfg, capacity_factor=100.0) if cfg.n_experts else cfg
    lg_ref, _ = forward(params, ref_cfg, batch2)
    np.testing.assert_allclose(
        np.asarray(lg_dec[:, : cfg.vocab]),
        np.asarray(lg_ref[:, -1, : cfg.vocab]),
        rtol=2e-2, atol=2e-2,
    )


def test_chunked_attention_matches_naive():
    """Online-softmax chunked GQA == naive softmax attention."""
    rng = np.random.default_rng(1)
    B, S, H, KV, D = 2, 37, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    for window in (None, 9):
        got = _chunked_gqa(q, k, v, pos, pos, causal=True, window=window, chunk=8)
        # naive reference
        kr = jnp.repeat(k, H // KV, axis=2)
        vr = jnp.repeat(v, H // KV, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kr) / np.sqrt(D)
        mask = pos[:, None, :, None] >= pos[:, None, None, :].transpose(0, 1, 3, 2)
        mask = pos[:, :, None] >= pos[:, None, :]
        if window is not None:
            mask &= pos[:, None, :] > pos[:, :, None] - window
        s = jnp.where(mask[:, None, :, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        want = jnp.einsum("bhqk,bkhd->bqhd", p, vr)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_rglru_decode_matches_sequence():
    from repro.models.rglru import (
        init_rglru_block,
        init_rglru_state,
        rglru_block,
        rglru_decode_step,
    )

    cfg = get_config("recurrentgemma-2b", smoke=True)
    p, _ = split_tree(init_rglru_block(jax.random.PRNGKey(1), cfg))
    rng = np.random.default_rng(2)
    B, S = 2, 12
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    y_seq, st_final = rglru_block(p, cfg, x)
    st = init_rglru_state(cfg, B)
    ys = []
    for t in range(S):
        y, st = rglru_decode_step(p, cfg, x[:, t : t + 1], st)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_seq), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st.h), np.asarray(st_final.h), rtol=1e-4, atol=1e-4)


def test_ssd_decode_matches_sequence():
    from repro.models.ssd import init_ssd_block, init_ssd_state, ssd_block, ssd_decode_step

    cfg = get_config("mamba2-130m", smoke=True)
    p, _ = split_tree(init_ssd_block(jax.random.PRNGKey(1), cfg))
    rng = np.random.default_rng(3)
    B, S = 2, 32  # multiple of ssm_chunk=16 plus a ragged tail would fail: keep aligned
    x = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    y_seq, st_final = ssd_block(p, cfg, x)
    st = init_ssd_state(cfg, B)
    ys = []
    for t in range(S):
        y, st = ssd_decode_step(p, cfg, x[:, t : t + 1], st)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_seq), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(st.h), np.asarray(st_final.h), rtol=2e-3, atol=2e-3)


def test_ssd_chunked_matches_recurrence():
    """SSD chunked scan == naive per-token recurrence (the SSM definition)."""
    from repro.models.ssd import _ssd_chunked

    rng = np.random.default_rng(4)
    B, S, H, P, N = 1, 24, 2, 4, 8
    x = jnp.asarray(rng.standard_normal((B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (B, S, H)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 2.0, (H,)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((B, S, N)), jnp.float32)
    y, hf = _ssd_chunked(x, dt, a, bm, cm, chunk=8)

    h = np.zeros((B, H, P, N))
    ys = np.zeros((B, S, H, P))
    for t in range(S):
        da = np.exp(np.asarray(dt[:, t]) * np.asarray(a))  # (B,H)
        h = h * da[..., None, None] + np.einsum(
            "bh,bn,bhp->bhpn", np.asarray(dt[:, t]), np.asarray(bm[:, t]), np.asarray(x[:, t])
        )
        ys[:, t] = np.einsum("bn,bhpn->bhp", np.asarray(cm[:, t]), h)
    np.testing.assert_allclose(np.asarray(y), ys, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), h, rtol=1e-4, atol=1e-4)


def test_mrope_sections_and_norm_preservation():
    rng = np.random.default_rng(5)
    B, S, H, D = 2, 8, 2, 16
    x = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
    pos3 = jnp.asarray(rng.integers(0, 100, (3, B, S)), jnp.int32)
    y = apply_rope(x, pos3, 1e4, sections=(2, 3, 3))
    np.testing.assert_allclose(  # rotation preserves per-pair norms
        np.asarray(jnp.linalg.norm(y, axis=-1)),
        np.asarray(jnp.linalg.norm(x, axis=-1)),
        rtol=1e-5,
    )
    # equal plane ids == plain rope
    pos = pos3[0]
    y1 = apply_rope(x, jnp.stack([pos, pos, pos]), 1e4, sections=(2, 3, 3))
    y2 = apply_rope(x, pos, 1e4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5, atol=1e-6)


def test_moe_routing_properties():
    from repro.models.mlp import init_moe, moe

    cfg = get_config("mixtral-8x7b", smoke=True)
    p, _ = split_tree(init_moe(jax.random.PRNGKey(2), cfg))
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.standard_normal((2, 64, cfg.d_model)), jnp.float32)
    y, aux = moe(p, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) >= 1.0 - 1e-3  # Switch aux loss lower bound at balance
