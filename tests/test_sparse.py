"""Sparse formats, generators, and the nnz-balanced partition (property tests)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.partition import nnz_balanced_splits, partition_matrix
from repro.sparse import SUITE, csr_from_coo, generate, suite_matrix, to_device_coo, to_device_ell


@pytest.mark.parametrize("kind", ["web", "road", "urand", "kron"])
def test_generators_symmetric_no_selfloops(kind):
    csr = generate(kind, 1024, 4.0, seed=3)
    a = csr.toarray()
    np.testing.assert_allclose(a, a.T, atol=0)
    assert np.all(np.diag(a) == 0)


def test_normalized_spectrum_bounded():
    csr = generate("web", 2048, 6.0, seed=0, values="normalized")
    from repro.core.metrics import eigsh_reference

    vals, _ = eigsh_reference(csr, 3)
    assert np.all(np.abs(vals) <= 1.0 + 1e-9)


@given(
    n=st.integers(16, 300),
    deg=st.floats(1.0, 8.0),
    g=st.integers(1, 7),
)
@settings(max_examples=20, deadline=None)
def test_partition_spmv_equivalence(n, deg, g):
    """Property: the padded partitioned SpMV == the unpartitioned SpMV."""
    csr = generate("urand", n, deg, seed=n, values="uniform")
    n = csr.n
    pm = partition_matrix(csr, g, dtype=jnp.float64, nnz_align=8)
    rng = np.random.default_rng(n)
    x = jnp.asarray(rng.standard_normal(n))
    xp = pm.pad_vector(x)  # (G, n_pad)
    x_full = xp.reshape(-1)  # padded-global layout
    ys = []
    for s in range(g):
        prod = pm.val[s] * jnp.take(x_full, pm.col[s])
        ys.append(jnp.asarray(np.asarray(jnp.zeros(pm.n_pad)).copy()).at[pm.row[s]].add(prod))
    y = pm.unpad_vector(jnp.stack(ys))
    want = csr.to_scipy() @ np.asarray(x)
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-9, atol=1e-9)


@given(g=st.integers(1, 9))
@settings(max_examples=9, deadline=None)
def test_nnz_balance_property(g):
    """Property: every shard's nnz is within one max-row-degree of n_nnz/G."""
    csr = generate("web", 4096, 6.0, seed=11, values="unit")
    splits = nnz_balanced_splits(csr.indptr, g)
    per = np.diff(csr.indptr[splits])
    assert per.sum() == csr.nnz
    max_row = int(csr.row_nnz().max())
    assert per.max() - per.min() <= 2 * max_row + csr.nnz // g  # sane balance
    # tighter: each shard within target +- max row degree
    target = csr.nnz / g
    assert np.all(np.abs(per - target) <= max_row + 1)


def test_ell_roundtrip(web_csr):
    ell = to_device_ell(web_csr)
    coo = to_device_coo(web_csr)
    x = jnp.asarray(np.random.default_rng(5).standard_normal(web_csr.n), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ell.matvec(x, accum_dtype=jnp.float64)),
        np.asarray(coo.matvec(x, accum_dtype=jnp.float64)),
        rtol=1e-10,
    )


def test_suite_covers_paper_table():
    assert len(SUITE) == 15  # the paper's Table I has 15 matrices
    csr = suite_matrix("WB-TA", scale=0.1)
    assert csr.nnz > 0


def test_csr_from_coo_dedupes():
    rows = np.array([0, 0, 1]); cols = np.array([1, 1, 0]); vals = np.array([1.0, 2.0, 3.0])
    csr = csr_from_coo(rows, cols, vals, 2)
    assert csr.nnz == 2
    assert csr.toarray()[0, 1] == 3.0
