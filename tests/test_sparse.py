"""Sparse formats, generators, and the nnz-balanced partition.

Property-style checks run here from a fixed seeded-random case list so the
suite needs no optional dependencies; when ``hypothesis`` is installed
(the ``[test]`` extra), ``test_sparse_properties.py`` additionally drives
the same check bodies from search strategies.
"""

import numpy as np
import pytest

from sparse_checks import check_nnz_balance, check_partition_spmv_equivalence

from repro.sparse import SUITE, csr_from_coo, generate, suite_matrix, to_device_coo, to_device_ell

import jax.numpy as jnp


@pytest.mark.parametrize("kind", ["web", "road", "urand", "kron"])
def test_generators_symmetric_no_selfloops(kind):
    csr = generate(kind, 1024, 4.0, seed=3)
    a = csr.toarray()
    np.testing.assert_allclose(a, a.T, atol=0)
    assert np.all(np.diag(a) == 0)


def test_normalized_spectrum_bounded():
    csr = generate("web", 2048, 6.0, seed=0, values="normalized")
    from repro.core.metrics import eigsh_reference

    vals, _ = eigsh_reference(csr, 3)
    assert np.all(np.abs(vals) <= 1.0 + 1e-9)


def _seeded_spmv_cases(num=20, seed=2024):
    rng = np.random.default_rng(seed)
    return [
        (int(rng.integers(16, 301)), float(rng.uniform(1.0, 8.0)), int(rng.integers(1, 8)))
        for _ in range(num)
    ]


@pytest.mark.parametrize("n,deg,g", _seeded_spmv_cases())
def test_partition_spmv_equivalence(n, deg, g):
    check_partition_spmv_equivalence(n, deg, g)


@pytest.mark.parametrize("g", range(1, 10))
def test_nnz_balance_property(g):
    check_nnz_balance(g)


def test_ell_roundtrip(web_csr):
    ell = to_device_ell(web_csr)
    coo = to_device_coo(web_csr)
    x = jnp.asarray(np.random.default_rng(5).standard_normal(web_csr.n), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ell.matvec(x, accum_dtype=jnp.float64)),
        np.asarray(coo.matvec(x, accum_dtype=jnp.float64)),
        rtol=1e-10,
    )


def test_suite_covers_paper_table():
    assert len(SUITE) == 15  # the paper's Table I has 15 matrices
    csr = suite_matrix("WB-TA", scale=0.1)
    assert csr.nnz > 0


def test_csr_from_coo_dedupes():
    rows = np.array([0, 0, 1])
    cols = np.array([1, 1, 0])
    vals = np.array([1.0, 2.0, 3.0])
    csr = csr_from_coo(rows, cols, vals, 2)
    assert csr.nnz == 2
    assert csr.toarray()[0, 1] == 3.0
