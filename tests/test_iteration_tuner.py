"""Whole-iteration autotuner: plan resolution, cache contract, parity.

The tentpole invariants:

* with probes disabled the static mode table decides (interpret -> unfused);
* measured plans round-trip through the persisted JSON cache, including the
  fused/unfused decision and the BSR block-size edge;
* a frozen (pre-seeded) cache is deterministic — no probe ever runs and the
  recorded decision is served verbatim;
* entries stamped by a different candidate space (grid fingerprint) are
  dropped, not served;
* routing the live solver through any plan rung preserves the spectrum —
  fused and unfused updates are bit-identical for uniform policies.
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.engine as eng
from repro.kernels.engine import (
    ITER_UPDATE_MODES,
    IterationPlan,
    TileConfig,
    grid_fingerprint,
    resolve_iteration_plan,
    table_update_mode,
)
from repro.sparse import generate


@pytest.fixture
def tuning(monkeypatch, tmp_path):
    """Isolated tuner: fresh cache file, probes ON, tiny budget, no pins."""
    cache = tmp_path / "tune.json"
    monkeypatch.setenv("REPRO_SPMV_TUNE", "1")
    monkeypatch.setenv("REPRO_SPMV_TUNE_BUDGET", "3")
    monkeypatch.setenv("REPRO_SPMV_TUNE_CACHE", str(cache))
    monkeypatch.delenv("REPRO_ITER_UPDATE", raising=False)
    monkeypatch.delenv("REPRO_FUSED_LANCZOS", raising=False)
    monkeypatch.setattr(eng, "_TUNER", None)
    yield cache
    monkeypatch.setattr(eng, "_TUNER", None)


def _fresh_tuner(monkeypatch):
    monkeypatch.setattr(eng, "_TUNER", None)
    return eng.get_tuner()


def test_table_fallback_when_probes_disabled(monkeypatch):
    monkeypatch.setenv("REPRO_SPMV_TUNE", "0")
    monkeypatch.delenv("REPRO_ITER_UPDATE", raising=False)
    monkeypatch.setattr(eng, "_TUNER", None)
    p_int = resolve_iteration_plan(1024, 32, interpret=True)
    p_tpu = resolve_iteration_plan(1024, 32, interpret=False)
    assert p_int.source == p_tpu.source == "table"
    assert p_int.update == table_update_mode(True) == "unfused"
    assert p_tpu.update == table_update_mode(False) == "fused"
    assert eng.tuner_probe_count() == 0  # the table never measures


def test_env_pin_overrides_everything(tuning, monkeypatch):
    monkeypatch.setenv("REPRO_ITER_UPDATE", "fused_spmv")
    plan = resolve_iteration_plan(512, 64, interpret=True)
    assert plan.update == "fused_spmv" and plan.source == "override"
    assert eng.tuner_probe_count() == 0  # pins never probe
    monkeypatch.setenv("REPRO_ITER_UPDATE", "sideways")
    with pytest.raises(ValueError, match="REPRO_ITER_UPDATE"):
        resolve_iteration_plan(512, 64, interpret=True)


def test_measured_plan_roundtrips_through_cache(tuning, monkeypatch):
    cache = tuning
    plan = resolve_iteration_plan(512, 64, format="ell", interpret=True)
    assert plan.source == "tuned" and plan.update in ITER_UPDATE_MODES
    assert eng.get_tuner().measure_count == 1

    payload = json.loads(cache.read_text())
    assert payload["version"] == 2
    iter_recs = {k: r for k, r in payload["entries"].items() if k.startswith("iter|")}
    assert iter_recs, "measured plan must persist as an iter| entry"
    (rec,) = iter_recs.values()
    assert rec["kind"] == "iteration" and rec["update"] == plan.update
    assert rec["grid"] == grid_fingerprint()
    assert rec["candidates_us"], "raw probe timings kept for postmortems"

    # A fresh tuner (next CI run restoring the cache) serves the identical
    # decision — fused/unfused choice and tiles included — without probing.
    _fresh_tuner(monkeypatch)
    again = resolve_iteration_plan(512, 64, format="ell", interpret=True)
    assert again == plan
    assert eng.get_tuner().measure_count == 0


def test_bsr_block_size_decision_roundtrips(tuning, monkeypatch):
    plan = resolve_iteration_plan(
        512, 64, format="bsr", tiles=TileConfig(), interpret=True
    )
    assert plan.source == "tuned"
    assert plan.update in ("unfused", "fused")  # no fused-SpMV pass for BSR
    assert plan.tiles.block_size in eng._ITER_BSR_BLOCKS
    _fresh_tuner(monkeypatch)
    again = resolve_iteration_plan(512, 64, format="bsr", tiles=TileConfig(), interpret=True)
    assert again == plan and again.tiles.block_size == plan.tiles.block_size
    assert eng.get_tuner().measure_count == 0


def _seed_cache(cache, key, update="fused", grid=None):
    rec = {
        "kind": "iteration",
        "update": update,
        "block_r": 16,
        "block_w": 64,
        "block_size": 8,
        "grid": grid if grid is not None else grid_fingerprint(),
        "best_us": 1.0,
        "candidates_us": {"seeded": 1.0},
    }
    cache.write_text(json.dumps({"version": 2, "entries": {key: rec}}))


def test_frozen_cache_is_deterministic(tuning, monkeypatch):
    """A pre-seeded cache entry is served verbatim, repeatedly, with zero
    probes — CI runs with a restored cache cannot flap on runner noise."""
    cache = tuning
    key = "iter|" + eng._tune_key("ell", jnp.float32, 512, 64, True)
    _seed_cache(cache, key, update="fused")
    expect = IterationPlan(
        update="fused", tiles=TileConfig(block_r=16, block_w=64, block_size=8), source="tuned"
    )
    for _ in range(3):
        _fresh_tuner(monkeypatch)
        assert resolve_iteration_plan(512, 64, format="ell", interpret=True) == expect
        assert eng.get_tuner().measure_count == 0


def test_stale_grid_fingerprint_invalidates(tuning, monkeypatch):
    """An entry stamped by a different candidate space must be re-measured,
    never served — the cache self-invalidates on autotuner/grid changes."""
    cache = tuning
    key = "iter|" + eng._tune_key("ell", jnp.float32, 512, 64, True)
    _seed_cache(cache, key, update="fused", grid="0" * 16)
    _fresh_tuner(monkeypatch)
    plan = resolve_iteration_plan(512, 64, format="ell", interpret=True)
    assert plan.source == "tuned"
    assert eng.get_tuner().measure_count == 1  # probed despite the entry
    rec = json.loads(cache.read_text())["entries"][key]
    assert rec["grid"] == grid_fingerprint()  # re-stamped with the live space


def test_engine_surfaces_plan_provenance(monkeypatch):
    monkeypatch.setenv("REPRO_SPMV_TUNE", "0")
    monkeypatch.delenv("REPRO_ITER_UPDATE", raising=False)
    csr = generate("web", 256, 4.0, seed=2, values="normalized")
    e = eng.make_engine(csr, "ell")
    assert e.iteration_plan is not None
    desc = e.describe()
    assert desc["iteration_plan"]["update"] == e.iteration_plan.update
    assert desc["iteration_plan"]["source"] in ("table", "tuned", "override")


# ------------------------------ parity ---------------------------------------


@pytest.mark.parametrize("mode", ["fused", "fused_spmv"])
def test_update_modes_bit_identical_eigenvalues(mode, monkeypatch):
    """Routing is a pure performance decision: for a uniform policy every
    plan rung returns the *same bits*.  n is padding-free (512 = multiple of
    every tile edge) so the fused alpha reduces over exactly the same lanes
    as the reference dot."""
    from repro.api import eigsh, session_cache_clear

    csr = generate("web", 512, 6.0, seed=5, values="normalized")
    monkeypatch.setenv("REPRO_SPMV_TUNE", "0")
    monkeypatch.delenv("REPRO_FUSED_LANCZOS", raising=False)
    vals = {}
    for m in ("unfused", mode):
        monkeypatch.setenv("REPRO_ITER_UPDATE", m)
        session_cache_clear()
        r = eigsh(csr, 4, num_iters=16, policy="FFF", reorth="full", seed=7)
        vals[m] = np.asarray(r.eigenvalues)
    session_cache_clear()
    np.testing.assert_array_equal(vals["unfused"], vals[mode])
