"""Deterministic fault injection for the solver and serving layers.

The recovery paths in this repo (``recovery="auto"`` escalation, solve
checkpoint resume, scheduler retries / circuit breaker / watchdog) are only
trustworthy while something exercises them.  This module is that something:
a process-local registry of *armed* faults that production code consults at
well-defined injection points, each firing deterministically at a requested
iteration / chunk / cycle and then disarming itself.

Two ways to arm a fault:

* context manager (tests)::

      from repro.testing import faults
      with faults.inject("spmv_nan@iter=7"):
          eigsh(a, k=4)           # SpMV output at Lanczos step 7 is NaN

* environment (CI permutations)::

      REPRO_FAULT="beta_collapse@iter=3" python -m ...

Grammar: ``kind[@key=val[,key=val...]]`` with keys ``iter`` / ``chunk`` /
``cycle`` (aliases for the trigger index) and ``count`` (times to fire
before going inert, default 1).  Kinds:

==================  =========================================================
``spmv_nan``        NaN written into the SpMV output at Lanczos step *iter*
``beta_collapse``   beta forced to 0 at step *iter* (lucky-breakdown shape)
``kernel_error``    raises :class:`InjectedKernelError` at sweep entry (the
                    shape of a Pallas/XLA lowering or execution failure)
``oom``             raises :class:`InjectedOOMError` at sweep entry (the
                    shape of a device RESOURCE_EXHAUSTED allocation failure)
``chunk_io_error``  raises :class:`InjectedChunkIOError` while staging chunk
                    *chunk* of an out-of-core stream
``solve_crash``     raises :class:`InjectedCrash` at the start of restart
                    cycle *cycle* (checkpoint/resume tests)
``scheduler_crash`` raises :class:`SchedulerThreadDeath` — a BaseException,
                    so it escapes ``except Exception`` wrappers and really
                    kills the dispatch thread (watchdog tests)
==================  =========================================================

Determinism under ``jax.jit``: the Lanczos taps are *decided at trace time*
(the armed spec is read host-side while the loop body traces) and the
injected poison is guarded by ``jnp.where(i == iter, ...)`` so it lands on
exactly one step whether ``i`` is a tracer or a Python int.  The armed state
is part of the jit cache key (see ``trace_key``), so a poisoned trace can
never be cached under the clean key, and a clean retry after the fault
disarms recompiles nothing.  On the jitted path the taps do **not** count a
firing (tracing happens zero or one times, execution many): the sweep
launcher calls :func:`consume_lanczos` host-side after each launch whose
cache key carried the fault, so ``fired`` advances exactly once per poisoned
sweep whether the trace was fresh or a cache hit.

When nothing is armed every hook is a cheap no-op (one list + one environ
lookup per *solve*, not per iteration).
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional, Union

from ..configs import env as envcfg

__all__ = [
    "FAULT_KINDS",
    "FaultSpec",
    "parse_fault",
    "inject",
    "fault_spec",
    "trace_key",
    "reset",
    "tap_spmv",
    "tap_beta",
    "consume_lanczos",
    "check_sweep_entry",
    "check_chunk_io",
    "check_solve_crash",
    "check_scheduler",
    "InjectedFault",
    "InjectedKernelError",
    "InjectedOOMError",
    "InjectedChunkIOError",
    "InjectedCrash",
    "SchedulerThreadDeath",
]

FAULT_KINDS = (
    "spmv_nan",
    "beta_collapse",
    "kernel_error",
    "oom",
    "chunk_io_error",
    "solve_crash",
    "scheduler_crash",
)

_ENV_VAR = "REPRO_FAULT"


class InjectedFault:
    """Mixin marking an exception as injected by this harness."""


class InjectedKernelError(InjectedFault, RuntimeError):
    """Stands in for a Pallas/XLA lowering or execution failure."""


class InjectedOOMError(InjectedFault, RuntimeError):
    """Stands in for a device allocation failure (message shape matters:
    recovery classifies on the RESOURCE_EXHAUSTED marker XLA uses)."""


class InjectedChunkIOError(InjectedFault, OSError):
    """Stands in for an I/O error while staging an out-of-core chunk."""


class InjectedCrash(InjectedFault, RuntimeError):
    """Aborts a solve mid-run (checkpoint/resume tests)."""


class SchedulerThreadDeath(InjectedFault, BaseException):
    """Kills a scheduler thread for real: derives from BaseException so the
    dispatch loop's ``except Exception`` guard cannot swallow it — the
    watchdog path is what must handle the aftermath."""


@dataclasses.dataclass
class FaultSpec:
    """One armed fault.  ``fired`` counts applications; the spec goes inert
    once ``fired >= count`` so recovery retries run clean."""

    kind: str
    iteration: Optional[int] = None
    count: int = 1
    fired: int = 0

    @property
    def armed(self) -> bool:
        return self.fired < self.count


def parse_fault(text: str) -> FaultSpec:
    """Parse ``kind[@key=val[,key=val...]]`` (see module docstring)."""
    text = text.strip()
    kind, _, params = text.partition("@")
    kind = kind.strip()
    if kind not in FAULT_KINDS:
        raise ValueError(f"unknown fault kind {kind!r}; expected one of {FAULT_KINDS}")
    spec = FaultSpec(kind=kind)
    if params:
        for item in params.split(","):
            key, sep, val = item.partition("=")
            key = key.strip()
            if not sep:
                raise ValueError(f"fault param {item!r} in {text!r}: expected key=value")
            try:
                ival = int(val)
            except ValueError:
                raise ValueError(f"fault param {item!r} in {text!r}: value must be an int")
            if key in ("iter", "chunk", "cycle", "iteration"):
                spec.iteration = ival
            elif key == "count":
                spec.count = ival
            else:
                raise ValueError(
                    f"unknown fault param {key!r} in {text!r}; "
                    "expected iter/chunk/cycle or count"
                )
    return spec


# ---------------------------------------------------------------------------
# registry: a context-manager stack plus a lazily parsed REPRO_FAULT env spec.
# Env specs are cached per raw string so their fired-count survives repeated
# lookups within one process (one process == one deterministic firing).

_lock = threading.Lock()
_stack: list[FaultSpec] = []
_env_cache: dict[str, list[FaultSpec]] = {}


def _env_specs() -> list[FaultSpec]:
    raw = (envcfg.get_str(_ENV_VAR) or "").strip()
    if not raw:
        return []
    cached = _env_cache.get(raw)
    if cached is None:
        cached = [parse_fault(part) for part in raw.split(";") if part.strip()]
        _env_cache[raw] = cached
    return cached


@contextlib.contextmanager
def inject(spec: Union[str, FaultSpec]):
    """Arm a fault for the duration of the block; yields the live spec so
    tests can assert on ``fired``."""
    fs = parse_fault(spec) if isinstance(spec, str) else spec
    with _lock:
        _stack.append(fs)
    try:
        yield fs
    finally:
        with _lock:
            _stack.remove(fs)


def reset() -> None:
    """Disarm everything (including cached env specs) — test teardown."""
    with _lock:
        _stack.clear()
        _env_cache.clear()


def fault_spec(kind: str) -> Optional[FaultSpec]:
    """The innermost armed spec for ``kind``, or None.  Cheap when idle."""
    if _stack:
        with _lock:
            for fs in reversed(_stack):
                if fs.kind == kind and fs.armed:
                    return fs
    for fs in _env_specs():
        if fs.kind == kind and fs.armed:
            return fs
    return None


def trace_key() -> Optional[tuple]:
    """Hashable description of the armed Lanczos-visible faults, for use as
    a jit static argument: None when idle (the clean cache key), a unique
    tuple per (spec, fired) state otherwise — so poisoned traces can never
    shadow the clean compiled sweep."""
    parts = []
    for kind in ("spmv_nan", "beta_collapse"):
        fs = fault_spec(kind)
        if fs is not None:
            parts.append((fs.kind, fs.iteration, fs.count, fs.fired))
    return tuple(parts) if parts else None


# ---------------------------------------------------------------------------
# injection points (called from production code; all cheap no-ops when idle)


def tap_spmv(u, i):
    """Poison the SpMV output at the armed step.  ``i`` may be a tracer
    (jitted ``fori_loop``) or a Python int (eager host loop)."""
    fs = fault_spec("spmv_nan")
    if fs is None:
        return u
    import jax.numpy as jnp

    it = fs.iteration or 0
    if isinstance(i, int):
        if i != it:
            return u
        fs.fired += 1
        return u.at[0].set(jnp.asarray(jnp.nan, u.dtype))
    # Traced: counted per *launch* by consume_lanczos, not per trace — a
    # cached poisoned trace still executes the poison.
    poisoned = u.at[0].set(jnp.asarray(jnp.nan, u.dtype))
    return jnp.where(jnp.equal(i, it), poisoned, u)


def tap_beta(beta, i):
    """Collapse beta to 0 at the armed step (lucky-breakdown shape).
    Accepts a jax scalar + tracer step, or Python floats (restarted loop)."""
    fs = fault_spec("beta_collapse")
    if fs is None:
        return beta
    it = fs.iteration or 0
    if isinstance(i, int):
        if i != it:
            return beta
        fs.fired += 1
        return type(beta)(0.0) if isinstance(beta, float) else beta * 0
    import jax.numpy as jnp

    # Traced: counted per launch by consume_lanczos (see tap_spmv).
    return jnp.where(jnp.equal(i, it), jnp.zeros_like(beta), beta)


def consume_lanczos(key: Optional[tuple]) -> None:
    """Count one firing per fault kind baked into a just-launched jitted
    sweep.  ``key`` is the ``trace_key()`` the launch was keyed on: None
    means the sweep was clean and nothing is consumed.  Called host-side by
    the sweep launchers so a cache hit on a poisoned trace (which executes
    the poison but never re-traces the tap) still advances ``fired``."""
    if not key:
        return
    for kind, *_ in key:
        fs = fault_spec(kind)
        if fs is not None:
            fs.fired += 1


def check_sweep_entry() -> None:
    """Raise the armed sweep-entry fault (kernel_error / oom), if any.
    Called once per Lanczos sweep, host-side, before any device work."""
    fs = fault_spec("kernel_error")
    if fs is not None:
        fs.fired += 1
        raise InjectedKernelError("injected Mosaic lowering failure (fault harness)")
    fs = fault_spec("oom")
    if fs is not None:
        fs.fired += 1
        raise InjectedOOMError(
            "RESOURCE_EXHAUSTED: out of memory while allocating Krylov basis "
            "(fault harness)"
        )


def check_chunk_io(chunk_index: int) -> None:
    """Raise the armed chunk-staging I/O fault when ``chunk_index`` matches."""
    fs = fault_spec("chunk_io_error")
    if fs is None:
        return
    if fs.iteration is not None and chunk_index != fs.iteration:
        return
    fs.fired += 1
    raise InjectedChunkIOError(f"injected I/O error staging chunk {chunk_index}")


def check_solve_crash(cycle: int) -> None:
    """Abort a restarted solve at the armed cycle (checkpoint tests)."""
    fs = fault_spec("solve_crash")
    if fs is None:
        return
    if fs.iteration is not None and cycle != fs.iteration:
        return
    fs.fired += 1
    raise InjectedCrash(f"injected crash at restart cycle {cycle}")


def check_scheduler() -> None:
    """Kill the calling scheduler thread (BaseException — see class doc)."""
    fs = fault_spec("scheduler_crash")
    if fs is None:
        return
    fs.fired += 1
    raise SchedulerThreadDeath("injected dispatch-thread death")
