"""Test-support utilities that ship with the package (not under tests/):
the deterministic fault-injection harness lives here because production
code hosts its injection points and CI arms it via ``REPRO_FAULT``."""

from . import faults
from .faults import FaultSpec, inject, parse_fault

__all__ = ["faults", "FaultSpec", "inject", "parse_fault"]
