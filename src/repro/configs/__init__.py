"""Architecture registry: --arch <id> -> (CONFIG, SMOKE).

All 10 assigned architectures (exact dims from the public assignment) plus
the paper's own eigensolver configs (paper_eigensolver.py).
"""

from . import (
    arctic_480b,
    codeqwen1_5_7b,
    mamba2_130m,
    mixtral_8x7b,
    phi3_medium_14b,
    qwen1_5_32b,
    qwen2_vl_72b,
    qwen3_0_6b,
    recurrentgemma_2b,
    seamless_m4t_medium,
)
from .shapes import SHAPES, ShapeSpec, applicable, input_specs

ARCHS = {
    "recurrentgemma-2b": recurrentgemma_2b,
    "qwen3-0.6b": qwen3_0_6b,
    "phi3-medium-14b": phi3_medium_14b,
    "codeqwen1.5-7b": codeqwen1_5_7b,
    "qwen1.5-32b": qwen1_5_32b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "arctic-480b": arctic_480b,
    "mixtral-8x7b": mixtral_8x7b,
    "qwen2-vl-72b": qwen2_vl_72b,
    "mamba2-130m": mamba2_130m,
}


def get_config(arch: str, smoke: bool = False):
    mod = ARCHS[arch]
    return mod.SMOKE if smoke else mod.CONFIG


__all__ = ["ARCHS", "get_config", "SHAPES", "ShapeSpec", "applicable", "input_specs"]
