"""Architecture registry: --arch <id> -> (CONFIG, SMOKE).

All 10 assigned architectures (exact dims from the public assignment) plus
the paper's own eigensolver configs (paper_eigensolver.py).

Arch modules import the (jax-heavy) model substrate, but this package also
hosts the dependency-free environment-knob registry (``configs/env.py``)
that low layers (``core``, ``kernels``) import; module loading is therefore
lazy (PEP 562) so ``from ..configs import env`` never drags the model stack
in.
"""

_ARCH_MODULES = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "qwen3-0.6b": "qwen3_0_6b",
    "phi3-medium-14b": "phi3_medium_14b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "qwen1.5-32b": "qwen1_5_32b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "arctic-480b": "arctic_480b",
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "mamba2-130m": "mamba2_130m",
}

_SHAPE_EXPORTS = ("SHAPES", "ShapeSpec", "applicable", "input_specs")


class _LazyArchs(dict):
    """ARCHS mapping that imports each arch module on first access."""

    def __missing__(self, arch):
        import importlib

        if arch not in _ARCH_MODULES:
            raise KeyError(arch)
        mod = importlib.import_module(f".{_ARCH_MODULES[arch]}", __name__)
        self[arch] = mod
        return mod

    def __contains__(self, arch):
        return arch in _ARCH_MODULES or dict.__contains__(self, arch)

    def __iter__(self):
        return iter(_ARCH_MODULES)

    def __len__(self):
        return len(_ARCH_MODULES)

    def keys(self):
        return _ARCH_MODULES.keys()

    def items(self):
        return ((a, self[a]) for a in _ARCH_MODULES)

    def values(self):
        return (self[a] for a in _ARCH_MODULES)


ARCHS = _LazyArchs()


def get_config(arch: str, smoke: bool = False):
    mod = ARCHS[arch]
    return mod.SMOKE if smoke else mod.CONFIG


def __getattr__(name):
    if name in _SHAPE_EXPORTS:
        from . import shapes

        return getattr(shapes, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = ["ARCHS", "get_config", "SHAPES", "ShapeSpec", "applicable", "input_specs"]
