"""mixtral-8x7b: 8 experts top-2, sliding-window attention [arXiv:2401.04088; hf]

Exact assigned config (full) + reduced same-family smoke config.
"""

import dataclasses

import jax.numpy as jnp

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, head_dim=128, n_experts=8, moe_top_k=2, window=4096,
    rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab=512, n_experts=4, moe_group_size=64, window=32,
    attn_chunk=32, compute_dtype=jnp.float32,
)
