"""Assigned input-shape sets and ShapeDtypeStruct builders (dry-run inputs).

Per the assignment, every LM arch is paired with four shapes:

  train_4k     seq=4096    global_batch=256   -> lowers train_step
  prefill_32k  seq=32768   global_batch=32    -> lowers prefill
  decode_32k   seq=32768   global_batch=128   -> lowers serve_step (1 token,
                                                 KV cache of seq_len)
  long_500k    seq=524288  global_batch=1     -> serve_step; sub-quadratic
                                                 archs only (SSM / RG-LRU
                                                 local attn / SWA)

``input_specs`` returns ShapeDtypeStructs only — no allocation — exactly the
pattern the multi-pod dry-run consumes.  For [audio]/[vlm] archs the modality
frontend is a stub: specs include precomputed frame/patch embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..models.common import ModelConfig

__all__ = ["SHAPES", "ShapeSpec", "input_specs", "applicable"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Archs with O(S^2) full attention cannot run 512k-token attention at all —
# skipped per the assignment (recorded in DESIGN.md §6 and EXPERIMENTS §Dry-run).
_SUBQUADRATIC = {"mamba2-130m", "recurrentgemma-2b", "mixtral-8x7b"}


def applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in _SUBQUADRATIC
    return True


def _tok(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(
    cfg: ModelConfig, shape: ShapeSpec, batch_override: Optional[int] = None
) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this (arch, shape).

    For 'train'/'prefill': a batch dict.  For 'decode': a batch dict with a
    1-token step plus the decode-state template built with jax.eval_shape
    (zero allocation).
    """
    b = batch_override or shape.global_batch
    s = shape.seq_len
    emb = jnp.float32  # stub frontend embeddings arrive in f32

    if shape.mode in ("train", "prefill"):
        if cfg.family == "encdec":
            src, tgt = s // 2, s // 2
            d = {
                "frames": jax.ShapeDtypeStruct((b, src, cfg.d_model), emb),
                "tokens": _tok((b, tgt)),
            }
            if shape.mode == "train":
                d["labels"] = _tok((b, tgt))
            return d
        if cfg.family == "vlm":
            patches = min(1024, s // 4)
            d = {
                "frames": jax.ShapeDtypeStruct((b, patches, cfg.d_model), emb),
                "tokens": _tok((b, s - patches)),
                "positions": _tok((3, b, s)),
            }
            if shape.mode == "train":
                d["labels"] = _tok((b, s))
            return d
        d = {"tokens": _tok((b, s))}
        if shape.mode == "train":
            d["labels"] = _tok((b, s))
        if cfg.mrope_sections is not None:
            d["positions"] = _tok((3, b, s))
        return d

    # decode: one new token against a state of length seq_len
    from ..models.model import init_decode_state

    enc_len = min(4096, s // 8)  # encdec: assumed encoder context
    state = jax.eval_shape(lambda: init_decode_state(cfg, b, s, step=s - 1, enc_len=enc_len))
    d = {"tokens": _tok((b, 1)), "state": state}
    return d
