"""recurrentgemma-2b: RG-LRU + local attention, 1 attn : 2 rec [arXiv:2402.19427; hf]

Exact assigned config (full) + reduced same-family smoke config.
"""

import dataclasses

import jax.numpy as jnp

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid_rglru",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_ff=7680,
    vocab=256000, head_dim=256, lru_width=2560, conv_width=4,
    block_pattern=("rec", "rec", "attn_local"), window=2048,
    rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab=512, lru_width=64, window=32, attn_chunk=32,
    compute_dtype=jnp.float32,
)
