"""qwen2-vl-72b: M-RoPE, dynamic-resolution vision stub [arXiv:2409.12191; hf]

Exact assigned config (full) + reduced same-family smoke config.
"""

import dataclasses

import jax.numpy as jnp

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152064, head_dim=128, mrope_sections=(16, 24, 24),
    frontend="vision", rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, mrope_sections=(2, 3, 3), attn_chunk=32,
    compute_dtype=jnp.float32,
)
