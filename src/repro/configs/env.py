"""Central registry of every ``REPRO_*`` environment knob.

Every environment variable the repo reads is declared here — name, type,
default, and a one-line description — and read through the typed accessors
(:func:`get_bool` / :func:`get_int` / :func:`get_float` / :func:`get_str`).
The config lint (rule ``E001`` in ``repro.analysis``) rejects any raw
``os.environ`` / ``os.getenv`` read of a ``REPRO_*`` name elsewhere in
``src/``, and rule ``E002`` cross-checks this registry against the README so
an undocumented knob fails CI.

This module must stay import-light (stdlib only): it is imported from
``kernels/`` and ``core/``, below everything else in the package.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Iterable, Optional

__all__ = [
    "EnvKnob",
    "KNOBS",
    "knob",
    "raw",
    "get_bool",
    "get_int",
    "get_float",
    "get_str",
    "is_falsey",
    "is_truthy",
]


@dataclasses.dataclass(frozen=True)
class EnvKnob:
    """One declared environment variable.

    ``type`` is documentation-facing ("bool", "int", "float", "str",
    "path"); parsing is done by the accessor the call site picks, so a knob
    whose raw string is parsed specially (e.g. ``REPRO_SPMV_TILES``'s
    ``RxW[@B]`` spec) declares type "str" and keeps its parser at the call
    site.
    """

    name: str
    type: str
    default: Any
    description: str


def _k(name: str, type: str, default: Any, description: str) -> EnvKnob:
    return EnvKnob(name=name, type=type, default=default, description=description)


_DECLARED: Iterable[EnvKnob] = (
    # --- SpMV engine / autotuner -------------------------------------------
    _k(
        "REPRO_SPMV_TUNE",
        "bool",
        False,
        "Enable the measured SpMV/iteration autotuner (off = heuristic tiles).",
    ),
    _k(
        "REPRO_SPMV_TUNE_CACHE",
        "path",
        ".cache/spmv_tune.json",
        "Path of the persistent autotune decision cache ('' disables persistence).",
    ),
    _k(
        "REPRO_SPMV_TUNE_BUDGET",
        "int",
        6,
        "Max number of tile candidates the autotuner measures per matrix.",
    ),
    _k(
        "REPRO_SPMV_TILES",
        "str",
        None,
        "Force SpMV tile config as 'RxW[@B]' (rows x width [@ bsr block]), bypassing heuristics.",
    ),
    _k(
        "REPRO_SPMV_ELL_OVERHEAD",
        "float",
        3.0,
        "Max ELL padded-cells / nnz overhead before falling back to COO/hybrid.",
    ),
    _k(
        "REPRO_SPMV_BSR_FILL",
        "float",
        0.35,
        "Min block fill fraction required to pick the BSR kernel.",
    ),
    _k(
        "REPRO_SPMV_HYBRID_Q",
        "float",
        0.995,
        "Row-length quantile that splits the ELL part from the COO tail in hybrid format.",
    ),
    _k(
        "REPRO_SPMV_HYBRID_TAIL",
        "float",
        0.05,
        "Max tail-nnz fraction for which hybrid is preferred over plain COO.",
    ),
    # --- Lanczos iteration plan --------------------------------------------
    _k(
        "REPRO_ITER_UPDATE",
        "str",
        None,
        "Force the Lanczos update mode: 'fused', 'fused_spmv', 'unfused', or 'auto'.",
    ),
    _k(
        "REPRO_FUSED_LANCZOS",
        "bool",
        True,
        "Allow the fused Lanczos vector-update kernel (0/false/off disables).",
    ),
    # --- API / session layer -----------------------------------------------
    _k(
        "REPRO_VALIDATE_INPUT",
        "bool",
        True,
        "Validate user matrices (finite values, symmetry probe) on ingestion.",
    ),
    _k(
        "REPRO_EIGSH_SESSION_CACHE",
        "int",
        8,
        "Max entries in the process-wide warm EigenSession cache (0 disables).",
    ),
    _k(
        "REPRO_EIGSH_SESSION_CACHE_MB",
        "float",
        2048.0,
        "Total device-bytes budget (MB) for the warm EigenSession cache.",
    ),
    _k(
        "REPRO_EIGSH_CHUNK_NNZ",
        "int",
        25_000_000,
        "nnz threshold above which eigsh routes to the out-of-core chunked engine.",
    ),
    _k(
        "REPRO_CHUNK_STAGING",
        "str",
        "f32",
        "Out-of-core chunk staging mode: 'f32' (plain), 'bf16'/'fp8' (packed), or 'auto'.",
    ),
    _k(
        "REPRO_CHUNK_CKPT_EVERY",
        "int",
        1,
        "Chunks between mid-step chunk-cursor checkpoints in the out-of-core host loop (0 = end-of-step saves only).",
    ),
    _k(
        "REPRO_DISKCSR_FP_BLOCKS",
        "int",
        16,
        "Strided 64KiB sample blocks per array in the DiskCSR content fingerprint.",
    ),
    # --- Serving -----------------------------------------------------------
    _k(
        "REPRO_SERVING_STORE",
        "path",
        None,
        "Directory for the serving layer's persistent session store (unset = in-memory).",
    ),
    _k(
        "REPRO_SOLVE_CHECKPOINTS",
        "path",
        None,
        "Directory for mid-solve Lanczos checkpoints (unset = checkpointing off).",
    ),
    # --- Testing / debugging -----------------------------------------------
    _k(
        "REPRO_FAULT",
        "str",
        None,
        "Fault-injection spec 'kind[@iter=N][,...]' armed for the next solve (CI robustness legs).",
    ),
    _k(
        "REPRO_PALLAS_LOWER_CHECK",
        "bool",
        False,
        "Make tests/test_lowering.py compile every Pallas entrypoint (canary CI legs).",
    ),
    # --- Static analysis / verification ------------------------------------
    _k(
        "REPRO_PRECISION_MEASURE",
        "bool",
        False,
        "Attach jaxpr-measured op counts (ops_by_dtype_measured) to result partitions.",
    ),
    _k(
        "REPRO_ANALYSIS_VMEM_MB",
        "float",
        16.0,
        "VMEM budget (MB per core) the kernel static checker enforces (rule K003).",
    ),
)

KNOBS: Dict[str, EnvKnob] = {k.name: k for k in _DECLARED}

_TRUE = frozenset({"1", "true", "on", "yes"})
_FALSE = frozenset({"0", "false", "off", "no"})


def knob(name: str) -> EnvKnob:
    """Return the declaration for ``name``; raise KeyError for undeclared knobs."""
    try:
        return KNOBS[name]
    except KeyError:
        raise KeyError(
            f"{name} is not a declared REPRO_* knob; add it to repro/configs/env.py"
        ) from None


def raw(name: str) -> Optional[str]:
    """The raw environment string for a declared knob, or None when unset."""
    knob(name)
    return os.environ.get(name)


def is_truthy(value: str) -> bool:
    return value.strip().lower() in _TRUE


def is_falsey(value: str) -> bool:
    return value.strip().lower() in _FALSE


def get_bool(name: str, default: Optional[bool] = None) -> bool:
    """Parse a boolean knob.

    Explicit true spellings (1/true/on/yes) -> True, explicit false
    spellings (0/false/off/no) -> False; unset or unrecognized -> the
    registry default (or ``default`` when given).
    """
    k = knob(name)
    fallback = k.default if default is None else default
    value = os.environ.get(name)
    if value is None:
        return bool(fallback)
    if is_truthy(value):
        return True
    if is_falsey(value):
        return False
    return bool(fallback)


def get_int(name: str, default: Optional[int] = None) -> int:
    """Parse an integer knob; an unparseable value raises ValueError."""
    k = knob(name)
    fallback = k.default if default is None else default
    value = os.environ.get(name)
    if value is None or not value.strip():
        return int(fallback)
    try:
        return int(value)
    except ValueError:
        raise ValueError(f"{name} must be an integer, got {value!r}") from None


def get_float(name: str, default: Optional[float] = None, *, lenient: bool = False) -> float:
    """Parse a float knob; ``lenient=True`` falls back to the default on junk."""
    k = knob(name)
    fallback = k.default if default is None else default
    value = os.environ.get(name)
    if value is None or not value.strip():
        return float(fallback)
    try:
        return float(value)
    except ValueError:
        if lenient:
            return float(fallback)
        raise ValueError(f"{name} must be a number, got {value!r}") from None


def get_str(name: str, default: Optional[str] = None) -> Optional[str]:
    """The raw string for a knob, or its default (registry default if None)."""
    k = knob(name)
    fallback = k.default if default is None else default
    value = os.environ.get(name)
    return value if value is not None else fallback
