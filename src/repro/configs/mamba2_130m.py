"""mamba2-130m: SSD (state-space duality) [arXiv:2405.21060; unverified]

Exact assigned config (full) + reduced same-family smoke config.
"""

import dataclasses

import jax.numpy as jnp

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=50280, ssm_state=128, ssm_expand=2, ssm_headdim=64,
    ssm_chunk=64, conv_width=4, tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, vocab=512, ssm_state=16, ssm_headdim=16,
    ssm_chunk=16, compute_dtype=jnp.float32,
)
