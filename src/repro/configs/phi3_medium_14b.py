"""phi3-medium-14b: RoPE SwiGLU GQA [arXiv:2404.14219; unverified]

Exact assigned config (full) + reduced same-family smoke config.
"""

import dataclasses

import jax.numpy as jnp

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, d_ff=17920,
    vocab=100352, head_dim=128, rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, attn_chunk=32, compute_dtype=jnp.float32,
)
