"""qwen3-0.6b: qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]

Exact assigned config (full) + reduced same-family smoke config.
"""

import dataclasses

import jax.numpy as jnp

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=3072,
    vocab=151936, head_dim=128, qk_norm=True, rope_theta=1e6,
    tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=512, attn_chunk=32, compute_dtype=jnp.float32,
)
