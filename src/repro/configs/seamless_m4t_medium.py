"""seamless-m4t-medium: enc-dec, audio frontend stub [arXiv:2308.11596; hf]

Exact assigned config (full) + reduced same-family smoke config.
"""

import dataclasses

import jax.numpy as jnp

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, head_dim=64, frontend="audio",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab=512, attn_chunk=32, compute_dtype=jnp.float32,
)
