"""arctic-480b: 128 experts top-2 + dense residual MLP [hf:Snowflake/snowflake-arctic-base; hf]

Exact assigned config (full) + reduced same-family smoke config.
"""

import dataclasses

import jax.numpy as jnp

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000, head_dim=128, n_experts=128, moe_top_k=2,
    dense_residual=True, rope_theta=1e4,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab=512, n_experts=4, moe_group_size=64, attn_chunk=32,
    compute_dtype=jnp.float32,
)
