"""qwen1.5-32b: QKV bias [hf:Qwen/Qwen1.5-0.5B; hf]

Exact assigned config (full) + reduced same-family smoke config.
"""

import dataclasses

import jax.numpy as jnp

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, d_ff=27392,
    vocab=152064, head_dim=128, qkv_bias=True, rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512, attn_chunk=32, compute_dtype=jnp.float32,
)
