"""codeqwen1.5-7b: qwen1.5 arch: QKV bias, MHA [hf:Qwen/CodeQwen1.5-7B; hf]

Exact assigned config (full) + reduced same-family smoke config.
"""

import dataclasses

import jax.numpy as jnp

from ..models.common import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=13440,
    vocab=92416, head_dim=128, qkv_bias=True, rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=512, attn_chunk=32, compute_dtype=jnp.float32,
)
