"""Plan/execute split for the solver frontend: prepared ``EigenSession``s.

``eigsh(A, k)`` reproduces the paper's transparency claim, but every call
re-pays the full plan phase — input coercion, format census, ELL/BSR/hybrid
conversion, tile tuning, shard remapping, chunk pinning — even when the
matrix is identical.  The serving pattern the ROADMAP targets (one graph,
millions of queries) is the opposite shape: one expensive plan, many cheap
executes.  This module makes the split explicit:

    sess = prepare(A, format="auto")            # pay the plan once
    r1 = sess.eigsh(8, policy="FDF")            # execute: no conversions
    r2 = sess.eigsh(4, tol=1e-7)                # execute: no conversions
    rs = sess.eigsh_many([{"k": 4}, {"k": 8}])  # batched: one shared sweep

A session owns the coerced input, the resolved placement, the converted
device/shard/chunk operators and their tuned tiles — everything that is a
function of the *matrix* and the layout-affecting config, and nothing that
is a function of the *query* (k, policy, tol, num_iters, start vector).
Operators are cached per precision policy (storage/compute dtype pair), so
a session serves mixed-policy query streams without rebuilding.

``eigsh`` stays the one-call entrypoint: it is now a thin wrapper over a
small fingerprint-keyed session cache (content digest of the CSR arrays +
the layout-affecting config fields), so naive repeated calls transparently
hit the prepared path.  Reuse is *verified*, not assumed: results report
the conversion and tuner-probe counts their call actually paid
(``partition["spmv"]``) and a ``session_reuse`` provenance flag.

``eigsh_many`` amortizes one matrix across many ``(k, policy, tol)``
queries: queries are grouped by (backend, policy, reorth, jacobi), each
group runs ONE Lanczos sweep at the group's largest subspace and every
query slices its Ritz pairs from it (columns are independent, so a k=4
answer inside a k=16 sweep is exactly the k=4 answer of that subspace —
never worse than the query's own sweep).  Queries that differ only in
their start vector run as a vmapped multi-start batch when the operator's
matvec is batchable (dense / COO segment-sum).
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
import os
import threading
import time
import warnings
from collections import OrderedDict
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import env as envcfg
from ..core.distributed import PreparedShards, prepare_sharded, solve_sharded
from ..core.eigensolver import ritz_decompose, ritz_extract, solve_fixed
from ..core.lanczos import LanczosResult, NumericalBreakdown, lanczos_tridiag_multi
from ..core.operators import (
    ChunkedOperator,
    DenseOperator,
    LinearOperator,
    SparseOperator,
    make_operator,
)
from ..core.precision import PrecisionPolicy, auto_ladder, phase_op_counts
from ..core.restarted import solve_restarted
from ..kernels.engine import FORMATS, SpmvEngine, make_engine, tuner_probe_count
from ..sparse.diskcsr import DiskCSR, is_diskcsr
from ..sparse.formats import CSR, conversion_count
from .coerce import CoercedInput, coerce_input, matrix_fingerprint
from .dispatch import select_backend
from .frontend import (
    SolverConfig,
    _default_tol,
    _resolve_reorth,
    is_auto_policy,
    resolve_policy,
)
from .result import EigenResult

__all__ = [
    "EigQuery",
    "EigenSession",
    "prepare",
    "eigsh_many",
    "policy_key",
    "config_fingerprint",
    "get_session",
    "session_cache_clear",
    "session_cache_info",
]

# Persisted-session schema version (EigenSession.export_state /
# import_plans).  Bump when the exported plan layout changes shape.
_EXPORT_SCHEMA = 1

_UNSET = object()  # distinguishes "inherit the session default" from None

# SolverConfig fields that change what a session *builds* (placement, device
# layouts, tiles).  Per-query fields (k, tol, num_iters, reorth, seed,
# subspace, max_restarts, jacobi, policy) are deliberately excluded: the
# session resolves them per query, and policies get per-dtype operator
# caches inside the session.
_LAYOUT_FIELDS = ("backend", "format", "chunk_nnz", "stage_depth", "axis", "staging")

# Largest on-disk payload the auto ladder's f64 residual verification will
# materialize: a bigger DiskCSR stays on disk and the ladder falls back to
# the Ritz bound (verification must never defeat the out-of-core budget).
_DISK_VERIFY_MAX_BYTES = 1 << 28


def policy_key(policy: Union[str, PrecisionPolicy]) -> str:
    """Stable identity key of a policy: the dtype triple — plus any
    per-phase compute overrides — never the spelling.  ``"FDF"`` and the
    ``FDF`` instance key identically (the frontend's session cache relies on
    this); a phase-split policy whose overrides all equal ``compute`` keys
    identically to the uniform policy.  Built *plans* are shared more
    aggressively than this key — see :func:`_plan_key`."""
    p = resolve_policy(policy).effective()
    parts = [
        jnp.dtype(p.storage).name,
        jnp.dtype(p.compute).name,
        jnp.dtype(p.output).name,
        f"c{int(p.compensated)}",
    ]
    if not p.is_uniform():
        parts.append(
            "ph[" + ",".join(f"{ph}:{dt}" for ph, dt in p.phase_map().items()) + "]"
        )
    return "-".join(parts)


def _plan_key(pol: PrecisionPolicy) -> str:
    """Key of what a built plan actually depends on: the storage dtype (the
    device container) and the SpMV-phase accumulator (the engine).  Narrower
    than :func:`policy_key` on purpose — a reorth/alpha_beta/ritz split
    changes per-query arithmetic (its ``Ops`` record, keyed per policy in
    ``_Prepared.ops_for``), never the converted operator, so e.g. FDF and
    FDF[reorth=f32] share one plan instead of double-converting."""
    return "-".join(
        (jnp.dtype(pol.storage).name, jnp.dtype(pol.phase_dtype("spmv")).name)
    )


# Policy the plan phase assumes when ``policy="auto"`` is requested: the
# ladder's f32-storage rung, so coercion never rounds the input below what
# any rung needs; each rung's own operators build lazily per policy_key.
_AUTO_PLAN_POLICY = "FFF"


def _plan_policy(policy) -> PrecisionPolicy:
    """The policy a session plans/coerces with (resolves "auto" to the
    ladder-neutral f32 rung; see :func:`auto_ladder`)."""
    return resolve_policy(_AUTO_PLAN_POLICY if is_auto_policy(policy) else policy)


def config_fingerprint(cfg: SolverConfig, fields: Optional[Sequence[str]] = None) -> str:
    """Stable digest of a :class:`SolverConfig` (or the ``fields`` subset).

    ``policy`` is normalized through :func:`resolve_policy` and hashed by
    name + dtype triple, so a config carrying a ``PrecisionPolicy`` instance
    fingerprints identically to one carrying the policy's name — passing
    ``policy=FDF`` must hit the same cache entry as ``policy="FDF"``.
    """
    if fields is not None:
        names = tuple(fields)
    else:
        names = tuple(f.name for f in dataclasses.fields(cfg))
    parts = []
    for name in sorted(names):
        v = getattr(cfg, name)
        if name == "policy":
            if is_auto_policy(v):
                v = ("auto", "auto")  # the ladder, not any one rung
            else:
                p = resolve_policy(v)
                v = (p.name, policy_key(p))
        parts.append(f"{name}={v!r}")
    return hashlib.blake2b("|".join(parts).encode(), digest_size=12).hexdigest()


@dataclasses.dataclass(frozen=True, eq=False)
class EigQuery:
    """One solve request against a prepared session.

    Every field except ``k`` defaults to the session's configuration
    (``_UNSET`` = inherit); explicit values — including ``None`` where that
    is meaningful, e.g. ``tol=None`` for fixed-iteration mode — override it.
    Plain dicts (``{"k": 8, "tol": 1e-6}``) and bare ints coerce.
    ``policy`` accepts everything :func:`repro.api.resolve_policy` does plus
    ``"auto"`` (the accuracy-driven escalation ladder; such queries solve
    individually, never grouped).
    """

    k: int
    policy: Any = None
    tol: Any = _UNSET
    num_iters: Any = _UNSET
    reorth: Any = _UNSET
    seed: Any = _UNSET
    v0: Any = None
    subspace: Any = _UNSET
    max_restarts: Any = _UNSET
    jacobi: Any = _UNSET
    recovery: Any = _UNSET


# recovery="auto" escalation bounds: total attempts (the first solve plus up
# to five recovery actions) and how many fresh start vectors a lucky
# breakdown may burn before it is treated as structural and re-raised.
_MAX_RECOVERY_ATTEMPTS = 6
_MAX_RESEEDS = 2


def _classify_failure(exc) -> Optional[str]:
    """Map an in-solve exception to a ``recovery="auto"`` action, or None
    when no documented recovery applies (the error re-raises unchanged).

    Classification is deliberately conservative: only errors whose shape
    identifies a *transient or escapable* failure mode map to an action —
    user errors (``ValueError``/``TypeError`` from validation) never retry.
    """
    from ..core.lanczos import NumericalBreakdown as _NB

    if isinstance(exc, _NB):
        # A lucky breakdown (the Krylov space closed early) wants a new
        # start vector; non-finite recurrence scalars want more headroom.
        return "reseed" if exc.kind == "beta_underflow" else "escalate_policy"
    msg = str(exc)
    if (
        isinstance(exc, MemoryError)
        or "RESOURCE_EXHAUSTED" in msg
        or "out of memory" in msg.lower()
    ):
        return "fallback_chunked"
    mod = type(exc).__module__ or ""
    looks_kernel = (
        "lowering" in msg.lower() or "Mosaic" in msg or "pallas" in msg.lower()
    )
    from ..testing.faults import InjectedKernelError

    if isinstance(exc, InjectedKernelError):
        return "unfuse"
    if looks_kernel and (
        mod.startswith("jax")
        or mod.startswith("jaxlib")
        or isinstance(exc, (RuntimeError, NotImplementedError))
    ):
        return "unfuse"
    return None


def _policy_rank(pol: PrecisionPolicy) -> tuple:
    """Orderable cost/headroom rank of a policy: compute width first (what
    breakdown escalation buys), then compensation, then storage width —
    matching :func:`auto_ladder`'s cheapest-first ordering."""
    p = pol.effective()
    return (
        jnp.dtype(p.compute).itemsize,
        int(bool(p.compensated)),
        jnp.dtype(p.storage).itemsize,
    )


def _next_rung(pol: PrecisionPolicy) -> Optional[PrecisionPolicy]:
    """The cheapest :func:`auto_ladder` rung strictly above ``pol`` in
    compute headroom, or None when ``pol`` already tops the ladder."""
    cur = _policy_rank(pol)
    for rung in auto_ladder():
        cand = resolve_policy(rung).effective()
        if _policy_rank(cand) > cur:
            return cand
    return None


def _as_query(q) -> EigQuery:
    if isinstance(q, EigQuery):
        return q
    if isinstance(q, dict):
        return EigQuery(**q)
    if isinstance(q, (int, np.integer)):
        return EigQuery(k=int(q))
    raise TypeError(
        f"eigsh_many query must be an EigQuery, a dict of its fields, or an "
        f"int k; got {type(q).__name__}"
    )


def _norm_group_key(q: "_NormQuery") -> tuple:
    """Group-compatibility key of a normalized query: queries sharing it are
    answered by ONE Lanczos sweep (``eigsh_many`` groups by exactly this; the
    serving scheduler coalesces queued queries by it).  ``recovery`` joins
    the key: a recovering sweep may escalate policy / unfuse / reseed, so a
    ``recovery="none"`` query must never ride along with it."""
    return (q.backend, q.pkey, q.pol.name, q.reorth, q.jacobi, q.recovery)


class _NormQuery(NamedTuple):
    """A query with every field resolved against the session defaults."""

    idx: int
    k: int
    pol: PrecisionPolicy  # effective()
    pkey: str
    backend: str
    reorth: str
    tol_req: Optional[float]
    tol_eff: float
    num_iters: Optional[int]
    m: int  # fixed-m subspace this query needs
    subspace: Optional[int]
    max_restarts: int
    seed: int
    v0: Any
    jacobi: str
    start_key: str
    recovery: str  # "none" | "raise" | "auto"
    ckpt_dir: Optional[str]  # solve-checkpoint directory (None = off)
    ckpt_every: int  # chunked host loop: steps between snapshots


@dataclasses.dataclass
class _Prepared:
    """One built execution plan: a device operator (single/chunked) or a
    shard set (distributed), plus what building it cost."""

    kind: str  # "single" | "chunked" | "distributed"
    operator: Optional[LinearOperator]
    shards: Optional[PreparedShards]
    spmv_format: Any
    engine: Optional[SpmvEngine]
    build_s: float = 0.0
    conversions: int = 0
    tuner_probes: int = 0
    # Arithmetic-kernel records (core.lanczos.Ops) memoized per policy: the
    # jitted Lanczos loop is keyed on the record's identity, so reusing one
    # record across queries turns every repeat solve into an XLA compile
    # cache hit — without this, "zero-conversion" executes still re-trace.
    ops_cache: Dict[tuple, Any] = dataclasses.field(default_factory=dict)

    def ops_for(self, pol: PrecisionPolicy, fused: Optional[bool] = None):
        from ..core.lanczos import ops_for_operator, resolve_update_mode

        eng = getattr(self.operator, "engine", None)
        plan = getattr(eng, "iteration_plan", None)
        # The resolved update mode joins the memo key so env-pin changes
        # (REPRO_FUSED_LANCZOS / REPRO_ITER_UPDATE) between executes on one
        # warm session can never serve a stale record.
        mode = resolve_update_mode(pol, plan=plan, fused=fused)
        key = (pol, fused, mode)
        ops = self.ops_cache.get(key)
        if ops is None:
            ops = ops_for_operator(self.operator, pol, fused=fused)
            self.ops_cache[key] = ops
        return ops


def _op_format(op) -> str:
    """SpMV layout label of a caller-provided operator."""
    fmt = getattr(op, "spmv_format", None)
    if fmt is not None:
        return fmt
    if isinstance(op, DenseOperator):
        return "dense"
    return "matfree"


class EigenSession:
    """Prepared solve state for one matrix; see the module docstring.

    Build one with :func:`prepare` (direct construction is supported but
    skips the frontend's session cache).  Concurrent use is safe but
    serialized: a session runs one query batch at a time (an internal lock
    — the shared operators and counters are single-stream); distinct
    sessions run in parallel.

    Attributes:
      cfg: the layout/default configuration the session was prepared with.
      n: problem dimension.
      csr: the owned host CSR (None for matrix-free/dense inputs).
      fingerprint: content+config digest keying the frontend cache (None
        when the input has no fingerprintable bytes, or when the session was
        built directly — digests are computed only for the cache's benefit).
      prepare_s: wall seconds the eager plan phase took.
      stats: {"queries", "sweeps", "cache_hits"} counters.
    """

    # Checked by repro.analysis C001: the prepared-plan cache is mutated
    # only under the build lock (queries hold _query_lock, which is a
    # different lock — reads of _prepared race only with idempotent
    # inserts, and insertion goes through _build_lock).
    _GUARDED_BY = {"_prepared": "_build_lock"}

    def __init__(
        self,
        A,
        config: Optional[SolverConfig] = None,
        *,
        mesh=None,
        n: Optional[int] = None,
        _coerced: Optional[CoercedInput] = None,
    ):
        cfg = config or SolverConfig()
        if cfg.format not in ("auto",) + FORMATS:
            raise ValueError(
                f"unknown SpMV format {cfg.format!r}; expected 'auto' or one of {FORMATS}"
            )
        self.cfg = cfg
        self.mesh = mesh
        self._default_mesh = None
        t0 = time.perf_counter()
        conv0, probes0 = conversion_count(), tuner_probe_count()
        pol0 = _plan_policy(cfg.policy).effective()
        ci = _coerced or coerce_input(A, n=n, storage_dtype=pol0.storage)
        self.op, self.csr, self.n = ci.operator, ci.csr, ci.n
        # Dense inputs keep the ORIGINAL array so a later query with a
        # different storage dtype re-coerces from the source, not from an
        # already-rounded copy.
        self._dense = A if isinstance(A, (np.ndarray, jax.Array)) else None
        self.device_count = mesh.size if mesh is not None else len(jax.devices())
        self.matrix_fingerprint = ci.fingerprint
        self.fingerprint = _session_key(ci.fingerprint, cfg, mesh) if ci.fingerprint else None
        self._prepared: Dict[Tuple[str, str], _Prepared] = {}
        self._verify_a = None  # lazy f64 matrix for the auto ladder's verification
        self._build_lock = threading.Lock()
        self._query_lock = threading.RLock()  # queries serialize per session
        self.stats = {"queries": 0, "sweeps": 0, "cache_hits": 0, "recoveries": 0}
        self.prepare_s = time.perf_counter() - t0
        self.prepare_conversions = conversion_count() - conv0
        self.prepare_tuner_probes = tuner_probe_count() - probes0
        # Coercion cost not yet attributed to any result: the first query
        # that builds a plan claims it into its timings["prepare_s"] (a
        # warmup() claims it into session.prepare_s instead).
        self._unclaimed_init_s = self.prepare_s

    def warmup(self) -> "EigenSession":
        """Eagerly build the plan for the configured placement and default
        policy, so :func:`prepare` — not the first query — pays the
        conversion/tuning cost.  (Construction alone builds lazily: the
        frontend's one-call path lets the first query build, so that call's
        counters honestly report what it paid.)"""
        pol0 = _plan_policy(self.cfg.policy).effective()
        backend0 = self._resolve_backend(self.cfg.tol)
        prep, built = self._ensure(backend0, pol0)
        if built:
            self.prepare_s += prep.build_s
            self.prepare_conversions += prep.conversions
            self.prepare_tuner_probes += prep.tuner_probes
        self._unclaimed_init_s = 0.0  # prepare() paid it; queries report 0
        return self

    def _claim_init_s(self) -> float:
        s, self._unclaimed_init_s = self._unclaimed_init_s, 0.0
        return s

    def _own_data(self) -> None:
        """Snapshot the host-side problem data (CSR arrays / dense source) so
        the session stops aliasing the caller's buffers.  Called when a
        session enters the frontend cache: its fingerprint pins the bytes it
        was built from, and a later in-place mutation by the caller must not
        leak into lazily-built per-policy plans — that would serve a stale
        plan for byte-identical input, the exact thing the digest forbids."""
        from ..sparse.formats import CSR as _CSR

        if isinstance(self.csr, DiskCSR):
            # Disk-backed sessions keep the mapping, never a RAM snapshot —
            # materializing would defeat the out-of-core budget, and the
            # sampled fingerprint already keys the on-disk content.
            self._verify_a = None
            return
        if self.csr is not None:
            self.csr = _CSR(
                indptr=np.array(self.csr.indptr, copy=True),
                indices=np.array(self.csr.indices, copy=True),
                data=np.array(self.csr.data, copy=True),
                shape=self.csr.shape,
            )
        if self._dense is not None:
            self._dense = np.array(self._dense, copy=True)
        # Rebuild the verification copy from the snapshotted data on demand
        # (it may alias the caller's pre-snapshot buffers).
        self._verify_a = None

    def approx_bytes(self) -> int:
        """Rough memory footprint of what caching this session pins: the host
        problem data plus ~one converted (device) copy per built plan —
        lazily-built per-policy plans grow it, and the cache re-enforces its
        byte budget after each build.  An estimate, not an audit."""
        if isinstance(self.csr, DiskCSR):
            # Disk pages are the kernel's to cache and reclaim; the session
            # pins only O(n) planning metadata per built plan.
            return int(self.csr.indptr.nbytes) * (2 + len(self._prepared))
        if self.csr is not None:
            base = self.csr.indptr.nbytes + self.csr.indices.nbytes + self.csr.data.nbytes
        elif self._dense is not None:
            base = int(getattr(self._dense, "nbytes", 0))
        else:
            base = 0
        return base * (2 + len(self._prepared))

    # ------------------------------------------------------------ planning

    def _resolve_backend(self, tol: Optional[float]) -> str:
        return select_backend(
            self.cfg.backend,
            has_matrix=self.csr is not None,
            nnz=self.csr.nnz if self.csr is not None else 0,
            tol=tol,
            device_count=self.device_count,
            mesh_given=self.mesh is not None,
            disk_bytes=(
                self.csr.nbytes_on_disk() if isinstance(self.csr, DiskCSR) else None
            ),
        )

    def _mesh_for_solve(self):
        from jax.sharding import Mesh

        if self.mesh is not None:
            return self.mesh
        if self._default_mesh is None:
            devs = np.array(jax.devices())
            self._default_mesh = Mesh(devs.reshape(len(devs)), (self.cfg.axis,))
        return self._default_mesh

    def _ensure(self, backend: str, pol: PrecisionPolicy) -> Tuple[_Prepared, bool]:
        """Prepared plan for (placement, policy dtypes): build once, reuse.
        Serialized: concurrent queries must not double-build one plan."""
        kind = backend if backend in ("distributed", "chunked") else "single"
        key = (kind, _plan_key(pol))
        with self._build_lock:
            hit = self._prepared.get(key)
            if hit is not None:
                return hit, False
            t0 = time.perf_counter()
            conv0, probes0 = conversion_count(), tuner_probe_count()
            if kind == "distributed":
                prep = self._build_distributed(pol)
            elif kind == "chunked":
                prep = self._build_chunked(pol)
            else:
                prep = self._build_single(pol)
            prep.build_s = time.perf_counter() - t0
            prep.conversions = conversion_count() - conv0
            prep.tuner_probes = tuner_probe_count() - probes0
            self._prepared[key] = prep
        # A lazy build grew this session's footprint: let the cache re-check
        # its byte budget (no-op for sessions that were never cached).
        _cache_enforce_budget()
        return prep, True

    def _build_single(self, pol: PrecisionPolicy) -> _Prepared:
        if self.op is not None:
            op = self.op
            if isinstance(op, DenseOperator) and self._dense is not None:
                want = jnp.dtype(pol.storage)
                if jnp.dtype(op.a.dtype) != want:
                    op = DenseOperator(jnp.asarray(self._dense, dtype=want))
            return _Prepared("single", op, None, _op_format(op), None)
        engine = make_engine(
            self.csr,
            self.cfg.format,
            accum_dtype=pol.phase_dtype("spmv"),
            storage_dtype=pol.storage,
        )
        op = make_operator(self.csr, dtype=pol.storage, engine=engine)
        return _Prepared("single", op, None, engine.format, engine)

    def _build_chunked(self, pol: PrecisionPolicy) -> _Prepared:
        cfg, csr = self.cfg, self.csr
        fmt = cfg.format if cfg.format != "auto" else "ell"
        # Build the ELL engine first even under "auto": its tiles determine
        # the per-chunk row padding, which the selection below must charge.
        engine = make_engine(
            csr,
            fmt,
            accum_dtype=pol.phase_dtype("spmv"),
            allowed=("coo", "ell"),  # per-chunk BSR/hybrid staging not implemented
            storage_dtype=pol.storage,
        )
        if cfg.format == "auto":
            # The chunked engine stages ELL per chunk at each chunk's OWN
            # 128-aligned max row width, so its ELL eligibility must be
            # judged on that realized layout — the whole-matrix selector's
            # global-max-row overhead would veto exactly the hub matrices
            # the per-chunk split handles (one hub inflates one chunk, not
            # all), while narrow matrices still lose to the 128-lane pad.
            # Memory being the backend's constraint, the padded footprint
            # must also not dwarf the COO triplets it replaces.
            from ..core.operators import chunk_row_bounds, chunk_rows_pad
            from ..kernels.engine import ell_overhead_bound

            row_nnz = csr.row_nnz()
            padded_slots = 0
            for r0, r1 in chunk_row_bounds(csr.indptr, csr.n, cfg.chunk_nnz):
                w = int(row_nnz[r0:r1].max()) if r1 > r0 else 1
                rows_pad = chunk_rows_pad(r1 - r0, engine.tiles.block_r, pol.storage)
                padded_slots += rows_pad * (-(-max(1, w) // 128) * 128)
            nnz = max(1, csr.nnz)
            ell_bytes = padded_slots * (jnp.dtype(pol.storage).itemsize + 4)
            overhead_ok = padded_slots / nnz <= ell_overhead_bound()
            if not (overhead_ok and ell_bytes <= 4 * nnz * 12):
                engine = make_engine(
                    csr,
                    "coo",
                    stats=engine.stats,
                    accum_dtype=pol.phase_dtype("spmv"),
                    storage_dtype=pol.storage,
                )
        # REPRO_CHUNK_STAGING pins the staged-chunk encoding for A/B runs,
        # overriding the config (ChunkedOperator validates the value).
        staging = envcfg.raw("REPRO_CHUNK_STAGING") or getattr(cfg, "staging", "f32")
        op = ChunkedOperator(
            csr,
            chunk_nnz=cfg.chunk_nnz,
            dtype=pol.storage,
            engine=engine,
            stage_depth=cfg.stage_depth,
            staging=staging,
            mesh=self.mesh,
            axis=cfg.axis,
        )
        return _Prepared("chunked", op, None, engine.format, engine)

    def _build_distributed(self, pol: PrecisionPolicy) -> _Prepared:
        mesh = self._mesh_for_solve()
        g = mesh.shape[self.cfg.axis]
        shards = prepare_sharded(self.csr, g, pol, self.cfg.format)
        return _Prepared("distributed", None, shards, shards.engine.format, shards.engine)

    # ----------------------------------------------------------- execution

    def eigsh(
        self,
        k: int,
        *,
        policy=None,
        tol=_UNSET,
        num_iters=_UNSET,
        reorth=_UNSET,
        v0=None,
        seed=_UNSET,
        subspace=_UNSET,
        max_restarts=_UNSET,
        jacobi=_UNSET,
        recovery=_UNSET,
    ) -> EigenResult:
        """Solve one query against the prepared plan.  Unset keywords inherit
        the session configuration; see :func:`repro.api.eigsh` for semantics."""
        q = EigQuery(
            k=k,
            policy=policy,
            tol=tol,
            num_iters=num_iters,
            reorth=reorth,
            seed=seed,
            v0=v0,
            subspace=subspace,
            max_restarts=max_restarts,
            jacobi=jacobi,
            recovery=recovery,
        )
        return self.eigsh_many([q])[0]

    def eigsh_many(self, queries, defaults: Optional[SolverConfig] = None) -> List[EigenResult]:
        """Batched execute: many ``(k, policy, tol, ...)`` queries, one matrix.

        Queries are grouped by (backend, policy, reorth, jacobi); each group
        (per start vector) runs one shared Lanczos sweep at the group's
        largest subspace and every member slices its Ritz pairs out of it.
        Groups differing only in start vector batch through the vmapped
        multi-start sweep when the operator supports it.  Results come back
        in input order, one :class:`EigenResult` per query.

        Merged groups run under the group's *most permissive* cost settings
        (largest ``num_iters``/``subspace``/``max_restarts``; a query with no
        budget lifts the cap for its restarted group) and its tightest
        ``tol`` — per-query step budgets are advisory under batching: the
        shared sweep can only make an individual answer more accurate, and
        its cost is paid once for the whole group.  Submit a query alone (or
        via :func:`repro.api.eigsh`) when its budget must bind exactly.
        """
        if not queries:
            return []
        cfg = defaults or self.cfg
        # Serialized: concurrent queries on ONE session would race the shared
        # operator counters and stats (distinct sessions still run parallel).
        with self._query_lock:
            raw = [_as_query(q) for q in queries]
            self.stats["queries"] += len(raw)
            results: List[Optional[EigenResult]] = [None] * len(raw)
            normal: List[_NormQuery] = []
            for i, rq in enumerate(raw):
                requested = rq.policy if rq.policy is not None else cfg.policy
                if is_auto_policy(requested):
                    # policy="auto" escalates through its own solve ladder;
                    # it never groups with fixed-policy queries.
                    results[i] = self._solve_auto(rq, cfg)
                else:
                    normal.append(self._normalize(rq, i, cfg))
            groups: Dict[tuple, List[_NormQuery]] = {}
            for q in normal:
                groups.setdefault(_norm_group_key(q), []).append(q)
            for group in groups.values():
                for idx, res in self._solve_group(group):
                    results[idx] = res
        return results  # type: ignore[return-value]

    def ensure_fingerprint(self) -> Optional[str]:
        """Content digest of this session's matrix, computing it on demand.

        Directly-constructed sessions skip the digest (it only exists for
        the frontend cache's benefit), but persistence needs one — the store
        keys entries by it and ``import_plans`` validates against it.  Still
        None for matrix-free inputs (no bytes to hash)."""
        if self.matrix_fingerprint is None:
            src = self.csr if self.csr is not None else self._dense
            if src is not None:
                self.matrix_fingerprint = matrix_fingerprint(src)
        return self.matrix_fingerprint

    def group_key(self, query, defaults: Optional[SolverConfig] = None) -> Optional[tuple]:
        """Public group-compatibility predicate: the key :meth:`eigsh_many`
        groups by.  Two queries whose keys are equal (on the same session)
        are served by ONE shared Lanczos sweep; the serving scheduler
        (``repro.serving``) coalesces queued queries by exactly this key, so
        its batches can never mix what the session would not merge.

        Returns ``None`` for ``policy="auto"`` queries — the escalation
        ladder solves individually and never groups.  Raises the same
        ``ValueError`` as submitting the query would (``k`` out of range,
        infeasible ``num_iters``), so callers can validate at admission time.
        """
        cfg = defaults or self.cfg
        rq = _as_query(query)
        requested = rq.policy if rq.policy is not None else cfg.policy
        if is_auto_policy(requested):
            return None
        return _norm_group_key(self._normalize(rq, 0, cfg))

    # --------------------------------------------------- persistence hooks

    def export_state(self) -> dict:
        """Serializable snapshot of this session's built plans (the warm
        state a restarted server needs): per-plan device-container arrays +
        the engine configuration (format, accumulator dtype, tuned tiles).
        The header carries the repro version, the matrix fingerprint, and the
        layout-config fingerprint so :meth:`import_plans` can reject stale
        artifacts.  Arrays come back as npz-safe NumPy (bf16 values are
        stored widened to f32 with their dtype recorded).

        Only "single"-placement plans over explicit device containers (COO /
        ELL / BSR / hybrid) or dense operators export; chunked plans are
        host-resident anyway (nothing device-converted to save) and
        distributed plans are mesh-bound — both rebuild lazily on import.
        """
        from .. import __version__

        with self._build_lock:
            items = list(self._prepared.items())
        plans = []
        for (kind, plan_key), prep in items:
            if kind != "single" or prep.operator is None:
                continue
            exported = _export_operator(prep.operator)
            if exported is None:
                continue
            container, arrays = exported
            dtypes = {name: str(a.dtype) for name, a in arrays.items()}
            # bf16 has no native NumPy container format: widen to f32 for the
            # npz (lossless — f32 is a superset); import narrows back via the
            # recorded dtype.
            arrays = {
                name: (a.astype(np.float32) if str(a.dtype) == "bfloat16" else a)
                for name, a in arrays.items()
            }
            engine_cfg = None
            if prep.engine is not None:
                e = prep.engine
                engine_cfg = {
                    "format": e.format,
                    "accum_dtype": str(jnp.dtype(e.accum_dtype)),
                    "tiles": {
                        "block_r": int(e.tiles.block_r),
                        "block_w": int(e.tiles.block_w),
                        "block_size": int(e.tiles.block_size),
                    },
                    "interpret": bool(e.interpret),
                    "requested": e.requested,
                    "tiles_from": e.tiles_from,
                    "iteration_plan": (
                        e.iteration_plan.as_dict() if e.iteration_plan is not None else None
                    ),
                }
            fmt = prep.spmv_format
            plans.append(
                {
                    "plan_key": plan_key,
                    "container": container,
                    "spmv_format": fmt if isinstance(fmt, str) else str(fmt),
                    "engine": engine_cfg,
                    "dtypes": dtypes,
                    "arrays": arrays,
                }
            )
        state = {
            "schema": _EXPORT_SCHEMA,
            "repro_version": __version__,
            "matrix_fingerprint": self.ensure_fingerprint(),
            "layout_fingerprint": config_fingerprint(self.cfg, _LAYOUT_FIELDS),
            "layout": {f: repr(getattr(self.cfg, f)) for f in _LAYOUT_FIELDS},
            "n": int(self.n),
            "plans": plans,
        }
        if isinstance(self.csr, DiskCSR):
            # Disk-backed sessions persist a POINTER to the matrix, never its
            # payload: the store can revive the session by reopening the
            # mapping and re-checking the sampled fingerprint.
            state["matrix_ref"] = {
                "kind": "diskcsr",
                "path": self.csr.path,
                "fingerprint": self.ensure_fingerprint(),
            }
        return state

    def import_plans(self, state: dict) -> int:
        """Install plans exported by :meth:`export_state` into this session;
        returns how many were imported.  Containers are rebuilt with the
        plain device constructors — NO format conversion runs (the
        ``conversion_count()`` audit stays untouched) and the persisted tiles
        ride in, so no tuner probes either: the next query is a pure execute.

        Stale artifacts are *rejected, not trusted*: a mismatched schema,
        repro version, matrix fingerprint, layout fingerprint, or dimension
        warns and returns 0 — the session simply cold-rebuilds lazily, the
        same behaviour as having no persisted state at all.
        """
        from .. import __version__

        header_checks = (
            ("schema", state.get("schema"), _EXPORT_SCHEMA),
            ("repro_version", state.get("repro_version"), __version__),
            ("matrix_fingerprint", state.get("matrix_fingerprint"), self.ensure_fingerprint()),
            (
                "layout_fingerprint",
                state.get("layout_fingerprint"),
                config_fingerprint(self.cfg, _LAYOUT_FIELDS),
            ),
            ("n", state.get("n"), int(self.n)),
        )
        for field, got, want in header_checks:
            if got != want:
                warnings.warn(
                    f"stale persisted session rejected ({field}: saved {got!r} != "
                    f"current {want!r}); falling back to a cold rebuild",
                    stacklevel=2,
                )
                return 0
        imported = 0
        for plan in state.get("plans", ()):
            try:
                prep = _import_plan(plan, int(self.n))
            except Exception as exc:  # corrupt payload: warn, keep serving
                warnings.warn(
                    f"corrupt persisted plan {plan.get('plan_key')!r} skipped "
                    f"({type(exc).__name__}: {exc}); it will cold-rebuild on demand",
                    stacklevel=2,
                )
                continue
            key = ("single", str(plan["plan_key"]))
            with self._build_lock:
                if key not in self._prepared:
                    self._prepared[key] = prep
                    imported += 1
        return imported

    # ---------------------------------------------------------- internals

    def _normalize(self, q: EigQuery, idx: int, cfg: SolverConfig) -> _NormQuery:
        def pick(v, dflt):
            return dflt if v is _UNSET else v

        k = int(q.k)
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        if k > self.n:
            raise ValueError(f"k={k} exceeds the operator dimension n={self.n}")
        pol = resolve_policy(q.policy if q.policy is not None else cfg.policy).effective()
        tol_req = pick(q.tol, cfg.tol)
        backend = self._resolve_backend(tol_req)
        reorth_raw = pick(q.reorth, cfg.reorth)
        num_iters = pick(q.num_iters, cfg.num_iters)
        if backend == "restarted":
            if reorth_raw not in (None, "full"):
                warnings.warn(
                    f"reorth={reorth_raw!r} is ignored by the restarted backend: "
                    "thick restart requires full re-orthogonalization to keep "
                    "the locked Ritz block orthogonal",
                    stacklevel=4,
                )
            reorth = "full"
            if num_iters is not None and num_iters < k + 2:
                raise ValueError(
                    f"num_iters={num_iters} cannot fund a restarted solve for "
                    f"k={k} (the subspace needs at least k + 2 = {k + 2} steps); "
                    "raise num_iters or use backend='single'"
                )
        else:
            reorth = _resolve_reorth(reorth_raw, backend)
            if num_iters is not None and num_iters < k:
                # Validated per query: a merged group's shared (larger)
                # subspace must not mask an individually infeasible request.
                raise ValueError(f"num_iters must be >= k (got {num_iters} < {k})")
        max_restarts = int(pick(q.max_restarts, cfg.max_restarts))
        if backend == "restarted" and max_restarts < 1:
            raise ValueError(f"max_restarts must be >= 1, got {max_restarts}")
        recovery = pick(q.recovery, getattr(cfg, "recovery", None)) or "raise"
        if recovery not in ("none", "raise", "auto"):
            raise ValueError(
                f"recovery must be 'none', 'raise', or 'auto'; got {recovery!r}"
            )
        seed = int(pick(q.seed, cfg.seed))
        if q.v0 is not None:
            h = hashlib.blake2b(np.asarray(q.v0).tobytes(), digest_size=8)
            start_key = f"v0:{h.hexdigest()}"
        else:
            start_key = f"seed:{seed}"
        m = int(num_iters) if num_iters is not None else k
        return _NormQuery(
            idx=idx,
            k=k,
            pol=pol,
            pkey=policy_key(pol),
            backend=backend,
            reorth=reorth,
            tol_req=tol_req,
            tol_eff=tol_req if tol_req is not None else _default_tol(pol),
            num_iters=num_iters,
            m=m,
            subspace=pick(q.subspace, cfg.subspace),
            max_restarts=max_restarts,
            seed=seed,
            v0=q.v0,
            jacobi=pick(q.jacobi, cfg.jacobi),
            start_key=start_key,
            recovery=recovery,
            ckpt_dir=getattr(cfg, "checkpoint_dir", None),
            ckpt_every=int(getattr(cfg, "checkpoint_every", 8) or 8),
        )

    def _solve_auto(self, rq: EigQuery, cfg: SolverConfig) -> EigenResult:
        """Accuracy-driven policy selection: probe the escalation ladder
        (:func:`repro.core.precision.auto_ladder`, cheapest rung first),
        re-solving until the *measured* residuals meet the query's effective
        tolerance.  For explicit-matrix inputs each rung is judged on
        verified f64 reconstruction residuals ``||A x - lambda x||`` (the
        Ritz residual bound converges with the Krylov process regardless of
        storage precision, so it cannot expose a too-narrow rung — the
        paper's Fig. 4 measures exactly this reconstruction error); matrix-
        free inputs fall back to the engines' Ritz bound and converged
        flags.  Each rung reuses this session's per-policy operator cache,
        so escalation pays solves, not plans.  The attempt trail — policy
        tried, max relative residual, what it was judged on, tol, accepted —
        is recorded on the returned result as ``policy_escalations``."""
        attempts: List[dict] = []
        res: Optional[EigenResult] = None
        for rung in auto_ladder():
            nq = self._normalize(dataclasses.replace(rq, policy=rung), 0, cfg)
            ((_, res),) = self._solve_group([nq])
            verified = self._verified_rel_residuals(res)
            if verified is None:
                max_rel = float(
                    np.max(
                        res.residuals
                        / np.maximum(np.abs(np.asarray(res.eigenvalues, np.float64)), 1e-300)
                    )
                )
                accepted = bool(res.all_converged)
                kind = "ritz_bound"
            else:
                max_rel = float(np.max(verified))
                accepted = bool(np.all(verified <= nq.tol_eff))
                kind = "verified"
            attempts.append(
                {
                    "policy": res.policy,
                    "max_residual": max_rel,
                    "residual_kind": kind,
                    "tol": float(nq.tol_eff),
                    "converged": accepted,
                }
            )
            if accepted:
                break
        return dataclasses.replace(res, policy_escalations=attempts)

    def _verified_rel_residuals(self, res: EigenResult) -> Optional[np.ndarray]:
        """(k,) relative reconstruction residuals ``||A x_i - lambda_i x_i||
        / max(|lambda_i|, tiny)`` in f64 against the session's host-side
        matrix — the accuracy measurement driving ``policy="auto"``.  None
        for matrix-free inputs (nothing f64-exact to verify against)."""
        a = self._verify_matrix()
        if a is None:
            return None
        x = np.asarray(res.eigenvectors, dtype=np.float64)
        lam = np.asarray(res.eigenvalues, dtype=np.float64)
        r = a @ x - x * lam
        # Columns are unit-norm up to policy rounding; no normalization by
        # ||x|| — the same convention as the Ritz bound the flags use.
        return np.linalg.norm(r, axis=0) / np.maximum(np.abs(lam), 1e-300)

    def _verify_matrix(self):
        """f64 host copy of the matrix used by the auto ladder's residual
        verification; built once per session (every rung of every auto query
        reuses it — escalation pays solves, not O(nnz) rebuilds) and dropped
        when the cache snapshots the host data (``_own_data``)."""
        if self._verify_a is None:
            if isinstance(self.csr, DiskCSR) and (
                self.csr.nbytes_on_disk() > _DISK_VERIFY_MAX_BYTES
            ):
                # Too big to materialize: verification must not defeat the
                # out-of-core budget — the ladder falls back to Ritz bounds.
                return None
            if self.csr is not None:
                import scipy.sparse as sp

                self._verify_a = sp.csr_matrix(
                    (
                        np.asarray(self.csr.data, dtype=np.float64),
                        np.asarray(self.csr.indices),
                        np.asarray(self.csr.indptr),
                    ),
                    shape=self.csr.shape,
                )
            elif self._dense is not None:
                self._verify_a = np.asarray(self._dense, dtype=np.float64)
        return self._verify_a

    def _nnz_estimate(self) -> int:
        """Matrix work per matvec for the precision audit: nnz for explicit
        sparse inputs, n^2 for dense, n for matrix-free (a black-box matvec
        is charged as one pass over the vector)."""
        if self.csr is not None:
            return int(self.csr.nnz)
        if self._dense is not None:
            return int(self.n) * int(self.n)
        return int(self.n)

    def _solve_group(self, group: List[_NormQuery]):
        if group[0].recovery == "auto":
            return self._solve_group_recovering(group)
        return self._solve_group_inner(group)

    def _solve_group_inner(self, group: List[_NormQuery], fused_pin: Optional[bool] = None):
        backend, pol = group[0].backend, group[0].pol
        prep, built = self._ensure(backend, pol)
        if not built:
            self.stats["cache_hits"] += 1
        starts: "OrderedDict[str, List[_NormQuery]]" = OrderedDict()
        for q in group:
            starts.setdefault(q.start_key, []).append(q)
        if backend == "restarted":
            return self._run_restarted(starts, prep, built)
        if backend == "distributed":
            return self._run_distributed(starts, prep, built)
        return self._run_fixed(starts, prep, built, backend, fused_pin=fused_pin)

    def _solve_group_recovering(self, group: List[_NormQuery]):
        """``recovery="auto"``: run the group, catching in-solve failures and
        escalating along the documented axes — re-seed the start vector on a
        lucky breakdown (beta underflow: the Krylov space closed early, a
        different start almost surely escapes), one precision rung up on
        overflow/NaN (:func:`auto_ladder` order), fused->unfused on kernel
        lowering/execution errors, single->chunked on device OOM.  Every
        action is appended to a trail that rides out on the results as
        ``recovery_trail``; an unrecoverable (or exhausted) failure re-raises
        the original error with the trail attached when it is a
        :class:`NumericalBreakdown`."""
        trail: List[dict] = []
        qs = list(group)
        fused_pin: Optional[bool] = None
        reseeds = 0
        last_exc: Optional[BaseException] = None
        for attempt in range(_MAX_RECOVERY_ATTEMPTS):
            try:
                out = self._solve_group_inner(qs, fused_pin=fused_pin)
            except Exception as exc:
                last_exc = exc
                action = _classify_failure(exc)
                if action is None:
                    raise self._attach_trail(exc, trail)
                entry = {
                    "action": action,
                    "error": f"{type(exc).__name__}: {exc}",
                    "attempt": attempt,
                }
                if isinstance(exc, NumericalBreakdown):
                    entry["kind"] = exc.kind
                    entry["iteration"] = exc.iteration
                if action == "reseed":
                    if reseeds >= _MAX_RESEEDS:
                        raise self._attach_trail(exc, trail)
                    reseeds += 1
                    seed2 = qs[0].seed + 1000 + attempt
                    entry["from"] = qs[0].start_key
                    entry["to"] = f"seed:{seed2}"
                    qs = [
                        q._replace(seed=seed2, v0=None, start_key=f"seed:{seed2}")
                        for q in qs
                    ]
                elif action == "escalate_policy":
                    nxt = _next_rung(qs[0].pol)
                    if nxt is None:  # already at the ladder top
                        raise self._attach_trail(exc, trail)
                    entry["from"] = qs[0].pol.name
                    entry["to"] = nxt.name
                    qs = [q._replace(pol=nxt, pkey=policy_key(nxt)) for q in qs]
                elif action == "unfuse":
                    if fused_pin is False or qs[0].backend == "distributed":
                        raise self._attach_trail(exc, trail)
                    entry["from"] = "fused"
                    entry["to"] = "unfused"
                    fused_pin = False
                elif action == "fallback_chunked":
                    if qs[0].backend == "chunked" or self.csr is None:
                        raise self._attach_trail(exc, trail)
                    entry["from"] = qs[0].backend
                    entry["to"] = "chunked"
                    qs = [q._replace(backend="chunked") for q in qs]
                trail.append(entry)
                self.stats["recoveries"] = self.stats.get("recoveries", 0) + 1
                continue
            if trail:
                out = [
                    (idx, dataclasses.replace(res, recovery_trail=list(trail)))
                    for idx, res in out
                ]
            return out
        raise self._attach_trail(last_exc, trail)

    @staticmethod
    def _attach_trail(exc, trail):
        if isinstance(exc, NumericalBreakdown) and trail:
            exc.recovery_trail = list(trail)
        return exc

    def _finish(
        self,
        q: _NormQuery,
        prep: _Prepared,
        built: bool,
        *,
        eigenvalues,
        eigenvectors,
        residuals,
        evals_f64,
        iterations,
        restarts,
        timings,
        partition,
        spmv_format,
        tridiag,
        group_size,
    ) -> Tuple[int, EigenResult]:
        # Judge convergence on the engines' full-precision eigenvalues so the
        # flags agree with the restarted engine's own stopping decision (the
        # output-dtype cast could flip a boundary pair).
        lam = np.abs(np.asarray(evals_f64, dtype=np.float64))
        converged = np.asarray(residuals) <= q.tol_eff * np.maximum(lam, 1e-300)
        t = dict(timings)
        solve_s = float(t.get("total_s", 0.0))
        t["solve_s"] = solve_s
        # A building call also claims the session's so-far-unattributed init
        # (coercion/fingerprint) cost, so first-call totals cover real wall
        # time; pure executes report 0.
        t["prepare_s"] = (prep.build_s + self._claim_init_s()) if built else 0.0
        t["total_s"] = t["prepare_s"] + solve_s
        if group_size > 1:
            t["amortized_over"] = float(group_size)
        part = dict(partition) if partition else {}
        spmv = dict(part.get("spmv", {}))
        if not spmv:
            if prep.engine is not None:
                spmv = prep.engine.describe()
            else:
                fmt0 = spmv_format[0] if isinstance(spmv_format, tuple) else spmv_format
                spmv = {"format": fmt0}
        # The reuse contract, verified: what THIS call actually paid.
        spmv["conversions"] = prep.conversions if built else 0
        spmv["tuner_probes"] = prep.tuner_probes if built else 0
        spmv["reused"] = not built
        # Iteration-plan provenance: what the tuner (or mode table) chose,
        # plus the update mode this query's policy actually allows — the
        # policy gate can demote a fused plan (compensated / phase splits).
        iter_plan = getattr(prep.engine, "iteration_plan", None)
        if spmv.get("iteration_plan") or iter_plan is not None:
            from ..core.lanczos import resolve_update_mode

            rec = dict(spmv.get("iteration_plan") or iter_plan.as_dict())
            rec["effective"] = resolve_update_mode(q.pol, plan=iter_plan)
            spmv["iteration_plan"] = rec
        # Per-phase precision audit: the phase map this solve executed and a
        # model-based count of element ops per dtype (how the "this split
        # reduced f64 work" claim is verified — see precision.phase_op_counts).
        spmv["precision"] = {
            "policy": q.pol.name,
            "phase_map": q.pol.phase_map(),
            "compensated": bool(q.pol.compensated),
            "uniform": q.pol.is_uniform(),
            "ops_by_dtype": phase_op_counts(
                q.pol,
                n=self.n,
                nnz=self._nnz_estimate(),
                m=int(iterations),
                k=q.k,
                reorth=q.reorth,
            ),
        }
        # Jaxpr-measured counterpart (repro.analysis P004 ground truth):
        # traces the session's own operator — no execution, no data copies —
        # so it is opt-in; a trace failure degrades to an error note, never
        # a failed solve.
        if envcfg.get_bool("REPRO_PRECISION_MEASURE"):
            if prep.operator is None:
                spmv["precision"]["ops_by_dtype_measured"] = {
                    "error": "no single-device operator to trace (distributed plan)"
                }
            else:
                try:
                    from ..analysis.precision_flow import measure_session_ops

                    spmv["precision"]["ops_by_dtype_measured"] = measure_session_ops(
                        q.pol,
                        prep.operator,
                        backend=q.backend,
                        m=max(int(iterations), 1),
                        k=q.k,
                        reorth=q.reorth,
                        jacobi=q.jacobi,
                    )
                except Exception as exc:  # pragma: no cover - defensive
                    spmv["precision"]["ops_by_dtype_measured"] = {"error": str(exc)}
        part["spmv"] = spmv
        res = EigenResult(
            eigenvalues=eigenvalues,
            eigenvectors=eigenvectors,
            residuals=np.asarray(residuals, dtype=np.float64),
            converged=converged,
            iterations=int(iterations),
            restarts=int(restarts),
            k=q.k,
            n=self.n,
            backend=q.backend,
            policy=q.pol.name,
            tol=q.tol_eff,
            num_devices=self.device_count if q.backend == "distributed" else 1,
            partition=part,
            timings=t,
            spmv_format=spmv_format,
            tridiag=tridiag,
            session_reuse=not built,
        )
        return q.idx, res

    def _chunked_partition(self, prep: _Prepared, staging_before: dict) -> dict:
        op = prep.operator
        staging = op.staging_stats()
        # transfers / bytes / stage seconds are per-call costs (the
        # operator's counters are cumulative across a reused session's
        # queries); conversions stays the one-time build count and
        # max_resident the residency bound — both are invariants of the
        # plan, not per-call costs.  Bandwidth and compression are derived
        # from the per-call deltas.
        for key in ("transfers", "bytes_staged", "bytes_plain", "stage_s"):
            staging[key] = staging[key] - staging_before.get(key, 0)
        staging["effective_bandwidth_gbps"] = (
            staging["bytes_plain"] / staging["stage_s"] / 1e9
            if staging["stage_s"] > 0
            else 0.0
        )
        staging["compression_ratio"] = (
            staging["bytes_plain"] / staging["bytes_staged"]
            if staging["bytes_staged"]
            else 1.0
        )
        spmv = op.engine.describe() if op.engine is not None else {"format": "coo"}
        spmv["staging"] = staging  # ISSUE contract: partition["spmv"]["staging"]
        return {
            "num_chunks": op.num_chunks,
            "stage_depth": op.stage_depth,
            "disk_backed": bool(getattr(op, "disk_backed", False)),
            "staging": staging,  # legacy location, kept for existing readers
            "spmv": spmv,
        }

    def _solve_checkpoint(self, q: _NormQuery, pol, backend: str, k: int, m: int):
        """(store, token) for this sweep's snapshots, or None when solve
        checkpointing is off.  The token hashes the matrix fingerprint plus
        every parameter that shapes the trajectory — budget knobs
        (max_restarts, the chunked loop's snapshot period) stay out so an
        interrupted run relaunched with a different budget still resumes."""
        if q.ckpt_dir is None:
            return None
        from ..serving.store import SolveCheckpoint

        store = SolveCheckpoint(q.ckpt_dir)
        token = SolveCheckpoint.token(
            self.ensure_fingerprint(),
            backend=backend,
            policy=pol.name,
            k=k,
            m=m,
            start=q.start_key,
            tol=q.tol_eff,
            reorth=q.reorth,
        )
        return store, token

    def _run_fixed(
        self,
        starts,
        prep: _Prepared,
        built: bool,
        backend: str,
        fused_pin: Optional[bool] = None,
    ):
        out = []
        pol = next(iter(starts.values()))[0].pol
        all_qs = [q for qs in starts.values() for q in qs]
        reorth, jacobi = all_qs[0].reorth, all_qs[0].jacobi
        if len(starts) > 1 and self._vmappable(prep):
            out.extend(self._run_fixed_multistart(starts, prep, built))
            return out
        for qs in starts.values():
            k_max = max(q.k for q in qs)
            m = max(q.m for q in qs)
            staging0 = dict(prep.operator.staging) if backend == "chunked" else {}
            ckpt = None
            if backend == "chunked":  # only the host loop can snapshot
                pair = self._solve_checkpoint(qs[0], pol, backend, k_max, m)
                if pair is not None:
                    # 4th element: the operator itself, so the host loop can
                    # checkpoint/restore the chunk cursor *inside* a step.
                    ckpt = (*pair, qs[0].ckpt_every, prep.operator)
            sweep = solve_fixed(
                prep.operator,
                k_max,
                policy=pol,
                reorth=reorth,
                num_iters=m,
                v1=qs[0].v0,
                seed=qs[0].seed,
                jacobi=jacobi,
                ops=prep.ops_for(pol, fused=fused_pin),
                probe=qs[0].recovery != "none",
                checkpoint=ckpt,
            )
            self.stats["sweeps"] += 1
            partition = (
                self._chunked_partition(prep, staging0) if backend == "chunked" else {}
            )
            for q in qs:
                out.append(
                    self._finish(
                        q,
                        prep,
                        built,
                        eigenvalues=sweep.eigenvalues[: q.k],
                        eigenvectors=sweep.eigenvectors[:, : q.k],
                        residuals=sweep.residuals[: q.k],
                        evals_f64=sweep.eigenvalues_f64[: q.k],
                        iterations=sweep.iterations,
                        restarts=0,
                        timings=sweep.timings,
                        partition=partition,
                        spmv_format=prep.spmv_format,
                        tridiag=sweep.tridiag,
                        group_size=len(qs),
                    )
                )
        return out

    def _vmappable(self, prep: _Prepared) -> bool:
        """Is this operator's matvec safe under ``jax.vmap``?  Dense matmul
        and the COO ``segment_sum`` path batch cleanly; the Pallas kernel
        layouts are excluded (their interpret-mode batching rule is
        unvalidated), as is the host-loop chunked operator."""
        op = prep.operator
        if isinstance(op, DenseOperator):
            return True
        if isinstance(op, SparseOperator):
            if op.engine is not None:
                return op.engine.format == "coo"
            return op.impl == "coo"
        return False

    def _run_fixed_multistart(self, starts, prep: _Prepared, built: bool):
        """One vmapped Lanczos sweep over all start vectors of a group."""
        out = []
        all_qs = [q for qs in starts.values() for q in qs]
        pol, reorth, jacobi = all_qs[0].pol, all_qs[0].reorth, all_qs[0].jacobi
        m = max(q.m for q in all_qs)
        v1s = []
        for qs in starts.values():
            q0 = qs[0]
            if q0.v0 is not None:
                v1s.append(jnp.asarray(q0.v0, dtype=pol.compute))
            else:
                v1s.append(
                    jax.random.normal(jax.random.PRNGKey(q0.seed), (self.n,), dtype=pol.compute)
                )
        t0 = time.perf_counter()
        batch = lanczos_tridiag_multi(
            prep.operator.bound_matvec(pol),
            jnp.stack(v1s),
            m,
            pol,
            reorth=reorth,
            ops=prep.ops_for(pol, fused=False),
        )
        batch = jax.tree.map(lambda x: x.block_until_ready(), batch)
        t_lanczos = time.perf_counter() - t0
        self.stats["sweeps"] += 1
        for s, qs in enumerate(starts.values()):
            lres = LanczosResult(
                alpha=batch.alpha[s],
                beta=batch.beta[s],
                basis=batch.basis[s],
                beta_last=batch.beta_last[s],
            )
            t1 = time.perf_counter()
            evals, w, evals_f64, w_f64, beta_m = ritz_decompose(lres, pol, jacobi)
            k_max = max(q.k for q in qs)
            evals_k, x, resid = ritz_extract(lres, evals, w, w_f64, beta_m, k_max, pol)
            t_finish = time.perf_counter() - t1
            timings = {
                "lanczos_s": t_lanczos,  # shared across all starts of the batch
                "jacobi_s": t_finish,
                "total_s": t_lanczos + t_finish,
            }
            for q in qs:
                out.append(
                    self._finish(
                        q,
                        prep,
                        built,
                        eigenvalues=evals_k[: q.k],
                        eigenvectors=x[:, : q.k],
                        residuals=resid[: q.k],
                        evals_f64=evals_f64[: q.k],
                        iterations=m,
                        restarts=0,
                        timings=timings,
                        partition={},
                        spmv_format=prep.spmv_format,
                        tridiag=lres,
                        group_size=len(all_qs),
                    )
                )
        return out

    def _run_restarted(self, starts, prep: _Prepared, built: bool):
        out = []
        for qs in starts.values():
            q0 = qs[0]
            pol = q0.pol
            k_max = max(q.k for q in qs)
            m = max(q.subspace or max(2 * q.k, q.k + 8) for q in qs)
            m = max(m, k_max + 2)
            max_restarts = max(q.max_restarts for q in qs)
            budgets = [q.num_iters for q in qs]
            if all(b is not None for b in budgets):
                # num_iters is a total step budget: the first cycle costs m
                # steps, each further cycle refills m - k rows — take only
                # the cycles that fit entirely (floor), never overshoot.
                budget = max(budgets)
                m = min(m, budget)
                extra = max(0, math.floor((budget - m) / max(m - k_max, 1)))
                max_restarts = min(max_restarts, extra + 1)
            tol_target = min(q.tol_eff for q in qs)
            sweep = solve_restarted(
                prep.operator,
                k_max,
                policy=pol,
                m=m,
                max_restarts=max_restarts,
                tol=tol_target,
                seed=q0.seed,
                v1=q0.v0,
                probe=q0.recovery != "none",
                checkpoint=self._solve_checkpoint(q0, pol, "restarted", k_max, m),
            )
            self.stats["sweeps"] += 1
            for q in qs:
                out.append(
                    self._finish(
                        q,
                        prep,
                        built,
                        eigenvalues=sweep.eigenvalues[: q.k],
                        eigenvectors=sweep.eigenvectors[:, : q.k],
                        residuals=sweep.residuals[: q.k],
                        evals_f64=sweep.eigenvalues_f64[: q.k],
                        iterations=sweep.iterations,
                        restarts=sweep.restarts,
                        timings=sweep.timings,
                        partition={},
                        spmv_format=prep.spmv_format,
                        tridiag=sweep.tridiag,
                        group_size=len(qs),
                    )
                )
        return out

    def _run_distributed(self, starts, prep: _Prepared, built: bool):
        out = []
        mesh = self._mesh_for_solve()
        for qs in starts.values():
            q0 = qs[0]
            k_max = max(q.k for q in qs)
            m = max(q.m for q in qs)
            sweep = solve_sharded(
                self.csr,
                k_max,
                mesh,
                policy=q0.pol,
                reorth=q0.reorth,
                num_iters=m,
                seed=q0.seed,
                axis=self.cfg.axis,
                v1=q0.v0,
                prepared=prep.shards,
                probe=q0.recovery != "none",
            )
            self.stats["sweeps"] += 1
            for q in qs:
                out.append(
                    self._finish(
                        q,
                        prep,
                        built,
                        eigenvalues=sweep.eigenvalues[: q.k],
                        eigenvectors=sweep.eigenvectors[:, : q.k],
                        residuals=sweep.residuals[: q.k],
                        evals_f64=sweep.eigenvalues_f64[: q.k],
                        iterations=sweep.iterations,
                        restarts=0,
                        timings=sweep.timings,
                        partition=sweep.partition,
                        spmv_format=sweep.spmv_format,
                        tridiag=sweep.tridiag,
                        group_size=len(qs),
                    )
                )
        return out


# ------------------------------------------------- plan (de)serialization


def _export_operator(op) -> Optional[Tuple[str, Dict[str, np.ndarray]]]:
    """(container type, host arrays) of a single-placement operator, or None
    when the operator is not persistable (matrix-free / unknown)."""
    from ..sparse.formats import DeviceBSR, DeviceCOO, DeviceELL, DeviceHybrid

    if isinstance(op, DenseOperator):
        return "dense", {"a": np.asarray(op.a)}
    if not isinstance(op, SparseOperator):
        return None
    m = op.mat
    if isinstance(m, DeviceCOO):
        return "coo", {
            "row": np.asarray(m.row),
            "col": np.asarray(m.col),
            "val": np.asarray(m.val),
        }
    if isinstance(m, DeviceELL):
        return "ell", {"val": np.asarray(m.val), "col": np.asarray(m.col)}
    if isinstance(m, DeviceBSR):
        return "bsr", {"val": np.asarray(m.val), "bcol": np.asarray(m.bcol)}
    if isinstance(m, DeviceHybrid):
        return "hybrid", {
            "ell_val": np.asarray(m.ell_val),
            "ell_col": np.asarray(m.ell_col),
            "tail_row": np.asarray(m.tail_row),
            "tail_col": np.asarray(m.tail_col),
            "tail_val": np.asarray(m.tail_val),
        }
    return None


def _import_plan(plan: dict, n: int) -> _Prepared:
    """Rebuild a :class:`_Prepared` from one exported plan record.  Uses the
    plain device-container constructors — never the ``to_device_*``
    converters — so the ``conversion_count()`` audit stays untouched; the
    persisted tiles ride into the engine, so no tuner probes either."""
    from ..kernels.engine import TileConfig
    from ..sparse.formats import DeviceBSR, DeviceCOO, DeviceELL, DeviceHybrid

    dtypes = plan.get("dtypes", {})

    def arr(name):
        a = plan["arrays"][name]
        want = dtypes.get(name)
        return jnp.asarray(a, dtype=jnp.dtype(want)) if want else jnp.asarray(a)

    engine = None
    ecfg = plan.get("engine")
    if ecfg:
        from ..kernels.engine import IterationPlan

        tiles = TileConfig(**{k: int(v) for k, v in ecfg["tiles"].items()})
        iter_plan = None
        ip = ecfg.get("iteration_plan")
        if ip:
            iter_plan = IterationPlan(
                update=ip["update"],
                tiles=TileConfig(
                    block_r=int(ip["block_r"]),
                    block_w=int(ip["block_w"]),
                    block_size=int(ip["block_size"]),
                ),
                source=ip.get("source", "tuned"),
            )
        engine = SpmvEngine(
            format=ecfg["format"],
            accum_dtype=jnp.dtype(ecfg["accum_dtype"]),
            tiles=tiles,
            interpret=bool(ecfg["interpret"]),
            requested=ecfg.get("requested", ecfg["format"]),
            stats=None,
            tiles_from=ecfg.get("tiles_from", "override"),
            iteration_plan=iter_plan,
        )
    ctype = plan["container"]
    if ctype == "dense":
        op: LinearOperator = DenseOperator(arr("a"))
    else:
        if ctype == "coo":
            mat = DeviceCOO(arr("row"), arr("col"), arr("val"), n, n)
        elif ctype == "ell":
            mat = DeviceELL(arr("val"), arr("col"), n, n)
        elif ctype == "bsr":
            mat = DeviceBSR(arr("val"), arr("bcol"), n, n)
        elif ctype == "hybrid":
            mat = DeviceHybrid(
                arr("ell_val"),
                arr("ell_col"),
                arr("tail_row"),
                arr("tail_col"),
                arr("tail_val"),
                n,
                n,
            )
        else:
            raise ValueError(f"unknown persisted container type {ctype!r}")
        op = SparseOperator(mat, impl="engine" if engine is not None else "coo", engine=engine)
    return _Prepared("single", op, None, plan.get("spmv_format"), engine)


# --------------------------------------------------------------- frontends


def prepare(
    A,
    *,
    config: Optional[SolverConfig] = None,
    n: Optional[int] = None,
    mesh=None,
    policy: Union[str, PrecisionPolicy] = "FDF",
    backend: str = "auto",
    format: str = "auto",
    reorth: Optional[str] = None,
    tol: Optional[float] = None,
    num_iters: Optional[int] = None,
    subspace: Optional[int] = None,
    max_restarts: int = 30,
    seed: int = 0,
    chunk_nnz: int = 1 << 20,
    stage_depth: int = 1,
    staging: Optional[str] = None,
    jacobi: str = "host",
    axis: str = "data",
    recovery: Optional[str] = None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 8,
) -> EigenSession:
    """Plan phase of :func:`repro.api.eigsh`: coerce, place, convert, tune —
    once — and return the :class:`EigenSession` that owns the result.

    Arguments mirror :func:`repro.api.eigsh` (minus the per-query ``k`` /
    ``v0``); the solver knobs become the session's per-query *defaults* and
    the layout knobs (``format``, ``backend``, ``chunk_nnz``, ``stage_depth``,
    ``axis``, ``mesh``) decide what gets built.

    The session keeps a reference to the host matrix for lazy per-policy
    builds — do not mutate it in place while holding the session (re-run
    ``prepare`` on changed data; the frontend's cache copies instead).
    """
    cfg = config or SolverConfig(
        policy=policy,
        backend=backend,
        reorth=reorth,
        tol=tol,
        num_iters=num_iters,
        subspace=subspace,
        max_restarts=max_restarts,
        seed=seed,
        format=format,
        chunk_nnz=chunk_nnz,
        stage_depth=stage_depth,
        staging=staging if staging is not None else "f32",
        jacobi=jacobi,
        axis=axis,
        recovery=recovery,
        checkpoint_dir=checkpoint_dir,
        checkpoint_every=checkpoint_every,
    )
    return EigenSession(A, cfg, mesh=mesh, n=n).warmup()


def eigsh_many(A, queries, *, config=None, n=None, mesh=None, **solver_kwargs):
    """Module-level batched solve: ``prepare`` (or hit the session cache),
    then :meth:`EigenSession.eigsh_many`.  ``solver_kwargs`` are the
    :func:`prepare` keywords; queries are dicts / :class:`EigQuery` / ints."""
    cfg = config or SolverConfig(**solver_kwargs)
    session, _ = get_session(A, cfg, mesh=mesh, n=n)
    return session.eigsh_many(queries, defaults=cfg)


# ----------------------------------------------------------- session cache


_SESSION_CACHE: "OrderedDict[str, EigenSession]" = OrderedDict()
_CACHE_LOCK = threading.Lock()  # eigsh() must stay safe to call concurrently


def _cache_limit() -> int:
    try:
        return envcfg.get_int("REPRO_EIGSH_SESSION_CACHE")
    except ValueError:
        return 8


def _cache_budget_bytes() -> int:
    """Byte budget across cached sessions (default 2 GB).  A session whose
    problem data alone exceeds it is never cached — the out-of-core sizes
    the chunked backend exists for must not stay pinned after the call."""
    try:
        return int(envcfg.get_float("REPRO_EIGSH_SESSION_CACHE_MB") * 1e6)
    except ValueError:
        return 2_048_000_000


def _session_key(matrix_fp: str, cfg: SolverConfig, mesh) -> str:
    if mesh is None:
        mesh_part = "mesh:none"
    else:
        ids = [int(d.id) for d in np.asarray(mesh.devices).flat]
        mesh_part = f"mesh:{tuple(mesh.axis_names)}:{ids}"
    # The staging pin rebuilds the chunked operator, so it is part of the
    # session identity — flipping it between calls must not serve the old plan.
    staging_pin = envcfg.raw("REPRO_CHUNK_STAGING") or ""
    return "|".join(
        (
            matrix_fp,
            config_fingerprint(cfg, _LAYOUT_FIELDS),
            mesh_part,
            f"dev{len(jax.devices())}",
            f"staging_pin:{staging_pin}",
        )
    )


def _cache_lookup(key: str) -> Optional[EigenSession]:
    with _CACHE_LOCK:
        hit = _SESSION_CACHE.get(key)
        if hit is not None:
            _SESSION_CACHE.move_to_end(key)
        return hit


def _cache_enforce_budget() -> None:
    """Evict LRU sessions until the cache fits its byte budget.  Called on
    store AND after any lazy per-policy plan build (plans grow a cached
    session's footprint after admission)."""
    budget = _cache_budget_bytes()
    with _CACHE_LOCK:
        while _SESSION_CACHE and (
            sum(s.approx_bytes() for s in _SESSION_CACHE.values()) > budget
        ):
            _SESSION_CACHE.popitem(last=False)


def _cache_store(key: str, session: EigenSession) -> None:
    if session.approx_bytes() > _cache_budget_bytes():
        return  # larger than the whole budget: serve it, don't pin it
    session._own_data()  # cached plans must not alias caller-mutable buffers
    with _CACHE_LOCK:
        _SESSION_CACHE[key] = session
        while len(_SESSION_CACHE) > _cache_limit():
            _SESSION_CACHE.popitem(last=False)
    _cache_enforce_budget()


def get_session(
    A, config: Optional[SolverConfig] = None, *, mesh=None, n: Optional[int] = None
) -> Tuple[EigenSession, bool]:
    """Session for (matrix, layout config): fingerprint-keyed LRU when the
    input has hashable bytes (CSR / scipy / dense), fresh prepare otherwise.

    Returns ``(session, cache_hit)``.  CSR and dense inputs are probed by
    content digest BEFORE any coercion, so a cache hit pays one O(bytes)
    hash and nothing else (no device transfer, no dtype cast); scipy inputs
    pay their one ``tocsr`` copy first (the digest is of the converted CSR).
    The cache holds at most ``REPRO_EIGSH_SESSION_CACHE`` sessions (default
    8; 0 disables) within a ``REPRO_EIGSH_SESSION_CACHE_MB`` byte budget;
    mutating a matrix in place changes its digest, so stale plans are never
    served — byte-identical re-submissions are.
    """
    cfg = config or SolverConfig()
    limit = _cache_limit()
    key = None
    fp = None
    if limit > 0 and (
        isinstance(A, (CSR, np.ndarray, jax.Array, DiskCSR))
        or (isinstance(A, (str, os.PathLike)) and is_diskcsr(A))
    ):
        # Digest-first fast path: a hit must not pay coercion.  (Note: a
        # device-resident jax.Array still pays one device->host read here —
        # the digest is of the host bytes; keep host copies of matrices you
        # re-submit in a hot loop.  Disk-backed inputs probe by the sampled
        # fingerprint — O(1) I/O however large the mapping.)
        fp = matrix_fingerprint(A)
        if fp is not None:
            key = _session_key(fp, cfg, mesh)
            hit = _cache_lookup(key)
            if hit is not None:
                return hit, True
    pol0 = _plan_policy(cfg.policy).effective()
    ci = coerce_input(
        A, n=n, storage_dtype=pol0.storage, fingerprint=fp, want_fingerprint=limit > 0
    )
    if key is None and limit > 0 and ci.fingerprint is not None:
        key = _session_key(ci.fingerprint, cfg, mesh)
        hit = _cache_lookup(key)
        if hit is not None:
            return hit, True
    session = EigenSession(A, cfg, mesh=mesh, n=n, _coerced=ci)
    if key is not None:
        _cache_store(key, session)
    return session, False


def session_cache_clear() -> None:
    """Drop every cached session (frees their device buffers)."""
    with _CACHE_LOCK:
        _SESSION_CACHE.clear()


def session_cache_info() -> dict:
    with _CACHE_LOCK:
        size = len(_SESSION_CACHE)
        total = sum(s.approx_bytes() for s in _SESSION_CACHE.values())
    return {
        "size": size,
        "limit": _cache_limit(),
        "bytes": total,
        "budget_bytes": _cache_budget_bytes(),
    }
