"""The unified solver result type returned by every ``eigsh`` backend.

The paper's transparency argument (one solver, any scale) only survives into
an API if every execution path — single-device, shard_map-distributed,
thick-restarted, chunked out-of-core — reports its outcome in the same
schema.  ``EigenResult`` is that schema: eigenpairs plus the convergence,
precision, placement, and timing facts a caller needs to trust (or retry)
a solve.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import numpy as np

from ..core.lanczos import LanczosResult

__all__ = ["EigenResult"]


@dataclasses.dataclass(frozen=True)
class EigenResult:
    """Result of :func:`repro.api.eigsh`, identical across all backends.

    Supports scipy-style unpacking: ``evals, evecs = eigsh(A, k)``.

    Attributes:
      eigenvalues: (k,) |lambda|-descending, in the policy's output dtype.
      eigenvectors: (n, k) column eigenvectors, same dtype.
      residuals: (k,) float64 Ritz residual bounds ``|beta_m * W[m-1, i]|``
        (an upper estimate of ``||A x_i - lambda_i x_i||``; free — no extra
        SpMV).
      converged: (k,) bool — ``residuals <= tol * |lambda_i|`` under the
        effective tolerance.
      iterations: Lanczos steps actually run (summed across restarts).
      restarts: thick restarts performed (0 for fixed-subspace backends).
      k / n: problem dimensions.
      backend: backend actually executed ("single" | "distributed" |
        "restarted" | "chunked").
      policy: name of the precision policy actually used (after any
        x64-unavailable downgrade, e.g. ``"FDF(x32!)"``).
      tol: the effective relative tolerance convergence was judged against.
      num_devices: devices the solve ran on.
      partition: placement facts, backend-dependent: the distributed backend
        records the row partition (num_shards / n_pad / splits / axis); the
        chunked backend records the chunk stream (num_chunks / stage_depth /
        ``"staging"`` counters: one-time host conversions, cumulative
        device_put transfers, peak device-resident chunks).  Both carry a
        ``"spmv"`` dict with the executed kernel format, tiles, tile
        provenance (``"tiles_from"``: "table" | "tuned" | "override" — the
        autotuner's decision trail), and padding stats.  None on the other
        backends.
      timings: seconds per phase — always contains ``"total_s"``; fixed-m
        backends add ``"lanczos_s"`` / ``"jacobi_s"`` / ``"project_s"``.
      spmv_format: SpMV layout the hot loop executed — "coo" | "ell" | "bsr"
        | "hybrid" (quantile-capped ELL + COO hub tail) for explicit sparse
        inputs ("dense" / "matfree" otherwise).  The distributed backend
        reports one entry per shard (a tuple; shard_map runs one program, so
        entries agree).  This is the outcome of the ``format="auto"``
        selection (see ``repro.kernels.engine``).
      tridiag: raw Lanczos output (alpha / beta / basis), for diagnostics.
    """

    eigenvalues: jax.Array
    eigenvectors: jax.Array
    residuals: np.ndarray
    converged: np.ndarray
    iterations: int
    restarts: int
    k: int
    n: int
    backend: str
    policy: str
    tol: float
    num_devices: int
    partition: Optional[dict]
    timings: Dict[str, float]
    spmv_format: Optional[object] = None  # str, or tuple of str per shard
    tridiag: Optional[LanczosResult] = None

    def __iter__(self):
        # scipy.sparse.linalg.eigsh compatibility: ``w, v = eigsh(A, k)``.
        yield self.eigenvalues
        yield self.eigenvectors

    @property
    def all_converged(self) -> bool:
        return bool(np.all(self.converged))

    @property
    def wall_time_s(self) -> float:
        return float(self.timings.get("total_s", 0.0))

    def summary(self) -> str:
        """One-paragraph human-readable report."""
        lam = np.asarray(self.eigenvalues, dtype=np.float64)
        fmt = self.spmv_format
        if isinstance(fmt, (tuple, list)):
            fmt = fmt[0] if fmt else None
        lines = [
            f"eigsh: k={self.k} n={self.n:,} backend={self.backend} "
            f"policy={self.policy} devices={self.num_devices}"
            + (f" spmv={fmt}" if fmt else ""),
            f"  iterations={self.iterations} restarts={self.restarts} "
            f"tol={self.tol:.1e} converged={int(self.converged.sum())}/{self.k} "
            f"wall={self.wall_time_s:.3f}s",
            f"  |lambda| range [{np.abs(lam).min():.4e}, {np.abs(lam).max():.4e}] "
            f"max residual {self.residuals.max():.2e}",
        ]
        return "\n".join(lines)
