"""The unified solver result type returned by every ``eigsh`` backend.

The paper's transparency argument (one solver, any scale) only survives into
an API if every execution path — single-device, shard_map-distributed,
thick-restarted, chunked out-of-core — reports its outcome in the same
schema.  ``EigenResult`` is that schema: eigenpairs plus the convergence,
precision, placement, and timing facts a caller needs to trust (or retry)
a solve.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.lanczos import LanczosResult

__all__ = ["EigenResult", "with_queue_time"]


def _jsonify(obj):
    """Recursively convert numpy/jax scalars and arrays to JSON-safe types."""
    if isinstance(obj, dict):
        return {str(k): _jsonify(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonify(v) for v in obj]
    if isinstance(obj, (np.bool_, bool)):
        return bool(obj)
    if isinstance(obj, (np.integer, int)):
        return int(obj)
    if isinstance(obj, (np.floating, float)):
        return float(obj)
    if isinstance(obj, (np.ndarray, jax.Array)):
        arr = np.asarray(obj)
        if arr.dtype == np.bool_:
            return arr.tolist()
        if np.issubdtype(arr.dtype, np.integer):
            return arr.astype(np.int64).tolist()  # exact: indices must stay ints
        return arr.astype(np.float64).tolist()
    return obj


@dataclasses.dataclass(frozen=True)
class EigenResult:
    """Result of :func:`repro.api.eigsh`, identical across all backends.

    Supports scipy-style unpacking: ``evals, evecs = eigsh(A, k)``.

    Attributes:
      eigenvalues: (k,) |lambda|-descending, in the policy's output dtype.
      eigenvectors: (n, k) column eigenvectors, same dtype.
      residuals: (k,) float64 Ritz residual bounds ``|beta_m * W[m-1, i]|``
        (an upper estimate of ``||A x_i - lambda_i x_i||``; free — no extra
        SpMV).
      converged: (k,) bool — ``residuals <= tol * |lambda_i|`` under the
        effective tolerance.
      iterations: Lanczos steps actually run (summed across restarts).
      restarts: thick restarts performed (0 for fixed-subspace backends).
      k / n: problem dimensions.
      backend: backend actually executed ("single" | "distributed" |
        "restarted" | "chunked").
      policy: name of the precision policy actually used (after any
        x64-unavailable downgrade, e.g. ``"FDF(x32!)"``).
      tol: the effective relative tolerance convergence was judged against.
      num_devices: devices the solve ran on.
      partition: placement facts, backend-dependent: the distributed backend
        records the row partition (num_shards / n_pad / splits / axis); the
        chunked backend records the chunk stream (num_chunks / stage_depth /
        ``"staging"`` counters: one-time host conversions, THIS call's
        device_put transfers, peak device-resident chunks).  Every backend
        (since the plan/execute split) carries a ``"spmv"`` dict with the
        executed kernel format, tiles, tile provenance (``"tiles_from"``:
        "table" | "tuned" | "override" — the autotuner's decision trail),
        padding stats, and the session-reuse audit (``"conversions"`` /
        ``"tuner_probes"`` this call paid, ``"reused"``).
      timings: seconds per phase — always contains ``"total_s"``, plus the
        plan/execute split ``"prepare_s"`` (what this call spent building
        session state: coercion, conversion, tuning; 0.0 on session reuse)
        and ``"solve_s"`` (the execute phase); fixed-m backends add
        ``"lanczos_s"`` / ``"jacobi_s"`` / ``"project_s"``.  Batched
        ``eigsh_many`` results sharing one sweep also carry
        ``"amortized_over"`` (queries served by these timings).  Results
        returned through the serving scheduler additionally carry the
        queue/solve split: ``"queue_s"`` (submit-to-dispatch wait) and
        ``"e2e_s"`` (``queue_s + total_s``, what the submitter observed) —
        see :func:`with_queue_time`.
      spmv_format: SpMV layout the hot loop executed — "coo" | "ell" | "bsr"
        | "hybrid" (quantile-capped ELL + COO hub tail) for explicit sparse
        inputs ("dense" / "matfree" otherwise).  The distributed backend
        reports one entry per shard (a tuple; shard_map runs one program, so
        entries agree).  This is the outcome of the ``format="auto"``
        selection (see ``repro.kernels.engine``).
      tridiag: raw Lanczos output (alpha / beta / basis), for diagnostics.
      session_reuse: this solve executed against an already-prepared
        :class:`~repro.api.session.EigenSession` — no coercion, format
        conversion, or tile tuning was paid (the counters in
        ``partition["spmv"]`` verify it).
      policy_escalations: ``policy="auto"`` attempt trail — one dict per
        ladder rung tried ({policy, max_residual, tol, converged}, cheapest
        first; the last entry is the policy this result executed).  None for
        explicit-policy solves.  The chosen per-phase dtype map rides in
        ``partition["spmv"]["precision"]["phase_map"]``.
      recovery_trail: ``recovery="auto"`` action trail — one dict per
        recovery action taken before this (successful) attempt:
        ``{action, error, kind, iteration, from, to, attempt}`` where
        ``action`` is "reseed" (lucky breakdown → new start vector),
        "escalate_policy" (overflow → one precision rung up),
        "unfuse" (kernel lowering/execution error → reference recurrence),
        or "fallback_chunked" (device OOM → out-of-core engine).  None when
        the solve succeeded first try or recovery was off.
    """

    eigenvalues: jax.Array
    eigenvectors: jax.Array
    residuals: np.ndarray
    converged: np.ndarray
    iterations: int
    restarts: int
    k: int
    n: int
    backend: str
    policy: str
    tol: float
    num_devices: int
    partition: Optional[dict]
    timings: Dict[str, float]
    spmv_format: Optional[object] = None  # str, or tuple of str per shard
    tridiag: Optional[LanczosResult] = None
    session_reuse: bool = False
    policy_escalations: Optional[list] = None
    recovery_trail: Optional[list] = None

    def __iter__(self):
        # scipy.sparse.linalg.eigsh compatibility: ``w, v = eigsh(A, k)``.
        yield self.eigenvalues
        yield self.eigenvectors

    @property
    def all_converged(self) -> bool:
        return bool(np.all(self.converged))

    @property
    def wall_time_s(self) -> float:
        return float(self.timings.get("total_s", 0.0))

    def to_dict(self) -> dict:
        """JSON-safe dict of the result: arrays become nested lists, with
        their dtypes recorded so :meth:`from_dict` can round-trip them.

        ``tridiag`` (the raw Lanczos basis — large and diagnostic-only) is
        dropped.  ``json.dumps(res.to_dict())`` is valid for every backend,
        which is what serving layers and ``benchmarks/run.py`` persist.
        """
        return {
            "schema": 1,
            "eigenvalues": np.asarray(self.eigenvalues, dtype=np.float64).tolist(),
            "eigenvectors": np.asarray(self.eigenvectors, dtype=np.float64).tolist(),
            "residuals": np.asarray(self.residuals, dtype=np.float64).tolist(),
            "converged": np.asarray(self.converged, dtype=bool).tolist(),
            "dtypes": {
                "eigenvalues": str(np.asarray(self.eigenvalues).dtype),
                "eigenvectors": str(np.asarray(self.eigenvectors).dtype),
            },
            "iterations": int(self.iterations),
            "restarts": int(self.restarts),
            "k": int(self.k),
            "n": int(self.n),
            "backend": self.backend,
            "policy": self.policy,
            "tol": float(self.tol),
            "num_devices": int(self.num_devices),
            "partition": _jsonify(self.partition) if self.partition is not None else None,
            "timings": {k: float(v) for k, v in self.timings.items()},
            "spmv_format": _jsonify(self.spmv_format),
            "session_reuse": bool(self.session_reuse),
            "policy_escalations": _jsonify(self.policy_escalations),
            "recovery_trail": _jsonify(self.recovery_trail),
        }

    @classmethod
    def from_dict(cls, d: dict) -> "EigenResult":
        """Rebuild a result from :meth:`to_dict` output (``tridiag`` is None)."""
        dtypes = d.get("dtypes", {})
        ev_dt = jnp.dtype(dtypes.get("eigenvalues", "float32"))
        x_dt = jnp.dtype(dtypes.get("eigenvectors", "float32"))
        fmt = d.get("spmv_format")
        return cls(
            eigenvalues=jnp.asarray(d["eigenvalues"], dtype=ev_dt),
            eigenvectors=jnp.asarray(d["eigenvectors"], dtype=x_dt),
            residuals=np.asarray(d["residuals"], dtype=np.float64),
            converged=np.asarray(d["converged"], dtype=bool),
            iterations=int(d["iterations"]),
            restarts=int(d["restarts"]),
            k=int(d["k"]),
            n=int(d["n"]),
            backend=d["backend"],
            policy=d["policy"],
            tol=float(d["tol"]),
            num_devices=int(d["num_devices"]),
            partition=d.get("partition"),
            timings=dict(d.get("timings", {})),
            spmv_format=tuple(fmt) if isinstance(fmt, list) else fmt,
            tridiag=None,
            session_reuse=bool(d.get("session_reuse", False)),
            policy_escalations=d.get("policy_escalations"),
            recovery_trail=d.get("recovery_trail"),
        )

    @property
    def queue_s(self) -> float:
        """Seconds this query waited in a serving queue before its solve was
        dispatched (0.0 when the result was not produced by a scheduler)."""
        return float(self.timings.get("queue_s", 0.0))

    def summary(self) -> str:
        """One-paragraph human-readable report."""
        lam = np.asarray(self.eigenvalues, dtype=np.float64)
        fmt = self.spmv_format
        if isinstance(fmt, (tuple, list)):
            fmt = fmt[0] if fmt else None
        lines = [
            f"eigsh: k={self.k} n={self.n:,} backend={self.backend} "
            f"policy={self.policy} devices={self.num_devices}"
            + (f" spmv={fmt}" if fmt else ""),
            f"  iterations={self.iterations} restarts={self.restarts} "
            f"tol={self.tol:.1e} converged={int(self.converged.sum())}/{self.k} "
            f"wall={self.wall_time_s:.3f}s",
            f"  |lambda| range [{np.abs(lam).min():.4e}, {np.abs(lam).max():.4e}] "
            f"max residual {self.residuals.max():.2e}",
        ]
        return "\n".join(lines)


def with_queue_time(res: EigenResult, queue_s: float) -> EigenResult:
    """Stamp the serving queue/solve timing split onto a result.

    Returns a copy whose ``timings`` carry ``"queue_s"`` (seconds between
    submission and dispatch — scheduler wait, not solver work) and
    ``"e2e_s"`` (``queue_s + total_s``: the latency the submitter actually
    observed).  ``"total_s"`` / ``"solve_s"`` / ``"prepare_s"`` keep their
    solver-side meaning, so amortization math on them is unaffected.
    """
    t = dict(res.timings)
    t["queue_s"] = float(queue_s)
    t["e2e_s"] = float(queue_s) + float(t.get("total_s", 0.0))
    return dataclasses.replace(res, timings=t)
