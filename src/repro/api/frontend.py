"""``eigsh`` — the unified SciPy-style frontend over every solver backend.

One call reproduces the paper's transparency claim: the caller hands over a
problem in whatever form it exists (dense array, CSR, scipy sparse, linear
operator, bare matvec) and the frontend coerces it, picks a precision policy,
dispatches to the right execution engine, and reports the outcome in a single
:class:`EigenResult` schema:

    from repro.api import eigsh
    res = eigsh(A, k=8, policy="FDF", tol=1e-7)
    res.eigenvalues, res.residuals, res.converged, res.backend

``num_iters`` and ``tol`` mean the same thing on every backend:

  * ``num_iters`` — total Lanczos steps the solve may spend (the Krylov
    subspace size for fixed-m backends; a step budget across restarts for
    the restarted backend).
  * ``tol`` — relative Ritz residual target ``|beta_m W[m-1,i]| <=
    tol * |lambda_i|``.  Every backend reports per-pair ``residuals`` and
    ``converged`` flags against it; the restarted backend additionally
    iterates until it holds (or the budget runs out).

Since the plan/execute split (``repro.api.session``), ``eigsh`` is a thin
wrapper: ``prepare(A, ...)`` builds an :class:`~repro.api.session.EigenSession`
owning every per-matrix setup product (coerced input, chosen placement,
converted operators, tuned tiles) and the call executes one query against
it.  A fingerprint-keyed cache of recent sessions makes naive repeated
calls on the same matrix hit the prepared path transparently — the second
byte-identical call performs zero format conversions and zero tuner probes
(verified by the counters in ``EigenResult.partition["spmv"]``, flagged by
``EigenResult.session_reuse``).  For many-query workloads, use
:func:`repro.api.prepare` / :func:`repro.api.eigsh_many` directly.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from collections.abc import Mapping
from typing import Optional, Union

import jax.numpy as jnp

from ..core.precision import POLICIES, PrecisionPolicy
from ..kernels.engine import FORMATS
from .result import EigenResult

__all__ = ["SolverConfig", "eigsh", "resolve_policy", "is_auto_policy"]


def is_auto_policy(policy) -> bool:
    """True for the ``policy="auto"`` sentinel: not a resolvable policy but a
    request for the accuracy-driven escalation ladder (see ``eigsh``)."""
    return isinstance(policy, str) and policy.strip().lower() == "auto"


def resolve_policy(policy: Union[str, Mapping, PrecisionPolicy]) -> PrecisionPolicy:
    """Resolve a precision-policy spec to a :class:`PrecisionPolicy`.

    Accepts a name from ``POLICIES`` (case-insensitive: "FDF", "bcf", ...),
    a ``PrecisionPolicy`` instance, or a phase-override mapping
    ``{"base": "FDF", "reorth": "f32", ...}`` (``base`` defaults to "FDF";
    the other keys are per-phase compute dtypes — an unknown phase key is a
    named error listing the valid phases, never a raw ``KeyError``).
    ``"auto"`` is a selection *mode*, not a policy: resolving it is an error
    pointing back at ``eigsh(policy="auto")``.
    """
    if isinstance(policy, PrecisionPolicy):
        return policy
    if isinstance(policy, str):
        if is_auto_policy(policy):
            raise ValueError(
                'policy="auto" is the accuracy-driven selection mode, not a '
                "resolvable policy — pass it to eigsh()/EigenSession.eigsh() "
                "(ideally with tol=) and the solver escalates through "
                "repro.core.precision.auto_ladder()"
            )
        try:
            return POLICIES[policy.strip().upper()]
        except KeyError:
            raise ValueError(
                f"unknown precision policy {policy!r}; known: {sorted(POLICIES)} "
                "(case-insensitive), \"auto\", or a {'base': name, <phase>: dtype} "
                "mapping"
            ) from None
    if isinstance(policy, Mapping):
        spec = dict(policy)
        base = resolve_policy(spec.pop("base", "FDF"))
        # with_phases validates the remaining keys against PHASES by name.
        return base.with_phases(**spec)
    raise TypeError(
        f"policy must be a str, PrecisionPolicy, or phase-override mapping, "
        f"got {type(policy).__name__}"
    )


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """All solver knobs of :func:`eigsh` as one reusable value.

    Useful for sweeping configurations (benchmarks) and for services that
    pin a tuned configuration: ``eigsh(A, k, config=cfg)``.  The subset of
    fields that affects what a session *builds* (``backend``, ``format``,
    ``chunk_nnz``, ``stage_depth``, ``axis``) keys the session cache; the
    rest are per-query defaults.
    """

    policy: Union[str, PrecisionPolicy] = "FDF"
    backend: str = "auto"
    # None = the paper's per-engine default: "half" on the single-device /
    # chunked paths (Alg. 1's parity scheme), "full" on the distributed path
    # (their multi-GPU configuration).
    reorth: Optional[str] = None
    tol: Optional[float] = None
    num_iters: Optional[int] = None
    subspace: Optional[int] = None  # restarted backend: m (defaults to max(2k, k+8))
    max_restarts: int = 30
    seed: int = 0
    # SpMV layout for explicit sparse inputs: "auto" selects COO / ELL /
    # blocked-ELL(BSR) / hybrid(ELL+COO hub split) from matrix statistics
    # (repro.kernels.engine); an explicit value forces it.  The decision
    # lands in EigenResult.spmv_format.
    format: str = "auto"
    chunk_nnz: int = 1 << 20  # chunked backend: device-resident nnz per chunk
    stage_depth: int = 1  # chunked backend: chunks prefetched ahead of compute
    # Chunked backend: how staged ELL chunks travel host -> device.  "f32"
    # ships plain storage-dtype buffers; "bf16"/"fp8" quantize values (with
    # per-row-block scales) and delta-encode columns, decompressed in-kernel
    # (kernels/spmv_ell_packed) for 2-4x effective staging bandwidth; "auto"
    # packs when the policy's storage dtype is already narrow.
    staging: str = "f32"
    jacobi: str = "host"  # phase-2 placement, "host" (paper) or "jax"
    axis: str = "data"  # mesh axis name for the distributed backend
    # Breakdown handling: "raise" (default — the in-loop health probe turns
    # NaN/Inf and beta underflow into a typed NumericalBreakdown), "auto"
    # (probe + escalate: reseed / precision rung up / unfuse / chunked
    # fallback, trail on EigenResult.recovery_trail), or "none" (legacy:
    # probes off, garbage flows through).  Per-query override via
    # eigsh(recovery=...).  Deliberately NOT a _LAYOUT_FIELDS member: it
    # never changes what a session builds.
    recovery: Optional[str] = None
    # Solve checkpointing (restarted + chunked engines): a directory enables
    # periodic snapshots via serving.store.SolveCheckpoint; interrupted
    # solves resume from the last completed restart cycle / step block.
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 8  # chunked host loop: steps between snapshots


def _resolve_reorth(reorth: Optional[str], backend: str) -> str:
    """None -> the paper's configuration for the engine that will run."""
    if reorth is not None:
        return reorth
    return "full" if backend == "distributed" else "half"


def _default_tol(policy: PrecisionPolicy) -> float:
    """Reporting tolerance when the caller didn't give one: sqrt(eps) of the
    compute dtype — the classical 'converged for this arithmetic' line."""
    try:
        return float(math.sqrt(float(jnp.finfo(policy.compute).eps)))
    except (TypeError, ValueError):
        return 1e-6


# Legacy ``impl=`` spellings -> the ``format=`` knob that replaced them.  The
# fixed per-impl operator plumbing below the frontend is gone; these now run
# through the SpmvEngine layer like everything else.
_IMPL_TO_FORMAT = {
    "coo": "coo",
    "ell": "ell",
    "ell_kernel": "ell",
    "bsr_kernel": "bsr",
}


def eigsh(
    A,
    k: int = 6,
    *,
    config: Optional[SolverConfig] = None,
    policy: Union[str, PrecisionPolicy] = "FDF",
    backend: str = "auto",
    reorth: Optional[str] = None,
    tol: Optional[float] = None,
    num_iters: Optional[int] = None,
    v0=None,
    seed: int = 0,
    n: Optional[int] = None,
    subspace: Optional[int] = None,
    max_restarts: int = 30,
    format: str = "auto",
    impl: Optional[str] = None,
    chunk_nnz: int = 1 << 20,
    stage_depth: int = 1,
    staging: str = "f32",
    jacobi: str = "host",
    mesh=None,
    axis: str = "data",
    recovery: Optional[str] = None,
    checkpoint_dir: Optional[str] = None,
    checkpoint_every: int = 8,
) -> EigenResult:
    """Top-K eigenpairs (largest |lambda|) of a symmetric operator.

    Args:
      A: dense array, ``repro.sparse.CSR``, scipy sparse matrix, a
        ``repro.sparse.DiskCSR`` mapping or the path of a ``save_diskcsr``
        directory (out-of-core: the matrix streams from disk and is never
        fully materialized), ``LinearOperator`` (ours or scipy's), or a bare
        matvec callable (then pass ``n=``).
      k: number of eigenpairs.
      config: a :class:`SolverConfig` carrying every solver knob below; when
        given, the individual keyword arguments are ignored (``v0`` / ``n`` /
        ``mesh`` are per-call and always honored).
      policy: precision policy name (see ``repro.core.POLICIES``,
        case-insensitive), a ``PrecisionPolicy`` instance, a phase-override
        mapping ``{"base": "FDF", "reorth": "f32", ...}`` (per-phase compute
        dtypes — see ``repro.core.precision.PHASES``), or ``"auto"``: an
        accuracy-driven selector that probes the escalation ladder
        BFF -> FFF -> FCF (-> FDF -> DDD under x64) cheapest-first and stops
        at the first policy whose measured residuals meet ``tol`` (each
        rung's own default tol when none is given).  The attempt trail is
        returned as ``EigenResult.policy_escalations`` and the chosen phase
        map in ``partition["spmv"]["precision"]``.
      backend: "auto" (dispatch on input size / device count / memory
        pressure — see ``repro.api.dispatch``) or one of "single",
        "distributed", "restarted", "chunked".
      reorth: re-orthogonalization mode ("none" | "half" | "full" | "full2");
        None picks the paper's configuration for the engine that runs
        ("half" single-device/chunked, "full" distributed).  The restarted
        backend always re-orthogonalizes fully (anything else is ignored
        with a warning).
      tol: relative Ritz residual target; selects the restarted backend under
        "auto" and defines the ``converged`` flags everywhere.  When the
        restarted backend runs without an explicit tol, it iterates toward
        the same default the flags are judged against
        (``sqrt(eps(compute))``).
      num_iters: total Lanczos step budget (defaults to ``k`` on fixed-m
        backends, ``subspace + restarts * (subspace - k)`` on restarted).
      v0: optional start vector (length n).
      n: problem size, required only for bare callables.
      subspace: restarted backend's subspace size m.
      max_restarts: restart cap (ignored when ``num_iters`` already caps it).
      format: SpMV layout for explicit sparse matrices — "auto" (default)
        picks COO vs ELL vs blocked-ELL/BSR vs hybrid (quantile-capped ELL
        plus a COO hub tail — how power-law matrices reach the kernel path)
        from cheap row-length and block-density statistics
        (``repro.kernels.engine``); "coo" / "ell" / "bsr" / "hybrid" force
        one.  The kernel formats execute through the Pallas SpMV kernels
        (interpret mode off-TPU); the executed choice is reported as
        ``EigenResult.spmv_format``.  The distributed backend auto-selects
        kernel formats only (pass format="coo" to opt back into
        ``segment_sum``); the chunked backend supports "coo" / "ell".
      impl: DEPRECATED — the legacy fixed SpMV knob now maps onto ``format=``
        ("ell"/"ell_kernel" -> "ell", "bsr_kernel" -> "bsr", "coo" -> "coo")
        with a ``DeprecationWarning``; the per-impl operator plumbing it
        selected is gone.  Pass ``format=`` directly.
      chunk_nnz: chunk size (nnz) for the out-of-core backend.
      stage_depth: out-of-core double buffering — how many chunks the
        chunked backend prefetches (``jax.device_put``) ahead of the chunk
        being computed on; device residency is bounded by ``stage_depth +
        1`` chunks.  0 disables the overlap.  Staging counters are reported
        in ``EigenResult.partition["staging"]``.
      staging: out-of-core staged-chunk encoding — "f32" (plain), "bf16" /
        "fp8" (quantized values + delta-encoded columns, decompressed
        in-kernel; multiplies effective staging bandwidth), or "auto" (pack
        iff the policy's storage dtype is already narrow).  Bytes staged,
        effective bandwidth, and compression ratio are reported in
        ``EigenResult.partition["spmv"]["staging"]``.
      jacobi: phase-2 Jacobi placement ("host" = the paper's, or "jax").
      mesh: optional ``jax.sharding.Mesh``; passing one under
        ``backend="auto"`` is an explicit request for the distributed
        backend (the default mesh is all visible devices on one axis named
        ``axis``).
      recovery: breakdown handling — None/"raise" (default): the health
        probe raises a typed ``NumericalBreakdown`` instead of returning
        NaN eigenpairs; "auto": catch and escalate (re-seed on lucky
        breakdown, one precision rung up on overflow, fused->unfused on
        kernel errors, single->chunked on device OOM) with the action
        trail on ``EigenResult.recovery_trail``; "none": legacy behavior,
        probes off.
      checkpoint_dir: directory for periodic solve snapshots (restarted +
        chunked engines); an interrupted run with the same matrix + solve
        parameters resumes from its last snapshot bit-identically.
      checkpoint_every: chunked host loop — Lanczos steps between snapshots.

    Returns:
      An :class:`EigenResult` with an identical schema on every backend.
      Repeated calls on a byte-identical matrix + layout config reuse the
      cached :class:`~repro.api.session.EigenSession` (``session_reuse`` is
      set, ``timings["prepare_s"]`` drops to 0); see the module docstring.
    """
    if impl is not None:
        warnings.warn(
            "impl= is deprecated and now maps onto format= (impl='ell'/"
            "'ell_kernel' -> format='ell', 'bsr_kernel' -> format='bsr', "
            "'coo' -> format='coo'); the legacy fixed SpMV paths are gone — "
            "pass format= directly",
            DeprecationWarning,
            stacklevel=2,
        )
        mapped = _IMPL_TO_FORMAT.get(impl)
        if mapped is None:
            raise ValueError(
                f"unknown legacy impl {impl!r}; expected one of {sorted(_IMPL_TO_FORMAT)}"
            )
        if format == "auto":
            # impl defaults to None now, so an explicit impl="coo" is a real
            # request for the segment-sum reference path and must pin it.
            format = mapped
    cfg = config or SolverConfig(
        policy=policy,
        backend=backend,
        reorth=reorth,
        tol=tol,
        num_iters=num_iters,
        subspace=subspace,
        max_restarts=max_restarts,
        seed=seed,
        format=format,
        chunk_nnz=chunk_nnz,
        stage_depth=stage_depth,
        staging=staging,
        jacobi=jacobi,
        axis=axis,
        recovery=recovery,
        checkpoint_dir=checkpoint_dir,
        checkpoint_every=checkpoint_every,
    )
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if cfg.format not in ("auto",) + FORMATS:
        raise ValueError(
            f"unknown SpMV format {cfg.format!r}; expected 'auto' or one of {FORMATS}"
        )

    from .session import get_session  # lazy: session imports this module

    session, _hit = get_session(A, cfg, mesh=mesh, n=n)
    # Per-query fields come from THIS call's config — a cached session may
    # have been prepared under different solver defaults.  Routed through
    # eigsh_many(defaults=cfg) so non-query knobs that must bind per call
    # (recovery, checkpoint_dir) resolve against THIS config too.
    from .session import EigQuery

    q = EigQuery(
        k=k,
        policy=cfg.policy,
        tol=cfg.tol,
        num_iters=cfg.num_iters,
        reorth=cfg.reorth,
        v0=v0,
        seed=cfg.seed,
        subspace=cfg.subspace,
        max_restarts=cfg.max_restarts,
        jacobi=cfg.jacobi,
        recovery=cfg.recovery,
    )
    return session.eigsh_many([q], defaults=cfg)[0]
