"""``eigsh`` — the unified SciPy-style frontend over every solver backend.

One call reproduces the paper's transparency claim: the caller hands over a
problem in whatever form it exists (dense array, CSR, scipy sparse, linear
operator, bare matvec) and the frontend coerces it, picks a precision policy,
dispatches to the right execution engine, and reports the outcome in a single
:class:`EigenResult` schema:

    from repro.api import eigsh
    res = eigsh(A, k=8, policy="FDF", tol=1e-7)
    res.eigenvalues, res.residuals, res.converged, res.backend

``num_iters`` and ``tol`` mean the same thing on every backend:

  * ``num_iters`` — total Lanczos steps the solve may spend (the Krylov
    subspace size for fixed-m backends; a step budget across restarts for
    the restarted backend).
  * ``tol`` — relative Ritz residual target ``|beta_m W[m-1,i]| <=
    tol * |lambda_i|``.  Every backend reports per-pair ``residuals`` and
    ``converged`` flags against it; the restarted backend additionally
    iterates until it holds (or the budget runs out).
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.distributed import solve_sharded
from ..core.eigensolver import solve_fixed
from ..core.operators import ChunkedOperator, DenseOperator, make_operator
from ..core.precision import POLICIES, PrecisionPolicy
from ..core.restarted import solve_restarted
from ..kernels.engine import FORMATS, make_engine
from ..sparse.formats import CSR
from .coerce import coerce_input
from .dispatch import select_backend
from .result import EigenResult

__all__ = ["SolverConfig", "eigsh", "resolve_policy"]


def resolve_policy(policy: Union[str, PrecisionPolicy]) -> PrecisionPolicy:
    """Accept a policy name from ``POLICIES`` ("FDF", "BCF", ...) or an instance."""
    if isinstance(policy, PrecisionPolicy):
        return policy
    if isinstance(policy, str):
        try:
            return POLICIES[policy.upper()]
        except KeyError:
            raise ValueError(
                f"unknown precision policy {policy!r}; known: {sorted(POLICIES)}"
            ) from None
    raise TypeError(f"policy must be a str or PrecisionPolicy, got {type(policy).__name__}")


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    """All solver knobs of :func:`eigsh` as one reusable value.

    Useful for sweeping configurations (benchmarks) and for services that
    pin a tuned configuration: ``eigsh(A, k, config=cfg)``.
    """

    policy: Union[str, PrecisionPolicy] = "FDF"
    backend: str = "auto"
    # None = the paper's per-engine default: "half" on the single-device /
    # chunked paths (Alg. 1's parity scheme), "full" on the distributed path
    # (their multi-GPU configuration).
    reorth: Optional[str] = None
    tol: Optional[float] = None
    num_iters: Optional[int] = None
    subspace: Optional[int] = None  # restarted backend: m (defaults to max(2k, k+8))
    max_restarts: int = 30
    seed: int = 0
    # SpMV layout for explicit sparse inputs: "auto" selects COO / ELL /
    # blocked-ELL(BSR) / hybrid(ELL+COO hub split) from matrix statistics
    # (repro.kernels.engine); an explicit value forces it.  The decision
    # lands in EigenResult.spmv_format.
    format: str = "auto"
    impl: str = "coo"  # deprecated fixed SpMV path; use ``format`` instead
    chunk_nnz: int = 1 << 20  # chunked backend: device-resident nnz per chunk
    stage_depth: int = 1  # chunked backend: chunks prefetched ahead of compute
    jacobi: str = "host"  # phase-2 placement, "host" (paper) or "jax"
    axis: str = "data"  # mesh axis name for the distributed backend


def _resolve_reorth(reorth: Optional[str], backend: str) -> str:
    """None -> the paper's configuration for the engine that will run."""
    if reorth is not None:
        return reorth
    return "full" if backend == "distributed" else "half"


def _default_tol(policy: PrecisionPolicy) -> float:
    """Reporting tolerance when the caller didn't give one: sqrt(eps) of the
    compute dtype — the classical 'converged for this arithmetic' line."""
    try:
        return float(math.sqrt(float(jnp.finfo(policy.compute).eps)))
    except (TypeError, ValueError):
        return 1e-6


def eigsh(
    A,
    k: int = 6,
    *,
    config: Optional[SolverConfig] = None,
    policy: Union[str, PrecisionPolicy] = "FDF",
    backend: str = "auto",
    reorth: Optional[str] = None,
    tol: Optional[float] = None,
    num_iters: Optional[int] = None,
    v0=None,
    seed: int = 0,
    n: Optional[int] = None,
    subspace: Optional[int] = None,
    max_restarts: int = 30,
    format: str = "auto",
    impl: str = "coo",
    chunk_nnz: int = 1 << 20,
    stage_depth: int = 1,
    jacobi: str = "host",
    mesh=None,
    axis: str = "data",
) -> EigenResult:
    """Top-K eigenpairs (largest |lambda|) of a symmetric operator.

    Args:
      A: dense array, ``repro.sparse.CSR``, scipy sparse matrix,
        ``LinearOperator`` (ours or scipy's), or a bare matvec callable
        (then pass ``n=``).
      k: number of eigenpairs.
      config: a :class:`SolverConfig` carrying every solver knob below; when
        given, the individual keyword arguments are ignored (``v0`` / ``n`` /
        ``mesh`` are per-call and always honored).
      policy: precision policy name (see ``repro.core.POLICIES``) or instance.
      backend: "auto" (dispatch on input size / device count / memory
        pressure — see ``repro.api.dispatch``) or one of "single",
        "distributed", "restarted", "chunked".
      reorth: re-orthogonalization mode ("none" | "half" | "full" | "full2");
        None picks the paper's configuration for the engine that runs
        ("half" single-device/chunked, "full" distributed).  The restarted
        backend always re-orthogonalizes fully (anything else is ignored
        with a warning).
      tol: relative Ritz residual target; selects the restarted backend under
        "auto" and defines the ``converged`` flags everywhere.  When the
        restarted backend runs without an explicit tol, it iterates toward
        the same default the flags are judged against
        (``sqrt(eps(compute))``).
      num_iters: total Lanczos step budget (defaults to ``k`` on fixed-m
        backends, ``subspace + restarts * (subspace - k)`` on restarted).
      v0: optional start vector (length n).
      n: problem size, required only for bare callables.
      subspace: restarted backend's subspace size m.
      max_restarts: restart cap (ignored when ``num_iters`` already caps it).
      format: SpMV layout for explicit sparse matrices — "auto" (default)
        picks COO vs ELL vs blocked-ELL/BSR vs hybrid (quantile-capped ELL
        plus a COO hub tail — how power-law matrices reach the kernel path)
        from cheap row-length and block-density statistics
        (``repro.kernels.engine``); "coo" / "ell" / "bsr" / "hybrid" force
        one.  The kernel formats execute through the Pallas SpMV kernels
        (interpret mode off-TPU); the executed choice is reported as
        ``EigenResult.spmv_format``.  The distributed backend auto-selects
        kernel formats only (pass format="coo" to opt back into
        ``segment_sum``); the chunked backend supports "coo" / "ell".
      impl: deprecated fixed SpMV path ("ell" | "ell_kernel" | "bsr_kernel");
        a non-default value is honored while ``format`` is untouched.  Note
        ``impl="coo"`` is the default and therefore indistinguishable from
        "unset": to pin the COO segment-sum reference path, pass
        ``format="coo"`` instead.
      chunk_nnz: chunk size (nnz) for the out-of-core backend.
      stage_depth: out-of-core double buffering — how many chunks the
        chunked backend prefetches (``jax.device_put``) ahead of the chunk
        being computed on; device residency is bounded by ``stage_depth +
        1`` chunks.  0 disables the overlap.  Staging counters are reported
        in ``EigenResult.partition["staging"]``.
      jacobi: phase-2 Jacobi placement ("host" = the paper's, or "jax").
      mesh: optional ``jax.sharding.Mesh``; passing one under
        ``backend="auto"`` is an explicit request for the distributed
        backend (the default mesh is all visible devices on one axis named
        ``axis``).

    Returns:
      An :class:`EigenResult` with an identical schema on every backend.
    """
    cfg = config or SolverConfig(
        policy=policy,
        backend=backend,
        reorth=reorth,
        tol=tol,
        num_iters=num_iters,
        subspace=subspace,
        max_restarts=max_restarts,
        seed=seed,
        format=format,
        impl=impl,
        chunk_nnz=chunk_nnz,
        stage_depth=stage_depth,
        jacobi=jacobi,
        axis=axis,
    )
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if cfg.format not in ("auto",) + FORMATS:
        raise ValueError(
            f"unknown SpMV format {cfg.format!r}; expected 'auto' or one of {FORMATS}"
        )

    pol = resolve_policy(cfg.policy).effective()
    op, csr, dim = coerce_input(A, n=n, storage_dtype=pol.storage)
    if k > dim:
        raise ValueError(f"k={k} exceeds the operator dimension n={dim}")

    device_count = mesh.size if mesh is not None else len(jax.devices())
    if cfg.backend == "auto" and mesh is not None:
        # An explicit mesh is an explicit request for the distributed path —
        # it must not be silently dropped by the auto policy (e.g. when tol
        # would otherwise pick the restarted engine).
        if csr is None:
            raise ValueError(
                "mesh= requests the distributed backend, which needs a host-side "
                "sparse matrix (repro CSR or scipy sparse) so it can be "
                "re-partitioned; device containers (DeviceCOO/DeviceELL) and "
                "matrix-free operators can't be — pass the host CSR instead"
            )
        chosen = "distributed"
    else:
        chosen = select_backend(
            cfg.backend,
            has_matrix=csr is not None,
            nnz=csr.nnz if csr is not None else 0,
            tol=cfg.tol,
            device_count=device_count,
        )

    # The effective tolerance: what the restarted engine iterates toward and
    # what every backend's converged flags are judged against.
    tol_eff = cfg.tol if cfg.tol is not None else _default_tol(pol)

    if chosen == "distributed":
        out = _run_distributed(csr, k, cfg, pol, mesh, v0)
        restarts, partition = 0, out.partition
        spmv_format = out.spmv_format
    elif chosen == "restarted":
        solver_op, spmv_format = _build_operator(op, csr, cfg, pol, chosen)
        out = _run_restarted(solver_op, k, cfg, pol, v0, tol_eff)
        restarts, partition = out.restarts, None
    else:  # "single" | "chunked"
        solver_op, spmv_format = _build_operator(op, csr, cfg, pol, chosen)
        out = solve_fixed(
            solver_op,
            k,
            policy=pol,
            reorth=_resolve_reorth(cfg.reorth, chosen),
            num_iters=cfg.num_iters,
            v1=v0,
            seed=cfg.seed,
            jacobi=cfg.jacobi,
        )
        restarts, partition = 0, None
        if isinstance(solver_op, ChunkedOperator):
            # Out-of-core placement facts: how the chunk stream behaved.
            partition = {
                "num_chunks": solver_op.num_chunks,
                "stage_depth": solver_op.stage_depth,
                "staging": dict(solver_op.staging),
                "spmv": (
                    solver_op.engine.describe()
                    if solver_op.engine is not None
                    else {"format": "coo"}
                ),
            }

    # Judge convergence on the engines' full-precision eigenvalues so the
    # flags agree with the restarted engine's own stopping decision (the
    # output-dtype cast could flip a boundary pair).
    lam = np.abs(out.eigenvalues_f64)
    converged = out.residuals <= tol_eff * np.maximum(lam, 1e-300)

    return EigenResult(
        eigenvalues=out.eigenvalues,
        eigenvectors=out.eigenvectors,
        residuals=out.residuals,
        converged=converged,
        iterations=out.iterations,
        restarts=restarts,
        k=k,
        n=dim,
        backend=chosen,
        policy=pol.name,
        tol=tol_eff,
        num_devices=device_count if chosen == "distributed" else 1,
        partition=partition,
        timings=out.timings,
        spmv_format=spmv_format,
        tridiag=out.tridiag,
    )


def _op_format(op) -> str:
    """SpMV layout label of a caller-provided operator."""
    fmt = getattr(op, "spmv_format", None)
    if fmt is not None:
        return fmt
    if isinstance(op, DenseOperator):
        return "dense"
    return "matfree"


def _build_operator(op, csr: Optional[CSR], cfg: SolverConfig, pol, backend: str):
    """Resolve (solver operator, spmv_format) for the non-distributed engines.

    Explicit sparse inputs go through the :class:`SpmvEngine` layer — the
    format knob (or its auto-selector) decides COO vs ELL vs BSR and the
    kernel tiles; caller-provided operators are used as-is.
    """
    if backend == "chunked":
        fmt = cfg.format if cfg.format != "auto" else "ell"
        # Build the ELL engine first even under "auto": its tiles determine
        # the per-chunk row padding, which the selection below must charge.
        engine = make_engine(
            csr,
            fmt,
            accum_dtype=pol.compute,
            allowed=("coo", "ell"),  # per-chunk BSR/hybrid staging not implemented
            storage_dtype=pol.storage,
        )
        if cfg.format == "auto":
            # The chunked engine stages ELL per chunk at each chunk's OWN
            # 128-aligned max row width, so its ELL eligibility must be
            # judged on that realized layout — the whole-matrix selector's
            # global-max-row overhead would veto exactly the hub matrices
            # the per-chunk split handles (one hub inflates one chunk, not
            # all), while narrow matrices still lose to the 128-lane pad.
            # Memory being the backend's constraint, the padded footprint
            # must also not dwarf the COO triplets it replaces.
            from ..core.operators import chunk_row_bounds, chunk_rows_pad
            from ..kernels.engine import ell_overhead_bound

            row_nnz = csr.row_nnz()
            padded_slots = 0
            for r0, r1 in chunk_row_bounds(csr.indptr, csr.n, cfg.chunk_nnz):
                w = int(row_nnz[r0:r1].max()) if r1 > r0 else 1
                rows_pad = chunk_rows_pad(r1 - r0, engine.tiles.block_r, pol.storage)
                padded_slots += rows_pad * (-(-max(1, w) // 128) * 128)
            nnz = max(1, csr.nnz)
            ell_bytes = padded_slots * (jnp.dtype(pol.storage).itemsize + 4)
            overhead_ok = padded_slots / nnz <= ell_overhead_bound()
            if not (overhead_ok and ell_bytes <= 4 * nnz * 12):
                engine = make_engine(
                    csr,
                    "coo",
                    stats=engine.stats,
                    accum_dtype=pol.compute,
                    storage_dtype=pol.storage,
                )
        chunked = ChunkedOperator(
            csr,
            chunk_nnz=cfg.chunk_nnz,
            dtype=pol.storage,
            engine=engine,
            stage_depth=cfg.stage_depth,
        )
        return chunked, engine.format
    if op is not None:
        return op, _op_format(op)
    if cfg.format == "auto" and cfg.impl != "coo":
        # Back-compat: an explicitly requested legacy impl wins while the
        # format knob is untouched.
        legacy = make_operator(csr, cfg.impl, dtype=pol.storage)
        return legacy, legacy.spmv_format
    engine = make_engine(
        csr, cfg.format, accum_dtype=pol.compute, storage_dtype=pol.storage
    )
    return make_operator(csr, dtype=pol.storage, engine=engine), engine.format


def _run_restarted(op, k: int, cfg: SolverConfig, pol, v0, tol: float):
    if cfg.reorth not in (None, "full"):
        warnings.warn(
            f"reorth={cfg.reorth!r} is ignored by the restarted backend: thick "
            "restart requires full re-orthogonalization to keep the locked "
            "Ritz block orthogonal",
            stacklevel=3,
        )
    m = cfg.subspace or max(2 * k, k + 8)
    max_restarts = cfg.max_restarts
    if cfg.num_iters is not None:
        # num_iters is a total step budget: the first cycle costs m steps,
        # each further cycle refills m - k rows — take only the cycles that
        # fit entirely (floor), never overshoot the stated budget.
        if cfg.num_iters < k + 2:
            raise ValueError(
                f"num_iters={cfg.num_iters} cannot fund a restarted solve for "
                f"k={k} (the subspace needs at least k + 2 = {k + 2} steps); "
                "raise num_iters or use backend='single'"
            )
        m = min(m, cfg.num_iters)
        extra_cycles = max(0, math.floor((cfg.num_iters - m) / max(m - k, 1)))
        max_restarts = min(max_restarts, extra_cycles + 1)
    return solve_restarted(
        op,
        k,
        policy=pol,
        m=m,
        max_restarts=max_restarts,
        tol=tol,
        seed=cfg.seed,
        v1=v0,
    )


def _run_distributed(csr: Optional[CSR], k: int, cfg: SolverConfig, pol, mesh, v0):
    from jax.sharding import Mesh

    if mesh is None:
        devs = np.array(jax.devices())
        mesh = Mesh(devs.reshape(len(devs)), (cfg.axis,))
    return solve_sharded(
        csr,
        k,
        mesh,
        policy=pol,
        reorth=_resolve_reorth(cfg.reorth, "distributed"),
        num_iters=cfg.num_iters,
        seed=cfg.seed,
        axis=cfg.axis,
        v1=v0,
        spmv_format=cfg.format,
    )
