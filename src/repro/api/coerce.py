"""Input coercion for the ``eigsh`` frontend.

Accepted problem descriptions, mirroring scipy/CoLA's dispatching frontends:

  * dense arrays (NumPy / JAX), square symmetric;
  * our host-side :class:`repro.sparse.CSR`;
  * any scipy sparse matrix/array (converted to CSR once, host-side);
  * device sparse containers (:class:`DeviceCOO` / :class:`DeviceELL`);
  * our :class:`LinearOperator` subclasses (incl. :class:`HvpOperator`);
  * scipy ``LinearOperator``s and bare matvec callables (``n=`` required
    for callables without a ``.shape``).

Coercion returns *both* an operator (when the input is already actionable)
and the host CSR (when the input is an explicit sparse matrix) — the CSR is
what makes the distributed and chunked backends possible, so it is kept
whenever the input provides it.
"""

from __future__ import annotations

import hashlib
import os
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import env as envcfg
from ..core.operators import (
    CallableOperator,
    DenseOperator,
    LinearOperator,
    SparseOperator,
)
from ..sparse.diskcsr import DiskCSR, diskcsr_fingerprint, is_diskcsr, open_diskcsr
from ..sparse.formats import CSR, DeviceCOO, DeviceELL

__all__ = ["CoercedInput", "coerce_input", "matrix_fingerprint"]


class CoercedInput(NamedTuple):
    operator: Optional[LinearOperator]  # None when only a host CSR was given
    csr: Optional[CSR]  # None for matrix-free / device-resident inputs
    n: int
    # Content digest of the problem data (CSR arrays or dense bytes), the
    # matrix half of the session-cache key (api/session.py); None for
    # matrix-free / device-resident inputs, which cannot be fingerprinted.
    fingerprint: Optional[str] = None


def matrix_fingerprint(a) -> Optional[str]:
    """xxhash-style content digest of an explicit matrix (CSR or dense).

    Hashes the raw buffers (indptr / indices / data + shape for CSR; the
    array bytes + dtype for dense), so mutating a matrix in place yields a
    different digest — the session cache treats it as a new problem — while
    a byte-identical re-submission hits.  O(nnz) blake2b: orders of
    magnitude cheaper than one format conversion.
    """
    # Disk-backed inputs get the *sampled* fingerprint: hashing the full
    # payload of an out-of-core matrix would read the whole file back in.
    if isinstance(a, DiskCSR):
        return diskcsr_fingerprint(a.path)
    if isinstance(a, (str, os.PathLike)) and is_diskcsr(a):
        return diskcsr_fingerprint(a)
    h = hashlib.blake2b(digest_size=16)
    if isinstance(a, CSR):
        h.update(b"csr")
        h.update(np.ascontiguousarray(a.indptr).tobytes())
        h.update(np.ascontiguousarray(a.indices).tobytes())
        h.update(np.ascontiguousarray(a.data).tobytes())
        h.update(repr(a.shape).encode())
        return h.hexdigest()
    if isinstance(a, (np.ndarray, jax.Array)):
        arr = np.asarray(a)
        h.update(b"dense")
        h.update(str(arr.dtype).encode())
        h.update(repr(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
        return h.hexdigest()
    return None


def _validate_values(data, storage_dtype, what: str) -> None:
    """Fail fast on inputs no solve can survive: NaN/Inf entries, or a value
    range the requested storage dtype cannot represent finitely.

    Catching this at ``prepare()``/submit time turns a confusing mid-solve
    ``NumericalBreakdown`` (or silently-Inf bf16 cast) into a named
    ``ValueError`` at the call that introduced the bad data.  O(nnz) host
    scan, paid once per session build — never per solve.
    ``REPRO_VALIDATE_INPUT=0`` is the kill switch.
    """
    if not envcfg.get_bool("REPRO_VALIDATE_INPUT"):
        return
    arr = np.asarray(data)
    if not np.issubdtype(arr.dtype, np.floating):
        return
    finite = np.isfinite(arr)
    if not finite.all():
        bad = int(arr.size - np.count_nonzero(finite))
        raise ValueError(
            f"input matrix contains {bad} non-finite value(s) in its {what}; "
            "eigsh requires finite input — mask or clean the data before "
            "prepare()/submit (set REPRO_VALIDATE_INPUT=0 to bypass)"
        )
    try:
        limit = float(jnp.finfo(storage_dtype).max)
    except (TypeError, ValueError):
        return
    peak = float(np.max(np.abs(arr))) if arr.size else 0.0
    if peak > limit:
        raise ValueError(
            f"input matrix peak magnitude {peak:.3e} overflows the requested "
            f"storage dtype {jnp.dtype(storage_dtype).name} "
            f"(finite max {limit:.3e}): this dtype combination is not "
            "finite-safe — rescale the matrix or pick a wider storage policy "
            "(set REPRO_VALIDATE_INPUT=0 to bypass)"
        )


def _csr_from_scipy(a) -> CSR:
    m = a.tocsr()
    m.sort_indices()
    if m.shape[0] != m.shape[1]:
        raise ValueError(f"eigsh needs a square matrix, got shape {m.shape}")
    return CSR(
        indptr=np.asarray(m.indptr, dtype=np.int64),
        indices=np.asarray(m.indices, dtype=np.int32),
        data=np.asarray(m.data, dtype=np.float64),
        shape=(m.shape[0], m.shape[1]),
    )


def coerce_input(
    a,
    *,
    n: Optional[int] = None,
    storage_dtype=jnp.float32,
    fingerprint: Optional[str] = None,
    want_fingerprint: bool = False,
) -> CoercedInput:
    """Normalize any accepted input into (operator, csr, n). See module doc.

    Fingerprinting is opt-in: pass ``fingerprint=`` when the digest is
    already computed (the session cache probes CSR/dense inputs before
    coercing), or ``want_fingerprint=True`` to have it computed here (the
    scipy path, whose digest is of the converted CSR).  The default skips
    the O(bytes) hash — direct ``prepare()`` sessions and cache-disabled
    calls never pay for a digest they will not use.
    """
    if isinstance(a, LinearOperator):
        return CoercedInput(operator=a, csr=None, n=int(a.n))

    def _fp(x):
        if fingerprint is not None:
            return fingerprint
        return matrix_fingerprint(x) if want_fingerprint else None

    if isinstance(a, CSR):
        _validate_values(a.data, storage_dtype, "CSR data")
        return CoercedInput(operator=None, csr=a, n=a.n, fingerprint=_fp(a))

    # Disk-native path: a diskcsr directory (str/PathLike) or an already-open
    # DiskCSR.  The mapping duck-types CSR's cheap surface, so it flows into
    # chunk planning unchanged — value validation is deliberately skipped
    # here: a full finite-scan would fault in the entire on-disk payload,
    # the exact thing the out-of-core path exists to avoid (the chunked
    # solve surfaces non-finite data as a NumericalBreakdown instead).
    if isinstance(a, (str, os.PathLike)):
        a = open_diskcsr(a)  # raises FileNotFoundError with a hint otherwise
    if isinstance(a, DiskCSR):
        return CoercedInput(operator=None, csr=a, n=a.n, fingerprint=_fp(a))

    if isinstance(a, (DeviceCOO, DeviceELL)):
        impl = "coo" if isinstance(a, DeviceCOO) else "ell"
        return CoercedInput(
            operator=SparseOperator(a, impl=impl), csr=None, n=int(a.n_rows)
        )

    # scipy sparse (spmatrix or the newer sparray) — duck-typed so scipy
    # stays an optional import.
    if hasattr(a, "tocsr") and hasattr(a, "shape"):
        csr = _csr_from_scipy(a)
        _validate_values(csr.data, storage_dtype, "sparse data")
        return CoercedInput(operator=None, csr=csr, n=csr.n, fingerprint=_fp(csr))

    if isinstance(a, (np.ndarray, jax.Array)):
        if a.ndim != 2 or a.shape[0] != a.shape[1]:
            raise ValueError(f"eigsh needs a square 2-D array, got shape {a.shape}")
        _validate_values(a, storage_dtype, "entries")
        return CoercedInput(
            operator=DenseOperator(jnp.asarray(a, dtype=storage_dtype)),
            csr=None,
            n=int(a.shape[0]),
            fingerprint=_fp(a),
        )

    # scipy.sparse.linalg.LinearOperator look-alikes: .matvec + .shape.
    if hasattr(a, "matvec") and hasattr(a, "shape"):
        dim = int(a.shape[0])
        if a.shape[0] != a.shape[1]:
            raise ValueError(f"eigsh needs a square operator, got shape {a.shape}")
        mv = a.matvec
        return CoercedInput(
            operator=CallableOperator(fn=lambda x: jnp.asarray(mv(np.asarray(x))), n=dim),
            csr=None,
            n=dim,
        )

    if callable(a):
        if n is None:
            raise ValueError(
                "eigsh(matvec_callable, ...) needs the problem size: pass n=<dim>"
            )
        return CoercedInput(operator=CallableOperator(fn=a, n=int(n)), csr=None, n=int(n))

    raise TypeError(
        f"eigsh does not understand input of type {type(a).__name__}: expected a "
        "dense array, CSR, scipy sparse matrix, LinearOperator, or matvec callable"
    )
