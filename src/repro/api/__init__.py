"""Unified solver API — the user-facing frontend of the reproduction.

    from repro.api import eigsh
    res = eigsh(A, k=8, policy="FDF")          # any input form, any backend
    evals, evecs = res                          # scipy-style unpack

See :func:`eigsh` for the full contract, ``dispatch`` for the backend-
selection policy, and :class:`EigenResult` for the result schema.
"""

from .coerce import CoercedInput, coerce_input
from .dispatch import BACKENDS, CHUNKED_NNZ_THRESHOLD, select_backend
from .frontend import SolverConfig, eigsh, resolve_policy
from .result import EigenResult

__all__ = [
    "eigsh",
    "SolverConfig",
    "EigenResult",
    "resolve_policy",
    "select_backend",
    "coerce_input",
    "CoercedInput",
    "BACKENDS",
    "CHUNKED_NNZ_THRESHOLD",
]
