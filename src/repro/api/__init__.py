"""Unified solver API — the user-facing frontend of the reproduction.

    from repro.api import eigsh
    res = eigsh(A, k=8, policy="FDF")          # any input form, any backend
    evals, evecs = res                          # scipy-style unpack

    from repro.api import prepare, eigsh_many   # plan/execute split
    sess = prepare(A)                           # pay setup once
    results = sess.eigsh_many([{"k": 4}, {"k": 8, "tol": 1e-7}])

See :func:`eigsh` for the full contract, ``dispatch`` for the backend-
selection policy, :class:`EigenResult` for the result schema, and
``session`` for the prepared-session / batched-serving layer.
"""

from ..core.lanczos import NumericalBreakdown
from .coerce import CoercedInput, coerce_input, matrix_fingerprint
from .dispatch import BACKENDS, CHUNKED_NNZ_THRESHOLD, select_backend
from .frontend import SolverConfig, eigsh, is_auto_policy, resolve_policy
from .result import EigenResult
from .session import (
    EigQuery,
    EigenSession,
    config_fingerprint,
    eigsh_many,
    prepare,
    session_cache_clear,
    session_cache_info,
)

__all__ = [
    "eigsh",
    "eigsh_many",
    "prepare",
    "EigenSession",
    "EigQuery",
    "SolverConfig",
    "EigenResult",
    "NumericalBreakdown",
    "resolve_policy",
    "is_auto_policy",
    "select_backend",
    "coerce_input",
    "CoercedInput",
    "matrix_fingerprint",
    "config_fingerprint",
    "session_cache_clear",
    "session_cache_info",
    "BACKENDS",
    "CHUNKED_NNZ_THRESHOLD",
]
