"""Backend selection for the ``eigsh`` frontend.

The paper's design goal is a *transparent* solver: the caller states the
problem, the solver decides placement (§III).  ``select_backend`` encodes
that decision as an explicit, testable function of the input:

  1. ``"restarted"``   — a convergence tolerance was requested: fixed-m
     Lanczos cannot promise a residual, thick-restart can, so an explicit
     ``tol`` always wins (use ``backend="distributed"`` explicitly to keep
     the multi-device path; ``tol`` then only defines the converged flags).
  2. ``"distributed"`` — an explicit sparse matrix and >1 visible device:
     the paper's nnz-balanced multi-GPU partition (its headline mode).
  3. ``"chunked"``     — an explicit sparse matrix too large to keep
     device-resident: the paper's out-of-core unified-memory mode.  Triggered
     above ``CHUNKED_NNZ_THRESHOLD`` non-zeros (~25M nnz ≈ 300 MB of COO
     triplets at f32 values) or when the estimated device working set
     exceeds half the free host RAM (this CPU container stands in for HBM).
  4. ``"single"``      — everything else: the paper's single-device pipeline.

Explicit ``backend=`` requests skip the policy but are validated (the
distributed and chunked paths need an explicit sparse matrix).
"""

from __future__ import annotations

import os
from typing import Optional

from ..configs import env as envcfg

__all__ = ["BACKENDS", "CHUNKED_NNZ_THRESHOLD", "select_backend", "host_available_bytes"]

BACKENDS = ("single", "distributed", "restarted", "chunked")

# nnz above which an in-core COO copy (val f32 + row/col i32 = 12 B/nnz) is
# deemed too large to keep device-resident; overridable for experiments.
CHUNKED_NNZ_THRESHOLD = envcfg.get_int("REPRO_EIGSH_CHUNK_NNZ")

_MATRIX_BACKENDS = ("distributed", "chunked")


def host_available_bytes() -> Optional[int]:
    """Free host memory, or None when the platform doesn't expose it."""
    try:
        return os.sysconf("SC_PAGE_SIZE") * os.sysconf("SC_AVPHYS_PAGES")
    except (ValueError, OSError, AttributeError):
        return None


def select_backend(
    requested: str,
    *,
    has_matrix: bool,
    nnz: int = 0,
    tol: Optional[float] = None,
    device_count: int = 1,
    free_bytes: Optional[int] = None,
    mesh_given: bool = False,
    disk_bytes: Optional[int] = None,
) -> str:
    """Resolve ``backend="auto"`` (or validate an explicit request).

    Args:
      requested: "auto" or one of BACKENDS.
      has_matrix: input coerced to an explicit host CSR (disk-backed DiskCSR
        mappings count: they can be re-partitioned/chunked from disk).
      nnz: non-zeros of that CSR (0 for matrix-free inputs).
      tol: requested convergence tolerance (None = fixed-iteration mode).
      device_count: visible (or mesh-provided) device count.
      free_bytes: host-memory budget; defaults to the live reading.
      mesh_given: the caller passed an explicit ``jax.sharding.Mesh`` — under
        "auto" that is an explicit request for the distributed path and must
        not be silently dropped (e.g. when ``tol`` would pick restarted).
      disk_bytes: on-disk payload size of a disk-backed (DiskCSR) input, or
        None for in-RAM inputs.  Under "auto", a disk matrix whose payload
        exceeds half the free host memory MUST stream: every other backend
        would materialize it.
    """
    if requested != "auto":
        if requested not in BACKENDS:
            raise ValueError(f"unknown backend {requested!r}; expected one of {BACKENDS}")
        if requested in _MATRIX_BACKENDS and not has_matrix:
            raise ValueError(
                f"backend={requested!r} needs a host-side sparse matrix (repro "
                "CSR or scipy sparse) so it can be re-partitioned/chunked; "
                "device containers (DeviceCOO/DeviceELL) and matrix-free "
                "operators can't be — pass the host CSR instead"
            )
        return requested

    # Host-memory pressure rule for disk-backed inputs: a mapping bigger than
    # the budget cannot be materialized by ANY other backend, so it overrides
    # even tol/device-count preferences (the chunked engine honors tol=None
    # fixed-m semantics; restarted-on-disk would page-thrash or OOM).
    if disk_bytes is not None and has_matrix:
        free = free_bytes if free_bytes is not None else host_available_bytes()
        if free is None or disk_bytes > free // 2:
            return "chunked"

    if mesh_given:
        if not has_matrix:
            raise ValueError(
                "mesh= requests the distributed backend, which needs a host-side "
                "sparse matrix (repro CSR or scipy sparse) so it can be "
                "re-partitioned; device containers (DeviceCOO/DeviceELL) and "
                "matrix-free operators can't be — pass the host CSR instead"
            )
        return "distributed"

    # A requested tolerance is a convergence *requirement*: only the restarted
    # engine iterates until it holds, so it wins even over multiple devices.
    # (Pass backend="distributed" explicitly to keep the fixed-m multi-device
    # path; tol then only defines the converged flags.)
    if tol is not None:
        return "restarted"
    if has_matrix and device_count > 1:
        return "distributed"
    if has_matrix:
        if nnz >= CHUNKED_NNZ_THRESHOLD:
            return "chunked"
        free = free_bytes if free_bytes is not None else host_available_bytes()
        if free is not None and nnz * 12 > free // 2:
            return "chunked"
    return "single"
