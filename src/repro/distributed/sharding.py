"""Logical-axis sharding rule engine (MaxText-style, with fallbacks).

Every parameter and activation in the model zoo is annotated with *logical*
axis names.  A rule table maps each name to an ordered list of candidate mesh
axes; resolution walks the tensor's axes left-to-right picking the first
candidate whose mesh size divides the dimension AND whose mesh axes are not
already used by this tensor.  This gives graceful degradation on awkward
architectures (e.g. 10 attention heads on a 16-wide model axis -> heads stay
replicated and the engine shards head_dim or the KV sequence instead), which
is what lets one rule table cover all 10 assigned architectures.

The active (mesh, rules) pair is installed via ``sharding_ctx`` by the
launcher / dry-run; with no context, ``hint`` is a no-op so single-device
smoke tests run the exact same model code.
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "DEFAULT_RULES",
    "ShardingCtx",
    "sharding_ctx",
    "current_ctx",
    "logical_spec",
    "hint",
    "named_sharding",
]

Candidate = Optional[Tuple[str, ...]]

# Ordered candidates per logical axis.  None = replicate.
DEFAULT_RULES: Dict[str, List[Candidate]] = {
    # --- activations ---
    "batch": [("pod", "data"), ("data",), None],
    "seq": [None],
    # sequence parallelism: the residual stream at layer boundaries (and thus
    # the remat-saved activation stack) is sharded over 'model'; attention
    # re-gathers inside the layer.  Trades collective bytes for the factor-16
    # activation-memory cut that lets 72B train_4k fit a v5e (EXPERIMENTS §Perf).
    "act_seq": [("model",), None],
    "act_embed": [None],
    "act_heads": [("model",), None],
    "act_kv_heads": [("model",), None],
    "act_mlp": [("model",), None],
    "act_vocab": [("model",), None],
    "act_expert": [("model",), None],
    "cache_seq": [("model",), None],  # KV-cache fallback when heads don't divide
    "act_ssm_inner": [("model",), None],
    # --- parameters (FSDP over 'data', TP over 'model') ---
    "vocab": [("model",), None],
    "embed": [("data",), None],  # FSDP axis
    "heads": [("model",), None],
    "kv_heads": [("model",), None],
    "head_dim": [None],
    "qkv": [("model",), None],  # fused q/k/v output dim
    "mlp": [("model",), None],
    "expert": [("model",), None],
    "moe_mlp": [("model",), None],  # falls back to TP-within-expert (mixtral)
    "conv": [None],
    "lru": [("model",), None],
    "ssm_inner": [("model",), None],
    "ssm_state": [None],
    "ssm_heads": [("model",), None],
    "layers": [None],  # stacked-layer leading axis (scan)
    "stage": [None],  # pipeline stage axis (see launch/pipeline)
}

# Resolution order: higher-priority logical axes claim mesh axes first, so a
# KV-cache (batch, seq, kv_heads, dim) gives 'model' to kv_heads when the
# head count divides, and only otherwise to the cache seq axis.
PRIORITY = {
    "vocab": 10,
    "heads": 10,
    "kv_heads": 10,
    "act_heads": 10,
    "act_kv_heads": 10,
    "expert": 10,
    "act_expert": 10,
    "batch": 9,
    "mlp": 8,
    "act_mlp": 8,
    "moe_mlp": 7,
    "qkv": 8,
    "lru": 8,
    "ssm_inner": 8,
    "act_ssm_inner": 8,
    "ssm_heads": 8,
    "embed": 6,
    "act_seq": 4,
    "cache_seq": 3,
}


@dataclasses.dataclass
class ShardingCtx:
    mesh: Optional[Mesh]
    rules: Dict[str, List[Candidate]]

    def axis_size(self, names: Tuple[str, ...]) -> int:
        return math.prod(self.mesh.shape[n] for n in names)


_STACK: List[ShardingCtx] = []


def current_ctx() -> Optional[ShardingCtx]:
    return _STACK[-1] if _STACK else None


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Optional[Dict] = None):
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    _STACK.append(ShardingCtx(mesh=mesh, rules=merged))
    try:
        yield _STACK[-1]
    finally:
        _STACK.pop()


def logical_spec(
    shape: Sequence[int], axes: Sequence[Optional[str]], ctx: Optional[ShardingCtx] = None
) -> P:
    """Resolve logical axis names to a PartitionSpec for this shape."""
    ctx = ctx or current_ctx()
    if ctx is None or ctx.mesh is None:
        return P()
    assert len(shape) == len(axes), f"shape {shape} vs axes {axes}"
    used: set = set()
    parts: List = [None] * len(shape)
    order = sorted(
        range(len(shape)),
        key=lambda i: -PRIORITY.get(axes[i], 5) if axes[i] is not None else 0,
    )
    for i in order:
        dim, name = shape[i], axes[i]
        if name is None:
            continue
        for cand in ctx.rules.get(name, [None]):
            if cand is None:
                break
            if any(a in used for a in cand):
                continue
            if any(a not in ctx.mesh.shape for a in cand):
                continue
            if dim % ctx.axis_size(cand) == 0:
                used.update(cand)
                parts[i] = cand[0] if len(cand) == 1 else cand
                break
    return P(*parts)


def hint(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh ctx."""
    ctx = current_ctx()
    if ctx is None or ctx.mesh is None:
        return x
    spec = logical_spec(x.shape, axes, ctx)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def named_sharding(
    shape: Sequence[int], axes: Sequence[Optional[str]], ctx: Optional[ShardingCtx] = None
) -> Optional[NamedSharding]:
    ctx = ctx or current_ctx()
    if ctx is None or ctx.mesh is None:
        return None
    return NamedSharding(ctx.mesh, logical_spec(shape, axes, ctx))


def _is_axes_leaf(x) -> bool:
    return x is None or (
        isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)
    )


def tree_shardings(values_tree, axes_tree, ctx: Optional[ShardingCtx] = None):
    """Zip a tree of arrays/SDS with a same-structure tree of logical-axes
    tuples into NamedShardings.

    Axes tuples are themselves pytrees and `()` is both "scalar" and "empty
    container", so leaves are matched by tree *path* rather than position;
    axes entries with no matching value (empty containers, None branches)
    are ignored."""
    ctx = ctx or current_ctx()
    flat_vals, treedef = jax.tree_util.tree_flatten_with_path(values_tree)
    axes_by_path = {
        jax.tree_util.keystr(path): leaf
        for path, leaf in jax.tree_util.tree_flatten_with_path(
            axes_tree, is_leaf=_is_axes_leaf
        )[0]
    }
    out = []
    for path, v in flat_vals:
        a = axes_by_path.get(jax.tree_util.keystr(path))
        a = a if a is not None else (None,) * len(v.shape)
        out.append(named_sharding(v.shape, a, ctx))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(values_tree), out)
