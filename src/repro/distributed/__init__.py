from .sharding import DEFAULT_RULES, hint, logical_spec, named_sharding, sharding_ctx
