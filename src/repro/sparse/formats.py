"""Sparse matrix containers used by the eigensolver.

Host-side construction is NumPy (CSR); device-side compute formats are:

* ``DeviceCOO``  — (row, col, val) triplets, the pure-jnp ``segment_sum`` SpMV
  reference path; also the per-shard format of the distributed solver.
* ``DeviceELL``  — row-tiled ELLPACK (uniform width, padded), the layout the
  Pallas TPU kernel consumes (DESIGN.md §4).

All device containers are registered pytrees so they can cross ``jit`` /
``shard_map`` boundaries.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CSR", "DeviceCOO", "DeviceELL", "csr_from_coo", "to_device_coo", "to_device_ell"]


@dataclasses.dataclass
class CSR:
    """Host-side CSR (NumPy). Always square, symmetric matrices here."""

    indptr: np.ndarray  # (n+1,) int64
    indices: np.ndarray  # (nnz,) int32
    data: np.ndarray  # (nnz,) float64
    shape: Tuple[int, int]

    @property
    def n(self) -> int:
        return self.shape[0]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    def to_scipy(self):
        import scipy.sparse as sp

        return sp.csr_matrix((self.data, self.indices, self.indptr), shape=self.shape)

    def toarray(self) -> np.ndarray:
        return self.to_scipy().toarray()


def csr_from_coo(
    rows: np.ndarray, cols: np.ndarray, vals: np.ndarray, n: int, sum_dups: bool = True
) -> CSR:
    """Build CSR from COO triplets (NumPy), summing duplicates."""
    import scipy.sparse as sp

    m = sp.coo_matrix((vals, (rows, cols)), shape=(n, n))
    if sum_dups:
        m.sum_duplicates()
    m = m.tocsr()
    m.sort_indices()
    return CSR(
        indptr=m.indptr.astype(np.int64),
        indices=m.indices.astype(np.int32),
        data=m.data.astype(np.float64),
        shape=(n, n),
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceCOO:
    """Device COO triplets; SpMV = segment_sum(val * x[col], row)."""

    row: jax.Array  # (nnz,) int32, sorted by row
    col: jax.Array  # (nnz,) int32
    val: jax.Array  # (nnz,) storage dtype
    n_rows: int  # static
    n_cols: int  # static

    def tree_flatten(self):
        return (self.row, self.col, self.val), (self.n_rows, self.n_cols)

    @classmethod
    def tree_unflatten(cls, aux, children):
        row, col, val = children
        return cls(row, col, val, *aux)

    @property
    def nnz(self) -> int:
        return int(self.val.shape[0])

    def matvec(self, x: jax.Array, accum_dtype=None) -> jax.Array:
        """SpMV with accumulation in ``accum_dtype`` (mixed-precision knob)."""
        acc = accum_dtype or self.val.dtype
        prod = self.val.astype(acc) * jnp.take(x, self.col).astype(acc)
        return jax.ops.segment_sum(prod, self.row, num_segments=self.n_rows)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceELL:
    """Uniform-width ELLPACK, row-major, padded.

    ``val[r, s]`` / ``col[r, s]``: s-th stored entry of row r.  Padding slots
    have ``val == 0`` and ``col == 0`` (they contribute 0).  Rows are padded to
    a multiple of ``row_tile`` and the width to a multiple of ``slot_tile`` so
    the Pallas kernel's BlockSpec grid divides evenly.
    """

    val: jax.Array  # (rows_padded, width) storage dtype
    col: jax.Array  # (rows_padded, width) int32
    n_rows: int  # logical rows (static)
    n_cols: int  # static

    def tree_flatten(self):
        return (self.val, self.col), (self.n_rows, self.n_cols)

    @classmethod
    def tree_unflatten(cls, aux, children):
        val, col = children
        return cls(val, col, *aux)

    @property
    def width(self) -> int:
        return int(self.val.shape[1])

    def matvec(self, x: jax.Array, accum_dtype=None) -> jax.Array:
        acc = accum_dtype or self.val.dtype
        gathered = jnp.take(x, self.col).astype(acc)  # (rows_padded, width)
        y = (self.val.astype(acc) * gathered).sum(axis=1)
        return y[: self.n_rows]


def to_device_coo(csr: CSR, dtype=jnp.float32) -> DeviceCOO:
    n = csr.n
    row = np.repeat(np.arange(n, dtype=np.int32), csr.row_nnz())
    return DeviceCOO(
        row=jnp.asarray(row),
        col=jnp.asarray(csr.indices, dtype=jnp.int32),
        val=jnp.asarray(csr.data, dtype=dtype),
        n_rows=n,
        n_cols=n,
    )


def to_device_ell(
    csr: CSR, dtype=jnp.float32, row_tile: int = 8, slot_tile: int = 128
) -> DeviceELL:
    """Convert CSR to uniform-width padded ELL (kernel layout)."""
    n = csr.n
    nnz_per_row = csr.row_nnz()
    width = int(max(1, nnz_per_row.max()))
    width = -(-width // slot_tile) * slot_tile
    rows_pad = -(-n // row_tile) * row_tile

    val = np.zeros((rows_pad, width), dtype=np.float64)
    col = np.zeros((rows_pad, width), dtype=np.int32)
    # Vectorized fill: position of each nnz within its row.
    pos = np.arange(csr.nnz) - np.repeat(csr.indptr[:-1], nnz_per_row)
    rix = np.repeat(np.arange(n), nnz_per_row)
    val[rix, pos] = csr.data
    col[rix, pos] = csr.indices
    return DeviceELL(
        val=jnp.asarray(val, dtype=dtype),
        col=jnp.asarray(col),
        n_rows=n,
        n_cols=n,
    )
