"""Sparse matrix containers used by the eigensolver.

Host-side construction is NumPy (CSR); device-side compute formats are:

* ``DeviceCOO``  — (row, col, val) triplets, the pure-jnp ``segment_sum`` SpMV
  reference path; also the per-shard format of the distributed solver.
* ``DeviceELL``  — row-tiled ELLPACK (uniform width, padded), the layout the
  Pallas TPU kernel consumes (DESIGN.md §4).
* ``DeviceBSR``  — blocked-ELL (uniform block-slots per block-row, padded),
  the MXU-native layout of ``kernels/spmv_bsr.py``.
* ``DeviceHybrid`` — hub-row split: ELL capped at a quantile of the row
  lengths (Pallas kernel part) plus a COO overflow tail (``segment_sum``),
  so power-law matrices reach the kernel path without padding blowup.

All device containers are registered pytrees so they can cross ``jit`` /
``shard_map`` boundaries.  The ``shard_to_*`` converters build *shard-local*
kernel layouts (uniform shapes across shards, columns remapped to the
padded-global coordinates of ``core/partition.py``) so the distributed
engine's hot loop runs the Pallas kernels instead of ``segment_sum``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "CSR",
    "DeviceCOO",
    "DeviceELL",
    "DeviceBSR",
    "DeviceHybrid",
    "csr_from_coo",
    "to_device_coo",
    "to_device_ell",
    "to_device_bsr",
    "to_device_hybrid",
    "ell_padding_stats",
    "blocked_ell_from_triplets",
    "padded_col_map",
    "shard_to_ell",
    "shard_to_blocked_ell",
    "shard_to_hybrid",
    "conversion_count",
    "count_conversions",
]

# Process-wide census of host->device format conversions (one tick per
# converted layout: a device container, a shard set, a pinned chunk).  The
# plan/execute split (api/session.py) is *verified* against this counter —
# a cache-hit solve must leave it untouched — so every conversion entry
# point below ticks it.
_CONVERSIONS = {"count": 0}


def conversion_count() -> int:
    """Total format conversions performed by this process so far."""
    return _CONVERSIONS["count"]


def count_conversions(n: int = 1) -> None:
    _CONVERSIONS["count"] += int(n)


@dataclasses.dataclass
class CSR:
    """Host-side CSR (NumPy). Always square, symmetric matrices here."""

    indptr: np.ndarray  # (n+1,) int64
    indices: np.ndarray  # (nnz,) int32
    data: np.ndarray  # (nnz,) float64
    shape: Tuple[int, int]

    @property
    def n(self) -> int:
        return self.shape[0]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    def to_scipy(self):
        import scipy.sparse as sp

        return sp.csr_matrix((self.data, self.indices, self.indptr), shape=self.shape)

    def toarray(self) -> np.ndarray:
        return self.to_scipy().toarray()


def csr_from_coo(
    rows: np.ndarray, cols: np.ndarray, vals: np.ndarray, n: int, sum_dups: bool = True
) -> CSR:
    """Build CSR from COO triplets (NumPy), summing duplicates."""
    import scipy.sparse as sp

    m = sp.coo_matrix((vals, (rows, cols)), shape=(n, n))
    if sum_dups:
        m.sum_duplicates()
    m = m.tocsr()
    m.sort_indices()
    return CSR(
        indptr=m.indptr.astype(np.int64),
        indices=m.indices.astype(np.int32),
        data=m.data.astype(np.float64),
        shape=(n, n),
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceCOO:
    """Device COO triplets; SpMV = segment_sum(val * x[col], row)."""

    row: jax.Array  # (nnz,) int32, sorted by row
    col: jax.Array  # (nnz,) int32
    val: jax.Array  # (nnz,) storage dtype
    n_rows: int  # static
    n_cols: int  # static

    def tree_flatten(self):
        return (self.row, self.col, self.val), (self.n_rows, self.n_cols)

    @classmethod
    def tree_unflatten(cls, aux, children):
        row, col, val = children
        return cls(row, col, val, *aux)

    @property
    def nnz(self) -> int:
        return int(self.val.shape[0])

    def matvec(self, x: jax.Array, accum_dtype=None) -> jax.Array:
        """SpMV with accumulation in ``accum_dtype`` (mixed-precision knob)."""
        acc = accum_dtype or self.val.dtype
        prod = self.val.astype(acc) * jnp.take(x, self.col).astype(acc)
        return jax.ops.segment_sum(prod, self.row, num_segments=self.n_rows)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceELL:
    """Uniform-width ELLPACK, row-major, padded.

    ``val[r, s]`` / ``col[r, s]``: s-th stored entry of row r.  Padding slots
    have ``val == 0`` and ``col == 0`` (they contribute 0).  Rows are padded to
    a multiple of ``row_tile`` and the width to a multiple of ``slot_tile`` so
    the Pallas kernel's BlockSpec grid divides evenly.
    """

    val: jax.Array  # (rows_padded, width) storage dtype
    col: jax.Array  # (rows_padded, width) int32
    n_rows: int  # logical rows (static)
    n_cols: int  # static

    def tree_flatten(self):
        return (self.val, self.col), (self.n_rows, self.n_cols)

    @classmethod
    def tree_unflatten(cls, aux, children):
        val, col = children
        return cls(val, col, *aux)

    @property
    def width(self) -> int:
        return int(self.val.shape[1])

    def matvec(self, x: jax.Array, accum_dtype=None) -> jax.Array:
        acc = accum_dtype or self.val.dtype
        gathered = jnp.take(x, self.col).astype(acc)  # (rows_padded, width)
        y = (self.val.astype(acc) * gathered).sum(axis=1)
        return y[: self.n_rows]


def _row_positions(csr: CSR) -> Tuple[np.ndarray, np.ndarray]:
    """(row index, position-within-row) of every stored nnz, in CSR order —
    the scatter coordinates every padded-layout conversion below shares."""
    row_nnz = csr.row_nnz()
    rix = np.repeat(np.arange(csr.n, dtype=np.int64), row_nnz)
    pos = np.arange(csr.nnz) - np.repeat(csr.indptr[:-1], row_nnz)
    return rix, pos


def to_device_coo(csr: CSR, dtype=jnp.float32) -> DeviceCOO:
    n = csr.n
    count_conversions()
    row = np.repeat(np.arange(n, dtype=np.int32), csr.row_nnz())
    return DeviceCOO(
        row=jnp.asarray(row),
        col=jnp.asarray(csr.indices, dtype=jnp.int32),
        val=jnp.asarray(csr.data, dtype=dtype),
        n_rows=n,
        n_cols=n,
    )


def to_device_ell(
    csr: CSR, dtype=jnp.float32, row_tile: int = 8, slot_tile: int = 128
) -> DeviceELL:
    """Convert CSR to uniform-width padded ELL (kernel layout)."""
    n = csr.n
    count_conversions()
    nnz_per_row = csr.row_nnz()
    width = int(max(1, nnz_per_row.max()))
    width = -(-width // slot_tile) * slot_tile
    rows_pad = -(-n // row_tile) * row_tile

    val = np.zeros((rows_pad, width), dtype=np.float64)
    col = np.zeros((rows_pad, width), dtype=np.int32)
    rix, pos = _row_positions(csr)  # vectorized fill coordinates
    val[rix, pos] = csr.data
    col[rix, pos] = csr.indices
    return DeviceELL(
        val=jnp.asarray(val, dtype=dtype),
        col=jnp.asarray(col),
        n_rows=n,
        n_cols=n,
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceHybrid:
    """Hub-row split: capped-width ELL + COO overflow tail.

    Every row stores its first ``width`` entries in the uniform ELL arrays
    (``val == 0`` / ``col == 0`` on padding slots); entries past the cap —
    the hub rows' overflow — live as COO triplets.  SpMV is the Pallas ELL
    kernel over the bounded part plus one ``segment_sum`` over the tail, so
    the padding cost is ``n * width_cap`` instead of ``n * max_row_nnz``.
    Tail arrays are zero-padded (row 0, col 0, val 0 contributes nothing).
    """

    ell_val: jax.Array  # (rows_padded, width_cap) storage dtype
    ell_col: jax.Array  # (rows_padded, width_cap) int32
    tail_row: jax.Array  # (tail_padded,) int32 — output row of each overflow nnz
    tail_col: jax.Array  # (tail_padded,) int32
    tail_val: jax.Array  # (tail_padded,) storage dtype
    n_rows: int  # logical rows (static)
    n_cols: int  # static

    def tree_flatten(self):
        children = (self.ell_val, self.ell_col, self.tail_row, self.tail_col, self.tail_val)
        return children, (self.n_rows, self.n_cols)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    @property
    def width(self) -> int:
        return int(self.ell_val.shape[1])

    @property
    def tail_slots(self) -> int:
        return int(self.tail_val.shape[0])

    def matvec(self, x: jax.Array, accum_dtype=None) -> jax.Array:
        """jnp reference SpMV (the Pallas path lives in ``kernels/engine.py``)."""
        acc = accum_dtype or self.ell_val.dtype
        gathered = jnp.take(x, self.ell_col).astype(acc)
        y = (self.ell_val.astype(acc) * gathered).sum(axis=1)[: self.n_rows]
        prod = self.tail_val.astype(acc) * jnp.take(x, self.tail_col).astype(acc)
        return y + jax.ops.segment_sum(prod, self.tail_row, num_segments=self.n_rows)


def to_device_hybrid(
    csr: CSR,
    dtype=jnp.float32,
    width_cap: Optional[int] = None,
    quantile: Optional[float] = None,
    row_tile: int = 8,
    slot_tile: int = 8,
    tail_align: int = 8,
) -> DeviceHybrid:
    """Convert CSR to the hub-split hybrid layout (capped ELL + COO tail).

    ``width_cap`` pins the ELL width (the engine passes the cap its selection
    statistics used); by default it is the ``quantile`` of the row lengths
    (``kernels.engine.hybrid_width_cap`` — env-tunable via
    ``REPRO_SPMV_HYBRID_Q``).  ``slot_tile`` aligns the capped width (kept
    small by default: a 128-lane pad would reinflate exactly the padding the
    split exists to avoid; the kernel shrinks its width tile to match).
    """
    from ..kernels.engine import hybrid_width_cap  # lazy: sparse sits below kernels

    n = csr.n
    count_conversions()
    row_nnz = csr.row_nnz()
    cap = hybrid_width_cap(row_nnz, quantile) if width_cap is None else int(width_cap)
    cap = max(1, min(cap, int(row_nnz.max()) if row_nnz.size else 1))
    width = -(-cap // slot_tile) * slot_tile
    rows_pad = -(-n // row_tile) * row_tile

    rix, pos = _row_positions(csr)
    keep = pos < width  # padded cap: the aligned slots might as well hold nnz
    val = np.zeros((rows_pad, width), dtype=np.float64)
    col = np.zeros((rows_pad, width), dtype=np.int32)
    val[rix[keep], pos[keep]] = csr.data[keep]
    col[rix[keep], pos[keep]] = csr.indices[keep]

    spill = ~keep
    tail_n = int(spill.sum())
    tail_pad = -(-max(tail_n, 1) // tail_align) * tail_align
    trow = np.zeros((tail_pad,), dtype=np.int32)
    tcol = np.zeros((tail_pad,), dtype=np.int32)
    tval = np.zeros((tail_pad,), dtype=np.float64)
    trow[:tail_n] = rix[spill]
    tcol[:tail_n] = csr.indices[spill]
    tval[:tail_n] = csr.data[spill]
    return DeviceHybrid(
        ell_val=jnp.asarray(val, dtype=dtype),
        ell_col=jnp.asarray(col),
        tail_row=jnp.asarray(trow),
        tail_col=jnp.asarray(tcol),
        tail_val=jnp.asarray(tval, dtype=dtype),
        n_rows=n,
        n_cols=n,
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DeviceBSR:
    """Blocked-ELL ("BSR-style"): dense (BS, BS) blocks at sparse block
    coordinates, uniform slot count per block-row, zero-padded.

    ``val[i, s]`` is the s-th stored block of block-row i; ``bcol[i, s]`` its
    block-column (0 on padding slots — the zero block makes padding inert).
    This is exactly the layout ``kernels/spmv_bsr.py`` consumes.
    """

    val: jax.Array  # (n_block_rows, slots, BS, BS) storage dtype
    bcol: jax.Array  # (n_block_rows, slots) int32
    n_rows: int  # logical rows (static)
    n_cols: int  # static

    def tree_flatten(self):
        return (self.val, self.bcol), (self.n_rows, self.n_cols)

    @classmethod
    def tree_unflatten(cls, aux, children):
        val, bcol = children
        return cls(val, bcol, *aux)

    @property
    def block_size(self) -> int:
        return int(self.val.shape[2])

    @property
    def slots(self) -> int:
        return int(self.val.shape[1])

    def matvec(self, x: jax.Array, accum_dtype=None) -> jax.Array:
        """jnp reference SpMV (the Pallas path lives in ``kernels/engine.py``)."""
        acc = accum_dtype or self.val.dtype
        nbr, slots, bs, _ = self.val.shape
        if x.shape[0] % bs:
            x = jnp.pad(x, (0, bs - x.shape[0] % bs))
        gathered = jnp.take(x.reshape(-1, bs), self.bcol, axis=0)  # (nbr, slots, bs)
        y = jnp.einsum("rsij,rsj->ri", self.val.astype(acc), gathered.astype(acc))
        return y.reshape(nbr * bs)[: self.n_rows]


def ell_padding_stats(row_nnz: np.ndarray) -> dict:
    """Padding cost of an ELL layout over rows with the given nnz counts:
    ``overhead`` = stored slots / nnz (1.0 = perfectly uniform rows)."""
    nnz = int(row_nnz.sum())
    width = int(row_nnz.max()) if row_nnz.size else 0
    return {
        "width": width,
        "mean_row_nnz": nnz / max(1, row_nnz.size),
        "overhead": (width * int(row_nnz.size)) / max(1, nnz),
    }


def blocked_ell_from_triplets(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    n_rows: int,
    n_cols: int,
    block_size: int = 8,
    slots: Optional[int] = None,
    dtype=jnp.float32,
) -> DeviceBSR:
    """Build a blocked-ELL layout from COO triplets (host, vectorized).

    ``slots`` forces a uniform slot count (>= the required maximum) so shards
    of a distributed solve share one shape; None sizes it to this matrix.
    """
    bs = block_size
    nbr = max(1, -(-n_rows // bs))
    nbc = max(1, -(-n_cols // bs))
    br = rows.astype(np.int64) // bs
    bc = cols.astype(np.int64) // bs
    keys = np.unique(br * nbc + bc)  # sorted: groups contiguous per block-row
    kbr = keys // nbc
    counts = np.bincount(kbr, minlength=nbr)
    needed = int(counts.max()) if keys.size else 1
    if slots is None:
        slots = max(1, needed)
    elif slots < needed:
        raise ValueError(f"slots={slots} < required {needed}")

    val = np.zeros((nbr, slots, bs, bs), dtype=np.float64)
    bcol = np.zeros((nbr, slots), dtype=np.int32)
    if keys.size:
        # Slot index of each stored block = its rank within its block-row.
        first = np.searchsorted(kbr, np.arange(nbr), side="left")
        slot_of_key = np.arange(keys.size) - first[kbr]
        bcol[kbr, slot_of_key] = (keys % nbc).astype(np.int32)
        # Scatter nnz into their block slot (CSR inputs are deduplicated).
        kidx = np.searchsorted(keys, br * nbc + bc)
        val[br, slot_of_key[kidx], rows % bs, cols % bs] = vals
    return DeviceBSR(
        val=jnp.asarray(val, dtype=dtype),
        bcol=jnp.asarray(bcol),
        n_rows=n_rows,
        n_cols=n_cols,
    )


def to_device_bsr(csr: CSR, block_size: int = 8, dtype=jnp.float32) -> DeviceBSR:
    """Convert CSR to the blocked-ELL/BSR kernel layout."""
    count_conversions()
    rows = np.repeat(np.arange(csr.n, dtype=np.int64), csr.row_nnz())
    return blocked_ell_from_triplets(
        rows, csr.indices, csr.data, csr.n, csr.n, block_size=block_size, dtype=dtype
    )


def padded_col_map(splits: np.ndarray, n_pad: int, n: int) -> np.ndarray:
    """Global column -> padded-global coordinate ``shard * n_pad + local``.

    The single definition of the distributed coordinate scheme: the COO path
    (``core.partition.partition_matrix``) and the kernel-format conversions
    below must index the all-gathered vector identically.
    """
    owner = np.searchsorted(splits, np.arange(n), side="right") - 1
    return (owner * n_pad + (np.arange(n) - splits[owner])).astype(np.int64)


def shard_to_ell(
    csr: CSR,
    splits: np.ndarray,
    n_pad: int,
    dtype=jnp.float32,
    row_tile: int = 8,
    slot_tile: int = 128,
) -> Tuple[jax.Array, jax.Array, dict]:
    """Row-shard a CSR into stacked uniform ELL arrays for ``shard_map``.

    Returns ``(val, col)`` of shape (G, rows_pad, width) — one identical-shape
    ELL block per shard, columns remapped to the padded-global coordinate
    system of ``core/partition.py`` (``g = shard * n_pad + local_row``) so the
    all-gathered replicated vector is indexed directly — plus a stats dict
    with the realized padding overhead.
    """
    g = len(splits) - 1
    n = csr.n
    row_nnz = csr.row_nnz()
    width = int(max(1, row_nnz.max()))
    width = -(-width // slot_tile) * slot_tile
    rows_pad = -(-n_pad // row_tile) * row_tile
    count_conversions(g)

    col_map = padded_col_map(splits, n_pad, n)
    rix, pos = _row_positions(csr)
    owner = np.searchsorted(splits, rix, side="right") - 1
    local_r = rix - splits[owner]

    val = np.zeros((g, rows_pad, width), dtype=np.float64)
    col = np.zeros((g, rows_pad, width), dtype=np.int32)
    val[owner, local_r, pos] = csr.data
    col[owner, local_r, pos] = col_map[csr.indices]
    stats = ell_padding_stats(row_nnz)
    stats["rows_pad"] = rows_pad
    stats["width_padded"] = width
    return jnp.asarray(val, dtype=dtype), jnp.asarray(col), stats


def shard_to_blocked_ell(
    csr: CSR,
    splits: np.ndarray,
    n_pad: int,
    block_size: int = 8,
    dtype=jnp.float32,
) -> Tuple[jax.Array, jax.Array, dict]:
    """Row-shard a CSR into stacked blocked-ELL arrays for ``shard_map``.

    Returns ``(val, bcol)`` of shapes (G, nbr, slots, BS, BS) / (G, nbr,
    slots) with a uniform slot count (the max over shards), block columns in
    the *flat padded-global* index space of the all-gathered vector.  Requires
    ``n_pad % block_size == 0`` (use ``partition_matrix(..., row_align=BS)``)
    so shard-local block rows stay aligned with the replicated vector.
    """
    if n_pad % block_size:
        raise ValueError(f"n_pad={n_pad} must be a multiple of block_size={block_size}")
    g = len(splits) - 1
    n = csr.n
    count_conversions(g)
    col_map = padded_col_map(splits, n_pad, n)
    row_nnz = csr.row_nnz()
    rix = np.repeat(np.arange(n, dtype=np.int64), row_nnz)

    shard_trip = []
    slots = 1
    for s in range(g):
        lo, hi = int(csr.indptr[splits[s]]), int(csr.indptr[splits[s + 1]])
        rows_l = rix[lo:hi] - splits[s]
        cols_g = col_map[csr.indices[lo:hi]]
        shard_trip.append((rows_l, cols_g, csr.data[lo:hi]))
        if rows_l.size:
            bkeys = (rows_l // block_size) * (g * n_pad // block_size) + cols_g // block_size
            counts = np.bincount(np.unique(bkeys) // (g * n_pad // block_size))
            slots = max(slots, int(counts.max()))

    vals, bcols = [], []
    for rows_l, cols_g, data in shard_trip:
        bsr = blocked_ell_from_triplets(
            rows_l, cols_g, data, n_pad, g * n_pad, block_size=block_size,
            slots=slots, dtype=dtype,
        )
        vals.append(bsr.val)
        bcols.append(bsr.bcol)
    stats = {"slots": slots, "block_size": block_size, "n_block_rows": n_pad // block_size}
    return jnp.stack(vals), jnp.stack(bcols), stats


def shard_to_hybrid(
    csr: CSR,
    splits: np.ndarray,
    n_pad: int,
    dtype=jnp.float32,
    width_cap: Optional[int] = None,
    quantile: Optional[float] = None,
    row_tile: int = 8,
    slot_tile: int = 8,
    tail_align: int = 8,
) -> Tuple[Tuple[jax.Array, ...], dict]:
    """Row-shard a CSR into stacked hybrid (capped ELL + COO tail) arrays.

    Returns ``(val, col, tail_row, tail_col, tail_val)`` with shapes
    (G, rows_pad, width_cap) / (G, tail_pad): one identical-shape hybrid
    block per shard (shard_map needs uniform shapes, so the width cap is
    *global* — the quantile of the full matrix's row lengths — and every
    shard's tail is padded to the largest shard tail).  Columns are remapped
    to the padded-global coordinates of ``core/partition.py``; tail rows are
    shard-local output rows.  Plus a stats dict with the realized split.
    """
    from ..kernels.engine import hybrid_width_cap  # lazy: sparse sits below kernels

    g = len(splits) - 1
    n = csr.n
    count_conversions(g)
    row_nnz = csr.row_nnz()
    cap = hybrid_width_cap(row_nnz, quantile) if width_cap is None else int(width_cap)
    cap = max(1, min(cap, int(row_nnz.max()) if row_nnz.size else 1))
    width = -(-cap // slot_tile) * slot_tile
    rows_pad = -(-n_pad // row_tile) * row_tile

    col_map = padded_col_map(splits, n_pad, n)
    rix, pos = _row_positions(csr)
    owner = np.searchsorted(splits, rix, side="right") - 1
    local_r = rix - splits[owner]
    keep = pos < width

    val = np.zeros((g, rows_pad, width), dtype=np.float64)
    col = np.zeros((g, rows_pad, width), dtype=np.int32)
    val[owner[keep], local_r[keep], pos[keep]] = csr.data[keep]
    col[owner[keep], local_r[keep], pos[keep]] = col_map[csr.indices[keep]]

    spill = ~keep
    tail_counts = np.bincount(owner[spill], minlength=g)
    tail_pad = -(-max(int(tail_counts.max()) if g else 0, 1) // tail_align) * tail_align
    trow = np.zeros((g, tail_pad), dtype=np.int32)
    tcol = np.zeros((g, tail_pad), dtype=np.int32)
    tval = np.zeros((g, tail_pad), dtype=np.float64)
    for s in range(g):
        sel = spill & (owner == s)
        k = int(sel.sum())
        trow[s, :k] = local_r[sel]
        tcol[s, :k] = col_map[csr.indices[sel]]
        tval[s, :k] = csr.data[sel]
    tail_nnz = int(spill.sum())
    stats = {
        "width_cap": width,
        "rows_pad": rows_pad,
        "tail_nnz": tail_nnz,
        "tail_pad": tail_pad,
        "hybrid_overhead": (g * rows_pad * width + tail_nnz) / max(1, csr.nnz),
        "tail_frac": tail_nnz / max(1, csr.nnz),
    }
    mats = (
        jnp.asarray(val, dtype=dtype),
        jnp.asarray(col),
        jnp.asarray(trow),
        jnp.asarray(tcol),
        jnp.asarray(tval, dtype=dtype),
    )
    return mats, stats
