from .formats import CSR, DeviceCOO, DeviceELL, csr_from_coo, to_device_coo, to_device_ell
from .generate import SUITE, generate, suite_matrix

__all__ = [
    "CSR",
    "DeviceCOO",
    "DeviceELL",
    "csr_from_coo",
    "to_device_coo",
    "to_device_ell",
    "SUITE",
    "generate",
    "suite_matrix",
]
