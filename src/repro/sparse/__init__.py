from .diskcsr import (
    DiskCSR,
    diskcsr_fingerprint,
    is_diskcsr,
    open_diskcsr,
    save_diskcsr,
)
from .formats import (
    CSR,
    DeviceBSR,
    DeviceCOO,
    DeviceELL,
    csr_from_coo,
    shard_to_blocked_ell,
    shard_to_ell,
    to_device_bsr,
    to_device_coo,
    to_device_ell,
)
from .generate import SUITE, generate, suite_matrix

__all__ = [
    "CSR",
    "DeviceBSR",
    "DeviceCOO",
    "DeviceELL",
    "DiskCSR",
    "csr_from_coo",
    "diskcsr_fingerprint",
    "is_diskcsr",
    "open_diskcsr",
    "save_diskcsr",
    "shard_to_blocked_ell",
    "shard_to_ell",
    "to_device_bsr",
    "to_device_coo",
    "to_device_ell",
    "SUITE",
    "generate",
    "suite_matrix",
]
