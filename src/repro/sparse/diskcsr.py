"""Disk-native CSR: an on-disk directory format + ``np.memmap``-backed reader.

The out-of-core engine (``core/operators.ChunkedOperator``) targets matrices
larger than host RAM, which means the matrix must never be required to exist
as in-memory arrays.  This module persists a CSR as a directory of plain
``.npy`` files plus a JSON header:

    <path>/
      header.json   {"format": "repro-diskcsr", "version": 1, "shape": [n, n],
                     "nnz": ..., "indptr_dtype": ..., "indices_dtype": ...,
                     "data_dtype": ...}
      indptr.npy    (n+1,) int64
      indices.npy   (nnz,) int32
      data.npy      (nnz,) value dtype (f64/f32/bf16 — caller's choice)

``open_diskcsr`` maps the arrays with ``np.load(mmap_mode="r")``: slicing a
row window reads only those pages from disk, so the reader's host residency
is bounded by what callers actually touch (the chunked operator touches one
staging window at a time).  ``DiskCSR`` duck-types the cheap parts of
``sparse.formats.CSR`` (``n``/``nnz``/``row_nnz``/``indptr``/``indices``/
``data``) so chunk planning code runs unchanged; ``to_csr()`` materializes —
callers must gate it on size.

``diskcsr_fingerprint`` is the content key for the session cache and
``SessionStore``: hashing the full byte payload (what ``matrix_fingerprint``
does for in-RAM CSR) would read the whole file back, so the disk fingerprint
digests the header plus *strided sample blocks* of each array file — O(1)
I/O regardless of matrix size, still invalidating on header change, size
change, or content change inside any sampled block (the block stride covers
the file ends and evenly spaced interior windows).
"""

from __future__ import annotations

import hashlib
import json
import os
from typing import Optional, Union

import numpy as np

from .formats import CSR

__all__ = [
    "DiskCSR",
    "save_diskcsr",
    "open_diskcsr",
    "is_diskcsr",
    "diskcsr_fingerprint",
]

_HEADER = "header.json"
_FORMAT = "repro-diskcsr"
_VERSION = 1
_ARRAYS = ("indptr", "indices", "data")
# Chunk size (elements) for the streaming writer: bounds the writer's own
# peak host bytes when persisting an already-materialized CSR.
_COPY_ELEMS = 1 << 22


class DiskCSR:
    """``np.memmap``-backed CSR view over a ``save_diskcsr`` directory.

    The three arrays are read-only memory maps: touching a slice faults in
    only the pages it covers.  Symmetric-square by repo convention (same as
    :class:`~repro.sparse.formats.CSR`).
    """

    def __init__(self, path: str):
        self.path = os.path.abspath(str(path))
        header_path = os.path.join(self.path, _HEADER)
        with open(header_path, "r") as f:
            header = json.load(f)
        if header.get("format") != _FORMAT:
            raise ValueError(f"{header_path}: not a {_FORMAT} header")
        if int(header.get("version", 0)) > _VERSION:
            raise ValueError(
                f"{header_path}: version {header['version']} is newer than "
                f"this reader ({_VERSION})"
            )
        self.header = header
        self.shape = tuple(int(s) for s in header["shape"])
        self.indptr = np.load(os.path.join(self.path, "indptr.npy"), mmap_mode="r")
        self.indices = np.load(os.path.join(self.path, "indices.npy"), mmap_mode="r")
        self.data = np.load(os.path.join(self.path, "data.npy"), mmap_mode="r")
        if self.indptr.shape[0] != self.shape[0] + 1:
            raise ValueError(
                f"{self.path}: indptr length {self.indptr.shape[0]} != n+1 "
                f"for shape {self.shape}"
            )
        if int(header["nnz"]) != self.indices.shape[0]:
            raise ValueError(
                f"{self.path}: header nnz {header['nnz']} != indices length "
                f"{self.indices.shape[0]}"
            )

    @property
    def n(self) -> int:
        return self.shape[0]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def row_nnz(self) -> np.ndarray:
        # O(n) — row counts, not nnz; fine to materialize even for huge nnz.
        return np.diff(self.indptr)

    def nbytes_on_disk(self) -> int:
        """Total bytes of the three array payloads (the staging-pressure
        estimate ``backend="auto"`` compares against free host memory)."""
        return int(
            self.indptr.nbytes + self.indices.nbytes + np.asarray(self.data.shape).prod()
            * self.data.dtype.itemsize
        )

    def to_csr(self) -> CSR:
        """Materialize into an in-RAM :class:`CSR`.  Loads everything —
        callers must gate this on matrix size (verification paths do)."""
        return CSR(
            indptr=np.asarray(self.indptr, dtype=np.int64),
            indices=np.asarray(self.indices, dtype=np.int32),
            data=np.asarray(self.data, dtype=np.float64),
            shape=self.shape,
        )

    def __repr__(self) -> str:
        return (
            f"DiskCSR(path={self.path!r}, shape={self.shape}, nnz={self.nnz}, "
            f"data_dtype={self.data.dtype})"
        )


def save_diskcsr(path: str, csr: CSR, data_dtype=None) -> str:
    """Persist a CSR as a diskcsr directory; returns the directory path.

    ``data_dtype`` narrows the on-disk value dtype (default: keep the source
    dtype).  Arrays are written through ``np.lib.format.open_memmap`` in
    bounded windows, so persisting never doubles the source's host footprint.
    """
    path = os.path.abspath(str(path))
    os.makedirs(path, exist_ok=True)
    ddt = np.dtype(data_dtype) if data_dtype is not None else csr.data.dtype
    arrays = {
        "indptr": (np.asarray(csr.indptr), np.dtype(np.int64)),
        "indices": (np.asarray(csr.indices), np.dtype(np.int32)),
        "data": (np.asarray(csr.data), ddt),
    }
    for name, (src, dtype) in arrays.items():
        out = np.lib.format.open_memmap(
            os.path.join(path, f"{name}.npy"), mode="w+", dtype=dtype, shape=src.shape
        )
        for lo in range(0, src.shape[0], _COPY_ELEMS):
            hi = min(lo + _COPY_ELEMS, src.shape[0])
            out[lo:hi] = src[lo:hi].astype(dtype, copy=False)
        out.flush()
        del out
    header = {
        "format": _FORMAT,
        "version": _VERSION,
        "shape": [int(s) for s in csr.shape],
        "nnz": int(csr.nnz),
        "indptr_dtype": "int64",
        "indices_dtype": "int32",
        "data_dtype": ddt.name,
    }
    tmp = os.path.join(path, _HEADER + ".tmp")
    with open(tmp, "w") as f:
        json.dump(header, f, indent=1, sort_keys=True)
    os.replace(tmp, os.path.join(path, _HEADER))  # header last: commit point
    return path


def is_diskcsr(path) -> bool:
    """True when ``path`` looks like a diskcsr directory (committed header)."""
    try:
        p = os.fspath(path)
    except TypeError:
        return False
    return os.path.isdir(p) and os.path.isfile(os.path.join(p, _HEADER))


def open_diskcsr(path: Union[str, os.PathLike]) -> DiskCSR:
    p = os.fspath(path)
    if not is_diskcsr(p):
        raise FileNotFoundError(
            f"{p!r} is not a repro diskcsr directory (missing {_HEADER}; "
            "write one with repro.sparse.save_diskcsr)"
        )
    return DiskCSR(p)


def _sample_file(h, fpath: str, blocks: int, block_bytes: int) -> None:
    """Feed strided sample windows of a file into a running hash: the first
    and last blocks always, plus evenly spaced interior blocks — O(blocks)
    reads however large the file is."""
    size = os.path.getsize(fpath)
    h.update(np.int64(size).tobytes())
    with open(fpath, "rb") as f:
        if size <= blocks * block_bytes:
            h.update(f.read())  # small file: exact
            return
        stride = (size - block_bytes) // max(1, blocks - 1)
        for b in range(blocks):
            off = min(b * stride, size - block_bytes)
            f.seek(off)
            h.update(np.int64(off).tobytes())
            h.update(f.read(block_bytes))


def diskcsr_fingerprint(
    path: Union[str, os.PathLike],
    blocks: Optional[int] = None,
    block_bytes: int = 1 << 16,
) -> str:
    """Sampled content fingerprint of a diskcsr directory.

    Digest = header bytes + per-array (file size + strided 64 KiB sample
    blocks).  Cost is O(blocks) I/O — feasible for disk-resident matrices
    where the full-payload ``matrix_fingerprint`` hash is not.  Any header
    or size change invalidates; content-only changes invalidate when they
    touch a sampled window (the documented contract of a *sampled* key —
    callers that rewrite data in place should bump the header or re-save).
    """
    if blocks is None:
        from ..configs import env as envcfg

        blocks = envcfg.get_int("REPRO_DISKCSR_FP_BLOCKS")
    p = os.fspath(path)
    h = hashlib.blake2b(digest_size=16)
    h.update(b"repro-diskcsr-fp-v1")
    with open(os.path.join(p, _HEADER), "rb") as f:
        h.update(f.read())
    for name in _ARRAYS:
        h.update(name.encode())
        _sample_file(h, os.path.join(p, f"{name}.npy"), int(blocks), block_bytes)
    return h.hexdigest()
