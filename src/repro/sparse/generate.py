"""Synthetic sparse-matrix suite mirroring the paper's Table I.

The paper evaluates on 15 SuiteSparse graph matrices (web crawls, road
networks, a Kronecker graph and a uniform-random graph).  The collection is
not shipped offline, so we generate *structure-matched* synthetic replicas at
CPU-tractable scale: matched family (power-law web graph / near-planar road
lattice / R-MAT Kronecker / Erdos-Renyi uniform), symmetric, zero-free
diagonal optional.  Matrix IDs reuse the paper's names with an ``@n`` scale
suffix.

Value models:
  * ``unit``        — adjacency (all ones), like the paper's graphs;
  * ``normalized``  — symmetric normalized adjacency D^-1/2 A D^-1/2, the
                       operator spectral clustering/PageRank-style methods use
                       (eigenvalues in [-1, 1] — convenient for accuracy
                       studies);
  * ``uniform``     — U(0,1) weights.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import numpy as np

from .formats import CSR, csr_from_coo

__all__ = ["generate", "SUITE", "suite_matrix", "SuiteEntry"]


def _dedupe_symmetrize(rows, cols, n, rng, values: str) -> CSR:
    """Symmetrize, drop self loops, dedupe, attach values."""
    keep = rows != cols
    rows, cols = rows[keep], cols[keep]
    r = np.concatenate([rows, cols])
    c = np.concatenate([cols, rows])
    key = r.astype(np.int64) * n + c
    _, idx = np.unique(key, return_index=True)
    r, c = r[idx], c[idx]
    if values == "uniform":
        # Symmetric weights: derive from the unordered pair key so (i,j),(j,i)
        # get identical values.
        lo = np.minimum(r, c).astype(np.uint64)
        hi = np.maximum(r, c).astype(np.uint64)
        mix = lo * np.uint64(2654435761) + hi * np.uint64(40503)
        v = ((mix % np.uint64(2**31)).astype(np.float64) / 2**31) + 1e-3
    else:
        v = np.ones(r.shape[0], dtype=np.float64)
    csr = csr_from_coo(r, c, v, n)
    if values == "normalized":
        deg = np.maximum(csr.row_nnz(), 1).astype(np.float64)
        dinv = 1.0 / np.sqrt(deg)
        rix = np.repeat(np.arange(n), csr.row_nnz())
        csr.data = csr.data * dinv[rix] * dinv[csr.indices]
    return csr


def _rmat_edges(n_log2: int, nnz: int, rng: np.random.Generator, a=0.57, b=0.19, c=0.19):
    """R-MAT / Kronecker edge generator (GAP-kron analogue)."""
    n = 1 << n_log2
    rows = np.zeros(nnz, dtype=np.int64)
    cols = np.zeros(nnz, dtype=np.int64)
    for level in range(n_log2):
        r = rng.random(nnz)
        bit_r = (r >= a + b).astype(np.int64) * ((r < a + b + c).astype(np.int64) * 0 + 1)
        # quadrant: [a | b; c | d]
        row_bit = (r >= a + b).astype(np.int64)
        col_bit = ((r >= a) & (r < a + b)).astype(np.int64) | (r >= a + b + c).astype(np.int64)
        rows = rows * 2 + row_bit
        cols = cols * 2 + col_bit
        del bit_r
    return rows, cols, n


def _er_edges(n: int, nnz: int, rng: np.random.Generator):
    rows = rng.integers(0, n, nnz)
    cols = rng.integers(0, n, nnz)
    return rows, cols


def _powerlaw_edges(n: int, nnz: int, rng: np.random.Generator, alpha=2.1):
    """Web-graph-like: endpoint probability ~ zipf(alpha)."""
    # Sample endpoints with probability proportional to rank^-alpha via
    # inverse-CDF on a precomputed table.
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    cdf = np.cumsum(p / p.sum())
    rows = np.searchsorted(cdf, rng.random(nnz))
    cols = rng.integers(0, n, nnz)  # one heavy endpoint, one uniform
    perm = rng.permutation(n)  # decorrelate id from degree
    return perm[rows], perm[cols]


def _road_edges(n: int, rng: np.random.Generator):
    """Road-network-like: 2-D lattice + sparse random chords (OSM analogue)."""
    side = int(np.sqrt(n))
    n = side * side
    ids = np.arange(n).reshape(side, side)
    right = np.stack([ids[:, :-1].ravel(), ids[:, 1:].ravel()], axis=1)
    down = np.stack([ids[:-1, :].ravel(), ids[1:, :].ravel()], axis=1)
    edges = np.concatenate([right, down], axis=0)
    # A few chords to break perfect regularity (~1% of edges).
    k = max(1, n // 100)
    chords = np.stack([rng.integers(0, n, k), rng.integers(0, n, k)], axis=1)
    edges = np.concatenate([edges, chords], axis=0)
    return edges[:, 0], edges[:, 1], n


def generate(
    kind: str, n: int, avg_deg: float = 8.0, seed: int = 0, values: str = "normalized"
) -> CSR:
    """Generate a symmetric sparse matrix of the given family."""
    rng = np.random.default_rng(seed)
    target_nnz = int(n * avg_deg)
    if kind == "kron":
        n_log2 = int(np.ceil(np.log2(max(n, 2))))
        rows, cols, n_eff = _rmat_edges(n_log2, target_nnz, rng)
        return _dedupe_symmetrize(rows, cols, n_eff, rng, values)
    if kind == "urand":
        rows, cols = _er_edges(n, target_nnz, rng)
        return _dedupe_symmetrize(rows, cols, n, rng, values)
    if kind == "web":
        rows, cols = _powerlaw_edges(n, target_nnz, rng)
        return _dedupe_symmetrize(rows, cols, n, rng, values)
    if kind == "road":
        rows, cols, n_eff = _road_edges(n, rng)
        return _dedupe_symmetrize(rows, cols, n_eff, rng, values)
    raise ValueError(f"unknown matrix family: {kind!r}")


@dataclasses.dataclass(frozen=True)
class SuiteEntry:
    paper_id: str  # paper Table I ID
    kind: str  # generator family
    n: int  # scaled row count
    avg_deg: float


# Paper Table I, structure-matched and scaled to CPU testbed size.  The two
# GAP matrices keep their role as the "largest / out-of-core" entries.
SUITE: Dict[str, SuiteEntry] = {
    "WB-TA": SuiteEntry("wiki-Talk", "web", 1 << 14, 2.1),
    "WB-GO": SuiteEntry("web-Google", "web", 1 << 14, 5.6),
    "WB-BE": SuiteEntry("web-Berkstan", "web", 1 << 14, 11.0),
    "FL": SuiteEntry("Flickr", "web", 1 << 14, 12.0),
    "IT": SuiteEntry("italy_osm", "road", 1 << 15, 2.1),
    "PA": SuiteEntry("patents", "urand", 1 << 15, 4.0),
    "VL3": SuiteEntry("venturiLevel3", "road", 1 << 15, 4.0),
    "DE": SuiteEntry("germany_osm", "road", 1 << 16, 2.1),
    "ASIA": SuiteEntry("asia_osm", "road", 1 << 16, 2.1),
    "RC": SuiteEntry("road_central", "road", 1 << 16, 2.4),
    "WK": SuiteEntry("Wikipedia", "web", 1 << 15, 12.6),
    "HT": SuiteEntry("hugetrace-00020", "road", 1 << 16, 3.0),
    "WB": SuiteEntry("wb-edu", "web", 1 << 16, 5.8),
    "KRON": SuiteEntry("GAP-kron", "kron", 1 << 17, 16.0),
    "URAND": SuiteEntry("GAP-urand", "urand", 1 << 17, 16.0),
}


def suite_matrix(mid: str, values: str = "normalized", seed: int = 0, scale: float = 1.0) -> CSR:
    e = SUITE[mid]
    n = max(256, int(e.n * scale))
    return generate(e.kind, n, e.avg_deg, seed=seed + hash(mid) % 1000, values=values)
