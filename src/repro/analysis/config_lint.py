"""Config lints: every REPRO_* knob flows through one declared registry.

  * **E001** — AST pass over the source tree: any ``os.environ[...]``,
    ``os.environ.get(...)`` or ``os.getenv(...)`` *read* of a ``REPRO_*``
    name outside ``configs/env.py`` bypasses the registry (no type
    discipline, no default, invisible to the docs sync).  Writes —
    ``os.environ[...] = ...``, ``.setdefault``, ``.pop``, ``del`` — are
    allowed: pinning a knob for a subprocess or a trace is how the registry
    itself is *driven*.

  * **E002** — the registry and the README agree both ways: every declared
    knob appears in the README, and every ``REPRO_*`` token the README
    mentions is a declared knob (docs for deleted knobs rot fast).
"""

from __future__ import annotations

import ast
import os
import re
from typing import Iterable, List, Optional, Tuple

from .findings import Finding, Findings, filter_suppressed

__all__ = [
    "DEFAULT_TARGETS",
    "find_raw_env_reads",
    "check_file",
    "check_readme_sync",
    "run",
]

DEFAULT_TARGETS = ("src/repro", "benchmarks")
_EXCLUDE_SUFFIXES = (os.path.join("configs", "env.py"),)
_REPRO_RE = re.compile(r"\bREPRO_[A-Z0-9_]+\b")


def _repro_name(node: ast.AST) -> Optional[str]:
    """The REPRO_* string constant a call/subscript argument carries."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        if node.value.startswith("REPRO_"):
            return node.value
    return None


def _is_os_environ(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Attribute)
        and node.attr == "environ"
        and isinstance(node.value, ast.Name)
        and node.value.id == "os"
    )


def find_raw_env_reads(source: str, path: str = "<string>") -> Findings:
    """E001 findings for one module's source text."""
    tree = ast.parse(source, filename=path)
    findings: List[Finding] = []

    def flag(name: str, lineno: int, how: str) -> None:
        findings.append(
            Finding(
                "E001",
                f"raw {how} read of {name} — route it through"
                f" repro.configs.env (declared knobs only)",
                file=path,
                line=lineno,
            )
        )

    for node in ast.walk(tree):
        # os.getenv("REPRO_X")  /  os.environ.get("REPRO_X")
        if isinstance(node, ast.Call):
            func = node.func
            if (
                isinstance(func, ast.Attribute)
                and func.attr == "getenv"
                and isinstance(func.value, ast.Name)
                and func.value.id == "os"
            ):
                name = _repro_name(node.args[0]) if node.args else None
                if name:
                    flag(name, node.lineno, "os.getenv")
            elif (
                isinstance(func, ast.Attribute)
                and func.attr == "get"
                and _is_os_environ(func.value)
            ):
                name = _repro_name(node.args[0]) if node.args else None
                if name:
                    flag(name, node.lineno, "os.environ.get")
        # os.environ["REPRO_X"] in Load context (stores/deletes are writes)
        elif isinstance(node, ast.Subscript):
            if _is_os_environ(node.value) and isinstance(node.ctx, ast.Load):
                name = _repro_name(node.slice)
                if name:
                    flag(name, node.lineno, "os.environ[]")
    return filter_suppressed(findings, source.splitlines())


def check_file(path: str, repo_root: str = ".") -> Findings:
    with open(path, encoding="utf-8") as fh:
        source = fh.read()
    rel = os.path.relpath(path, repo_root)
    return find_raw_env_reads(source, rel)


def check_readme_sync(
    knob_names: Iterable[str], readme_text: str, readme_path: str = "README.md"
) -> Findings:
    """E002: registry <-> README, both directions."""
    declared = set(knob_names)
    documented = set(_REPRO_RE.findall(readme_text))
    findings: List[Finding] = []
    for name in sorted(declared - documented):
        findings.append(
            Finding(
                "E002",
                f"knob {name} is declared in repro/configs/env.py but"
                f" undocumented in {readme_path}",
                file=readme_path,
            )
        )
    for name in sorted(documented - declared):
        findings.append(
            Finding(
                "E002",
                f"{readme_path} documents {name}, which is not declared in"
                f" repro/configs/env.py (deleted or misspelled knob)",
                file=readme_path,
            )
        )
    return findings


def _iter_py(target: str) -> List[str]:
    if os.path.isfile(target):
        return [target]
    out = []
    for dirpath, _, files in os.walk(target):
        out.extend(
            os.path.join(dirpath, f) for f in sorted(files) if f.endswith(".py")
        )
    return out


def run(
    targets: Tuple[str, ...] = DEFAULT_TARGETS, repo_root: str = "."
) -> Findings:
    findings: List[Finding] = []
    for target in targets:
        full = target if os.path.isabs(target) else os.path.join(repo_root, target)
        if not os.path.exists(full):
            continue
        for path in _iter_py(full):
            if any(path.endswith(suffix) for suffix in _EXCLUDE_SUFFIXES):
                continue
            findings.extend(check_file(path, repo_root))
    readme = os.path.join(repo_root, "README.md")
    if os.path.exists(readme):
        from ..configs.env import KNOBS

        with open(readme, encoding="utf-8") as fh:
            findings.extend(check_readme_sync(KNOBS, fh.read()))
    return findings
