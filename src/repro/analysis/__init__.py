"""repro.analysis — static verification of the eigensolver's three riskiest
claims: the precision phase map (jaxpr-traced, P-rules), the Pallas kernel
tiling contracts (grid-mapping-checked, K-rules), and the serving layer's
lock and config discipline (AST-linted, C/E-rules).

Three ways in, same checks:

  * library — :func:`run_checks` / the per-pass ``run()`` functions;
  * CLI — ``python -m repro.analysis [--check ...] [--strict]``;
  * CI — the ``analysis`` job (see .github/workflows/ci.yml).

Rule IDs are stable (see :data:`RULES` and the README's "Static analysis"
table); a source-anchored finding can be suppressed with an inline
``# repro: ignore[RULE]`` comment on its line.
"""

from __future__ import annotations

from typing import Dict, Iterable, Optional

from .findings import RULES, Finding, Findings, format_findings, is_suppressed

__all__ = [
    "RULES",
    "Finding",
    "Findings",
    "CHECKS",
    "format_findings",
    "is_suppressed",
    "run_checks",
]

# Check name -> zero-arg-callable factory (imported lazily: the precision
# pass pulls in the whole solver stack, the AST passes need nothing).
CHECKS = ("precision", "kernels", "concurrency", "config")


def run_checks(
    checks: Optional[Iterable[str]] = None,
    *,
    repo_root: str = ".",
    vmem_budget_mb: Optional[float] = None,
) -> Dict[str, Findings]:
    """Run the selected passes; returns {check name: findings}."""
    selected = list(checks) if checks is not None else list(CHECKS)
    unknown = [c for c in selected if c not in CHECKS]
    if unknown:
        raise ValueError(f"unknown checks {unknown}; available: {list(CHECKS)}")
    out: Dict[str, Findings] = {}
    for name in selected:
        if name == "precision":
            from . import precision_flow

            out[name] = precision_flow.run()
        elif name == "kernels":
            from . import kernel_check

            out[name] = kernel_check.run(vmem_budget_mb)
        elif name == "concurrency":
            from . import concurrency

            out[name] = concurrency.run(repo_root=repo_root)
        elif name == "config":
            from . import config_lint

            out[name] = config_lint.run(repo_root=repo_root)
    return out
