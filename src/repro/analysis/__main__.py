"""CLI: ``python -m repro.analysis [--check NAME]... [--strict]``.

Exit status: 0 when clean (or when not ``--strict``), 1 when ``--strict``
and any finding survived suppression.  ``--summary-out`` appends a one-line
result (the CI job points it at ``$GITHUB_STEP_SUMMARY``).
"""

from __future__ import annotations

import argparse
import sys
import time

from . import CHECKS, format_findings, run_checks


def _summary_line(results, elapsed: float) -> str:
    total = sum(len(v) for v in results.values())
    per = ", ".join(f"{k}: {len(v)}" for k, v in results.items())
    status = "clean" if total == 0 else f"{total} finding(s)"
    return (
        f"static analysis: {status} ({per}) in {elapsed:.1f}s"
    )


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static verification: precision flow, kernel tiling, "
        "concurrency and config discipline.",
    )
    parser.add_argument(
        "--check",
        action="append",
        choices=CHECKS,
        help="run only this pass (repeatable; default: all)",
    )
    parser.add_argument(
        "--strict", action="store_true", help="exit 1 on any finding"
    )
    parser.add_argument(
        "--vmem-budget-mb",
        type=float,
        default=None,
        help="VMEM budget for K003 (default: REPRO_ANALYSIS_VMEM_MB, 16.0)",
    )
    parser.add_argument(
        "--repo-root", default=".", help="tree the AST passes lint (default: cwd)"
    )
    parser.add_argument(
        "--summary-out",
        default=None,
        help="append a one-line summary to this file (e.g. $GITHUB_STEP_SUMMARY)",
    )
    args = parser.parse_args(argv)

    t0 = time.time()
    results = run_checks(
        args.check, repo_root=args.repo_root, vmem_budget_mb=args.vmem_budget_mb
    )
    elapsed = time.time() - t0

    total = 0
    for name, findings in results.items():
        print(f"[{name}] {len(findings)} finding(s)")
        if findings:
            print(format_findings(findings))
        total += len(findings)
    line = _summary_line(results, elapsed)
    print(line)
    if args.summary_out:
        with open(args.summary_out, "a", encoding="utf-8") as fh:
            fh.write(line + "\n")
    return 1 if (args.strict and total) else 0


if __name__ == "__main__":
    sys.exit(main())
