"""Pallas kernel static checker: every kernel x every tile the tuner can emit.

The kernels are traced (``jax.make_jaxpr`` — nothing executes, no TPU
needed) and each ``pallas_call`` equation's ``GridMapping`` is checked:

  * **K001** — every block shape divides its operand's padded dims (the ELL
    conversions pad to the tile, ``_fit_tile`` clamps runtime tiles; this
    verifies the contract holds for every candidate the autotuner probes);
  * **K002** — the index map stays in bounds: evaluated at every corner of
    the grid (index maps here are monotone affine, so corners are
    sufficient), ``(block_index + 1) * block_shape`` must not exceed the
    operand extent;
  * **K003** — VMEM footprint: double-buffered block working set
    (``2 x sum(block bytes)``) against a configurable budget
    (``REPRO_ANALYSIS_VMEM_MB``, default 16 MB/core).  Interpret-mode
    traces are exempt — the interpreter has no VMEM ceiling and its tile
    table is deliberately huge;
  * **K004** — a grid-pinned accumulator output (an output block mapping
    that is constant along some grid dim, like the (1,)-block alpha of the
    fused SpMV) may only be pinned along *sequentially executed* dims.
    ``PARALLEL_DIMS`` is each kernel's declared contract of which grid dims
    its design allows to be farmed out; a pinned output along one of those
    is a read-modify-write race.
"""

from __future__ import annotations

import itertools
import math
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import env as envcfg
from .findings import Finding, Findings

__all__ = [
    "KERNELS",
    "PARALLEL_DIMS",
    "vmem_budget_bytes",
    "pallas_eqns",
    "check_pallas_eqn",
    "check_kernel_trace",
    "run",
]

KERNELS = (
    "spmv_ell",
    "spmv_ell_packed",
    "spmv_bsr",
    "lanczos_update",
    "lanczos_fused",
    "mixed_dot",
)

# Which grid dims each kernel's DESIGN permits to execute in parallel.
# Everything else is sequential (TPU grids execute minor-to-major in order;
# the kernels rely on that for their accumulator patterns):
#   spmv_ell / spmv_bsr: row tiles (dim 0) are independent — the width/slot
#     sweep (dim 1) accumulates into the pinned row-tile output;
#   spmv_ell_packed: 1-D grid of independent row tiles (the delta cumsum
#     keeps the full width in one tile, so there is no accumulator at all);
#   lanczos_update / mixed_dot / lanczos_fused: a scalar accumulator is
#     pinned across the whole grid, so NO dim may be parallel.
PARALLEL_DIMS: Dict[str, FrozenSet[int]] = {
    "spmv_ell": frozenset({0}),
    "spmv_ell_packed": frozenset({0}),
    "spmv_bsr": frozenset({0}),
    "lanczos_update": frozenset(),
    "lanczos_fused": frozenset(),
    "mixed_dot": frozenset(),
}


def vmem_budget_bytes(override_mb: Optional[float] = None) -> int:
    mb = override_mb if override_mb is not None else envcfg.get_float(
        "REPRO_ANALYSIS_VMEM_MB"
    )
    return int(mb * (1 << 20))


def pallas_eqns(jaxpr) -> List:
    """Every pallas_call eqn reachable from a (Closed)Jaxpr."""
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            out.append(eqn)
            continue
        for p in eqn.params.values():
            if isinstance(p, jax.core.ClosedJaxpr):
                out.extend(pallas_eqns(p.jaxpr))
            elif isinstance(p, jax.core.Jaxpr):
                out.extend(pallas_eqns(p))
            elif isinstance(p, (tuple, list)):
                for item in p:
                    if isinstance(item, (jax.core.ClosedJaxpr, jax.core.Jaxpr)):
                        out.extend(pallas_eqns(item))
    return out


def _eval_index_map(bm, grid_point: Sequence[int]) -> Tuple[int, ...]:
    cj = bm.index_map_jaxpr
    out = jax.core.eval_jaxpr(
        cj.jaxpr, cj.consts, *(np.int32(g) for g in grid_point)
    )
    return tuple(int(v) for v in out)


def _grid_corners(grid: Sequence[int]) -> Iterable[Tuple[int, ...]]:
    return itertools.product(*[(0,) if g <= 1 else (0, g - 1) for g in grid])


def _block_dims(bm) -> Tuple[int, ...]:
    # Mapped (None) dims carry no block extent; treat as 1.
    return tuple(1 if b is None else int(b) for b in bm.block_shape)


def check_pallas_eqn(
    eqn,
    kernel_name: str,
    *,
    vmem_budget: Optional[int] = None,
    parallel_dims: Optional[FrozenSet[int]] = None,
    context: str = "",
) -> Findings:
    """All four K-rules for one traced pallas_call equation."""
    gm = eqn.params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    interpret = bool(eqn.params.get("interpret", False))
    if parallel_dims is None:
        parallel_dims = PARALLEL_DIMS.get(kernel_name, frozenset())
    budget = vmem_budget if vmem_budget is not None else vmem_budget_bytes()
    ctx = context or kernel_name
    findings: List[Finding] = []

    vmem = 0
    for pos, bm in enumerate(gm.block_mappings):
        arr = bm.array_shape_dtype
        block = _block_dims(bm)
        vmem += math.prod(block) * jnp.dtype(arr.dtype).itemsize
        # K001: blocks divide the padded operand dims
        for d, (bdim, adim) in enumerate(zip(block, arr.shape)):
            if int(adim) % bdim:
                findings.append(
                    Finding(
                        "K001",
                        f"operand {pos} dim {d}: extent {adim} not divisible"
                        f" by block {bdim}",
                        context=ctx,
                    )
                )
        # K002: index map in bounds at every grid corner
        for corner in _grid_corners(grid):
            idx = _eval_index_map(bm, corner)
            for d, (i_blk, bdim, adim) in enumerate(zip(idx, block, arr.shape)):
                if (i_blk + 1) * bdim > int(adim) or i_blk < 0:
                    findings.append(
                        Finding(
                            "K002",
                            f"operand {pos} dim {d}: block index {i_blk} at"
                            f" grid point {corner} addresses"
                            f" [{i_blk * bdim}, {(i_blk + 1) * bdim}) outside"
                            f" extent {adim}",
                            context=ctx,
                        )
                    )
                    break  # one finding per (operand, corner) is enough

    # K003: double-buffered working set vs the VMEM budget (compiled mode)
    if not interpret and 2 * vmem > budget:
        findings.append(
            Finding(
                "K003",
                f"double-buffered block working set {2 * vmem} B exceeds"
                f" VMEM budget {budget} B",
                context=ctx,
            )
        )

    # K004: pinned accumulator outputs along declared-parallel dims
    for pos, bm in enumerate(gm.block_mappings_output):
        for d in range(len(grid)):
            if grid[d] <= 1:
                continue
            lo = [0] * len(grid)
            hi = list(lo)
            hi[d] = grid[d] - 1
            if _eval_index_map(bm, lo) == _eval_index_map(bm, hi) and d in parallel_dims:
                findings.append(
                    Finding(
                        "K004",
                        f"output {pos} is grid-pinned along dim {d}, which"
                        f" {kernel_name} declares parallel — accumulation"
                        f" across parallel steps is a write race",
                        context=ctx,
                    )
                )
    return findings


def check_kernel_trace(
    fn,
    avals: Sequence[jax.ShapeDtypeStruct],
    kernel_name: str,
    *,
    vmem_budget: Optional[int] = None,
    parallel_dims: Optional[FrozenSet[int]] = None,
    context: str = "",
) -> Findings:
    """Trace ``fn(*avals)`` and check every pallas_call inside.

    An entrypoint that *raises* on bad tiles (the kernels' own divisibility
    guards) reports as K001 rather than crashing the pass.
    """
    ctx = context or kernel_name
    try:
        jaxpr = jax.make_jaxpr(fn)(*avals)
    except ValueError as exc:
        return [Finding("K001", f"kernel rejected the configuration: {exc}", context=ctx)]
    findings: List[Finding] = []
    for eqn in pallas_eqns(jaxpr):
        findings.extend(
            check_pallas_eqn(
                eqn, kernel_name,
                vmem_budget=vmem_budget, parallel_dims=parallel_dims, context=ctx,
            )
        )
    return findings


# ------------------------------------------------------------- the sweep


def _pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _ell_tile_universe(dtype, rows: int, width: int):
    """Every (block_r, block_w, rows_pad, width_pad, interpret) the engine
    can actually run: the static-table prior plus the autotuner's candidate
    grid, clamped by ``_fit_tile`` against the layout the conversions build
    — exactly what ``ell_matvec`` does at runtime."""
    from ..kernels.engine import _candidate_tiles, _fit_tile, select_tiles

    for interpret in (False, True):
        prior = select_tiles(rows, width, dtype, interpret=interpret)
        width_pad = _pad_to(width, 128)  # slot_tile in make_operator
        rows_pad = _pad_to(rows, prior.block_r)
        configs = {prior}
        configs.update(_candidate_tiles(prior, dtype, interpret, prior.block_size))
        for cfg in sorted(configs, key=lambda c: (c.block_r, c.block_w)):
            br = _fit_tile(cfg.block_r, rows_pad)
            bw = _fit_tile(cfg.block_w, width_pad)
            yield br, bw, rows_pad, width_pad, interpret


def run(
    vmem_budget_mb: Optional[float] = None,
    *,
    rows: int = 960,
    width: int = 48,
    dtypes=(jnp.float32, jnp.bfloat16),
) -> Findings:
    """The CI sweep over every kernel entrypoint and emittable tile."""
    from ..kernels.engine import _ITER_BSR_BLOCKS
    from ..kernels.lanczos_fused import spmv_ell_alpha_kernel_call
    from ..kernels.spmv_bsr import spmv_bsr_kernel_call
    from ..kernels.spmv_ell import spmv_ell_kernel_call

    budget = vmem_budget_bytes(vmem_budget_mb)
    findings: List[Finding] = []
    f32 = jnp.float32
    i32 = jnp.int32

    for dtype in dtypes:
        dname = jnp.dtype(dtype).name
        for br, bw, rpad, wpad, interp in _ell_tile_universe(dtype, rows, width):
            val = jax.ShapeDtypeStruct((rpad, wpad), dtype)
            col = jax.ShapeDtypeStruct((rpad, wpad), i32)
            x = jax.ShapeDtypeStruct((rows,), dtype)
            v = jax.ShapeDtypeStruct((rpad,), f32)
            mode = "interp" if interp else "compiled"
            findings.extend(
                check_kernel_trace(
                    lambda a, c, xx: spmv_ell_kernel_call(
                        a, c, xx, block_r=br, block_w=bw, accum_dtype=f32,
                        interpret=interp,
                    ),
                    (val, col, x), "spmv_ell", vmem_budget=budget,
                    context=f"spmv_ell/{dname}/r{br}xw{bw}/{mode}",
                )
            )
            findings.extend(
                check_kernel_trace(
                    lambda a, c, xx, vv: spmv_ell_alpha_kernel_call(
                        a, c, xx, vv, block_r=br, block_w=bw, accum_dtype=f32,
                        interpret=interp,
                    ),
                    (val, col, x, v), "lanczos_fused", vmem_budget=budget,
                    context=f"lanczos_fused/{dname}/r{br}xw{bw}/{mode}",
                )
            )

        # BSR: the tile is fixed by the block edge; sweep the probe set.
        for bs in _ITER_BSR_BLOCKS:
            nbr = _pad_to(rows, bs) // bs
            slots = 4
            val = jax.ShapeDtypeStruct((nbr, slots, bs, bs), dtype)
            bcol = jax.ShapeDtypeStruct((nbr, slots), i32)
            x = jax.ShapeDtypeStruct((nbr * bs,), dtype)
            findings.extend(
                check_kernel_trace(
                    lambda a, c, xx: spmv_bsr_kernel_call(
                        a, c, xx, accum_dtype=f32, interpret=False
                    ),
                    (val, bcol, x), "spmv_bsr", vmem_budget=budget,
                    context=f"spmv_bsr/{dname}/bs{bs}",
                )
            )

    # Packed-ELL (compressed staging): 1-D row grid, full width per tile.
    # The staging layer builds rows_pad at the STAGED dtype's sublane
    # minimum (bf16: 16, fp8: 32) and block_r adapts via _fit_tile, so
    # the universe is the packed dtypes x index widths x row tiles.
    from ..kernels.engine import _fit_tile as _fit
    from ..kernels.spmv_ell_packed import (
        PACKED_VALUE_DTYPES,
        spmv_ell_packed_kernel_call,
    )

    wpadp = _pad_to(width, 128)
    for pmode, vdt in sorted(PACKED_VALUE_DTYPES.items()):
        min_r = {1: 32, 2: 16}.get(np.dtype(vdt).itemsize, 8)
        rpadp = _pad_to(rows, min_r)
        for idt in (jnp.int16, jnp.int32):
            for want_br in (8, 16, 32):
                br = _fit(max(want_br, min_r), rpadp)
                pval = jax.ShapeDtypeStruct((rpadp, wpadp), np.dtype(vdt))
                pscale = jax.ShapeDtypeStruct((rpadp, 1), f32)
                pbase = jax.ShapeDtypeStruct((rpadp, 1), i32)
                pdcol = jax.ShapeDtypeStruct((rpadp, wpadp), idt)
                px = jax.ShapeDtypeStruct((rows,), f32)
                for interp in (False, True):
                    mode = "interp" if interp else "compiled"
                    findings.extend(
                        check_kernel_trace(
                            lambda a, s, b, d, xx, _br=br, _i=interp: (
                                spmv_ell_packed_kernel_call(
                                    a, s, b, d, xx, block_r=_br,
                                    accum_dtype=f32, interpret=_i,
                                )
                            ),
                            (pval, pscale, pbase, pdcol, px),
                            "spmv_ell_packed", vmem_budget=budget,
                            context=(
                                f"spmv_ell_packed/{pmode}/"
                                f"{jnp.dtype(idt).name}/r{br}/{mode}"
                            ),
                        )
                    )

    # Vector kernels: lengths that exercise the block clamp and the padding
    # wrappers (8000 is NOT a multiple of the 4096 default block — the ops.py
    # wrappers must pad).
    from ..kernels import ops as kops

    for n in (960, 4096, 8000, 8192):
        a = jax.ShapeDtypeStruct((n,), f32)
        s = jax.ShapeDtypeStruct((), f32)
        findings.extend(
            check_kernel_trace(
                lambda w, v, vp, al, be: kops.lanczos_update(
                    w, v, vp, al, be, accum_dtype=f32, interpret=False
                ),
                (a, a, a, s, s), "lanczos_update", vmem_budget=budget,
                context=f"lanczos_update/n{n}",
            )
        )
        for comp in (False, True):
            findings.extend(
                check_kernel_trace(
                    lambda p, q: kops.mixed_dot(
                        p, q, accum_dtype=f32, compensated=comp, interpret=False
                    ),
                    (a, a), "mixed_dot", vmem_budget=budget,
                    context=f"mixed_dot/n{n}/{'kahan' if comp else 'plain'}",
                )
            )
    return findings
