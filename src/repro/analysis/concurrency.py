"""Concurrency lints over the serving layer (AST — no imports, no execution).

  * **C001** — a class that declares ``_GUARDED_BY = {"field": "_lock"}``
    promises every mutation of ``self.field`` happens inside a
    ``with self._lock:`` block.  The pass tracks the lexical lock stack
    through each method (nested functions inherit the locks held at their
    definition point — the scheduler's worker closures are defined and
    called under the same lock discipline) and flags writes, augmented
    assignments, subscript stores, and mutating container calls
    (``append``/``pop``/...) outside the declared lock.  Exempt:
    ``__init__`` (no concurrent access before construction completes),
    methods named ``*_locked``, and methods whose ``def`` line carries
    ``# repro: holds[LOCK]``.

  * **C002** — lock-acquisition order.  The deadlock-free order across the
    serving stack is scheduler ``_cv`` -> session ``_query_lock`` ->
    session ``_build_lock`` (:data:`LOCK_ORDER`).  Flagged: acquiring an
    earlier-ranked lock while lexically holding a later-ranked one, and —
    the cross-object case the ranks can't see — calling a session
    entrypoint (``eigsh``/``eigsh_many``/``warmup``/...) on a non-self
    object while holding ``_cv``: those entrypoints take ``_query_lock``
    internally, so the call inverts the order whenever a session thread
    simultaneously reaches back into the scheduler.
"""

from __future__ import annotations

import ast
import os
import re
from typing import Dict, List, Optional, Tuple

from .findings import Finding, Findings, filter_suppressed

__all__ = [
    "LOCK_ORDER",
    "SESSION_ENTRYPOINTS",
    "MUTATING_METHODS",
    "check_source",
    "check_file",
    "run",
    "DEFAULT_TARGETS",
]

# Canonical acquisition order (lower rank acquired first).
LOCK_ORDER: Dict[str, int] = {"_cv": 0, "_query_lock": 1, "_build_lock": 2}

# Session methods that internally take _query_lock / _build_lock: calling
# them on another object while holding _cv inverts LOCK_ORDER.
SESSION_ENTRYPOINTS = frozenset(
    {"eigsh", "eigsh_many", "warmup", "import_plans", "export_state"}
)

# Container-method calls that mutate their receiver.
MUTATING_METHODS = frozenset(
    {
        "append", "appendleft", "extend", "insert", "pop", "popleft",
        "popitem", "remove", "clear", "update", "add", "discard",
        "setdefault", "move_to_end", "sort", "reverse",
    }
)

DEFAULT_TARGETS = ("src/repro/serving", "src/repro/api/session.py")

_HOLDS_RE = re.compile(r"#\s*repro:\s*holds\[(\w+)\]")


def _self_attr(node: ast.AST) -> Optional[str]:
    """'X' when node is exactly ``self.X``."""
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return node.attr
    return None


def _mutated_self_field(target: ast.AST) -> Optional[str]:
    """The ``self.X`` field a store-target mutates, if any.

    Covers ``self.X = ...``, ``self.X[...] = ...``, ``self.X.attr = ...``
    (attribute of a guarded object counts as mutating the guarded object).
    """
    node = target
    while isinstance(node, (ast.Subscript, ast.Attribute)):
        field = _self_attr(node)
        if field is not None:
            return field
        node = node.value
    return None


def _with_lock_names(node: ast.With) -> List[str]:
    """Locks this with-statement acquires via ``with self.<lock>:``."""
    names = []
    for item in node.items:
        field = _self_attr(item.context_expr)
        if field is not None:
            names.append(field)
    return names


class _MethodVisitor(ast.NodeVisitor):
    """Walks one method body tracking the lexical lock stack."""

    def __init__(
        self,
        guarded: Dict[str, str],
        path: str,
        exempt: bool,
        findings: List[Finding],
        held: Optional[List[str]] = None,
    ):
        self.guarded = guarded
        self.path = path
        self.exempt = exempt
        self.findings = findings
        self.held: List[str] = list(held or [])

    # -- lock tracking -----------------------------------------------------

    def visit_With(self, node: ast.With) -> None:
        locks = _with_lock_names(node)
        for lock in locks:
            rank = LOCK_ORDER.get(lock)
            if rank is not None:
                worst = max(
                    (LOCK_ORDER[h] for h in self.held if h in LOCK_ORDER),
                    default=-1,
                )
                if worst > rank:
                    holder = next(
                        h for h in self.held
                        if h in LOCK_ORDER and LOCK_ORDER[h] == worst
                    )
                    self.findings.append(
                        Finding(
                            "C002",
                            f"acquires {lock} while holding {holder}"
                            f" (canonical order: "
                            f"{' -> '.join(sorted(LOCK_ORDER, key=LOCK_ORDER.get))})",
                            file=self.path,
                            line=node.lineno,
                        )
                    )
        self.held.extend(locks)
        for child in node.body:
            self.visit(child)
        for _ in locks:
            self.held.pop()

    # -- mutations ---------------------------------------------------------

    def _check_mutation(self, field: str, lineno: int) -> None:
        if self.exempt:
            return
        lock = self.guarded.get(field)
        if lock is not None and lock not in self.held:
            self.findings.append(
                Finding(
                    "C001",
                    f"self.{field} is declared guarded by {lock} but is"
                    f" mutated without holding it",
                    file=self.path,
                    line=lineno,
                )
            )

    def visit_Assign(self, node: ast.Assign) -> None:
        for target in node.targets:
            field = _mutated_self_field(target)
            if field is not None:
                self._check_mutation(field, node.lineno)
        self.visit(node.value)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        field = _mutated_self_field(node.target)
        if field is not None:
            self._check_mutation(field, node.lineno)
        self.visit(node.value)

    def visit_Call(self, node: ast.Call) -> None:
        func = node.func
        if isinstance(func, ast.Attribute):
            # self.X.append(...) — mutating call on a guarded container
            if func.attr in MUTATING_METHODS:
                field = _mutated_self_field(func.value)
                if field is not None:
                    self._check_mutation(field, node.lineno)
            # C002 cross-object: session entrypoint called under _cv on a
            # receiver that is not self (self-calls are rank-checked above).
            if (
                func.attr in SESSION_ENTRYPOINTS
                and "_cv" in self.held
                and not (isinstance(func.value, ast.Name) and func.value.id == "self")
            ):
                self.findings.append(
                    Finding(
                        "C002",
                        f".{func.attr}() called while holding _cv — session"
                        f" entrypoints take _query_lock internally, inverting"
                        f" the lock order",
                        file=self.path,
                        line=node.lineno,
                    )
                )
        self.generic_visit(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # Nested closure: inherits the lock stack at its definition point.
        inner = _MethodVisitor(
            self.guarded, self.path, self.exempt, self.findings, held=self.held
        )
        for child in node.body:
            inner.visit(child)

    visit_AsyncFunctionDef = visit_FunctionDef  # type: ignore[assignment]


def _guarded_by_map(cls: ast.ClassDef) -> Dict[str, str]:
    """The ``_GUARDED_BY`` dict literal of a class body, if declared."""
    for stmt in cls.body:
        if not isinstance(stmt, ast.Assign):
            continue
        if not any(
            isinstance(t, ast.Name) and t.id == "_GUARDED_BY" for t in stmt.targets
        ):
            continue
        if isinstance(stmt.value, ast.Dict):
            out = {}
            for k, v in zip(stmt.value.keys, stmt.value.values):
                if isinstance(k, ast.Constant) and isinstance(v, ast.Constant):
                    out[str(k.value)] = str(v.value)
            return out
    return {}


def _method_exempt(fn: ast.FunctionDef, source_lines: List[str]) -> bool:
    if fn.name == "__init__" or fn.name.endswith("_locked"):
        return True
    if 1 <= fn.lineno <= len(source_lines):
        if _HOLDS_RE.search(source_lines[fn.lineno - 1]):
            return True
    return False


def check_source(source: str, path: str = "<string>") -> Findings:
    """Both lints over one module's source text."""
    tree = ast.parse(source, filename=path)
    lines = source.splitlines()
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        guarded = _guarded_by_map(node)
        for stmt in node.body:
            if not isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            visitor = _MethodVisitor(
                guarded, path, _method_exempt(stmt, lines), findings
            )
            for child in stmt.body:
                visitor.visit(child)
    return filter_suppressed(findings, lines)


def check_file(path: str, repo_root: str = ".") -> Findings:
    with open(path, encoding="utf-8") as fh:
        source = fh.read()
    rel = os.path.relpath(path, repo_root)
    return check_source(source, rel)


def _iter_py(target: str) -> List[str]:
    if os.path.isfile(target):
        return [target]
    out = []
    for dirpath, _, files in os.walk(target):
        out.extend(
            os.path.join(dirpath, f) for f in sorted(files) if f.endswith(".py")
        )
    return out


def run(targets: Tuple[str, ...] = DEFAULT_TARGETS, repo_root: str = ".") -> Findings:
    findings: List[Finding] = []
    for target in targets:
        full = target if os.path.isabs(target) else os.path.join(repo_root, target)
        if not os.path.exists(full):
            continue
        for path in _iter_py(full):
            findings.extend(check_file(path, repo_root))
    return findings
