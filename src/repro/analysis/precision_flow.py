"""Precision-flow verifier: the declared phase map vs the lowered truth.

For each engine (single, restarted, distributed, chunked) and iteration mode
(fused / unfused) this pass traces the *actual solver callables* — the ops
record built by ``core.lanczos.make_local_ops`` / ``core.distributed._make_
sharded_ops``, the restarted engine's ``restart_kernels``, the real Lanczos
loop, the real ritz projection — to jaxprs on abstract inputs (nothing
executes) and checks:

  * **P003** per compute phase: every float arithmetic op in the phase's
    trace runs in the declared phase dtype or the storage dtype — a foreign
    dtype is a phase leak;
  * **P001** over the whole solve: every widening conversion lands in a
    dtype the policy declares somewhere (storage/compute/output/phases) — a
    silent upcast would falsify the mixed-precision speed claim;
  * **P002** over the whole solve: a value cast *down* and then back *up*
    loses bits for no declared reason unless the narrow dtype is the
    policy's storage or a declared phase dtype (the intentional
    round-through-storage of reorthogonalization);
  * **P004**: the measured per-dtype op counts agree with the
    ``phase_op_counts`` model under its ``executed=True`` convention
    (:func:`core.precision.assert_phase_count_parity`) — the tripwire that
    keeps the hand-maintained model honest.

The measured counts are also what ``REPRO_PRECISION_MEASURE=1`` surfaces as
``partition["spmv"]["precision"]["ops_by_dtype_measured"]``.

The whole pass runs under ``jax.experimental.enable_x64`` so f64 rungs trace
as real f64 regardless of the process default, without flipping global
state for the rest of the process.
"""

from __future__ import annotations

import contextlib
import os
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from ..core.lanczos import lanczos_tridiag, ops_for_operator
from ..core.operators import make_operator
from ..core.precision import (
    PHASES,
    POLICIES,
    PrecisionPolicy,
    assert_phase_count_parity,
    phase_op_counts,
)
from ..core.restarted import restart_kernels, ritz_project
from .findings import Finding, Findings
from .jaxpr_tools import abstract, conversions, count_ops_by_dtype, make_jaxpr_of

__all__ = [
    "ENGINES",
    "RUNGS",
    "policy_dtypes",
    "find_upcasts",
    "find_double_rounding",
    "find_phase_leaks",
    "trace_phases",
    "measure_ops_by_dtype",
    "measure_session_ops",
    "check_policy",
    "run",
]

ENGINES = ("single", "restarted", "distributed", "chunked")
# The five paper/TPU rungs the CI gate sweeps (compensated rungs are covered
# by tests; HFF aliases BFF structurally).
RUNGS = ("BFF", "FFF", "FCF", "FDF", "DDD")

_FLOAT_SIZES = {"bfloat16": 2, "float16": 2, "float32": 4, "float64": 8}


def _size(name: str) -> int:
    return _FLOAT_SIZES.get(name, np.dtype(name).itemsize)


def policy_dtypes(policy: PrecisionPolicy) -> set:
    """Every dtype name the policy declares anywhere."""
    p = policy
    names = {jnp.dtype(p.storage).name, jnp.dtype(p.compute).name, jnp.dtype(p.output).name}
    names.update(jnp.dtype(p.phase_dtype(ph)).name for ph in PHASES)
    return names


def find_upcasts(jaxpr, policy: PrecisionPolicy, context: str = "") -> Findings:
    """P001: widening conversions into undeclared dtypes."""
    declared = policy_dtypes(policy)
    out: List[Finding] = []
    seen = set()
    for conv in conversions(jaxpr):
        if _size(conv.dst) > _size(conv.src) and conv.dst not in declared:
            key = (conv.src, conv.dst)
            if key in seen:
                continue
            seen.add(key)
            out.append(
                Finding(
                    "P001",
                    f"upcast {conv.src} -> {conv.dst}, but {conv.dst} is not"
                    f" declared anywhere in policy {policy.name}",
                    context=context,
                )
            )
    return out


def find_double_rounding(jaxpr, policy: PrecisionPolicy, context: str = "") -> Findings:
    """P002: down-then-up cast chains through an undeclared narrow dtype."""
    declared = policy_dtypes(policy)
    out: List[Finding] = []
    seen = set()
    for conv in conversions(jaxpr):
        if conv.prev_src is None:
            continue
        a, b, c = conv.prev_src, conv.src, conv.dst
        if _size(b) < _size(a) and _size(c) > _size(b) and b not in declared:
            key = (a, b, c)
            if key in seen:
                continue
            seen.add(key)
            out.append(
                Finding(
                    "P002",
                    f"value rounded {a} -> {b} -> {c}; the intermediate {b} is"
                    f" not the storage or any declared phase dtype of {policy.name}",
                    context=context,
                )
            )
    return out


def find_phase_leaks(
    jaxpr, policy: PrecisionPolicy, phase: str, context: str = "", min_share: float = 0.01
) -> Findings:
    """P003: arithmetic in a dtype foreign to the declared phase.

    Allowed in a phase's trace: the declared phase dtype and the storage
    dtype (inputs are held in storage; elementwise pre-accumulation work may
    legally run there).  Anything else carrying more than ``min_share`` of
    the phase's ops is a leak.
    """
    allowed = {
        jnp.dtype(policy.phase_dtype(phase)).name,
        jnp.dtype(policy.storage).name,
    }
    counts = count_ops_by_dtype(jaxpr)
    total = sum(counts.values())
    out: List[Finding] = []
    if not total:
        return out
    for dt, cnt in sorted(counts.items()):
        if dt not in allowed and cnt / total >= min_share:
            out.append(
                Finding(
                    "P003",
                    f"phase '{phase}' declared {jnp.dtype(policy.phase_dtype(phase)).name}"
                    f" but executes {cnt} ops ({cnt / total:.0%}) in {dt}",
                    context=context,
                )
            )
    return out


# ------------------------------------------------------------ trace builders


@contextlib.contextmanager
def _pin_update_mode(mode: Optional[str]):
    """Pin REPRO_ITER_UPDATE for the duration of a trace build (the same
    knob the engines honor, so the pinned mode is the executed mode)."""
    if mode is None:
        yield
        return
    from ..configs import env as envcfg

    old = envcfg.raw("REPRO_ITER_UPDATE")
    os.environ["REPRO_ITER_UPDATE"] = mode
    try:
        yield
    finally:
        if old is None:
            os.environ.pop("REPRO_ITER_UPDATE", None)
        else:
            os.environ["REPRO_ITER_UPDATE"] = old


def _fixture(policy: PrecisionPolicy, n: int, seed: int = 3):
    """Synthetic near-uniform problem + ELL engine + operator for tracing.

    'road' degree structure keeps the ELL padding overhead small so the
    executed-ops parity bound stays tight.
    """
    from ..kernels.engine import make_engine
    from ..sparse import generate

    csr = generate("road", n, 4.0, seed=seed, values="normalized")
    pol = policy.effective()
    eng = make_engine(
        csr, "ell", accum_dtype=pol.phase_dtype("spmv"), interpret=True
    )
    op = make_operator(csr, dtype=pol.storage, engine=eng)
    return csr, eng, op


def _executed_nnz(op, fallback_nnz: int) -> int:
    """SpMV elements per matvec as the kernel executes them (ELL: every
    padded cell), falling back to logical nnz."""
    mat = getattr(op, "mat", None)
    val = getattr(mat, "val", None)
    if val is not None:
        return int(np.prod(val.shape))
    return int(fallback_nnz)


def _trace_ritz(policy: PrecisionPolicy, *, n: int, m: int, k: int, jacobi: str):
    """Jaxprs of the ritz phase: back-projection (+ device Jacobi)."""
    pol = policy.effective()
    sdt, cdt = pol.storage, pol.compute
    rzdt = pol.phase_dtype("ritz")
    traces = [
        make_jaxpr_of(
            lambda basis, w: ritz_project(basis, w, pol),
            abstract((m, n), sdt),
            abstract((m, k), rzdt),
        )
    ]
    if jacobi == "device":
        from ..core.jacobi import jacobi_eigh, tridiag_to_dense

        traces.append(
            make_jaxpr_of(
                lambda a, b: jacobi_eigh(tridiag_to_dense(a, b).astype(rzdt)),
                abstract((m,), cdt),
                abstract((m - 1,), cdt),
            )
        )
    return traces


def _single_traces(policy, *, n, m, reorth, op, chunked: bool = False):
    """(phase jaxprs, full-loop jaxpr, n_model, nnz_exec) for the in-core
    single-device loop (also the chunked engine's loop, eager/unrolled)."""
    pol = policy.effective()
    sdt, cdt = pol.storage, pol.compute
    ops = ops_for_operator(op, pol)
    mv = op.bound_matvec(pol)
    phases = {
        "spmv": make_jaxpr_of(lambda v: ops.matvec(v), abstract((n,), sdt)),
        "alpha_beta": make_jaxpr_of(
            ops.dot, abstract((n,), cdt), abstract((n,), cdt)
        ),
        "reorth": make_jaxpr_of(
            ops.project_out,
            abstract((m, n), sdt),
            abstract((n,), cdt),
            abstract((m,), cdt),
        ),
    }
    full = make_jaxpr_of(
        lambda v: lanczos_tridiag(mv, v, m, pol, reorth=reorth, ops=ops, jit=not chunked),
        abstract((n,), cdt),
    )
    return phases, full


def _restarted_traces(policy, *, n, m, reorth, op):
    """Phase + per-step traces from the restarted engine's shared kernels."""
    pol = policy.effective()
    sdt, cdt = pol.storage, pol.compute
    dot, orth = restart_kernels(pol)
    mv = op.bound_matvec(pol)

    def step(v, v_prev, beta, basis, mask):
        u = mv(v.astype(sdt)).astype(cdt)
        alpha = dot(v, u)
        u = u - alpha * v - beta * v_prev
        u = orth(u, basis, mask)
        beta2 = jnp.sqrt(jnp.maximum(dot(u, u), jnp.zeros((), u.dtype)))
        return u / beta2, beta2

    phases = {
        "spmv": make_jaxpr_of(lambda v: mv(v.astype(sdt)), abstract((n,), cdt)),
        "alpha_beta": make_jaxpr_of(dot, abstract((n,), cdt), abstract((n,), cdt)),
        "reorth": make_jaxpr_of(
            orth, abstract((n,), cdt), abstract((m, n), sdt), abstract((m,), cdt)
        ),
    }
    step_jaxpr = make_jaxpr_of(
        step,
        abstract((n,), cdt),
        abstract((n,), cdt),
        abstract((), cdt),
        abstract((m, n), sdt),
        abstract((m,), cdt),
    )
    return phases, step_jaxpr


def _distributed_traces(policy, *, n, m, reorth, csr, fmt="ell"):
    """Phase + full traces through the real shard_map program (1-device mesh)."""
    from jax.sharding import Mesh

    from ..core.distributed import _make_sharded_ops, prepare_sharded, sharded_lanczos

    pol = policy.effective()
    sdt, cdt = pol.storage, pol.compute
    ps = prepare_sharded(csr, 1, pol, spmv_format=fmt)
    n_pad = ps.pm.n_pad
    axis = "data"
    local = tuple(mat[0] for mat in ps.mats)
    ops = _make_sharded_ops(local, n_pad, pol, axis, engine=ps.engine)
    env = [(axis, 1)]
    phases = {
        "spmv": jax.make_jaxpr(lambda v: ops.matvec(v), axis_env=env)(
            abstract((n_pad,), sdt)
        ),
        "alpha_beta": jax.make_jaxpr(ops.dot, axis_env=env)(
            abstract((n_pad,), cdt), abstract((n_pad,), cdt)
        ),
        "reorth": jax.make_jaxpr(ops.project_out, axis_env=env)(
            abstract((m, n_pad), sdt),
            abstract((n_pad,), cdt),
            abstract((m,), cdt),
        ),
    }
    mesh = Mesh(np.array(jax.devices()[:1]), (axis,))
    full = make_jaxpr_of(
        lambda v: sharded_lanczos(
            ps.pm, v, m, pol, mesh, reorth=reorth, axis=axis,
            engine=ps.engine, mats=ps.mats,
        ),
        abstract((1, n_pad), cdt),
    )
    nnz_exec = int(np.prod(ps.mats[0].shape)) if fmt in ("ell", "bsr") else csr.nnz
    return phases, full, n_pad, nnz_exec


def _merge(*count_dicts: Dict[str, int]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for d in count_dicts:
        for k, v in d.items():
            out[k] = out.get(k, 0) + v
    return out


def _scaled(counts: Dict[str, int], factor: int) -> Dict[str, int]:
    return {k: v * factor for k, v in counts.items()}


def _build_traces(
    policy: PrecisionPolicy,
    engine: str,
    *,
    fused: bool,
    n: int,
    m: int,
    k: int,
    reorth: str,
    jacobi: str,
):
    """All jaxprs + parity-model inputs for one (policy, engine, mode)."""
    pol = policy.effective()
    mode = "fused" if fused else "unfused"
    with _pin_update_mode(mode):
        csr, _, op = _fixture(pol, n)
        n = csr.n  # 'road' rounds n up to a grid square
        if engine == "distributed":
            phases, full, n_model, nnz_exec = _distributed_traces(
                pol, n=n, m=m, reorth=reorth, csr=csr
            )
            step_scale = 1
        elif engine == "restarted":
            phases, full = _restarted_traces(pol, n=n, m=m, reorth=reorth, op=op)
            n_model = n
            nnz_exec = _executed_nnz(op, csr.nnz)
            step_scale = m  # host loop: one traced step x m fill iterations
        elif engine == "chunked":
            from ..core.operators import ChunkedOperator

            # Two chunks: exercises the streaming loop; chunks are padded to
            # chunk_nnz, so executed nnz is the padded total.
            chunk_nnz = max(1, (csr.nnz + 1) // 2)
            op = ChunkedOperator(csr, chunk_nnz=chunk_nnz, dtype=pol.storage)
            phases, full = _single_traces(
                pol, n=n, m=m, reorth=reorth, op=op, chunked=True
            )
            n_model = n
            nnz_exec = op.num_chunks * chunk_nnz
            step_scale = 1
        elif engine == "single":
            phases, full = _single_traces(pol, n=n, m=m, reorth=reorth, op=op)
            n_model = n
            nnz_exec = _executed_nnz(op, csr.nnz)
            step_scale = 1
        else:
            raise ValueError(f"unknown engine {engine!r}; expected one of {ENGINES}")
    ritz_traces = _trace_ritz(pol, n=n_model, m=m, k=k, jacobi=jacobi)
    phases["ritz"] = ritz_traces[0]
    return phases, full, ritz_traces, step_scale, n_model, nnz_exec


def trace_phases(policy, engine="single", *, fused=False, n=64, m=8, k=4,
                 reorth="full", jacobi="host"):
    """Public: {phase: jaxpr} for one engine config (for tests/inspection)."""
    with jax.experimental.enable_x64():
        phases, _, _, _, _, _ = _build_traces(
            POLICIES.get(policy, policy) if isinstance(policy, str) else policy,
            engine, fused=fused, n=n, m=m, k=k, reorth=reorth, jacobi=jacobi,
        )
        return phases


def measure_ops_by_dtype(
    policy: PrecisionPolicy,
    engine: str = "single",
    *,
    fused: bool = False,
    n: int = 64,
    m: int = 8,
    k: int = 4,
    reorth: str = "full",
    jacobi: str = "host",
) -> Dict[str, int]:
    """Jaxpr-measured element ops per dtype for one traced solve."""
    with jax.experimental.enable_x64():
        _, full, ritz_traces, step_scale, _, _ = _build_traces(
            policy, engine, fused=fused, n=n, m=m, k=k, reorth=reorth, jacobi=jacobi
        )
        counts = _scaled(count_ops_by_dtype(full), step_scale)
        for rt in ritz_traces:
            counts = _merge(counts, count_ops_by_dtype(rt))
        return counts


def check_policy(
    policy: PrecisionPolicy,
    engine: str = "single",
    *,
    fused: bool = False,
    n: int = 64,
    m: int = 8,
    k: int = 4,
    reorth: str = "full",
    jacobi: str = "host",
    parity_ratio: float = 8.0,
) -> Tuple[Findings, Dict[str, int]]:
    """Run all four precision rules for one (policy, engine, mode).

    Returns ``(findings, measured_ops_by_dtype)``.
    """
    pol = policy.effective() if not isinstance(policy, str) else POLICIES[policy]
    findings: List[Finding] = []
    with jax.experimental.enable_x64():
        pol = (POLICIES[policy] if isinstance(policy, str) else policy).effective()
        ctx = f"{pol.name}/{engine}/{'fused' if fused else 'unfused'}"
        phases, full, ritz_traces, step_scale, n_model, nnz_exec = _build_traces(
            pol, engine, fused=fused, n=n, m=m, k=k, reorth=reorth, jacobi=jacobi
        )
        # P003 per phase
        for ph, jx in phases.items():
            findings.extend(find_phase_leaks(jx, pol, ph, context=f"{ctx}/{ph}"))
        # P001/P002 over the full solve + ritz
        for jx in [full, *ritz_traces]:
            findings.extend(find_upcasts(jx, pol, context=ctx))
            findings.extend(find_double_rounding(jx, pol, context=ctx))
        # P004 parity with the model
        measured = _scaled(count_ops_by_dtype(full), step_scale)
        for rt in ritz_traces:
            measured = _merge(measured, count_ops_by_dtype(rt))
        model = phase_op_counts(
            pol, n=n_model, nnz=nnz_exec, m=m, k=k,
            reorth=reorth, jacobi=jacobi, executed=True,
        )
        try:
            assert_phase_count_parity(
                model, measured, ratio=parity_ratio, context=ctx
            )
        except AssertionError as exc:
            findings.append(Finding("P004", str(exc), context=ctx))
    return findings, measured


def run(
    rungs: Iterable[str] = RUNGS,
    engines: Iterable[str] = ENGINES,
    modes: Iterable[bool] = (False, True),
    **kw,
) -> Findings:
    """The CI sweep: every rung x engine x fused/unfused."""
    findings: List[Finding] = []
    for name in rungs:
        pol = POLICIES[name]
        for eng in engines:
            for fused in modes:
                fs, _ = check_policy(pol, eng, fused=fused, **kw)
                findings.extend(fs)
    return findings


# ------------------------------------------------------ session integration

_SESSION_MEASURE_CACHE: Dict[tuple, Dict[str, int]] = {}
_SESSION_MEASURE_CACHE_MAX = 32


def measure_session_ops(
    policy: PrecisionPolicy,
    operator,
    *,
    backend: str,
    m: int,
    k: int,
    reorth: str,
    jacobi: str = "host",
) -> Dict[str, int]:
    """``ops_by_dtype_measured`` for a live session solve (behind
    ``REPRO_PRECISION_MEASURE``).

    Traces the session's *own* operator (its device arrays close over as
    constants — tracing allocates nothing and executes nothing).  The
    restarted backend uses its per-step trace x ``m``; every other backend
    uses the jitted loop trace, whose phase dtypes are shared by
    construction across the single/distributed/chunked engines.
    """
    pol = policy.effective()
    key = (id(operator), pol.name, backend, m, k, reorth, jacobi)
    hit = _SESSION_MEASURE_CACHE.get(key)
    if hit is not None:
        return hit
    n = operator.n
    if backend == "restarted":
        phases, step = _restarted_traces(pol, n=n, m=m, reorth="full", op=operator)
        counts = _scaled(count_ops_by_dtype(step), m)
    else:
        _, full = _single_traces(pol, n=n, m=m, reorth=reorth, op=operator)
        counts = count_ops_by_dtype(full)
    for rt in _trace_ritz(pol, n=n, m=m, k=k, jacobi=jacobi):
        counts = _merge(counts, count_ops_by_dtype(rt))
    if len(_SESSION_MEASURE_CACHE) >= _SESSION_MEASURE_CACHE_MAX:
        _SESSION_MEASURE_CACHE.pop(next(iter(_SESSION_MEASURE_CACHE)))
    _SESSION_MEASURE_CACHE[key] = counts
    return counts
