"""Finding/rule infrastructure shared by every ``repro.analysis`` pass.

Each rule has a stable ID (table below, mirrored in the README's "Static
analysis" section); findings carry ``file:line`` when they anchor to source
and a synthetic location (``<trace:...>``) when they anchor to a traced
computation.  A finding on a source line can be suppressed with an inline
``# repro: ignore[RULE]`` comment on that line — grep-able, per-rule, and
deliberately loud in review diffs.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Iterable, List, Optional

__all__ = ["RULES", "Finding", "Findings", "is_suppressed", "format_findings"]

# Stable rule IDs.  Never renumber: suppression comments and CI baselines
# reference these strings.
RULES: Dict[str, str] = {
    # Precision-flow verifier (jaxpr-level)
    "P001": "undeclared upcast: a conversion widens into a dtype the policy never declares",
    "P002": "double rounding: value cast down then back up through an undeclared dtype",
    "P003": "phase leak: arithmetic executes in a dtype foreign to the declared phase",
    "P004": "model divergence: phase_op_counts disagrees with the jaxpr-measured counts",
    # Pallas kernel static checker
    "K001": "tile does not divide the padded layout dims of the kernel grid",
    "K002": "index map addresses a block outside the operand bounds",
    "K003": "estimated VMEM footprint of the kernel's refs exceeds the budget",
    "K004": "grid-pinned accumulator output written along a parallel grid dimension",
    # Concurrency lints (AST-level)
    "C001": "field declared in _GUARDED_BY mutated outside a `with self.<lock>` block",
    "C002": "lock acquisition order violation between scheduler and session locks",
    # Config lints
    "E001": "raw os.environ/os.getenv read of a REPRO_* knob bypassing configs/env.py",
    "E002": "env-knob registry and README documentation out of sync",
}

_IGNORE_RE = re.compile(r"#\s*repro:\s*ignore\[([A-Z]\d{3}(?:\s*,\s*[A-Z]\d{3})*)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One verified problem: a stable rule ID, a location, and the story."""

    rule: str
    message: str
    file: str = ""  # repo-relative path, or "" for trace-anchored findings
    line: int = 0
    context: str = ""  # e.g. "FDF/single/fused" or a kernel/tile label

    def __post_init__(self):
        if self.rule not in RULES:
            raise ValueError(f"unknown rule ID {self.rule!r}; known: {sorted(RULES)}")

    def location(self) -> str:
        if self.file:
            return f"{self.file}:{self.line}" if self.line else self.file
        return f"<{self.context}>" if self.context else "<trace>"

    def __str__(self) -> str:
        ctx = f" [{self.context}]" if self.context and self.file else ""
        return f"{self.rule} {self.location()}{ctx}: {self.message}"


Findings = List[Finding]


def is_suppressed(source_line: str, rule: str) -> bool:
    """True when ``source_line`` carries ``# repro: ignore[...]`` naming ``rule``."""
    m = _IGNORE_RE.search(source_line)
    if not m:
        return False
    rules = {r.strip() for r in m.group(1).split(",")}
    return rule in rules


def filter_suppressed(
    findings: Iterable[Finding], source_lines: Optional[List[str]]
) -> Findings:
    """Drop findings whose anchoring source line suppresses their rule."""
    if source_lines is None:
        return list(findings)
    kept = []
    for f in findings:
        if f.line and 1 <= f.line <= len(source_lines):
            if is_suppressed(source_lines[f.line - 1], f.rule):
                continue
        kept.append(f)
    return kept


def format_findings(findings: Iterable[Finding]) -> str:
    fs = list(findings)
    if not fs:
        return "no findings"
    return "\n".join(str(f) for f in fs)
