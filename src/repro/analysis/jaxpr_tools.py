"""jaxpr walking utilities for the precision-flow verifier.

Everything here operates on traces (``jax.make_jaxpr`` output) — nothing is
executed.  Two products:

  * :func:`count_ops_by_dtype` — element-operation counts per float dtype,
    descending into sub-jaxprs with the right multipliers (``scan`` bodies
    count ``length`` times, ``pallas_call`` bodies once per grid step, a
    ``while`` body once — a trace records a dynamic loop's body, not its
    trip count);
  * :func:`conversions` — every ``convert_element_type`` with its
    (src, dst) dtypes and a def-use link to the producing conversion, the
    raw material of the upcast / double-rounding rules.

Counting conventions (shared with ``core.precision.phase_op_counts`` via the
parity assertion's ratio tolerance): elementwise arithmetic counts its
output size in the *output* dtype; ``dot_general`` counts its
multiply-accumulates (``prod(out_shape) * prod(contracted dims)``) in the
output dtype; reductions count their operand size in the operand dtype.
Conversions, layout ops, and integer index arithmetic are not "work".
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "ARITH_PRIMS",
    "REDUCE_PRIMS",
    "Conversion",
    "count_ops_by_dtype",
    "conversions",
    "make_jaxpr_of",
]

# Elementwise float arithmetic counted as work (output-size ops).
ARITH_PRIMS = frozenset(
    {
        "add", "sub", "mul", "div", "rem", "neg", "sign", "abs",
        "max", "min", "pow", "integer_pow", "sqrt", "rsqrt", "cbrt",
        "exp", "log", "log1p", "expm1", "tanh", "logistic",
        "atan2", "erf", "square",
    }
)
# Reductions counted as operand-size ops in the operand dtype.
REDUCE_PRIMS = frozenset(
    {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "cumsum", "cumprod"}
)
# Collectives: per-device arithmetic negligible; not counted.
_SKIP_PRIMS = frozenset(
    {
        "convert_element_type", "broadcast_in_dim", "reshape", "transpose",
        "squeeze", "slice", "dynamic_slice", "dynamic_update_slice", "concatenate",
        "gather", "scatter", "scatter-add", "pad", "iota", "select_n", "rev",
        "copy", "device_put", "stop_gradient", "eq", "ne", "lt", "le", "gt", "ge",
        "and", "or", "not", "xor", "is_finite", "argmax", "argmin", "sort",
        "reduce_and", "reduce_or", "rng_bit_generator", "clamp", "round", "floor",
        "ceil", "nextafter", "real", "imag", "sharding_constraint",
        "all_gather", "psum", "pmax", "pmin", "ppermute", "axis_index",
    }
)


def _aval_size(aval) -> int:
    shape = getattr(aval, "shape", ())
    return int(math.prod(shape)) if shape else 1


def _is_float(aval) -> bool:
    dt = getattr(aval, "dtype", None)
    return dt is not None and jnp.issubdtype(dt, jnp.floating)


def _dtype_name(aval) -> str:
    return jnp.dtype(aval.dtype).name


def _sub_jaxprs(params: Any) -> List[Any]:
    """Every Jaxpr/ClosedJaxpr reachable from an eqn's params (one level)."""
    out: List[Any] = []

    def visit(v):
        if isinstance(v, jax.core.ClosedJaxpr):
            out.append(v.jaxpr)
        elif isinstance(v, jax.core.Jaxpr):
            out.append(v)
        elif isinstance(v, (tuple, list)):
            for item in v:
                visit(item)
        elif isinstance(v, dict):
            for item in v.values():
                visit(item)

    for v in params.values():
        visit(v)
    return out


def _pallas_grid_steps(params: Dict[str, Any]) -> int:
    gm = params.get("grid_mapping")
    grid = getattr(gm, "grid", ()) if gm is not None else params.get("grid", ())
    steps = 1
    for g in grid:
        try:
            steps *= int(g)
        except (TypeError, ValueError):  # dynamic/symbolic dim: count once
            pass
    return max(steps, 1)


def _eqn_scale(eqn) -> int:
    """Multiplier applied to ops inside this eqn's sub-jaxprs."""
    name = eqn.primitive.name
    if name == "scan":
        return int(eqn.params.get("length", 1))
    if name == "pallas_call":
        return _pallas_grid_steps(eqn.params)
    # while: the trace holds one body; trip count is dynamic -> count once.
    return 1


def _dot_general_macs(eqn) -> int:
    (lhs, rhs) = eqn.invars[:2]
    dims = eqn.params["dimension_numbers"]
    (lhs_contract, _), _ = dims
    out_size = _aval_size(eqn.outvars[0].aval)
    contracted = 1
    for d in lhs_contract:
        contracted *= int(lhs.aval.shape[d])
    return out_size * max(contracted, 1)


def count_ops_by_dtype(jaxpr, _scale: int = 1) -> Dict[str, int]:
    """Float element-op counts per dtype name for a (Closed)Jaxpr."""
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    counts: Dict[str, int] = {}

    def add(name: str, ops: int) -> None:
        if ops:
            counts[name] = counts.get(name, 0) + ops

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        out_aval = eqn.outvars[0].aval if eqn.outvars else None
        if name == "dot_general":
            if out_aval is not None and _is_float(out_aval):
                add(_dtype_name(out_aval), _scale * _dot_general_macs(eqn))
            continue
        if name in REDUCE_PRIMS:
            in_aval = eqn.invars[0].aval
            if _is_float(in_aval):
                add(_dtype_name(in_aval), _scale * _aval_size(in_aval))
            continue
        if name in ARITH_PRIMS:
            if out_aval is not None and _is_float(out_aval):
                add(_dtype_name(out_aval), _scale * _aval_size(out_aval))
            continue
        subs = _sub_jaxprs(eqn.params)
        if not subs:
            continue
        scale = _scale * _eqn_scale(eqn)
        if name == "cond":
            # Branches are alternatives: count the heaviest one.
            best: Dict[str, int] = {}
            for sub in subs:
                c = count_ops_by_dtype(sub, scale)
                if sum(c.values()) > sum(best.values()):
                    best = c
            for dt, c in best.items():
                add(dt, c)
            continue
        for sub in subs:
            for dt, c in count_ops_by_dtype(sub, scale).items():
                add(dt, c)
    return counts


class Conversion(NamedTuple):
    """One convert_element_type: src -> dst, with the producing conversion
    of its operand when that operand itself came from a convert."""

    src: str
    dst: str
    prev_src: Optional[str]  # dtype the operand held before ITS conversion


def conversions(jaxpr) -> List[Conversion]:
    """Every float->float conversion in the trace (recursing into sub-jaxprs),
    def-use-linked one step back for double-rounding detection."""
    if isinstance(jaxpr, jax.core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    out: List[Conversion] = []
    produced_by_convert: Dict[Any, str] = {}  # outvar -> src dtype name

    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "convert_element_type":
            in_aval = eqn.invars[0].aval
            out_aval = eqn.outvars[0].aval
            if not (_is_float(in_aval) and _is_float(out_aval)):
                continue
            src, dst = _dtype_name(in_aval), _dtype_name(out_aval)
            if src == dst:
                continue
            invar = eqn.invars[0]
            prev = produced_by_convert.get(invar)
            out.append(Conversion(src, dst, prev))
            produced_by_convert[eqn.outvars[0]] = src
        else:
            for sub in _sub_jaxprs(eqn.params):
                out.extend(conversions(sub))
    return out


def make_jaxpr_of(fn, *avals) -> jax.core.ClosedJaxpr:
    """``jax.make_jaxpr`` over ShapeDtypeStructs — tracing only, no execution."""
    return jax.make_jaxpr(fn)(*avals)


def abstract(shape: Tuple[int, ...], dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))
