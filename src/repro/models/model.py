"""Model zoo top level: init / forward / prefill / decode for all families.

Families (DESIGN.md §5):
  dense | moe | vlm    — uniform decoder stack (attention + MLP/MoE),
  hybrid_rglru         — RecurrentGemma pattern (rec, rec, local-attn),
  ssm                  — Mamba-2 SSD stack,
  encdec               — encoder + decoder with cross-attention (seamless).

Layer parameters are stacked on a leading 'layers' axis and the stack is a
``lax.scan`` (+ optional full remat), which keeps HLO size O(1) in depth —
required for the 512-device dry-run of 80-layer models.  Heterogeneous
patterns scan over *superblocks* (one pattern period) with any remainder
layers unrolled.

The modality frontends of [audio]/[vlm] archs are stubs per the assignment:
``batch["frames"]`` carries precomputed frame/patch embeddings.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from .attention import (
    AttnCache,
    _project_qkv,
    attention,
    decode_attention,
    init_attention,
    init_cache,
    project_kv_only,
)
from .common import Leaf, ModelConfig, make_positions, rms_norm
from .mlp import init_mlp, init_moe, mlp, moe
from .rglru import init_rglru_block, init_rglru_state, rglru_block, rglru_decode_step
from .ssd import init_ssd_block, init_ssd_state, ssd_block, ssd_decode_step

__all__ = [
    "init_model",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
    "init_decode_state",
    "DecodeState",
]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _norm_leaf(cfg):
    return Leaf(jnp.zeros((cfg.d_model,), jnp.float32), (None,))


def _init_block(key, cfg: ModelConfig, kind: str):
    ks = jax.random.split(key, 4)
    if kind in ("dense", "attn_local", "enc"):
        return {"n1": _norm_leaf(cfg), "attn": init_attention(ks[0], cfg),
                "n2": _norm_leaf(cfg), "mlp": init_mlp(ks[1], cfg)}
    if kind == "moe":
        return {"n1": _norm_leaf(cfg), "attn": init_attention(ks[0], cfg),
                "n2": _norm_leaf(cfg), "moe": init_moe(ks[1], cfg)}
    if kind == "rec":
        return {"n1": _norm_leaf(cfg), "rec": init_rglru_block(ks[0], cfg),
                "n2": _norm_leaf(cfg), "mlp": init_mlp(ks[1], cfg)}
    if kind == "ssd":
        return {"n1": _norm_leaf(cfg), "ssd": init_ssd_block(ks[0], cfg)}
    if kind == "dec":
        return {"n1": _norm_leaf(cfg), "attn": init_attention(ks[0], cfg),
                "nx": _norm_leaf(cfg), "xattn": init_attention(ks[1], cfg),
                "n2": _norm_leaf(cfg), "mlp": init_mlp(ks[2], cfg)}
    raise ValueError(kind)


def _stacked_init(key, cfg: ModelConfig, kind: str, n: int):
    keys = jax.random.split(key, n)
    blocks = jax.vmap(lambda k: _init_block(k, cfg, kind))(keys)
    return jax.tree.map(
        lambda l: Leaf(l.value, ("layers",) + l.axes),
        blocks,
        is_leaf=lambda x: isinstance(x, Leaf),
    )


def _pattern(cfg: ModelConfig) -> Tuple[str, ...]:
    if cfg.family == "hybrid_rglru":
        return cfg.block_pattern or ("rec", "rec", "attn_local")
    if cfg.family == "ssm":
        return ("ssd",)
    if cfg.family == "moe":
        return ("moe",)
    if cfg.family == "encdec":
        return ("dec",)
    return ("dense",)


def init_model(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    vp = cfg.vocab_padded
    params: Dict[str, Any] = {
        "embed": Leaf(
            (jax.random.normal(ks[0], (vp, cfg.d_model), jnp.float32) * 0.02).astype(
                cfg.param_dtype
            ),
            ("vocab", "embed"),
        ),
        "final_norm": _norm_leaf(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = Leaf(
            (jax.random.normal(ks[1], (cfg.d_model, vp), jnp.float32) * 0.02).astype(
                cfg.param_dtype
            ),
            ("embed", "vocab"),
        )
    if cfg.family == "encdec":
        params["enc"] = _stacked_init(ks[2], cfg, "enc", cfg.n_enc_layers)
        params["enc_norm"] = _norm_leaf(cfg)
        params["blocks"] = {"dec_0": _stacked_init(ks[3], cfg, "dec", cfg.n_layers)}
        params["rem"] = []
        return params

    pat = _pattern(cfg)
    n_full, rem = divmod(cfg.n_layers, len(pat))
    params["blocks"] = {
        f"{kind}_{i}": _stacked_init(jax.random.fold_in(ks[4], i), cfg, kind, n_full)
        for i, kind in enumerate(pat)
    }
    params["rem"] = [
        _init_block(jax.random.fold_in(ks[5], i), cfg, pat[i]) for i in range(rem)
    ]
    return params


# --------------------------------------------------------------------------
# sequence forward (training / prefill / encoder)
# --------------------------------------------------------------------------

def _block_seq(p, cfg: ModelConfig, kind: str, x, pos, enc_ctx=None, collect: bool = False):
    """One residual block, full-sequence. Returns (x, aux, side).

    ``side``: with collect=True — (k, v) for attention kinds, the final
    recurrent state for rec/ssd kinds; else ().  ``collect`` also marks the
    serving path, which runs the MoE dropless (see mlp.moe).
    """
    side: Any = ()
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "moe", "attn_local", "enc", "dec"):
        window = cfg.window if kind not in ("enc", "dec") else None
        causal = kind != "enc"
        h = rms_norm(x, p["n1"], cfg.rms_eps)
        if collect:
            _, k, v = _project_qkv(p["attn"], cfg, h, pos)
            side = (k, v)
        x = x + attention(p["attn"], cfg, h, pos, causal=causal, window=window)
        if kind == "dec":
            enc_out, enc_pos = enc_ctx
            hx = rms_norm(x, p["nx"], cfg.rms_eps)
            k, v = project_kv_only(p["xattn"], cfg, enc_out)
            x = x + attention(
                p["xattn"], cfg, hx, pos if pos.ndim == 2 else pos[0],
                causal=False, kv_override=(k, v, enc_pos),
            )
        h2 = rms_norm(x, p["n2"], cfg.rms_eps)
        if kind == "moe":
            y, aux = moe(p["moe"], cfg, h2, dropless=collect)
        else:
            y = mlp(p["mlp"], cfg, h2)
        x = x + y
    elif kind == "rec":
        h = rms_norm(x, p["n1"], cfg.rms_eps)
        y, st = rglru_block(p["rec"], cfg, h)
        if collect:
            side = st
        x = x + y
        h2 = rms_norm(x, p["n2"], cfg.rms_eps)
        x = x + mlp(p["mlp"], cfg, h2)
    elif kind == "ssd":
        h = rms_norm(x, p["n1"], cfg.rms_eps)
        y, st = ssd_block(p["ssd"], cfg, h)
        if collect:
            side = st
        x = x + y
    else:
        raise ValueError(kind)
    return x, aux, side


def _run_stack(params, cfg: ModelConfig, x, pos, enc_ctx=None, collect: bool = False):
    """Scan the (super-)block stack. Returns (x, aux_sum, (side_stacks, rem_sides))."""
    pat = _pattern(cfg)

    def superblock(x, block_params):
        auxes = jnp.zeros((), jnp.float32)
        sides = {}
        for i, kind in enumerate(pat):
            key = f"{kind}_{i}"
            x, aux, side = _block_seq(block_params[key], cfg, kind, x, pos, enc_ctx, collect)
            auxes = auxes + aux
            sides[key] = side
        # sequence parallelism at the layer boundary: the scan carry (== the
        # remat-saved activation stack) lives seq-sharded over 'model'.
        # (Hillclimb A2 tried exempting hybrid blocks: refuted, +1.2 GiB
        # collectives — see EXPERIMENTS.md §Perf.)
        x = hint(x, "batch", "act_seq", "act_embed")
        return x, (auxes, sides)

    fn = superblock
    if cfg.remat == "dots":
        # save matmul outputs so backward skips recompute.  Hillclimb C1
        # REFUTED this for qwen3-0.6b: the inner attention/CE checkpoints
        # own the dominant recompute, so body FLOPs dropped only ~3% while
        # temp grew 4.85 -> 48.7 GiB.  Kept as an option for memory-rich,
        # attention-light configs.
        fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.checkpoint_dots)
    elif cfg.remat != "none":
        fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)

    x, (auxes, side_stacks) = jax.lax.scan(lambda c, p_: fn(c, p_), x, params["blocks"])
    aux_sum = auxes.sum()

    rem_sides = []
    for i, bp in enumerate(params.get("rem", [])):
        x, aux, side = _block_seq(bp, cfg, pat[i], x, pos, enc_ctx, collect)
        aux_sum = aux_sum + aux
        rem_sides.append(side)
    return x, aux_sum, (side_stacks, rem_sides)


def _embed(params, cfg: ModelConfig, tokens):
    """Embedding lookup as a one-hot contraction with a custom VJP.

    Forward: a gather from a vocab-sharded table forces the SPMD partitioner
    into involuntary full rematerialization (replicates the table); the
    one-hot einsum contracts over the sharded vocab axis cleanly (a psum
    over 'model') and runs on the MXU.  Exact: each row sums a single term.

    Backward: AD of the one-hot matmul upcasts the (B,S,V) one-hot to f32
    (XLA hoists the convert -> multi-GB buffer); the custom VJP instead
    recomputes the one-hot in bf16 and lets the table-gradient einsum
    accumulate bf16 x bf16 -> f32, which is exact per product.
    """
    dt = cfg.compute_dtype
    vp = cfg.vocab_padded

    @jax.custom_vjp
    def lookup(table, toks):
        oh = jax.nn.one_hot(toks, vp, dtype=dt)
        return oh @ table.astype(dt)

    def fwd(table, toks):
        return lookup(table, toks), toks

    def bwd(toks, dy):
        oh = jax.nn.one_hot(toks, vp, dtype=dt)
        d_table = jnp.einsum("bsv,bsd->vd", oh, dy, preferred_element_type=jnp.float32)
        return d_table, None

    lookup.defvjp(fwd, bwd)
    return hint(lookup(params["embed"], tokens), "batch", "seq", "act_embed")


def _logits(params, cfg: ModelConfig, x):
    dt = cfg.compute_dtype
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head.astype(dt)).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:  # mask padded vocab slots
        pad = cfg.vocab_padded - cfg.vocab
        mask = jnp.concatenate([jnp.zeros((cfg.vocab,)), jnp.full((pad,), -1e30)]).astype(
            jnp.float32
        )
        logits = logits + mask
    return hint(logits, "batch", "seq", "act_vocab")


def _encode(params, cfg: ModelConfig, frames):
    """Encoder stack over stub frame embeddings. Returns (enc_out, enc_pos)."""
    b, s_src = frames.shape[:2]
    enc_pos = make_positions(b, s_src)
    x = hint(frames.astype(cfg.compute_dtype), "batch", "seq", "act_embed")

    def enc_block(x, p):
        x, _, _ = _block_seq(p, cfg, "enc", x, enc_pos)
        return x, None

    fn = enc_block
    if cfg.remat != "none":
        fn = jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(fn, x, params["enc"])
    return rms_norm(x, params["enc_norm"], cfg.rms_eps), enc_pos


def forward(params, cfg: ModelConfig, batch: Dict[str, jax.Array]):
    """batch: tokens (B,S) [, frames, positions] -> (logits (B,S,Vp), aux)."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    enc_ctx = None
    if cfg.family == "encdec":
        enc_ctx = _encode(params, cfg, batch["frames"])
    x = _embed(params, cfg, tokens)
    if cfg.family == "vlm" and batch.get("frames") is not None:
        # vision stub: precomputed patch embeddings prefix the text tokens
        x = jnp.concatenate([batch["frames"].astype(cfg.compute_dtype), x], axis=1)
    pos = batch.get("positions")
    if pos is None:
        pos = make_positions(b, x.shape[1], mrope=cfg.mrope_sections is not None)
    x, aux, _ = _run_stack(params, cfg, x, pos, enc_ctx)
    return _logits(params, cfg, x), {"moe_aux": aux}


def _backbone(params, cfg: ModelConfig, batch):
    """Everything up to (but not including) the LM head. Returns (x, aux)."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    enc_ctx = None
    if cfg.family == "encdec":
        enc_ctx = _encode(params, cfg, batch["frames"])
    x = _embed(params, cfg, tokens)
    if cfg.family == "vlm" and batch.get("frames") is not None:
        x = jnp.concatenate([batch["frames"].astype(cfg.compute_dtype), x], axis=1)
    pos = batch.get("positions")
    if pos is None:
        pos = make_positions(b, x.shape[1], mrope=cfg.mrope_sections is not None)
    x, aux, _ = _run_stack(params, cfg, x, pos, enc_ctx)
    return x, aux


def loss_fn(params, cfg: ModelConfig, batch, aux_weight: float = 0.01):
    """Next-token cross-entropy (+ MoE aux). Labels < 0 are masked.

    Memory shape: the backbone output stays *sequence*-sharded over 'model'
    (matching the layer boundaries), so the f32 logits live as
    (B_loc, S/16, V) per device and the whole CE tail is rematerialized —
    no (B, S, V) buffer ever exists.  The label logit comes from a one-hot
    contraction (a take_along_axis over a sharded axis would all-gather
    the logits).
    """
    x, aux = _backbone(params, cfg, batch)
    labels = batch["labels"]
    b, s, d = x.shape

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def ce_chunk_fn(xc, lc):
        logits = _logits(params, cfg, xc)  # (B,C,Vp) f32, vocab-sharded
        valid = lc >= 0
        lbl = jnp.maximum(lc, 0)
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
        oh = jax.nn.one_hot(lbl, cfg.vocab_padded, dtype=logits.dtype)
        label_logit = jnp.sum(logits * oh, axis=-1)
        nll = (lse - label_logit) * valid
        return jnp.sum(nll).astype(jnp.float32), jnp.sum(valid).astype(jnp.int32)

    chunk = min(512, s)
    if s % chunk:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        s += pad
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        tot, cnt = carry
        nll, n = ce_chunk_fn(*inp)
        return (tot + nll, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (xc, lc)
    )
    loss = tot / jnp.maximum(cnt, 1)
    return loss + aux_weight * aux, {"ce": loss, "moe_aux": aux}


# --------------------------------------------------------------------------
# decode (serving)
# --------------------------------------------------------------------------

class DecodeState(NamedTuple):
    step: jax.Array  # () int32 — absolute position of the NEXT token
    layers: Any  # dict: per-pattern-kind stacked layer states
    rem: Any  # remainder-layer states (tuple)
    cross: Any  # encdec: (k_stack, v_stack, enc_pos) or None


def _layer_state(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind in ("dense", "moe", "attn_local", "dec"):
        w = min(max_len, cfg.window) if cfg.window else max_len
        return init_cache(cfg, batch, w)
    if kind == "rec":
        return init_rglru_state(cfg, batch)
    if kind == "ssd":
        return init_ssd_state(cfg, batch)
    raise ValueError(kind)


def init_decode_state(
    cfg: ModelConfig, batch: int, max_len: int, step: int = 0, enc_len: int = 1
) -> DecodeState:
    pat = _pattern(cfg)
    n_full, rem = divmod(cfg.n_layers, len(pat))
    stack = lambda st, n: jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n,) + a.shape), st)
    layers = {
        f"{kind}_{i}": stack(_layer_state(cfg, kind, batch, max_len), n_full)
        for i, kind in enumerate(pat)
    }
    rem_states = tuple(_layer_state(cfg, pat[i], batch, max_len) for i in range(rem))
    cross = None
    if cfg.family == "encdec":
        kv, hd = cfg.n_kv_heads, cfg.hd
        # cross K/V placeholders (filled by prefill); enc_len sizes the
        # encoder context the dry-run assumes
        cross = (
            jnp.zeros((cfg.n_layers, batch, enc_len, kv, hd), cfg.compute_dtype),
            jnp.zeros((cfg.n_layers, batch, enc_len, kv, hd), cfg.compute_dtype),
            jnp.zeros((batch, enc_len), jnp.int32),
        )
    return DecodeState(
        step=jnp.asarray(step, jnp.int32), layers=layers, rem=rem_states, cross=cross
    )


def _layer_state_axes(cfg: ModelConfig, kind: str):
    """Logical sharding axes mirroring _layer_state's structure."""
    if kind in ("dense", "moe", "attn_local", "dec"):
        sc = ("batch", "cache_seq", "act_kv_heads") if cfg.kv_cache_dtype == "int8" else None
        return AttnCache(
            k=("batch", "cache_seq", "act_kv_heads", None),
            v=("batch", "cache_seq", "act_kv_heads", None),
            slot_pos=("batch", "cache_seq"),
            k_scale=sc,
            v_scale=sc,
        )
    if kind == "rec":
        from .rglru import RGLRUState

        return RGLRUState(h=("batch", "act_ssm_inner"), conv=("batch", None, "act_ssm_inner"))
    if kind == "ssd":
        from .ssd import SSDState

        return SSDState(h=("batch", "ssm_heads", None, None), conv=("batch", None, "act_ssm_inner"))
    raise ValueError(kind)


def decode_state_axes(cfg: ModelConfig) -> DecodeState:
    """Logical-axes tree matching init_decode_state (for in_shardings)."""
    pat = _pattern(cfg)
    n_full, rem = divmod(cfg.n_layers, len(pat))
    prepend = lambda st: jax.tree.map(
        lambda a: ("layers",) + a,
        st,
        is_leaf=lambda x: isinstance(x, tuple) and not hasattr(x, "_fields"),
    )
    layers = {
        f"{kind}_{i}": prepend(_layer_state_axes(cfg, kind)) for i, kind in enumerate(pat)
    }
    rem_axes = tuple(_layer_state_axes(cfg, pat[i]) for i in range(rem))
    cross = None
    if cfg.family == "encdec":
        cross = (
            ("layers", "batch", None, "act_kv_heads", None),
            ("layers", "batch", None, "act_kv_heads", None),
            ("batch", None),
        )
    return DecodeState(step=(), layers=layers, rem=rem_axes, cross=cross)


def _block_decode(p, cfg, kind, x, pos, state, cross_kv=None):
    if kind in ("dense", "moe", "attn_local", "dec"):
        window = cfg.window if kind != "dec" else None
        h = rms_norm(x, p["n1"], cfg.rms_eps)
        y, state = decode_attention(p["attn"], cfg, h, pos, state, window=window)
        x = x + y
        if kind == "dec":
            hx = rms_norm(x, p["nx"], cfg.rms_eps)
            yx, _ = decode_attention(p["xattn"], cfg, hx, pos, state, cross_kv=cross_kv)
            x = x + yx
        h2 = rms_norm(x, p["n2"], cfg.rms_eps)
        if kind == "moe":
            y2, _ = moe(p["moe"], cfg, h2, dropless=True)  # serving: no drops
        else:
            y2 = mlp(p["mlp"], cfg, h2)
        x = x + y2
    elif kind == "rec":
        h = rms_norm(x, p["n1"], cfg.rms_eps)
        y, state = rglru_decode_step(p["rec"], cfg, h, state)
        x = x + y
        h2 = rms_norm(x, p["n2"], cfg.rms_eps)
        x = x + mlp(p["mlp"], cfg, h2)
    elif kind == "ssd":
        h = rms_norm(x, p["n1"], cfg.rms_eps)
        y, state = ssd_decode_step(p["ssd"], cfg, h, state)
        x = x + y
    else:
        raise ValueError(kind)
    return x, state


def decode_step(params, cfg: ModelConfig, state: DecodeState, tokens: jax.Array):
    """One serving step: tokens (B, 1) -> (logits (B, vocab_padded), state')."""
    b = tokens.shape[0]
    pos = make_positions(b, 1, offset=state.step, mrope=cfg.mrope_sections is not None)
    x = _embed(params, cfg, tokens)
    pat = _pattern(cfg)

    if cfg.family == "encdec":
        ck, cv, cpos = state.cross

        def scan_fn(x, scanned):
            bp, st, k, v = scanned
            x, st2 = _block_decode(bp, cfg, "dec", x, pos, st, cross_kv=(k, v, cpos))
            return x, st2

        x, new_caches = jax.lax.scan(
            scan_fn, x, (params["blocks"]["dec_0"], state.layers["dec_0"], ck, cv)
        )
        new_layer_states = {"dec_0": new_caches}
    else:

        def scan_fn(x, scanned):
            bp, st = scanned
            new_states = {}
            for i, kind in enumerate(pat):
                key = f"{kind}_{i}"
                x, new_states[key] = _block_decode(bp[key], cfg, kind, x, pos, st[key])
            return x, new_states

        x, new_layer_states = jax.lax.scan(scan_fn, x, (params["blocks"], state.layers))

    new_rem = []
    for i, (bp, st) in enumerate(zip(params.get("rem", []), state.rem)):
        x, st2 = _block_decode(bp, cfg, pat[i], x, pos, st)
        new_rem.append(st2)

    logits = _logits(params, cfg, x)[:, 0]
    return logits, DecodeState(
        step=state.step + 1, layers=new_layer_states, rem=tuple(new_rem), cross=state.cross
    )


# --------------------------------------------------------------------------
# prefill
# --------------------------------------------------------------------------

def _fill_cache(cache: AttnCache, k, v, pos2d):
    """Place projected prompt K/V into a (ring) cache — scatter-free.

    Position p lives at slot p % w, so the last `take` positions form a
    cyclic shift: pad-to-w + roll covers every case without advanced-index
    scatter (which the SPMD partitioner can only realize by replicating the
    whole cache — measured at +hundreds of GB on 32k MHA prefills).
    """
    s = k.shape[1]
    w = cache.k.shape[1]
    take = min(w, s)
    shift = (s - take) % w

    def place(buf, new, fill):
        new = new[:, s - take :].astype(buf.dtype)
        if take < w:
            pad = [(0, 0)] * new.ndim
            pad[1] = (0, w - take)
            new = jnp.pad(new, pad, constant_values=fill)
        return jnp.roll(new, shift, axis=1) if shift else new

    if cache.k_scale is not None:  # int8 cache: quantize the prompt K/V
        from .attention import quantize_kv

        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        return AttnCache(
            k=place(cache.k, kq, 0), v=place(cache.v, vq, 0),
            slot_pos=place(cache.slot_pos, pos2d, -1),
            k_scale=place(cache.k_scale, ks, 0), v_scale=place(cache.v_scale, vs, 0),
        )
    return AttnCache(
        k=place(cache.k, k, 0),
        v=place(cache.v, v, 0),
        slot_pos=place(cache.slot_pos, pos2d, -1),
    )


def prefill(params, cfg: ModelConfig, batch, max_len: int):
    """Run the prompt; build the decode state. Returns (state, last_logits)."""
    tokens = batch["tokens"]
    b = tokens.shape[0]
    enc_ctx = None
    cross = None
    if cfg.family == "encdec":
        enc_out, enc_pos = _encode(params, cfg, batch["frames"])
        enc_ctx = (enc_out, enc_pos)
        k_stack, v_stack = jax.vmap(
            lambda p: project_kv_only(p["xattn"], cfg, enc_out)
        )(params["blocks"]["dec_0"])
        cross = (k_stack, v_stack, enc_pos)
    x = _embed(params, cfg, tokens)
    if cfg.family == "vlm" and batch.get("frames") is not None:
        x = jnp.concatenate([batch["frames"].astype(cfg.compute_dtype), x], axis=1)
    s = x.shape[1]
    pos = batch.get("positions")
    if pos is None:
        pos = make_positions(b, s, mrope=cfg.mrope_sections is not None)
    pos2d = pos if pos.ndim == 2 else pos[0]
    x, _, (side_stacks, rem_sides) = _run_stack(params, cfg, x, pos, enc_ctx, collect=True)
    # logits for the LAST position only (full-prompt logits at 32k x 100k
    # vocab would be tens of GB of f32)
    logits = _logits(params, cfg, x[:, -1:, :])[:, -1]

    state = init_decode_state(cfg, b, max_len, step=s)
    pat = _pattern(cfg)
    new_layers = {}
    for i, kind in enumerate(pat):
        key = f"{kind}_{i}"
        side = side_stacks[key]  # stacked over layers
        if kind in ("dense", "moe", "attn_local", "dec"):
            k_st, v_st = side  # (L, B, S, KV, D)
            new_layers[key] = jax.vmap(lambda c, k, v: _fill_cache(c, k, v, pos2d))(
                state.layers[key], k_st, v_st
            )
        else:
            new_layers[key] = side  # recurrent states, already stacked
    new_rem = []
    for i, side in enumerate(rem_sides):
        if pat[i] in ("dense", "moe", "attn_local", "dec"):
            new_rem.append(_fill_cache(state.rem[i], side[0], side[1], pos2d))
        else:
            new_rem.append(side)
    return (
        DecodeState(
            step=jnp.asarray(s, jnp.int32), layers=new_layers, rem=tuple(new_rem), cross=cross
        ),
        logits,
    )
