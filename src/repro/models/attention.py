"""Attention layers: GQA/MHA with RoPE / M-RoPE, qk-norm, QKV bias,
sliding-window & local masks, cross-attention, and a KV-cached decode path.

The training/prefill path uses *online-softmax chunked attention* (a
flash-attention-style lax.scan over KV chunks).  This keeps the live score
tensor at (B, H, S, chunk) instead of (B, H, S, S) — the difference between
fitting and not fitting prefill_32k on a v5e — and is the pure-JAX analogue
of the memory-hierarchy blocking a Pallas flash kernel would do (the MXU
einsums inside each chunk are already ideal XLA fusion targets).

GQA is computed in grouped form (no materialized head-replication of K/V).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from .common import Leaf, ModelConfig, apply_rope, dense_init, rms_norm

__all__ = ["init_attention", "attention", "decode_attention", "AttnCache"]

_NEG = -1e30


class AttnCache(NamedTuple):
    """KV cache, optionally int8-quantized.

    ``k``/``v`` are bf16 (scales None) or int8 with per-(batch, slot, head)
    f32 scales — KV quantization halves-to-quarters serving HBM, the lever
    that fits MHA archs (qwen1.5-32b: 5.5 TB of bf16 KV at batch 128 x 32k)
    on a pod.  Dequantization happens tile-wise inside the attention chunk
    scan, so no full-width bf16 copy ever materializes.
    """

    k: jax.Array  # (B, W, KV, D) bf16 | int8
    v: jax.Array  # (B, W, KV, D)
    slot_pos: jax.Array  # (B, W) int32 absolute position per slot, -1 = empty
    k_scale: Optional[jax.Array] = None  # (B, W, KV) f32 when int8
    v_scale: Optional[jax.Array] = None


def quantize_kv(x: jax.Array):
    """Per-(batch, slot, head) symmetric int8. x: (..., D) -> (int8, f32 scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-10)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def _deq(x: jax.Array, scale: Optional[jax.Array]):
    if scale is None:
        return x.astype(jnp.float32)
    return x.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


def init_attention(key, cfg: ModelConfig, d_in: Optional[int] = None):
    d = d_in or cfg.d_model
    hd, h, kv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), ("embed", "qkv"), cfg.param_dtype),
        "wk": dense_init(ks[1], (d, kv * hd), ("embed", "qkv"), cfg.param_dtype),
        "wv": dense_init(ks[2], (d, kv * hd), ("embed", "qkv"), cfg.param_dtype),
        "wo": dense_init(ks[3], (h * hd, d), ("qkv", "embed"), cfg.param_dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = Leaf(jnp.zeros((h * hd,), cfg.param_dtype), ("qkv",))
        p["bk"] = Leaf(jnp.zeros((kv * hd,), cfg.param_dtype), ("qkv",))
        p["bv"] = Leaf(jnp.zeros((kv * hd,), cfg.param_dtype), ("qkv",))
    if cfg.qk_norm:
        p["q_norm"] = Leaf(jnp.zeros((hd,), cfg.param_dtype), (None,))
        p["k_norm"] = Leaf(jnp.zeros((hd,), cfg.param_dtype), (None,))
    return p


def _project_qkv(p, cfg: ModelConfig, x, pos, rope: bool = True):
    b, s, _ = x.shape
    hd, h, kv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    dt = cfg.compute_dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, s, h, hd)
    k = (x @ p["wk"].astype(dt)).reshape(b, s, kv, hd)
    v = (x @ p["wv"].astype(dt)).reshape(b, s, kv, hd)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt).reshape(h, hd)
        k = k + p["bk"].astype(dt).reshape(kv, hd)
        v = v + p["bv"].astype(dt).reshape(kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.rms_eps)
        k = rms_norm(k, p["k_norm"], cfg.rms_eps)
    if rope:
        q = apply_rope(q, pos, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, pos if pos.ndim == 2 else pos, cfg.rope_theta, cfg.mrope_sections)
    # heads shard over 'model' when divisible; otherwise the higher-priority
    # head axis abstains and 'act_seq' picks up 'model' (sequence-parallel
    # attention for awkward head counts, e.g. recurrentgemma's 10 heads).
    q = hint(q, "batch", "act_seq", "act_heads", None)
    k = hint(k, "batch", "act_seq", "act_kv_heads", None)
    v = hint(v, "batch", "act_seq", "act_kv_heads", None)
    return q, k, v


def _chunked_gqa(q, k, v, q_pos, kv_pos, *, causal: bool, window: Optional[int], chunk: int,
                 k_scale=None, v_scale=None):
    """Online-softmax attention over KV chunks.

    q: (B, Sq, H, D); k, v: (B, Skv, KV, D) bf16 or int8 (with per-(B,S,KV)
    f32 scales); positions: (B, Sq) / (B, Skv).  Returns (B, Sq, H, D).
    """
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh  # GQA group size
    scale = d ** -0.5
    chunk = min(chunk, skv)
    if skv % chunk:  # pad KV to a chunk multiple; padded slots mask via pos=-1
        pad = chunk - skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
        skv += pad
    nc = skv // chunk

    qg = q.reshape(b, sq, kvh, g, d).astype(jnp.float32) * scale
    kc = k.reshape(b, nc, chunk, kvh, d)
    vc = v.reshape(b, nc, chunk, kvh, d)
    pc = kv_pos.reshape(b, nc, chunk)
    ksc = (
        k_scale.reshape(b, nc, chunk, kvh) if k_scale is not None else jnp.zeros((b, nc, chunk, 0))
    )
    vsc = (
        v_scale.reshape(b, nc, chunk, kvh) if v_scale is not None else jnp.zeros((b, nc, chunk, 0))
    )
    quantized = k_scale is not None

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def step(carry, inp):
        # rematerialized (flash-attention style): backward recomputes the
        # (B,Sq,KV,G,C) score tile from q/k instead of stashing one per chunk
        m, l, acc = carry  # (B,Sq,KV,G), (B,Sq,KV,G), (B,Sq,KV,G,D)
        kj, vj, pj, ksj, vsj = inp  # (B,C,KV,D) x2, (B,C), (B,C,KV) x2

        def compute(carry):
            m, l, acc = carry
            kjf = _deq(kj, ksj) if quantized else kj.astype(jnp.float32)
            vjf = _deq(vj, vsj) if quantized else vj.astype(jnp.float32)
            s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kjf)
            msk = jnp.ones((b, sq, chunk), bool)
            if causal:
                msk &= pj[:, None, :] <= q_pos[:, :, None]
            if window is not None:
                msk &= pj[:, None, :] > (q_pos[:, :, None] - window)
            msk &= pj[:, None, :] >= 0  # empty cache slots
            s = jnp.where(msk[:, :, None, None, :], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bqkgc,bckd->bqkgd", p, vjf)
            return m_new, l_new, acc_new

        # skip chunks that are fully masked for every query this device holds
        # (causal upper triangle / outside the sliding window / empty slots):
        # on TPU lax.cond executes one branch, reclaiming the ~2x causal
        # masking waste of dense chunked attention (hillclimb C3).
        live = pj >= 0
        if causal:
            live &= pj <= q_pos.max()
        if window is not None:
            live &= pj > q_pos.min() - window
        any_live = jnp.any(live)
        return jax.lax.cond(any_live, compute, lambda c: c, (m, l, acc)), None

    m0 = jnp.full((b, sq, kvh, g), _NEG, jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kvh, g, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step,
        (m0, l0, a0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), pc.transpose(1, 0, 2),
         ksc.transpose(1, 0, 2, 3), vsc.transpose(1, 0, 2, 3)),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def attention(
    p,
    cfg: ModelConfig,
    x: jax.Array,
    pos: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    kv_override: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None,
):
    """Full-sequence attention (training / prefill / encoder).

    ``kv_override`` = (k, v, kv_pos) enables cross-attention (decoder side).
    """
    dt = cfg.compute_dtype
    x = x.astype(dt)
    pos2d = pos if pos.ndim == 2 else pos[0]
    q, k, v = _project_qkv(p, cfg, x, pos, rope=kv_override is None or cfg.family != "encdec")
    if kv_override is not None:
        k, v, kv_pos = kv_override
    else:
        kv_pos = pos2d
    out = _chunked_gqa(q, k, v, pos2d, kv_pos, causal=causal, window=window, chunk=cfg.attn_chunk)
    out = hint(out, "batch", "seq", "act_heads", None)
    b, s = x.shape[:2]
    y = out.reshape(b, s, cfg.n_heads * cfg.hd) @ p["wo"].astype(dt)
    return hint(y, "batch", "seq", "act_embed")


def project_kv_only(p, cfg: ModelConfig, x: jax.Array):
    """K/V projection of encoder output for cross-attention (no RoPE)."""
    b, s, _ = x.shape
    dt = cfg.compute_dtype
    kv, hd = cfg.n_kv_heads, cfg.hd
    k = (x.astype(dt) @ p["wk"].astype(dt)).reshape(b, s, kv, hd)
    v = (x.astype(dt) @ p["wv"].astype(dt)).reshape(b, s, kv, hd)
    return k, v


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> AttnCache:
    dtype = dtype or (jnp.int8 if cfg.kv_cache_dtype == "int8" else cfg.compute_dtype)
    kv, hd = cfg.n_kv_heads, cfg.hd
    int8 = jnp.dtype(dtype) == jnp.dtype(jnp.int8)
    return AttnCache(
        k=jnp.zeros((batch, max_len, kv, hd), dtype),
        v=jnp.zeros((batch, max_len, kv, hd), dtype),
        slot_pos=jnp.full((batch, max_len), -1, jnp.int32),
        k_scale=jnp.zeros((batch, max_len, kv), jnp.float32) if int8 else None,
        v_scale=jnp.zeros((batch, max_len, kv), jnp.float32) if int8 else None,
    )


def decode_attention(
    p,
    cfg: ModelConfig,
    x: jax.Array,  # (B, 1, d)
    pos: jax.Array,  # (B, 1) or (3, B, 1) absolute position of the new token
    cache: AttnCache,
    *,
    window: Optional[int] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None,
):
    """Single-token attention against a (ring-buffered) KV cache.

    Windowed archs keep ``max_len == window`` and overwrite slots modulo the
    window — this is what makes long_500k decode O(window), not O(seq).
    Returns (y (B,1,d), new cache).
    """
    dt = cfg.compute_dtype
    x = x.astype(dt)
    pos2d = pos if pos.ndim == 2 else pos[0]
    q, k_new, v_new = _project_qkv(p, cfg, x, pos, rope=cross_kv is None)
    k_scale = v_scale = None
    if cross_kv is not None:
        k, v, kv_pos = cross_kv
        new_cache = cache
    else:
        w = cache.k.shape[1]
        # Decode batches advance in lockstep (slot identical across rows), so
        # the cache write is ONE dynamic_update_slice at a scalar slot — the
        # per-row vmap'd update lowers to scatter, which costs a full second
        # cache copy under SPMD (hillclimb B3, EXPERIMENTS.md §Perf).
        slot0 = pos2d[0, 0] % w
        zero = jnp.zeros((), slot0.dtype)

        def upd(buf, new):  # new: (B, 1, ...) -> write column `slot0`
            return jax.lax.dynamic_update_slice(
                buf, new.astype(buf.dtype), (zero, slot0) + (zero,) * (buf.ndim - 2)
            )


        if cache.k_scale is not None:  # int8 cache: quantize the new K/V
            kq, ks = quantize_kv(k_new)
            vq, vs = quantize_kv(v_new)
            new_cache = AttnCache(
                k=upd(cache.k, kq), v=upd(cache.v, vq),
                slot_pos=jax.lax.dynamic_update_slice(cache.slot_pos, pos2d, (zero, slot0)),
                k_scale=upd(cache.k_scale, ks), v_scale=upd(cache.v_scale, vs),
            )
            k_scale, v_scale = new_cache.k_scale, new_cache.v_scale
        else:
            new_cache = AttnCache(
                k=upd(cache.k, k_new),
                v=upd(cache.v, v_new),
                slot_pos=jax.lax.dynamic_update_slice(cache.slot_pos, pos2d, (zero, slot0)),
            )
        k, v, kv_pos = new_cache.k, new_cache.v, new_cache.slot_pos
    out = _chunked_gqa(
        q, k, v, pos2d, kv_pos,
        causal=cross_kv is None,  # cross-attention sees the whole encoder
        window=window, chunk=min(cfg.attn_chunk, k.shape[1]),
        k_scale=k_scale, v_scale=v_scale,
    )
    b = x.shape[0]
    y = out.reshape(b, 1, cfg.n_heads * cfg.hd) @ p["wo"].astype(dt)
    return y, new_cache
