"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

The SSD form computes the selective-SSM recurrence

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;   y_t = C_t . h_t + D x_t

in *chunks*: within a chunk the input-output map is an attention-like
lower-triangular matmul (MXU-friendly — this is the core reason SSD maps
well to TPU); across chunks a lax.scan carries the (H, P, N) state.  This is
the standard "minimal SSD" algorithm, expressed so only one chunk's
(L x L) decay matrix is ever live.

Decode is the O(1) recurrence update.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from .common import Leaf, ModelConfig, dense_init, rms_norm

__all__ = ["init_ssd_block", "ssd_block", "ssd_decode_step", "SSDState", "init_ssd_state"]


class SSDState(NamedTuple):
    h: jax.Array  # (B, H, P, N) SSM state
    conv: jax.Array  # (B, cw-1, conv_dim) conv tail


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    h = cfg.n_ssm_heads
    p = cfg.ssm_headdim
    n = cfg.ssm_state
    conv_dim = di + 2 * n  # conv over (x, B, C)
    return di, h, p, n, conv_dim


def init_ssd_block(key, cfg: ModelConfig):
    d = cfg.d_model
    di, h, p, n, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 6)
    in_dim = 2 * di + 2 * n + h  # z, x, B, C, dt
    dt_bias = jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
        ks[3], (h,), jnp.float32, jnp.log(1e-3), jnp.log(1e-1)))))
    return {
        "w_in": dense_init(ks[0], (d, in_dim), ("embed", "ssm_inner"), cfg.param_dtype),
        "w_out": dense_init(ks[1], (di, d), ("ssm_inner", "embed"), cfg.param_dtype),
        "conv_w": Leaf(
            jax.random.normal(ks[2], (cfg.conv_width, conv_dim), jnp.float32) / cfg.conv_width,
            ("conv", "ssm_inner"),
        ),
        "conv_b": Leaf(jnp.zeros((conv_dim,), jnp.float32), (None,)),
        "a_log": Leaf(jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)), ("ssm_heads",)),
        "dt_bias": Leaf(dt_bias, ("ssm_heads",)),
        "d_skip": Leaf(jnp.ones((h,), jnp.float32), ("ssm_heads",)),
        "out_norm": Leaf(jnp.zeros((di,), jnp.float32), (None,)),
    }


def _split_proj(p, cfg: ModelConfig, u: jax.Array):
    """u: (B,S,d) -> z (B,S,di), xbc (B,S,conv_dim), dt (B,S,H) pre-softplus."""
    di, h, _, n, conv_dim = _dims(cfg)
    dt_ = cfg.compute_dtype
    zxbcdt = u.astype(dt_) @ p["w_in"].astype(dt_)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + conv_dim]
    dt = zxbcdt[..., di + conv_dim :]
    return z, xbc, dt


def _ssd_chunked(x, dt, a, b, c, chunk: int, h0=None):
    """Chunked SSD scan.

    x: (B,S,H,P); dt: (B,S,H) (post-softplus); a: (H,) negative;
    b, c: (B,S,N) (single group, broadcast over heads).
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    bsz, s, nh, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    xc = x.reshape(bsz, nc, chunk, nh, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(bsz, nc, chunk, nh).transpose(1, 0, 2, 3)
    bc = b.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    cc = c.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    def step(hprev, inp):
        xj, dtj, bj, cj = inp  # (B,L,H,P), (B,L,H), (B,L,N), (B,L,N)
        da = dtj * a  # (B,L,H)
        dac = jnp.cumsum(da, axis=1)  # inclusive
        # intra-chunk: y_i += sum_{j<=i} (C_i.B_j) exp(dac_i - dac_j) dt_j x_j
        scores = jnp.einsum("bin,bjn->bij", cj, bj)
        decay = jnp.exp(dac[:, :, None, :] - dac[:, None, :, :])  # (B,L,L,H)
        decay = jnp.where(tri[None, :, :, None], decay, 0.0)
        y = jnp.einsum("bij,bijh,bjh,bjhp->bihp", scores, decay, dtj, xj)
        # + contribution of the incoming state
        y = y + jnp.einsum("bin,bhpn->bihp", cj, hprev) * jnp.exp(dac)[..., None]
        # state update to end of chunk
        dec_end = jnp.exp(dac[:, -1:, :] - dac)  # (B,L,H)
        hnew = hprev * jnp.exp(dac[:, -1])[:, :, None, None] + jnp.einsum(
            "bjn,bjh,bjhp->bhpn", bj, dtj * dec_end, xj
        )
        return hnew, y

    h0 = h0 if h0 is not None else jnp.zeros((bsz, nh, p, n), jnp.float32)
    hf, ys = jax.lax.scan(step, h0, (xc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, s, nh, p)
    return y, hf


def ssd_block(p, cfg: ModelConfig, u: jax.Array):
    """Sequence form. u: (B,S,d) -> ((B,S,d), final SSDState)."""
    from .rglru import _causal_conv  # same depthwise causal conv

    di, nh, hp, n, conv_dim = _dims(cfg)
    dt_ = cfg.compute_dtype
    z, xbc, dtp = _split_proj(p, cfg, u)
    conv_tail = xbc[:, -(cfg.conv_width - 1) :, :]  # decode conv state
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_)))
    x = xbc[..., :di]
    b = xbc[..., di : di + n]
    c = xbc[..., di + n :]
    bsz, s = x.shape[:2]
    xh = x.reshape(bsz, s, nh, hp).astype(jnp.float32)
    xh = hint(xh, "batch", "seq", "act_heads", None)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    # ragged sequences: pad to a chunk multiple with dt=0 steps (identity
    # recurrence: no decay, no input) so the final state is untouched.
    s_pad = (-s) % cfg.ssm_chunk
    if s_pad:
        pad3 = lambda t: jnp.pad(t, ((0, 0), (0, s_pad)) + ((0, 0),) * (t.ndim - 2))
        xh, dt, b, c = pad3(xh), pad3(dt), pad3(b), pad3(c)
    y, hf = _ssd_chunked(xh, dt, a, b.astype(jnp.float32), c.astype(jnp.float32), cfg.ssm_chunk)
    y = y + xh * p["d_skip"][None, None, :, None]
    if s_pad:
        y = y[:, :s]
    y = y.reshape(bsz, s, di).astype(dt_)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.rms_eps)
    out = hint(y @ p["w_out"].astype(dt_), "batch", "seq", "act_embed")
    return out, SSDState(h=hf, conv=conv_tail)


def init_ssd_state(cfg: ModelConfig, batch: int) -> SSDState:
    di, nh, hp, n, conv_dim = _dims(cfg)
    return SSDState(
        h=jnp.zeros((batch, nh, hp, n), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, conv_dim), cfg.compute_dtype),
    )


def ssd_decode_step(
    p, cfg: ModelConfig, u: jax.Array, state: SSDState
) -> Tuple[jax.Array, SSDState]:
    """Single-token form: O(1) state update. u: (B,1,d)."""
    di, nh, hp, n, conv_dim = _dims(cfg)
    dt_ = cfg.compute_dtype
    z, xbc, dtp = _split_proj(p, cfg, u)
    conv_in = jnp.concatenate([state.conv, xbc], axis=1)  # (B,cw,conv_dim)
    w = p["conv_w"].astype(dt_)
    xbc_c = sum(conv_in[:, i : i + 1, :] * w[i] for i in range(w.shape[0])) + p["conv_b"].astype(
        dt_
    )
    xbc_c = jax.nn.silu(xbc_c)
    x = xbc_c[..., :di].reshape(-1, nh, hp).astype(jnp.float32)  # (B,H,P)
    b = xbc_c[:, 0, di : di + n].astype(jnp.float32)  # (B,N)
    c = xbc_c[:, 0, di + n :].astype(jnp.float32)
    dt = jax.nn.softplus(dtp[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)  # (B,H)
    h = state.h * da[..., None, None] + jnp.einsum("bh,bn,bhp->bhpn", dt, b, x)
    y = jnp.einsum("bn,bhpn->bhp", c, h) + x * p["d_skip"][None, :, None]
    y = y.reshape(-1, 1, di).astype(dt_)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.rms_eps)
    return y @ p["w_out"].astype(dt_), SSDState(h=h, conv=conv_in[:, 1:, :])
