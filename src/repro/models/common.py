"""Shared model substrate: config, param machinery, norms, RoPE / M-RoPE.

Parameters are built as trees of ``Leaf(value, axes)`` where ``axes`` are the
*logical* sharding axes (distributed/sharding.py); ``split_tree`` separates
them into a plain value tree (what apply-functions consume) and an axes tree
(what the launcher turns into NamedShardings and the checkpointer stores as
layout metadata).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "ModelConfig",
    "Leaf",
    "split_tree",
    "dense_init",
    "rms_norm",
    "apply_rope",
    "make_positions",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config covers all 10 assigned architectures (see configs/)."""

    name: str
    family: str  # dense | moe | hybrid_rglru | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # attention flavor
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    window: Optional[int] = None  # sliding-window (mixtral SWA / rg local attn)
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    # MoE
    n_experts: int = 0
    moe_top_k: int = 2
    capacity_factor: float = 1.25
    moe_group_size: int = 512
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    # hybrid (recurrentgemma)
    block_pattern: Tuple[str, ...] = ()
    lru_width: Optional[int] = None
    conv_width: int = 4
    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 64
    # encoder-decoder
    n_enc_layers: int = 0
    frontend: Optional[str] = None  # 'audio' | 'vision' (stub: embeddings given)
    # numerics / execution
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    remat: str = "full"  # 'none' | 'full'
    scan_layers: bool = True
    vocab_round: int = 128
    # attention micro-tiling (online-softmax KV chunk)
    attn_chunk: int = 1024
    kv_cache_dtype: str = "bf16"  # 'bf16' | 'int8' (quantized serving cache)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        return -(-self.vocab // self.vocab_round) * self.vocab_round

    @property
    def d_inner(self) -> int:  # mamba2
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def params_count_note(self) -> str:
        return f"{self.name}: {self.n_layers}L d={self.d_model}"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Leaf:
    """Parameter leaf with logical sharding axes metadata."""

    value: jax.Array
    axes: Tuple[Optional[str], ...]

    def tree_flatten(self):
        return (self.value,), (self.axes,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])


def split_tree(tree):
    """Tree of Leaf -> (values tree, axes tree)."""
    leaves_with_path = jax.tree.leaves(tree, is_leaf=lambda x: isinstance(x, Leaf))
    values = jax.tree.map(lambda l: l.value, tree, is_leaf=lambda x: isinstance(x, Leaf))
    axes = jax.tree.map(lambda l: l.axes, tree, is_leaf=lambda x: isinstance(x, Leaf))
    del leaves_with_path
    return values, axes


def dense_init(key, shape, axes, dtype, scale: Optional[float] = None) -> Leaf:
    """Truncated-normal fan-in init with logical axes."""
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    v = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * s
    return Leaf(v.astype(dtype), tuple(axes))


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """RMSNorm with f32 statistics but no full-width f32 copy of x.

    The mean-square runs as an einsum with f32 accumulation: bf16 x bf16
    products are exact in f32, so the statistic matches the classic
    upcast-everything formulation to accumulation order.  Keeping x itself
    in bf16 matters structurally: if the first use of the residual stream
    were ``x.astype(f32)``, XLA hoists that convert out of the backward
    layer loop and materializes an f32 copy of the *entire* saved
    activation stack (measured: +7 GiB/device on qwen3-0.6b train_4k —
    EXPERIMENTS.md §Perf).
    """
    var = (
        jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32)[..., None]
        / x.shape[-1]
    )
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)  # (B,S,1): tiny in any dtype
    return x * inv * (1.0 + scale).astype(x.dtype)


def _rope_freqs(hd_half: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(hd_half, dtype=jnp.float32) / hd_half))


def apply_rope(
    x: jax.Array,
    pos: jax.Array,
    theta: float,
    sections: Optional[Tuple[int, int, int]] = None,
) -> jax.Array:
    """Rotary embedding, half-split convention.

    x: (B, S, H, D).  pos: (B, S) int32, or (3, B, S) for M-RoPE where the
    three planes are (temporal, height, width) position ids and ``sections``
    partitions the D/2 frequency slots among them (qwen2-vl).
    """
    b, s, h, d = x.shape
    half = d // 2
    freqs = _rope_freqs(half, theta)  # (half,)
    if sections is None:
        angles = pos.astype(jnp.float32)[..., None] * freqs  # (B, S, half)
    else:
        assert pos.ndim == 3, "M-RoPE needs (3, B, S) positions"
        secs = []
        off = 0
        for i, w in enumerate(sections):
            secs.append(pos[i].astype(jnp.float32)[..., None] * freqs[off : off + w])
            off += w
        assert off == half, f"mrope sections {sections} must sum to {half}"
        angles = jnp.concatenate(secs, axis=-1)  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def make_positions(batch: int, seq: int, offset=0, mrope: bool = False) -> jax.Array:
    p = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    p = jnp.broadcast_to(p, (batch, seq))
    if mrope:
        return jnp.broadcast_to(p[None], (3, batch, seq))
    return p
