"""MLP blocks: SwiGLU, GELU, and token-choice top-K MoE.

The MoE uses GShard/Switch-style capacity-based dispatch expressed entirely
as einsums (dispatch/combine one-hots), which partitions cleanly: tokens are
grouped along the batch*seq dim (groups sharded over ('pod','data')), experts
are sharded over 'model' (expert parallelism).  When the expert count does
not divide the model axis (mixtral: 8 experts on a 16-wide axis) the rule
engine falls back to tensor-parallel experts (d_ff over 'model') — see
distributed/sharding.py.

Routing is standard top-k softmax gating with capacity dropping (tokens over
capacity fall through on the residual path) and an auxiliary load-balancing
loss (Switch §2.2), returned to the trainer.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from .common import Leaf, ModelConfig, dense_init

__all__ = ["init_mlp", "mlp", "init_moe", "moe"]


def init_mlp(key, cfg: ModelConfig, d_ff=None, d_in=None):
    d, f = d_in or cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wi": dense_init(ks[0], (d, f), ("embed", "mlp"), cfg.param_dtype),
        "wg": dense_init(ks[1], (d, f), ("embed", "mlp"), cfg.param_dtype),
        "wo": dense_init(ks[2], (f, d), ("mlp", "embed"), cfg.param_dtype),
    }


def mlp(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    dt = cfg.compute_dtype
    x = x.astype(dt)
    h = jax.nn.silu(x @ p["wg"].astype(dt)) * (x @ p["wi"].astype(dt))
    h = hint(h, "batch", "seq", "act_mlp")
    return hint(h @ p["wo"].astype(dt), "batch", "seq", "act_embed")


def init_moe(key, cfg: ModelConfig):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), ("embed", None), jnp.float32),
        "wi": dense_init(ks[1], (e, d, f), ("expert", "embed", "moe_mlp"), cfg.param_dtype),
        "wg": dense_init(ks[2], (e, d, f), ("expert", "embed", "moe_mlp"), cfg.param_dtype),
        "wo": dense_init(ks[3], (e, f, d), ("expert", "moe_mlp", "embed"), cfg.param_dtype),
    }
    if cfg.dense_residual:  # arctic: dense MLP in parallel with the MoE
        p["dense"] = init_mlp(ks[4], cfg)
    return p


def moe(p, cfg: ModelConfig, x: jax.Array, dropless: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,d), aux load-balance loss scalar).

    ``dropless=True`` (serving): capacity = 4x the fair share (vs the
    training capacity factor ~1.25), so drops are negligible without the
    quadratic (group, group) dispatch tensor a cap == group_size would cost
    — capacity-based MoE otherwise skews between the batched training pass
    and single-token decode (a real train/serve consistency hazard; see
    DESIGN.md §5).  Groups smaller than ~2x experts use full capacity
    (single-token decode: exactness is free there).
    """
    dt = cfg.compute_dtype
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    gs = min(cfg.moe_group_size, b * s)
    ng = (b * s) // gs
    assert ng * gs == b * s, f"tokens {b*s} % group {gs}"
    if dropless:
        cap = gs if gs <= 2 * e else min(gs, int(gs * k / e * 4.0) + 1)
    else:
        cap = min(gs, int(gs * k / e * cfg.capacity_factor) + 1)

    xt = x.reshape(ng, gs, d)
    xt = hint(xt, "batch", None, "act_embed")
    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (ng, gs, e)
    probs = jax.nn.softmax(logits, axis=-1)

    # top-k routing with capacity: iteratively take the argmax k times.
    gates = jnp.zeros_like(probs)
    rem = probs
    for _ in range(k):
        idx = jnp.argmax(rem, axis=-1)
        oh = jax.nn.one_hot(idx, e, dtype=probs.dtype)
        gates = gates + rem * oh
        rem = rem * (1.0 - oh)
    mask = gates > 0.0

    # capacity assignment: position of each token within its expert's queue
    pos_in_e = jnp.cumsum(mask.astype(jnp.int32), axis=1) - 1  # (ng, gs, e)
    keep = mask & (pos_in_e < cap)
    gates = jnp.where(keep, gates, 0.0)
    denom = jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    gates = gates / denom  # renormalize kept top-k weights

    # dispatch/combine one-hots (GShard): (ng, gs, e, cap)
    cap_oh = jax.nn.one_hot(jnp.where(keep, pos_in_e, -1), cap, dtype=dt)
    dispatch = cap_oh  # bool-ish
    combine = gates[..., None].astype(dt) * cap_oh

    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xt.astype(dt))  # (ng,e,cap,d)
    xe = hint(xe, "batch", "act_expert", None, None)
    hg = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["wg"].astype(dt)))
    hi = jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(dt))
    ye = jnp.einsum("gecf,efd->gecd", hg * hi, p["wo"].astype(dt))
    ye = hint(ye, "batch", "act_expert", None, None)
    y = jnp.einsum("gsec,gecd->gsd", combine, ye).reshape(b, s, d)

    # Switch aux loss: e * sum_e (fraction routed to e) * (mean router prob e)
    frac = mask.astype(jnp.float32).mean(axis=(0, 1)) / k
    imp = probs.mean(axis=(0, 1))
    aux = e * jnp.sum(frac * imp)

    if cfg.dense_residual:
        y = y + mlp(p["dense"], cfg, x)
    return hint(y, "batch", "seq", "act_embed"), aux
