"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: x -> [gate branch: W_gate -> GeLU] * [rec branch: W_rec -> causal
depthwise conv1d(4) -> RG-LRU] -> W_out.

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(-c * softplus(L) * r_t)       c = 8, L learnable
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The sequence form uses ``jax.lax.associative_scan`` over the (a, b) linear
recurrence — O(log S) depth, MXU/VPU friendly, and the natural TPU analogue
of the CUDA linear-scan kernels the Griffin paper uses.  The decode form is
the O(1) single-step update carrying h.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import hint
from .common import Leaf, ModelConfig, dense_init

__all__ = ["init_rglru_block", "rglru_block", "rglru_decode_step", "RGLRUState"]

_C = 8.0


class RGLRUState(NamedTuple):
    h: jax.Array  # (B, W) recurrence state
    conv: jax.Array  # (B, cw-1, W) conv tail


def init_rglru_block(key, cfg: ModelConfig):
    d, w, cw = cfg.d_model, cfg.lru_width or cfg.d_model, cfg.conv_width
    ks = jax.random.split(key, 7)
    # Lambda init so that a = exp(-c*softplus(L)) lands in [0.9, 0.999).
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # inverse softplus
    return {
        "w_gate": dense_init(ks[0], (d, w), ("embed", "lru"), cfg.param_dtype),
        "w_rec": dense_init(ks[1], (d, w), ("embed", "lru"), cfg.param_dtype),
        "w_out": dense_init(ks[2], (w, d), ("lru", "embed"), cfg.param_dtype),
        # gate weights shard on the OUTPUT width only: contracting over a
        # 'model'-sharded input width costs two f32 all-reduces per layer
        # (hillclimb A3, EXPERIMENTS.md §Perf)
        "w_a": dense_init(ks[3], (w, w), (None, "lru"), cfg.param_dtype, scale=0.0),
        "w_x": dense_init(ks[4], (w, w), (None, "lru"), cfg.param_dtype, scale=0.0),
        "b_a": Leaf(jnp.zeros((w,), jnp.float32), (None,)),
        "b_x": Leaf(jnp.zeros((w,), jnp.float32), (None,)),
        "lam": Leaf(lam, (None,)),
        "conv_w": Leaf(
            jax.random.normal(ks[6], (cw, w), jnp.float32) * (1.0 / cw), ("conv", "lru")
        ),
        "conv_b": Leaf(jnp.zeros((w,), jnp.float32), (None,)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array, tail: jax.Array = None):
    """Depthwise causal conv1d; x: (B,S,W), w: (cw,W). tail: (B,cw-1,W)."""
    cw = w.shape[0]
    pad = tail if tail is not None else jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(cw))
    return y + b


def _gates(p, xr: jax.Array):
    """Returns (log_a (B,S,W) f32, gated input (B,S,W) f32)."""
    xf = xr.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_x"].astype(jnp.float32) + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-9)) * (i * xf)
    return log_a, b


def rglru_block(p, cfg: ModelConfig, x: jax.Array):
    """Sequence form. x: (B,S,d) -> ((B,S,d), final RGLRUState)."""
    dt = cfg.compute_dtype
    x = x.astype(dt)
    gate = jax.nn.gelu(x @ p["w_gate"].astype(dt))
    xr = x @ p["w_rec"].astype(dt)
    xr = hint(xr, "batch", "act_seq", None)
    conv_tail = xr[:, -(cfg.conv_width - 1) :, :]  # raw inputs: decode conv state
    xr = _causal_conv(xr, p["conv_w"].astype(dt), p["conv_b"].astype(dt))
    log_a, b = _gates(p, xr)
    # The recurrence is elementwise across the LRU width: shard the f32
    # gate/state tensors over 'model' so each device scans its channel slice
    # (without this the (B,S,W) f32 intermediates replicate per device).
    log_a = hint(log_a, "batch", "act_seq", None)
    b = hint(b, "batch", "act_seq", None)
    # associative linear recurrence: h_t = a_t h_{t-1} + b_t
    a = jnp.exp(log_a)

    def combine(u, v):
        a1, b1 = u
        a2, b2 = v
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = hint(h, "batch", "act_seq", None)
    y = (h.astype(dt) * gate) @ p["w_out"].astype(dt)
    state = RGLRUState(h=h[:, -1], conv=conv_tail)
    return hint(y, "batch", "seq", "act_embed"), state


def init_rglru_state(cfg: ModelConfig, batch: int) -> RGLRUState:
    w = cfg.lru_width or cfg.d_model
    return RGLRUState(
        h=jnp.zeros((batch, w), jnp.float32),
        conv=jnp.zeros((batch, cfg.conv_width - 1, w), cfg.compute_dtype),
    )


def rglru_decode_step(
    p, cfg: ModelConfig, x: jax.Array, state: RGLRUState
) -> Tuple[jax.Array, RGLRUState]:
    """Single-token form. x: (B,1,d) -> (B,1,d); O(1) in sequence length."""
    dt = cfg.compute_dtype
    x = x.astype(dt)
    gate = jax.nn.gelu(x @ p["w_gate"].astype(dt))
    xr = x @ p["w_rec"].astype(dt)  # (B,1,W)
    conv_in = jnp.concatenate([state.conv, xr], axis=1)  # (B,cw,W)
    w = p["conv_w"].astype(dt)
    xr_c = sum(conv_in[:, i : i + 1, :] * w[i] for i in range(w.shape[0])) + p["conv_b"].astype(dt)
    log_a, b = _gates(p, xr_c)
    h = jnp.exp(log_a[:, 0]) * state.h + b[:, 0]
    y = (h[:, None, :].astype(dt) * gate) @ p["w_out"].astype(dt)
    return y, RGLRUState(h=h, conv=conv_in[:, 1:, :])
