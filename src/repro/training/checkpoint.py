"""Sharding-aware, elastic, atomic checkpointing (no orbax, offline env).

Layout:  <dir>/step_<N>/manifest.json + arrays/<i>.npy (one per leaf).
Arrays are stored *logically* (fully gathered), so a checkpoint written on a
4-device mesh restores onto 1, 8, or 512 devices — elastic restart is just
``load(..., sharding_fn)`` resharding each leaf at device_put time.

Write protocol: write into ``<dir>/.tmp_step_<N>`` then ``os.rename`` —
a crash mid-save never corrupts the latest checkpoint (preemption safety).
Optional async mode hands the gathered host arrays to a writer thread so the
training loop resumes immediately (overlap of I/O with compute).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step", "CheckpointManager"]


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save_checkpoint(directory: str, step: int, tree, extra: Optional[dict] = None,
                    async_write: bool = False) -> threading.Thread | None:
    os.makedirs(directory, exist_ok=True)
    names, leaves, _ = _flatten_with_names(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]  # gather now

    def _write():
        tmp = os.path.join(directory, f".tmp_step_{step:08d}")
        final = os.path.join(directory, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(os.path.join(tmp, "arrays"))
        manifest = {"step": step, "leaves": [], "extra": extra or {}}
        for i, (name, arr) in enumerate(zip(names, host_leaves)):
            np.save(os.path.join(tmp, "arrays", f"{i}.npy"), arr)
            manifest["leaves"].append(
                {"i": i, "name": name, "shape": list(arr.shape), "dtype": str(arr.dtype)}
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish

    if async_write:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and os.path.exists(os.path.join(directory, d, "manifest.json"))
    ]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, template,
                    sharding_fn: Optional[Callable[[str, np.ndarray], Any]] = None):
    """Restore a pytree.  ``sharding_fn(name, arr) -> Sharding | None`` lets the
    caller reshard each leaf for the *current* mesh (elastic restart)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    names, leaves, treedef = _flatten_with_names(template)
    assert len(names) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, template {len(names)}"
    )
    by_name = {l["name"]: l for l in manifest["leaves"]}
    out = []
    for name, tmpl in zip(names, leaves):
        rec = by_name[name]
        arr = np.load(os.path.join(path, "arrays", f"{rec['i']}.npy"))
        shard = sharding_fn(name, arr) if sharding_fn else None
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.device_put(arr.astype(tmpl.dtype) if hasattr(tmpl, "dtype") else arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


class CheckpointManager:
    """keep_n retention + resume + async writes."""

    def __init__(self, directory: str, keep_n: int = 3, async_write: bool = False):
        self.directory = directory
        self.keep_n = keep_n
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None

    def save(self, step: int, tree, extra: Optional[dict] = None):
        self.wait()
        self._pending = save_checkpoint(
            self.directory, step, tree, extra, async_write=self.async_write
        )
        if not self.async_write:
            self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None
            self._gc()

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory) if d.startswith("step_")
        )
        for s in steps[: -self.keep_n]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    def restore_latest(self, template, sharding_fn=None):
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, None, None
        tree, extra = load_checkpoint(self.directory, step, template, sharding_fn)
        return step, tree, extra
