"""AdamW + warmup-cosine schedule + global-norm clipping (no optax, per the
offline environment).  Optimizer state is a pytree shaped like the params, so
it inherits the params' NamedShardings (FSDP'd m/v — ZeRO-style)."""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "OptState", "init_opt_state", "adamw_update", "lr_at"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: jax.Array  # () int32
    m: Any  # first moment (params-shaped, f32)
    v: Any  # second moment


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def lr_at(cfg: OptConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


class FactoredState(NamedTuple):
    """Adafactor-style second-moment factorization (Shazeer & Stern 2018).

    For an N-D parameter (..., n, m) the second moment is stored as row/col
    running means (..., n) and (..., m) instead of the full (..., n, m) —
    the lever that fits a 480B-parameter optimizer state on one pod
    (AdamW's full f32 m+v for arctic-480b needs 5.8 TB > a 4 TB v5e pod).
    """

    step: jax.Array
    vr: Any  # row second moments (or full v for vectors/scalars)
    vc: Any  # col second moments (None-shaped zeros for vectors)


def init_factored_state(params) -> FactoredState:
    def rows(p):
        return (
            jnp.zeros(p.shape[:-1], jnp.float32) if p.ndim >= 2 else jnp.zeros(p.shape, jnp.float32)
        )

    def cols(p):
        return (
            jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            if p.ndim >= 2
            else jnp.zeros((), jnp.float32)
        )

    return FactoredState(
        step=jnp.zeros((), jnp.int32),
        vr=jax.tree.map(rows, params),
        vc=jax.tree.map(cols, params),
    )


def adafactor_update(
    grads, state: FactoredState, params, cfg: OptConfig
) -> Tuple[Any, FactoredState, dict]:
    """Adafactor (no momentum, fixed beta2) with update clipping."""
    step = state.step + 1
    gnorm = global_norm(grads)
    b2 = cfg.b2
    lr = lr_at(cfg, step)

    def upd_slice(p, g, vr, vc):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            vr2 = b2 * vr + (1 - b2) * g2.mean(axis=-1)
            vc2 = b2 * vc + (1 - b2) * g2.mean(axis=-2)
            denom = vr2.mean(axis=-1, keepdims=True)
            vhat = (vr2 / jnp.maximum(denom, 1e-30))[..., None] * vc2[..., None, :]
            u = g / (jnp.sqrt(vhat) + cfg.eps)
        else:
            vr2 = b2 * vr + (1 - b2) * g2
            vc2 = vc
            u = g / (jnp.sqrt(vr2) + cfg.eps)
        # update clipping by RMS (Adafactor's d=1.0 rule)
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u)
        new_p = p.astype(jnp.float32) - lr * (u + cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), vr2, vc2

    def upd(p, g, vr, vc):
        # Stacked-layer (or expert) leaves: scan the update over the leading
        # axis so the f32 temporaries are one slice, not the whole stack —
        # at 480B params the difference between ~10 GiB and ~0.3 GiB of
        # optimizer scratch per device.
        if p.ndim >= 3 and p.shape[0] > 1:
            def body(_, sl):
                return None, upd_slice(*sl)

            _, (np_, vr2, vc2) = jax.lax.scan(body, None, (p, g, vr, vc))
            return np_, vr2, vc2
        return upd_slice(p, g, vr, vc)

    out = jax.tree.map(upd, params, grads, state.vr, state.vc)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    vr = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    vc = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, FactoredState(step=step, vr=vr, vc=vc), {"grad_norm": gnorm, "lr": lr}


def adamw_update(grads, state: OptState, params, cfg: OptConfig) -> Tuple[Any, OptState, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g, state.v, grads)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = lr_at(cfg, step)

    def upd(p, mm, vv):
        mhat = mm / bc1
        vhat = vv / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, OptState(step=step, m=m, v=v), {"grad_norm": gnorm, "lr": lr}
