"""Deterministic synthetic LM data pipeline.

Zipf-distributed token streams with a planted bigram structure (so the loss
has real signal to minimize — overfit tests and the ~100M-token example
driver need learnable data, not uniform noise).  Batches are derived purely
from (seed, step, host), so every host of a multi-pod job can regenerate its
shard independently (no data server), and a restarted job resumes the stream
exactly — checkpoint/restart reproducibility.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.common import ModelConfig

__all__ = ["DataConfig", "synthetic_batch", "data_stream"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.3
    num_hosts: int = 1
    host_id: int = 0


def _zipf_tokens(rng: np.random.Generator, shape, vocab: int, a: float) -> np.ndarray:
    """Bounded zipf via inverse-CDF (np.random.zipf is unbounded)."""
    ranks = np.arange(1, min(vocab, 65536) + 1, dtype=np.float64)
    p = ranks ** (-a)
    cdf = np.cumsum(p / p.sum())
    u = rng.random(shape)
    return np.searchsorted(cdf, u).astype(np.int32) % vocab


def synthetic_batch(cfg: ModelConfig, dc: DataConfig, step: int) -> Dict[str, jax.Array]:
    """One batch. Planted structure: every token at odd position repeats a
    deterministic function of its predecessor (learnable bigrams)."""
    rng = np.random.default_rng((dc.seed * 1_000_003 + step) * 97 + dc.host_id)
    b = dc.batch // dc.num_hosts
    s = dc.seq_len
    toks = _zipf_tokens(rng, (b, s), cfg.vocab, dc.zipf_a)
    # plant bigram signal: t[2i+1] = (t[2i] * 7 + 13) % vocab
    toks[:, 1::2] = (toks[:, 0::2] * 7 + 13) % cfg.vocab
    batch = {
        "tokens": jnp.asarray(toks),
        "labels": jnp.asarray(np.concatenate([toks[:, 1:], toks[:, :1]], axis=1)),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)).astype(np.float32) * 0.02
        )
    if cfg.family == "vlm":
        npatch = max(1, s // 8)
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, npatch, cfg.d_model)).astype(np.float32) * 0.02
        )
        lbl = np.concatenate(
            [np.full((b, npatch), -1, np.int32), np.asarray(batch["labels"])], axis=1
        )
        batch["labels"] = jnp.asarray(lbl)
    return batch


def data_stream(cfg: ModelConfig, dc: DataConfig, start_step: int = 0) -> Iterator[Dict]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, dc, step)
        step += 1
