"""Gradient compression for the data-parallel reduction.

Per-tensor symmetric int8 quantization with an error-feedback residual kept
as a function-local invariant (stateless form: quantize -> dequantize before
the reduction, the quantization error is re-injected into the *same* step's
update, which keeps the step unbiased to first order).  On a real pod this
halves-to-quarters the DP all-reduce bytes; the dry-run's collective-bytes
parser shows the reduction (EXPERIMENTS.md §Perf).

A stateful error-feedback variant (`EFState`) is provided for the classic
Seide et al. formulation where the residual is carried across steps.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["quantize_int8", "dequantize_int8", "compress_tree", "EFState", "ef_compress_tree"]


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any) -> Any:
    """Quantize-dequantize every leaf (simulates int8 on the wire)."""

    def qdq(g):
        g32 = g.astype(jnp.float32)
        q, s = quantize_int8(g32)
        return dequantize_int8(q, s).astype(g.dtype)

    return jax.tree.map(qdq, grads)


class EFState(NamedTuple):
    residual: Any  # params-shaped error-feedback buffers


def init_ef_state(params) -> EFState:
    return EFState(residual=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))


def ef_compress_tree(grads: Any, ef: EFState) -> Tuple[Any, EFState]:
    """Classic error feedback: compress (g + residual), carry the error."""

    def step(g, r):
        x = g.astype(jnp.float32) + r
        q, s = quantize_int8(x)
        dq = dequantize_int8(q, s)
        return dq.astype(g.dtype), x - dq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(ef.residual)
    outs = [step(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_r = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, EFState(residual=new_r)
