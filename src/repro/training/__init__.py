from .checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from .data import DataConfig, data_stream, synthetic_batch
from .optimizer import OptConfig, adamw_update, init_opt_state
from .train_loop import TrainConfig, Trainer, make_train_step
