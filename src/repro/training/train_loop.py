"""Train-step factory and the fault-tolerant training loop.

``make_train_step`` builds a pure (params, opt_state, batch) -> (params,
opt_state, metrics) function with optional gradient accumulation and optional
int8+error-feedback gradient compression on the data-parallel reduction; it
is what the launcher jits with in/out shardings and what the multi-pod
dry-run lowers.

``Trainer`` wraps it with the production concerns (DESIGN.md §5): periodic
atomic checkpoints (async), NaN/inf rollback, preemption-safe resume,
straggler detection, and the paper-integration spectral monitor (top-K
Hessian eigenvalues through the Lanczos core).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from ..models.common import ModelConfig
from ..models.model import loss_fn
from .checkpoint import CheckpointManager
from .optimizer import (
    FactoredState,
    OptConfig,
    OptState,
    adafactor_update,
    adamw_update,
    init_factored_state,
    init_opt_state,
)

__all__ = ["TrainConfig", "make_train_step", "Trainer"]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    accum_steps: int = 1
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_n: int = 3
    async_ckpt: bool = True
    straggler_factor: float = 3.0
    spectral_every: int = 0  # 0 = off; else compute top-K Hessian eigs
    spectral_k: int = 4
    compress_grads: bool = False  # int8 + error feedback on the DP reduction
    optimizer: str = "adamw"  # 'adamw' | 'adafactor' (factored 2nd moment)
    accum_dtype: Any = None  # grad-accumulation dtype; None -> f32


def make_train_step(cfg: ModelConfig, tc: TrainConfig) -> Callable:
    """Pure SPMD train step (grad accumulation via scan over microbatches)."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, cfg, batch)
        return loss, metrics, grads

    def train_step(params, opt_state: OptState, batch):
        if tc.accum_steps > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                loss, _, grads = grads_of(params, mb)
                return (
                    jax.tree.map(lambda a, g: a + g.astype(a.dtype), gsum, grads),
                    lsum + loss,
                ), None

            def split_mb(key, x):
                # batch axis: 1 for M-RoPE positions (3, B, S), else 0
                ax = 1 if key == "positions" else 0
                b = x.shape[ax]
                shp = x.shape[:ax] + (tc.accum_steps, b // tc.accum_steps) + x.shape[ax + 1 :]
                return jnp.moveaxis(x.reshape(shp), ax, 0)

            mbs = {k: split_mb(k, v) for k, v in batch.items()}
            adt = tc.accum_dtype or jnp.float32
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / tc.accum_steps, gsum)
            loss = lsum / tc.accum_steps
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)

        if tc.compress_grads:
            from .compression import compress_tree

            grads = compress_tree(grads)

        if tc.optimizer == "adafactor":
            new_params, new_opt, opt_metrics = adafactor_update(grads, opt_state, params, tc.opt)
        else:
            new_params, new_opt, opt_metrics = adamw_update(grads, opt_state, params, tc.opt)
        out = {"loss": loss, **metrics, **opt_metrics}
        return new_params, new_opt, out

    return train_step


class Trainer:
    """Fault-tolerant host loop around the jitted step function."""

    def __init__(
        self,
        cfg: ModelConfig,
        tc: TrainConfig,
        params,
        step_fn: Optional[Callable] = None,
        probe_batch_fn: Optional[Callable[[], Dict]] = None,
    ):
        self.cfg = cfg
        self.tc = tc
        # own the buffers: the jitted step donates (params, opt_state), which
        # would otherwise invalidate the caller's arrays after step 1
        self.params = jax.tree.map(jnp.copy, params)
        self.opt_state = (
            init_factored_state(self.params) if tc.optimizer == "adafactor"
            else init_opt_state(self.params)
        )
        self.step_fn = step_fn or jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
        self.ckpt = CheckpointManager(tc.ckpt_dir, keep_n=tc.keep_n, async_write=tc.async_ckpt)
        self.step = 0
        self.rollbacks = 0
        self.straggler_events = []
        self.spectra: Dict[int, Any] = {}
        self._probe_batch_fn = probe_batch_fn
        self._ema_dt = None

    # ---- fault tolerance ----
    def try_resume(self):
        tmpl = {"params": self.params, "opt": self.opt_state}
        step, tree, extra = self.ckpt.restore_latest(tmpl)
        if step is not None:
            self.params = tree["params"]
            self.opt_state = tree["opt"]
            self.step = step
            return True
        return False

    def _checkpoint(self):
        self.ckpt.save(self.step, {"params": self.params, "opt": self.opt_state},
                       extra={"rollbacks": self.rollbacks})

    def _rollback(self):
        """NaN/inf loss: restore last good checkpoint and skip forward."""
        tmpl = {"params": self.params, "opt": self.opt_state}
        step, tree, _ = self.ckpt.restore_latest(tmpl)
        if step is None:
            raise RuntimeError("non-finite loss before any checkpoint exists")
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = step
        self.rollbacks += 1

    def _spectral_probe(self):
        """Paper integration: top-K eigenvalues of the loss Hessian via the
        mixed-precision Lanczos core (matrix-free HVP operator)."""
        from .spectral import hessian_topk

        batch = self._probe_batch_fn()
        evals = hessian_topk(self.params, self.cfg, batch, k=self.tc.spectral_k)
        self.spectra[self.step] = evals

    # ---- main loop ----
    def run(self, stream: Iterator[Dict], num_steps: int, log_every: int = 10,
            log_fn: Callable = print):
        if self.step == 0:
            self._checkpoint()  # step-0 anchor for rollback
        history = []
        for batch in stream:
            if self.step >= num_steps:
                break
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(self.params, self.opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            # straggler detection (per-step time watchdog)
            if self._ema_dt is not None and dt > self.tc.straggler_factor * self._ema_dt:
                self.straggler_events.append((self.step, dt, self._ema_dt))
            self._ema_dt = dt if self._ema_dt is None else 0.9 * self._ema_dt + 0.1 * dt

            if not jnp.isfinite(loss):
                log_fn(f"step {self.step}: non-finite loss ({loss}); rolling back")
                self._rollback()
                continue
            self.step += 1
            history.append(loss)
            if self.step % self.tc.ckpt_every == 0:
                self._checkpoint()
            if self.tc.spectral_every and self.step % self.tc.spectral_every == 0 \
                    and self._probe_batch_fn is not None:
                self._spectral_probe()
            if self.step % log_every == 0:
                log_fn(
                    f"step {self.step}: loss={loss:.4f} lr={float(metrics['lr']):.2e} "
                    f"gnorm={float(metrics['grad_norm']):.2f} dt={dt*1e3:.0f}ms"
                )
        self.ckpt.wait()
        return history
