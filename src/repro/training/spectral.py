"""Spectral monitoring of training — the framework integration of the paper.

The paper's contribution is a distributed mixed-precision Top-K eigensolver.
In an ML fleet the same solver runs *matrix-free* on the loss Hessian (the
HVP operator): top-K curvature eigenvalues diagnose sharpness, LR stability
(lambda_max vs 2/eta), and loss-landscape conditioning.  This module wires
the unified ``repro.api.eigsh`` frontend to the model zoo through
``core.operators.HvpOperator`` — every one of the 10 assigned architectures
can be probed (DESIGN.md §6).

The mixed-precision policy applies unchanged: Lanczos vectors are stored in
the policy's storage dtype while the alpha/beta reductions accumulate wide —
on a params-sized vector (up to 72B entries) that storage halving is exactly
the paper's memory argument transplanted to the Hessian domain.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

from ..api import EigenResult, eigsh
from ..core.operators import HvpOperator
from ..core.precision import FFF, PrecisionPolicy
from ..models.common import ModelConfig
from ..models.model import loss_fn

__all__ = ["hessian_topk", "hessian_spectrum"]


def hessian_spectrum(
    params,
    cfg: ModelConfig,
    batch: Dict,
    k: int = 4,
    policy: PrecisionPolicy = FFF,
    num_iters: int | None = None,
    seed: int = 0,
    tol: float | None = None,
) -> EigenResult:
    """Full :class:`EigenResult` for the Hessian of the batch loss at ``params``."""

    def scalar_loss(p):
        return loss_fn(p, cfg, batch)[0]

    op = HvpOperator(scalar_loss, params)
    return eigsh(
        op,
        k,
        policy=policy,
        backend="single",
        reorth="full",
        num_iters=num_iters or max(2 * k, 8),
        tol=tol,
        seed=seed,
    )


def hessian_topk(
    params,
    cfg: ModelConfig,
    batch: Dict,
    k: int = 4,
    policy: PrecisionPolicy = FFF,
    num_iters: int | None = None,
    seed: int = 0,
) -> np.ndarray:
    """Top-K |eigenvalues| of the Hessian of the batch loss at ``params``."""
    res = hessian_spectrum(params, cfg, batch, k=k, policy=policy, num_iters=num_iters, seed=seed)
    return np.asarray(res.eigenvalues, dtype=np.float64)
