"""Cross-process session persistence: the serving warm-start store.

A served matrix's expensive state — converted device layouts, tuned SpMV
tiles, per-policy plan configuration — is a pure function of (matrix bytes,
layout config, repro version).  ``SessionStore`` persists exactly that
state next to the SpMV tune cache, keyed by matrix fingerprint + layout
fingerprint, so a restarted server warms instantly: reloading rebuilds the
device containers from the saved arrays with the *plain constructors*
(``conversion_count()`` does not move) and injects the saved tiles
(``tuner_probe_count()`` does not move either).

Layout on disk (one directory per (matrix, layout) pair)::

    <root>/<matrix_fp>-<layout_fp>/
        header.json   # schema, repro version, fingerprints, n, plan configs
        plans.npz     # the device-container arrays, one prefix per plan

Staleness is rejected, never trusted: the header carries the repro version
and the layout-config fingerprint, and :meth:`EigenSession.import_plans`
refuses any mismatch with a warning — the session then cold-rebuilds
lazily, identical to having no store at all.  A corrupt payload likewise
warns and is treated as absent.
"""

from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path
from typing import Optional

import numpy as np

__all__ = ["SessionStore", "default_store_root"]

_HEADER = "header.json"
_PLANS = "plans.npz"


def default_store_root() -> str:
    """Default store location: ``REPRO_SERVING_STORE`` if set, else a
    ``serving_store`` directory next to the SpMV tune cache."""
    env = os.environ.get("REPRO_SERVING_STORE")
    if env:
        return env
    from ..kernels.engine import DEFAULT_TUNE_CACHE

    return os.path.join(os.path.dirname(DEFAULT_TUNE_CACHE), "serving_store")


class SessionStore:
    """Fingerprint-keyed persistent store of exported session plans."""

    def __init__(self, root: Optional[str] = None):
        self.root = Path(root if root is not None else default_store_root())
        self.root.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------- layout

    def _key(self, matrix_fp: str, layout_fp: str) -> str:
        return f"{matrix_fp}-{layout_fp}"

    def path_for(self, session) -> Optional[Path]:
        """Directory this session persists under, or None when the session
        has no matrix fingerprint (matrix-free input: nothing to key by)."""
        from ..api.session import _LAYOUT_FIELDS, config_fingerprint

        matrix_fp = session.ensure_fingerprint()
        if matrix_fp is None:
            return None
        layout_fp = config_fingerprint(session.cfg, _LAYOUT_FIELDS)
        return self.root / self._key(matrix_fp, layout_fp)

    def entries(self) -> list:
        """Persisted (matrix, layout) keys currently on disk."""
        return sorted(p.name for p in self.root.iterdir() if (p / _HEADER).exists())

    # --------------------------------------------------------------- save

    def save(self, session) -> Optional[Path]:
        """Persist the session's built plans; returns the entry path, or
        None when there is nothing persistable (no fingerprint, or no
        exportable plans built yet).  The write is atomic-enough (temp files
        + rename) that a concurrent reader never sees a torn entry."""
        path = self.path_for(session)
        if path is None:
            return None
        state = session.export_state()
        if not state["plans"]:
            return None
        arrays = {}
        plan_headers = []
        for i, plan in enumerate(state["plans"]):
            rec = {k: v for k, v in plan.items() if k != "arrays"}
            rec["array_names"] = sorted(plan["arrays"])
            plan_headers.append(rec)
            for name, a in plan["arrays"].items():
                arrays[f"p{i}.{name}"] = np.asarray(a)
        header = {k: v for k, v in state.items() if k != "plans"}
        header["plans"] = plan_headers
        path.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path, suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, path / _PLANS)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        fd, tmp = tempfile.mkstemp(dir=path, suffix=".json.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(header, f, indent=1)
            os.replace(tmp, path / _HEADER)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return path

    # --------------------------------------------------------------- load

    def load_state(self, session) -> Optional[dict]:
        """Read this session's persisted state back into ``export_state``
        form (arrays rehydrated from the npz), or None when absent/corrupt.
        Header validation itself happens in ``import_plans`` — this method
        only reassembles bytes."""
        import warnings

        path = self.path_for(session)
        if path is None or not (path / _HEADER).exists():
            return None
        try:
            with open(path / _HEADER) as f:
                header = json.load(f)
            with np.load(path / _PLANS) as z:
                plans = []
                for i, rec in enumerate(header.get("plans", [])):
                    plan = dict(rec)
                    plan["arrays"] = {
                        name: z[f"p{i}.{name}"] for name in rec.get("array_names", [])
                    }
                    plans.append(plan)
            header["plans"] = plans
            return header
        except Exception as exc:
            warnings.warn(
                f"corrupt serving-store entry {path.name} ignored "
                f"({type(exc).__name__}: {exc}); the session will cold-build",
                stacklevel=2,
            )
            return None

    def load_into(self, session) -> int:
        """Warm a session from its persisted entry: returns plans imported
        (0 when absent, stale, or corrupt — the session cold-builds lazily)."""
        state = self.load_state(session)
        if state is None:
            return 0
        return session.import_plans(state)
