"""Cross-process session persistence: the serving warm-start store.

A served matrix's expensive state — converted device layouts, tuned SpMV
tiles, per-policy plan configuration — is a pure function of (matrix bytes,
layout config, repro version).  ``SessionStore`` persists exactly that
state next to the SpMV tune cache, keyed by matrix fingerprint + layout
fingerprint, so a restarted server warms instantly: reloading rebuilds the
device containers from the saved arrays with the *plain constructors*
(``conversion_count()`` does not move) and injects the saved tiles
(``tuner_probe_count()`` does not move either).

Layout on disk (one directory per (matrix, layout) pair)::

    <root>/<matrix_fp>-<layout_fp>/
        header.json   # schema, repro version, fingerprints, n, plan configs
        plans.npz     # the device-container arrays, one prefix per plan

Staleness is rejected, never trusted: the header carries the repro version
and the layout-config fingerprint, and :meth:`EigenSession.import_plans`
refuses any mismatch with a warning — the session then cold-rebuilds
lazily, identical to having no store at all.  A corrupt payload likewise
warns and is treated as absent.
"""

from __future__ import annotations

import hashlib
import json
import os
import tempfile
from pathlib import Path
from typing import Optional

import numpy as np

from ..configs import env as envcfg

__all__ = [
    "SessionStore",
    "SolveCheckpoint",
    "default_store_root",
    "default_checkpoint_root",
]

_HEADER = "header.json"
_PLANS = "plans.npz"
_CKPT_SCHEMA = 1


def default_store_root() -> str:
    """Default store location: ``REPRO_SERVING_STORE`` if set, else a
    ``serving_store`` directory next to the SpMV tune cache."""
    env = envcfg.get_str("REPRO_SERVING_STORE")
    if env:
        return env
    from ..kernels.engine import DEFAULT_TUNE_CACHE

    return os.path.join(os.path.dirname(DEFAULT_TUNE_CACHE), "serving_store")


class SessionStore:
    """Fingerprint-keyed persistent store of exported session plans."""

    def __init__(self, root: Optional[str] = None):
        self.root = Path(root if root is not None else default_store_root())
        self.root.mkdir(parents=True, exist_ok=True)

    # ------------------------------------------------------------- layout

    def _key(self, matrix_fp: str, layout_fp: str) -> str:
        return f"{matrix_fp}-{layout_fp}"

    def path_for(self, session) -> Optional[Path]:
        """Directory this session persists under, or None when the session
        has no matrix fingerprint (matrix-free input: nothing to key by)."""
        from ..api.session import _LAYOUT_FIELDS, config_fingerprint

        matrix_fp = session.ensure_fingerprint()
        if matrix_fp is None:
            return None
        layout_fp = config_fingerprint(session.cfg, _LAYOUT_FIELDS)
        return self.root / self._key(matrix_fp, layout_fp)

    def entries(self) -> list:
        """Persisted (matrix, layout) keys currently on disk."""
        return sorted(p.name for p in self.root.iterdir() if (p / _HEADER).exists())

    # --------------------------------------------------------------- save

    def save(self, session) -> Optional[Path]:
        """Persist the session's built plans; returns the entry path, or
        None when there is nothing persistable (no fingerprint, or no
        exportable plans built yet).  The write is atomic-enough (temp files
        + rename) that a concurrent reader never sees a torn entry."""
        path = self.path_for(session)
        if path is None:
            return None
        state = session.export_state()
        if not state["plans"] and not state.get("matrix_ref"):
            # Nothing persistable.  Disk-backed sessions ARE persistable even
            # with zero exportable plans: their entry is a header-only
            # POINTER (path + sampled fingerprint) to the on-disk matrix —
            # never a copy of an out-of-core payload into plans.npz.
            return None
        arrays = {}
        plan_headers = []
        for i, plan in enumerate(state["plans"]):
            rec = {k: v for k, v in plan.items() if k != "arrays"}
            rec["array_names"] = sorted(plan["arrays"])
            plan_headers.append(rec)
            for name, a in plan["arrays"].items():
                arrays[f"p{i}.{name}"] = np.asarray(a)
        header = {k: v for k, v in state.items() if k != "plans"}
        header["plans"] = plan_headers
        path.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path, suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, path / _PLANS)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        fd, tmp = tempfile.mkstemp(dir=path, suffix=".json.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(header, f, indent=1)
            os.replace(tmp, path / _HEADER)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return path

    # --------------------------------------------------------------- load

    def load_state(self, session) -> Optional[dict]:
        """Read this session's persisted state back into ``export_state``
        form (arrays rehydrated from the npz), or None when absent/corrupt.
        Header validation itself happens in ``import_plans`` — this method
        only reassembles bytes."""
        import warnings

        path = self.path_for(session)
        if path is None or not (path / _HEADER).exists():
            return None
        try:
            with open(path / _HEADER) as f:
                header = json.load(f)
            with np.load(path / _PLANS) as z:
                plans = []
                for i, rec in enumerate(header.get("plans", [])):
                    plan = dict(rec)
                    plan["arrays"] = {
                        name: z[f"p{i}.{name}"] for name in rec.get("array_names", [])
                    }
                    plans.append(plan)
            header["plans"] = plans
            return header
        except Exception as exc:
            warnings.warn(
                f"corrupt serving-store entry {path.name} ignored "
                f"({type(exc).__name__}: {exc}); the session will cold-build",
                stacklevel=2,
            )
            return None

    def load_into(self, session) -> int:
        """Warm a session from its persisted entry: returns plans imported
        (0 when absent, stale, or corrupt — the session cold-builds lazily)."""
        state = self.load_state(session)
        if state is None:
            return 0
        return session.import_plans(state)

    @staticmethod
    def revive_matrix(state: dict):
        """Reopen the on-disk matrix a persisted entry's ``matrix_ref``
        points at, verifying the sampled content fingerprint — a moved,
        rewritten, or deleted mapping warns and reads as absent (None), so a
        revived server never serves plans for bytes that changed under it.
        Returns a :class:`~repro.sparse.diskcsr.DiskCSR` or None."""
        import warnings

        ref = (state or {}).get("matrix_ref")
        if not ref or ref.get("kind") != "diskcsr":
            return None
        from ..sparse.diskcsr import diskcsr_fingerprint, is_diskcsr, open_diskcsr

        path = ref.get("path")
        if not path or not is_diskcsr(path):
            warnings.warn(
                f"persisted matrix_ref points at {path!r}, which is no longer "
                "a diskcsr directory; the entry reads as absent",
                stacklevel=2,
            )
            return None
        if diskcsr_fingerprint(path) != ref.get("fingerprint"):
            warnings.warn(
                f"on-disk matrix at {path!r} changed since this entry was "
                "persisted (sampled fingerprint mismatch); refusing to revive",
                stacklevel=2,
            )
            return None
        return open_diskcsr(path)


def default_checkpoint_root() -> str:
    """Default checkpoint location: ``REPRO_SOLVE_CHECKPOINTS`` if set, else
    a ``solve_checkpoints`` directory next to the SpMV tune cache."""
    env = envcfg.get_str("REPRO_SOLVE_CHECKPOINTS")
    if env:
        return env
    from ..kernels.engine import DEFAULT_TUNE_CACHE

    return os.path.join(os.path.dirname(DEFAULT_TUNE_CACHE), "solve_checkpoints")


class SolveCheckpoint:
    """Mid-solve snapshot store — the :class:`SessionStore` sibling for
    *in-flight* state rather than prepared plans.

    The restarted engine saves its full restart state (basis block,
    projected matrix, arrow border, next start vector, counters) after
    every completed compression; the chunked engine's host Lanczos loop
    saves its carry every N steps.  A killed run re-invoked with the same
    token resumes from the last snapshot **bit-identically**: each saved
    state fully determines the remaining trajectory (per-cycle
    ``beta_prev`` resets to 0, so no unsaved recurrence state leaks across
    the snapshot boundary), and arrays round-trip exactly (bf16 is widened
    to f32 — lossless — for npz, and narrowed back on load).

    Layout on disk (one directory per solve token)::

        <root>/<token>/
            header.json   # schema + scalar state (engine, cycle/step, dims)
            state.npz     # the array state

    Writes are atomic (temp file + ``os.replace``) so a crash mid-save
    leaves the previous snapshot intact, never a torn one.  Completed
    solves ``clear`` their entry so a finished token cannot resurrect.
    """

    _STATE = "state.npz"

    def __init__(self, root: Optional[str] = None):
        self.root = Path(root if root is not None else default_checkpoint_root())
        self.root.mkdir(parents=True, exist_ok=True)

    @staticmethod
    def token(matrix_fp: Optional[str], **params) -> str:
        """Deterministic solve identity: matrix fingerprint + the solve
        parameters that shape the trajectory (backend, policy, k, m, seed,
        tol, reorth — NOT budget knobs like max_restarts, which only decide
        where the trajectory stops)."""
        h = hashlib.blake2b(digest_size=12)
        h.update((matrix_fp or "anon").encode())
        for key in sorted(params):
            h.update(f"|{key}={params[key]!r}".encode())
        return h.hexdigest()

    def path_for(self, token: str) -> Path:
        return self.root / token

    def entries(self) -> list:
        return sorted(p.name for p in self.root.iterdir() if (p / _HEADER).exists())

    def save(self, token: str, state: dict) -> Path:
        """Persist one snapshot: ndarray/jax-array values go to the npz
        (bf16 widened to f32, original dtype recorded), everything else to
        the JSON header."""
        path = self.path_for(token)
        path.mkdir(parents=True, exist_ok=True)
        arrays = {}
        dtypes = {}
        header = {"schema": _CKPT_SCHEMA}
        for key, val in state.items():
            if hasattr(val, "ndim") or isinstance(val, np.ndarray):
                arr = np.asarray(val)
                dtypes[key] = str(arr.dtype)
                if arr.dtype.name == "bfloat16":
                    arr = arr.astype(np.float32)  # exact widening
                arrays[key] = arr
            else:
                header[key] = val
        header["array_dtypes"] = dtypes
        fd, tmp = tempfile.mkstemp(dir=path, suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **arrays)
            os.replace(tmp, path / self._STATE)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        fd, tmp = tempfile.mkstemp(dir=path, suffix=".json.tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(header, f, indent=1)
            os.replace(tmp, path / _HEADER)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return path

    def load(self, token: str) -> Optional[dict]:
        """The last snapshot for ``token``, or None when absent/corrupt
        (corrupt entries warn and read as absent: the solve starts over,
        identical to having no checkpoint)."""
        import warnings

        path = self.path_for(token)
        if not (path / _HEADER).exists():
            return None
        try:
            with open(path / _HEADER) as f:
                header = json.load(f)
            if header.get("schema") != _CKPT_SCHEMA:
                return None
            dtypes = header.pop("array_dtypes", {})
            state = dict(header)
            with np.load(path / self._STATE) as z:
                for key in z.files:
                    arr = z[key]
                    want = dtypes.get(key)
                    if want == "bfloat16":
                        import ml_dtypes

                        arr = arr.astype(ml_dtypes.bfloat16)  # exact narrowing back
                    state[key] = arr
            return state
        except Exception as exc:
            warnings.warn(
                f"corrupt solve checkpoint {path.name} ignored "
                f"({type(exc).__name__}: {exc}); the solve restarts from zero",
                stacklevel=2,
            )
            return None

    def clear(self, token: str) -> bool:
        """Remove ``token``'s snapshot; True when something was deleted."""
        path = self.path_for(token)
        if not path.exists():
            return False
        for name in (self._STATE, _HEADER):
            try:
                (path / name).unlink()
            except FileNotFoundError:
                pass
        try:
            path.rmdir()
        except OSError:
            pass  # stray tmp files: leave the directory, entry is still gone
        return True
