"""Serving metrics: counters, latency histograms, and the ``ServerStats``
snapshot the scheduler exposes.

The paper's serving claim — one expensive plan amortized over a stream of
Top-K queries — is only auditable if the serving layer *measures* it.  This
module keeps the bookkeeping in one place: per-request latency histograms
(queue wait / solve / end-to-end, log-bucketed so p50/p99 stay O(1) and
allocation-free on the hot path), coalescing counters (how many sweeps
served how many queries), and warm-start counters (sessions restored from
the persistent store vs cold-built).  Everything is thread-safe: submitter
threads and the dispatch thread record concurrently.
"""

from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, Optional

__all__ = ["LatencyHistogram", "ServingMetrics", "ServerStats"]

# Log-spaced bucket grid: 8 buckets per decade from 1us to 10^4 s.  Latency
# percentiles from a fixed grid are exact to ~+/-15% (one bucket), which is
# what a p99 regression gate needs — not microsecond forensics.
_BUCKETS_PER_DECADE = 8
_FLOOR_S = 1e-6
_DECADES = 10
_N_BUCKETS = _BUCKETS_PER_DECADE * _DECADES


class LatencyHistogram:
    """Fixed-grid log-bucketed latency histogram (seconds), thread-safe."""

    # Checked by repro.analysis rule C001.
    _GUARDED_BY = {
        "_counts": "_lock",
        "_n": "_lock",
        "_sum": "_lock",
        "_max": "_lock",
    }

    def __init__(self):
        self._counts = [0] * _N_BUCKETS
        self._n = 0
        self._sum = 0.0
        self._max = 0.0
        self._lock = threading.Lock()

    @staticmethod
    def _bucket(seconds: float) -> int:
        if seconds <= _FLOOR_S:
            return 0
        idx = int(math.log10(seconds / _FLOOR_S) * _BUCKETS_PER_DECADE)
        return min(idx, _N_BUCKETS - 1)

    @staticmethod
    def _bucket_mid(idx: int) -> float:
        # Geometric midpoint of the bucket's [lo, hi) span.
        return _FLOOR_S * 10.0 ** ((idx + 0.5) / _BUCKETS_PER_DECADE)

    def record(self, seconds: float) -> None:
        s = max(0.0, float(seconds))
        with self._lock:
            self._counts[self._bucket(s)] += 1
            self._n += 1
            self._sum += s
            self._max = max(self._max, s)

    @property
    def count(self) -> int:
        return self._n

    def mean(self) -> float:
        with self._lock:
            return self._sum / self._n if self._n else 0.0

    def percentile(self, p: float) -> float:
        """p in [0, 100] -> seconds (geometric bucket midpoint; the true max
        is reported exactly for the topmost sample)."""
        with self._lock:
            if not self._n:
                return 0.0
            target = max(1, math.ceil(self._n * min(max(p, 0.0), 100.0) / 100.0))
            seen = 0
            for i, c in enumerate(self._counts):
                seen += c
                if seen >= target:
                    if seen == self._n and target == self._n:
                        return self._max  # the last sample: exact
                    return min(self._bucket_mid(i), self._max)
            return self._max

    def snapshot(self) -> Dict[str, float]:
        return {
            "count": float(self.count),
            "mean_s": self.mean(),
            "p50_s": self.percentile(50.0),
            "p99_s": self.percentile(99.0),
            "max_s": self._max,
        }


@dataclasses.dataclass(frozen=True)
class ServerStats:
    """Point-in-time snapshot of a scheduler's serving state.

    Attributes:
      queue_depth: requests admitted but not yet dispatched.
      sessions: resident prepared sessions (the bounded matrix pool).
      submitted / completed / failed: request outcomes so far.
      rejected_full: submissions refused by queue backpressure.
      rejected_deadline: requests whose deadline expired before dispatch.
      cancelled: requests cancelled while queued.
      groups: coalesced ``eigsh_many`` dispatches executed.
      grouped_queries: queries those dispatches served (so
        ``batch_occupancy = grouped_queries / groups``).
      coalesce_rate: fraction of completed queries that shared their sweep
        with at least one other query (0.0 = everything solo).
      warm_starts / cold_builds: sessions restored from the persistent store
        (zero conversions) vs built from scratch.
      retries: dispatch attempts re-queued after a retryable solve failure
        (each retry is one increment — a request retried twice counts 2).
      rejected_breaker: submissions refused because the matrix's circuit
        breaker was open (``SessionUnhealthyError``).
      breaker_trips: times a per-matrix breaker transitioned to open.
      watchdog_trips: dispatch-thread deaths detected by the watchdog.
      dispatch_errors: exceptions that escaped a dispatch and were contained
        by the loop guard (each one failed its group typed, not the thread).
      latency: per-phase histogram summaries (``queue`` / ``solve`` /
        ``e2e``), each with count / mean_s / p50_s / p99_s / max_s.
    """

    queue_depth: int
    sessions: int
    submitted: int
    completed: int
    failed: int
    rejected_full: int
    rejected_deadline: int
    cancelled: int
    groups: int
    grouped_queries: int
    coalesce_rate: float
    warm_starts: int
    cold_builds: int
    retries: int
    rejected_breaker: int
    breaker_trips: int
    watchdog_trips: int
    dispatch_errors: int
    latency: Dict[str, Dict[str, float]]

    @property
    def batch_occupancy(self) -> float:
        return self.grouped_queries / self.groups if self.groups else 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["batch_occupancy"] = self.batch_occupancy
        return d

    def summary(self) -> str:
        e2e = self.latency.get("e2e", {})
        q = self.latency.get("queue", {})
        return (
            f"served {self.completed}/{self.submitted} queries in {self.groups} sweeps "
            f"(occupancy {self.batch_occupancy:.2f}, coalesce rate {self.coalesce_rate:.2f})\n"
            f"  rejected: {self.rejected_full} full, {self.rejected_deadline} deadline; "
            f"cancelled {self.cancelled}; failed {self.failed}\n"
            f"  sessions: {self.sessions} resident "
            f"({self.warm_starts} warm-started, {self.cold_builds} cold-built)\n"
            f"  recovery: {self.retries} retries, {self.rejected_breaker} breaker-rejected "
            f"({self.breaker_trips} trips), {self.dispatch_errors} dispatch errors, "
            f"{self.watchdog_trips} watchdog trips\n"
            f"  latency e2e p50 {e2e.get('p50_s', 0.0) * 1e3:.2f}ms "
            f"p99 {e2e.get('p99_s', 0.0) * 1e3:.2f}ms; "
            f"queue p50 {q.get('p50_s', 0.0) * 1e3:.2f}ms p99 {q.get('p99_s', 0.0) * 1e3:.2f}ms"
        )


class ServingMetrics:
    """Mutable, thread-safe metric accumulators behind a scheduler."""

    # Checked by repro.analysis rule C001 (the ``inc`` counters go through
    # setattr and are covered by that method holding the lock).
    _GUARDED_BY = {
        "groups": "_lock",
        "grouped_queries": "_lock",
        "coalesced_queries": "_lock",
    }

    def __init__(self):
        self._lock = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.rejected_full = 0
        self.rejected_deadline = 0
        self.cancelled = 0
        self.groups = 0
        self.grouped_queries = 0
        self.coalesced_queries = 0  # completed queries that shared a sweep
        self.warm_starts = 0
        self.cold_builds = 0
        self.retries = 0
        self.rejected_breaker = 0
        self.breaker_trips = 0
        self.watchdog_trips = 0
        self.dispatch_errors = 0
        self.queue_wait = LatencyHistogram()
        self.solve = LatencyHistogram()
        self.e2e = LatencyHistogram()

    def inc(self, field: str, by: int = 1) -> None:
        with self._lock:
            setattr(self, field, getattr(self, field) + by)

    def record_group(self, size: int) -> None:
        with self._lock:
            self.groups += 1
            self.grouped_queries += size
            if size > 1:
                self.coalesced_queries += size

    def record_latency(self, queue_s: float, solve_s: float) -> None:
        self.queue_wait.record(queue_s)
        self.solve.record(solve_s)
        self.e2e.record(queue_s + solve_s)

    def snapshot(self, queue_depth: int = 0, sessions: int = 0) -> ServerStats:
        with self._lock:
            completed = self.completed
            coalesce_rate = self.coalesced_queries / completed if completed else 0.0
            return ServerStats(
                queue_depth=int(queue_depth),
                sessions=int(sessions),
                submitted=self.submitted,
                completed=completed,
                failed=self.failed,
                rejected_full=self.rejected_full,
                rejected_deadline=self.rejected_deadline,
                cancelled=self.cancelled,
                groups=self.groups,
                grouped_queries=self.grouped_queries,
                coalesce_rate=coalesce_rate,
                warm_starts=self.warm_starts,
                cold_builds=self.cold_builds,
                retries=self.retries,
                rejected_breaker=self.rejected_breaker,
                breaker_trips=self.breaker_trips,
                watchdog_trips=self.watchdog_trips,
                dispatch_errors=self.dispatch_errors,
                latency={
                    "queue": self.queue_wait.snapshot(),
                    "solve": self.solve.snapshot(),
                    "e2e": self.e2e.snapshot(),
                },
            )
