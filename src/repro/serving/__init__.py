"""Eigensolver serving: async scheduler, persistent warm sessions, metrics.

    from repro.serving import EigenScheduler, SchedulerConfig, SessionStore

    with EigenScheduler(store=SessionStore(root)) as sched:
        key = sched.add_matrix(csr)              # warm from store, or build
        h = sched.submit(key, k=8, num_iters=32) # future
        res = h.result()                         # per-query EigenResult
        print(sched.stats().summary())           # p50/p99, coalesce rate

The legacy LM decode engine moved to ``repro.serving.lm``; importing
``Engine`` / ``ServeConfig`` from here still works with a
``DeprecationWarning``.
"""

import warnings

from .metrics import LatencyHistogram, ServerStats, ServingMetrics
from .scheduler import (
    DeadlineExceededError,
    EigenScheduler,
    QueryCancelledError,
    QueryHandle,
    QueueFullError,
    SchedulerConfig,
    SchedulerCrashedError,
    ServingError,
    SessionUnhealthyError,
    UnknownMatrixError,
)
from .store import (
    SessionStore,
    SolveCheckpoint,
    default_checkpoint_root,
    default_store_root,
)

__all__ = [
    "EigenScheduler",
    "SchedulerConfig",
    "QueryHandle",
    "SessionStore",
    "SolveCheckpoint",
    "default_store_root",
    "default_checkpoint_root",
    "ServingMetrics",
    "ServerStats",
    "LatencyHistogram",
    "ServingError",
    "QueueFullError",
    "DeadlineExceededError",
    "QueryCancelledError",
    "UnknownMatrixError",
    "SessionUnhealthyError",
    "SchedulerCrashedError",
]

_LEGACY = ("Engine", "ServeConfig")


def __getattr__(name: str):
    if name in _LEGACY:
        warnings.warn(
            f"repro.serving.{name} is the legacy LM decode engine; import it "
            "from repro.serving.lm (the eigensolver serving layer is "
            "repro.serving.EigenScheduler)",
            DeprecationWarning,
            stacklevel=2,
        )
        from . import lm

        return getattr(lm, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
