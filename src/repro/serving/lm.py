"""Legacy LM decode engine (prefill + jitted single-token decode loop).

This is the seed's language-model serving shell, kept for the model-side
tests and demos; it is NOT the eigensolver serving layer — that is
``repro.serving.scheduler`` (the async scheduler over prepared
``EigenSession``\\ s).  Importing ``Engine`` / ``ServeConfig`` from
``repro.serving`` still works but emits a ``DeprecationWarning``; import
from ``repro.serving.lm`` directly to silence it.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.common import ModelConfig
from ..models.model import decode_step, prefill

__all__ = ["ServeConfig", "Engine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0  # 0 = greedy
    eos_id: int = -1  # -1 = never stop
    pad_id: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig = ServeConfig()):
        self.cfg = cfg
        self.params = params
        self.sc = sc
        self._decode = jax.jit(partial(decode_step, cfg=cfg))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        logits = logits[..., : self.cfg.vocab]
        if self.sc.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.sc.temperature, axis=-1).astype(jnp.int32)

    def generate(self, batch: Dict, steps: int, seed: int = 0) -> Tuple[jax.Array, Dict]:
        """batch: prompt dict (tokens (B,S), [frames...]). Returns (B, steps)."""
        state, logits = prefill(self.params, self.cfg, batch, max_len=self.sc.max_len)
        b = batch["tokens"].shape[0]
        key = jax.random.PRNGKey(seed)
        done = jnp.zeros((b,), bool)
        outs = []
        tok_ps = []
        for i in range(steps):
            key, k2 = jax.random.split(key)
            nxt = self._sample(logits, k2)
            logp = jax.nn.log_softmax(logits[..., : self.cfg.vocab], axis=-1)
            tok_ps.append(jnp.take_along_axis(logp, nxt[:, None], axis=-1)[:, 0])
            nxt = jnp.where(done, self.sc.pad_id, nxt)
            outs.append(nxt)
            if self.sc.eos_id >= 0:
                done = done | (nxt == self.sc.eos_id)
            logits, state = self._decode(params=self.params, state=state, tokens=nxt[:, None])
        tokens = jnp.stack(outs, axis=1)
        return tokens, {"token_logprobs": jnp.stack(tok_ps, axis=1), "final_state": state}
