"""Eigensolver serving surface (scheduler + store + metrics re-exports).

Historically this module held the seed's LM decode ``Engine``; that code
now lives in ``repro.serving.lm`` and the names here are the eigensolver
serving layer the ROADMAP targets.  ``Engine`` / ``ServeConfig`` remain
importable through a ``DeprecationWarning`` shim for the LM tests/demos.
"""

from __future__ import annotations

import warnings

from .metrics import LatencyHistogram, ServerStats, ServingMetrics
from .scheduler import (
    DeadlineExceededError,
    EigenScheduler,
    QueryCancelledError,
    QueryHandle,
    QueueFullError,
    SchedulerConfig,
    ServingError,
    UnknownMatrixError,
)
from .store import SessionStore, default_store_root

__all__ = [
    "EigenScheduler",
    "SchedulerConfig",
    "QueryHandle",
    "SessionStore",
    "default_store_root",
    "ServingMetrics",
    "ServerStats",
    "LatencyHistogram",
    "ServingError",
    "QueueFullError",
    "DeadlineExceededError",
    "QueryCancelledError",
    "UnknownMatrixError",
]

_LEGACY = ("Engine", "ServeConfig")


def __getattr__(name: str):
    if name in _LEGACY:
        warnings.warn(
            f"repro.serving.engine.{name} is the legacy LM decode engine; "
            "import it from repro.serving.lm (the eigensolver serving layer "
            "is repro.serving.EigenScheduler)",
            DeprecationWarning,
            stacklevel=2,
        )
        from . import lm

        return getattr(lm, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
