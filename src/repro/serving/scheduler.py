"""Eigensolver-as-a-service: async scheduler with continuous batching.

The paper's economics — one expensive per-matrix setup (format conversion,
partitioning, precision tuning) amortized over a stream of Top-K queries —
become a *serving* problem the moment queries arrive asynchronously: who
holds the prepared sessions, which queued queries may share one Lanczos
sweep, and what happens when the queue outruns the solver.  This module is
that layer:

* ``EigenScheduler`` admits :class:`~repro.api.EigQuery` requests against a
  bounded pool of resident :class:`~repro.api.EigenSession`\\ s and resolves
  each request's :class:`QueryHandle` future with its own
  :class:`~repro.api.EigenResult`.
* **Continuous batching**: a dispatch thread pulls the oldest request, then
  holds the batch open for a tunable *admission window*, coalescing every
  queued request with the same session and the same
  :meth:`EigenSession.group_key` — exactly the predicate ``eigsh_many``
  groups by, so a coalesced batch is served by ONE shared sweep and each
  query's answer is identical to what the batched API returns.  Queries the
  session would not merge (``policy="auto"``, different reorth/policy/
  backend) are never coalesced.
* **SLOs**: per-request deadlines shrink the admission window (a batch never
  idles past its most urgent member) and expire queued requests with a typed
  :class:`DeadlineExceededError`; requests can be cancelled while queued; a
  bounded queue rejects overload with :class:`QueueFullError` instead of
  buffering without limit.
* **Warm restarts**: with a :class:`~repro.serving.store.SessionStore`
  attached, ``add_matrix`` restores persisted device layouts + tuned tiles
  keyed by matrix fingerprint — zero conversions, counter-verified — and
  persists cold-built sessions for the next process.
* **Metrics**: queue depth, batch occupancy, coalesce rate, warm-start
  counters, and p50/p99 latency histograms via :meth:`EigenScheduler.stats`.
* **Fault tolerance**: per-request retry budgets (exponential backoff +
  jitter, transient solve failures only), a per-matrix circuit breaker
  (N consecutive dispatch failures open it — submissions fail fast with
  :class:`SessionUnhealthyError` until a cooldown probe closes it again),
  a dispatch-loop guard that contains any per-group exception (failing the
  group typed, never the thread), and a watchdog thread that detects
  dispatch-thread death and fails every stranded request with
  :class:`SchedulerCrashedError` instead of hanging its futures forever.
"""

from __future__ import annotations

import dataclasses
import random
import threading
import time
from collections import OrderedDict, deque
from typing import Any, Deque, Dict, List, Optional

from ..api.frontend import SolverConfig
from ..api.result import EigenResult, with_queue_time
from ..api.session import EigenSession, _as_query
from ..testing import faults as _faults
from .metrics import ServerStats, ServingMetrics
from .store import SessionStore

__all__ = [
    "EigenScheduler",
    "SchedulerConfig",
    "QueryHandle",
    "ServingError",
    "QueueFullError",
    "DeadlineExceededError",
    "QueryCancelledError",
    "UnknownMatrixError",
    "SessionUnhealthyError",
    "SchedulerCrashedError",
]


class ServingError(RuntimeError):
    """Base class of every typed serving-layer failure."""


class QueueFullError(ServingError):
    """Submission rejected: the bounded request queue is at capacity."""


class DeadlineExceededError(ServingError):
    """The request's deadline expired before its solve was dispatched."""


class QueryCancelledError(ServingError):
    """The request was cancelled while still queued."""


class UnknownMatrixError(ServingError):
    """The named matrix is not resident in the scheduler's session pool."""


class SessionUnhealthyError(ServingError):
    """The matrix's circuit breaker is open: its last
    ``SchedulerConfig.breaker_threshold`` dispatches all failed, so
    submissions fail fast instead of queueing onto a known-bad session.
    The breaker half-opens after ``breaker_cooldown_s`` — one probe query
    is admitted; success closes it, failure re-opens it."""


class SchedulerCrashedError(ServingError):
    """The dispatch thread died; the watchdog failed every pending request
    with this instead of leaving their futures hanging.  ``start()`` the
    scheduler again to recover."""


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """Serving knobs.

    Attributes:
      max_queue: bounded-queue backpressure limit — submissions beyond this
        many pending requests raise :class:`QueueFullError`.
      admission_window_s: how long the dispatcher holds a batch open for
        more compatible queries after pulling its first member.  0 disables
        waiting (still coalesces whatever is already queued).
      max_group: most queries one coalesced ``eigsh_many`` dispatch serves.
      max_sessions: bounded session pool — adding a matrix beyond this
        evicts the least-recently-used resident session (persisted to the
        store first, when one is attached).
      max_retries: per-request retry budget for *transient* dispatch
        failures (numerical breakdown, OOM, I/O, injected faults — never
        validation errors).  0 (default) fails on first error, matching the
        pre-retry behavior exactly.
      retry_backoff_s: base delay before a retried request becomes eligible
        again; attempt ``i`` waits ``retry_backoff_s * 2**(i-1)`` scaled by
        up to ``1 + retry_jitter`` of random jitter (decorrelates retry
        storms after a shared-cause failure).
      retry_jitter: jitter fraction on the backoff (0 = deterministic).
      breaker_threshold: consecutive dispatch failures on one matrix that
        open its circuit breaker (submissions then raise
        :class:`SessionUnhealthyError` until a cooldown probe succeeds).
        0 (default) disables the breaker.
      breaker_cooldown_s: how long an open breaker rejects before it
        half-opens and admits one probe query.
      watchdog_interval_s: poll period of the dispatch-thread watchdog.
    """

    max_queue: int = 256
    admission_window_s: float = 2e-3
    max_group: int = 32
    max_sessions: int = 8
    max_retries: int = 0
    retry_backoff_s: float = 0.05
    retry_jitter: float = 0.2
    breaker_threshold: int = 0
    breaker_cooldown_s: float = 5.0
    watchdog_interval_s: float = 0.5


class QueryHandle:
    """Future for one submitted query.

    ``result(timeout)`` blocks until the solve lands and returns the
    per-query :class:`~repro.api.EigenResult` (with the ``queue_s`` /
    ``e2e_s`` timing split stamped in), or raises the typed error the
    request died with.  ``cancel()`` withdraws a still-queued request.
    """

    # Checked by repro.analysis rule C001: these fields are only mutated
    # while holding the named lock (dispatch/cancel race on them).
    _GUARDED_BY = {"_cancelled": "_lock", "_started": "_lock"}

    def __init__(self, matrix: str, query, group_key: Optional[tuple], deadline: Optional[float]):
        self.matrix = matrix
        self.query = query
        self.group_key = group_key
        self.deadline = deadline  # absolute time.monotonic(), or None
        self.submit_t = time.monotonic()
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._result: Optional[EigenResult] = None
        self._exception: Optional[BaseException] = None
        self._cancelled = False
        self._started = False
        self.attempts = 0  # dispatch attempts so far (retry accounting)
        self.not_before = 0.0  # monotonic time before which a retry must wait

    # -- caller side ------------------------------------------------------

    def cancel(self) -> bool:
        """Withdraw the request if it has not been dispatched; returns
        whether the cancellation took effect."""
        with self._lock:
            if self._started or self._event.is_set():
                return False
            self._cancelled = True
            return True

    def cancelled(self) -> bool:
        return self._cancelled

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> EigenResult:
        if not self._event.wait(timeout):
            raise TimeoutError(f"query against {self.matrix!r} not done after {timeout}s")
        if self._exception is not None:
            raise self._exception
        return self._result  # type: ignore[return-value]

    def exception(self, timeout: Optional[float] = None) -> Optional[BaseException]:
        if not self._event.wait(timeout):
            raise TimeoutError(f"query against {self.matrix!r} not done after {timeout}s")
        return self._exception

    # -- scheduler side ---------------------------------------------------

    def _start(self) -> bool:
        """Mark dispatched; False when a cancel won the race."""
        with self._lock:
            if self._cancelled:
                return False
            self._started = True
            return True

    def _set_result(self, res: EigenResult) -> None:
        self._result = res
        self._event.set()

    def _set_exception(self, exc: BaseException) -> None:
        self._exception = exc
        self._event.set()

    def _reset_for_retry(self) -> None:
        """Back onto the queue after a retryable failure: un-mark dispatched
        so cancel() works again while the retry waits out its backoff."""
        with self._lock:
            self._started = False


class EigenScheduler:
    """Async eigensolver server over a bounded pool of prepared sessions.

    ::

        store = SessionStore(root)                  # optional persistence
        with EigenScheduler(store=store) as sched:
            key = sched.add_matrix(csr)             # warm from store, or build
            h = sched.submit(key, k=8, num_iters=32, deadline_s=0.5)
            res = h.result()                        # EigenResult future

    One dispatch thread executes coalesced ``eigsh_many`` groups; distinct
    sessions stay independent (the session layer serializes per-session
    query batches internally).  ``start=False`` constructs the scheduler
    paused — submissions queue but nothing dispatches until :meth:`start` —
    which is also the deterministic way to test backpressure and deadlines.
    """

    # Checked by repro.analysis rule C001.  Everything the dispatch thread
    # and submitters share is guarded by the scheduler condition variable
    # (``_cv`` wraps ``_lock``); ``_thread``/``_watchdog`` are lifecycle
    # handles owned by start()/close() callers and deliberately absent.
    _GUARDED_BY = {
        "_sessions": "_cv",
        "_queue": "_cv",
        "_running": "_cv",
        "_closed": "_cv",
        "_crashed": "_cv",
        "_inflight": "_cv",
        "_breakers": "_cv",
    }

    def __init__(
        self,
        config: Optional[SchedulerConfig] = None,
        *,
        store: Optional[SessionStore] = None,
        start: bool = True,
    ):
        self.config = config or SchedulerConfig()
        self.store = store
        self.metrics = ServingMetrics()
        self._sessions: "OrderedDict[str, EigenSession]" = OrderedDict()
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._queue: Deque[QueryHandle] = deque()
        self._thread: Optional[threading.Thread] = None
        self._watchdog: Optional[threading.Thread] = None
        self._running = False
        self._closed = False
        self._crashed = False
        self._inflight: List[QueryHandle] = []  # group the dispatch thread holds
        self._breakers: Dict[str, dict] = {}  # matrix -> breaker state
        if start:
            self.start()

    # ---------------------------------------------------------- lifecycle

    def start(self) -> "EigenScheduler":
        with self._cv:
            if self._closed:
                raise ServingError("scheduler is closed")
            if self._running:
                return self
            self._running = True
            self._crashed = False
            self._thread = threading.Thread(
                target=self._loop, name="eigen-scheduler", daemon=True
            )
            self._thread.start()
            self._watchdog = threading.Thread(
                target=self._watchdog_loop,
                args=(self._thread,),
                name="eigen-scheduler-watchdog",
                daemon=True,
            )
            self._watchdog.start()
        return self

    def close(self, *, persist: bool = True, timeout: float = 30.0) -> None:
        """Stop dispatching, fail leftover queued requests with
        :class:`ServingError`, and (by default) persist every resident
        session to the attached store."""
        with self._cv:
            self._closed = True
            self._running = False
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        if self._watchdog is not None:
            # The watchdog exits on its next poll once _running is False.
            self._watchdog.join(self.config.watchdog_interval_s * 4)
            self._watchdog = None
        with self._cv:
            leftovers = list(self._queue)
            self._queue.clear()
        for h in leftovers:
            h._set_exception(ServingError("scheduler closed before dispatch"))
        if persist:
            self.persist()

    def persist(self) -> int:
        """Save every resident session's built plans to the store (no-op
        without one); returns how many sessions were written."""
        if self.store is None:
            return 0
        with self._cv:
            sessions = list(self._sessions.values())
        return sum(1 for s in sessions if self.store.save(s) is not None)

    def __enter__(self) -> "EigenScheduler":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # --------------------------------------------------------- admin plane

    def add_matrix(
        self,
        A,
        *,
        name: Optional[str] = None,
        config: Optional[SolverConfig] = None,
        n: Optional[int] = None,
    ) -> str:
        """Make a matrix resident: prepare (or warm-restore) its session and
        return the key ``submit`` addresses it by (``name``, defaulting to
        the matrix fingerprint).  With a store attached, a persisted entry
        for (matrix, layout) warms the session with zero conversions; a cold
        build is persisted for the next process.  Beyond
        ``config.max_sessions`` residents, the LRU session is evicted."""
        session = EigenSession(A, config, n=n)
        imported = self.store.load_into(session) if self.store is not None else 0
        if imported > 0:
            self.metrics.inc("warm_starts")
        else:
            session.warmup()
            self.metrics.inc("cold_builds")
            if self.store is not None:
                self.store.save(session)
        key = name or session.ensure_fingerprint()
        if key is None:
            raise ServingError(
                "matrix has no content fingerprint (matrix-free input?); pass name="
            )
        evicted: List[EigenSession] = []
        with self._cv:
            self._sessions[key] = session
            self._sessions.move_to_end(key)
            while len(self._sessions) > self.config.max_sessions:
                _, old = self._sessions.popitem(last=False)
                evicted.append(old)
        for old in evicted:  # persist outside the lock: saves can be slow
            if self.store is not None:
                self.store.save(old)
        return key

    def session(self, matrix: str) -> EigenSession:
        with self._cv:
            sess = self._sessions.get(matrix)
        if sess is None:
            raise UnknownMatrixError(f"matrix {matrix!r} is not resident; add_matrix first")
        return sess

    # --------------------------------------------------------- query plane

    def submit(
        self,
        matrix: str,
        query: Any = None,
        *,
        deadline_s: Optional[float] = None,
        **fields,
    ) -> QueryHandle:
        """Queue one query against a resident matrix; returns its future.

        ``query`` is anything ``eigsh_many`` accepts (an ``EigQuery``, a
        dict, a bare ``k``); alternatively pass the fields as keywords
        (``submit(key, k=8, policy="FDF")``).  Validation runs *here* — an
        infeasible query (bad ``k``/``num_iters``) raises ``ValueError``
        synchronously, never poisoning a batch.  ``deadline_s`` (relative
        seconds) bounds queue wait: the dispatcher never holds a batch open
        past it, and expires the request with
        :class:`DeadlineExceededError` if the solve cannot start in time.
        """
        sess = self.session(matrix)  # raises UnknownMatrixError
        q = _as_query(query if query is not None else fields)
        gkey = sess.group_key(q)  # validates; raises ValueError on bad queries
        deadline = time.monotonic() + float(deadline_s) if deadline_s is not None else None
        h = QueryHandle(matrix, q, gkey, deadline)
        with self._cv:
            if self._closed:
                raise ServingError("scheduler is closed")
            if self._crashed:
                raise SchedulerCrashedError(
                    "scheduler dispatch thread died; start() it again to recover"
                )
            self._breaker_admit_locked(matrix)
            if len(self._queue) >= self.config.max_queue:
                self.metrics.inc("rejected_full")
                raise QueueFullError(
                    f"request queue at capacity ({self.config.max_queue} pending); "
                    "retry with backoff or raise SchedulerConfig.max_queue"
                )
            self._queue.append(h)
            self._sessions.move_to_end(matrix)  # LRU touch
            self.metrics.inc("submitted")
            self._cv.notify_all()
        return h

    def stats(self) -> ServerStats:
        """Point-in-time :class:`~repro.serving.metrics.ServerStats`."""
        with self._cv:
            depth = len(self._queue)
            nsess = len(self._sessions)
        return self.metrics.snapshot(queue_depth=depth, sessions=nsess)

    # ------------------------------------------------------ circuit breaker

    def _breaker_admit_locked(self, matrix: str) -> None:
        """Fail-fast gate at submission (caller holds the lock): raises
        :class:`SessionUnhealthyError` while the matrix's breaker is open.
        After the cooldown the breaker half-opens — ONE probe submission
        passes; further submissions keep failing until the probe's dispatch
        outcome closes (success) or re-opens (failure) the breaker."""
        if self.config.breaker_threshold <= 0:
            return
        b = self._breakers.get(matrix)
        if b is None or b["state"] == "closed":
            return
        now = time.monotonic()
        if b["state"] == "open" and now >= b["open_until"]:
            b["state"] = "half"  # this submission is the probe
            return
        self.metrics.inc("rejected_breaker")
        raise SessionUnhealthyError(
            f"matrix {matrix!r} breaker is {b['state']} after "
            f"{b['failures']} consecutive dispatch failure(s); "
            f"retry after the cooldown ({self.config.breaker_cooldown_s}s)"
        )

    def _breaker_record(self, matrix: str, ok: bool) -> None:
        """Fold one dispatch outcome into the matrix's breaker state."""
        if self.config.breaker_threshold <= 0:
            return
        with self._cv:
            b = self._breakers.setdefault(
                matrix, {"state": "closed", "failures": 0, "open_until": 0.0}
            )
            if ok:
                b["state"] = "closed"
                b["failures"] = 0
                return
            b["failures"] += 1
            tripping = (
                b["failures"] >= self.config.breaker_threshold
                or b["state"] == "half"  # the probe itself failed
            )
            if tripping and b["state"] != "open":
                b["state"] = "open"
                b["open_until"] = time.monotonic() + self.config.breaker_cooldown_s
                self.metrics.inc("breaker_trips")
            elif b["state"] == "open":
                b["open_until"] = time.monotonic() + self.config.breaker_cooldown_s

    def breaker_state(self, matrix: str) -> str:
        """Current breaker state for a matrix: "closed" | "open" | "half"."""
        with self._cv:
            b = self._breakers.get(matrix)
            return b["state"] if b else "closed"

    # ------------------------------------------------------- dispatch loop

    def _resolve_dead(self, h: QueryHandle, now: float) -> bool:
        """Terminally resolve a cancelled/expired request; True if it died."""
        if h.cancelled():
            self.metrics.inc("cancelled")
            h._set_exception(QueryCancelledError(f"query against {h.matrix!r} cancelled"))
            return True
        if h.deadline is not None and now > h.deadline:
            self.metrics.inc("rejected_deadline")
            h._set_exception(
                DeadlineExceededError(
                    f"deadline exceeded before dispatch "
                    f"(waited {now - h.submit_t:.3f}s in queue)"
                )
            )
            return True
        return False

    def _take_compatible(self, seed: QueryHandle, room: int) -> List[QueryHandle]:  # repro: holds[_cv]
        """Pull every queued request coalescible with ``seed`` (same matrix,
        same non-None group key), resolving dead ones along the way.  Caller
        holds the lock."""
        if seed.group_key is None or room <= 0:
            return []
        now = time.monotonic()
        taken: List[QueryHandle] = []
        keep: Deque[QueryHandle] = deque()
        while self._queue:
            h = self._queue.popleft()
            if self._resolve_dead(h, now):
                continue
            if (
                len(taken) < room
                and h.matrix == seed.matrix
                and h.group_key == seed.group_key
                and h.not_before <= now  # retries wait out their backoff
            ):
                taken.append(h)
            else:
                keep.append(h)
        self._queue.extend(keep)
        return taken

    def _next_group(self) -> Optional[List[QueryHandle]]:
        """Block until a batch is ready: pop the oldest live request, then
        hold the batch open for the admission window (clipped to the batch's
        earliest deadline), coalescing compatible arrivals."""
        with self._cv:
            seed: Optional[QueryHandle] = None
            while seed is None:
                if not self._running:
                    return None
                now = time.monotonic()
                backing_off: Deque[QueryHandle] = deque()
                while self._queue:
                    h = self._queue.popleft()
                    if self._resolve_dead(h, now):
                        continue
                    if h.not_before > now:
                        backing_off.append(h)  # retry not yet eligible
                        continue
                    seed = h
                    break
                while backing_off:  # restore skipped retries, order kept
                    self._queue.appendleft(backing_off.pop())
                if seed is None:
                    self._cv.wait(timeout=0.1)
            group = [seed]
            window_end = time.monotonic() + self.config.admission_window_s
            if seed.deadline is not None:
                window_end = min(window_end, seed.deadline)
            while len(group) < self.config.max_group:
                taken = self._take_compatible(seed, self.config.max_group - len(group))
                group.extend(taken)
                for h in taken:
                    if h.deadline is not None:
                        # Deadline-aware formation: never idle past the most
                        # urgent member's slack.
                        window_end = min(window_end, h.deadline)
                if seed.group_key is None or len(group) >= self.config.max_group:
                    break
                remaining = window_end - time.monotonic()
                if remaining <= 0 or not self._running:
                    break
                self._cv.wait(timeout=remaining)
            # Last sweep: arrivals during the final wait still make the bus.
            if seed.group_key is not None and len(group) < self.config.max_group:
                group.extend(self._take_compatible(seed, self.config.max_group - len(group)))
        return group

    def _dispatch(self, group: List[QueryHandle]) -> None:
        t_dispatch = time.monotonic()
        live = [h for h in group if not self._resolve_dead(h, t_dispatch) and h._start()]
        if not live:
            return
        with self._cv:
            sess = self._sessions.get(live[0].matrix)
        if sess is None:
            self.metrics.inc("failed", len(live))
            for h in live:
                h._set_exception(
                    UnknownMatrixError(f"matrix {h.matrix!r} was evicted while queued")
                )
            return
        try:
            results = sess.eigsh_many([h.query for h in live])
        except Exception as exc:
            self._dispatch_failed(live, exc)
            return
        self._breaker_record(live[0].matrix, ok=True)
        self.metrics.record_group(len(live))
        for h, res in zip(live, results):
            queue_s = t_dispatch - h.submit_t
            res = with_queue_time(res, queue_s)
            self.metrics.record_latency(queue_s, float(res.timings.get("total_s", 0.0)))
            self.metrics.inc("completed")
            h._set_result(res)

    @staticmethod
    def _retryable(exc: BaseException) -> bool:
        """Is this dispatch failure worth a retry?  Transient solver/runtime
        failures only — a validation error fails the same way every time."""
        from ..core.lanczos import NumericalBreakdown
        from ..testing.faults import InjectedFault

        if isinstance(exc, (ServingError, ValueError, TypeError)):
            return False
        if isinstance(exc, (NumericalBreakdown, OSError, MemoryError, InjectedFault)):
            return True
        msg = str(exc)
        return "RESOURCE_EXHAUSTED" in msg or "out of memory" in msg.lower()

    def _dispatch_failed(self, live: List[QueryHandle], exc: Exception) -> None:
        """One dispatch blew up: feed the breaker, then split the group into
        requeued retries (budget left, transient failure — exponential
        backoff + jitter decides when each becomes eligible) and terminal
        failures (resolved with the original exception)."""
        self._breaker_record(live[0].matrix, ok=False)
        cfg = self.config
        retryable = cfg.max_retries > 0 and self._retryable(exc)
        retry = [h for h in live if retryable and h.attempts < cfg.max_retries]
        fail = [h for h in live if h not in retry]
        if fail:
            self.metrics.inc("failed", len(fail))
            for h in fail:
                h._set_exception(exc)
        if not retry:
            return
        now = time.monotonic()
        with self._cv:
            for h in retry:
                h.attempts += 1
                backoff = cfg.retry_backoff_s * (2.0 ** (h.attempts - 1))
                backoff *= 1.0 + max(0.0, cfg.retry_jitter) * random.random()
                h.not_before = now + backoff
                h._reset_for_retry()
                self._queue.append(h)
            self.metrics.inc("retries", len(retry))
            self._cv.notify_all()

    def _loop(self) -> None:
        # Guarded loop: ANY exception a dispatch leaks is contained here —
        # the group fails typed, the thread survives, the next group runs.
        # (Before this guard, one leaked exception killed the thread and
        # stranded every queued future forever.)  Injected
        # SchedulerThreadDeath derives from BaseException on purpose: it
        # escapes the guard and genuinely kills the thread, which is the
        # watchdog's test surface.
        while True:
            group = self._next_group()
            if group is None:
                return
            with self._cv:
                self._inflight = group
            try:
                _faults.check_scheduler()
                self._dispatch(group)
            except Exception as exc:
                self.metrics.inc("dispatch_errors")
                pending = [h for h in group if not h.done()]
                if pending:
                    self.metrics.inc("failed", len(pending))
                    err = ServingError(
                        f"internal dispatch failure: {type(exc).__name__}: {exc}"
                    )
                    for h in pending:
                        h._set_exception(err)
            with self._cv:
                self._inflight = []

    # ------------------------------------------------------------ watchdog

    def _watchdog_loop(self, thread: threading.Thread) -> None:
        """Detect dispatch-thread death (anything that escapes the loop
        guard) and fail every stranded request with a typed
        :class:`SchedulerCrashedError` — a crashed scheduler must never
        leave submitters blocked on futures that cannot resolve."""
        while True:
            time.sleep(self.config.watchdog_interval_s)
            with self._cv:
                if not self._running or self._thread is not thread:
                    return  # closed, or superseded by a restart
            if not thread.is_alive():
                self._on_dispatch_death()
                return

    def _on_dispatch_death(self) -> None:
        with self._cv:
            if not self._running:
                return  # normal close raced us
            self._crashed = True
            self._running = False
            stranded = [
                h
                for h in list(self._queue) + list(self._inflight)
                if not h.done()
            ]
            self._queue.clear()
            self._inflight = []
            self.metrics.inc("watchdog_trips")
            if stranded:
                self.metrics.inc("failed", len(stranded))
            self._cv.notify_all()
        err = SchedulerCrashedError(
            "dispatch thread died unexpectedly; this query was failed by the "
            "watchdog (start() the scheduler again to recover)"
        )
        for h in stranded:
            h._set_exception(err)
