"""Pallas TPU kernel: packed-ELL SpMV — compressed out-of-core staging.

The out-of-core engine's bottleneck is staging bandwidth (host DRAM -> HBM
DMA on TPU; see ``core/operators.ChunkedOperator``).  This kernel multiplies
the *effective* bandwidth by shipping each staged chunk compressed and
decompressing on-chip:

  * ``val``   — (rows, width) chunk values in a narrow storage dtype
    (bf16 or fp8 e4m3), quantized per row block;
  * ``scale`` — (rows, 1) f32 dequantization scale (one scale per row block
    of the packing, expanded to per-row at pack time so the kernel tile
    math stays trivial);
  * ``base``  — (rows, 1) int32 first stored column of each row;
  * ``dcol``  — (rows, width) int16/int32 *delta-encoded* column indices:
    ``dcol[r, 0] == 0`` and ``dcol[r, s] == col[r, s] - col[r, s-1]``.
    Sorted CSR rows make the deltas small, so int16 usually suffices —
    half the index bytes of the plain ELL layout.

In-kernel decompression recovers ``col = base + cumsum(dcol, axis=1)`` and
``v = val * scale``; the row-wise cumsum requires the whole width in one
tile, so the grid is one-dimensional over row blocks (chunk widths are
per-chunk and modest — the staging layer builds per-chunk-width tiles, see
``ChunkedOperator._build_chunk``).  The single grid dimension is parallel
over independent row blocks; there is no cross-step accumulator.

Packing itself (quantize + delta-encode) is host-side NumPy in the staging
path — the kernel is the *decompress + SpMV* half that runs per staged
chunk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
from jax.experimental import pallas as pl

__all__ = [
    "PACKED_VALUE_DTYPES",
    "pack_ell_chunk",
    "spmv_ell_packed_kernel_call",
]

# staging-mode name -> narrow storage dtype of the packed values
PACKED_VALUE_DTYPES = {
    "bf16": np.dtype(ml_dtypes.bfloat16),
    "fp8": np.dtype(ml_dtypes.float8_e4m3fn),
}
# Rows sharing one quantization scale (the "per-row-block" granularity).
SCALE_BLOCK_ROWS = 8


def pack_ell_chunk(val: np.ndarray, col: np.ndarray, mode: str):
    """Quantize + delta-encode one host-side ELL chunk.

    Returns ``(val_packed, scale, base, dcol)`` host arrays matching the
    kernel's operand layout.  ``scale`` is computed over row blocks of
    ``SCALE_BLOCK_ROWS`` rows (max-abs mapped to the dtype's finite range
    for fp8; bf16 shares f32's exponent range so its scale is 1) and
    expanded to per-row ``(rows, 1)``.  ``dcol`` narrows to int16 when every
    delta fits, else stays int32.
    """
    vdt = PACKED_VALUE_DTYPES.get(mode)
    if vdt is None:
        raise ValueError(
            f"unknown packed staging mode {mode!r}; expected {tuple(PACKED_VALUE_DTYPES)}"
        )
    rows, width = val.shape
    if rows % SCALE_BLOCK_ROWS:
        raise ValueError(
            f"packed chunk rows {rows} must be a multiple of {SCALE_BLOCK_ROWS}"
        )
    v64 = np.asarray(val, dtype=np.float64)
    if mode == "fp8":
        absmax = np.abs(v64).reshape(rows // SCALE_BLOCK_ROWS, -1).max(axis=1)
        fmax = float(ml_dtypes.finfo(vdt).max)
        block_scale = np.where(absmax > 0, absmax / fmax, 1.0)
    else:
        block_scale = np.ones(rows // SCALE_BLOCK_ROWS, dtype=np.float64)
    scale = np.repeat(block_scale, SCALE_BLOCK_ROWS).astype(np.float32).reshape(rows, 1)
    val_packed = (v64 / scale).astype(vdt)
    base = np.ascontiguousarray(col[:, :1], dtype=np.int32)
    dcol32 = np.diff(col.astype(np.int64), axis=1, prepend=base.astype(np.int64))
    idt = np.int16 if np.abs(dcol32).max(initial=0) < (1 << 15) else np.int32
    return val_packed, scale, base, dcol32.astype(idt)


def _kernel(x_ref, val_ref, scale_ref, base_ref, dcol_ref, y_ref, *, accum_dtype):
    x = x_ref[...]  # full vector, VMEM-resident (same contract as spmv_ell)
    # Decompress: dequantize values, cumsum the column deltas back to
    # absolute indices.  The whole row width is in this tile (1-D grid).
    vals = val_ref[...].astype(accum_dtype) * scale_ref[...].astype(accum_dtype)
    cols = base_ref[...] + jnp.cumsum(dcol_ref[...].astype(jnp.int32), axis=1)
    gathered = jnp.take(x, cols.reshape(-1), axis=0).reshape(cols.shape).astype(accum_dtype)
    y_ref[...] = jnp.sum(vals * gathered, axis=1)  # (BR,)


@functools.partial(
    jax.jit, static_argnames=("block_r", "accum_dtype", "interpret")
)
def spmv_ell_packed_kernel_call(
    val: jax.Array,
    scale: jax.Array,
    base: jax.Array,
    dcol: jax.Array,
    x: jax.Array,
    *,
    block_r: int = 8,
    accum_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    """y = dequant(val, scale) @ x at columns ``base + cumsum(dcol)``.

    Accumulates in ``accum_dtype``; returns (rows,).  The grid tiles rows
    only — the delta cumsum needs the full width per tile.
    """
    rows, width = val.shape
    if rows % block_r:
        raise ValueError(
            f"packed ELL shape {val.shape} rows not divisible by block_r={block_r}"
        )
    n = x.shape[0]
    grid = (rows // block_r,)
    return pl.pallas_call(
        functools.partial(_kernel, accum_dtype=accum_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),  # x: full vector each step
            pl.BlockSpec((block_r, width), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_r, width), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), accum_dtype),
        interpret=interpret,
    )(x, val, scale, base, dcol)
