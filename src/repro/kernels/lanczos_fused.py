"""Pallas TPU kernel: fused ELL SpMV + alpha-dot — one read of the Krylov vector.

A Lanczos iteration (Alg. 1 lines 5-7) is three memory-bound passes today:

    w     = A @ v                      (SpMV kernel)
    alpha = <v, w>                     (dot over n)
    u     = w - alpha v - beta v_prev  (+ ||u||^2, fused update kernel)

``roofline.py`` puts the step firmly on the memory roofline, so every pass
saved over the n-length vectors is throughput.  The dependency structure
caps fusion at *two* passes, not one: alpha needs every row of ``w`` before
any element of ``u`` can be written, and the TPU grid is sequential — a
single kernel that both produced ``w`` and consumed the finished alpha
would have to revisit output blocks non-consecutively, which Pallas does
not guarantee.  What *is* legal is folding the alpha reduction into the
SpMV itself: each row tile of ``w`` is still in VMEM when its width sweep
finishes, so the kernel accumulates ``alpha += <v_tile, w_tile>`` right
there (the (1,) alpha output block is pinned to every grid step, exactly
like the norm accumulator in ``lanczos_update.py``).  Combined with the
fused update kernel the iteration touches each n-vector once per pass:

    pass 1: spmv_alpha  -> w, alpha     (reads x/val/col, writes w, alpha free)
    pass 2: lanczos_update -> u, ||u||^2 (reads w/v/v_prev, writes u, norm free)

i.e. 2 passes instead of 4, and both reductions ride along for free.

``x`` is the gather source in *storage* dtype (full vector, VMEM-resident —
see spmv_ell.py for why); ``v`` is the same vector in *compute* dtype so the
in-kernel alpha matches the reference ``dot(v, w)`` association exactly on a
single row tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["spmv_ell_alpha_kernel_call"]


def _kernel(x_ref, v_ref, val_ref, col_ref, y_ref, alpha_ref, *, accum_dtype, n_w_steps):
    i = pl.program_id(0)
    j = pl.program_id(1)
    x = x_ref[...]  # full vector, VMEM-resident
    cols = col_ref[...]  # (BR, BW) int32
    vals = val_ref[...].astype(accum_dtype)
    gathered = jnp.take(x, cols.reshape(-1), axis=0).reshape(cols.shape).astype(accum_dtype)
    part = jnp.sum(vals * gathered, axis=1)  # (BR,)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = part

    @pl.when(j != 0)
    def _acc():
        y_ref[...] = y_ref[...] + part

    # The row tile of w is complete once the (sequential) width sweep ends;
    # fold its alpha contribution in while it is still in VMEM.  The (1,)
    # alpha block is pinned to every grid step, so it accumulates across
    # row tiles like the norm accumulator in lanczos_update.py.
    @pl.when(j == n_w_steps - 1)
    def _alpha():
        contrib = jnp.sum(y_ref[...] * v_ref[...].astype(accum_dtype))

        @pl.when(i == 0)
        def _first():
            alpha_ref[0] = contrib

        @pl.when(i != 0)
        def _rest():
            alpha_ref[0] = alpha_ref[0] + contrib


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_w", "accum_dtype", "interpret")
)
def spmv_ell_alpha_kernel_call(
    val: jax.Array,
    col: jax.Array,
    x: jax.Array,
    v: jax.Array,
    *,
    block_r: int = 8,
    block_w: int = 512,
    accum_dtype=jnp.float32,
    interpret: bool = True,
):
    """Fused ``w = ELL(val, col) @ x`` and ``alpha = <v, w>`` in one pass.

    ``x`` is the gather source (storage dtype); ``v`` is the dot operand
    (compute dtype), padded to ``rows`` — padded rows of an ELL layout have
    all-zero values, so they contribute w = 0 and nothing to alpha.
    Returns ``(w (rows,) accum_dtype, alpha (1,) accum_dtype)``.
    """
    rows, width = val.shape
    block_w = min(block_w, width)
    if rows % block_r or width % block_w:
        raise ValueError(f"ELL shape {val.shape} not divisible by ({block_r},{block_w})")
    if v.shape[0] != rows:
        raise ValueError(f"v length {v.shape[0]} != padded rows {rows}")
    n = x.shape[0]
    grid = (rows // block_r, width // block_w)
    return pl.pallas_call(
        functools.partial(_kernel, accum_dtype=accum_dtype, n_w_steps=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i, j: (0,)),  # x: full vector each step
            pl.BlockSpec((block_r,), lambda i, j: (i,)),  # v: row tile
            pl.BlockSpec((block_r, block_w), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, block_w), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_r,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows,), accum_dtype),
            jax.ShapeDtypeStruct((1,), accum_dtype),
        ],
        interpret=interpret,
    )(x, v, val, col)
