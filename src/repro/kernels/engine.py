"""Pluggable SpMV execution layer: format selection + tile configuration.

The paper's headline speedup is the SpMV hot loop, but which *layout* wins is
a property of the matrix, not the solver: ELL when row lengths are near
uniform (padding overhead bounded), blocked-ELL/BSR when the non-zeros
cluster into dense blocks (SpMV becomes a stream of MXU matmuls — see
``spmv_bsr.py`` for the ~1/BS fill crossover), COO ``segment_sum`` otherwise.
:class:`SpmvEngine` packages that decision — format + accumulation dtype +
Pallas tile parameters — behind one object so every solver engine
(``solve_fixed``, ``solve_sharded``, ``ChunkedOperator``) executes the same
kernels instead of each open-coding its own SpMV.

Format auto-selection (``choose_format``) runs on cheap O(nnz) statistics of
the host CSR:

  * ``ell_overhead``  — padded ELL slots / nnz = ``max_row_nnz * n / nnz``.
    ELL is chosen when this is bounded (default <= 3.0: at most 2/3 of the
    kernel's work is padding).
  * ``block_fill``    — nnz / (touched BS x BS blocks * BS^2).  BSR wins when
    a stored block is dense enough that one MXU matvec beats BS scalar-gather
    rows; the absolute flop crossover is ~1/BS (spmv_bsr.py), but padding and
    bandwidth push the practical line higher, so the default requires
    ``block_fill >= BSR_FILL_FACTOR / BS`` (factor 4 => half-dense blocks at
    BS=8).

A fourth format, ``hybrid``, is the hub-row split: ELL width is capped at a
quantile of the row lengths and the overflow of the few hub rows spills into
a COO tail (``segment_sum``).  Power-law matrices whose max row blows the ELL
bound still run the Pallas kernel for the bounded bulk of their non-zeros
(``hyb_overhead`` / ``hyb_tail_frac`` in :class:`SpmvStats` drive the choice).

Tile parameters come from the static table (``select_tiles``) by default, or
from the **measured autotuner** (:func:`tuned_tiles`) when
``REPRO_SPMV_TUNE=1``: a small candidate grid is timed on probe SpMVs for the
actual (shape-bucket, dtype, format), memoized in-process and persisted to a
JSON cache (``REPRO_SPMV_TUNE_CACHE``).  The static table remains the prior
and the cold-start fallback, and ``REPRO_SPMV_TILES`` pins tiles outright;
the decision's provenance ("table" | "tuned" | "override") is surfaced in
``partition["spmv"]``.

On top of the per-SpMV tile probes, the tuner resolves a **whole-iteration
plan** (:class:`IterationPlan`): fused-vs-unfused Lanczos update (and the
fully-fused SpMV+alpha pass for ELL) x tile shapes x BSR block size, timed
on a real Lanczos step — SpMV, alpha dot, three-term update, norm — because
the fastest SpMV tile is not always the fastest *iteration* (the fused
kernels shift where the memory traffic goes).  The winner persists in the
same JSON cache (``kind: "iteration"`` entries) and is surfaced as
``partition["spmv"]["iteration_plan"]``; with tuning off a static table
keyed on the execution mode decides (interpret mode pays per-grid-step
interpreter overhead that makes the fused kernels lose, so it defaults to
unfused; compiled Mosaic defaults to fused).  Every persisted entry carries
a grid fingerprint (:func:`grid_fingerprint`) hashing the candidate-space
definition, so autotuner or kernel-grid changes auto-invalidate stale
entries instead of requiring a manual CI cache-key bump.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
import warnings
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import env as envcfg

__all__ = [
    "FORMATS",
    "ITER_UPDATE_MODES",
    "TileConfig",
    "IterationPlan",
    "TileTuner",
    "SpmvStats",
    "SpmvEngine",
    "grid_fingerprint",
    "matrix_stats",
    "shard_stats",
    "choose_format",
    "select_tiles",
    "tuned_tiles",
    "resolve_iteration_plan",
    "table_update_mode",
    "get_tuner",
    "tuner_probe_count",
    "make_engine",
]

FORMATS = ("coo", "ell", "bsr", "hybrid")

# ELL accepted while padded slots <= ELL_MAX_OVERHEAD * nnz.
ELL_MAX_OVERHEAD = 3.0
# BSR accepted while block_fill >= BSR_FILL_FACTOR / block_size.
BSR_FILL_FACTOR = 4.0
DEFAULT_BLOCK_SIZE = 8
# Hybrid ELL+COO: cap the ELL width at this quantile of the row lengths...
HYBRID_QUANTILE = 0.95
# ...and accept while the spilled tail stays a minority of the nnz (the
# kernel must do the bulk of the work for the split to beat plain COO).
HYBRID_MAX_TAIL = 0.6


def _env_float(name: str, default: float) -> float:
    return envcfg.get_float(name, default, lenient=True)


def ell_overhead_bound() -> float:
    """The effective ELL padding bound (env-overridable) — the single parse
    every consumer of ``REPRO_SPMV_ELL_OVERHEAD`` shares."""
    return _env_float("REPRO_SPMV_ELL_OVERHEAD", ELL_MAX_OVERHEAD)


def _fit_tile(tile: int, extent: int) -> int:
    """Largest tile <= ``tile`` that divides ``extent`` (halving search)."""
    t = max(1, min(tile, extent))
    while extent % t:
        t //= 2
    return t


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """Pallas grid tile parameters for the SpMV kernels.

    ``block_r`` / ``block_w`` tile the ELL (rows, width) grid; ``block_size``
    is the dense block edge of the blocked-ELL/BSR layout.  Conversions pad
    rows to ``block_r`` and widths to ``block_w`` so the kernel BlockSpecs
    always divide evenly.
    """

    block_r: int = 8
    block_w: int = 128
    block_size: int = DEFAULT_BLOCK_SIZE


# How the Lanczos three-term update runs, in increasing fusion order:
#   unfused    — jnp expressions (XLA fuses what it can; fastest in interpret
#                mode, where Pallas pays per-grid-step interpreter overhead)
#   fused      — the lanczos_update kernel (update + norm in one pass)
#   fused_spmv — spmv_ell_alpha + lanczos_update: the whole iteration in two
#                passes over the Krylov vectors (ELL only)
ITER_UPDATE_MODES = ("unfused", "fused", "fused_spmv")
# BSR block edges the iteration probe re-converts through (the block size
# changes the *layout*, so picking it needs a measurement, not a re-tile).
_ITER_BSR_BLOCKS = (4, 8, 16)


@dataclasses.dataclass(frozen=True)
class IterationPlan:
    """Measured whole-iteration decision: update mode + tiles (jointly).

    ``tiles.block_size`` carries the BSR block-edge decision (a re-conversion,
    not a re-tile).  ``source`` is the provenance: "table" (static default for
    the execution mode), "tuned" (won a measured whole-iteration probe), or
    "override" (``REPRO_ITER_UPDATE`` pin).
    """

    update: str = "unfused"
    tiles: TileConfig = TileConfig()
    source: str = "table"  # "table" | "tuned" | "override"

    def __post_init__(self):
        if self.update not in ITER_UPDATE_MODES:
            raise ValueError(
                f"unknown update mode {self.update!r}; expected {ITER_UPDATE_MODES}"
            )

    def as_dict(self) -> dict:
        return {
            "update": self.update,
            "block_r": self.tiles.block_r,
            "block_w": self.tiles.block_w,
            "block_size": self.tiles.block_size,
            "source": self.source,
        }


# Bump when the cache entry layout itself changes (fields, key format).
_GRID_SCHEMA = 2


def grid_fingerprint() -> str:
    """Hash of the autotuner's candidate-space definition.

    Stamped into every persisted cache entry and checked on load: a change to
    the tile table, the update-mode space, or the probe grids silently drops
    stale entries (they re-measure on next use) instead of serving tiles that
    were never measured against the current kernels.  This replaces the old
    "bump the CI cache-key suffix by hand" contract.
    """
    payload = repr((_GRID_SCHEMA, _TILE_TABLE, ITER_UPDATE_MODES, _ITER_BSR_BLOCKS))
    return hashlib.blake2b(payload.encode(), digest_size=8).hexdigest()


# Static tile table: (max_rows, max_width) upper bounds -> (block_r, block_w).
# Larger shards get taller/wider tiles to amortize grid steps; entries are
# scanned in order and the first row that fits is used.  bf16/f16 rows double
# block_r to honor the TPU (16, 128) sublane minimum for 16-bit dtypes.
_TILE_TABLE: Tuple[Tuple[int, int, int, int], ...] = (
    # max_rows, max_width, block_r, block_w
    (1 << 10, 1 << 8, 8, 128),
    (1 << 10, 1 << 30, 8, 256),
    (1 << 14, 1 << 8, 16, 128),
    (1 << 14, 1 << 30, 16, 256),
    (1 << 30, 1 << 8, 32, 128),
    (1 << 30, 1 << 30, 32, 512),
)


def select_tiles(
    n_rows: int,
    width: int,
    dtype=jnp.float32,
    block_size: int = DEFAULT_BLOCK_SIZE,
    interpret: bool = False,
) -> TileConfig:
    """Pick kernel tiles from the static table (env override wins).

    ``REPRO_SPMV_TILES="block_r,block_w[,block_size]"`` pins the tiles for
    experiments (the env/config hook the ROADMAP autotuner will replace).

    ``interpret=True`` (CPU validation): the Pallas interpreter executes grid
    steps sequentially with high per-step overhead and has no VMEM ceiling,
    so it gets few, large tiles — same kernel code, tractable wall time.
    """
    env = envcfg.get_str("REPRO_SPMV_TILES")
    if env:
        parts = [int(p) for p in env.split(",")]
        if len(parts) not in (2, 3):
            raise ValueError(
                f"REPRO_SPMV_TILES={env!r}: expected 'block_r,block_w[,block_size]'"
            )
        bs = parts[2] if len(parts) == 3 else block_size
        return TileConfig(block_r=parts[0], block_w=parts[1], block_size=bs)

    if interpret:
        return TileConfig(block_r=512, block_w=2048, block_size=block_size)

    block_r, block_w = _TILE_TABLE[-1][2:]
    for max_rows, max_width, br, bw in _TILE_TABLE:
        if n_rows <= max_rows and width <= max_width:
            block_r, block_w = br, bw
            break
    if jnp.dtype(dtype).itemsize == 2:  # bf16/f16 sublane minimum is 16
        block_r = max(block_r, 16)
    return TileConfig(block_r=block_r, block_w=block_w, block_size=block_size)


# ------------------------------ tile autotuner -------------------------------

DEFAULT_TUNE_CACHE = os.path.join(
    os.path.expanduser("~"), ".cache", "repro", "spmv_tune.json"
)
# Formats whose kernel exposes tile knobs (the BSR kernel's tiling is fixed by
# its block size, so only the ELL-family grids are tunable).
_TUNABLE_FORMATS = ("ell", "hybrid")


def tune_enabled() -> bool:
    """Measured tuning is opt-in: the static table is the default behavior."""
    return envcfg.get_bool("REPRO_SPMV_TUNE")


class TileTuner:
    """Measured tile cache: in-process memo + persistent JSON.

    One entry per (format, dtype, shape-bucket, execution mode) key; the value
    is the fastest :class:`TileConfig` of the measured candidate grid plus the
    raw per-candidate timings (kept for postmortems).  Whole-iteration plans
    (:class:`IterationPlan`) live in the same file as ``kind: "iteration"``
    entries under an ``iter|``-prefixed key.  Every entry is stamped with the
    current :func:`grid_fingerprint`; entries whose stamp mismatches (or is
    absent — pre-fingerprint caches) are dropped on load, so a stale cache
    re-measures instead of serving tiles from a different candidate space.
    The JSON survives processes (CI caches it between runs); a missing/corrupt
    file degrades to an empty cache, never an error.
    """

    def __init__(self, cache_path: Optional[str] = None):
        self.cache_path = cache_path or DEFAULT_TUNE_CACHE
        self._mem: Dict[str, TileConfig] = {}
        self._plans: Dict[str, IterationPlan] = {}
        self._meta: Dict[str, dict] = {}
        self._loaded = False
        self.measure_count = 0  # tune passes actually run (tests assert on it)

    def _load(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        fp = grid_fingerprint()
        try:
            with open(self.cache_path) as f:
                payload = json.load(f)
            for key, rec in payload.get("entries", {}).items():
                if rec.get("grid") != fp:
                    continue  # stale candidate space: drop, re-measure on use
                tiles = TileConfig(
                    block_r=int(rec["block_r"]),
                    block_w=int(rec["block_w"]),
                    block_size=int(rec.get("block_size", DEFAULT_BLOCK_SIZE)),
                )
                if rec.get("kind") == "iteration":
                    self._plans[key] = IterationPlan(
                        update=str(rec["update"]), tiles=tiles, source="tuned"
                    )
                else:
                    self._mem[key] = tiles
                self._meta[key] = rec
        except (OSError, ValueError, KeyError, TypeError):
            pass  # absent or corrupt cache = cold start

    def lookup(self, key: str) -> Optional[TileConfig]:
        self._load()
        return self._mem.get(key)

    def lookup_plan(self, key: str) -> Optional[IterationPlan]:
        self._load()
        return self._plans.get(key)

    def _dump(self) -> None:
        try:
            os.makedirs(os.path.dirname(os.path.abspath(self.cache_path)), exist_ok=True)
            tmp = self.cache_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"version": 2, "entries": self._meta}, f, indent=1, sort_keys=True)
            os.replace(tmp, self.cache_path)
        except OSError:
            pass  # read-only cache dir: keep the in-process memo only

    def record(self, key: str, tiles: TileConfig, timings: Dict[str, float]) -> None:
        self._load()
        self._mem[key] = tiles
        self._meta[key] = {
            "block_r": tiles.block_r,
            "block_w": tiles.block_w,
            "block_size": tiles.block_size,
            "grid": grid_fingerprint(),
            "best_us": min(timings.values()) if timings else None,
            "candidates_us": timings,
        }
        self._dump()

    def record_plan(self, key: str, plan: IterationPlan, timings: Dict[str, float]) -> None:
        self._load()
        plan = dataclasses.replace(plan, source="tuned")
        self._plans[key] = plan
        self._meta[key] = {
            "kind": "iteration",
            "update": plan.update,
            "block_r": plan.tiles.block_r,
            "block_w": plan.tiles.block_w,
            "block_size": plan.tiles.block_size,
            "grid": grid_fingerprint(),
            "best_us": min(timings.values()) if timings else None,
            "candidates_us": timings,
        }
        self._dump()


_TUNER: Optional[TileTuner] = None


def get_tuner() -> TileTuner:
    """Process-wide tuner bound to the current ``REPRO_SPMV_TUNE_CACHE``."""
    global _TUNER
    path = envcfg.raw("REPRO_SPMV_TUNE_CACHE") or DEFAULT_TUNE_CACHE
    if _TUNER is None or _TUNER.cache_path != path:
        _TUNER = TileTuner(path)
    return _TUNER


def tuner_probe_count() -> int:
    """Measured tune passes run by this process so far (0 when tuning is
    off).  The session layer (api/session.py) verifies plan reuse against
    this: a cache-hit solve must not add probes."""
    return _TUNER.measure_count if _TUNER is not None else 0


def _next_pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


def _tune_key(fmt: str, dtype, n_rows: int, width: int, interpret: bool) -> str:
    """Shape-bucketed cache key: tiles depend on the size class, not the
    exact shard shape, so nearby problems share one measurement."""
    mode = "interp" if interpret else "mosaic"
    return f"{fmt}|{jnp.dtype(dtype).name}|r{_next_pow2(n_rows)}|w{_next_pow2(width)}|{mode}"


def _candidate_tiles(
    prior: TileConfig, dtype, interpret: bool, block_size: int
) -> Tuple[TileConfig, ...]:
    """Small grid around the static-table prior (the prior is always in it,
    so a tuned choice can never be worse than the table on the probe)."""
    budget = envcfg.get_int("REPRO_SPMV_TUNE_BUDGET")
    min_r = 16 if jnp.dtype(dtype).itemsize == 2 else 8
    if interpret:
        # The interpreter pays ~ms per grid step: only few-large-tile layouts
        # are viable, so the grid just probes the step-count tradeoff.
        rows = (prior.block_r, prior.block_r * 2, max(min_r, prior.block_r // 2))
        widths = (prior.block_w,)
    else:
        rows = (prior.block_r, prior.block_r * 2, max(min_r, prior.block_r // 2))
        widths = (prior.block_w, max(128, prior.block_w // 2), min(2048, prior.block_w * 2))
    out = []
    for r in rows:
        for w in widths:
            cfg = TileConfig(block_r=r, block_w=w, block_size=block_size)
            if cfg not in out:
                out.append(cfg)
    return tuple(out[: max(1, budget)])


def _measure_ell_tiles(
    n_rows: int,
    width: int,
    dtype,
    candidates: Sequence[TileConfig],
    interpret: bool,
    reps: int = 3,
) -> Dict[str, float]:
    """Median wall time (us) of probe ELL SpMVs per candidate tile config.

    The probe is a synthetic uniform ELL at the *layout* width the caller's
    conversions would build (callers pass the aligned width, see
    ``make_engine``), so the width tile each candidate is timed with is the
    one ``ell_matvec``'s divisibility clamp would actually run — the
    recorded key holds that runtime-adapted tile, never an unmeasured one.
    Rows are pow2-bucketed and capped so a tune pass stays sub-second-ish
    per candidate in interpret mode; the result is a *relative* ranking for
    this (shape, dtype, mode), not an absolute projection.
    """
    from .spmv_ell import spmv_ell_kernel_call

    # Probe at the problem's own row bucket: candidates whose block_r exceeds
    # it are skipped below (building the layout at such a tile would inflate
    # the real padded rows — a cost a bigger probe could never see).
    min_br = min(c.block_r for c in candidates)
    rows_cap = 1 << 12 if interpret else 1 << 16
    rows = min(max(_next_pow2(n_rows), min_br), max(rows_cap, min_br))
    # Probe width: the real (already-aligned) layout width, capped for cost —
    # the cap rounds DOWN to the width's own alignment so candidate tiles
    # divide the probe exactly when they divide the real layout.
    width = max(8, width)
    width_cap = 1 << 11
    if width <= width_cap:
        width_b = width
    else:
        align = 128 if width % 128 == 0 else 8
        width_b = max(align, (width_cap // align) * align)
    rng = np.random.default_rng(0)
    val = jnp.asarray(rng.standard_normal((rows, width_b)), dtype=dtype)
    col = jnp.asarray(rng.integers(0, rows, (rows, width_b)), jnp.int32)
    x = jnp.asarray(rng.standard_normal(rows), dtype=dtype)
    # Dedup on the runtime-adapted tile: candidates differing only in a
    # block_w that _fit_tile collapses to the same width are one measurement.
    fitted = []
    for cfg in candidates:
        if rows % cfg.block_r:
            continue
        bw_real = _fit_tile(cfg.block_w, width)  # what ell_matvec would run
        if (cfg.block_r, bw_real) not in fitted:
            fitted.append((cfg.block_r, bw_real))
    timings: Dict[str, float] = {}
    for block_r, bw_real in fitted:
        bw_probe = _fit_tile(bw_real, width_b)
        acc = jnp.float32

        def run(br=block_r, bw=bw_probe):
            return spmv_ell_kernel_call(
                val, col, x, block_r=br, block_w=bw, accum_dtype=acc, interpret=interpret
            ).block_until_ready()

        run()  # compile/trace outside the timed reps
        ts = []
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            run()
            ts.append(time.perf_counter() - t0)
        timings[f"{block_r}x{bw_real}"] = float(np.median(ts) * 1e6)
    return timings


def tuned_tiles(
    n_rows: int,
    width: int,
    dtype=jnp.float32,
    format: str = "ell",
    block_size: int = DEFAULT_BLOCK_SIZE,
    interpret: bool = False,
) -> Tuple[TileConfig, str]:
    """Resolve kernel tiles with provenance: "table" | "tuned" | "override".

    Resolution order: the ``REPRO_SPMV_TILES`` pin wins outright ("override");
    otherwise the static table is the prior, and — only when
    ``REPRO_SPMV_TUNE=1`` and the format has tunable tiles — a measured pass
    over a small candidate grid refines it ("tuned"), cached under
    ``REPRO_SPMV_TUNE_CACHE`` so each (shape-bucket, dtype, format, mode) is
    measured at most once per cache lifetime.
    """
    if envcfg.get_str("REPRO_SPMV_TILES"):
        return select_tiles(n_rows, width, dtype, block_size, interpret), "override"
    prior = select_tiles(n_rows, width, dtype, block_size, interpret)
    if not tune_enabled() or format not in _TUNABLE_FORMATS or n_rows <= 0 or width <= 0:
        return prior, "table"
    tuner = get_tuner()
    key = _tune_key(format, dtype, n_rows, width, interpret)
    hit = tuner.lookup(key)
    if hit is not None:
        return dataclasses.replace(hit, block_size=block_size), "tuned"
    candidates = _candidate_tiles(prior, dtype, interpret, block_size)
    timings = _measure_ell_tiles(n_rows, width, dtype, candidates, interpret)
    tuner.measure_count += 1
    if not timings:  # no candidate survived shape constraints: keep the prior
        return prior, "table"
    best_name = min(timings, key=timings.get)
    br, bw = (int(p) for p in best_name.split("x"))
    best = TileConfig(block_r=br, block_w=bw, block_size=block_size)
    tuner.record(key, best, timings)
    return best, "tuned"


# --------------------------- whole-iteration tuner ---------------------------


def table_update_mode(interpret: bool) -> str:
    """Static update-mode prior when no measured plan exists.

    Interpret mode (CPU validation) pays ~ms of interpreter overhead per
    Pallas grid step, so the fused kernels *lose* there — the smoke baseline
    measured the fused update ~9x slower than XLA's unfused expressions.
    Compiled Mosaic is the memory-bound regime the fusion targets.
    """
    return "unfused" if interpret else "fused"


def _iter_candidates(
    fmt: str, tiles: TileConfig, interpret: bool, tile_variants: bool
) -> Tuple[Tuple[str, TileConfig], ...]:
    """(update mode, tiles) candidate space for the whole-iteration probe.

    ELL probes the fully-fused pass and one taller tile variant; BSR probes
    block edges (a re-conversion decision — the layout changes with the
    edge); COO/hybrid only choose fused-vs-unfused update (their SpMV is
    identical across update modes).
    """
    if fmt == "bsr":
        return tuple(
            (mode, dataclasses.replace(tiles, block_size=bs))
            for mode in ("unfused", "fused")
            for bs in _ITER_BSR_BLOCKS
        )
    if fmt == "ell":
        tile_opts = [tiles]
        if tile_variants:
            taller = dataclasses.replace(tiles, block_r=tiles.block_r * 2)
            if taller not in tile_opts:
                tile_opts.append(taller)
        return tuple((mode, t) for mode in ITER_UPDATE_MODES for t in tile_opts)
    return tuple((mode, tiles) for mode in ("unfused", "fused"))


def _measure_iteration(
    n_rows: int,
    width: int,
    dtype,
    fmt: str,
    candidates: Sequence[Tuple[str, TileConfig]],
    interpret: bool,
    reps: int = 3,
) -> Tuple[Dict[str, float], Dict[str, Tuple[str, TileConfig]]]:
    """Median wall time (us) of one synthetic Lanczos step per candidate.

    The step is the real per-iteration work — SpMV, alpha dot, three-term
    update, squared norm — composed from the same kernel entrypoints the
    solvers run, jitted as one function so the ranking sees what XLA actually
    schedules.  Shapes are pow2-bucketed and capped exactly like the SpMV
    probe; the result is a relative ranking, not an absolute projection.
    """
    from .lanczos_fused import spmv_ell_alpha_kernel_call
    from .lanczos_update import lanczos_update_kernel_call
    from .spmv_bsr import spmv_bsr_kernel_call
    from .spmv_ell import spmv_ell_kernel_call

    acc = jnp.float32
    rows_cap = 1 << 12 if interpret else 1 << 16
    rows = min(max(_next_pow2(n_rows), 8), rows_cap)
    width = max(8, width)
    width_cap = 1 << 11
    if width <= width_cap:
        width_b = width
    else:
        align = 128 if width % 128 == 0 else 8
        width_b = max(align, (width_cap // align) * align)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(rows), dtype=acc)
    xp = jnp.asarray(rng.standard_normal(rows), dtype=acc)
    beta = jnp.asarray(0.25, acc)
    ublock = min(4096, rows)
    ell_data = bsr_data = w_synth = None
    if fmt == "ell":
        ell_data = (
            jnp.asarray(rng.standard_normal((rows, width_b)), dtype=dtype),
            jnp.asarray(rng.integers(0, rows, (rows, width_b)), jnp.int32),
        )
    elif fmt == "bsr":
        bsr_data = {}
    else:
        w_synth = jnp.asarray(rng.standard_normal(rows), dtype=acc)

    def _update(w, mode):
        a = jnp.sum(x * w)
        if mode == "unfused":
            u = w - a * x - beta * xp
            return u, jnp.sum(u * u)
        return lanczos_update_kernel_call(
            w, x, xp, a, beta, block=ublock, accum_dtype=acc, interpret=interpret
        )

    timings: Dict[str, float] = {}
    by_name: Dict[str, Tuple[str, TileConfig]] = {}
    for mode, tiles in candidates:
        if fmt == "ell":
            # Fit oversized tiles to the probe shape exactly like ell_matvec
            # adapts at runtime (small problems vs interpret-mode 512-row
            # tiles); variants collapsing to the same fitted grid dedupe on
            # the name below.
            br = _fit_tile(tiles.block_r, rows)
            bw = _fit_tile(tiles.block_w, width_b)
            val, col = ell_data
            if mode == "fused_spmv":

                def step(br=br, bw=bw, val=val, col=col):
                    w, a = spmv_ell_alpha_kernel_call(
                        val, col, x, x, block_r=br, block_w=bw,
                        accum_dtype=acc, interpret=interpret,
                    )
                    u, nrm = lanczos_update_kernel_call(
                        w, x, xp, a, beta, block=ublock,
                        accum_dtype=acc, interpret=interpret,
                    )
                    return u, nrm
            else:

                def step(br=br, bw=bw, val=val, col=col, mode=mode):
                    w = spmv_ell_kernel_call(
                        val, col, x, block_r=br, block_w=bw,
                        accum_dtype=acc, interpret=interpret,
                    )
                    return _update(w, mode)

            name = f"{mode}|{br}x{bw}"
        elif fmt == "bsr":
            bs = tiles.block_size
            if rows % bs:
                continue
            if bs not in bsr_data:
                nbr = rows // bs
                slots = max(1, min(8, width_b // bs))
                bsr_data[bs] = (
                    jnp.asarray(rng.standard_normal((nbr, slots, bs, bs)), dtype=dtype),
                    jnp.asarray(rng.integers(0, nbr, (nbr, slots)), jnp.int32),
                )
            val, bcol = bsr_data[bs]

            def step(val=val, bcol=bcol, mode=mode):
                w = spmv_bsr_kernel_call(val, bcol, x, accum_dtype=acc, interpret=interpret)
                return _update(w, mode)

            name = f"{mode}|bs{bs}"
        else:
            # COO/hybrid: the SpMV is the same either way, so probe just the
            # update half the decision actually switches.
            def step(mode=mode):
                return _update(w_synth, mode)

            name = f"{mode}|update"
        if name in by_name:
            continue  # tile variants that fit to the same probe grid
        by_name[name] = (mode, tiles)
        run = jax.jit(step)

        def call():
            u, nrm = run()
            u.block_until_ready()
            return nrm

        call()  # compile/trace outside the timed reps
        ts = []
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            call()
            ts.append(time.perf_counter() - t0)
        timings[name] = float(np.median(ts) * 1e6)
    return timings, by_name


def resolve_iteration_plan(
    n_rows: int,
    width: int,
    dtype=jnp.float32,
    format: str = "ell",
    tiles: TileConfig = TileConfig(),
    interpret: bool = False,
    tile_variants: bool = True,
) -> IterationPlan:
    """Resolve the whole-iteration plan with provenance.

    Resolution order mirrors :func:`tuned_tiles`: a ``REPRO_ITER_UPDATE`` pin
    wins outright ("override"); with ``REPRO_SPMV_TUNE=1`` a measured probe
    over :func:`_iter_candidates` decides and persists ("tuned"); otherwise
    the static mode table decides ("table").  ``tiles`` is the already-
    resolved SpMV tile choice — the probe may refine it (ELL tile variants,
    BSR block edges), and :func:`make_engine` adopts the winner's tiles.
    """
    env = (envcfg.get_str("REPRO_ITER_UPDATE") or "").strip().lower()
    if env:
        if env not in ITER_UPDATE_MODES:
            raise ValueError(
                f"REPRO_ITER_UPDATE={env!r}: expected one of {ITER_UPDATE_MODES}"
            )
        return IterationPlan(update=env, tiles=tiles, source="override")
    table = IterationPlan(update=table_update_mode(interpret), tiles=tiles, source="table")
    if not tune_enabled() or n_rows <= 0 or width <= 0:
        return table
    tuner = get_tuner()
    key = "iter|" + _tune_key(format, dtype, n_rows, width, interpret)
    hit = tuner.lookup_plan(key)
    if hit is not None:
        return hit
    candidates = _iter_candidates(format, tiles, interpret, tile_variants)
    budget = envcfg.get_int("REPRO_SPMV_TUNE_BUDGET")
    candidates = candidates[: max(2, budget * 2)]
    timings, by_name = _measure_iteration(n_rows, width, dtype, format, candidates, interpret)
    tuner.measure_count += 1
    if not timings:  # no candidate survived shape constraints
        return table
    best_name = min(timings, key=timings.get)
    mode, best_tiles = by_name[best_name]
    plan = IterationPlan(update=mode, tiles=best_tiles, source="tuned")
    tuner.record_plan(key, plan, timings)
    return plan


@dataclasses.dataclass(frozen=True)
class SpmvStats:
    """Cheap per-matrix (or per-shard) layout statistics driving selection."""

    n_rows: int
    nnz: int
    max_row_nnz: int
    mean_row_nnz: float
    ell_overhead: float  # padded ELL slots / nnz (1.0 = no padding)
    block_size: int
    n_blocks: int  # touched BS x BS blocks
    block_fill: float  # nnz / (n_blocks * BS^2)
    # Hybrid ELL+COO split: ELL width capped at the HYBRID_QUANTILE of row
    # lengths, hub overflow spilled to a COO tail.
    hyb_width: int = 0  # the capped ELL width
    hyb_tail_nnz: int = 0  # nnz spilled past the cap
    hyb_overhead: float = 0.0  # (capped ELL slots + tail) / nnz
    hyb_tail_frac: float = 0.0  # tail nnz / nnz

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def hybrid_quantile() -> float:
    return _env_float("REPRO_SPMV_HYBRID_Q", HYBRID_QUANTILE)


def hybrid_width_cap(row_nnz: np.ndarray, quantile: Optional[float] = None) -> int:
    """The hybrid split's ELL width: the given quantile of the row lengths
    (hub rows above it spill their overflow into the COO tail)."""
    if not row_nnz.size or not int(row_nnz.max()):
        return 0
    q = hybrid_quantile() if quantile is None else quantile
    cap = int(np.ceil(np.quantile(row_nnz, min(max(q, 0.0), 1.0))))
    return max(1, min(cap, int(row_nnz.max())))


def _stats_from_triplets(
    row_nnz: np.ndarray,
    rows: Optional[np.ndarray],
    cols: Optional[np.ndarray],
    n_rows: int,
    block_size: int,
    width: Optional[int] = None,
    hyb_width: Optional[int] = None,
) -> SpmvStats:
    """``rows``/``cols`` may be None to skip the (sort-heavy) block census —
    used when the format is forced and block density is never consulted.
    ``width`` overrides the ELL width used for the overhead estimate (shards
    of a distributed solve all pay the *global* max row width, since
    shard_map forces one shared ELL shape); ``hyb_width`` likewise overrides
    the hybrid cap (shards share one capped width too)."""
    nnz = int(row_nnz.sum())
    max_row = int(row_nnz.max()) if row_nnz.size else 0
    mean_row = nnz / max(1, n_rows)
    overhead = (max(max_row, width or 0) * n_rows) / max(1, nnz)
    bs = block_size
    if nnz and rows is not None:
        nbc = -(-int(cols.max() + 1) // bs)
        keys = (rows // bs).astype(np.int64) * nbc + cols // bs
        n_blocks = int(np.unique(keys).size)
    else:
        n_blocks = 0
    # No census (skipped or empty matrix) must read as "no block structure",
    # never as infinite fill — otherwise auto-selection would pick BSR.
    fill = nnz / (n_blocks * bs * bs) if n_blocks else 0.0
    cap = hybrid_width_cap(row_nnz) if hyb_width is None else int(hyb_width)
    tail = int(np.maximum(row_nnz - cap, 0).sum()) if (nnz and cap) else 0
    return SpmvStats(
        n_rows=n_rows,
        nnz=nnz,
        max_row_nnz=max_row,
        mean_row_nnz=mean_row,
        ell_overhead=overhead,
        block_size=bs,
        n_blocks=n_blocks,
        block_fill=fill,
        hyb_width=cap,
        hyb_tail_nnz=tail,
        hyb_overhead=(cap * n_rows + tail) / max(1, nnz),
        hyb_tail_frac=tail / max(1, nnz),
    )


def matrix_stats(
    csr, block_size: int = DEFAULT_BLOCK_SIZE, with_blocks: bool = True
) -> SpmvStats:
    """O(nnz) layout statistics of a host CSR (the block census is the only
    super-linear part; skip it with ``with_blocks=False``)."""
    row_nnz = csr.row_nnz()
    if with_blocks:
        rows = np.repeat(np.arange(csr.n, dtype=np.int64), row_nnz)
        return _stats_from_triplets(row_nnz, rows, csr.indices, csr.n, block_size)
    return _stats_from_triplets(row_nnz, None, None, csr.n, block_size)


def shard_stats(
    csr,
    splits: np.ndarray,
    block_size: int = DEFAULT_BLOCK_SIZE,
    with_blocks: bool = True,
) -> Tuple[SpmvStats, ...]:
    """Per-shard statistics for a row-partitioned CSR (splits from
    ``core.partition.nnz_balanced_splits``).

    Block density is measured in the *remapped padded-global* column
    coordinates the distributed BSR layout actually uses
    (``sparse.formats.shard_to_blocked_ell``: columns become
    ``owner * n_pad + local`` with ``n_pad`` block-aligned), and each shard's
    ``ell_overhead`` is charged at the *global* max row width (shard_map
    forces one shared ELL shape — ``shard_to_ell`` pads every shard to it),
    so the selector judges the layout it would build, not a local optimum.
    """
    out = []
    row_nnz = csr.row_nnz()
    global_width = int(row_nnz.max()) if row_nnz.size else 0
    global_cap = hybrid_width_cap(row_nnz)  # hybrid too shares one shape
    # Every shard is padded to the SAME row count (n_pad ~ max shard rows) and
    # the same width, so each shard's overhead is charged at that uniform
    # shape — a shard with few dense rows still allocates max_rows x width.
    max_rows = int((splits[1:] - splits[:-1]).max()) if len(splits) > 1 else csr.n
    max_rows = max(1, max_rows)
    cols_pg = None
    if with_blocks:
        n_pad_bsr = -(-max_rows // block_size) * block_size
        owner = np.searchsorted(splits, csr.indices, side="right") - 1
        cols_pg = owner * n_pad_bsr + (csr.indices - splits[owner])
    for s in range(len(splits) - 1):
        r0, r1 = int(splits[s]), int(splits[s + 1])
        lo, hi = int(csr.indptr[r0]), int(csr.indptr[r1])
        local_nnz = row_nnz[r0:r1]
        if with_blocks:
            rows = np.repeat(np.arange(r1 - r0, dtype=np.int64), local_nnz)
            cols = cols_pg[lo:hi]
        else:
            rows = cols = None
        out.append(
            _stats_from_triplets(
                local_nnz,
                rows,
                cols,
                max_rows,
                block_size,
                width=global_width,
                hyb_width=global_cap,
            )
        )
    return tuple(out)


def choose_format(
    stats,
    allowed: Sequence[str] = FORMATS,
    *,
    ell_max_overhead: Optional[float] = None,
    bsr_fill_factor: Optional[float] = None,
) -> str:
    """Pick a SpMV format from layout statistics (see module docstring).

    ``stats`` is one :class:`SpmvStats` or a sequence of per-shard stats; with
    several shards the choice must hold for *every* shard (shard_map runs one
    program on all of them), so the worst shard decides.

    ``allowed`` restricts the candidates: the distributed engine passes
    ``("ell", "bsr")`` because its hot loop is kernel-only (COO remains an
    explicit opt-out there), the chunked engine passes ``("coo", "ell")``
    because per-chunk BSR staging is not implemented.
    """
    if isinstance(stats, SpmvStats):
        stats = (stats,)
    ell_max = ell_max_overhead if ell_max_overhead is not None else ell_overhead_bound()
    bsr_factor = (
        bsr_fill_factor
        if bsr_fill_factor is not None
        else _env_float("REPRO_SPMV_BSR_FILL", BSR_FILL_FACTOR)
    )
    tail_max = _env_float("REPRO_SPMV_HYBRID_TAIL", HYBRID_MAX_TAIL)
    bsr_ok = "bsr" in allowed and all(
        s.block_fill >= bsr_factor / s.block_size for s in stats
    )
    if bsr_ok:
        return "bsr"
    ell_ok = "ell" in allowed and all(s.ell_overhead <= ell_max for s in stats)
    if ell_ok:
        return "ell"
    # Hub-row split: the quantile-capped ELL part must respect the same
    # padding bound plain ELL failed (a *memory* bound: per shard), and the
    # spilled tail must stay a minority of the nnz (a *throughput* ratio:
    # judged on the aggregate — nnz-balanced splits concentrate hubs into
    # few-row shards whose local tail share is skewed by construction).
    # Otherwise segment_sum is doing the work anyway and plain COO is the
    # honest choice.
    tail_frac = sum(s.hyb_tail_nnz for s in stats) / max(1, sum(s.nnz for s in stats))
    hyb_ok = (
        "hybrid" in allowed
        and tail_frac <= tail_max
        and all(s.hyb_overhead <= ell_max for s in stats)
    )
    if hyb_ok:
        return "hybrid"
    if "coo" in allowed:
        return "coo"
    for fmt in ("hybrid", "ell"):
        if fmt not in allowed:
            continue
        # Kernel-only paths (distributed): ELL/hybrid are always *correct*;
        # the bounds above only optimize padding, so fall back rather than
        # fail — but loudly: padded ELL costs O(n * max_row_nnz) memory,
        # which on hub-dominated (power-law) matrices can dwarf the O(nnz)
        # COO path (the hybrid split bounds that, hence it is preferred).
        worst = max(
            (s.hyb_overhead if fmt == "hybrid" else s.ell_overhead) for s in stats
        )
        warnings.warn(
            f"SpMV auto-selection is restricted to kernel formats here and "
            f"fell back to {fmt.upper()} despite a {worst:.0f}x padding "
            f"overhead (bound: {ell_max:.1f}x); for hub-dominated matrices "
            f"consider format='coo' (segment-sum reference path) or a larger "
            f"REPRO_SPMV_ELL_OVERHEAD",
            stacklevel=2,
        )
        return fmt
    raise ValueError(f"no admissible SpMV format among {tuple(allowed)}")


def _default_interpret() -> bool:
    from .ops import default_interpret  # lazy: keeps package init order simple

    return default_interpret()


@dataclasses.dataclass(frozen=True)
class SpmvEngine:
    """One SpMV execution configuration: format + accum dtype + tiles.

    Frozen and hashable so it can ride through ``jax.jit`` static arguments.
    ``interpret`` selects the Pallas interpreter (CPU containers) vs compiled
    Mosaic (real TPU).  f64 accumulation is TPU-unsupported, so off-interpret
    it falls back to the vectorized jnp layouts (still ELL/BSR, never
    ``segment_sum``).
    """

    format: str = "auto"
    accum_dtype: Any = jnp.float32
    tiles: TileConfig = TileConfig()
    interpret: bool = True
    requested: str = "auto"
    stats: Optional[Tuple[SpmvStats, ...]] = None
    tiles_from: str = "table"  # "table" | "tuned" | "override"
    # Whole-iteration decision (update fusion mode + jointly-picked tiles);
    # None on hand-built engines — consumers treat that as the static table.
    iteration_plan: Optional[IterationPlan] = None

    def __post_init__(self):
        if self.format not in FORMATS:
            raise ValueError(f"unknown SpMV format {self.format!r}; expected {FORMATS}")

    # --- raw-array kernel dispatch (used inside shard_map / jit) -----------

    def _use_kernel(self) -> bool:
        return not (
            jnp.dtype(self.accum_dtype) == jnp.dtype(jnp.float64) and not self.interpret
        )

    def ell_matvec(self, val: jax.Array, col: jax.Array, x: jax.Array) -> jax.Array:
        """y = ELL(val, col) @ x -> (rows_padded,) in the accum dtype."""
        acc = jnp.dtype(self.accum_dtype)
        if not self._use_kernel():
            from .ref import spmv_ell_ref

            return spmv_ell_ref(val, col, x, accum_dtype=acc)
        from .spmv_ell import spmv_ell_kernel_call

        # Largest tiles <= the configured ones that divide the padded ELL
        # shape, so the kernel grid always divides evenly (per-chunk layouts
        # pad rows to their own small tile rather than the global block_r —
        # see ChunkedOperator — hence the row adaptation too).
        block_r = _fit_tile(self.tiles.block_r, val.shape[0])
        block_w = _fit_tile(self.tiles.block_w, val.shape[1])
        return spmv_ell_kernel_call(
            val,
            col,
            x,
            block_r=block_r,
            block_w=block_w,
            accum_dtype=acc,
            interpret=self.interpret,
        )

    def packed_ell_matvec(
        self,
        val: jax.Array,
        scale: jax.Array,
        base: jax.Array,
        dcol: jax.Array,
        x: jax.Array,
    ) -> jax.Array:
        """y = dequant(val, scale) @ x over delta-encoded columns (compressed
        out-of-core staging; see ``kernels/spmv_ell_packed.py``).  Returns
        (rows_padded,) in the accum dtype."""
        acc = jnp.dtype(self.accum_dtype)
        if not self._use_kernel():
            vals = val.astype(acc) * scale.astype(acc)
            cols = base + jnp.cumsum(dcol.astype(jnp.int32), axis=1)
            return jnp.sum(vals * jnp.take(x, cols).astype(acc), axis=1)
        from .spmv_ell_packed import spmv_ell_packed_kernel_call

        # Row tile adapts to the per-chunk padded row count (same contract
        # as ell_matvec); the width is one tile — the in-kernel delta cumsum
        # needs the whole row.
        block_r = _fit_tile(self.tiles.block_r, val.shape[0])
        return spmv_ell_packed_kernel_call(
            val,
            scale,
            base,
            dcol,
            x,
            block_r=block_r,
            accum_dtype=acc,
            interpret=self.interpret,
        )

    def bsr_matvec(self, val: jax.Array, bcol: jax.Array, x: jax.Array) -> jax.Array:
        """y = BSR(val, bcol) @ x -> (nbr * BS,) in the accum dtype."""
        acc = jnp.dtype(self.accum_dtype)
        nbr, slots, bs, _ = val.shape
        if x.shape[0] % bs:
            x = jnp.pad(x, (0, bs - x.shape[0] % bs))
        if not self._use_kernel():
            # Same einsum as DeviceBSR.matvec, without the [:n_rows] slice
            # (callers hold the logical row count).
            gathered = jnp.take(x.reshape(-1, bs), bcol, axis=0)  # (nbr, slots, bs)
            y = jnp.einsum("rsij,rsj->ri", val.astype(acc), gathered.astype(acc))
            return y.reshape(nbr * bs)
        from .spmv_bsr import spmv_bsr_kernel_call

        return spmv_bsr_kernel_call(
            val, bcol, x, accum_dtype=acc, interpret=self.interpret
        )

    def hybrid_matvec(
        self,
        val: jax.Array,
        col: jax.Array,
        tail_row: jax.Array,
        tail_col: jax.Array,
        tail_val: jax.Array,
        x: jax.Array,
        n_rows: int,
    ) -> jax.Array:
        """Hub-split SpMV: capped-width ELL kernel + COO ``segment_sum`` tail.

        ``tail_row`` indexes the output rows; padding slots (val 0, row 0)
        contribute nothing.  Returns (n_rows,) in the accum dtype.
        """
        acc = jnp.dtype(self.accum_dtype)
        y = self.ell_matvec(val, col, x)[:n_rows]
        prod = tail_val.astype(acc) * jnp.take(x, tail_col).astype(acc)
        return y + jax.ops.segment_sum(prod, tail_row, num_segments=n_rows)

    # --- container-level dispatch (single-device operators) ----------------

    def spmv(self, mat, x: jax.Array, accum_dtype=None) -> jax.Array:
        """SpMV on a device container (DeviceCOO/ELL/BSR/Hybrid)."""
        from ..sparse.formats import DeviceBSR, DeviceCOO, DeviceELL, DeviceHybrid

        acc = accum_dtype or self.accum_dtype
        if isinstance(mat, DeviceCOO):
            return mat.matvec(x, accum_dtype=acc)
        eng = self if acc == self.accum_dtype else dataclasses.replace(self, accum_dtype=acc)
        if isinstance(mat, DeviceELL):
            return eng.ell_matvec(mat.val, mat.col, x)[: mat.n_rows]
        if isinstance(mat, DeviceBSR):
            return eng.bsr_matvec(mat.val, mat.bcol, x)[: mat.n_rows]
        if isinstance(mat, DeviceHybrid):
            return eng.hybrid_matvec(
                mat.ell_val, mat.ell_col, mat.tail_row, mat.tail_col, mat.tail_val,
                x, mat.n_rows,
            )
        raise TypeError(f"SpmvEngine.spmv: unsupported container {type(mat).__name__}")

    def describe(self) -> dict:
        """Loggable summary (what ``EigenResult.partition`` records)."""
        return {
            "format": self.format,
            "requested": self.requested,
            "accum_dtype": str(jnp.dtype(self.accum_dtype)),
            "block_r": self.tiles.block_r,
            "block_w": self.tiles.block_w,
            "block_size": self.tiles.block_size,
            "interpret": self.interpret,
            "tiles_from": self.tiles_from,
            "iteration_plan": (
                self.iteration_plan.as_dict() if self.iteration_plan is not None else None
            ),
        }


def make_engine(
    csr=None,
    format: str = "auto",
    *,
    stats=None,
    accum_dtype: Any = jnp.float32,
    allowed: Sequence[str] = FORMATS,
    block_size: int = DEFAULT_BLOCK_SIZE,
    interpret: Optional[bool] = None,
    tiles: Optional[TileConfig] = None,
    storage_dtype: Any = None,
    ell_max_overhead: Optional[float] = None,
    bsr_fill_factor: Optional[float] = None,
) -> SpmvEngine:
    """Build a :class:`SpmvEngine` for a matrix (or precomputed shard stats).

    ``format="auto"`` runs :func:`choose_format` on the statistics; an
    explicit format is validated against ``allowed`` and used as-is.
    """
    requested = format
    if stats is None:
        if csr is None:
            raise ValueError("make_engine needs a csr or precomputed stats")
        # The block census (an O(nnz log nnz) sort) only matters when BSR is
        # actually in play; forced COO/ELL solves skip it.
        with_blocks = format == "auto" and "bsr" in allowed
        stats = (matrix_stats(csr, block_size=block_size, with_blocks=with_blocks),)
    elif isinstance(stats, SpmvStats):
        stats = (stats,)
    else:
        stats = tuple(stats)

    if format == "auto":
        fmt = choose_format(
            stats,
            allowed,
            ell_max_overhead=ell_max_overhead,
            bsr_fill_factor=bsr_fill_factor,
        )
    else:
        if format not in FORMATS:
            raise ValueError(f"unknown SpMV format {format!r}; expected {FORMATS} or 'auto'")
        if format not in allowed:
            raise ValueError(
                f"format={format!r} is not supported by this backend (allowed: {tuple(allowed)})"
            )
        fmt = format

    interp = _default_interpret() if interpret is None else interpret
    tiles_from = "override"
    n_rows = max(s.n_rows for s in stats)
    # Tiles (and autotune probes) must see the width the built layout
    # will actually have, not the raw row statistic: hybrid runs the ELL
    # kernel at the capped width (8-slot aligned, to_device_hybrid),
    # plain ELL pads to the 128-lane tile (to_device_ell/shard_to_ell).
    if fmt == "hybrid":
        width = -(-max(1, max(s.hyb_width for s in stats)) // 8) * 8
    elif fmt == "ell":
        width = -(-max(1, max(s.max_row_nnz for s in stats)) // 128) * 128
    else:
        width = max(s.max_row_nnz for s in stats)
    explicit_tiles = tiles is not None
    if tiles is None:
        # The storage dtype governs the TPU sublane minimum of the value tiles.
        tiles, tiles_from = tuned_tiles(
            n_rows,
            width,
            dtype=storage_dtype or accum_dtype,
            format=fmt,
            block_size=block_size,
            interpret=interp,
        )
    # Whole-iteration plan: fused-vs-unfused update (x tiles x BSR block
    # edge) measured on a composite Lanczos step when tuning is on.  f64
    # accumulation runs the jnp reference kernels, where no fusion applies.
    if jnp.dtype(accum_dtype) == jnp.dtype(jnp.float64):
        plan = IterationPlan(update="unfused", tiles=tiles, source="table")
    else:
        plan = resolve_iteration_plan(
            n_rows,
            width,
            dtype=storage_dtype or accum_dtype,
            format=fmt,
            tiles=tiles,
            interpret=interp,
            # A user-pinned TileConfig is a layout commitment the probe must
            # not second-guess (the layout may already be converted to it).
            tile_variants=not explicit_tiles and tiles_from != "override",
        )
        if plan.source == "tuned" and not explicit_tiles and tiles_from != "override":
            # The iteration probe picks update mode and tiles jointly; adopt
            # its tiles (incl. the BSR block edge — a re-conversion) so the
            # layout is built for the measured winner.
            tiles, tiles_from = plan.tiles, "tuned"
    return SpmvEngine(
        format=fmt,
        accum_dtype=accum_dtype,
        tiles=tiles,
        interpret=interp,
        requested=requested,
        stats=stats,
        tiles_from=tiles_from,
        iteration_plan=plan,
    )
