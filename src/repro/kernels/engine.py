"""Pluggable SpMV execution layer: format selection + tile configuration.

The paper's headline speedup is the SpMV hot loop, but which *layout* wins is
a property of the matrix, not the solver: ELL when row lengths are near
uniform (padding overhead bounded), blocked-ELL/BSR when the non-zeros
cluster into dense blocks (SpMV becomes a stream of MXU matmuls — see
``spmv_bsr.py`` for the ~1/BS fill crossover), COO ``segment_sum`` otherwise.
:class:`SpmvEngine` packages that decision — format + accumulation dtype +
Pallas tile parameters — behind one object so every solver engine
(``solve_fixed``, ``solve_sharded``, ``ChunkedOperator``) executes the same
kernels instead of each open-coding its own SpMV.

Format auto-selection (``choose_format``) runs on cheap O(nnz) statistics of
the host CSR:

  * ``ell_overhead``  — padded ELL slots / nnz = ``max_row_nnz * n / nnz``.
    ELL is chosen when this is bounded (default <= 3.0: at most 2/3 of the
    kernel's work is padding).
  * ``block_fill``    — nnz / (touched BS x BS blocks * BS^2).  BSR wins when
    a stored block is dense enough that one MXU matvec beats BS scalar-gather
    rows; the absolute flop crossover is ~1/BS (spmv_bsr.py), but padding and
    bandwidth push the practical line higher, so the default requires
    ``block_fill >= BSR_FILL_FACTOR / BS`` (factor 4 => half-dense blocks at
    BS=8).

Tile parameters come from a small static table keyed on the shard shape and
storage dtype — the first step toward the ROADMAP autotuner — overridable via
``REPRO_SPMV_TILES="block_r,block_w[,block_size]"`` or per-call arguments.
"""

from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FORMATS",
    "TileConfig",
    "SpmvStats",
    "SpmvEngine",
    "matrix_stats",
    "shard_stats",
    "choose_format",
    "select_tiles",
    "make_engine",
]

FORMATS = ("coo", "ell", "bsr")

# ELL accepted while padded slots <= ELL_MAX_OVERHEAD * nnz.
ELL_MAX_OVERHEAD = 3.0
# BSR accepted while block_fill >= BSR_FILL_FACTOR / block_size.
BSR_FILL_FACTOR = 4.0
DEFAULT_BLOCK_SIZE = 8


def _env_float(name: str, default: float) -> float:
    try:
        return float(os.environ[name])
    except (KeyError, ValueError):
        return default


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """Pallas grid tile parameters for the SpMV kernels.

    ``block_r`` / ``block_w`` tile the ELL (rows, width) grid; ``block_size``
    is the dense block edge of the blocked-ELL/BSR layout.  Conversions pad
    rows to ``block_r`` and widths to ``block_w`` so the kernel BlockSpecs
    always divide evenly.
    """

    block_r: int = 8
    block_w: int = 128
    block_size: int = DEFAULT_BLOCK_SIZE


# Static tile table: (max_rows, max_width) upper bounds -> (block_r, block_w).
# Larger shards get taller/wider tiles to amortize grid steps; entries are
# scanned in order and the first row that fits is used.  bf16/f16 rows double
# block_r to honor the TPU (16, 128) sublane minimum for 16-bit dtypes.
_TILE_TABLE: Tuple[Tuple[int, int, int, int], ...] = (
    # max_rows, max_width, block_r, block_w
    (1 << 10, 1 << 8, 8, 128),
    (1 << 10, 1 << 30, 8, 256),
    (1 << 14, 1 << 8, 16, 128),
    (1 << 14, 1 << 30, 16, 256),
    (1 << 30, 1 << 8, 32, 128),
    (1 << 30, 1 << 30, 32, 512),
)


def select_tiles(
    n_rows: int,
    width: int,
    dtype=jnp.float32,
    block_size: int = DEFAULT_BLOCK_SIZE,
    interpret: bool = False,
) -> TileConfig:
    """Pick kernel tiles from the static table (env override wins).

    ``REPRO_SPMV_TILES="block_r,block_w[,block_size]"`` pins the tiles for
    experiments (the env/config hook the ROADMAP autotuner will replace).

    ``interpret=True`` (CPU validation): the Pallas interpreter executes grid
    steps sequentially with high per-step overhead and has no VMEM ceiling,
    so it gets few, large tiles — same kernel code, tractable wall time.
    """
    env = os.environ.get("REPRO_SPMV_TILES")
    if env:
        parts = [int(p) for p in env.split(",")]
        if len(parts) not in (2, 3):
            raise ValueError(
                f"REPRO_SPMV_TILES={env!r}: expected 'block_r,block_w[,block_size]'"
            )
        bs = parts[2] if len(parts) == 3 else block_size
        return TileConfig(block_r=parts[0], block_w=parts[1], block_size=bs)

    if interpret:
        return TileConfig(block_r=512, block_w=2048, block_size=block_size)

    block_r, block_w = _TILE_TABLE[-1][2:]
    for max_rows, max_width, br, bw in _TILE_TABLE:
        if n_rows <= max_rows and width <= max_width:
            block_r, block_w = br, bw
            break
    if jnp.dtype(dtype).itemsize == 2:  # bf16/f16 sublane minimum is 16
        block_r = max(block_r, 16)
    return TileConfig(block_r=block_r, block_w=block_w, block_size=block_size)


@dataclasses.dataclass(frozen=True)
class SpmvStats:
    """Cheap per-matrix (or per-shard) layout statistics driving selection."""

    n_rows: int
    nnz: int
    max_row_nnz: int
    mean_row_nnz: float
    ell_overhead: float  # padded ELL slots / nnz (1.0 = no padding)
    block_size: int
    n_blocks: int  # touched BS x BS blocks
    block_fill: float  # nnz / (n_blocks * BS^2)

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _stats_from_triplets(
    row_nnz: np.ndarray,
    rows: Optional[np.ndarray],
    cols: Optional[np.ndarray],
    n_rows: int,
    block_size: int,
    width: Optional[int] = None,
) -> SpmvStats:
    """``rows``/``cols`` may be None to skip the (sort-heavy) block census —
    used when the format is forced and block density is never consulted.
    ``width`` overrides the ELL width used for the overhead estimate (shards
    of a distributed solve all pay the *global* max row width, since
    shard_map forces one shared ELL shape)."""
    nnz = int(row_nnz.sum())
    max_row = int(row_nnz.max()) if row_nnz.size else 0
    mean_row = nnz / max(1, n_rows)
    overhead = (max(max_row, width or 0) * n_rows) / max(1, nnz)
    bs = block_size
    if nnz and rows is not None:
        nbc = -(-int(cols.max() + 1) // bs)
        keys = (rows // bs).astype(np.int64) * nbc + cols // bs
        n_blocks = int(np.unique(keys).size)
    else:
        n_blocks = 0
    # No census (skipped or empty matrix) must read as "no block structure",
    # never as infinite fill — otherwise auto-selection would pick BSR.
    fill = nnz / (n_blocks * bs * bs) if n_blocks else 0.0
    return SpmvStats(
        n_rows=n_rows,
        nnz=nnz,
        max_row_nnz=max_row,
        mean_row_nnz=mean_row,
        ell_overhead=overhead,
        block_size=bs,
        n_blocks=n_blocks,
        block_fill=fill,
    )


def matrix_stats(
    csr, block_size: int = DEFAULT_BLOCK_SIZE, with_blocks: bool = True
) -> SpmvStats:
    """O(nnz) layout statistics of a host CSR (the block census is the only
    super-linear part; skip it with ``with_blocks=False``)."""
    row_nnz = csr.row_nnz()
    if with_blocks:
        rows = np.repeat(np.arange(csr.n, dtype=np.int64), row_nnz)
        return _stats_from_triplets(row_nnz, rows, csr.indices, csr.n, block_size)
    return _stats_from_triplets(row_nnz, None, None, csr.n, block_size)


def shard_stats(
    csr,
    splits: np.ndarray,
    block_size: int = DEFAULT_BLOCK_SIZE,
    with_blocks: bool = True,
) -> Tuple[SpmvStats, ...]:
    """Per-shard statistics for a row-partitioned CSR (splits from
    ``core.partition.nnz_balanced_splits``).

    Block density is measured in the *remapped padded-global* column
    coordinates the distributed BSR layout actually uses
    (``sparse.formats.shard_to_blocked_ell``: columns become
    ``owner * n_pad + local`` with ``n_pad`` block-aligned), and each shard's
    ``ell_overhead`` is charged at the *global* max row width (shard_map
    forces one shared ELL shape — ``shard_to_ell`` pads every shard to it),
    so the selector judges the layout it would build, not a local optimum.
    """
    out = []
    row_nnz = csr.row_nnz()
    global_width = int(row_nnz.max()) if row_nnz.size else 0
    # Every shard is padded to the SAME row count (n_pad ~ max shard rows) and
    # the same width, so each shard's overhead is charged at that uniform
    # shape — a shard with few dense rows still allocates max_rows x width.
    max_rows = int((splits[1:] - splits[:-1]).max()) if len(splits) > 1 else csr.n
    max_rows = max(1, max_rows)
    cols_pg = None
    if with_blocks:
        n_pad_bsr = -(-max_rows // block_size) * block_size
        owner = np.searchsorted(splits, csr.indices, side="right") - 1
        cols_pg = owner * n_pad_bsr + (csr.indices - splits[owner])
    for s in range(len(splits) - 1):
        r0, r1 = int(splits[s]), int(splits[s + 1])
        lo, hi = int(csr.indptr[r0]), int(csr.indptr[r1])
        local_nnz = row_nnz[r0:r1]
        if with_blocks:
            rows = np.repeat(np.arange(r1 - r0, dtype=np.int64), local_nnz)
            cols = cols_pg[lo:hi]
        else:
            rows = cols = None
        out.append(
            _stats_from_triplets(
                local_nnz, rows, cols, max_rows, block_size, width=global_width
            )
        )
    return tuple(out)


def choose_format(
    stats,
    allowed: Sequence[str] = FORMATS,
    *,
    ell_max_overhead: Optional[float] = None,
    bsr_fill_factor: Optional[float] = None,
) -> str:
    """Pick a SpMV format from layout statistics (see module docstring).

    ``stats`` is one :class:`SpmvStats` or a sequence of per-shard stats; with
    several shards the choice must hold for *every* shard (shard_map runs one
    program on all of them), so the worst shard decides.

    ``allowed`` restricts the candidates: the distributed engine passes
    ``("ell", "bsr")`` because its hot loop is kernel-only (COO remains an
    explicit opt-out there), the chunked engine passes ``("coo", "ell")``
    because per-chunk BSR staging is not implemented.
    """
    if isinstance(stats, SpmvStats):
        stats = (stats,)
    ell_max = (
        ell_max_overhead
        if ell_max_overhead is not None
        else _env_float("REPRO_SPMV_ELL_OVERHEAD", ELL_MAX_OVERHEAD)
    )
    bsr_factor = (
        bsr_fill_factor
        if bsr_fill_factor is not None
        else _env_float("REPRO_SPMV_BSR_FILL", BSR_FILL_FACTOR)
    )
    bsr_ok = "bsr" in allowed and all(
        s.block_fill >= bsr_factor / s.block_size for s in stats
    )
    if bsr_ok:
        return "bsr"
    ell_ok = "ell" in allowed and all(s.ell_overhead <= ell_max for s in stats)
    if ell_ok:
        return "ell"
    if "coo" in allowed:
        return "coo"
    if "ell" in allowed:
        # Kernel-only paths (distributed): ELL is always *correct*; the bound
        # above only optimizes padding, so fall back to it rather than fail —
        # but loudly: padded ELL costs O(n * max_row_nnz) memory, which on
        # hub-dominated (power-law) matrices can dwarf the O(nnz) COO path.
        worst = max(s.ell_overhead for s in stats)
        warnings.warn(
            f"SpMV auto-selection is restricted to kernel formats here and "
            f"fell back to ELL despite a {worst:.0f}x padding overhead "
            f"(bound: {ell_max:.1f}x); for hub-dominated matrices consider "
            f"format='coo' (segment-sum reference path) or a larger "
            f"REPRO_SPMV_ELL_OVERHEAD",
            stacklevel=2,
        )
        return "ell"
    raise ValueError(f"no admissible SpMV format among {tuple(allowed)}")


def _default_interpret() -> bool:
    from .ops import default_interpret  # lazy: keeps package init order simple

    return default_interpret()


@dataclasses.dataclass(frozen=True)
class SpmvEngine:
    """One SpMV execution configuration: format + accum dtype + tiles.

    Frozen and hashable so it can ride through ``jax.jit`` static arguments.
    ``interpret`` selects the Pallas interpreter (CPU containers) vs compiled
    Mosaic (real TPU).  f64 accumulation is TPU-unsupported, so off-interpret
    it falls back to the vectorized jnp layouts (still ELL/BSR, never
    ``segment_sum``).
    """

    format: str = "auto"
    accum_dtype: Any = jnp.float32
    tiles: TileConfig = TileConfig()
    interpret: bool = True
    requested: str = "auto"
    stats: Optional[Tuple[SpmvStats, ...]] = None

    def __post_init__(self):
        if self.format not in FORMATS:
            raise ValueError(f"unknown SpMV format {self.format!r}; expected {FORMATS}")

    # --- raw-array kernel dispatch (used inside shard_map / jit) -----------

    def _use_kernel(self) -> bool:
        return not (
            jnp.dtype(self.accum_dtype) == jnp.dtype(jnp.float64) and not self.interpret
        )

    def ell_matvec(self, val: jax.Array, col: jax.Array, x: jax.Array) -> jax.Array:
        """y = ELL(val, col) @ x -> (rows_padded,) in the accum dtype."""
        acc = jnp.dtype(self.accum_dtype)
        if not self._use_kernel():
            from .ref import spmv_ell_ref

            return spmv_ell_ref(val, col, x, accum_dtype=acc)
        from .spmv_ell import spmv_ell_kernel_call

        # Largest width tile <= the configured one that divides the (128-
        # aligned) ELL width, so the kernel grid always divides evenly.
        block_w = max(1, min(self.tiles.block_w, val.shape[1]))
        while val.shape[1] % block_w:
            block_w //= 2
        return spmv_ell_kernel_call(
            val,
            col,
            x,
            block_r=self.tiles.block_r,
            block_w=block_w,
            accum_dtype=acc,
            interpret=self.interpret,
        )

    def bsr_matvec(self, val: jax.Array, bcol: jax.Array, x: jax.Array) -> jax.Array:
        """y = BSR(val, bcol) @ x -> (nbr * BS,) in the accum dtype."""
        acc = jnp.dtype(self.accum_dtype)
        nbr, slots, bs, _ = val.shape
        if x.shape[0] % bs:
            x = jnp.pad(x, (0, bs - x.shape[0] % bs))
        if not self._use_kernel():
            # Same einsum as DeviceBSR.matvec, without the [:n_rows] slice
            # (callers hold the logical row count).
            gathered = jnp.take(x.reshape(-1, bs), bcol, axis=0)  # (nbr, slots, bs)
            y = jnp.einsum("rsij,rsj->ri", val.astype(acc), gathered.astype(acc))
            return y.reshape(nbr * bs)
        from .spmv_bsr import spmv_bsr_kernel_call

        return spmv_bsr_kernel_call(
            val, bcol, x, accum_dtype=acc, interpret=self.interpret
        )

    # --- container-level dispatch (single-device operators) ----------------

    def spmv(self, mat, x: jax.Array, accum_dtype=None) -> jax.Array:
        """SpMV on a device container (DeviceCOO / DeviceELL / DeviceBSR)."""
        from ..sparse.formats import DeviceBSR, DeviceCOO, DeviceELL

        acc = accum_dtype or self.accum_dtype
        if isinstance(mat, DeviceCOO):
            return mat.matvec(x, accum_dtype=acc)
        eng = self if acc == self.accum_dtype else dataclasses.replace(self, accum_dtype=acc)
        if isinstance(mat, DeviceELL):
            return eng.ell_matvec(mat.val, mat.col, x)[: mat.n_rows]
        if isinstance(mat, DeviceBSR):
            return eng.bsr_matvec(mat.val, mat.bcol, x)[: mat.n_rows]
        raise TypeError(f"SpmvEngine.spmv: unsupported container {type(mat).__name__}")

    def describe(self) -> dict:
        """Loggable summary (what ``EigenResult.partition`` records)."""
        return {
            "format": self.format,
            "requested": self.requested,
            "accum_dtype": str(jnp.dtype(self.accum_dtype)),
            "block_r": self.tiles.block_r,
            "block_w": self.tiles.block_w,
            "block_size": self.tiles.block_size,
            "interpret": self.interpret,
        }


def make_engine(
    csr=None,
    format: str = "auto",
    *,
    stats=None,
    accum_dtype: Any = jnp.float32,
    allowed: Sequence[str] = FORMATS,
    block_size: int = DEFAULT_BLOCK_SIZE,
    interpret: Optional[bool] = None,
    tiles: Optional[TileConfig] = None,
    storage_dtype: Any = None,
    ell_max_overhead: Optional[float] = None,
    bsr_fill_factor: Optional[float] = None,
) -> SpmvEngine:
    """Build a :class:`SpmvEngine` for a matrix (or precomputed shard stats).

    ``format="auto"`` runs :func:`choose_format` on the statistics; an
    explicit format is validated against ``allowed`` and used as-is.
    """
    requested = format
    if stats is None:
        if csr is None:
            raise ValueError("make_engine needs a csr or precomputed stats")
        # The block census (an O(nnz log nnz) sort) only matters when BSR is
        # actually in play; forced COO/ELL solves skip it.
        with_blocks = format == "auto" and "bsr" in allowed
        stats = (matrix_stats(csr, block_size=block_size, with_blocks=with_blocks),)
    elif isinstance(stats, SpmvStats):
        stats = (stats,)
    else:
        stats = tuple(stats)

    if format == "auto":
        fmt = choose_format(
            stats,
            allowed,
            ell_max_overhead=ell_max_overhead,
            bsr_fill_factor=bsr_fill_factor,
        )
    else:
        if format not in FORMATS:
            raise ValueError(f"unknown SpMV format {format!r}; expected {FORMATS} or 'auto'")
        if format not in allowed:
            raise ValueError(
                f"format={format!r} is not supported by this backend (allowed: {tuple(allowed)})"
            )
        fmt = format

    interp = _default_interpret() if interpret is None else interpret
    if tiles is None:
        n_rows = max(s.n_rows for s in stats)
        width = max(s.max_row_nnz for s in stats)
        # The storage dtype governs the TPU sublane minimum of the value tiles.
        tiles = select_tiles(
            n_rows,
            width,
            dtype=storage_dtype or accum_dtype,
            block_size=block_size,
            interpret=interp,
        )
    return SpmvEngine(
        format=fmt,
        accum_dtype=accum_dtype,
        tiles=tiles,
        interpret=interp,
        requested=requested,
        stats=stats,
    )
