from . import engine, ops, ref
from .engine import SpmvEngine, choose_format, make_engine, select_tiles

__all__ = [
    "engine",
    "ops",
    "ref",
    "SpmvEngine",
    "choose_format",
    "make_engine",
    "select_tiles",
]
