"""Pallas TPU kernel: fused Lanczos three-term recurrence + norm.

Between two SpMVs the paper performs (Alg. 1 lines 6/11):

    u      = w - alpha * v - beta * v_prev     (vector update)
    beta'  = ||u||_2                           (next normalization)

Executed separately these are 4 full memory passes over n-length vectors
(read w/v/v_prev + write u, then read u again for the norm).  This kernel
fuses them into a single pass — the squared-norm partial is accumulated
across the sequential TPU grid while the update tile is still in VMEM.
This is a beyond-paper optimization targeting the memory roofline term of
the solver (EXPERIMENTS.md §Perf-eigensolver).

Scalars (alpha, beta) arrive as (1,)-shaped operands pinned to every grid
step; outputs are the updated vector (storage dtype) and a (1,) f32
squared norm.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lanczos_update_kernel_call"]


def _kernel(alpha_ref, beta_ref, w_ref, v_ref, vp_ref, u_ref, nrm_ref, *, accum_dtype):
    i = pl.program_id(0)
    acc = accum_dtype
    alpha = alpha_ref[0].astype(acc)
    beta = beta_ref[0].astype(acc)
    u = w_ref[...].astype(acc) - alpha * v_ref[...].astype(acc) - beta * vp_ref[...].astype(acc)
    u_ref[...] = u.astype(u_ref.dtype)
    part = jnp.sum(u * u)

    @pl.when(i == 0)
    def _init():
        nrm_ref[0] = part

    @pl.when(i != 0)
    def _acc():
        nrm_ref[0] = nrm_ref[0] + part


@functools.partial(jax.jit, static_argnames=("block", "accum_dtype", "interpret"))
def lanczos_update_kernel_call(
    w: jax.Array,
    v: jax.Array,
    v_prev: jax.Array,
    alpha: jax.Array,
    beta: jax.Array,
    *,
    block: int = 4096,
    accum_dtype=jnp.float32,
    interpret: bool = True,
):
    """Returns (u (n,) w.dtype, norm_sq (1,) accum_dtype)."""
    n = w.shape[0]
    block = min(block, n)
    if n % block:
        raise ValueError(f"length {n} not divisible by block {block}")
    alpha = jnp.reshape(alpha, (1,)).astype(accum_dtype)
    beta = jnp.reshape(beta, (1,)).astype(accum_dtype)
    return pl.pallas_call(
        functools.partial(_kernel, accum_dtype=accum_dtype),
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),  # alpha
            pl.BlockSpec((1,), lambda i: (0,)),  # beta
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), w.dtype),
            jax.ShapeDtypeStruct((1,), accum_dtype),
        ],
        interpret=interpret,
    )(alpha, beta, w, v, v_prev)
