"""Pallas TPU kernel: mixed-precision dot product / squared norm.

The alpha and beta reductions are the accuracy-critical synchronization
points of the paper's Lanczos (Alg. 1 lines 6/10); the paper computes them
in f64 while storing vectors in f32.  TPUs have no fast f64, so this kernel
offers the TPU-native ladder (DESIGN.md §3.1):

  * inputs in any storage dtype (bf16 / f16 / f32),
  * per-tile products and sums in ``accum_dtype`` (f32),
  * optional Neumaier compensation *across tiles* — the sequential TPU grid
    makes the cross-tile accumulation a genuine running sum, so carrying a
    compensation term recovers most of the accuracy a 2x-wider accumulator
    would give (the stand-in for the paper's f64).

Output layout: (2,) f32 = (sum, compensation); callers take ``out.sum()``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["mixed_dot_kernel_call"]


def _kernel(a_ref, b_ref, o_ref, *, accum_dtype, compensated):
    i = pl.program_id(0)
    part = jnp.sum(a_ref[...].astype(accum_dtype) * b_ref[...].astype(accum_dtype))

    @pl.when(i == 0)
    def _init():
        o_ref[0] = part
        o_ref[1] = jnp.zeros((), accum_dtype)

    @pl.when(i != 0)
    def _acc():
        s = o_ref[0]
        if compensated:
            t = s + part
            comp = jnp.where(jnp.abs(s) >= jnp.abs(part), (s - t) + part, (part - t) + s)
            o_ref[0] = t
            o_ref[1] = o_ref[1] + comp
        else:
            o_ref[0] = s + part


@functools.partial(jax.jit, static_argnames=("block", "accum_dtype", "compensated", "interpret"))
def mixed_dot_kernel_call(
    a: jax.Array,
    b: jax.Array,
    *,
    block: int = 4096,
    accum_dtype=jnp.float32,
    compensated: bool = False,
    interpret: bool = True,
) -> jax.Array:
    """Returns (2,) accum_dtype (sum, compensation); dot = out.sum()."""
    n = a.shape[0]
    block = min(block, n)
    if n % block:
        raise ValueError(f"length {n} not divisible by block {block}")
    return pl.pallas_call(
        functools.partial(_kernel, accum_dtype=accum_dtype, compensated=compensated),
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), accum_dtype),
        interpret=interpret,
    )(a, b)
