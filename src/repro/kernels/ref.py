"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels must match
(``tests/test_kernels.py`` sweeps shapes/dtypes and asserts allclose).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["spmv_ell_ref", "mixed_dot_ref", "lanczos_update_ref"]


def spmv_ell_ref(
    val: jax.Array, col: jax.Array, x: jax.Array, accum_dtype=jnp.float32
) -> jax.Array:
    """ELL SpMV: y[r] = sum_s val[r, s] * x[col[r, s]] with wide accumulation."""
    gathered = jnp.take(x, col).astype(accum_dtype)
    return (val.astype(accum_dtype) * gathered).sum(axis=1)


def mixed_dot_ref(a: jax.Array, b: jax.Array, accum_dtype=jnp.float32) -> jax.Array:
    """Mixed-precision dot: storage-dtype inputs, accum-dtype products + sum."""
    return jnp.sum(a.astype(accum_dtype) * b.astype(accum_dtype))


def lanczos_update_ref(
    w: jax.Array,
    v: jax.Array,
    v_prev: jax.Array,
    alpha: jax.Array,
    beta: jax.Array,
    accum_dtype=jnp.float32,
):
    """Fused three-term recurrence + norm^2 of the result (single pass).

    u = w - alpha v - beta v_prev;  returns (u in w.dtype, ||u||^2 in accum).
    """
    acc = accum_dtype
    u = w.astype(acc) - alpha.astype(acc) * v.astype(acc) - beta.astype(acc) * v_prev.astype(acc)
    return u.astype(w.dtype), jnp.sum(u * u)
