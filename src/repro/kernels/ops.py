"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; TPU is
the compilation target) and False on real TPU backends.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..sparse.formats import DeviceELL
from .lanczos_fused import spmv_ell_alpha_kernel_call
from .lanczos_update import lanczos_update_kernel_call
from .mixed_dot import mixed_dot_kernel_call
from .spmv_bsr import blocked_ell_from_csr, spmv_bsr_kernel_call
from .spmv_ell import spmv_ell_kernel_call
from .spmv_ell_packed import spmv_ell_packed_kernel_call

__all__ = [
    "default_interpret",
    "spmv_ell",
    "spmv_ell_alpha",
    "spmv_ell_packed",
    "spmv_bsr",
    "mixed_dot",
    "lanczos_update",
]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def spmv_ell(mat: DeviceELL, x: jax.Array, accum_dtype=None, **kw) -> jax.Array:
    """SpMV through the Pallas ELL kernel; returns (n_rows,) in accum dtype."""
    acc = jnp.dtype(accum_dtype or jnp.float32)
    # The Pallas gather path needs a real dtype accumulator supported on TPU;
    # f64 accumulation (CPU-only validation) falls back to the jnp reference.
    if acc == jnp.dtype(jnp.float64):
        return mat.matvec(x, accum_dtype=acc)
    kw.setdefault("interpret", default_interpret())
    y = spmv_ell_kernel_call(mat.val, mat.col, x, accum_dtype=acc, **kw)
    return y[: mat.n_rows]


def spmv_ell_alpha(mat: DeviceELL, x: jax.Array, v: jax.Array, accum_dtype=None, **kw):
    """Fused ``w = A @ x`` and ``alpha = <v, w>`` through one Pallas pass.

    ``x`` is the gather source (storage dtype); ``v`` the alpha operand in
    compute dtype, length ``n_rows`` — padded up to the ELL row padding
    (padded rows have zero values, so they add nothing to alpha).  Returns
    ``(w (n_rows,), alpha scalar)`` in accum dtype.  f64 accumulation (CPU
    validation) falls back to the jnp reference pair.
    """
    acc = jnp.dtype(accum_dtype or jnp.float32)
    if acc == jnp.dtype(jnp.float64):
        w = mat.matvec(x, accum_dtype=acc)
        return w, jnp.sum(v.astype(acc) * w)
    kw.setdefault("interpret", default_interpret())
    rows = mat.val.shape[0]
    vpad = jnp.pad(v, (0, rows - v.shape[0])) if v.shape[0] < rows else v
    w, alpha = spmv_ell_alpha_kernel_call(mat.val, mat.col, x, vpad, accum_dtype=acc, **kw)
    return w[: mat.n_rows], alpha[0]


def spmv_ell_packed(
    val: jax.Array,
    scale: jax.Array,
    base: jax.Array,
    dcol: jax.Array,
    x: jax.Array,
    n_rows: int,
    accum_dtype=None,
    **kw,
) -> jax.Array:
    """SpMV over one compressed staged chunk (see ``spmv_ell_packed.py``):
    dequantizes bf16/fp8 values by the row-block scales and cumsums the
    delta-encoded columns in-kernel.  Returns (n_rows,) in accum dtype."""
    acc = jnp.dtype(accum_dtype or jnp.float32)
    if acc == jnp.dtype(jnp.float64):
        # jnp reference for CPU f64 validation (same decompress arithmetic).
        vals = val.astype(acc) * scale.astype(acc)
        cols = base + jnp.cumsum(dcol.astype(jnp.int32), axis=1)
        y = jnp.sum(vals * jnp.take(x, cols).astype(acc), axis=1)
        return y[:n_rows]
    kw.setdefault("interpret", default_interpret())
    y = spmv_ell_packed_kernel_call(val, scale, base, dcol, x, accum_dtype=acc, **kw)
    return y[:n_rows]


def spmv_bsr(blocked, x: jax.Array, accum_dtype=None, **kw) -> jax.Array:
    """SpMV through the blocked-ELL (MXU) kernel.

    ``blocked``: (val, bcol, n_rows) from ``blocked_ell_from_csr``.
    """
    val, bcol, n_rows = blocked
    acc = jnp.dtype(accum_dtype or jnp.float32)
    if acc == jnp.dtype(jnp.float64):
        # jnp fallback for CPU f64 validation
        nbr, slots, bs, _ = val.shape
        xs = x[: nbr * bs].reshape(nbr, bs) if x.shape[0] >= nbr * bs else jnp.pad(
            x, (0, nbr * bs - x.shape[0])).reshape(nbr, bs)
        gathered = jnp.take(xs, bcol, axis=0)  # (nbr, slots, bs)
        y = jnp.einsum("rsij,rsj->ri", val.astype(acc), gathered.astype(acc))
        return y.reshape(-1)[:n_rows]
    kw.setdefault("interpret", default_interpret())
    xpad = x
    nbr, slots, bs, _ = val.shape
    if x.shape[0] < nbr * bs:
        xpad = jnp.pad(x, (0, nbr * bs - x.shape[0]))
    y = spmv_bsr_kernel_call(val, bcol, xpad, accum_dtype=acc, **kw)
    return y[:n_rows]


def mixed_dot(
    a: jax.Array, b: jax.Array, accum_dtype=None, compensated: bool = False, **kw
) -> jax.Array:
    acc = jnp.dtype(accum_dtype or jnp.float32)
    if acc == jnp.dtype(jnp.float64):
        return jnp.sum(a.astype(acc) * b.astype(acc))
    kw.setdefault("interpret", default_interpret())
    # Zero-pad up to the kernel block (padding lanes contribute nothing to
    # the sum or its compensation) — mirrors lanczos_update below.
    n = a.shape[0]
    block = min(kw.pop("block", 4096), n)
    pad = (-n) % block
    if pad:
        a, b = jnp.pad(a, (0, pad)), jnp.pad(b, (0, pad))
    out = mixed_dot_kernel_call(
        a, b, block=block, accum_dtype=acc, compensated=compensated, **kw
    )
    return out.sum()


def lanczos_update(w, v, v_prev, alpha, beta, accum_dtype=None, **kw):
    """Fused ``u = w - alpha v - beta v_prev`` + ``||u||^2`` (one memory pass).

    Arbitrary lengths are zero-padded up to the kernel block (padding lanes
    produce u = 0 and contribute nothing to the norm) and sliced back.
    """
    acc = jnp.dtype(accum_dtype or jnp.float32)
    if acc == jnp.dtype(jnp.float64):
        from .ref import lanczos_update_ref

        return lanczos_update_ref(w, v, v_prev, alpha, beta, accum_dtype=acc)
    kw.setdefault("interpret", default_interpret())
    n = w.shape[0]
    block = min(kw.pop("block", 4096), n)
    pad = (-n) % block
    if pad:
        w, v, v_prev = (jnp.pad(a, (0, pad)) for a in (w, v, v_prev))
    u, nrm = lanczos_update_kernel_call(
        w, v, v_prev, alpha, beta, block=block, accum_dtype=acc, **kw
    )
    return u[:n], nrm[0]
