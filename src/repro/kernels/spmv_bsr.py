"""Pallas TPU kernel: blocked-ELL (BSR-style) SpMV — the MXU-native variant.

The ELL kernel (spmv_ell.py) gathers scalars with the VPU; when the matrix
has block structure (FEM meshes, banded graphs, the paper's venturiLevel3),
storing dense (BS x BS) blocks at sparse block coordinates turns SpMV into a
stream of small dense matmuls on the MXU.  Layout ("blocked ELL": uniform
block-slots per block-row, zero-padded):

  val:  (n_block_rows, slots, BS, BS)
  bcol: (n_block_rows, slots) int32  — block-column index (0 for padding,
                                        val zeros make padding inert)
  x:    (n_cols,) — VMEM-resident like the ELL kernel

Grid = (n_block_rows, slots); the slot axis is sequential on TPU, so the
(BS,) output tile accumulates across slots.  The block gather is a dynamic
slice of x at bcol*BS — contiguous, no scalar scatter/gather at all, which
is the entire point of the format on TPU.

Crossover vs ELL is density-dependent: a block is worth storing when more
than ~1/BS of it is non-zero (see benchmarks/kernels_bench.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["spmv_bsr_kernel_call", "blocked_ell_from_csr"]


def _kernel(x_ref, val_ref, bcol_ref, y_ref, *, accum_dtype, block_size):
    j = pl.program_id(1)
    bcol = bcol_ref[0, 0]
    xs = jax.lax.dynamic_slice(x_ref[...], (bcol * block_size,), (block_size,))
    blk = val_ref[0, 0].astype(accum_dtype)  # (BS, BS)
    part = blk @ xs.astype(accum_dtype)  # MXU matvec

    @pl.when(j == 0)
    def _init():
        y_ref[0, :] = part

    @pl.when(j != 0)
    def _acc():
        y_ref[0, :] = y_ref[0, :] + part


@functools.partial(jax.jit, static_argnames=("accum_dtype", "interpret"))
def spmv_bsr_kernel_call(
    val: jax.Array,  # (nbr, slots, BS, BS)
    bcol: jax.Array,  # (nbr, slots) int32
    x: jax.Array,  # (n_cols,)
    *,
    accum_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    nbr, slots, bs, _ = val.shape
    n = x.shape[0]
    return pl.pallas_call(
        functools.partial(_kernel, accum_dtype=accum_dtype, block_size=bs),
        grid=(nbr, slots),
        in_specs=[
            pl.BlockSpec((n,), lambda i, j: (0,)),
            pl.BlockSpec((1, 1, bs, bs), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, bs), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbr, bs), accum_dtype),
        interpret=interpret,
    )(x, val, bcol).reshape(nbr * bs)


def blocked_ell_from_csr(csr, block_size: int = 8, dtype=jnp.float32):
    """Host conversion: CSR -> (val, bcol, n_rows). Zero-pads to uniform slots.

    Thin tuple-returning shim over the vectorized container conversion in
    ``sparse/formats.py`` (kept for callers predating :class:`DeviceBSR`).
    """
    from ..sparse.formats import to_device_bsr

    bsr = to_device_bsr(csr, block_size=block_size, dtype=dtype)
    return bsr.val, bsr.bcol, bsr.n_rows
