"""Pallas TPU kernel: ELLPACK SpMV — the paper's hot-spot (DESIGN.md §4).

Layout (see ``sparse/formats.py::DeviceELL``): ``val``/``col`` are
(rows_padded, width) with zero padding; ``x`` is the SpMV input vector
(replicated per shard in the distributed solver — the paper's §III-A).

Tiling: grid = (rows/BLOCK_R, width/BLOCK_W).  Each step holds in VMEM:
  * a (BLOCK_R, BLOCK_W) value tile and its column-index tile,
  * the full ``x`` vector (the gather source must be on-chip: TPU has no
    efficient random HBM gather — this is the central hardware adaptation
    from the paper's GPU design, which gathers through the L2/unified
    memory. VMEM residency caps a single shard at ~3M f32 columns; larger
    matrices are row+column partitioned across devices first, which is
    exactly the paper's multi-device partition scheme),
  * a (BLOCK_R,) f32 output accumulator tile.

The width dimension of the grid is sequential on TPU, so the kernel
accumulates partial row sums into the output tile across width steps
(`pl.when(j == 0)` initializes).  Accumulation dtype is a parameter — the
paper's mixed-precision "compute" knob.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["spmv_ell_kernel_call"]


def _kernel(x_ref, val_ref, col_ref, y_ref, *, accum_dtype):
    j = pl.program_id(1)
    x = x_ref[...]  # full vector, VMEM-resident
    cols = col_ref[...]  # (BR, BW) int32
    vals = val_ref[...].astype(accum_dtype)
    gathered = jnp.take(x, cols.reshape(-1), axis=0).reshape(cols.shape).astype(accum_dtype)
    part = jnp.sum(vals * gathered, axis=1)  # (BR,)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = part

    @pl.when(j != 0)
    def _acc():
        y_ref[...] = y_ref[...] + part


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_w", "accum_dtype", "interpret")
)
def spmv_ell_kernel_call(
    val: jax.Array,
    col: jax.Array,
    x: jax.Array,
    *,
    block_r: int = 8,
    block_w: int = 512,
    accum_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    """y = ELL(val, col) @ x, accumulated in ``accum_dtype``. Returns (rows,)."""
    rows, width = val.shape
    block_w = min(block_w, width)
    if rows % block_r or width % block_w:
        raise ValueError(f"ELL shape {val.shape} not divisible by ({block_r},{block_w})")
    n = x.shape[0]
    grid = (rows // block_r, width // block_w)
    return pl.pallas_call(
        functools.partial(_kernel, accum_dtype=accum_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n,), lambda i, j: (0,)),  # x: full vector each step
            pl.BlockSpec((block_r, block_w), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, block_w), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_r,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), accum_dtype),
        interpret=interpret,
    )(x, val, col)
