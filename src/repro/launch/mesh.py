"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module never touches jax device state — required because the
dry-run must set XLA_FLAGS before the first jax device query.

Mesh topology (v5e pods, 256 chips each):
  single pod : (data=16, model=16)            — 256 chips
  two pods   : (pod=2, data=16, model=16)     — 512 chips; the 'pod' axis
               carries only data parallelism (gradient all-reduce crosses
               DCN, everything else stays inside a pod's ICI)
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_flat_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_flat_mesh(n: int | None = None, axis: str = "data"):
    """1-axis mesh over the first n devices (eigensolver + tests)."""
    import numpy as np

    devs = jax.devices()
    n = n or len(devs)
    return jax.sharding.Mesh(np.array(devs[:n]), (axis,))
