"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 100 --batch 8 --seq 128

On a real multi-chip platform (jax.device_count() > 1) the driver builds a
(data, model) mesh, resolves parameter/batch NamedShardings through the
logical rule engine, and jits the train step with those shardings — the same
code path the multi-pod dry-run compiles for 512 chips.  On one device it
runs the identical model/trainer without a mesh.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced same-family config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "adafactor"])
    ap.add_argument("--spectral-every", type=int, default=0,
                    help="every N steps, top-K Hessian eigenvalues via the paper's Lanczos")
    ap.add_argument("--mesh-model", type=int, default=1)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.distributed.sharding import sharding_ctx
    from repro.models.common import split_tree
    from repro.models.model import init_model
    from repro.training import DataConfig, OptConfig, TrainConfig, Trainer, data_stream
    from repro.training.data import synthetic_batch

    cfg = get_config(args.arch, smoke=args.smoke)
    if not args.smoke:
        cfg = dataclasses.replace(cfg, compute_dtype=jnp.bfloat16)

    n_dev = jax.device_count()
    mesh = None
    if n_dev > 1:
        model_ax = args.mesh_model
        data_ax = n_dev // model_ax
        mesh = jax.make_mesh((data_ax, model_ax), ("data", "model"))
        print(f"mesh: data={data_ax} model={model_ax}")

    tc = TrainConfig(
        opt=OptConfig(peak_lr=args.lr, warmup_steps=max(5, args.steps // 20),
                      decay_steps=args.steps),
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
        spectral_every=args.spectral_every, optimizer=args.optimizer,
    )
    dc = DataConfig(batch=args.batch, seq_len=args.seq, seed=0)

    ctx = sharding_ctx(mesh) if mesh is not None else sharding_ctx(None)
    with ctx:
        params, _ = split_tree(init_model(jax.random.PRNGKey(0), cfg))
        n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        print(f"{cfg.name}: {n_params/1e6:.1f}M params")
        trainer = Trainer(cfg, tc, params,
                          probe_batch_fn=lambda: synthetic_batch(cfg, dc, 10**6))
        if args.resume and trainer.try_resume():
            print(f"resumed from step {trainer.step}")
        hist = trainer.run(data_stream(cfg, dc, start_step=trainer.step), num_steps=args.steps)
        print(f"final loss: {np.mean(hist[-5:]):.4f} (start {hist[0]:.4f})")
        if trainer.straggler_events:
            print(f"straggler events: {len(trainer.straggler_events)}")
        if trainer.spectra:
            for step, ev in trainer.spectra.items():
                print(f"  Hessian top-|λ| @ step {step}: {ev}")
    return trainer


if __name__ == "__main__":
    main()
