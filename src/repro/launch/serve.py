"""Eigensolver serving driver: resident matrices, a synthetic query stream,
and the serving stats that prove the paper's amortization claim end to end.

    PYTHONPATH=src python -m repro.launch.serve --smoke

Loads (generates) a pool of sparse matrices, makes them resident in an
``EigenScheduler``, fires a threaded synthetic query stream at it, and
prints the ``ServerStats`` snapshot (throughput, p50/p99 latency, coalesce
rate).  With a persistent store (``--store``, or always under ``--smoke``
via a temp dir) it then simulates a server restart: a second scheduler
warms every matrix from the store and the conversion counter is asserted
not to move — the zero-conversion warm-start contract, verified live.

``--kill-resume`` is the crash drill: run the smoke as a subprocess,
SIGTERM it mid-burst, assert it shuts down bounded (the scheduler's close
path fails pending futures typed — no submitter thread can hang), then
restart against the same store and assert the warm-resume contract still
holds.  SIGTERM itself is handled as a graceful ``SystemExit`` so the
scheduler context manager unwinds instead of the process dying mid-future.

(The old LM decode driver moved with its engine: ``repro.serving.lm``.)
"""

from __future__ import annotations

import argparse
import signal
import subprocess
import sys
import tempfile
import threading
import time


def _build_matrices(args):
    from repro.sparse import generate

    specs = [("web", 6.0), ("road", 3.0), ("web", 9.0)][: args.matrices]
    return [
        generate(kind, args.n, deg, seed=11 + i, values="normalized")
        for i, (kind, deg) in enumerate(specs)
    ]


def _run_stream(sched, keys, args):
    """Threaded synthetic stream: each submitter thread round-robins the
    resident matrices with compatible queries (one shared group key per
    matrix), so the admission window has something to coalesce."""
    from repro.serving import DeadlineExceededError, QueueFullError

    errors = []
    lock = threading.Lock()

    def submitter(tid: int):
        handles = []
        for i in range(args.queries_per_thread):
            key = keys[(tid + i) % len(keys)]
            k = 2 + (i % 3) * 2  # k in {2, 4, 6}: same sweep, sliced
            try:
                handles.append(
                    sched.submit(key, k=k, num_iters=args.iters, reorth="full")
                )
            except (QueueFullError, DeadlineExceededError) as exc:
                with lock:
                    errors.append(exc)
        for h in handles:
            try:
                h.result(timeout=120.0)
            except Exception as exc:
                with lock:
                    errors.append(exc)

    threads = [threading.Thread(target=submitter, args=(t,)) for t in range(args.threads)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return wall, errors


def _install_sigterm_handler() -> None:
    """Turn SIGTERM into a graceful ``SystemExit(143)``: the scheduler's
    context manager then runs ``close()`` — in-flight work finishes, queued
    futures fail typed — so no submitter thread is ever stranded on a future
    that cannot resolve.  Main thread only (signal API requirement)."""
    if threading.current_thread() is not threading.main_thread():
        return

    def _graceful(signum, frame):
        raise SystemExit(143)

    signal.signal(signal.SIGTERM, _graceful)


def _kill_resume(args) -> int:
    """The crash drill: smoke-run a child server, SIGTERM it mid-burst,
    assert the shutdown is bounded, then restart on the same store and
    assert the warm-resume contract (see module docstring)."""
    store_dir = args.store or tempfile.mkdtemp(prefix="repro-serving-")
    cmd = [sys.executable, "-m", "repro.launch.serve", "--smoke", "--store", store_dir]
    child = subprocess.Popen(
        cmd, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True
    )
    resident = False
    assert child.stdout is not None
    for line in child.stdout:
        print(f"[child] {line}", end="")
        if line.startswith("resident:"):
            resident = True
            break
    if not resident:
        child.kill()
        child.wait()
        print("FAIL: child exited before its matrices became resident")
        return 1
    time.sleep(1.0)  # land the SIGTERM inside the query burst
    child.send_signal(signal.SIGTERM)
    try:
        out, _ = child.communicate(timeout=60.0)
    except subprocess.TimeoutExpired:
        child.kill()
        print("FAIL: child hung after SIGTERM — stranded queries in shutdown")
        return 1
    for line in out.splitlines():
        print(f"[child] {line}")
    print(f"kill-resume: child exited rc={child.returncode} within bound after SIGTERM")
    rc = main(["--smoke", "--store", store_dir])
    if rc != 0:
        print("FAIL: restart after SIGTERM did not warm-resume")
        return 1
    print("kill-resume: warm resume after SIGTERM verified")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true", help="small sizes, temp store, fast")
    ap.add_argument("--n", type=int, default=4096, help="matrix dimension")
    ap.add_argument("--matrices", type=int, default=2, help="resident matrix pool size")
    ap.add_argument("--threads", type=int, default=4, help="concurrent submitter threads")
    ap.add_argument("--queries-per-thread", type=int, default=8)
    ap.add_argument("--iters", type=int, default=24, help="Lanczos steps per sweep")
    ap.add_argument("--window-ms", type=float, default=20.0, help="admission window")
    ap.add_argument("--max-group", type=int, default=16)
    ap.add_argument("--store", default=None, help="session store dir (persists warm state)")
    ap.add_argument(
        "--kill-resume",
        action="store_true",
        help="crash drill: SIGTERM a child smoke run mid-burst, then assert "
        "bounded shutdown + warm resume from the same store",
    )
    args = ap.parse_args(argv)
    _install_sigterm_handler()
    if args.kill_resume:
        return _kill_resume(args)
    if args.smoke:
        args.n = min(args.n, 1024)
        args.matrices = min(args.matrices, 2)
        args.threads = min(args.threads, 3)
        args.queries_per_thread = min(args.queries_per_thread, 6)

    from repro.serving import EigenScheduler, SchedulerConfig, SessionStore
    from repro.sparse.formats import conversion_count

    store_dir = args.store or (tempfile.mkdtemp(prefix="repro-serving-") if args.smoke else None)
    store = SessionStore(store_dir) if store_dir else None
    cfg = SchedulerConfig(
        admission_window_s=args.window_ms * 1e-3,
        max_group=args.max_group,
        max_sessions=max(args.matrices, 2),
    )

    matrices = _build_matrices(args)
    with EigenScheduler(cfg, store=store) as sched:
        t0 = time.perf_counter()
        keys = [sched.add_matrix(m, name=f"mat{i}") for i, m in enumerate(matrices)]
        prep_s = time.perf_counter() - t0
        print(f"resident: {len(keys)} matrices (n={args.n}) prepared in {prep_s:.2f}s")
        wall, errors = _run_stream(sched, keys, args)
        stats = sched.stats()
        qps = stats.completed / wall if wall > 0 else 0.0
        print(stats.summary())
        print(f"throughput: {stats.completed} queries in {wall:.2f}s = {qps:.1f} q/s")
        if errors:
            print(f"stream errors: {len(errors)} ({type(errors[0]).__name__}: {errors[0]})")
    if errors:
        return 1

    if store is not None:
        # Simulated restart: a fresh scheduler must warm every matrix from
        # the persisted store without converting anything.
        conv0 = conversion_count()
        with EigenScheduler(cfg, store=store) as sched2:
            for i, m in enumerate(matrices):
                sched2.add_matrix(m, name=f"mat{i}")
            s2 = sched2.stats()
            h = sched2.submit("mat0", k=4, num_iters=args.iters, reorth="full")
            res = h.result(timeout=120.0)
        dconv = conversion_count() - conv0
        print(
            f"restart: {s2.warm_starts}/{len(matrices)} sessions warm-started, "
            f"{dconv} conversions, first solve reused={res.session_reuse}"
        )
        if s2.warm_starts != len(matrices) or dconv != 0 or not res.session_reuse:
            print("FAIL: warm restart paid conversions")
            return 1
        print("warm-restart contract verified: zero conversions after restart")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
