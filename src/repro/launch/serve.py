"""Eigensolver serving driver: resident matrices, a synthetic query stream,
and the serving stats that prove the paper's amortization claim end to end.

    PYTHONPATH=src python -m repro.launch.serve --smoke

Loads (generates) a pool of sparse matrices, makes them resident in an
``EigenScheduler``, fires a threaded synthetic query stream at it, and
prints the ``ServerStats`` snapshot (throughput, p50/p99 latency, coalesce
rate).  With a persistent store (``--store``, or always under ``--smoke``
via a temp dir) it then simulates a server restart: a second scheduler
warms every matrix from the store and the conversion counter is asserted
not to move — the zero-conversion warm-start contract, verified live.

(The old LM decode driver moved with its engine: ``repro.serving.lm``.)
"""

from __future__ import annotations

import argparse
import tempfile
import threading
import time


def _build_matrices(args):
    from repro.sparse import generate

    specs = [("web", 6.0), ("road", 3.0), ("web", 9.0)][: args.matrices]
    return [
        generate(kind, args.n, deg, seed=11 + i, values="normalized")
        for i, (kind, deg) in enumerate(specs)
    ]


def _run_stream(sched, keys, args):
    """Threaded synthetic stream: each submitter thread round-robins the
    resident matrices with compatible queries (one shared group key per
    matrix), so the admission window has something to coalesce."""
    from repro.serving import DeadlineExceededError, QueueFullError

    errors = []
    lock = threading.Lock()

    def submitter(tid: int):
        handles = []
        for i in range(args.queries_per_thread):
            key = keys[(tid + i) % len(keys)]
            k = 2 + (i % 3) * 2  # k in {2, 4, 6}: same sweep, sliced
            try:
                handles.append(
                    sched.submit(key, k=k, num_iters=args.iters, reorth="full")
                )
            except (QueueFullError, DeadlineExceededError) as exc:
                with lock:
                    errors.append(exc)
        for h in handles:
            try:
                h.result(timeout=120.0)
            except Exception as exc:
                with lock:
                    errors.append(exc)

    threads = [threading.Thread(target=submitter, args=(t,)) for t in range(args.threads)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return wall, errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true", help="small sizes, temp store, fast")
    ap.add_argument("--n", type=int, default=4096, help="matrix dimension")
    ap.add_argument("--matrices", type=int, default=2, help="resident matrix pool size")
    ap.add_argument("--threads", type=int, default=4, help="concurrent submitter threads")
    ap.add_argument("--queries-per-thread", type=int, default=8)
    ap.add_argument("--iters", type=int, default=24, help="Lanczos steps per sweep")
    ap.add_argument("--window-ms", type=float, default=20.0, help="admission window")
    ap.add_argument("--max-group", type=int, default=16)
    ap.add_argument("--store", default=None, help="session store dir (persists warm state)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.n = min(args.n, 1024)
        args.matrices = min(args.matrices, 2)
        args.threads = min(args.threads, 3)
        args.queries_per_thread = min(args.queries_per_thread, 6)

    from repro.serving import EigenScheduler, SchedulerConfig, SessionStore
    from repro.sparse.formats import conversion_count

    store_dir = args.store or (tempfile.mkdtemp(prefix="repro-serving-") if args.smoke else None)
    store = SessionStore(store_dir) if store_dir else None
    cfg = SchedulerConfig(
        admission_window_s=args.window_ms * 1e-3,
        max_group=args.max_group,
        max_sessions=max(args.matrices, 2),
    )

    matrices = _build_matrices(args)
    with EigenScheduler(cfg, store=store) as sched:
        t0 = time.perf_counter()
        keys = [sched.add_matrix(m, name=f"mat{i}") for i, m in enumerate(matrices)]
        prep_s = time.perf_counter() - t0
        print(f"resident: {len(keys)} matrices (n={args.n}) prepared in {prep_s:.2f}s")
        wall, errors = _run_stream(sched, keys, args)
        stats = sched.stats()
        qps = stats.completed / wall if wall > 0 else 0.0
        print(stats.summary())
        print(f"throughput: {stats.completed} queries in {wall:.2f}s = {qps:.1f} q/s")
        if errors:
            print(f"stream errors: {len(errors)} ({type(errors[0]).__name__}: {errors[0]})")
    if errors:
        return 1

    if store is not None:
        # Simulated restart: a fresh scheduler must warm every matrix from
        # the persisted store without converting anything.
        conv0 = conversion_count()
        with EigenScheduler(cfg, store=store) as sched2:
            for i, m in enumerate(matrices):
                sched2.add_matrix(m, name=f"mat{i}")
            s2 = sched2.stats()
            h = sched2.submit("mat0", k=4, num_iters=args.iters, reorth="full")
            res = h.result(timeout=120.0)
        dconv = conversion_count() - conv0
        print(
            f"restart: {s2.warm_starts}/{len(matrices)} sessions warm-started, "
            f"{dconv} conversions, first solve reused={res.session_reuse}"
        )
        if s2.warm_starts != len(matrices) or dconv != 0 or not res.session_reuse:
            print("FAIL: warm restart paid conversions")
            return 1
        print("warm-restart contract verified: zero conversions after restart")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
