"""Serving driver: prefill + batched decode with the KV-cache engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke --steps 16
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ckpt-dir", default=None, help="restore trained params")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models.common import split_tree
    from repro.models.model import init_model
    from repro.serving import Engine, ServeConfig

    cfg = get_config(args.arch, smoke=args.smoke)
    params, _ = split_tree(init_model(jax.random.PRNGKey(0), cfg))
    if args.ckpt_dir:
        from repro.training.checkpoint import CheckpointManager

        mgr = CheckpointManager(args.ckpt_dir)
        step, tree, _ = mgr.restore_latest({"params": params, "opt": None})
        if step is not None:
            params = tree["params"]
            print(f"restored step {step}")

    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
                                   jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, args.prompt_len, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((args.batch, 8, cfg.d_model)), jnp.float32)

    eng = Engine(cfg, params, ServeConfig(max_len=args.max_len, temperature=args.temperature))
    t0 = time.perf_counter()
    toks, info = eng.generate(batch, steps=args.steps)
    wall = time.perf_counter() - t0
    print(f"{cfg.name}: generated {args.batch}x{args.steps} tokens in {wall:.2f}s "
          f"({args.batch*args.steps/wall:.1f} tok/s)")
    print("sample:", np.asarray(toks[0]))
    print("mean token logprob:", float(info["token_logprobs"].mean()))


if __name__ == "__main__":
    main()
