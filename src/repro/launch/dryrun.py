import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, prove it fits, and extract the roofline inputs.

The two lines above MUST stay the first statements in this module: jax locks
the platform device count at first initialization, and the dry-run needs 512
placeholder host devices to build the 2x16x16 multi-pod mesh.  This module is
the ONLY place that flag is set — smoke tests and benchmarks see 1 device.

Per cell this script:
  1. builds the arch config (bf16 compute / f32 params, full remat + scan),
  2. resolves parameter/optimizer/input NamedShardings via the logical rule
     engine (distributed/sharding.py),
  3. ``jax.jit(step).lower(...)`` with ShapeDtypeStruct inputs (no allocation)
     and ``.compile()`` on the single-pod (16,16) mesh and the multi-pod
     (2,16,16) mesh,
  4. records memory_analysis / cost_analysis / per-collective byte counts
     parsed from the post-SPMD optimized HLO into a JSON artifact consumed by
     benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/dryrun
"""

import argparse
import dataclasses
import json
import re
import sys
import time
from typing import Any, Dict

import jax
import jax.numpy as jnp


_COLL_RE = re.compile(
    r"(?P<dtype>[a-z0-9]+)\[(?P<dims>[0-9,]*)\][^ ]* "
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum result-buffer bytes of every collective in the optimized HLO.

    Ring-algorithm wire factors: all-reduce moves ~2x its buffer
    (reduce-scatter + all-gather phases); the others ~1x.  This is the
    collective-term numerator of EXPERIMENTS.md §Roofline.
    """
    per_op: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group("op")
        if op.endswith("-start"):
            op = op[: -len("-start")]
        dims = [int(d) for d in m.group("dims").split(",") if d]
        nbytes = _DTYPE_BYTES.get(m.group("dtype"), 4)
        for d in dims:
            nbytes *= d
        factor = 2.0 if op == "all-reduce" else 1.0
        per_op[op] = per_op.get(op, 0.0) + nbytes * factor
        counts[op] = counts.get(op, 0) + 1
    return {"bytes_by_op": per_op, "counts": counts, "total_bytes": sum(per_op.values())}


def cpu_upcast_artifact_bytes(hlo_text: str) -> int:
    """Estimate XLA-CPU bf16->f32 canonicalization artifacts.

    The CPU backend has no native bf16 dot: it upcasts operands to f32, and
    its loop-invariant hoisting then materializes whole-stack f32 copies of
    bf16 buffers (saved activation stacks, stacked parameters) that would
    never exist on TPU (native bf16 MXU).  Heuristic: any >=100 MB buffer
    whose exact dims appear in the module in BOTH bf16 and f32 with ndim>=3
    counts its f32 size once.  Reported alongside raw temp so the roofline
    table can show a TPU-adjusted footprint (see EXPERIMENTS.md SDry-run).
    """
    dims_by_dtype = {}
    for m in re.finditer(r"\b(bf16|f32)\[([0-9,]+)\]", hlo_text):
        dims_by_dtype.setdefault(m.group(2), set()).add(m.group(1))
    total = 0
    for dims, dtypes in dims_by_dtype.items():
        if {"bf16", "f32"} <= dtypes:
            parts = [int(d) for d in dims.split(",")]
            if len(parts) >= 3:
                n = 4
                for d in parts:
                    n *= d
                if n >= 100 * 2**20:
                    total += n
    return total


def accum_steps_for(cfg, shape, optimizer: str = "adamw") -> int:
    """Gradient-accumulation microbatching for the big archs: the remat-saved
    activation stack scales with the per-step microbatch, so models with
    L x d_model beyond ~200k split the global batch (standard practice —
    global batch semantics unchanged).

    The adafactor/bf16 giants (arctic-480b) accumulate in bf16 (the f32
    gradient-sum tree would cost params x 4 B/device ~ 7.5 GiB)."""
    if shape.mode != "train":
        return 1
    if optimizer == "adafactor":
        return 4
    score = cfg.n_layers * cfg.d_model
    if score >= 400_000:  # qwen2-vl-72b (80x8192), qwen1.5-32b (64x5120)
        return 8
    if score >= 200_000:  # phi3 (40x5120)
        return 4
    return 1


def estimate_param_count(cfg) -> int:
    """Rough parameter count (embedding + blocks), for optimizer selection."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab_padded
    hd = cfg.hd
    attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd + cfg.n_heads * hd * d
    mlp = 3 * d * f
    if cfg.n_experts:
        mlp = cfg.n_experts * 3 * d * f + (3 * d * f if cfg.dense_residual else 0)
    if cfg.family == "ssm":
        di = cfg.d_inner
        per_layer = d * (2 * di + 2 * cfg.ssm_state + cfg.n_ssm_heads) + di * d
    elif cfg.family == "hybrid_rglru":
        w = cfg.lru_width or d
        per_layer = (
            (2 * d * w + w * d + 2 * w * w + mlp + attn) // len(cfg.block_pattern or (1, 1, 1)) * 1
        )
        per_layer = (2 * (2 * d * w + w * d + 2 * w * w + 3 * d * f) + (attn + 3 * d * f)) // 3
    else:
        per_layer = attn + mlp
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    layers = cfg.n_layers + cfg.n_enc_layers
    return int(emb + layers * per_layer)


def plan_cell(cfg, shape, num_devices: int, hbm_per_chip: int = 16 * 2**30):
    """Production planning: optimizer + param dtype for this cell.

    AdamW keeps f32 params + f32 m/v (12 B/param).  When that exceeds ~60%
    of pod HBM (leaving room for activations), switch to bf16 params +
    Adafactor factored second moment (~2.1 B/param) — the arctic-480b case.
    Serving always uses bf16 params.
    """
    n_params = estimate_param_count(cfg)
    if shape.mode != "train":
        cfg = dataclasses.replace(cfg, param_dtype=jnp.bfloat16)
        if shape.mode == "decode" and cfg.family not in ("ssm",):
            # KV bytes at bf16; quantize to int8 when the pod share is large
            w = min(shape.seq_len, cfg.window or shape.seq_len)
            kv_bytes = 2 * cfg.n_layers * shape.global_batch * w * cfg.n_kv_heads * cfg.hd * 2
            if kv_bytes / num_devices > 4 * 2**30:
                cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
        return cfg, "none", n_params
    adamw_bytes = 12 * n_params
    if adamw_bytes > 0.6 * num_devices * hbm_per_chip:
        return dataclasses.replace(cfg, param_dtype=jnp.bfloat16), "adafactor", n_params
    return cfg, "adamw", n_params


def _build_cell(arch: str, shape_name: str):
    from repro.configs import SHAPES, get_config, input_specs

    cfg = get_config(arch)
    cfg = dataclasses.replace(cfg, compute_dtype=jnp.bfloat16, remat="full", scan_layers=True)
    shape = SHAPES[shape_name]
    specs = input_specs(cfg, shape)
    return cfg, shape, specs


def lower_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True) -> Dict:
    from functools import partial

    from repro.configs import SHAPES
    from repro.distributed.sharding import sharding_ctx, tree_shardings
    from repro.launch.mesh import make_production_mesh
    from repro.models.common import split_tree
    from repro.models.model import decode_state_axes, decode_step, init_model, prefill
    from repro.training import TrainConfig, make_train_step
    from repro.training.optimizer import OptState

    cfg, shape, specs = _build_cell(arch, shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg, optimizer, n_params = plan_cell(cfg, shape, mesh.size)
    if shape.mode == "decode":  # state template must match the planned dtype
        from repro.configs import input_specs as _ispecs

        specs = _ispecs(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": f"{dict(mesh.shape)}",
        "num_devices": mesh.size, "mode": shape.mode, "optimizer": optimizer,
        "est_params": n_params,
    }
    t0 = time.perf_counter()

    with sharding_ctx(mesh):
        # abstract params + their shardings (no allocation: eval_shape)
        ptree = jax.eval_shape(lambda: init_model(jax.random.PRNGKey(0), cfg))
        params_sds, axes = split_tree(ptree)
        p_shard = tree_shardings(params_sds, axes)
        batch_axes = {
            "tokens": ("batch", None),
            "labels": ("batch", None),
            "frames": ("batch", None, None),
            "positions": (None, "batch", None),
        }

        if shape.mode == "train":
            if optimizer == "adafactor":
                from repro.training.optimizer import FactoredState

                f32sds = lambda shp: jax.ShapeDtypeStruct(shp, jnp.float32)
                opt_sds = FactoredState(
                    step=jax.ShapeDtypeStruct((), jnp.int32),
                    vr=jax.tree.map(
                        lambda p: f32sds(p.shape[:-1] if len(p.shape) >= 2 else p.shape),
                        params_sds,
                    ),
                    vc=jax.tree.map(
                        lambda p: f32sds(p.shape[:-2] + p.shape[-1:] if len(p.shape) >= 2 else ()),
                        params_sds,
                    ),
                )
                slice_axes = lambda sel: jax.tree_util.tree_map(
                    lambda a: sel(a), axes, is_leaf=lambda x: isinstance(x, tuple)
                )
                vr_axes = slice_axes(lambda a: a[:-1] if len(a) >= 2 else a)
                vc_axes = slice_axes(lambda a: a[:-2] + a[-1:] if len(a) >= 2 else ())
                o_shard = FactoredState(
                    step=None,
                    vr=tree_shardings(opt_sds.vr, vr_axes),
                    vc=tree_shardings(opt_sds.vc, vc_axes),
                )
            else:
                opt_sds = OptState(
                    step=jax.ShapeDtypeStruct((), jnp.int32),
                    m=jax.tree.map(
                        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_sds
                    ),
                    v=jax.tree.map(
                        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_sds
                    ),
                )
                o_shard = OptState(step=None, m=p_shard, v=p_shard)
            b_shard = {k: tree_shardings(v, batch_axes[k]) for k, v in specs.items()}
            accum = accum_steps_for(cfg, shape, optimizer)
            rec["accum_steps"] = accum
            accum_dtype = jnp.bfloat16 if optimizer == "adafactor" else None
            step_fn = make_train_step(
                cfg, TrainConfig(accum_steps=accum, optimizer=optimizer, accum_dtype=accum_dtype)
            )
            jitted = jax.jit(
                step_fn,
                in_shardings=(p_shard, o_shard, b_shard),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_sds, opt_sds, specs)
        elif shape.mode == "prefill":
            b_shard = {k: tree_shardings(v, batch_axes[k]) for k, v in specs.items()}
            fn = partial(prefill, cfg=cfg, max_len=min(shape.seq_len, cfg.window or shape.seq_len))
            jitted = jax.jit(
                lambda params, batch: fn(params=params, batch=batch),
                in_shardings=(p_shard, b_shard),
            )
            lowered = jitted.lower(params_sds, specs)
        else:  # decode
            st_axes = decode_state_axes(cfg)
            st_shard = tree_shardings(specs["state"], st_axes)
            tok_shard = tree_shardings(specs["tokens"], ("batch", None))
            jitted = jax.jit(
                lambda params, state, tokens: decode_step(params, cfg, state, tokens),
                in_shardings=(p_shard, st_shard, tok_shard),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_sds, specs["state"], specs["tokens"])

        rec["lower_s"] = round(time.perf_counter() - t0, 2)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.perf_counter() - t1, 2)

        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
        cost = compiled.cost_analysis()
        rec["cost_analysis"] = {
            k: float(v)
            for k, v in (cost or {}).items()
            if isinstance(v, (int, float))
            and k
            in (
                "flops",
                "bytes accessed",
                "transcendentals",
                "utilization operand 0 {}",
                "bytes accessed output {}",
            )
        }
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo)
        rec["cpu_upcast_artifact_bytes"] = cpu_upcast_artifact_bytes(hlo)
        rec["hlo_chars"] = len(hlo)
        del hlo

    if verbose:
        ma = rec["memory_analysis"]
        print(
            f"[{arch} x {shape_name} x {'2pod' if multi_pod else '1pod'}] "
            f"compile={rec['compile_s']}s "
            f"args/device={ma.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
            f"temp/device={ma.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
            f"flops/device={rec['cost_analysis'].get('flops', 0):.3e} "
            f"coll={rec['collectives']['total_bytes']/2**30:.3f}GiB "
            f"cpu_artifacts={rec['cpu_upcast_artifact_bytes']/2**30:.2f}GiB"
        )
        print("  memory_analysis:", rec["memory_analysis"])
        print("  cost_analysis:", rec["cost_analysis"])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str)
    ap.add_argument("--shape", type=str)
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default=None, help="JSON output path or dir (--all)")
    args = ap.parse_args()

    from repro.configs import ARCHS, SHAPES, applicable

    if args.all:
        cells = [(a, s) for a in ARCHS for s in SHAPES if applicable(a, s)]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    results = []
    failures = []
    for arch, shp in cells:
        recs = []
        try:
            if not args.multi_pod_only:
                recs.append(lower_cell(arch, shp, multi_pod=False))
            if not args.single_pod_only:
                recs.append(lower_cell(arch, shp, multi_pod=True))
            results.extend(recs)
        except Exception as e:  # noqa: BLE001 — report and continue
            import traceback

            failures.append((arch, shp, f"{type(e).__name__}: {e}"))
            traceback.print_exc()
    if args.out:
        if args.all or len(results) > 1:
            os.makedirs(args.out, exist_ok=True)
            for rec in results:
                tag = "2pod" if rec["num_devices"] == 512 else "1pod"
                path = os.path.join(args.out, f"{rec['arch']}__{rec['shape']}__{tag}.json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
        else:
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    if failures:
        print("FAILURES:")
        for a, s, e in failures:
            print(f"  {a} x {s}: {e}")
        sys.exit(1)
    print(f"dry-run OK: {len(results)} compilations")


if __name__ == "__main__":
    main()
