"""repro: mixed-precision multi-device Top-K sparse eigensolver framework.

The one-call entrypoint is :func:`repro.eigsh` (re-exported from
``repro.api``) — a SciPy-style frontend that coerces any problem form
(dense, CSR, scipy sparse, operator, callable) and dispatches across the
single-device, distributed, thick-restarted, and out-of-core engines.
"""

__version__ = "1.2.0"

from .api import EigenResult, EigenSession, SolverConfig, eigsh, eigsh_many, prepare

__all__ = [
    "eigsh",
    "eigsh_many",
    "prepare",
    "EigenSession",
    "SolverConfig",
    "EigenResult",
    "__version__",
]
