"""repro: mixed-precision multi-device Top-K sparse eigensolver framework."""
__version__ = "1.0.0"
