"""Phase 2 of the paper's eigensolver: the Jacobi eigenvalue algorithm.

The Lanczos phase reduces the n x n problem to a K x K tridiagonal matrix T
(K ~ 8..32).  The paper solves T with cyclic Jacobi rotations *on the host
CPU*, because a 24x24 matrix cannot saturate a GPU (their §III-B, Fig. 1 D).
We keep both placements:

  * ``jacobi_eigh_host`` — NumPy, the paper-faithful host placement used by
    the standalone driver;
  * ``jacobi_eigh``      — pure-JAX (``lax.while_loop`` over sweeps,
    ``lax.fori_loop`` over the fixed (p, q) cycle), used when the whole
    solver must live inside one jit/dry-run program.

Both implement classical *cyclic-by-row* Jacobi on the dense symmetric matrix
and return eigenpairs sorted by |lambda| descending (Top-K semantics: the
paper's "largest in modulo").
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["jacobi_eigh", "jacobi_eigh_host", "tridiag_to_dense"]


def tridiag_to_dense(alpha: jax.Array, beta: jax.Array) -> jax.Array:
    """Build dense symmetric tridiagonal T from Lanczos alpha (k,), beta (k-1,)."""
    k = alpha.shape[0]
    t = jnp.diag(alpha)
    if k > 1:
        t = t + jnp.diag(beta, 1) + jnp.diag(beta, -1)
    return t


def _rotation(app, aqq, apq, eps):
    """Jacobi rotation (c, s) zeroing A[p,q]; identity when |apq| < eps."""
    tau = (aqq - app) / (2.0 * jnp.where(jnp.abs(apq) < eps, 1.0, apq))
    t = jnp.sign(tau) / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
    c = 1.0 / jnp.sqrt(1.0 + t * t)
    s = t * c
    skip = jnp.abs(apq) < eps
    return jnp.where(skip, 1.0, c), jnp.where(skip, 0.0, s)


@partial(jax.jit, static_argnames=("max_sweeps",))
def jacobi_eigh(
    a: jax.Array, max_sweeps: int = 30, tol: float = 0.0
) -> Tuple[jax.Array, jax.Array]:
    """Cyclic Jacobi eigendecomposition of a symmetric matrix (pure JAX).

    Returns (eigenvalues (k,), eigenvectors (k, k) column-wise), sorted by
    |lambda| descending.
    """
    k = a.shape[0]
    dtype = a.dtype
    eps = jnp.asarray(np.finfo(np.dtype(dtype)).eps, dtype) * 10
    tol = jnp.asarray(tol, dtype)
    if k == 1:
        return a[0:1, 0], jnp.ones((1, 1), dtype)

    ps, qs = np.triu_indices(k, 1)
    ps = jnp.asarray(ps, jnp.int32)
    qs = jnp.asarray(qs, jnp.int32)

    def rotate(carry, idx):
        a, v = carry
        p, q = ps[idx], qs[idx]
        app, aqq, apq = a[p, p], a[q, q], a[p, q]
        c, s = _rotation(app, aqq, apq, eps)
        # Row/col updates: A <- J^T A J, V <- V J with J = G(p, q, c, s).
        ap, aq = a[p, :], a[q, :]
        a = a.at[p, :].set(c * ap - s * aq)
        a = a.at[q, :].set(s * ap + c * aq)
        ap, aq = a[:, p], a[:, q]
        a = a.at[:, p].set(c * ap - s * aq)
        a = a.at[:, q].set(s * ap + c * aq)
        vp, vq = v[:, p], v[:, q]
        v = v.at[:, p].set(c * vp - s * vq)
        v = v.at[:, q].set(s * vp + c * vq)
        return (a, v), None

    def sweep(state):
        a, v, it = state
        (a, v), _ = jax.lax.scan(rotate, (a, v), jnp.arange(ps.shape[0]))
        return a, v, it + 1

    def offdiag(a):
        return jnp.sqrt(jnp.sum((a - jnp.diag(jnp.diag(a))) ** 2))

    def cond(state):
        a, _, it = state
        return jnp.logical_and(it < max_sweeps, offdiag(a) > jnp.maximum(tol, eps))

    a0 = a.astype(dtype)
    v0 = jnp.eye(k, dtype=dtype)
    a_f, v_f, _ = jax.lax.while_loop(cond, sweep, (a0, v0, jnp.asarray(0)))
    evals = jnp.diag(a_f)
    order = jnp.argsort(-jnp.abs(evals))
    return evals[order], v_f[:, order]


def jacobi_eigh_host(
    a: np.ndarray, max_sweeps: int = 30, tol: float = 1e-14
) -> Tuple[np.ndarray, np.ndarray]:
    """NumPy cyclic Jacobi — the paper's host-CPU placement of phase 2."""
    a = np.array(a, dtype=np.float64, copy=True)
    k = a.shape[0]
    v = np.eye(k)
    for _ in range(max_sweeps):
        off = np.sqrt(np.sum(np.tril(a, -1) ** 2) * 2)
        if off <= tol:
            break
        for p in range(k - 1):
            for q in range(p + 1, k):
                apq = a[p, q]
                if abs(apq) < 1e-300:
                    continue
                tau = (a[q, q] - a[p, p]) / (2.0 * apq)
                if abs(tau) > 1e150:  # rotation angle ~ 1/(2 tau) -> identity
                    continue
                t = np.sign(tau) / (abs(tau) + np.sqrt(1.0 + tau * tau)) if tau != 0 else 1.0
                c = 1.0 / np.sqrt(1.0 + t * t)
                s = t * c
                ap = a[p, :].copy()
                aq = a[q, :].copy()
                a[p, :] = c * ap - s * aq
                a[q, :] = s * ap + c * aq
                ap = a[:, p].copy()
                aq = a[:, q].copy()
                a[:, p] = c * ap - s * aq
                a[:, q] = s * ap + c * aq
                vp = v[:, p].copy()
                vq = v[:, q].copy()
                v[:, p] = c * vp - s * vq
                v[:, q] = s * vp + c * vq
    evals = np.diag(a).copy()
    order = np.argsort(-np.abs(evals))
    return evals[order], v[:, order]
