"""Result-quality metrics used in the paper's evaluation (§IV-D, Fig. 3b/4).

* ``reconstruction_error`` — mean L2 norm of ``M x - lambda x`` over the K
  eigenpairs (the paper's "L2 error", computed from the eigenvalue
  definition; their headline: below 1e-5 on average).
* ``pairwise_orthogonality_deg`` — mean angle in degrees between eigenvector
  pairs (exactly 90 for perfect results; the paper reports ~2 degrees of
  improvement from re-orthogonalization).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .operators import LinearOperator

__all__ = ["reconstruction_error", "pairwise_orthogonality_deg", "eigsh_reference"]


def reconstruction_error(op: LinearOperator, evals, evecs, accum_dtype=jnp.float32) -> float:
    """Mean over j of || M x_j - lambda_j x_j ||_2 / || x_j ||_2."""
    errs = []
    evals = np.asarray(evals, dtype=np.float64)
    for j in range(evals.shape[0]):
        x = evecs[:, j]
        mx = np.asarray(op.matvec(x, accum_dtype=accum_dtype), dtype=np.float64)
        xs = np.asarray(x, dtype=np.float64)
        nrm = np.linalg.norm(xs)
        errs.append(np.linalg.norm(mx - evals[j] * xs) / max(nrm, 1e-300))
    return float(np.mean(errs))


def pairwise_orthogonality_deg(evecs) -> float:
    """Mean pairwise angle (degrees) between eigenvector columns."""
    x = np.asarray(evecs, dtype=np.float64)
    x = x / np.maximum(np.linalg.norm(x, axis=0, keepdims=True), 1e-300)
    g = x.T @ x
    k = g.shape[0]
    iu = np.triu_indices(k, 1)
    cosines = np.clip(np.abs(g[iu]), 0.0, 1.0)
    return float(np.degrees(np.mean(np.arccos(cosines))))


def eigsh_reference(csr, k: int):
    """ARPACK reference (scipy wraps the same library the paper benchmarks)."""
    import scipy.sparse.linalg as spla

    evals, evecs = spla.eigsh(csr.to_scipy().astype(np.float64), k=k, which="LM")
    order = np.argsort(-np.abs(evals))
    return evals[order], evecs[:, order]
