"""Mixed-precision policies for the Top-K sparse eigensolver.

The paper (Sgherzi et al., 2022, §III-A / §IV-D) parameterizes the solver by a
*dtype triple* — the precision in which vectors are **stored**, the precision
in which the numerically critical reductions (the ``alpha`` dot products, the
``beta`` L2 norms and the re-orthogonalization coefficients) are **computed**,
and the precision of the **output** eigencomponents.  Their headline result is
that FDF (store f32 / compute f64 / output f32) is 50% faster than DDD and 12x
more accurate than FFF.

TPU adaptation (see DESIGN.md §3): TPUs have no fast f64, so the TPU-native
ladder is shifted one rung down — bf16/f16 storage with f32 compute — and the
"extra accumulator width" role of f64 is played by *compensated* (Neumaier)
f32 summation, exposed here as ``compensated=True`` policies.  The f64 paths
remain available on CPU (JAX x64) and are used to reproduce the paper's
Fig. 4 exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "PrecisionPolicy",
    "FFF",
    "FDF",
    "DDD",
    "BFF",
    "HFF",
    "FCF",
    "BCF",
    "POLICIES",
    "x64_enabled",
]


def x64_enabled() -> bool:
    return bool(jax.config.read("jax_enable_x64"))


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """(storage, compute, output) dtype triple, the paper's precision knob.

    Attributes:
      name: short id, e.g. ``"FDF"``.
      storage: dtype in which the Lanczos basis V and carried vectors are kept.
      compute: dtype in which SpMV accumulation and the alpha/beta/reorth
        reductions are performed (the paper's "intermediate operations").
      output: dtype of the returned eigenvalues/eigenvectors.
      compensated: if True, scalar reductions additionally use Neumaier
        compensated summation in the ``compute`` dtype (TPU-native analogue
        of the paper's f64 accumulation; beyond-paper feature).
    """

    name: str
    storage: Any
    compute: Any
    output: Any
    compensated: bool = False

    def effective(self) -> "PrecisionPolicy":
        """Downgrade f64 members to f32 when x64 is disabled (with a note)."""
        if x64_enabled():
            return self

        def _eff(dt):
            return jnp.float32 if jnp.dtype(dt) == jnp.dtype(jnp.float64) else dt

        if (
            jnp.dtype(self.storage) == jnp.dtype(jnp.float64)
            or jnp.dtype(self.compute) == jnp.dtype(jnp.float64)
            or jnp.dtype(self.output) == jnp.dtype(jnp.float64)
        ):
            return dataclasses.replace(
                self,
                name=self.name + "(x32!)",
                storage=_eff(self.storage),
                compute=_eff(self.compute),
                output=_eff(self.output),
            )
        return self

    def short(self) -> str:
        return self.name


# Paper's three configurations (their §IV-D, Fig. 4).
FFF = PrecisionPolicy("FFF", jnp.float32, jnp.float32, jnp.float32)
FDF = PrecisionPolicy("FDF", jnp.float32, jnp.float64, jnp.float32)
DDD = PrecisionPolicy("DDD", jnp.float64, jnp.float64, jnp.float64)

# TPU-native ladder (DESIGN.md §3): bf16/f16 storage, f32 compute; the
# compensated variants recover the wide-accumulator role of f64.
BFF = PrecisionPolicy("BFF", jnp.bfloat16, jnp.float32, jnp.float32)
HFF = PrecisionPolicy("HFF", jnp.float16, jnp.float32, jnp.float32)
FCF = PrecisionPolicy("FCF", jnp.float32, jnp.float32, jnp.float32, compensated=True)
BCF = PrecisionPolicy("BCF", jnp.bfloat16, jnp.float32, jnp.float32, compensated=True)

POLICIES = {p.name: p for p in (FFF, FDF, DDD, BFF, HFF, FCF, BCF)}


def compensated_sum(x: jax.Array, dtype) -> jax.Array:
    """Neumaier (improved Kahan) compensated summation of a 1-D array.

    Sequential over ``x`` in chunks: each chunk is summed natively (one rounding
    per chunk) and chunk totals are combined with Neumaier compensation.  This
    bounds the error like a ~2x-wider accumulator at a small bandwidth cost —
    the TPU stand-in for the paper's f64 accumulation.
    """
    x = x.astype(dtype)
    n = x.shape[0]
    chunk = 256
    pad = (-n) % chunk
    xp = jnp.pad(x, (0, pad))
    parts = xp.reshape(-1, chunk).sum(axis=1)  # one native sum per chunk

    def body(carry, p):
        s, c = carry
        t = s + p
        # Neumaier: pick compensation direction by magnitude.
        comp = jnp.where(
            jnp.abs(s) >= jnp.abs(p), (s - t) + p, (p - t) + s
        )
        return (t, c + comp), None

    (s, c), _ = jax.lax.scan(body, (jnp.zeros((), dtype), jnp.zeros((), dtype)), parts)
    return s + c


def reduce_sum(x: jax.Array, policy: PrecisionPolicy) -> jax.Array:
    """Policy-directed sum reduction (the paper's alpha/beta accumulators)."""
    if policy.compensated:
        return compensated_sum(x.reshape(-1), policy.compute)
    return jnp.sum(x.astype(policy.compute))


def dot(a: jax.Array, b: jax.Array, policy: PrecisionPolicy) -> jax.Array:
    """Mixed-precision dot product: storage-dtype inputs, compute-dtype accum."""
    prod = a.astype(policy.compute) * b.astype(policy.compute)
    return reduce_sum(prod, policy)


def norm2(a: jax.Array, policy: PrecisionPolicy) -> jax.Array:
    return jnp.sqrt(dot(a, a, policy))
