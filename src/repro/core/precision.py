"""Mixed-precision policies for the Top-K sparse eigensolver.

The paper (Sgherzi et al., 2022, §III-A / §IV-D) parameterizes the solver by a
*dtype triple* — the precision in which vectors are **stored**, the precision
in which the numerically critical reductions (the ``alpha`` dot products, the
``beta`` L2 norms and the re-orthogonalization coefficients) are **computed**,
and the precision of the **output** eigencomponents.  Their headline result is
that FDF (store f32 / compute f64 / output f32) is 50% faster than DDD and 12x
more accurate than FFF.

TPU adaptation (see DESIGN.md §3): TPUs have no fast f64, so the TPU-native
ladder is shifted one rung down — bf16/f16 storage with f32 compute — and the
"extra accumulator width" role of f64 is played by *compensated* (Neumaier)
f32 summation, exposed here as ``compensated=True`` policies.  The f64 paths
remain available on CPU (JAX x64) and are used to reproduce the paper's
Fig. 4 exactly.

Per-phase compute dtypes (beyond-paper): the solver's "intermediate
operations" are not one phase but four — the SpMV accumulator, the
alpha/beta reductions, the re-orthogonalization projections, and the
Ritz/restart arithmetic — and they tolerate narrow formats very differently
(Hunhold et al. 2025: reorthogonalization and the tridiagonal solve are the
accuracy-critical ones).  ``PrecisionPolicy`` therefore carries optional
per-phase overrides of the ``compute`` dtype (:data:`PHASES`,
:meth:`PrecisionPolicy.with_phases`); ``None`` means "inherit ``compute``",
so a policy with no overrides behaves — bit-identically — like the uniform
triple.  ``phase_op_counts`` provides the model-based per-dtype operation
audit surfaced in ``EigenResult.partition["spmv"]["precision"]``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

__all__ = [
    "PHASES",
    "PrecisionPolicy",
    "FFF",
    "FDF",
    "DDD",
    "BFF",
    "HFF",
    "FCF",
    "BCF",
    "POLICIES",
    "x64_enabled",
    "auto_ladder",
    "phase_op_counts",
    "assert_phase_count_parity",
]

# The four compute phases of one Lanczos-based solve, in hot-loop order:
#   spmv       — the SpMV accumulator (y = A @ x partial sums);
#   alpha_beta — the alpha dot products and beta L2 norms (sync points A/B);
#   reorth     — the re-orthogonalization coefficient dots + subtraction (C);
#   ritz       — Ritz extraction / thick-restart arithmetic (X = V^T W).
PHASES = ("spmv", "alpha_beta", "reorth", "ritz")

# Short dtype spellings accepted by ``with_phases`` / phase-override dicts.
_DTYPE_ALIASES = {
    "f16": "float16",
    "f32": "float32",
    "f64": "float64",
    "bf16": "bfloat16",
}


def _parse_dtype(dt):
    """Accept a dtype object or a (shorthand) name; normalize via jnp.dtype."""
    if isinstance(dt, str):
        dt = _DTYPE_ALIASES.get(dt.lower(), dt.lower())
    try:
        return jnp.dtype(dt)
    except TypeError as e:
        raise ValueError(f"unparseable phase dtype {dt!r}") from e


def x64_enabled() -> bool:
    return bool(jax.config.read("jax_enable_x64"))


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """(storage, compute, output) dtype triple, the paper's precision knob.

    Attributes:
      name: short id, e.g. ``"FDF"``.
      storage: dtype in which the Lanczos basis V and carried vectors are kept.
      compute: dtype in which SpMV accumulation and the alpha/beta/reorth
        reductions are performed (the paper's "intermediate operations").
      output: dtype of the returned eigenvalues/eigenvectors.
      compensated: if True, scalar reductions additionally use Neumaier
        compensated summation in the ``compute`` dtype (TPU-native analogue
        of the paper's f64 accumulation; beyond-paper feature).
      spmv / alpha_beta / reorth / ritz: optional per-phase overrides of
        ``compute`` (see :data:`PHASES`); ``None`` inherits ``compute``, so
        a policy without overrides is exactly the paper's uniform triple.
        Build them with :meth:`with_phases`.
    """

    name: str
    storage: Any
    compute: Any
    output: Any
    compensated: bool = False
    # Per-phase overrides of ``compute`` (None = inherit).  See PHASES.
    spmv: Any = None
    alpha_beta: Any = None
    reorth: Any = None
    ritz: Any = None

    def phase_dtype(self, phase: str):
        """Compute dtype of one solver phase (the override, or ``compute``)."""
        if phase not in PHASES:
            raise ValueError(f"unknown precision phase {phase!r}; valid phases: {PHASES}")
        override = getattr(self, phase)
        return self.compute if override is None else override

    def phase_map(self) -> Dict[str, str]:
        """{phase: dtype name} of every compute phase — the provenance record
        surfaced in ``EigenResult.partition["spmv"]["precision"]``."""
        return {ph: jnp.dtype(self.phase_dtype(ph)).name for ph in PHASES}

    def is_uniform(self) -> bool:
        """True when every phase runs in the plain ``compute`` dtype."""
        cdt = jnp.dtype(self.compute)
        return all(
            getattr(self, ph) is None or jnp.dtype(getattr(self, ph)) == cdt
            for ph in PHASES
        )

    def with_phases(self, **overrides) -> "PrecisionPolicy":
        """New policy with per-phase compute dtypes, e.g.
        ``FDF.with_phases(reorth="f32")`` (alpha/beta stay f64).  Unknown
        phase names are a named error listing the valid phases; dtypes may be
        objects or (shorthand) names.  ``None`` clears an override."""
        bad = sorted(set(overrides) - set(PHASES))
        if bad:
            raise ValueError(
                f"unknown precision phase(s) {bad}; valid phases: {PHASES}"
            )
        parsed = {
            ph: (None if dt is None else _parse_dtype(dt)) for ph, dt in overrides.items()
        }
        new = dataclasses.replace(self, **parsed)
        tags = ",".join(
            f"{ph}={jnp.dtype(getattr(new, ph)).name}"
            for ph in PHASES
            if getattr(new, ph) is not None
        )
        base = self.name.split("[")[0]
        return dataclasses.replace(new, name=f"{base}[{tags}]" if tags else base)

    def effective(self) -> "PrecisionPolicy":
        """Downgrade f64 members to f32 when x64 is disabled (with a note)."""
        if x64_enabled():
            return self

        f64 = jnp.dtype(jnp.float64)

        def _eff(dt):
            return jnp.float32 if jnp.dtype(dt) == f64 else dt

        members = [self.storage, self.compute, self.output] + [
            getattr(self, ph) for ph in PHASES if getattr(self, ph) is not None
        ]
        if any(jnp.dtype(dt) == f64 for dt in members):
            return dataclasses.replace(
                self,
                name=self.name + "(x32!)",
                storage=_eff(self.storage),
                compute=_eff(self.compute),
                output=_eff(self.output),
                **{
                    ph: _eff(getattr(self, ph))
                    for ph in PHASES
                    if getattr(self, ph) is not None
                },
            )
        return self

    def short(self) -> str:
        return self.name


# Paper's three configurations (their §IV-D, Fig. 4).
FFF = PrecisionPolicy("FFF", jnp.float32, jnp.float32, jnp.float32)
FDF = PrecisionPolicy("FDF", jnp.float32, jnp.float64, jnp.float32)
DDD = PrecisionPolicy("DDD", jnp.float64, jnp.float64, jnp.float64)

# TPU-native ladder (DESIGN.md §3): bf16/f16 storage, f32 compute; the
# compensated variants recover the wide-accumulator role of f64.
BFF = PrecisionPolicy("BFF", jnp.bfloat16, jnp.float32, jnp.float32)
HFF = PrecisionPolicy("HFF", jnp.float16, jnp.float32, jnp.float32)
FCF = PrecisionPolicy("FCF", jnp.float32, jnp.float32, jnp.float32, compensated=True)
BCF = PrecisionPolicy("BCF", jnp.bfloat16, jnp.float32, jnp.float32, compensated=True)

POLICIES = {p.name: p for p in (FFF, FDF, DDD, BFF, HFF, FCF, BCF)}


def compensated_sum(x: jax.Array, dtype) -> jax.Array:
    """Neumaier (improved Kahan) compensated summation of a 1-D array.

    Sequential over ``x`` in chunks: each chunk is summed natively (one rounding
    per chunk) and chunk totals are combined with Neumaier compensation.  This
    bounds the error like a ~2x-wider accumulator at a small bandwidth cost —
    the TPU stand-in for the paper's f64 accumulation.
    """
    x = x.astype(dtype)
    n = x.shape[0]
    chunk = 256
    pad = (-n) % chunk
    xp = jnp.pad(x, (0, pad))
    parts = xp.reshape(-1, chunk).sum(axis=1)  # one native sum per chunk

    def body(carry, p):
        s, c = carry
        t = s + p
        # Neumaier: pick compensation direction by magnitude.
        comp = jnp.where(
            jnp.abs(s) >= jnp.abs(p), (s - t) + p, (p - t) + s
        )
        return (t, c + comp), None

    (s, c), _ = jax.lax.scan(body, (jnp.zeros((), dtype), jnp.zeros((), dtype)), parts)
    return s + c


def reduce_sum(x: jax.Array, policy: PrecisionPolicy) -> jax.Array:
    """Policy-directed sum reduction (the paper's alpha/beta accumulators)."""
    if policy.compensated:
        return compensated_sum(x.reshape(-1), policy.compute)
    return jnp.sum(x.astype(policy.compute))


def dot(a: jax.Array, b: jax.Array, policy: PrecisionPolicy) -> jax.Array:
    """Mixed-precision dot product: storage-dtype inputs, compute-dtype accum."""
    prod = a.astype(policy.compute) * b.astype(policy.compute)
    return reduce_sum(prod, policy)


def norm2(a: jax.Array, policy: PrecisionPolicy) -> jax.Array:
    return jnp.sqrt(dot(a, a, policy))


# --------------------------- accuracy-driven auto ----------------------------

# Escalation ladder for ``policy="auto"``: cheapest first.  Each rung is a
# real policy from POLICIES; the selector probes rungs in order and stops at
# the first whose measured residuals meet the requested tol.  The f64 rungs
# only exist where x64 does (they would silently alias FFF otherwise).
_AUTO_LADDER_X64 = ("BFF", "FFF", "FCF", "FDF", "DDD")
_AUTO_LADDER_X32 = ("BFF", "FFF", "FCF")


def auto_ladder() -> tuple:
    """Policy names ``policy="auto"`` escalates through, cheapest first,
    capped by :func:`x64_enabled` (no point escalating to a rung that the
    x32 downgrade folds back onto an earlier one)."""
    return _AUTO_LADDER_X64 if x64_enabled() else _AUTO_LADDER_X32


# Fraction of the stored basis each re-orthogonalization mode touches per
# pass (the paper's parity scheme halves it; CGS2 runs two full passes).
_REORTH_PASS_FRAC = {"none": 0.0, "half": 0.5, "half_alt": 0.5, "full": 1.0, "full2": 2.0}
# Fraction of the basis each mode's *kernel* actually sweeps.  The parity
# modes are implemented as one masked full-width matmul per iteration (the
# mask zeroes the coefficients, not the work), so their executed fraction is
# 1.0 — the 0.5 above models data *touched*, the quantity the paper's parity
# argument is about.  The jaxpr-measured audit counts executed ops, so the
# parity assertion compares against this table.
_REORTH_EXEC_FRAC = {"none": 0.0, "half": 1.0, "half_alt": 1.0, "full": 1.0, "full2": 2.0}

# Element ops of one cyclic-Jacobi sweep on an m x m matrix: m(m-1)/2
# rotations, each applying 6 axpy-like updates of length m (two rows, two
# cols, two eigenvector cols at 3 ops/element) => ~9 m^3 per sweep.
_JACOBI_SWEEP_OPS = 9.0


def phase_op_counts(
    policy: PrecisionPolicy,
    *,
    n: int,
    nnz: int,
    m: int,
    k: int,
    reorth: str = "half",
    jacobi: str = "host",
    jacobi_sweeps: float = 6.0,
    executed: bool = False,
) -> Dict[str, int]:
    """Model-based count of element operations per compute dtype for one
    solve — the audit behind the per-phase precision claim ("this split
    reduced f64 work"), surfaced in ``partition["spmv"]["precision"]``.

    Counts are the leading terms of the solver's arithmetic, attributed to
    the phase that executes them: ``m * nnz`` SpMV accumulations, ``2 m n``
    alpha/beta reduction elements, ``2 f m^2 n`` re-orthogonalization
    elements (``f`` = the mode's basis fraction per pass; coefficient dot +
    subtraction), and ``n m k`` back-projection elements.  An *estimate* of
    work by dtype, not a hardware counter.

    ``jacobi="device"`` additionally attributes the on-device Jacobi
    eigensolve of the m x m projected matrix to the ritz phase
    (``~9 m^3`` per sweep x ``jacobi_sweeps``); the host placement runs in
    NumPy and contributes no device ops.  Before the jaxpr audit existed the
    model silently attributed zero ops to device Jacobi — the divergence the
    precision-flow verifier was built to catch.

    ``executed=True`` switches the reorth term from the algorithmic
    touched-data fractions to the fractions the masked kernels actually
    execute (see ``_REORTH_EXEC_FRAC``) and counts one Jacobi sweep (a jaxpr
    records a ``while`` body once) — the convention under which the counts
    are comparable to the verifier's ``ops_by_dtype_measured``.
    """
    p = policy.effective()
    counts: Dict[str, int] = {}

    def add(phase: str, ops: float) -> None:
        name = jnp.dtype(p.phase_dtype(phase)).name
        counts[name] = counts.get(name, 0) + int(ops)

    table = _REORTH_EXEC_FRAC if executed else _REORTH_PASS_FRAC
    frac = table.get(reorth, 1.0)
    add("spmv", m * nnz)
    add("alpha_beta", 2 * m * n)
    add("reorth", 2.0 * frac * m * m * n)
    add("ritz", n * m * k)
    if jacobi == "device":
        sweeps = 1.0 if executed else jacobi_sweeps
        add("ritz", _JACOBI_SWEEP_OPS * sweeps * m**3)
    return counts


def assert_phase_count_parity(
    model: Dict[str, int],
    measured: Dict[str, int],
    *,
    ratio: float = 8.0,
    min_share: float = 0.02,
    context: str = "",
) -> None:
    """Tripwire pinning the model to the jaxpr-measured reality.

    The model and the trace count with different granularity (a matmul is
    ``MNK`` macs in the model, multiply + reduce eqns in the trace), so this
    does not demand equality; it demands the same *story*: every dtype
    carrying a non-trivial share (``min_share``) of the work appears on both
    sides, and per-dtype totals agree within a factor of ``ratio``.  A wrong
    phase-dtype attribution (the device-Jacobi bug this was added for) moves
    whole ``m^3``/``m^2 n`` terms between dtypes and trips either check long
    before any constant-factor slack matters.
    """
    problems = []
    total_meas = sum(measured.values()) or 1
    total_model = sum(model.values()) or 1
    for dt, cnt in sorted(measured.items()):
        if cnt / total_meas >= min_share and model.get(dt, 0) == 0:
            problems.append(
                f"measured dtype {dt} ({cnt} ops, {cnt / total_meas:.0%} of trace)"
                " is absent from the model"
            )
    for dt, cnt in sorted(model.items()):
        if cnt / total_model >= min_share and measured.get(dt, 0) == 0:
            problems.append(
                f"model dtype {dt} ({cnt} ops, {cnt / total_model:.0%} of model)"
                " never appears in the trace"
            )
    for dt in sorted(set(model) & set(measured)):
        if model[dt] == 0 or measured[dt] == 0:
            continue
        r = measured[dt] / model[dt]
        if not (1.0 / ratio <= r <= ratio):
            problems.append(
                f"{dt}: measured/model ratio {r:.3g} outside"
                f" [{1.0 / ratio:.3g}, {ratio:.3g}]"
                f" (measured={measured[dt]}, model={model[dt]})"
            )
    if problems:
        where = f" [{context}]" if context else ""
        raise AssertionError(
            f"phase_op_counts parity failure{where}:\n  " + "\n  ".join(problems)
        )
