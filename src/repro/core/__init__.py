"""Core: the paper's Top-K sparse eigensolver engines (Lanczos + Jacobi).

User-facing entrypoint: ``repro.api.eigsh``.  The ``topk_eigs*`` names here
are deprecated shims kept for compatibility.
"""

from .eigensolver import EigResult, FixedSolveOutput, solve_fixed, topk_eigs
from .jacobi import jacobi_eigh, jacobi_eigh_host, tridiag_to_dense
from .lanczos import LanczosResult, lanczos_tridiag
from .operators import (
    CallableOperator,
    ChunkedOperator,
    DenseOperator,
    HvpOperator,
    LinearOperator,
    SparseOperator,
    make_operator,
)
from .partition import PartitionedMatrix, nnz_balanced_splits, partition_matrix
from .precision import (
    BCF,
    BFF,
    DDD,
    FCF,
    FDF,
    FFF,
    HFF,
    PHASES,
    POLICIES,
    PrecisionPolicy,
    assert_phase_count_parity,
    auto_ladder,
    phase_op_counts,
)
from .restarted import RestartedSolveOutput, solve_restarted, topk_eigs_restarted
