"""Core: the paper's Top-K sparse eigensolver (Lanczos + Jacobi)."""

from .eigensolver import EigResult, topk_eigs
from .jacobi import jacobi_eigh, jacobi_eigh_host, tridiag_to_dense
from .lanczos import LanczosResult, lanczos_tridiag
from .operators import (
    ChunkedOperator,
    DenseOperator,
    HvpOperator,
    LinearOperator,
    SparseOperator,
    make_operator,
)
from .partition import PartitionedMatrix, nnz_balanced_splits, partition_matrix
from .precision import BCF, BFF, DDD, FCF, FDF, FFF, HFF, POLICIES, PrecisionPolicy
from .restarted import topk_eigs_restarted
