"""Thick-restart Lanczos (Wu & Simon 2000) — beyond-paper accuracy feature.

The paper runs exactly K Lanczos steps (their K is both subspace size and
output count), which caps attainable accuracy by Krylov truncation.  Their
CPU baseline, ARPACK, *restarts* instead: it compresses the subspace to the
best Ritz directions and continues, converging to machine-precision
residuals with bounded memory.  This module adds the same capability on top
of our mixed-precision substrate:

  * subspace of m vectors (m >= k + a few), restart keeps the top-k Ritz
    vectors "thick" + the residual direction;
  * the projected matrix after a restart is arrowhead-plus-tridiagonal,
    handled densely (m <= 64) by the same Jacobi phase-2 as the paper;
  * per-pair convergence test: |beta_m * W[m-1, i]| <= tol * |theta_i|
    (the classical Ritz residual bound — no extra SpMV needed);
  * all vector arithmetic honors the PrecisionPolicy (storage vs compute),
    so the paper's FFF/FDF/DDD study extends to restarted solves.

Host-orchestrated restarts around jitted vector kernels: the right split for
a latency-insensitive convergence loop (identical placement to the paper's
host-side Jacobi phase).

This module is an *engine*: the user-facing entrypoint is ``repro.api.eigsh``
with ``backend="restarted"`` (or any ``tol=``, which auto-selects it).
``topk_eigs_restarted`` remains as a deprecated shim.
"""

from __future__ import annotations

import time
import warnings
from typing import NamedTuple, Optional

import numpy as np

import jax
import jax.numpy as jnp

from ..testing import faults as _faults
from .eigensolver import EigResult
from .jacobi import jacobi_eigh_host
from .lanczos import LanczosResult, NumericalBreakdown
from .operators import LinearOperator
from .precision import FDF, PrecisionPolicy

__all__ = [
    "RestartedSolveOutput",
    "restart_kernels",
    "ritz_project",
    "solve_restarted",
    "topk_eigs_restarted",
]


def restart_kernels(policy: PrecisionPolicy):
    """The restarted engine's jitted vector kernels ``(dot, orth)``.

    Module-level (rather than closures inside :func:`solve_restarted`) so the
    precision-flow verifier (``repro.analysis``) traces the *same* callables
    the engine executes — the declared phase map is checked against the real
    lowering, not a re-implementation.
    """
    policy = policy.effective()
    cdt = policy.compute
    abdt = policy.phase_dtype("alpha_beta")
    rdt = policy.phase_dtype("reorth")

    @jax.jit
    def dot(a, b):
        return jnp.sum(a.astype(abdt) * b.astype(abdt)).astype(cdt)

    @jax.jit
    def orth(u, basis, nvalid_mask):
        coeffs = (basis.astype(rdt) @ u.astype(rdt)) * nvalid_mask.astype(rdt)
        return (u.astype(rdt) - coeffs @ basis.astype(rdt)).astype(cdt)

    return dot, orth


def ritz_project(basis: jax.Array, wk: jax.Array, policy: PrecisionPolicy, out_dtype=None):
    """Ritz back-projection ``V^T @ W_k`` in the policy's ritz phase dtype.

    Shared by the restart compression, the final eigenvector assembly, and
    the precision-flow verifier's ritz-phase trace.
    """
    rzdt = policy.phase_dtype("ritz")
    x = basis.astype(rzdt).T @ wk.astype(rzdt)
    return x.astype(out_dtype if out_dtype is not None else policy.output)


class RestartedSolveOutput(NamedTuple):
    """Raw engine output consumed by the ``eigsh`` frontend."""

    eigenvalues: jax.Array  # (k,) output dtype
    eigenvectors: jax.Array  # (n, k) output dtype
    residuals: np.ndarray  # (k,) float64 — final Ritz residual bounds
    eigenvalues_f64: np.ndarray  # (k,) float64 — pre-output-cast, for tol checks
    tridiag: LanczosResult
    iterations: int  # total Lanczos steps across all restarts
    restarts: int  # restarts actually performed
    timings: dict


def solve_restarted(
    op: LinearOperator,
    k: int,
    policy: PrecisionPolicy = FDF,
    m: int | None = None,
    max_restarts: int = 30,
    tol: float = 1e-8,
    seed: int = 0,
    v1: Optional[jax.Array] = None,
    probe: bool = True,
    checkpoint=None,
) -> RestartedSolveOutput:
    """Top-k eigenpairs by |lambda| with restarts until the Ritz residual
    bound satisfies ``tol`` (relative) for every pair.

    ``probe`` enables the in-loop health check: alpha/beta are already
    Python floats here, so non-finite values and beta underflow raise a
    typed :class:`NumericalBreakdown` at the offending step for free.

    ``checkpoint`` is a ``(store, token)`` pair (see
    :class:`~repro.serving.store.SolveCheckpoint`): the full restart state
    (basis block, projected matrix, arrow border, next start vector,
    counters) is snapshotted after every completed compression, and a rerun
    with the same token resumes from the last completed cycle bit-identically
    — each cycle's fill loop depends only on that state, never on the
    original ``v1``.
    """
    policy = policy.effective()
    cdt, sdt = policy.compute, policy.storage
    rzdt = policy.phase_dtype("ritz")  # Ritz/restart arithmetic phase
    n = op.n
    m = m or max(2 * k, k + 8)
    assert m > k + 1, "subspace must exceed k by at least 2"
    if max_restarts < 1:
        raise ValueError(f"max_restarts must be >= 1, got {max_restarts}")
    mv = op.bound_matvec(policy)
    _dot, _orth = restart_kernels(policy)

    t0 = time.perf_counter()
    if v1 is None:
        rng = np.random.default_rng(seed)
        v = jnp.asarray(rng.standard_normal(n), dtype=cdt)
    else:
        v = jnp.asarray(v1, dtype=cdt)
    v = v / jnp.sqrt(_dot(v, v))

    basis = jnp.zeros((m, n), sdt)
    t_hat = np.zeros((m, m))
    nkeep = 0  # locked Ritz vectors at the head of the basis
    s_border = np.zeros(0)  # arrow column entries for the kept block
    evals = w = None
    steps = 0
    restarts = 0
    resid = np.zeros(k)
    breakdown_tiny = float(jnp.finfo(cdt).tiny) * 1e3
    pol_name = getattr(policy, "name", None) or str(policy)

    start_cycle = 0
    if checkpoint is not None:
        store, token = checkpoint
        state = store.load(token)
        if (
            state is not None
            and state.get("engine") == "restarted"
            and int(state.get("n", -1)) == n
            and int(state.get("m", -1)) == m
            and int(state.get("k", -1)) == k
        ):
            basis = jnp.asarray(state["basis"], sdt)
            t_hat = np.asarray(state["t_hat"], np.float64)
            s_border = np.asarray(state["s_border"], np.float64)
            v = jnp.asarray(state["v"], cdt)
            nkeep = int(state["nkeep"])
            steps = int(state["steps"])
            restarts = int(state["restarts"])
            start_cycle = int(state["cycle"]) + 1

    for cycle in range(start_cycle, max_restarts):
        _faults.check_solve_crash(cycle)
        # --- fill rows nkeep..m-1 with (re-orthogonalized) Lanczos steps ---
        beta_prev = 0.0
        v_prev = jnp.zeros((n,), cdt)
        for i in range(nkeep, m):
            basis = basis.at[i].set(v.astype(sdt))
            u = mv(v.astype(sdt)).astype(cdt)
            u = _faults.tap_spmv(u, i)
            alpha = float(_dot(v, u))
            if probe and not np.isfinite(alpha):
                raise NumericalBreakdown("nonfinite", i, pol_name, f"alpha={alpha!r}")
            t_hat[i, i] = alpha
            u = u - alpha * v - beta_prev * v_prev
            if i == nkeep and nkeep > 0:
                # arrowhead coupling to the kept Ritz block
                u = u - jnp.asarray(s_border, cdt) @ basis[:nkeep].astype(cdt)
                t_hat[i, :nkeep] = s_border
                t_hat[:nkeep, i] = s_border
            # full re-orthogonalization (stability: see EXPERIMENTS §Reorth)
            mask = (jnp.arange(m) <= i).astype(cdt)
            u = _orth(u, basis, mask)
            beta = float(jnp.sqrt(jnp.maximum(_dot(u, u), 0.0)))
            beta = float(_faults.tap_beta(beta, i))
            if probe:
                if not np.isfinite(beta):
                    raise NumericalBreakdown("nonfinite", i, pol_name, f"beta={beta!r}")
                if beta <= breakdown_tiny and i < m - 1:
                    raise NumericalBreakdown(
                        "beta_underflow", i, pol_name,
                        f"beta={beta:.3e} <= {breakdown_tiny:.3e}",
                    )
            if i < m - 1:
                t_hat[i, i + 1] = beta
                t_hat[i + 1, i] = beta
            beta_prev, v_prev = beta, v
            v = u / max(beta, 1e-300)
            steps += 1
        beta_m = beta_prev

        # --- Ritz pairs of the projected matrix ---
        evals, w = jacobi_eigh_host(t_hat)  # |lambda|-desc
        resid = np.abs(beta_m * w[m - 1, :k])
        if np.all(resid <= tol * np.maximum(np.abs(evals[:k]), 1e-300)):
            break
        if cycle == max_restarts - 1:
            # Budget exhausted: stop here WITHOUT compressing, so the final
            # projection below uses `w` in the coordinates of the current
            # `basis` (compressing would leave them in different systems).
            break

        # --- thick restart: compress to top-k Ritz vectors + residual dir ---
        restarts += 1
        wk = jnp.asarray(w[:, :k], dtype=rzdt)
        ritz = ritz_project(basis, wk, policy, out_dtype=rzdt).T  # (k, n)
        new_basis = jnp.zeros((m, n), sdt)
        new_basis = new_basis.at[:k].set(ritz.astype(sdt))
        basis = new_basis
        t_hat = np.zeros((m, m))
        t_hat[:k, :k] = np.diag(evals[:k])
        s_border = beta_m * w[m - 1, :k]
        nkeep = k
        # v (the next Lanczos vector) already holds the residual direction

        if checkpoint is not None:
            store, token = checkpoint
            store.save(
                token,
                {
                    "engine": "restarted",
                    "cycle": cycle,
                    "n": n,
                    "m": m,
                    "k": k,
                    "nkeep": nkeep,
                    "steps": steps,
                    "restarts": restarts,
                    "basis": basis,
                    "t_hat": t_hat,
                    "s_border": s_border,
                    "v": v,
                },
            )

    if checkpoint is not None:
        store, token = checkpoint
        store.clear(token)  # completed: the snapshot must not resurrect
    evals_k = jnp.asarray(evals[:k], dtype=policy.output)
    wk = jnp.asarray(w[:, :k], dtype=rzdt)
    x = ritz_project(basis, wk, policy)
    lres = LanczosResult(
        alpha=jnp.asarray(np.diag(t_hat), cdt),
        beta=jnp.asarray(np.diag(t_hat, 1), cdt),
        basis=basis,
        beta_last=jnp.asarray(beta_m, cdt),
    )
    total = time.perf_counter() - t0
    return RestartedSolveOutput(
        eigenvalues=evals_k,
        eigenvectors=x,
        residuals=np.asarray(resid, dtype=np.float64),
        eigenvalues_f64=np.asarray(evals[:k], dtype=np.float64),
        tridiag=lres,
        iterations=steps,
        restarts=restarts,
        timings={"total_s": total},
    )


def topk_eigs_restarted(
    op: LinearOperator,
    k: int,
    policy: PrecisionPolicy = FDF,
    m: int | None = None,
    max_restarts: int = 30,
    tol: float = 1e-8,
    seed: int = 0,
) -> EigResult:
    """Deprecated: use :func:`repro.api.eigsh` with ``tol=``/``backend="restarted"``."""
    warnings.warn(
        "topk_eigs_restarted is deprecated; use "
        "repro.api.eigsh(A, k, backend='restarted', tol=..., subspace=m, ...)",
        DeprecationWarning,
        stacklevel=2,
    )
    from ..api import eigsh

    res = eigsh(
        op,
        k,
        policy=policy,
        backend="restarted",
        tol=tol,
        subspace=m,
        max_restarts=max_restarts,
        seed=seed,
    )
    return EigResult(
        eigenvalues=res.eigenvalues,
        eigenvectors=res.eigenvectors,
        tridiag=res.tridiag,
        wall_time_s=res.timings["total_s"],
    )
