"""Linear operators consumed by the eigensolver.

The paper's solver is matrix-driven (sparse SpMV), but the Lanczos phase only
needs ``y = A @ x``; we expose that as a small operator protocol so the same
solver runs on:

  * explicit sparse matrices (COO segment-sum path, or the Pallas ELL/BSR
    kernels — the paper's case);
  * chunk-streamed matrices whose triplets live in **host** memory and are
    staged to the device chunk-by-chunk (the paper's out-of-core unified
    memory mode, DESIGN.md §3.4);
  * matrix-free Hessian/GGN-vector products of a model loss — the framework
    integration (spectral monitoring of training, DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from ..kernels.engine import SpmvEngine
from ..sparse.formats import (
    CSR,
    DeviceCOO,
    DeviceELL,
    count_conversions,
    to_device_bsr,
    to_device_coo,
    to_device_ell,
    to_device_hybrid,
)
from ..testing import faults as _faults
from .precision import PrecisionPolicy

__all__ = [
    "LinearOperator",
    "DenseOperator",
    "SparseOperator",
    "ChunkedOperator",
    "CallableOperator",
    "HvpOperator",
    "chunk_row_bounds",
    "make_operator",
]


def chunk_row_bounds(indptr: np.ndarray, n: int, chunk_nnz: int) -> list:
    """Row-contiguous chunk bounds holding <= ``chunk_nnz`` non-zeros each
    (single rows larger than the budget get a chunk of their own).  Shared
    by :class:`ChunkedOperator` and the frontend's staging-footprint
    estimate so both reason about the same chunking."""
    starts = [0]
    while starts[-1] < n:
        r0 = starts[-1]
        r1 = int(np.searchsorted(indptr, indptr[r0] + chunk_nnz, side="right")) - 1
        starts.append(min(n, max(r1, r0 + 1)))
    return list(zip(starts[:-1], starts[1:]))


def chunk_rows_pad(rows: int, block_r: int, storage_dtype) -> int:
    """Padded row count of one staged ELL chunk: rows round up to the chunk's
    own row tile — the kernel's ``block_r`` capped at the next power of two
    of the row count (floored at the TPU sublane minimum), so a chunk with
    FEW rows (e.g. a hub row chunked alone) never allocates the full global
    row tile times its huge width.  ``ell_matvec`` adapts its row tile down
    to whatever divides this."""
    min_r = 16 if jnp.dtype(storage_dtype).itemsize == 2 else 8
    np2 = 1 << max(0, max(rows, min_r) - 1).bit_length()  # next pow2 >= rows
    tile = max(min_r, min(block_r, np2))
    return -(-rows // tile) * tile


class LinearOperator:
    """Protocol: symmetric square operator with policy-aware matvec."""

    n: int

    def matvec(self, x: jax.Array, accum_dtype=None) -> jax.Array:
        raise NotImplementedError

    def bound_matvec(self, policy: PrecisionPolicy) -> Callable:
        # The SpMV accumulator runs in its own phase dtype (defaults to the
        # policy's compute dtype); the Lanczos loop rounds the product back
        # to the carried compute dtype at the phase boundary.
        acc = policy.phase_dtype("spmv")

        def mv(x):
            return self.matvec(x, accum_dtype=acc)

        return mv


@dataclasses.dataclass
class DenseOperator(LinearOperator):
    a: jax.Array

    @property
    def n(self) -> int:
        return self.a.shape[0]

    def matvec(self, x, accum_dtype=None):
        acc = accum_dtype or x.dtype
        return self.a.astype(acc) @ x.astype(acc)


@dataclasses.dataclass
class SparseOperator(LinearOperator):
    """Explicit sparse matrix; ``impl`` (or an :class:`SpmvEngine`) picks the
    SpMV execution path.  With an engine attached, the container format and
    tile parameters come from the engine (`kernels/engine.py`)."""

    mat: object  # DeviceCOO | DeviceELL | DeviceBSR
    impl: str = "coo"  # "coo" | "ell" | "ell_kernel" | "bsr_kernel" | "engine"
    engine: Optional[SpmvEngine] = None

    @property
    def n(self) -> int:
        if isinstance(self.mat, tuple):  # blocked-ELL: (val, bcol, n_rows)
            return int(self.mat[2])
        return self.mat.n_rows

    @property
    def spmv_format(self) -> str:
        if self.engine is not None:
            return self.engine.format
        return {"ell_kernel": "ell", "bsr_kernel": "bsr"}.get(self.impl, self.impl)

    def matvec(self, x, accum_dtype=None):
        if self.engine is not None:
            return self.engine.spmv(self.mat, x, accum_dtype=accum_dtype)
        if self.impl in ("coo", "ell"):
            return self.mat.matvec(x, accum_dtype=accum_dtype)
        if self.impl == "ell_kernel":
            from ..kernels import ops as kops

            return kops.spmv_ell(self.mat, x, accum_dtype=accum_dtype)
        if self.impl == "bsr_kernel":
            from ..kernels import ops as kops

            return kops.spmv_bsr(self.mat, x, accum_dtype=accum_dtype)  # mat = (val,bcol,n)
        raise ValueError(f"unknown SpMV impl {self.impl!r}")


class ChunkedOperator(LinearOperator):
    """Out-of-core SpMV: matrix data stays in host NumPy; each matvec streams
    fixed-size chunks to the device and accumulates partial products.

    This reproduces the paper's unified-memory out-of-core mode: at any moment
    at most ``stage_depth + 1`` chunks are device-resident.  On a real TPU the
    staging is host-DRAM -> HBM DMA; here the same code path exercises the
    chunking and double-buffering logic.

    Staging is double-buffered: chunks are *pre-pinned* once at construction
    (host buffers already in the on-device storage dtype, so the per-matvec
    path is a pure ``jax.device_put`` transfer — no repeated dtype/layout
    conversion), and the transfer of chunk ``i+1 .. i+stage_depth`` is issued
    asynchronously while chunk ``i``'s partial SpMV is in flight.  Transfer /
    conversion / residency counters live in ``self.staging`` (surfaced by
    ``eigsh`` in ``EigenResult.partition``).

    With an ELL-format :class:`SpmvEngine` attached, chunks are row ranges
    staged as per-chunk-width ELL tiles (a hub row inflates only its own
    chunk's padding, not every chunk's) and the partial SpMV runs the Pallas
    kernel; otherwise the COO ``segment_sum`` reference path streams
    nnz-sized slices.
    """

    # The Lanczos loop must stay a host loop for this operator: tracing the
    # chunk stream would bake every chunk into one executable as constants,
    # defeating the bounded-residency staging (see lanczos_tridiag(jit=...)).
    prefers_jit = False

    def __init__(
        self,
        csr: CSR,
        chunk_nnz: int = 1 << 20,
        dtype=jnp.float32,
        engine: Optional[SpmvEngine] = None,
        stage_depth: int = 1,
    ):
        self.n = csr.n
        self._dtype = dtype
        self.engine = engine
        self.stage_depth = max(0, int(stage_depth))
        self.spmv_format = engine.format if engine is not None else "coo"
        if self.spmv_format in ("bsr", "hybrid"):
            raise ValueError(
                "ChunkedOperator stages chunks as COO or ELL; per-chunk "
                f"{self.spmv_format.upper()} is not supported (pick format='ell' or 'coo')"
            )
        self.staging = {"conversions": 0, "transfers": 0, "max_resident": 0}
        if self.spmv_format == "ell":
            self._init_ell_chunks(csr, chunk_nnz, dtype, engine)
        else:
            self._init_coo_chunks(csr, chunk_nnz, dtype)

    def _init_coo_chunks(self, csr: CSR, chunk_nnz: int, dtype):
        row = np.repeat(np.arange(csr.n, dtype=np.int32), csr.row_nnz())
        np_dtype = np.dtype(jnp.dtype(dtype))  # bf16 host buffers via ml_dtypes
        self._chunks = []
        nnz = csr.nnz
        for lo in range(0, nnz, chunk_nnz):
            hi = min(lo + chunk_nnz, nnz)
            pad = chunk_nnz - (hi - lo)
            self._chunks.append(
                (
                    np.pad(row[lo:hi], (0, pad)),
                    np.pad(csr.indices[lo:hi], (0, pad)),
                    np.pad(csr.data[lo:hi], (0, pad)).astype(np_dtype),
                )
            )
            self.staging["conversions"] += 1  # host layout/dtype prep: once
        self.num_chunks = len(self._chunks)
        count_conversions(self.num_chunks)

        # One jitted partial-SpMV per instance, keyed on the (static) accum
        # dtype: defining it inside matvec would retrace on every call.
        @partial(jax.jit, static_argnames=("acc",))
        def _partial_spmv(row, col, val, x, y, *, acc):
            prod = val.astype(acc) * jnp.take(x, col).astype(acc)
            return y + jax.ops.segment_sum(prod, row, num_segments=self.n)

        self._partial_spmv = _partial_spmv

    def _init_ell_chunks(self, csr: CSR, chunk_nnz: int, dtype, engine: SpmvEngine):
        indptr, n = csr.indptr, csr.n
        bounds = chunk_row_bounds(indptr, n, chunk_nnz)

        row_nnz = csr.row_nnz()
        np_dtype = np.dtype(jnp.dtype(dtype))  # bf16 host buffers via ml_dtypes

        self._chunks = []
        self._r0s = []
        n_out_pad = 0
        for r0, r1 in bounds:
            lo, hi = int(indptr[r0]), int(indptr[r1])
            local_nnz = row_nnz[r0:r1]
            # Per-chunk width (128-lane aligned) AND per-chunk row padding:
            # a hub row pays for its own chunk only — neither its width nor
            # the global row tile inflates any other chunk, and a few-row
            # hub chunk never allocates block_r x hub_width zeros.
            width = int(max(1, local_nnz.max() if local_nnz.size else 1))
            width = -(-width // 128) * 128
            rows_pad = chunk_rows_pad(r1 - r0, engine.tiles.block_r, dtype)
            rix = np.repeat(np.arange(r1 - r0), local_nnz)
            pos = np.arange(hi - lo) - np.repeat(indptr[r0:r1] - lo, local_nnz)
            val = np.zeros((rows_pad, width), dtype=np_dtype)
            col = np.zeros((rows_pad, width), dtype=np.int32)
            val[rix, pos] = csr.data[lo:hi]
            col[rix, pos] = csr.indices[lo:hi]
            self._chunks.append((val, col))
            self._r0s.append(r0)
            n_out_pad = max(n_out_pad, r0 + rows_pad)
            self.staging["conversions"] += 1  # host layout/dtype prep: once
        self.num_chunks = len(self._chunks)
        count_conversions(self.num_chunks)
        self._n_out_pad = n_out_pad
        self.padded_slots = sum(v.size for v, _ in self._chunks)

        # Jitted per-chunk kernel SpMV; static over the engine (hashable) so a
        # different accum dtype retraces once per distinct chunk width, not
        # per chunk per call.
        @partial(jax.jit, static_argnames=("eng",))
        def _partial_ell(val, col, x, y, r0, *, eng):
            yk = eng.ell_matvec(val, col, x).astype(y.dtype)
            seg = jax.lax.dynamic_slice(y, (r0,), (yk.shape[0],))
            return jax.lax.dynamic_update_slice(y, seg + yk, (r0,))

        self._partial_ell = _partial_ell

    def _stream(self, consume):
        """Double-buffered chunk stream: stage (device_put) up to
        ``stage_depth`` chunks ahead of the one being consumed; references
        are dropped as soon as a chunk's partial SpMV is dispatched, so at
        most ``stage_depth + 1`` chunks are device-resident."""
        staged = {}

        def stage(j):
            if j < self.num_chunks and j not in staged:
                _faults.check_chunk_io(j)
                staged[j] = tuple(jax.device_put(a) for a in self._chunks[j])
                self.staging["transfers"] += 1

        for i in range(self.num_chunks):
            stage(i)
            for j in range(i + 1, min(i + 1 + self.stage_depth, self.num_chunks)):
                stage(j)  # issued while chunk i's compute is in flight
            self.staging["max_resident"] = max(self.staging["max_resident"], len(staged))
            consume(i, staged.pop(i))

    def matvec(self, x, accum_dtype=None):
        acc = jnp.dtype(accum_dtype or self._dtype)
        if self.spmv_format == "ell":
            import dataclasses as _dc

            eng = self.engine
            if jnp.dtype(eng.accum_dtype) != acc:
                eng = _dc.replace(eng, accum_dtype=acc)
            y = [jnp.zeros((self._n_out_pad,), acc)]

            def consume(i, arrs):
                val, col = arrs
                y[0] = self._partial_ell(
                    val, col, x, y[0], jnp.asarray(self._r0s[i], jnp.int32), eng=eng
                )

            self._stream(consume)
            return y[0][: self.n]
        y = [jnp.zeros((self.n,), acc)]

        def consume(i, arrs):
            row, col, val = arrs
            y[0] = self._partial_spmv(row, col, val, x, y[0], acc=acc)

        self._stream(consume)
        return y[0]


@dataclasses.dataclass
class CallableOperator(LinearOperator):
    """Wrap a bare symmetric matvec callable ``fn(x) -> A @ x``.

    This is how the ``eigsh`` frontend accepts matrix-free problems (scipy's
    ``LinearOperator`` or any function): the callable is treated as a black
    box, so the mixed-precision policy governs only the surrounding Lanczos
    arithmetic, not the matvec interior.

    The Lanczos loop runs under ``jit``, so a callable that computes in
    NumPy (e.g. a scipy ``LinearOperator``) cannot be traced.  We probe
    traceability once with ``jax.eval_shape``: traceable callables are
    inlined into the compiled loop; host callables are bridged with
    ``jax.pure_callback`` (one device<->host round-trip per matvec — the
    same placement cost scipy's ARPACK wrapper pays).
    """

    fn: Callable[[jax.Array], jax.Array]
    n: int

    def __post_init__(self):
        try:
            out = jax.eval_shape(self.fn, jax.ShapeDtypeStruct((self.n,), jnp.float32))
        except Exception:
            self._traceable = False
        else:
            if out.shape != (self.n,):
                raise ValueError(
                    f"matvec callable returned shape {out.shape}, expected ({self.n},)"
                )
            self._traceable = True

    def matvec(self, x, accum_dtype=None):
        if self._traceable:
            y = jnp.asarray(self.fn(x))
        else:
            spec = jax.ShapeDtypeStruct((self.n,), x.dtype)
            y = jax.pure_callback(
                lambda xv: np.asarray(self.fn(xv), dtype=xv.dtype), spec, x
            )
        return y.astype(accum_dtype) if accum_dtype is not None else y


class HvpOperator(LinearOperator):
    """Matrix-free Hessian-vector product of ``loss(params)`` (framework
    integration of the paper's solver; see training/spectral.py)."""

    def __init__(self, loss_fn: Callable, params, ggn: bool = False):
        self._loss = loss_fn
        self._params = params
        flat, unravel = jax.flatten_util.ravel_pytree(params)
        self._flat0 = flat
        self._unravel = unravel
        self.n = flat.shape[0]

        def hvp(v):
            # reverse-over-reverse: H v = d/dp <grad(loss)(p), v>.  (Forward-
            # over-reverse is cheaper but jvp does not compose with the
            # custom_vjp embedding lookup in the model zoo.)
            def gv(flat_p):
                g = jax.flatten_util.ravel_pytree(jax.grad(loss_fn)(unravel(flat_p)))[0]
                return jnp.vdot(g, v)

            return jax.grad(gv)(flat)

        self._hvp = jax.jit(hvp)

    def matvec(self, x, accum_dtype=None):
        y = self._hvp(x.astype(self._flat0.dtype))
        return y.astype(accum_dtype) if accum_dtype else y


def make_operator(
    csr: CSR,
    impl: str = "coo",
    dtype=jnp.float32,
    engine: Optional[SpmvEngine] = None,
) -> LinearOperator:
    """Build a solver operator for an explicit sparse matrix.

    With an :class:`SpmvEngine`, the engine's chosen format drives the device
    container and the kernel tile parameters (``impl`` is ignored); otherwise
    ``impl`` picks the legacy fixed path.
    """
    if engine is not None:
        if engine.format == "ell":
            mat = to_device_ell(
                csr, dtype=dtype, row_tile=engine.tiles.block_r, slot_tile=128
            )
        elif engine.format == "bsr":
            mat = to_device_bsr(csr, block_size=engine.tiles.block_size, dtype=dtype)
        elif engine.format == "hybrid":
            # Reuse the cap the selection statistics were computed with, so
            # the built layout matches the overhead the selector accepted.
            cap = max(s.hyb_width for s in engine.stats) if engine.stats else None
            mat = to_device_hybrid(
                csr, dtype=dtype, width_cap=cap, row_tile=engine.tiles.block_r
            )
        else:
            mat = to_device_coo(csr, dtype=dtype)
        return SparseOperator(mat, impl="engine", engine=engine)
    if impl == "coo":
        return SparseOperator(to_device_coo(csr, dtype=dtype), impl="coo")
    if impl in ("ell", "ell_kernel"):
        return SparseOperator(to_device_ell(csr, dtype=dtype), impl=impl)
    if impl == "bsr_kernel":
        from ..kernels.spmv_bsr import blocked_ell_from_csr

        return SparseOperator(blocked_ell_from_csr(csr, dtype=dtype), impl=impl)
    if impl == "chunked":
        return ChunkedOperator(csr, dtype=dtype)
    raise ValueError(f"unknown operator impl {impl!r}")
