"""Linear operators consumed by the eigensolver.

The paper's solver is matrix-driven (sparse SpMV), but the Lanczos phase only
needs ``y = A @ x``; we expose that as a small operator protocol so the same
solver runs on:

  * explicit sparse matrices (COO segment-sum path, or the Pallas ELL/BSR
    kernels — the paper's case);
  * chunk-streamed matrices whose triplets live in **host** memory and are
    staged to the device chunk-by-chunk (the paper's out-of-core unified
    memory mode, DESIGN.md §3.4);
  * matrix-free Hessian/GGN-vector products of a model loss — the framework
    integration (spectral monitoring of training, DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from ..sparse.formats import CSR, DeviceCOO, DeviceELL, to_device_coo, to_device_ell
from .precision import PrecisionPolicy

__all__ = [
    "LinearOperator",
    "DenseOperator",
    "SparseOperator",
    "ChunkedOperator",
    "CallableOperator",
    "HvpOperator",
    "make_operator",
]


class LinearOperator:
    """Protocol: symmetric square operator with policy-aware matvec."""

    n: int

    def matvec(self, x: jax.Array, accum_dtype=None) -> jax.Array:
        raise NotImplementedError

    def bound_matvec(self, policy: PrecisionPolicy) -> Callable:
        acc = policy.compute

        def mv(x):
            return self.matvec(x, accum_dtype=acc)

        return mv


@dataclasses.dataclass
class DenseOperator(LinearOperator):
    a: jax.Array

    @property
    def n(self) -> int:
        return self.a.shape[0]

    def matvec(self, x, accum_dtype=None):
        acc = accum_dtype or x.dtype
        return self.a.astype(acc) @ x.astype(acc)


@dataclasses.dataclass
class SparseOperator(LinearOperator):
    """Explicit sparse matrix; ``impl`` picks the SpMV engine."""

    mat: object  # DeviceCOO | DeviceELL
    impl: str = "coo"  # "coo" | "ell" | "ell_kernel" | "bsr_kernel"

    @property
    def n(self) -> int:
        if isinstance(self.mat, tuple):  # blocked-ELL: (val, bcol, n_rows)
            return int(self.mat[2])
        return self.mat.n_rows

    def matvec(self, x, accum_dtype=None):
        if self.impl in ("coo", "ell"):
            return self.mat.matvec(x, accum_dtype=accum_dtype)
        if self.impl == "ell_kernel":
            from ..kernels import ops as kops

            return kops.spmv_ell(self.mat, x, accum_dtype=accum_dtype)
        if self.impl == "bsr_kernel":
            from ..kernels import ops as kops

            return kops.spmv_bsr(self.mat, x, accum_dtype=accum_dtype)  # mat = (val,bcol,n)
        raise ValueError(f"unknown SpMV impl {self.impl!r}")


class ChunkedOperator(LinearOperator):
    """Out-of-core SpMV: COO triplets stay in host NumPy; each matvec streams
    fixed-size chunks to the device and accumulates partial products.

    This reproduces the paper's unified-memory out-of-core mode: at any moment
    only ``chunk_nnz`` non-zeros are device-resident.  On a real TPU the
    staging is host-DRAM -> HBM DMA; here the same code path exercises the
    chunking logic.
    """

    def __init__(self, csr: CSR, chunk_nnz: int = 1 << 20, dtype=jnp.float32):
        self.n = csr.n
        self._dtype = dtype
        row = np.repeat(np.arange(csr.n, dtype=np.int32), csr.row_nnz())
        self._chunks = []
        nnz = csr.nnz
        for lo in range(0, nnz, chunk_nnz):
            hi = min(lo + chunk_nnz, nnz)
            pad = chunk_nnz - (hi - lo)
            self._chunks.append(
                (
                    np.pad(row[lo:hi], (0, pad)),
                    np.pad(csr.indices[lo:hi], (0, pad)),
                    np.pad(csr.data[lo:hi], (0, pad)).astype(np.dtype(dtype) if dtype != jnp.bfloat16 else np.float32),
                )
            )
        self.num_chunks = len(self._chunks)

        # One jitted partial-SpMV per instance, keyed on the (static) accum
        # dtype: defining it inside matvec would retrace on every call.
        @partial(jax.jit, static_argnames=("acc",))
        def _partial_spmv(row, col, val, x, y, *, acc):
            prod = val.astype(acc) * jnp.take(x, col).astype(acc)
            return y + jax.ops.segment_sum(prod, row, num_segments=self.n)

        self._partial_spmv = _partial_spmv

    def matvec(self, x, accum_dtype=None):
        acc = jnp.dtype(accum_dtype or self._dtype)
        y = jnp.zeros((self.n,), acc)
        for row, col, val in self._chunks:  # host loop = the UM page stream
            y = self._partial_spmv(
                jnp.asarray(row), jnp.asarray(col), jnp.asarray(val, dtype=self._dtype), x, y,
                acc=acc,
            )
        return y


@dataclasses.dataclass
class CallableOperator(LinearOperator):
    """Wrap a bare symmetric matvec callable ``fn(x) -> A @ x``.

    This is how the ``eigsh`` frontend accepts matrix-free problems (scipy's
    ``LinearOperator`` or any function): the callable is treated as a black
    box, so the mixed-precision policy governs only the surrounding Lanczos
    arithmetic, not the matvec interior.

    The Lanczos loop runs under ``jit``, so a callable that computes in
    NumPy (e.g. a scipy ``LinearOperator``) cannot be traced.  We probe
    traceability once with ``jax.eval_shape``: traceable callables are
    inlined into the compiled loop; host callables are bridged with
    ``jax.pure_callback`` (one device<->host round-trip per matvec — the
    same placement cost scipy's ARPACK wrapper pays).
    """

    fn: Callable[[jax.Array], jax.Array]
    n: int

    def __post_init__(self):
        try:
            out = jax.eval_shape(self.fn, jax.ShapeDtypeStruct((self.n,), jnp.float32))
        except Exception:
            self._traceable = False
        else:
            if out.shape != (self.n,):
                raise ValueError(
                    f"matvec callable returned shape {out.shape}, expected ({self.n},)"
                )
            self._traceable = True

    def matvec(self, x, accum_dtype=None):
        if self._traceable:
            y = jnp.asarray(self.fn(x))
        else:
            spec = jax.ShapeDtypeStruct((self.n,), x.dtype)
            y = jax.pure_callback(
                lambda xv: np.asarray(self.fn(xv), dtype=xv.dtype), spec, x
            )
        return y.astype(accum_dtype) if accum_dtype is not None else y


class HvpOperator(LinearOperator):
    """Matrix-free Hessian-vector product of ``loss(params)`` (framework
    integration of the paper's solver; see training/spectral.py)."""

    def __init__(self, loss_fn: Callable, params, ggn: bool = False):
        self._loss = loss_fn
        self._params = params
        flat, unravel = jax.flatten_util.ravel_pytree(params)
        self._flat0 = flat
        self._unravel = unravel
        self.n = flat.shape[0]

        def hvp(v):
            # reverse-over-reverse: H v = d/dp <grad(loss)(p), v>.  (Forward-
            # over-reverse is cheaper but jvp does not compose with the
            # custom_vjp embedding lookup in the model zoo.)
            def gv(flat_p):
                g = jax.flatten_util.ravel_pytree(jax.grad(loss_fn)(unravel(flat_p)))[0]
                return jnp.vdot(g, v)

            return jax.grad(gv)(flat)

        self._hvp = jax.jit(hvp)

    def matvec(self, x, accum_dtype=None):
        y = self._hvp(x.astype(self._flat0.dtype))
        return y.astype(accum_dtype) if accum_dtype else y


def make_operator(csr: CSR, impl: str = "coo", dtype=jnp.float32) -> LinearOperator:
    if impl == "coo":
        return SparseOperator(to_device_coo(csr, dtype=dtype), impl="coo")
    if impl in ("ell", "ell_kernel"):
        return SparseOperator(to_device_ell(csr, dtype=dtype), impl=impl)
    if impl == "bsr_kernel":
        from ..kernels.spmv_bsr import blocked_ell_from_csr

        return SparseOperator(blocked_ell_from_csr(csr, dtype=dtype), impl=impl)
    if impl == "chunked":
        return ChunkedOperator(csr, dtype=dtype)
    raise ValueError(f"unknown operator impl {impl!r}")
