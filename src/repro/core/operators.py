"""Linear operators consumed by the eigensolver.

The paper's solver is matrix-driven (sparse SpMV), but the Lanczos phase only
needs ``y = A @ x``; we expose that as a small operator protocol so the same
solver runs on:

  * explicit sparse matrices (COO segment-sum path, or the Pallas ELL/BSR
    kernels — the paper's case);
  * chunk-streamed matrices whose triplets live in **host** memory and are
    staged to the device chunk-by-chunk (the paper's out-of-core unified
    memory mode, DESIGN.md §3.4);
  * matrix-free Hessian/GGN-vector products of a model loss — the framework
    integration (spectral monitoring of training, DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.flatten_util
import jax.numpy as jnp
import numpy as np

from ..kernels.engine import SpmvEngine
from ..sparse.formats import (
    CSR,
    DeviceCOO,
    DeviceELL,
    count_conversions,
    to_device_bsr,
    to_device_coo,
    to_device_ell,
    to_device_hybrid,
)
from ..testing import faults as _faults
from .precision import PrecisionPolicy

__all__ = [
    "LinearOperator",
    "DenseOperator",
    "SparseOperator",
    "ChunkedOperator",
    "CallableOperator",
    "HvpOperator",
    "chunk_row_bounds",
    "make_operator",
]


def chunk_row_bounds(indptr: np.ndarray, n: int, chunk_nnz: int) -> list:
    """Row-contiguous chunk bounds holding <= ``chunk_nnz`` non-zeros each
    (single rows larger than the budget get a chunk of their own).  Shared
    by :class:`ChunkedOperator` and the frontend's staging-footprint
    estimate so both reason about the same chunking."""
    starts = [0]
    while starts[-1] < n:
        r0 = starts[-1]
        r1 = int(np.searchsorted(indptr, indptr[r0] + chunk_nnz, side="right")) - 1
        starts.append(min(n, max(r1, r0 + 1)))
    return list(zip(starts[:-1], starts[1:]))


def chunk_rows_pad(rows: int, block_r: int, storage_dtype, row_multiple: int = 1) -> int:
    """Padded row count of one staged ELL chunk: rows round up to the chunk's
    own row tile — the kernel's ``block_r`` capped at the next power of two
    of the row count (floored at the TPU sublane minimum: 8 for 4-byte
    dtypes, 16 for bf16/f16, 32 for fp8), so a chunk with FEW rows (e.g. a
    hub row chunked alone) never allocates the full global row tile times
    its huge width.  ``ell_matvec`` adapts its row tile down to whatever
    divides this.  ``row_multiple`` additionally aligns the padded count
    (the chunk-resident sharded path needs rows divisible by the mesh)."""
    itemsize = jnp.dtype(storage_dtype).itemsize
    min_r = {1: 32, 2: 16}.get(itemsize, 8)
    np2 = 1 << max(0, max(rows, min_r) - 1).bit_length()  # next pow2 >= rows
    tile = max(min_r, min(block_r, np2)) * max(1, int(row_multiple))
    return -(-rows // tile) * tile


class LinearOperator:
    """Protocol: symmetric square operator with policy-aware matvec."""

    n: int

    def matvec(self, x: jax.Array, accum_dtype=None) -> jax.Array:
        raise NotImplementedError

    def bound_matvec(self, policy: PrecisionPolicy) -> Callable:
        # The SpMV accumulator runs in its own phase dtype (defaults to the
        # policy's compute dtype); the Lanczos loop rounds the product back
        # to the carried compute dtype at the phase boundary.
        acc = policy.phase_dtype("spmv")

        def mv(x):
            return self.matvec(x, accum_dtype=acc)

        return mv


@dataclasses.dataclass
class DenseOperator(LinearOperator):
    a: jax.Array

    @property
    def n(self) -> int:
        return self.a.shape[0]

    def matvec(self, x, accum_dtype=None):
        acc = accum_dtype or x.dtype
        return self.a.astype(acc) @ x.astype(acc)


@dataclasses.dataclass
class SparseOperator(LinearOperator):
    """Explicit sparse matrix; ``impl`` (or an :class:`SpmvEngine`) picks the
    SpMV execution path.  With an engine attached, the container format and
    tile parameters come from the engine (`kernels/engine.py`)."""

    mat: object  # DeviceCOO | DeviceELL | DeviceBSR
    impl: str = "coo"  # "coo" | "ell" | "ell_kernel" | "bsr_kernel" | "engine"
    engine: Optional[SpmvEngine] = None

    @property
    def n(self) -> int:
        if isinstance(self.mat, tuple):  # blocked-ELL: (val, bcol, n_rows)
            return int(self.mat[2])
        return self.mat.n_rows

    @property
    def spmv_format(self) -> str:
        if self.engine is not None:
            return self.engine.format
        return {"ell_kernel": "ell", "bsr_kernel": "bsr"}.get(self.impl, self.impl)

    def matvec(self, x, accum_dtype=None):
        if self.engine is not None:
            return self.engine.spmv(self.mat, x, accum_dtype=accum_dtype)
        if self.impl in ("coo", "ell"):
            return self.mat.matvec(x, accum_dtype=accum_dtype)
        if self.impl == "ell_kernel":
            from ..kernels import ops as kops

            return kops.spmv_ell(self.mat, x, accum_dtype=accum_dtype)
        if self.impl == "bsr_kernel":
            from ..kernels import ops as kops

            return kops.spmv_bsr(self.mat, x, accum_dtype=accum_dtype)  # mat = (val,bcol,n)
        raise ValueError(f"unknown SpMV impl {self.impl!r}")


class ChunkedOperator(LinearOperator):
    """Out-of-core SpMV: matrix data stays on the host (in-RAM CSR **or** an
    ``np.memmap``-backed :class:`~repro.sparse.diskcsr.DiskCSR`); each matvec
    streams fixed-size chunks to the device and accumulates partial products.

    This reproduces the paper's unified-memory out-of-core mode: at any moment
    at most ``stage_depth + 1`` chunks are device-resident.  On a real TPU the
    staging is host-DRAM -> HBM DMA; here the same code path exercises the
    chunking and double-buffering logic.

    **Host residency contract.**  Chunk buffers are built *lazily per staged
    window* from the source CSR/mapping and dropped as soon as the chunk's
    transfer is issued, so peak host residency is the source matrix (disk
    pages for a ``DiskCSR``) plus ``stage_depth + 1`` chunk windows — never a
    second full pinned copy of the matrix.  ``own_data=True`` opts into the
    legacy eager pre-pin (conversion paid once, fastest repeat sweeps) and in
    exchange the operator *drops its source-CSR reference* after pinning: the
    caller hands the arrays over, and host residency ends at one copy again.

    **Compressed staging.**  ``staging="bf16" | "fp8"`` stages ELL chunk
    values quantized to the narrow dtype with per-row-block scales and
    delta-encoded int16/int32 columns, decompressed inside the Pallas kernel
    (``kernels/spmv_ell_packed.py``) — 2-4x the effective staging bandwidth.
    ``staging="auto"`` packs when the storage dtype is already narrow
    (bf16/f16 policies) and ships plain buffers otherwise.  Byte / bandwidth
    / compression counters accumulate in ``self.staging`` (surfaced by
    ``eigsh`` in ``EigenResult.partition["spmv"]["staging"]``).

    **Sharded chunk residency.**  With a ``mesh``, each staged ELL chunk is
    placed row-sharded across the mesh and its partial SpMV runs *inside*
    ``shard_map`` — out-of-core and multi-device compose instead of
    excluding each other (the PR 3 open item).

    With an ELL-format :class:`SpmvEngine` attached, chunks are row ranges
    staged as per-chunk-width ELL tiles (a hub row inflates only its own
    chunk's padding, not every chunk's) and the partial SpMV runs the Pallas
    kernel; otherwise the COO ``segment_sum`` reference path streams
    nnz-sized slices (plain staging only).
    """

    # The Lanczos loop must stay a host loop for this operator: tracing the
    # chunk stream would bake every chunk into one executable as constants,
    # defeating the bounded-residency staging (see lanczos_tridiag(jit=...)).
    prefers_jit = False

    STAGING_MODES = ("f32", "bf16", "fp8", "auto")

    def __init__(
        self,
        csr,
        chunk_nnz: int = 1 << 20,
        dtype=jnp.float32,
        engine: Optional[SpmvEngine] = None,
        stage_depth: int = 1,
        own_data: bool = False,
        staging: str = "f32",
        mesh=None,
        axis: str = "data",
    ):
        self.n = csr.n
        self._dtype = dtype
        self.engine = engine
        self.stage_depth = max(0, int(stage_depth))
        self.spmv_format = engine.format if engine is not None else "coo"
        if self.spmv_format in ("bsr", "hybrid"):
            raise ValueError(
                "ChunkedOperator stages chunks as COO or ELL; per-chunk "
                f"{self.spmv_format.upper()} is not supported (pick format='ell' or 'coo')"
            )
        if staging not in self.STAGING_MODES:
            raise ValueError(
                f"unknown staging mode {staging!r}; expected one of {self.STAGING_MODES}"
            )
        if staging == "auto":
            # Pack when the storage dtype is already narrow: the quantization
            # the policy accepted is the quantization the staging ships.
            itemsize = jnp.dtype(dtype).itemsize
            staging = "bf16" if itemsize == 2 else ("fp8" if itemsize == 1 else "f32")
        if staging != "f32" and self.spmv_format != "ell":
            staging = "f32"  # packed staging is an ELL-kernel path
        self.staging_mode = staging
        self.mesh = mesh
        self._axis = axis
        self._mesh_size = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
        from ..sparse.diskcsr import DiskCSR  # local: sparse imports stay light

        self.disk_backed = isinstance(csr, DiskCSR)
        self.source_path = csr.path if self.disk_backed else None
        self.staging = {
            "conversions": 0,
            "transfers": 0,
            "max_resident": 0,
            "bytes_staged": 0,
            "bytes_plain": 0,
            "stage_s": 0.0,
            "mode": self.staging_mode,
        }
        self._csr = csr
        self._row_nnz = np.asarray(csr.row_nnz())  # O(n), not O(nnz)
        if self.spmv_format == "ell":
            self._init_ell_meta(csr, chunk_nnz, dtype, engine)
        else:
            self._init_coo_meta(csr, chunk_nnz)
        self._built = np.zeros(self.num_chunks, dtype=bool)
        self._pinned = None
        # Mid-step checkpoint bindings (see ``set_step_hook``/``set_resume``):
        # the Lanczos host loop installs these so the ONE streamed matvec per
        # step can persist/restore its chunk cursor without the loop having
        # to thread extra arguments through the generic Ops.matvec closure.
        self._step_hook = None
        self._resume = None
        if own_data and not self.disk_backed:
            # Eager pre-pin (the legacy fast path), then release the source:
            # the caller opted into handing the arrays over, so only ONE host
            # copy (the pinned chunks) survives construction.
            self._pinned = [self._build_chunk(j) for j in range(self.num_chunks)]
            self._csr = None
            self._row_nnz = None

    # ------------------------------ chunk planning ------------------------------

    def _init_coo_meta(self, csr, chunk_nnz: int):
        nnz = csr.nnz
        self._coo_chunk_nnz = int(chunk_nnz)
        self._coo_bounds = [
            (lo, min(lo + chunk_nnz, nnz)) for lo in range(0, max(nnz, 1), chunk_nnz)
        ]
        self.num_chunks = len(self._coo_bounds)

        # One jitted partial-SpMV per instance, keyed on the (static) accum
        # dtype: defining it inside matvec would retrace on every call.
        @partial(jax.jit, static_argnames=("acc",))
        def _partial_spmv(row, col, val, x, y, *, acc):
            prod = val.astype(acc) * jnp.take(x, col).astype(acc)
            return y + jax.ops.segment_sum(prod, row, num_segments=self.n)

        self._partial_spmv = _partial_spmv

    def _init_ell_meta(self, csr, chunk_nnz: int, dtype, engine: SpmvEngine):
        indptr, n = csr.indptr, csr.n
        bounds = chunk_row_bounds(indptr, n, chunk_nnz)
        # TPU sublane minima follow the *staged* value dtype (fp8 tiles need
        # 32 sublanes); the sharded path additionally needs rows divisible by
        # the mesh extent.
        staged_dtype = {"bf16": jnp.bfloat16, "fp8": "float8_e4m3fn"}.get(
            self.staging_mode, dtype
        )
        self._bounds = []
        self._widths = []
        self._rows_pads = []
        self._r0s = []
        n_out_pad = 0
        self.padded_slots = 0
        for r0, r1 in bounds:
            local_nnz = self._row_nnz[r0:r1]
            # Per-chunk width (128-lane aligned) AND per-chunk row padding:
            # a hub row pays for its own chunk only — neither its width nor
            # the global row tile inflates any other chunk, and a few-row
            # hub chunk never allocates block_r x hub_width zeros.
            width = int(max(1, local_nnz.max() if local_nnz.size else 1))
            width = -(-width // 128) * 128
            rows_pad = chunk_rows_pad(
                r1 - r0, engine.tiles.block_r, staged_dtype, row_multiple=self._mesh_size
            )
            self._bounds.append((r0, r1))
            self._widths.append(width)
            self._rows_pads.append(rows_pad)
            self._r0s.append(r0)
            n_out_pad = max(n_out_pad, r0 + rows_pad)
            self.padded_slots += rows_pad * width
        self.num_chunks = len(self._bounds)
        self._n_out_pad = n_out_pad

        # Jitted per-chunk kernel SpMV; static over the engine (hashable) so a
        # different accum dtype retraces once per distinct chunk width, not
        # per chunk per call.
        @partial(jax.jit, static_argnames=("eng",))
        def _partial_ell(val, col, x, y, r0, *, eng):
            yk = eng.ell_matvec(val, col, x).astype(y.dtype)
            seg = jax.lax.dynamic_slice(y, (r0,), (yk.shape[0],))
            return jax.lax.dynamic_update_slice(y, seg + yk, (r0,))

        @partial(jax.jit, static_argnames=("eng",))
        def _partial_ell_packed(val, scale, base, dcol, x, y, r0, *, eng):
            yk = eng.packed_ell_matvec(val, scale, base, dcol, x).astype(y.dtype)
            seg = jax.lax.dynamic_slice(y, (r0,), (yk.shape[0],))
            return jax.lax.dynamic_update_slice(y, seg + yk, (r0,))

        self._partial_ell = _partial_ell
        self._partial_ell_packed = _partial_ell_packed

    # ------------------------------ chunk building ------------------------------

    def _build_chunk(self, j: int):
        """Materialize chunk ``j``'s host staging buffers from the source
        CSR/mapping.  Called lazily per staged window (the headline host-
        memory fix: buffers exist only while their window is staged) or once
        per chunk from the eager ``own_data`` pre-pin."""
        arrs = (
            self._build_ell_chunk(j)
            if self.spmv_format == "ell"
            else self._build_coo_chunk(j)
        )
        if not self._built[j]:
            # Conversion census ticks once per chunk per operator lifetime:
            # rebuilding the same window on a later sweep is staging traffic
            # (counted in bytes_staged), not a new layout conversion.
            self._built[j] = True
            self.staging["conversions"] += 1
            count_conversions(1)
        return arrs

    def _build_coo_chunk(self, j: int):
        lo, hi = self._coo_bounds[j]
        indptr = self._csr.indptr
        np_dtype = np.dtype(jnp.dtype(self._dtype))  # bf16 host buffers via ml_dtypes
        # Rows overlapping [lo, hi): repeat each row id by its nnz inside the
        # window — O(window), never the O(nnz) full row array.
        r_lo = int(np.searchsorted(indptr, lo, side="right")) - 1
        r_hi = int(np.searchsorted(indptr, hi, side="left"))
        counts = np.minimum(indptr[r_lo + 1 : r_hi + 1], hi) - np.maximum(
            indptr[r_lo:r_hi], lo
        )
        row = np.repeat(np.arange(r_lo, r_hi, dtype=np.int32), counts)
        pad = self._coo_chunk_nnz - (hi - lo)
        return (
            np.pad(row, (0, pad)),
            np.pad(np.asarray(self._csr.indices[lo:hi]), (0, pad)),
            np.pad(np.asarray(self._csr.data[lo:hi], dtype=np.float64), (0, pad)).astype(
                np_dtype
            ),
        )

    def _build_ell_chunk(self, j: int):
        r0, r1 = self._bounds[j]
        indptr = self._csr.indptr
        lo, hi = int(indptr[r0]), int(indptr[r1])
        local_nnz = self._row_nnz[r0:r1]
        width, rows_pad = self._widths[j], self._rows_pads[j]
        rix = np.repeat(np.arange(r1 - r0), local_nnz)
        pos = np.arange(hi - lo) - np.repeat(np.asarray(indptr[r0:r1]) - lo, local_nnz)
        col = np.zeros((rows_pad, width), dtype=np.int32)
        col[rix, pos] = self._csr.indices[lo:hi]
        if self.staging_mode == "f32":
            np_dtype = np.dtype(jnp.dtype(self._dtype))
            val = np.zeros((rows_pad, width), dtype=np_dtype)
            val[rix, pos] = np.asarray(self._csr.data[lo:hi], dtype=np.float64).astype(
                np_dtype
            )
            return (val, col)
        from ..kernels.spmv_ell_packed import pack_ell_chunk

        val = np.zeros((rows_pad, width), dtype=np.float32)
        val[rix, pos] = self._csr.data[lo:hi]
        return pack_ell_chunk(val, col, self.staging_mode)

    def _plain_chunk_bytes(self, j: int) -> int:
        """Bytes plain (uncompressed) staging would ship for chunk ``j`` —
        the numerator of the compression ratio."""
        if self.spmv_format == "ell":
            slots = self._rows_pads[j] * self._widths[j]
            return slots * (jnp.dtype(self._dtype).itemsize + 4)  # val + int32 col
        return self._coo_chunk_nnz * (8 + jnp.dtype(self._dtype).itemsize)

    # ------------------------------- staging loop -------------------------------

    def _device_put_chunk(self, arrs):
        if self.mesh is None or self.spmv_format != "ell":
            return tuple(jax.device_put(a) for a in arrs)
        from jax.sharding import NamedSharding, PartitionSpec

        # Chunk-resident sharding: rows of the staged window split across the
        # mesh (rows_pad is padded to a mesh multiple), columns replicated.
        sh = NamedSharding(self.mesh, PartitionSpec(self._axis, None))
        return tuple(jax.device_put(a, sh) for a in arrs)

    def _stream(self, consume, start: int = 0):
        """Double-buffered chunk stream: build + stage (device_put) up to
        ``stage_depth`` chunks ahead of the one being consumed; host buffers
        are dropped once their transfer is issued and device references as
        soon as the chunk's partial SpMV is dispatched, so at most
        ``stage_depth + 1`` chunks are resident on either side.  ``start``
        skips already-consumed chunks (mid-step checkpoint resume)."""
        import time as _time

        staged = {}

        def stage(j):
            if j < self.num_chunks and j not in staged:
                _faults.check_chunk_io(j)
                t0 = _time.perf_counter()
                arrs = self._pinned[j] if self._pinned is not None else self._build_chunk(j)
                staged[j] = self._device_put_chunk(arrs)
                self.staging["stage_s"] += _time.perf_counter() - t0
                self.staging["transfers"] += 1
                self.staging["bytes_staged"] += sum(int(a.nbytes) for a in arrs)
                self.staging["bytes_plain"] += self._plain_chunk_bytes(j)

        for i in range(start, self.num_chunks):
            stage(i)
            for j in range(i + 1, min(i + 1 + self.stage_depth, self.num_chunks)):
                stage(j)  # issued while chunk i's compute is in flight
            self.staging["max_resident"] = max(self.staging["max_resident"], len(staged))
            consume(i, staged.pop(i))

    def staging_stats(self) -> dict:
        """Staging counters + derived bandwidth/compression metrics (what
        ``partition["spmv"]["staging"]`` reports)."""
        out = dict(self.staging)
        staged = out["bytes_staged"]
        out["effective_bandwidth_gbps"] = (
            out["bytes_plain"] / out["stage_s"] / 1e9 if out["stage_s"] > 0 else 0.0
        )
        out["compression_ratio"] = out["bytes_plain"] / staged if staged else 1.0
        return out

    # --------------------------------- matvec -----------------------------------

    def _throttle(self, i: int, y) -> None:
        """Bound the async dispatch queue to the staging window.  The host
        loop builds and dispatches chunks far faster than the device drains
        them; without a periodic sync the executor's queue pins EVERY
        dispatched chunk's buffers at once and the ``stage_depth + 1``
        residency contract only holds for the host-side windows.  Blocking
        on the running accumulator once per window retires the chunks behind
        it while the window ahead still overlaps build/transfer/compute."""
        if (i + 1) % (self.stage_depth + 1) == 0:
            jax.block_until_ready(y)

    def set_step_hook(self, hook):
        """Install ``hook(chunk_index, partial_accumulator)`` to observe the
        running accumulator of the *next* matvec after each consumed chunk
        (the chunk-cursor checkpoint writer).  One-per-step: the caller
        reinstalls before each step."""
        self._step_hook = hook

    def set_resume(self, start_chunk: int, partial_y):
        """Arm the next matvec to skip chunks ``< start_chunk`` and seed its
        accumulator from ``partial_y`` (chunk-cursor checkpoint restore).
        Consumed by exactly one matvec call."""
        self._resume = (int(start_chunk), partial_y)

    def matvec(self, x, accum_dtype=None, *, start_chunk: int = 0, partial_y=None,
               on_chunk=None):
        """Streamed SpMV.  ``start_chunk``/``partial_y`` resume a partially
        accumulated product from a mid-step checkpoint (chunks are consumed
        in a fixed order, so resuming from the saved partial is bit-identical
        to an uninterrupted sweep); ``on_chunk(i, y)`` observes the running
        accumulator after each chunk (the checkpoint writer hook)."""
        if start_chunk == 0 and partial_y is None and self._resume is not None:
            start_chunk, partial_y = self._resume
            self._resume = None
        if on_chunk is None:
            on_chunk = self._step_hook
        acc = jnp.dtype(accum_dtype or self._dtype)
        if self.spmv_format == "ell":
            import dataclasses as _dc

            eng = self.engine
            if jnp.dtype(eng.accum_dtype) != acc:
                eng = _dc.replace(eng, accum_dtype=acc)
            if partial_y is not None:
                y = [jnp.asarray(partial_y, acc)]
            else:
                y = [jnp.zeros((self._n_out_pad,), acc)]

            packed = self.staging_mode != "f32"

            def consume(i, arrs):
                r0 = jnp.asarray(self._r0s[i], jnp.int32)
                if packed:
                    val, scale, base, dcol = arrs
                    y[0] = self._sharded_or_local_packed(
                        val, scale, base, dcol, x, y[0], r0, eng
                    )
                else:
                    val, col = arrs
                    y[0] = self._sharded_or_local_plain(val, col, x, y[0], r0, eng)
                self._throttle(i, y[0])
                if on_chunk is not None:
                    on_chunk(i, y[0])

            self._stream(consume, start=start_chunk)
            return y[0][: self.n]
        y = [
            jnp.asarray(partial_y, acc)
            if partial_y is not None
            else jnp.zeros((self.n,), acc)
        ]

        def consume(i, arrs):
            row, col, val = arrs
            y[0] = self._partial_spmv(row, col, val, x, y[0], acc=acc)
            self._throttle(i, y[0])
            if on_chunk is not None:
                on_chunk(i, y[0])

        self._stream(consume, start=start_chunk)
        return y[0]

    # ------------------------- sharded partial dispatch -------------------------

    def _shard_fn(self, eng, packed: bool):
        """shard_map-wrapped per-chunk partial SpMV: the kernel runs on each
        device's row slice of the staged chunk, with ``x`` replicated — the
        composition of out-of-core staging and the paper's multi-device
        partition.  Cached per (engine, kind) since shard_map closures are
        rebuilt otherwise."""
        key = (eng, packed)
        cache = getattr(self, "_shard_fns", None)
        if cache is None:
            cache = self._shard_fns = {}
        if key not in cache:
            from jax.sharding import PartitionSpec as P

            # lazy: avoids an import cycle (check_vma/check_rep off: the
            # replicated-x rule for pallas_call is unimplemented upstream)
            from .distributed import _SHARD_MAP_KW, _shard_map

            ax = self._axis
            if packed:

                def local(val, scale, base, dcol, x):
                    return eng.packed_ell_matvec(val, scale, base, dcol, x)

                in_specs = (P(ax, None),) * 4 + (P(),)
            else:

                def local(val, col, x):
                    return eng.ell_matvec(val, col, x)

                in_specs = (P(ax, None), P(ax, None), P())
            cache[key] = jax.jit(
                _shard_map(
                    local, mesh=self.mesh, in_specs=in_specs, out_specs=P(ax),
                    **_SHARD_MAP_KW,
                )
            )
        return cache[key]

    def _sharded_or_local_plain(self, val, col, x, y, r0, eng):
        if self.mesh is None:
            return self._partial_ell(val, col, x, y, r0, eng=eng)
        yk = self._shard_fn(eng, packed=False)(val, col, x).astype(y.dtype)
        seg = jax.lax.dynamic_slice(y, (r0,), (yk.shape[0],))
        return jax.lax.dynamic_update_slice(y, seg + yk, (r0,))

    def _sharded_or_local_packed(self, val, scale, base, dcol, x, y, r0, eng):
        if self.mesh is None:
            return self._partial_ell_packed(val, scale, base, dcol, x, y, r0, eng=eng)
        yk = self._shard_fn(eng, packed=True)(val, scale, base, dcol, x).astype(y.dtype)
        seg = jax.lax.dynamic_slice(y, (r0,), (yk.shape[0],))
        return jax.lax.dynamic_update_slice(y, seg + yk, (r0,))


@dataclasses.dataclass
class CallableOperator(LinearOperator):
    """Wrap a bare symmetric matvec callable ``fn(x) -> A @ x``.

    This is how the ``eigsh`` frontend accepts matrix-free problems (scipy's
    ``LinearOperator`` or any function): the callable is treated as a black
    box, so the mixed-precision policy governs only the surrounding Lanczos
    arithmetic, not the matvec interior.

    The Lanczos loop runs under ``jit``, so a callable that computes in
    NumPy (e.g. a scipy ``LinearOperator``) cannot be traced.  We probe
    traceability once with ``jax.eval_shape``: traceable callables are
    inlined into the compiled loop; host callables are bridged with
    ``jax.pure_callback`` (one device<->host round-trip per matvec — the
    same placement cost scipy's ARPACK wrapper pays).
    """

    fn: Callable[[jax.Array], jax.Array]
    n: int

    def __post_init__(self):
        try:
            out = jax.eval_shape(self.fn, jax.ShapeDtypeStruct((self.n,), jnp.float32))
        except Exception:
            self._traceable = False
        else:
            if out.shape != (self.n,):
                raise ValueError(
                    f"matvec callable returned shape {out.shape}, expected ({self.n},)"
                )
            self._traceable = True

    def matvec(self, x, accum_dtype=None):
        if self._traceable:
            y = jnp.asarray(self.fn(x))
        else:
            spec = jax.ShapeDtypeStruct((self.n,), x.dtype)
            y = jax.pure_callback(
                lambda xv: np.asarray(self.fn(xv), dtype=xv.dtype), spec, x
            )
        return y.astype(accum_dtype) if accum_dtype is not None else y


class HvpOperator(LinearOperator):
    """Matrix-free Hessian-vector product of ``loss(params)`` (framework
    integration of the paper's solver; see training/spectral.py)."""

    def __init__(self, loss_fn: Callable, params, ggn: bool = False):
        self._loss = loss_fn
        self._params = params
        flat, unravel = jax.flatten_util.ravel_pytree(params)
        self._flat0 = flat
        self._unravel = unravel
        self.n = flat.shape[0]

        def hvp(v):
            # reverse-over-reverse: H v = d/dp <grad(loss)(p), v>.  (Forward-
            # over-reverse is cheaper but jvp does not compose with the
            # custom_vjp embedding lookup in the model zoo.)
            def gv(flat_p):
                g = jax.flatten_util.ravel_pytree(jax.grad(loss_fn)(unravel(flat_p)))[0]
                return jnp.vdot(g, v)

            return jax.grad(gv)(flat)

        self._hvp = jax.jit(hvp)

    def matvec(self, x, accum_dtype=None):
        y = self._hvp(x.astype(self._flat0.dtype))
        return y.astype(accum_dtype) if accum_dtype else y


def make_operator(
    csr: CSR,
    impl: str = "coo",
    dtype=jnp.float32,
    engine: Optional[SpmvEngine] = None,
) -> LinearOperator:
    """Build a solver operator for an explicit sparse matrix.

    With an :class:`SpmvEngine`, the engine's chosen format drives the device
    container and the kernel tile parameters (``impl`` is ignored); otherwise
    ``impl`` picks the legacy fixed path.
    """
    if engine is not None:
        if engine.format == "ell":
            mat = to_device_ell(
                csr, dtype=dtype, row_tile=engine.tiles.block_r, slot_tile=128
            )
        elif engine.format == "bsr":
            mat = to_device_bsr(csr, block_size=engine.tiles.block_size, dtype=dtype)
        elif engine.format == "hybrid":
            # Reuse the cap the selection statistics were computed with, so
            # the built layout matches the overhead the selector accepted.
            cap = max(s.hyb_width for s in engine.stats) if engine.stats else None
            mat = to_device_hybrid(
                csr, dtype=dtype, width_cap=cap, row_tile=engine.tiles.block_r
            )
        else:
            mat = to_device_coo(csr, dtype=dtype)
        return SparseOperator(mat, impl="engine", engine=engine)
    if impl == "coo":
        return SparseOperator(to_device_coo(csr, dtype=dtype), impl="coo")
    if impl in ("ell", "ell_kernel"):
        return SparseOperator(to_device_ell(csr, dtype=dtype), impl=impl)
    if impl == "bsr_kernel":
        from ..kernels.spmv_bsr import blocked_ell_from_csr

        return SparseOperator(blocked_ell_from_csr(csr, dtype=dtype), impl=impl)
    if impl == "chunked":
        return ChunkedOperator(csr, dtype=dtype)
    raise ValueError(f"unknown operator impl {impl!r}")
