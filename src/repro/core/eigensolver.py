"""Fixed-subspace Top-K sparse eigensolver engine (the paper's Fig. 1 pipeline).

``solve_fixed`` = Lanczos (device, phase 1) + Jacobi (host CPU by default,
exactly the paper's placement; pure-JAX optional) + basis combination
``X = V^T W`` + |lambda|-descending selection.

This module is an *engine*: the user-facing entrypoint is ``repro.api.eigsh``
(the unified frontend), which dispatches here for the single-device and
chunked out-of-core paths.  ``topk_eigs`` remains as a deprecated shim.
"""

from __future__ import annotations

import time
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .jacobi import jacobi_eigh, jacobi_eigh_host, tridiag_to_dense
from .lanczos import LanczosResult, check_tridiag_health, lanczos_tridiag, ops_for_operator
from .operators import LinearOperator
from .precision import FDF, PrecisionPolicy

__all__ = [
    "EigResult",
    "FixedSolveOutput",
    "ritz_decompose",
    "ritz_extract",
    "solve_fixed",
    "topk_eigs",
]


class EigResult(NamedTuple):
    """Legacy result type kept for the deprecated ``topk_eigs`` shims."""

    eigenvalues: jax.Array  # (k,) output dtype, |lambda| descending
    eigenvectors: jax.Array  # (n, k) output dtype, column-wise
    tridiag: LanczosResult  # raw Lanczos output (alpha, beta, basis)
    wall_time_s: float


class FixedSolveOutput(NamedTuple):
    """Raw engine output consumed by the ``eigsh`` frontend."""

    eigenvalues: jax.Array  # (k,) output dtype, |lambda| descending
    eigenvectors: jax.Array  # (n, k) output dtype
    residuals: np.ndarray  # (k,) float64 — Ritz residual bounds |beta_m W[m-1,i]|
    eigenvalues_f64: np.ndarray  # (k,) float64 — pre-output-cast, for tol checks
    tridiag: LanczosResult
    iterations: int  # Lanczos steps actually run (= m)
    timings: dict  # seconds: lanczos / jacobi / project / total


def ritz_decompose(lres: LanczosResult, policy: PrecisionPolicy, jacobi: str = "host"):
    """Phase 2: eigen-decompose the Lanczos tridiagonal.

    Returns ``(evals, w, evals_f64, w_f64, beta_m)`` where ``evals`` / ``w``
    are device arrays in the compute dtype (|lambda|-descending), the f64
    copies are host-side for residual/tolerance arithmetic, and ``beta_m``
    is the final residual norm scaling the classical Ritz bound.  Split out
    of :func:`solve_fixed` so the session layer's shared-subspace sweep
    (``api/session.py``) can decompose one tridiagonal and serve many
    ``(k, tol)`` queries from it.
    """
    rzdt = policy.phase_dtype("ritz")  # Ritz/restart arithmetic phase dtype
    if jacobi == "host":
        t_host = tridiag_to_dense(
            np.asarray(lres.alpha, dtype=np.float64),
            np.asarray(lres.beta, dtype=np.float64),
        )
        evals_f64, w_host = jacobi_eigh_host(np.asarray(t_host))
        evals = jnp.asarray(evals_f64, dtype=rzdt)
        w = jnp.asarray(w_host, dtype=rzdt)
    else:
        # The device Jacobi runs in the tridiagonal's dtype: cast to the ritz
        # phase dtype first (no-op when it equals compute) so the phase_map
        # audit reports what actually executed.
        t_dev = tridiag_to_dense(lres.alpha, lres.beta).astype(rzdt)
        evals, w = jacobi_eigh(t_dev)
        evals_f64 = np.asarray(evals, dtype=np.float64)
    # Residual arithmetic sees W *as the solver uses it* — rounded through
    # the compute dtype — so reported residuals are bit-identical to the
    # pre-refactor solve_fixed for every policy (f32-compute included).
    w_f64 = np.asarray(w, dtype=np.float64)
    beta_m = (
        float(np.asarray(lres.beta_last, dtype=np.float64)) if lres.beta_last is not None else 0.0
    )
    return evals, w, np.asarray(evals_f64, dtype=np.float64), w_f64, beta_m


def ritz_extract(
    lres: LanczosResult,
    evals,
    w,
    w_f64: np.ndarray,
    beta_m: float,
    k: int,
    policy: PrecisionPolicy,
):
    """Phase 3: Top-K selection + back-projection ``X = V^T W`` + residuals.

    Returns ``(evals_k, x, residuals)`` with ``evals_k`` / ``x`` in the
    policy's output dtype.  Columns are independent, so extracting at
    ``k_max`` and slicing serves every smaller-``k`` query of a batch.
    """
    m = int(w_f64.shape[0])
    evals_k = evals[:k]
    w_k = w[:, :k].astype(policy.phase_dtype("ritz"))
    x = (lres.basis.astype(policy.phase_dtype("ritz")).T @ w_k).astype(policy.output)
    # Classical Ritz residual bound: ||A x_i - theta_i x_i|| = |beta_m W[m-1,i]|.
    residuals = np.abs(beta_m * w_f64[m - 1, :k])
    return evals_k.astype(policy.output), x, residuals


def solve_fixed(
    op: LinearOperator,
    k: int,
    policy: PrecisionPolicy = FDF,
    reorth: str = "half",
    num_iters: Optional[int] = None,
    v1: Optional[jax.Array] = None,
    seed: int = 0,
    jacobi: str = "host",
    ops=None,
    probe: bool = True,
    checkpoint=None,
) -> FixedSolveOutput:
    """Compute the K eigenpairs of largest |lambda| of a symmetric operator.

    ``num_iters`` defaults to ``k`` — the paper's configuration (their K is
    both the subspace size and the output count).  Larger values give an
    extended Krylov subspace from which the Top-K Ritz pairs are extracted
    (beyond-paper accuracy knob).

    ``ops`` (an :class:`~repro.core.lanczos.Ops`) lets a caller reuse ONE
    arithmetic-kernel record across solves: the jitted Lanczos loop is keyed
    on the record's identity, so a stable record means repeated solves hit
    the XLA compile cache instead of retracing — the session layer's serving
    path passes its per-(plan, policy) record here.
    """
    policy = policy.effective()
    m = num_iters or k
    if m < k:
        raise ValueError("num_iters must be >= k")
    n = op.n
    if v1 is None:
        v1 = jax.random.normal(jax.random.PRNGKey(seed), (n,), dtype=policy.compute)

    t0 = time.perf_counter()
    # Operators that stream host data per step (ChunkedOperator) must run the
    # Lanczos loop eagerly: see LinearOperator.prefers_jit / lanczos module doc.
    use_jit = getattr(op, "prefers_jit", True)
    if ops is None:
        # Route by the operator's measured iteration plan (fused/unfused/
        # fully-fused SpMV+alpha) instead of the bare policy gate.
        ops = ops_for_operator(op, policy)
    lres = lanczos_tridiag(
        op.bound_matvec(policy), v1, m, policy, reorth=reorth, jit=use_jit, ops=ops,
        checkpoint=checkpoint if not use_jit else None,
    )
    lres = jax.tree.map(lambda x: x.block_until_ready(), lres)
    if probe:
        # Health probe on the already-materialized tridiagonal scalars: a
        # typed NumericalBreakdown beats NaN eigenvalues (see lanczos module).
        check_tridiag_health(lres, policy)
    t_lanczos = time.perf_counter() - t0

    # Phase 2 — Jacobi on the K x K tridiagonal matrix.
    t1 = time.perf_counter()
    evals, w, evals_f64, w_f64, beta_m = ritz_decompose(lres, policy, jacobi)
    t_jacobi = time.perf_counter() - t1

    # Top-K selection (already |lambda|-sorted) and back-projection X = V^T W.
    t2 = time.perf_counter()
    evals_k, x, residuals = ritz_extract(lres, evals, w, w_f64, beta_m, k, policy)
    x.block_until_ready()
    t_project = time.perf_counter() - t2

    total = time.perf_counter() - t0
    return FixedSolveOutput(
        eigenvalues=evals_k,
        eigenvectors=x,
        residuals=residuals,
        eigenvalues_f64=np.asarray(evals_f64[:k], dtype=np.float64),
        tridiag=lres,
        iterations=m,
        timings={
            "lanczos_s": t_lanczos,
            "jacobi_s": t_jacobi,
            "project_s": t_project,
            "total_s": total,
        },
    )


def topk_eigs(
    op: LinearOperator,
    k: int,
    policy: PrecisionPolicy = FDF,
    reorth: str = "half",
    num_iters: Optional[int] = None,
    v1: Optional[jax.Array] = None,
    seed: int = 0,
    jacobi: str = "host",
) -> EigResult:
    """Deprecated: use :func:`repro.api.eigsh` (the unified frontend)."""
    warnings.warn(
        "topk_eigs is deprecated; use repro.api.eigsh(A, k, backend='single', ...)",
        DeprecationWarning,
        stacklevel=2,
    )
    from ..api import eigsh

    res = eigsh(
        op,
        k,
        policy=policy,
        backend="single",
        reorth=reorth,
        num_iters=num_iters,
        v0=v1,
        seed=seed,
        jacobi=jacobi,
    )
    return EigResult(
        eigenvalues=res.eigenvalues,
        eigenvectors=res.eigenvectors,
        tridiag=res.tridiag,
        wall_time_s=res.timings["total_s"],
    )
