"""Top-level Top-K sparse eigensolver (the paper's Fig. 1 pipeline).

``topk_eigs`` = Lanczos (device, phase 1) + Jacobi (host CPU by default,
exactly the paper's placement; pure-JAX optional) + basis combination
``X = V^T W`` + |lambda|-descending selection.
"""

from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .jacobi import jacobi_eigh, jacobi_eigh_host, tridiag_to_dense
from .lanczos import LanczosResult, lanczos_tridiag
from .operators import LinearOperator
from .precision import FDF, PrecisionPolicy

__all__ = ["EigResult", "topk_eigs"]


class EigResult(NamedTuple):
    eigenvalues: jax.Array  # (k,) output dtype, |lambda| descending
    eigenvectors: jax.Array  # (n, k) output dtype, column-wise
    tridiag: LanczosResult  # raw Lanczos output (alpha, beta, basis)
    wall_time_s: float


def topk_eigs(
    op: LinearOperator,
    k: int,
    policy: PrecisionPolicy = FDF,
    reorth: str = "half",
    num_iters: Optional[int] = None,
    v1: Optional[jax.Array] = None,
    seed: int = 0,
    jacobi: str = "host",
) -> EigResult:
    """Compute the K eigenpairs of largest |lambda| of a symmetric operator.

    ``num_iters`` defaults to ``k`` — the paper's configuration (their K is
    both the subspace size and the output count).  Larger values give an
    extended Krylov subspace from which the Top-K Ritz pairs are extracted
    (beyond-paper accuracy knob).
    """
    policy = policy.effective()
    m = num_iters or k
    if m < k:
        raise ValueError("num_iters must be >= k")
    n = op.n
    if v1 is None:
        v1 = jax.random.normal(jax.random.PRNGKey(seed), (n,), dtype=policy.compute)

    t0 = time.perf_counter()
    lres = lanczos_tridiag(op.bound_matvec(policy), v1, m, policy, reorth=reorth)
    lres = jax.tree.map(lambda x: x.block_until_ready(), lres)

    # Phase 2 — Jacobi on the K x K tridiagonal matrix.
    if jacobi == "host":
        t_host = tridiag_to_dense(
            np.asarray(lres.alpha, dtype=np.float64),
            np.asarray(lres.beta, dtype=np.float64),
        )
        evals, w = jacobi_eigh_host(np.asarray(t_host))
        evals = jnp.asarray(evals, dtype=policy.compute)
        w = jnp.asarray(w, dtype=policy.compute)
    else:
        t_dev = tridiag_to_dense(lres.alpha, lres.beta)
        evals, w = jacobi_eigh(t_dev)

    # Top-K selection (already |lambda|-sorted) and back-projection X = V^T W.
    evals_k = evals[:k]
    w_k = w[:, :k]
    x = (lres.basis.astype(policy.compute).T @ w_k).astype(policy.output)
    wall = time.perf_counter() - t0
    return EigResult(
        eigenvalues=evals_k.astype(policy.output),
        eigenvectors=x,
        tridiag=lres,
        wall_time_s=wall,
    )
