"""Phase 1 of the paper's eigensolver: the Lanczos algorithm (Algorithm 1).

Builds a Krylov basis V = [v_1 .. v_m] of a symmetric operator and the
tridiagonal matrix T = tridiag(beta, alpha, beta) whose eigenpairs
approximate the Top-K eigenpairs of the operator.

Mixed precision follows the paper exactly (§III-A): the basis V and the
carried vectors are kept in ``policy.storage``; SpMV accumulation and the
alpha / beta / re-orthogonalization reductions run in ``policy.compute`` —
or, per phase, in the policy's ``spmv`` / ``alpha_beta`` / ``reorth``
overrides (``core/precision.PHASES``), with every phase result rounded back
to the carried ``compute`` dtype at the phase boundary.

Re-orthogonalization modes:
  * ``"none"`` — plain three-term recurrence;
  * ``"half"`` — the paper's scheme (Alg. 1 lines 12-21): the new vector is
    re-orthogonalized against every *other* stored Lanczos vector
    (alternating parity), matching their quoted O(n K^2 / 2) cost;
  * ``"full"`` — classical full re-orthogonalization against all stored
    vectors (beyond-paper reference point).

The loop body is generic over an ``Ops`` record so the same code runs
single-device (plain reductions) and multi-device (psum reductions inside
``shard_map`` — see ``core/distributed.py``).

Three memory-roofline optimizations ride on the record (beyond-paper):

  * ``fused_update`` — the three-term recurrence + squared norm execute as
    ONE pass over the n-length vectors through the Pallas kernel in
    ``kernels/lanczos_update.py`` (policy-gated: compensated policies keep
    the reference reductions; f64 compute falls back to ``kernels/ref.py``
    inside the wrapper).
  * ``fused_iteration`` — the whole SpMV + alpha + update (+ norm) step in
    two passes over the Krylov vectors (``kernels/lanczos_fused.py`` chained
    with the update kernel); ELL operators only.
  * ``project_out`` — the masked re-orthogonalization casts the stored basis
    to the compute dtype ONCE per pass (coefficients and subtraction reuse
    the same masked cast) instead of materializing two full (m, n) copies.

Which of these actually runs is a **measured decision**: the engine's
:class:`~repro.kernels.engine.IterationPlan` (whole-iteration autotuner, or
its static mode table) routes the update via :func:`resolve_update_mode`.
``REPRO_FUSED_LANCZOS=0`` force-disables all fusion; ``=1`` force-enables the
fused update; ``REPRO_ITER_UPDATE`` pins the exact mode at the plan layer.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs import env as envcfg
from ..testing import faults as _faults
from .precision import PrecisionPolicy, compensated_sum

__all__ = [
    "LanczosResult",
    "NumericalBreakdown",
    "check_tridiag_health",
    "lanczos_tridiag",
    "lanczos_tridiag_multi",
    "make_local_ops",
    "ops_for_operator",
    "fused_update_enabled",
    "resolve_update_mode",
    "Ops",
]


class NumericalBreakdown(ArithmeticError):
    """The Lanczos recurrence produced values no downstream phase can use.

    ``kind`` is the breakdown taxonomy the recovery layer dispatches on:

    * ``"nonfinite"`` — NaN/Inf in alpha, beta, or the residual norm; the
      shape of low-precision overflow (bf16/fp8 rungs) or a poisoned SpMV.
      Recovery re-runs one precision rung up the ladder.
    * ``"beta_underflow"`` — beta collapsed to ~0 *before* the final step:
      the classical "lucky breakdown" (the start vector hit an invariant
      subspace too early).  Recovery re-seeds the start vector.

    ``iteration`` is the first offending step, ``policy`` the precision
    policy name the sweep ran under.
    """

    def __init__(self, kind: str, iteration: int, policy: Optional[str] = None, detail: str = ""):
        self.kind = kind
        self.iteration = iteration
        self.policy = policy
        self.recovery_trail: Optional[list] = None  # stamped when recovery gives up
        msg = f"Lanczos breakdown: {kind} at iteration {iteration}"
        if policy:
            msg += f" under policy {policy}"
        if detail:
            msg += f" ({detail})"
        super().__init__(msg)


def check_tridiag_health(result: "LanczosResult", policy: PrecisionPolicy) -> None:
    """Post-sweep health probe: raise :class:`NumericalBreakdown` instead of
    letting garbage flow into the Ritz phase.

    Cost is O(m) host work on the already-materialized tridiagonal scalars
    (the (m, n) basis is never touched), so it is ~free next to the sweep.
    ``beta_last`` is checked for non-finiteness only: a *small* final
    residual norm means the subspace converged, which is success, not
    breakdown.  Multi-start (vmapped) results are checked flattened.
    """
    import numpy as np

    pol = getattr(policy, "name", None) or str(policy)
    alpha = np.asarray(result.alpha, dtype=np.float64).reshape(-1)
    beta = np.asarray(result.beta, dtype=np.float64).reshape(-1)
    m = result.alpha.shape[-1]
    tiny = float(jnp.finfo(policy.effective().compute).tiny) * 1e3
    # A breakdown cascades (beta ~ 0 at step i makes alpha at step i+1
    # non-finite), so find the EARLIEST offending step across all checks —
    # that is the one whose kind the recovery layer must dispatch on.
    found = []  # (iteration, priority, kind, detail)
    bad = ~np.isfinite(alpha)
    if bad.any():
        j = int(np.argmax(bad))
        found.append((j % m, 0, "nonfinite", f"alpha[{j % m}]={alpha[j]!r}"))
    bad = ~np.isfinite(beta)
    if bad.any():
        j = int(np.argmax(bad))
        found.append((j % max(m - 1, 1), 0, "nonfinite", f"beta[{j % max(m - 1, 1)}]={beta[j]!r}"))
    if result.beta_last is not None:
        bl = np.asarray(result.beta_last, dtype=np.float64).reshape(-1)
        if not np.isfinite(bl).all():
            found.append((m - 1, 0, "nonfinite", "beta_last"))
    small = beta <= tiny
    if small.any():
        j = int(np.argmax(small))
        found.append(
            (j % max(m - 1, 1), 1, "beta_underflow", f"beta={beta[j]:.3e} <= {tiny:.3e}")
        )
    if found:
        i, _, kind, detail = min(found)
        raise NumericalBreakdown(kind, i, pol, detail)


class LanczosResult(NamedTuple):
    alpha: jax.Array  # (m,) compute dtype — diagonal of T
    beta: jax.Array  # (m-1,) compute dtype — off-diagonal of T
    basis: jax.Array  # (m, n) storage dtype — Lanczos vectors (V), row-major
    # norm of the residual after the final step: the scale of the classical
    # Ritz residual bound |beta_m * W[m-1, i]| used for convergence reporting
    beta_last: Optional[jax.Array] = None


@dataclasses.dataclass(frozen=True)
class Ops:
    """Arithmetic kernel set; distributed variants psum the reductions."""

    matvec: Callable[[jax.Array], jax.Array]  # storage-in, compute-out
    dot: Callable[[jax.Array, jax.Array], jax.Array]  # compute-dtype scalar
    gram: Callable[[jax.Array, jax.Array], jax.Array]  # (m,n)@(n,) -> (m,)
    # (basis, u, mask) -> u minus its projection onto the masked rows;
    # None falls back to the legacy gram-based two-cast path.
    project_out: Optional[Callable] = None
    # (w, v, v_prev, alpha, beta, need_norm) -> (w - alpha v - beta v_prev,
    # ||.||^2) in one memory pass; None keeps the separate recurrence + dot.
    # ``need_norm=False`` tells distributed variants the caller will discard
    # the norm (reorth recomputes beta), so they must not psum it.
    fused_update: Optional[Callable] = None
    # (v, v_prev, beta_prev, need_norm) -> (u, alpha, ||u||^2 or None): the
    # whole SpMV + alpha + three-term update step in two fused passes.  When
    # set it subsumes matvec/dot/fused_update in the loop body.
    fused_iteration: Optional[Callable] = None


def fused_update_enabled(policy: PrecisionPolicy) -> bool:
    """Policy gate for the fused Pallas update: compensated policies need
    the compensated reductions for beta, so they keep the reference path,
    and a per-phase ``alpha_beta`` override splits the fused norm's dtype
    away from the recurrence's, so it keeps the reference path too;
    ``REPRO_FUSED_LANCZOS=0`` is the kill switch."""
    if not envcfg.get_bool("REPRO_FUSED_LANCZOS"):
        return False
    if policy.compensated:
        return False
    return jnp.dtype(policy.phase_dtype("alpha_beta")) == jnp.dtype(policy.compute)


def resolve_update_mode(policy: PrecisionPolicy, plan=None, fused: Optional[bool] = None) -> str:
    """How the three-term update (and SpMV fusion) should run for this solve.

    Layered decision:
      1. an explicit ``fused=`` pin from the caller wins (legacy knob — e.g.
         the vmapped multi-start path pins ``False``);
      2. the policy gate (:func:`fused_update_enabled`, which also honors the
         ``REPRO_FUSED_LANCZOS=0`` kill switch) can force "unfused";
      3. ``REPRO_FUSED_LANCZOS=1`` *explicitly set* force-enables fusion (the
         pre-plan default behavior, kept for A/B runs);
      4. otherwise the engine's measured :class:`IterationPlan` decides, or —
         with no plan in scope — the static mode table for the execution mode
         (interpret -> unfused: the Pallas interpreter's per-grid-step
         overhead makes fused kernels lose there; compiled -> fused).
    """
    if fused is not None:
        return "fused" if (fused and fused_update_enabled(policy)) else "unfused"
    if not fused_update_enabled(policy):
        return "unfused"
    pin = (envcfg.get_str("REPRO_ITER_UPDATE") or "").strip().lower()
    if pin:
        # Same pin resolve_iteration_plan honors — re-checked here so it
        # also reaches warm sessions whose plan was built before the pin.
        from ..kernels.engine import ITER_UPDATE_MODES

        if pin not in ITER_UPDATE_MODES:
            raise ValueError(
                f"REPRO_ITER_UPDATE={pin!r}: expected one of {ITER_UPDATE_MODES}"
            )
        return pin
    env = (envcfg.raw("REPRO_FUSED_LANCZOS") or "").strip().lower()
    if env in ("1", "true", "on", "yes"):
        if plan is not None and plan.update != "unfused":
            return plan.update
        return "fused"
    if plan is not None:
        return plan.update
    from ..kernels.engine import table_update_mode
    from ..kernels.ops import default_interpret

    return table_update_mode(default_interpret())


def _local_reduce(x: jax.Array, policy: PrecisionPolicy, dtype=None) -> jax.Array:
    if policy.compensated:
        return compensated_sum(x.reshape(-1), dtype or policy.compute)
    return jnp.sum(x)


def _make_fused_iteration(operator, policy: PrecisionPolicy) -> Optional[Callable]:
    """Whole-iteration fused step for an ELL-backed operator, or None.

    Requires the operator to expose its :class:`DeviceELL` container and
    engine (``SparseOperator`` does), and the spmv-phase accumulation dtype
    to match the carried compute dtype — the kernel's in-pass alpha replaces
    ``dot(v, w)``, so a phase split there would change what alpha means.
    """
    eng = getattr(operator, "engine", None)
    mat = getattr(operator, "mat", None)
    if eng is None or mat is None or eng.format != "ell":
        return None
    from ..kernels import ops as kops  # lazy: core sits below kernels
    from ..sparse.formats import DeviceELL

    if not isinstance(mat, DeviceELL):
        return None
    cdt, sdt = policy.compute, policy.storage
    acc = jnp.dtype(policy.phase_dtype("spmv"))
    if acc != jnp.dtype(cdt):
        return None
    from ..kernels.engine import _fit_tile

    # Same divisibility clamp the engine's ell_matvec applies to its tiles.
    block_r = _fit_tile(eng.tiles.block_r, mat.val.shape[0])
    block_w = _fit_tile(eng.tiles.block_w, mat.val.shape[1])

    def fused_iteration(v, v_prev, beta, need_norm=True):
        # Pass 1: w = A v with alpha = <v, w> folded into the width sweep.
        w, alpha = kops.spmv_ell_alpha(
            mat,
            v.astype(sdt),
            v,
            accum_dtype=acc,
            block_r=block_r,
            block_w=block_w,
            interpret=eng.interpret,
        )
        alpha = alpha.astype(cdt)
        # Pass 2: three-term update + squared norm in one pass.
        u, nrm = kops.lanczos_update(w.astype(cdt), v, v_prev, alpha, beta, accum_dtype=cdt)
        return u, alpha, nrm

    return fused_iteration


def make_local_ops(
    matvec: Callable,
    policy: PrecisionPolicy,
    fused: Optional[bool] = None,
    plan=None,
    operator=None,
) -> Ops:
    """Single-device ops: plain reductions in the per-phase compute dtypes
    (``alpha_beta`` for dot, ``reorth`` for gram/project_out); every result
    is cast back to the carried ``compute`` dtype, so a policy with no phase
    overrides is bit-identical to the pre-phase uniform arithmetic.

    ``plan`` (an :class:`~repro.kernels.engine.IterationPlan`) and the legacy
    ``fused`` pin route the update through :func:`resolve_update_mode`;
    ``operator`` enables the fully-fused SpMV+alpha pass when the plan asks
    for it and the operator exposes an ELL layout.
    """
    cdt = policy.compute
    abdt = policy.phase_dtype("alpha_beta")
    rdt = policy.phase_dtype("reorth")

    def dot(a, b):
        return _local_reduce(a.astype(abdt) * b.astype(abdt), policy, abdt).astype(cdt)

    def gram(vs, u):
        return (vs.astype(rdt) @ u.astype(rdt)).astype(cdt)

    def project_out(basis, u, mask):
        basis_c = basis.astype(rdt) * mask.astype(rdt)[:, None]  # ONE (m, n) cast
        # u rounds through the storage dtype before the coefficient dot —
        # the same policy semantics the legacy gram path applied (the
        # fig4 precision ablation measures exactly this rounding).
        coeffs = basis_c @ u.astype(policy.storage).astype(rdt)
        return (u.astype(rdt) - coeffs @ basis_c).astype(cdt)

    mode = resolve_update_mode(policy, plan=plan, fused=fused)
    fused_iteration = None
    if mode == "fused_spmv":
        fused_iteration = _make_fused_iteration(operator, policy)
        if fused_iteration is None:
            mode = "fused"  # operator can't supply the fused pass: next rung
    fused_update = None
    if mode in ("fused", "fused_spmv") and fused_iteration is None:
        from ..kernels import ops as kops  # lazy: core sits below kernels

        def fused_update(w, v, v_prev, alpha, beta, need_norm=True):
            return kops.lanczos_update(w, v, v_prev, alpha, beta, accum_dtype=cdt)

    return Ops(
        matvec=matvec, dot=dot, gram=gram, project_out=project_out,
        fused_update=fused_update, fused_iteration=fused_iteration,
    )


def ops_for_operator(operator, policy: PrecisionPolicy, fused: Optional[bool] = None) -> Ops:
    """Ops for a :class:`LinearOperator`, routed by its engine's measured
    :class:`IterationPlan` (operators without an engine fall back to the
    static mode table)."""
    eng = getattr(operator, "engine", None)
    plan = getattr(eng, "iteration_plan", None)
    return make_local_ops(
        operator.bound_matvec(policy), policy, fused=fused, plan=plan, operator=operator
    )


def _reorth_mask(m: int, i: jax.Array, mode: str, dtype) -> jax.Array:
    """Mask over stored vector indices j (0-based) used for re-orth at step i."""
    j = jnp.arange(m)
    stored = j <= i  # vectors written so far (includes current v_i)
    if mode == "none":
        return jnp.zeros((m,), dtype)
    if mode == "half":
        # Paper's parity scheme (Alg. 1 lines 13-18): re-orthogonalize
        # against the odd-indexed (1-based) half of the basis.  Cost: the
        # paper's quoted O(n K^2 / 2).
        return (stored & (j % 2 == 0)).astype(dtype)
    if mode == "half_alt":
        # Variant: alternate the parity with the step index (both halves
        # cleaned on consecutive steps).  Empirically less stable -- see
        # EXPERIMENTS.md SReorth.
        return (stored & (j % 2 == i % 2)).astype(dtype)
    if mode in ("full", "full2"):
        return stored.astype(dtype)
    raise ValueError(f"unknown reorth mode {mode!r}")


@partial(jax.jit, static_argnames=("ops", "num_iters", "policy", "reorth", "fault_key"))
def _lanczos_jit(v1, ops: Ops, num_iters: int, policy: PrecisionPolicy, reorth: str, fault_key=None):
    # fault_key is unused in the computation: it exists so an armed fault
    # (read at trace time inside the loop body) retraces under its own cache
    # key and the poisoned executable never shadows the clean one.
    return _lanczos_loop(v1, ops, num_iters, policy, reorth)


def _lanczos_loop(
    v1,
    ops: Ops,
    num_iters: int,
    policy: PrecisionPolicy,
    reorth: str,
    host_loop: bool = False,
    checkpoint=None,
):
    m = num_iters
    n = v1.shape[0]
    cdt, sdt = policy.compute, policy.storage
    tiny = jnp.asarray(jnp.finfo(cdt).tiny * 1e3, cdt)

    v1 = v1.astype(cdt)
    v1 = v1 / jnp.sqrt(ops.dot(v1, v1))

    basis0 = jnp.zeros((m, n), sdt)
    alphas0 = jnp.zeros((m,), cdt)
    betas0 = jnp.zeros((m,), cdt)

    def body(i, carry):
        basis, alphas, betas, v_prev, w, beta_prev = carry
        # --- normalize the incoming vector (paper lines 5-7) ---
        v = jnp.where(i == 0, v1, w / jnp.maximum(beta_prev, tiny))
        basis = jax.lax.dynamic_update_slice(basis, v.astype(sdt)[None, :], (i, 0))
        nrm_sq = None
        if ops.fused_iteration is not None:
            # --- lines 9-11 in two fused passes: SpMV + alpha in one kernel,
            # update + norm in the other (each Krylov vector read once) ---
            u, alpha, fused_nrm = ops.fused_iteration(
                v, v_prev, beta_prev, need_norm=(reorth == "none")
            )
            u = _faults.tap_spmv(u, i)
            alphas = alphas.at[i].set(alpha)
            if reorth == "none":
                nrm_sq = fused_nrm
        else:
            # --- projection (line 9): SpMV in compute precision ---
            u = ops.matvec(v.astype(sdt)).astype(cdt)
            u = _faults.tap_spmv(u, i)
            # --- alpha (line 10): sync point A ---
            alpha = ops.dot(v, u)
            alphas = alphas.at[i].set(alpha)
            # --- three-term recurrence (line 11): one fused memory pass when
            # the plan asks for it (the kernel also yields ||u||^2 for free) ---
            if ops.fused_update is not None:
                u, fused_nrm = ops.fused_update(
                    u, v, v_prev, alpha, beta_prev, need_norm=(reorth == "none")
                )
                if reorth == "none":
                    nrm_sq = fused_nrm
            else:
                u = u - alpha * v - beta_prev * v_prev
        # --- re-orthogonalization (lines 12-21): sync point C ---
        if reorth != "none":
            mask = _reorth_mask(m, i, reorth, cdt)
            passes = 2 if reorth == "full2" else 1  # CGS2: "twice is enough"
            for _ in range(passes):
                if ops.project_out is not None:
                    u = ops.project_out(basis, u, mask)
                else:
                    coeffs = ops.gram(basis, u.astype(sdt)) * mask  # (m,)
                    u = u - coeffs @ basis.astype(cdt)
        # --- beta (line 6, next iteration): sync point B ---
        if nrm_sq is not None:
            beta = jnp.sqrt(jnp.maximum(nrm_sq.astype(cdt), 0.0))
        else:
            beta = jnp.sqrt(jnp.maximum(ops.dot(u, u), 0.0))
        beta = _faults.tap_beta(beta, i)
        betas = betas.at[i].set(beta)
        return (basis, alphas, betas, v, u, beta)

    init = (basis0, alphas0, betas0, jnp.zeros((n,), cdt), jnp.zeros((n,), cdt), jnp.zeros((), cdt))
    if host_loop:
        # Eager Python loop: required by operators whose matvec must execute
        # host-side per step (ChunkedOperator streams chunks through the
        # device; tracing it would bake every chunk into one executable and
        # defeat the bounded-residency staging).
        carry = init
        start = 0
        ckpt_op = None
        if checkpoint is not None:
            store, token, every, *rest = checkpoint
            # Optional 4th element: a ChunkedOperator whose streamed matvec
            # checkpoints its *chunk cursor* mid-step — a crash between chunk
            # stagings inside one step no longer loses the whole step.
            ckpt_op = rest[0] if rest else None
            if ckpt_op is not None and not hasattr(ckpt_op, "set_resume"):
                ckpt_op = None
            state = store.load(token)
            if (
                state is not None
                and state.get("engine") == "lanczos"
                and int(state.get("n", -1)) == n
                and int(state.get("m", -1)) == m
            ):
                carry = (
                    jnp.asarray(state["basis"], sdt),
                    jnp.asarray(state["alphas"], cdt),
                    jnp.asarray(state["betas"], cdt),
                    jnp.asarray(state["v_prev"], cdt),
                    jnp.asarray(state["w"], cdt),
                    jnp.asarray(state["beta_prev"], cdt),
                )
                if state.get("chunk") is not None and ckpt_op is not None:
                    # Mid-step snapshot: the carry above is the STEP-START
                    # carry of step i; re-enter step i with the matvec armed
                    # to skip already-accumulated chunks.  Chunk order is
                    # fixed, so the resumed sweep is bit-identical.
                    start = int(state["i"])
                    ckpt_op.set_resume(
                        int(state["chunk"]) + 1, jnp.asarray(state["partial"])
                    )
                else:
                    start = int(state["i"]) + 1
        ck_chunk_every = 0
        if ckpt_op is not None and getattr(ckpt_op, "num_chunks", 1) > 1:
            from ..configs import env as _envcfg

            ck_chunk_every = _envcfg.get_int("REPRO_CHUNK_CKPT_EVERY")
        for i in range(start, m):
            if ck_chunk_every > 0:
                basis_s, alphas_s, betas_s, v_prev_s, w_s, beta_prev_s = carry

                def _chunk_hook(c, partial, _i=i):
                    if (c + 1) % ck_chunk_every or c + 1 >= ckpt_op.num_chunks:
                        return  # end-of-step save covers the final chunk
                    store.save(
                        token,
                        {
                            "engine": "lanczos",
                            "i": _i,
                            "n": n,
                            "m": m,
                            "chunk": c,
                            "partial": partial,
                            "basis": basis_s,
                            "alphas": alphas_s,
                            "betas": betas_s,
                            "v_prev": v_prev_s,
                            "w": w_s,
                            "beta_prev": beta_prev_s,
                        },
                    )

                ckpt_op.set_step_hook(_chunk_hook)
            try:
                carry = body(i, carry)
            finally:
                if ck_chunk_every > 0:
                    ckpt_op.set_step_hook(None)
            if checkpoint is not None and (i + 1) % every == 0 and i + 1 < m:
                basis_c, alphas_c, betas_c, v_prev_c, w_c, beta_prev_c = carry
                store.save(
                    token,
                    {
                        "engine": "lanczos",
                        "i": i,
                        "n": n,
                        "m": m,
                        "basis": basis_c,
                        "alphas": alphas_c,
                        "betas": betas_c,
                        "v_prev": v_prev_c,
                        "w": w_c,
                        "beta_prev": beta_prev_c,
                    },
                )
        if checkpoint is not None:
            store.clear(token)
        basis, alphas, betas = carry[:3]
    else:
        basis, alphas, betas, _, _, _ = jax.lax.fori_loop(0, m, body, init)
    return LanczosResult(
        alpha=alphas, beta=betas[: m - 1], basis=basis, beta_last=betas[m - 1]
    )


def lanczos_tridiag(
    matvec: Callable,
    v1: jax.Array,
    num_iters: int,
    policy: PrecisionPolicy,
    reorth: str = "half",
    ops: Optional[Ops] = None,
    jit: bool = True,
    checkpoint=None,
) -> LanczosResult:
    """Run ``num_iters`` Lanczos steps. See module docstring.

    ``jit=False`` runs an eager host loop (no ``fori_loop``), letting the
    matvec perform host-side work per iteration — the out-of-core engine's
    mode (see :class:`~repro.core.operators.ChunkedOperator`).  Only that
    host loop honors ``checkpoint`` — a ``(store, token, every)`` triple
    (see :class:`~repro.serving.store.SolveCheckpoint`) snapshotting the
    loop carry every ``every`` completed steps and resuming from the last
    snapshot bit-identically.
    """
    policy = policy.effective()
    _faults.check_sweep_entry()
    ops = ops or make_local_ops(matvec, policy)
    if jit:
        fault_key = _faults.trace_key()
        res = _lanczos_jit(v1, ops, num_iters, policy, reorth, fault_key=fault_key)
        _faults.consume_lanczos(fault_key)
        return res
    return _lanczos_loop(v1, ops, num_iters, policy, reorth, host_loop=True, checkpoint=checkpoint)


@partial(jax.jit, static_argnames=("ops", "num_iters", "policy", "reorth", "fault_key"))
def _lanczos_vmap(v1s, ops: Ops, num_iters: int, policy: PrecisionPolicy, reorth: str, fault_key=None):
    return jax.vmap(lambda v: _lanczos_loop(v, ops, num_iters, policy, reorth))(v1s)


def lanczos_tridiag_multi(
    matvec: Callable,
    v1s: jax.Array,
    num_iters: int,
    policy: PrecisionPolicy,
    reorth: str = "half",
    ops: Optional[Ops] = None,
) -> LanczosResult:
    """Vmapped multi-start Lanczos: ``v1s`` is (s, n) start vectors; every
    field of the result gains a leading start axis ((s, m) alpha, (s, m, n)
    basis, ...).  One compiled sweep builds all s bases — the batched-serving
    path for many-query workloads that differ only in their start vector
    (``api/session.py``).  The fused Pallas update is not used here (the
    batching rule of the interpreter path is unvalidated); callers gate
    vmappability of the *matvec* (dense / COO segment-sum are safe).
    """
    policy = policy.effective()
    _faults.check_sweep_entry()
    ops = ops or make_local_ops(matvec, policy, fused=False)
    fault_key = _faults.trace_key()
    res = _lanczos_vmap(v1s, ops, num_iters, policy, reorth, fault_key=fault_key)
    _faults.consume_lanczos(fault_key)
    return res
