"""Multi-device Top-K eigensolver (the paper's §III-A partition scheme).

Mapping of the paper's multi-GPU design onto a JAX device mesh:

  paper                                 | here
  --------------------------------------+----------------------------------
  row partitions balanced by nnz        | ``core/partition.py`` (same greedy
                                        | prefix scheme), shards stacked on a
                                        | leading axis consumed by shard_map
  every vector partitioned like M       | vectors live as (n_pad,) locals
  SpMV input v_i replicated per GPU     | ``lax.all_gather(..., tiled=True)``
  round-robin partition swap to refill  | the all-gather's ring schedule on
  the replicas (their Fig. 1 C)         | the ICI torus *is* that round-robin
  sync points alpha / beta (A / B)      | two ``lax.psum`` per iteration
  reorth sync (C)                       | one psum per reorth pass (k-vector)
  out-of-core unified memory            | ChunkedOperator (operators.py)

The entire Lanczos loop executes inside ONE ``shard_map`` region, so the
only cross-device traffic per iteration is: 1 all-gather (n floats) +
2 scalar psums + (optionally) 1 k-length psum — matching the paper's
communication analysis.

Per-shard SpMV runs through the :class:`~repro.kernels.engine.SpmvEngine`
layer: each shard's COO slice is converted host-side to ELL, blocked-ELL,
or the hybrid hub split (``sparse.formats.shard_to_*``) and the Lanczos hot
loop calls the Pallas kernels (interpret mode off-TPU).  ``spmv_format=
"auto"`` picks ELL vs BSR vs hybrid from per-shard statistics — hybrid keeps
power-law shards on the kernel path by capping the ELL width and spilling
hub overflow to a small ``segment_sum`` tail; plain COO remains only as an
explicit opt-out (``spmv_format="coo"``).
"""

from __future__ import annotations

import time
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..kernels.engine import SpmvEngine, make_engine, shard_stats
from ..sparse.formats import CSR, shard_to_blocked_ell, shard_to_ell, shard_to_hybrid
from .eigensolver import EigResult
from .jacobi import jacobi_eigh_host, tridiag_to_dense
from ..testing import faults as _faults
from .lanczos import (
    LanczosResult,
    Ops,
    _lanczos_loop,
    check_tridiag_health,
    resolve_update_mode,
)
from .partition import PartitionedMatrix, nnz_balanced_splits, partition_matrix
from .precision import PrecisionPolicy, FDF, compensated_sum

__all__ = [
    "DISTRIBUTED_FORMATS",
    "PreparedShards",
    "prepare_sharded",
    "ShardedSolveOutput",
    "solve_sharded",
    "topk_eigs_sharded",
    "sharded_lanczos",
]

# Formats the distributed hot loop may auto-select: kernel-backed only (the
# paper's design point; hybrid's tail segment_sum is bounded by the hub
# split, so it still counts).  "coo" stays available as an explicit request.
DISTRIBUTED_FORMATS = ("ell", "bsr", "hybrid")

# jax.shard_map is top-level (with check_vma) only on newer jax; fall back to
# the jax.experimental spelling (check_rep) so the engine runs on both.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _SHARD_MAP_KW = {"check_vma": False}
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _SHARD_MAP_KW = {"check_rep": False}


def _make_sharded_ops(
    mats: tuple,
    n_pad: int,
    policy: PrecisionPolicy,
    axis: str,
    engine: Optional[SpmvEngine] = None,
) -> Ops:
    cdt = policy.compute
    abdt = policy.phase_dtype("alpha_beta")  # alpha/beta reduction phase
    rdt = policy.phase_dtype("reorth")  # re-orthogonalization phase
    sdt_spmv = policy.phase_dtype("spmv")  # SpMV accumulator phase
    fmt = engine.format if engine is not None else "coo"

    def matvec(x_local):
        # Replicate the SpMV input: the paper's round-robin partition swap.
        x_full = jax.lax.all_gather(x_local, axis, tiled=True)  # (G * n_pad,)
        if fmt == "ell":
            val, col = mats
            return engine.ell_matvec(val, col, x_full)[:n_pad].astype(cdt)
        if fmt == "bsr":
            val, bcol = mats
            return engine.bsr_matvec(val, bcol, x_full)[:n_pad].astype(cdt)
        if fmt == "hybrid":
            val, col, trow, tcol, tval = mats
            y = engine.hybrid_matvec(val, col, trow, tcol, tval, x_full, n_pad)
            return y.astype(cdt)
        row, col, val = mats
        prod = val.astype(sdt_spmv) * jnp.take(x_full, col).astype(sdt_spmv)
        return jax.ops.segment_sum(prod, row, num_segments=n_pad).astype(cdt)

    def dot(a, b):
        prods = a.astype(abdt) * b.astype(abdt)
        local = compensated_sum(prods, abdt) if policy.compensated else jnp.sum(prods)
        return jax.lax.psum(local, axis).astype(cdt)  # sync point A / B

    def gram(vs, u):
        local = vs.astype(rdt) @ u.astype(rdt)
        return jax.lax.psum(local, axis).astype(cdt)  # sync point C

    def project_out(vs, u, mask):
        vs_c = vs.astype(rdt) * mask.astype(rdt)[:, None]  # ONE (m, n_pad) cast
        # u rounds through the storage dtype first — legacy gram-path policy
        # semantics (see make_local_ops.project_out).
        local = vs_c @ u.astype(policy.storage).astype(rdt)
        coeffs = jax.lax.psum(local, axis)  # sync point C
        return (u.astype(rdt) - coeffs @ vs_c).astype(cdt)

    plan = getattr(engine, "iteration_plan", None) if engine is not None else None
    mode = resolve_update_mode(policy, plan=plan)
    fused_iteration = None
    if mode == "fused_spmv" and fmt == "ell" and jnp.dtype(sdt_spmv) == jnp.dtype(cdt):
        from ..kernels import ops as kops
        from ..kernels.engine import _fit_tile
        from ..kernels.lanczos_fused import spmv_ell_alpha_kernel_call

        val, col = mats
        rows = val.shape[0]  # local padded rows (>= n_pad)
        block_r = _fit_tile(engine.tiles.block_r, rows)
        block_w = _fit_tile(engine.tiles.block_w, val.shape[1])
        acc = jnp.dtype(sdt_spmv)

        def fused_iteration(v, v_prev, beta, need_norm=True):
            x_full = jax.lax.all_gather(v.astype(policy.storage), axis, tiled=True)
            vpad = jnp.pad(v, (0, rows - n_pad)) if rows > n_pad else v
            w, a_loc = spmv_ell_alpha_kernel_call(
                val, col, x_full, vpad,
                block_r=block_r, block_w=block_w,
                accum_dtype=acc, interpret=engine.interpret,
            )
            # Sync point A, dispatched immediately so XLA's scheduler can
            # overlap it with the local SpMV tail below: the beta term of the
            # three-term update needs no alpha, so ``t`` computes while the
            # alpha partials are on the wire.  (Association differs from the
            # single-device path — (w - beta v_prev) - alpha v — an accepted
            # last-ulp tradeoff for the overlap.)
            alpha = jax.lax.psum(a_loc[0], axis).astype(cdt)
            t = w[:n_pad].astype(cdt) - beta * v_prev
            u, nrm_sq = kops.lanczos_update(
                t, v, v, alpha, jnp.zeros((), cdt), accum_dtype=cdt
            )
            if need_norm:
                nrm_sq = jax.lax.psum(nrm_sq, axis)  # sync point B
            # Two collectives per iteration — the paper's 2-psum budget holds.
            return u, alpha, nrm_sq

    fused_update = None
    if fused_iteration is None and mode in ("fused", "fused_spmv"):
        from ..kernels import ops as kops

        def fused_update(w, v, v_prev, alpha, beta, need_norm=True):
            u, nrm_sq = kops.lanczos_update(w, v, v_prev, alpha, beta, accum_dtype=cdt)
            # Only pay the collective when the caller will use the norm
            # (under reorth the loop recomputes beta post-projection, and an
            # extra psum per iteration would break the paper's sync budget).
            if need_norm:
                nrm_sq = jax.lax.psum(nrm_sq, axis)  # sync point B
            return u, nrm_sq

    return Ops(
        matvec=matvec, dot=dot, gram=gram, project_out=project_out,
        fused_update=fused_update, fused_iteration=fused_iteration,
    )


def sharded_lanczos(
    pm: PartitionedMatrix,
    v1_padded: jax.Array,
    num_iters: int,
    policy: PrecisionPolicy,
    mesh: Mesh,
    reorth: str = "full",
    axis: str = "data",
    engine: Optional[SpmvEngine] = None,
    mats: Optional[tuple] = None,
) -> LanczosResult:
    """Run the distributed Lanczos loop. ``v1_padded``: (G, n_pad) layout.

    ``mats`` are the shard-stacked SpMV arrays matching ``engine.format``
    (default: the COO triplets of ``pm`` — the legacy segment-sum path).
    """
    policy = policy.effective()
    _faults.check_sweep_entry()
    if mats is None:
        mats = (pm.row, pm.col, pm.val)

    def local_fn(v1, *shard_mats):
        v1 = v1[0]  # drop shard axis
        local = tuple(m[0] for m in shard_mats)
        ops = _make_sharded_ops(local, pm.n_pad, policy, axis, engine=engine)
        res = _lanczos_loop(v1, ops, num_iters, policy, reorth)
        return res.alpha, res.beta, res.beta_last, res.basis[None]  # re-add shard axis

    fn = _shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(axis),) * (1 + len(mats)),
        out_specs=(P(), P(), P(), P(axis, None, None)),
        **_SHARD_MAP_KW,
    )
    alpha, beta, beta_last, basis_sh = jax.jit(fn)(v1_padded, *mats)
    # The wrapper re-traces per call (fresh jit object), so an armed Lanczos
    # fault is baked into this launch; count it host-side (see faults docs).
    _faults.consume_lanczos(_faults.trace_key())
    return LanczosResult(alpha=alpha, beta=beta, basis=basis_sh, beta_last=beta_last)


class PreparedShards(NamedTuple):
    """Plan-time product of the distributed engine: the nnz-balanced row
    partition, the shard-stacked SpMV arrays in the engine's chosen kernel
    format, and the engine itself (tiles + accum dtype).  Building one is
    the entire per-matrix setup cost of :func:`solve_sharded`; reusing it
    across solves (``api/session.py``) skips every host-side conversion."""

    pm: PartitionedMatrix
    mats: tuple  # shard-stacked SpMV arrays matching engine.format
    engine: SpmvEngine
    spmv_meta: dict  # engine.describe() + conversion stats
    convert_s: float  # one-time partition + conversion wall time


def prepare_sharded(
    csr: CSR,
    g: int,
    policy: PrecisionPolicy = FDF,
    spmv_format: str = "auto",
    engine: Optional[SpmvEngine] = None,
) -> PreparedShards:
    """Partition + convert a CSR for a ``g``-shard distributed solve.

    This is the *plan* half of :func:`solve_sharded`: everything here is a
    pure function of (matrix, shard count, storage/compute dtypes, format)
    and none of it depends on the query (k, num_iters, tol, start vector),
    so one ``PreparedShards`` amortizes across arbitrarily many solves.
    """
    policy = policy.effective()
    t0 = time.perf_counter()
    splits = nnz_balanced_splits(csr.indptr, g)
    if engine is None:
        allowed = DISTRIBUTED_FORMATS if spmv_format == "auto" else ("coo",) + DISTRIBUTED_FORMATS
        engine = make_engine(
            csr,
            spmv_format,
            stats=shard_stats(csr, splits, with_blocks=(spmv_format == "auto")),
            accum_dtype=policy.phase_dtype("spmv"),
            allowed=allowed,
            storage_dtype=policy.storage,
        )
    fmt = engine.format
    row_align = {
        "ell": engine.tiles.block_r,
        "hybrid": engine.tiles.block_r,
        "bsr": engine.tiles.block_size,
    }.get(fmt, 1)
    pm = partition_matrix(
        csr, g, dtype=policy.storage, row_align=row_align, with_coo=(fmt == "coo"),
        splits=splits,
    )
    spmv_meta = engine.describe()
    if fmt == "ell":
        ell_val, ell_col, conv_stats = shard_to_ell(
            csr,
            pm.splits(),
            pm.n_pad,
            dtype=policy.storage,
            row_tile=engine.tiles.block_r,
            slot_tile=128,
        )
        mats = (ell_val, ell_col)
        spmv_meta.update(conv_stats)
    elif fmt == "bsr":
        bsr_val, bsr_bcol, conv_stats = shard_to_blocked_ell(
            csr,
            pm.splits(),
            pm.n_pad,
            block_size=engine.tiles.block_size,
            dtype=policy.storage,
        )
        mats = (bsr_val, bsr_bcol)
        spmv_meta.update(conv_stats)
    elif fmt == "hybrid":
        mats, conv_stats = shard_to_hybrid(
            csr,
            pm.splits(),
            pm.n_pad,
            dtype=policy.storage,
            width_cap=max(s.hyb_width for s in engine.stats) if engine.stats else None,
            row_tile=engine.tiles.block_r,
        )
        spmv_meta.update(conv_stats)
    else:
        mats = (pm.row, pm.col, pm.val)
    return PreparedShards(
        pm=pm,
        mats=mats,
        engine=engine,
        spmv_meta=spmv_meta,
        convert_s=time.perf_counter() - t0,
    )


class ShardedSolveOutput(NamedTuple):
    """Raw engine output consumed by the ``eigsh`` frontend."""

    eigenvalues: jax.Array  # (k,) output dtype
    eigenvectors: jax.Array  # (n, k) output dtype
    residuals: np.ndarray  # (k,) float64 — Ritz residual bounds
    eigenvalues_f64: np.ndarray  # (k,) float64 — pre-output-cast, for tol checks
    tridiag: LanczosResult
    iterations: int
    partition: dict  # num_shards / n_pad / splits / axis / spmv
    timings: dict
    spmv_format: tuple = ()  # per-shard executed SpMV format


def solve_sharded(
    csr: CSR,
    k: int,
    mesh: Mesh,
    policy: PrecisionPolicy = FDF,
    reorth: str = "full",
    num_iters: Optional[int] = None,
    seed: int = 0,
    axis: str = "data",
    v1: Optional[jax.Array] = None,
    spmv_format: str = "auto",
    engine: Optional[SpmvEngine] = None,
    prepared: Optional[PreparedShards] = None,
    probe: bool = True,
) -> ShardedSolveOutput:
    """End-to-end distributed Top-K eigensolver on a 1-axis mesh.

    ``spmv_format``: "auto" picks ELL vs blocked-ELL per shard statistics
    (kernel-backed hot loop, the paper's design); "ell" / "bsr" force a
    kernel layout; "coo" opts back into the ``segment_sum`` reference path.
    A prebuilt ``engine`` overrides ``spmv_format``; a ``prepared``
    :class:`PreparedShards` (see :func:`prepare_sharded`) skips the whole
    plan phase — partition, conversion, tile selection — entirely.
    """
    policy = policy.effective()
    g = mesh.shape[axis]
    m = num_iters or k

    t_conv0 = time.perf_counter()
    if prepared is None:
        prepared = prepare_sharded(csr, g, policy, spmv_format, engine=engine)
        t_convert = prepared.convert_s
    else:
        t_convert = 0.0  # plan reused: this call pays no conversion
    pm, mats = prepared.pm, prepared.mats
    engine, spmv_meta = prepared.engine, dict(prepared.spmv_meta)
    fmt = engine.format

    if v1 is None:
        rng = np.random.default_rng(seed)
        v1 = jnp.asarray(rng.standard_normal(csr.n), dtype=policy.compute)
    v1p = pm.pad_vector(jnp.asarray(v1, dtype=policy.compute))

    t0 = time.perf_counter()
    lres = sharded_lanczos(
        pm, v1p, m, policy, mesh, reorth=reorth, axis=axis, engine=engine, mats=mats
    )
    lres = jax.tree.map(lambda a: a.block_until_ready(), lres)  # timings = execution, not dispatch
    if probe:
        check_tridiag_health(lres, policy)
    t_lanczos = time.perf_counter() - t0
    t1 = time.perf_counter()
    alpha = np.asarray(lres.alpha, dtype=np.float64)
    beta = np.asarray(lres.beta, dtype=np.float64)
    evals, w = jacobi_eigh_host(np.asarray(tridiag_to_dense(jnp.asarray(alpha), jnp.asarray(beta))))
    t_jacobi = time.perf_counter() - t1

    # X = V^T W on the padded layout, then strip padding.
    t2 = time.perf_counter()
    basis = lres.basis  # (G, m, n_pad) shard-stacked
    rzdt = policy.phase_dtype("ritz")  # Ritz-extraction phase dtype
    w_k = jnp.asarray(w[:, :k], dtype=rzdt)
    x_pad = jnp.einsum("gmn,mk->gnk", basis.astype(rzdt), w_k)
    parts = []
    splits = pm.splits()
    for s in range(g):
        lo, hi = int(splits[s]), int(splits[s + 1])
        parts.append(x_pad[s, : hi - lo, :])
    x = jnp.concatenate(parts, axis=0).astype(policy.output)
    x.block_until_ready()
    t_project = time.perf_counter() - t2

    beta_m = float(np.asarray(lres.beta_last, dtype=np.float64))
    residuals = np.abs(beta_m * np.asarray(w, dtype=np.float64)[m - 1, :k])
    total = time.perf_counter() - t_conv0  # includes host-side format conversion
    return ShardedSolveOutput(
        eigenvalues=jnp.asarray(evals[:k], dtype=policy.output),
        eigenvectors=x,
        residuals=residuals,
        eigenvalues_f64=np.asarray(evals[:k], dtype=np.float64),
        tridiag=lres,
        iterations=m,
        partition={
            "num_shards": int(g),
            "n_pad": int(pm.n_pad),
            "splits": [int(s) for s in splits],
            "axis": axis,
            "spmv": spmv_meta,
        },
        timings={
            "convert_s": t_convert,
            "lanczos_s": t_lanczos,
            "jacobi_s": t_jacobi,
            "project_s": t_project,
            "total_s": total,
        },
        spmv_format=(fmt,) * int(g),
    )


def topk_eigs_sharded(
    csr: CSR,
    k: int,
    mesh: Mesh,
    policy: PrecisionPolicy = FDF,
    reorth: str = "full",
    num_iters: Optional[int] = None,
    seed: int = 0,
    axis: str = "data",
) -> EigResult:
    """Deprecated: use :func:`repro.api.eigsh` with ``backend="distributed"``."""
    warnings.warn(
        "topk_eigs_sharded is deprecated; use "
        "repro.api.eigsh(csr, k, backend='distributed', mesh=mesh, ...)",
        DeprecationWarning,
        stacklevel=2,
    )
    from ..api import eigsh

    res = eigsh(
        csr,
        k,
        policy=policy,
        backend="distributed",
        reorth=reorth,
        num_iters=num_iters,
        seed=seed,
        mesh=mesh,
        axis=axis,
    )
    return EigResult(
        eigenvalues=res.eigenvalues,
        eigenvectors=res.eigenvectors,
        tridiag=res.tridiag,
        wall_time_s=res.timings["total_s"],
    )
