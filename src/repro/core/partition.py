"""nnz-balanced row partitioning (paper §III-A).

The paper partitions the input matrix by *balancing the number of non-zero
elements per device* (not rows), partitions every vector the same way, and
replicates only the SpMV input vector.  SPMD execution additionally requires
every shard to carry identically-shaped arrays, so we:

  1. choose split rows so each shard holds ~nnz/G non-zeros (greedy prefix
     split on the CSR row-pointer — exactly the paper's scheme);
  2. pad each shard to the maximum local row count ``n_pad`` and the maximum
     local nnz (padding entries have val=0 → contribute nothing);
  3. remap column indices into the *padded global* coordinate system
     ``g = shard * n_pad + local_row`` so the all-gathered replicated vector
     can be indexed directly.

``PartitionedMatrix`` stacks the shards on a leading axis of size G, ready to
be consumed by ``shard_map`` with ``P('data')`` on that axis.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sparse.formats import CSR, padded_col_map

__all__ = ["PartitionedMatrix", "nnz_balanced_splits", "partition_matrix"]


def nnz_balanced_splits(indptr: np.ndarray, num_shards: int) -> np.ndarray:
    """Row split points so each shard gets ~equal nnz. Returns (G+1,) rows."""
    nnz = int(indptr[-1])
    targets = (np.arange(1, num_shards) * nnz) / num_shards
    cuts = np.searchsorted(indptr, targets, side="left")
    splits = np.concatenate([[0], cuts, [len(indptr) - 1]]).astype(np.int64)
    # Ensure monotone non-decreasing (degenerate cases: empty shards allowed).
    return np.maximum.accumulate(splits)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PartitionedMatrix:
    """G row-shards of a square sparse matrix in padded-COO form.

    Arrays are stacked along a leading shard axis (G, ...):
      row: (G, nnz_pad) int32   local row index within the shard
      col: (G, nnz_pad) int32   *padded-global* column index (see module doc)
      val: (G, nnz_pad) float   0.0 on padding slots
    """

    row: jax.Array
    col: jax.Array
    val: jax.Array
    n: int  # logical size (static)
    n_pad: int  # padded rows per shard (static)
    num_shards: int  # G (static)

    def tree_flatten(self):
        return (self.row, self.col, self.val), (self.n, self.n_pad, self.num_shards)

    @classmethod
    def tree_unflatten(cls, aux, children):
        row, col, val = children
        return cls(*children, *aux)

    # --- vector layout helpers (host/NumPy and device/jnp both supported) ---
    def splits(self) -> np.ndarray:
        return self._splits

    def pad_vector(self, x) -> jax.Array:
        """(n,) logical -> (G, n_pad) padded-shard layout."""
        xp = jnp.zeros((self.num_shards, self.n_pad), dtype=x.dtype)
        for s in range(self.num_shards):
            lo, hi = int(self._splits[s]), int(self._splits[s + 1])
            xp = xp.at[s, : hi - lo].set(x[lo:hi])
        return xp

    def unpad_vector(self, xp: jax.Array) -> jax.Array:
        """(G, n_pad) padded -> (n,) logical."""
        parts = []
        for s in range(self.num_shards):
            lo, hi = int(self._splits[s]), int(self._splits[s + 1])
            parts.append(xp[s, : hi - lo])
        return jnp.concatenate(parts)


def partition_matrix(
    csr: CSR,
    num_shards: int,
    dtype=jnp.float32,
    nnz_align: int = 128,
    row_align: int = 1,
    with_coo: bool = True,
    splits: np.ndarray = None,
) -> PartitionedMatrix:
    """Build the paper's nnz-balanced partition as stacked padded COO shards.

    ``row_align`` rounds the per-shard row count ``n_pad`` up to a multiple
    (the Pallas kernel formats need it: ELL row tiles and BSR blocks must
    divide the padded-global coordinate stride).  ``with_coo=False`` skips
    materializing the COO triplets when the SpMV will run a kernel format
    (``sparse.formats.shard_to_ell`` / ``shard_to_blocked_ell``) instead.
    ``splits`` accepts precomputed split rows (one source of truth when the
    caller also feeds them to shard statistics/conversions).
    """
    n = csr.n
    if splits is None:
        splits = nnz_balanced_splits(csr.indptr, num_shards)
    n_pad = int(max(1, (splits[1:] - splits[:-1]).max()))
    n_pad = -(-n_pad // row_align) * row_align
    if with_coo:
        local_nnz = np.array(
            [csr.indptr[splits[s + 1]] - csr.indptr[splits[s]] for s in range(num_shards)]
        )
        nnz_pad = int(max(nnz_align, -(-int(local_nnz.max()) // nnz_align) * nnz_align))

        # Map each global column to its padded-global coordinate (the same
        # scheme the kernel-format conversions use — single definition).
        col_map = padded_col_map(splits, n_pad, n).astype(np.int32)

        rows = np.zeros((num_shards, nnz_pad), dtype=np.int32)
        cols = np.zeros((num_shards, nnz_pad), dtype=np.int32)
        vals = np.zeros((num_shards, nnz_pad), dtype=np.float64)
        row_of_nnz = np.repeat(np.arange(n, dtype=np.int64), csr.row_nnz())
        for s in range(num_shards):
            lo, hi = int(csr.indptr[splits[s]]), int(csr.indptr[splits[s + 1]])
            k = hi - lo
            rows[s, :k] = (row_of_nnz[lo:hi] - splits[s]).astype(np.int32)
            cols[s, :k] = col_map[csr.indices[lo:hi]]
            vals[s, :k] = csr.data[lo:hi]
            # Padding: row 0, col 0, val 0 — contributes 0 to row 0.
    else:
        rows = np.zeros((num_shards, 0), dtype=np.int32)
        cols = np.zeros((num_shards, 0), dtype=np.int32)
        vals = np.zeros((num_shards, 0), dtype=np.float64)

    pm = PartitionedMatrix(
        row=jnp.asarray(rows),
        col=jnp.asarray(cols),
        val=jnp.asarray(vals, dtype=dtype),
        n=n,
        n_pad=n_pad,
        num_shards=num_shards,
    )
    pm._splits = splits  # host-side metadata (not traced)
    return pm
