"""Quickstart: Top-K eigenpairs of a sparse graph matrix in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax

jax.config.update("jax_enable_x64", True)  # enables the paper's f64 compute (FDF/DDD)

import jax.numpy as jnp
import numpy as np

from repro import eigsh
from repro.core import make_operator
from repro.core.metrics import eigsh_reference, pairwise_orthogonality_deg, reconstruction_error
from repro.sparse import generate


def main():
    # a power-law web graph, symmetric-normalized adjacency (spectrum in [-1, 1])
    csr = generate("web", n=1 << 14, avg_deg=8.0, seed=0, values="normalized")
    print(f"matrix: n={csr.n:,} nnz={csr.nnz:,}")

    # one call: coercion, backend dispatch, precision policy, convergence report
    result = eigsh(csr, k=8, policy="FDF", reorth="full", num_iters=32)
    print(result.summary())

    print("top-8 |eigenvalues|:", np.asarray(result.eigenvalues))
    op = make_operator(csr, impl="coo", dtype=jnp.float32)
    err = reconstruction_error(op, result.eigenvalues, result.eigenvectors, accum_dtype=jnp.float64)
    print(f"mean L2 reconstruction error ||Mx - λx||: {err:.2e}")
    print(f"mean pairwise eigenvector angle: {pairwise_orthogonality_deg(result.eigenvectors):.2f}°")

    ref_vals, _ = eigsh_reference(csr, 8)  # ARPACK — the paper's CPU baseline
    print("ARPACK agrees to:", float(np.abs(np.asarray(result.eigenvalues) - ref_vals).max()))
    print(f"solver wall time: {result.wall_time_s:.2f}s "
          f"(lanczos {result.timings['lanczos_s']:.2f}s, jacobi {result.timings['jacobi_s']:.3f}s)")


if __name__ == "__main__":
    main()
