"""Eigensolver-as-a-service in ~40 lines: continuous batching end to end.

Makes one web graph resident in an ``EigenScheduler``, fires concurrent
Top-K queries at it from several threads, and shows the serving contract:
every request gets its own ``EigenResult`` future, compatible requests are
coalesced into shared Lanczos sweeps (watch the coalesce rate), and the
queue/solve latency split rides in each result's timings.

    PYTHONPATH=src python examples/serve_eigs.py

(This replaced the seed's LM slot-recycling demo; the legacy decode engine
lives on in ``repro.serving.lm``.)
"""

import threading

from repro.serving import EigenScheduler, SchedulerConfig, SessionStore
from repro.sparse import generate


def main():
    csr = generate("web", 2048, 8.0, seed=7, values="normalized")
    cfg = SchedulerConfig(admission_window_s=0.05, max_group=16)
    store = SessionStore()  # persists warm state next to the tune cache

    with EigenScheduler(cfg, store=store) as sched:
        key = sched.add_matrix(csr, name="web-2048")

        results = {}

        def client(cid: int):
            # Same num_iters/reorth/policy => one group key: these queries
            # ride one shared sweep and slice their own Ritz pairs from it.
            h = sched.submit(key, k=2 + 2 * (cid % 3), num_iters=32, reorth="full",
                             deadline_s=30.0)
            results[cid] = h.result(timeout=60.0)

        threads = [threading.Thread(target=client, args=(i,)) for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

        for cid in sorted(results):
            r = results[cid]
            lam = float(abs(r.eigenvalues[0]))
            print(
                f"client {cid}: k={r.k} |lambda_1|={lam:.6f} "
                f"queue={r.timings['queue_s'] * 1e3:.1f}ms "
                f"solve={r.timings['solve_s'] * 1e3:.1f}ms "
                f"amortized_over={r.timings.get('amortized_over', 1)}"
            )
        print()
        print(sched.stats().summary())


if __name__ == "__main__":
    main()
