"""Paper-technique-in-the-framework: Hessian spectrum during LM training.

Trains a small LM and probes the top-K |eigenvalues| of the loss Hessian
with the paper's mixed-precision Lanczos (matrix-free HVP operator) at
several checkpoints — the curvature trace practitioners use to diagnose
sharpness and learning-rate stability (lambda_max vs 2/eta).

    PYTHONPATH=src python examples/hessian_spectrum.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.common import split_tree
from repro.models.model import init_model
from repro.training import DataConfig, OptConfig, TrainConfig, Trainer, data_stream
from repro.training.data import synthetic_batch
from repro.training.spectral import hessian_spectrum, hessian_topk
from repro.core.precision import FFF, FDF


def main():
    cfg = get_config("qwen3-0.6b", smoke=True)
    params, _ = split_tree(init_model(jax.random.PRNGKey(0), cfg))
    dc = DataConfig(batch=4, seq_len=32, seed=3)
    probe = synthetic_batch(cfg, dc, 10**6)

    res0 = hessian_spectrum(params, cfg, probe, k=4, policy=FDF, num_iters=12)
    ev0 = np.asarray(res0.eigenvalues, dtype=np.float64)
    print(f"init      top-4 |λ(H)|: {np.round(ev0, 4)}   "
          f"(eigsh backend={res0.backend}, max residual {res0.residuals.max():.1e})")

    tc = TrainConfig(opt=OptConfig(peak_lr=3e-3, warmup_steps=5, decay_steps=80),
                     ckpt_every=1000, ckpt_dir="/tmp/repro_hess")
    tr = Trainer(cfg, tc, params)
    for phase in range(2):
        tr.run(data_stream(cfg, dc, start_step=tr.step), num_steps=tr.step + 40,
               log_fn=lambda *_: None)
        ev = hessian_topk(tr.params, cfg, probe, k=4, policy=FDF, num_iters=12)
        lr = 3e-3
        print(f"step {tr.step:4d} top-4 |λ(H)|: {np.round(ev, 4)}   "
              f"(2/η = {2/lr:.0f} — stable while |λ|max below this)")
    # mixed-precision comparison on the same operator (the paper's knob)
    ev_fff = hessian_topk(tr.params, cfg, probe, k=4, policy=FFF, num_iters=12)
    print(f"policy FFF vs FDF λmax delta: {abs(ev_fff[0] - ev[0]):.2e}")


if __name__ == "__main__":
    main()
