"""End-to-end LM training driver example.

Trains a width-reduced mamba2-family model (~15M params — the container has
one CPU core; pass --full for the real mamba2-130m config) for a few hundred
steps on the deterministic synthetic stream, with checkpointing, NaN
rollback, and the paper-integrated spectral monitor (top-K Hessian
eigenvalues via mixed-precision Lanczos) enabled.

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.common import split_tree
from repro.models.model import init_model
from repro.training import DataConfig, OptConfig, TrainConfig, Trainer, data_stream
from repro.training.data import synthetic_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--full", action="store_true", help="use the full config (slow on CPU)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=not args.full)
    if not args.full:  # scale the smoke config up to ~15M params
        cfg = dataclasses.replace(cfg, d_model=256, n_layers=6, vocab=8192,
                                  ssm_state=64 if cfg.family == "ssm" else cfg.ssm_state)

    params, _ = split_tree(init_model(jax.random.PRNGKey(0), cfg))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{cfg.name} (reduced={not args.full}): {n_params/1e6:.1f}M params")

    dc = DataConfig(batch=args.batch, seq_len=args.seq, seed=0)
    tc = TrainConfig(
        opt=OptConfig(peak_lr=3e-3, warmup_steps=20, decay_steps=args.steps),
        ckpt_every=100, ckpt_dir=args.ckpt_dir,
        spectral_every=max(50, args.steps // 3), spectral_k=3,
    )
    trainer = Trainer(cfg, tc, params,
                      probe_batch_fn=lambda: synthetic_batch(cfg, dc, 10**6))
    hist = trainer.run(data_stream(cfg, dc), num_steps=args.steps, log_every=25)
    print(f"loss: {hist[0]:.3f} -> {np.mean(hist[-10:]):.3f} over {len(hist)} steps")
    for step, ev in trainer.spectra.items():
        print(f"Hessian top-3 |λ| @ step {step}: {np.round(ev, 4)}")


if __name__ == "__main__":
    main()
