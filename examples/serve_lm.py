"""Batched serving example with continuous-batching-style slot recycling.

Maintains a fixed decode batch; when a sequence finishes (EOS or length
budget), its slot is refilled from the pending queue without stopping the
other slots — prefill for the new request runs while the batch keeps its
state (the fixed-batch analogue of vLLM-style continuous batching).

    PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.common import split_tree
from repro.models.model import decode_step, init_model, prefill


def main():
    cfg = get_config("qwen3-0.6b", smoke=True)
    params, _ = split_tree(init_model(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)

    # a queue of 6 "requests" with different lengths, slots for 2
    requests = [rng.integers(1, cfg.vocab, (rng.integers(8, 24),)).astype(np.int32)
                for _ in range(6)]
    budgets = [8, 12, 6, 10, 7, 9]
    slots = [None, None]  # each: dict(state, remaining, rid, out)
    step_fn = jax.jit(lambda p, s, t: decode_step(p, cfg, s, t))
    next_req = 0
    done = []

    def fill(slot_idx):
        nonlocal next_req
        if next_req >= len(requests):
            return None
        toks = requests[next_req][None, :]
        state, logits = prefill(params, cfg, {"tokens": jnp.asarray(toks)}, max_len=64)
        slot = dict(state=state, remaining=budgets[next_req], rid=next_req,
                    out=[], last=int(jnp.argmax(logits[0, : cfg.vocab])))
        next_req += 1
        return slot

    slots = [fill(0), fill(1)]
    steps = 0
    while any(s is not None for s in slots):
        for i, s in enumerate(slots):
            if s is None:
                continue
            tok = jnp.asarray([[s["last"]]], jnp.int32)
            logits, s["state"] = step_fn(params, s["state"], tok)
            s["out"].append(s["last"])
            s["last"] = int(jnp.argmax(logits[0, : cfg.vocab]))
            s["remaining"] -= 1
            steps += 1
            if s["remaining"] <= 0:
                done.append((s["rid"], s["out"]))
                slots[i] = fill(i)  # recycle the slot immediately
    for rid, out in sorted(done):
        print(f"request {rid}: generated {len(out)} tokens: {out}")
    print(f"served {len(done)} requests in {steps} decode steps across 2 slots")
    assert len(done) == len(requests)


if __name__ == "__main__":
    main()
