"""Spectral clustering with the Top-K eigensolver — the paper's own domain.

Builds a planted-partition graph (4 communities), computes the top
eigenvectors of the normalized adjacency with the mixed-precision Lanczos
solver, embeds vertices in spectral space, clusters with k-means (NumPy),
and reports clustering accuracy vs the planted labels.

    PYTHONPATH=src python examples/spectral_graph.py
"""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np

from repro import eigsh
from repro.sparse import csr_from_coo


def planted_partition(n=8192, k=4, p_in=12.0, p_out=1.0, seed=0):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, k, n)
    # sample edges: in-community with rate p_in/n per pair-bucket, cross p_out/n
    m_in = int(n * p_in / 2)
    m_out = int(n * p_out / 2)
    rows, cols = [], []
    for c in range(k):
        idx = np.where(labels == c)[0]
        r = rng.choice(idx, size=m_in // k * 2)
        rows.append(r[: m_in // k]); cols.append(r[m_in // k :])
    rows.append(rng.integers(0, n, m_out)); cols.append(rng.integers(0, n, m_out))
    rows, cols = np.concatenate(rows), np.concatenate(cols)
    vals = np.ones_like(rows, dtype=np.float64)
    csr = csr_from_coo(rows, cols, vals, n)
    # normalized adjacency
    deg = np.maximum(csr.row_nnz(), 1).astype(np.float64)
    dinv = 1.0 / np.sqrt(deg)
    rix = np.repeat(np.arange(n), csr.row_nnz())
    csr.data = csr.data * dinv[rix] * dinv[csr.indices]
    return csr, labels


def kmeans(x, k, iters=50, seed=0):
    rng = np.random.default_rng(seed)
    centers = x[rng.choice(len(x), k, replace=False)]
    for _ in range(iters):
        d = ((x[:, None, :] - centers[None]) ** 2).sum(-1)
        a = d.argmin(1)
        centers = np.stack([x[a == c].mean(0) if (a == c).any() else centers[c] for c in range(k)])
    return a


def accuracy(pred, truth, k):
    # best label permutation (greedy; k=4 so fine)
    import itertools

    best = 0.0
    for perm in itertools.permutations(range(k)):
        mapped = np.array([perm[p] for p in pred])
        best = max(best, (mapped == truth).mean())
    return best


def main():
    csr, labels = planted_partition()
    print(f"graph: n={csr.n:,} nnz={csr.nnz:,}, 4 planted communities")
    res = eigsh(csr, k=4, policy="FDF", reorth="full", num_iters=24)
    print("top-4 eigenvalues:", np.asarray(res.eigenvalues),
          f"(backend={res.backend}, {int(res.converged.sum())}/4 converged)")
    emb = np.asarray(res.eigenvectors, dtype=np.float64)
    emb = emb / np.maximum(np.linalg.norm(emb, axis=1, keepdims=True), 1e-12)
    pred = kmeans(emb, 4)
    acc = accuracy(pred, labels, 4)
    print(f"spectral clustering accuracy vs planted labels: {acc:.3f}")
    assert acc > 0.85, "clustering should recover planted communities"


if __name__ == "__main__":
    main()
