"""Gate a bench-smoke run against the committed baseline.

Usage:
    python -m benchmarks.compare artifacts/BENCH_pr.json \
        benchmarks/baseline_smoke.json --max-slowdown 2.0

The gate applies to metrics large enough to time stably (>= ``--gate-floor-us``
in either run, default 50ms): measured run-to-run dispersion of the smoke
suite is <= ~1.4x for these, so a >2x raw ratio is a real regression, not
scheduler noise.  Smaller metrics are printed for trend-watching but never
fail the gate (their dispersion on shared runners exceeds the threshold).
The machine-speed calibration probe is reported for context; it is not used
to normalize (per-op noise on small containers made normalized ratios less
stable than raw ones).  New/removed metrics are reported but never fail —
refresh the baseline when the benched surface legitimately changes:
``python -m benchmarks.run --smoke --out benchmarks/baseline_smoke.json``.
"""

import argparse
import json
import sys


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def compare(pr: dict, base: dict, max_slowdown: float, gate_floor_us: float) -> int:
    pr_m, base_m = pr.get("metrics", {}), base.get("metrics", {})
    shared = sorted(set(pr_m) & set(base_m))
    regressions = []
    gated = 0
    print(
        f"calibration (informational): pr={float(pr.get('calibration_us') or 0):.1f}us "
        f"baseline={float(base.get('calibration_us') or 0):.1f}us"
    )
    print(f"{'metric':45s} {'base_us':>10s} {'pr_us':>10s} {'ratio':>7s}")
    for name in shared:
        b, p = float(base_m[name]), float(pr_m[name])
        if b <= 0 or p <= 0:
            continue  # unmeasured placeholders (e.g. table1's 0.0 rows)
        ratio = p / b
        in_gate = max(b, p) >= gate_floor_us
        gated += in_gate
        flag = ""
        if in_gate and ratio > max_slowdown:
            regressions.append((name, ratio))
            flag = "  << REGRESSION"
        elif not in_gate:
            flag = "  (info only)"
        print(f"{name:45s} {b:10.1f} {p:10.1f} {ratio:6.2f}x{flag}")
    for name in sorted(set(pr_m) - set(base_m)):
        print(f"{name:45s} {'-':>10s} {float(pr_m[name]):10.1f}   (new)")
    for name in sorted(set(base_m) - set(pr_m)):
        print(f"{name:45s} {float(base_m[name]):10.1f} {'-':>10s}   (removed)")
    if regressions:
        print(f"\nFAIL: {len(regressions)} gated metric(s) slowed by >{max_slowdown}x:")
        for name, ratio in regressions:
            print(f"  {name}: {ratio:.2f}x")
        return 1
    print(f"\nOK: no gated metric slowed by >{max_slowdown}x ({gated} gated)")
    return 0


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("pr_json")
    parser.add_argument("baseline_json")
    parser.add_argument("--max-slowdown", type=float, default=2.0)
    parser.add_argument(
        "--gate-floor-us",
        type=float,
        default=50_000.0,
        help="gate only metrics at least this large in one run (smaller ones "
        "are too noisy on shared runners and are reported info-only)",
    )
    args = parser.parse_args(argv)
    sys.exit(
        compare(load(args.pr_json), load(args.baseline_json), args.max_slowdown, args.gate_floor_us)
    )


if __name__ == "__main__":
    main()
