"""Gate a bench-smoke run against the committed baseline — and against itself.

Usage:
    python -m benchmarks.compare artifacts/BENCH_pr.json \
        benchmarks/baseline_smoke.json --max-slowdown 2.0 \
        --pair engine/lanczos_step/fused:engine/lanczos_step/unfused

Three independent checks, one exit code:

* **Baseline gate** — metrics large enough to time stably (>=
  ``--gate-floor-us`` in either run, default 50ms) must not exceed
  ``--max-slowdown`` x their committed baseline: measured run-to-run
  dispersion of the smoke suite is <= ~1.4x for these, so a >2x raw ratio is
  a real regression, not scheduler noise.  Smaller metrics are printed for
  trend-watching but never fail the gate.  New/removed metrics are reported
  but never fail — refresh the baseline when the benched surface changes:
  ``python -m benchmarks.run --smoke --out benchmarks/baseline_smoke.json``.

* **Pair gates** (``--pair A:B[:RATIO]``) — intra-run invariants: metric A
  must not exceed RATIO x metric B *within the same run* (default
  ``--max-ratio``, 1.0).  This is what makes "the fused path lost to the
  unfused path" unlandable even when both moved together (the baseline gate
  compares each metric only to its own past).  A pair is *escaped* when the
  run's recorded decision plan for the metrics' common prefix selected
  something other than A's leaf — e.g. the whole-iteration autotuner chose
  the unfused update, so fused losing is the measured, routed-around truth,
  not a shipped regression.  No recorded plan means no escape.

* **Trend watch** (``--trend history.jsonl``) — warn-only: flags metrics
  that degraded monotonically over the last 3 runs (slow leaks the 2x gate
  can't see).  History lines are appended on main by ``--append-history``.

``--summary-out`` appends a markdown report (CI passes
``$GITHUB_STEP_SUMMARY``); ``--skip-gate`` reports without failing (the
post-merge history step on main — its PR already passed the real gate).
"""

import argparse
import json
import os
import sys
from datetime import datetime, timezone


def load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _md(lines, row) -> None:
    if lines is not None:
        lines.append(row)


def compare(pr, base, max_slowdown, gate_floor_us, md=None) -> list:
    """Baseline gate: returns the list of failing (name, ratio) pairs."""
    pr_m, base_m = pr.get("metrics", {}), base.get("metrics", {})
    shared = sorted(set(pr_m) & set(base_m))
    regressions = []
    gated = 0
    print(
        f"calibration (informational): pr={float(pr.get('calibration_us') or 0):.1f}us "
        f"baseline={float(base.get('calibration_us') or 0):.1f}us"
    )
    print(f"{'metric':45s} {'base_us':>10s} {'pr_us':>10s} {'ratio':>7s}")
    _md(md, "### Baseline gate (vs committed smoke baseline)\n")
    _md(md, "| metric | base µs | pr µs | ratio | status |")
    _md(md, "|---|---:|---:|---:|---|")
    for name in shared:
        b, p = float(base_m[name]), float(pr_m[name])
        if b <= 0 or p <= 0:
            continue  # unmeasured placeholders (e.g. table1's 0.0 rows)
        ratio = p / b
        in_gate = max(b, p) >= gate_floor_us
        gated += in_gate
        flag = ""
        status = "ok" if in_gate else "info"
        if in_gate and ratio > max_slowdown:
            regressions.append((name, ratio))
            flag = "  << REGRESSION"
            status = "**REGRESSION**"
        elif not in_gate:
            flag = "  (info only)"
        print(f"{name:45s} {b:10.1f} {p:10.1f} {ratio:6.2f}x{flag}")
        _md(md, f"| {name} | {b:.1f} | {p:.1f} | {ratio:.2f}x | {status} |")
    for name in sorted(set(pr_m) - set(base_m)):
        print(f"{name:45s} {'-':>10s} {float(pr_m[name]):10.1f}   (new)")
        _md(md, f"| {name} | – | {float(pr_m[name]):.1f} | – | new |")
    for name in sorted(set(base_m) - set(pr_m)):
        print(f"{name:45s} {float(base_m[name]):10.1f} {'-':>10s}   (removed)")
        _md(md, f"| {name} | {float(base_m[name]):.1f} | – | – | removed |")
    if regressions:
        print(f"\nFAIL: {len(regressions)} gated metric(s) slowed by >{max_slowdown}x:")
        for name, ratio in regressions:
            print(f"  {name}: {ratio:.2f}x")
    else:
        print(f"\nOK: no gated metric slowed by >{max_slowdown}x ({gated} gated)")
    return regressions


def _split_common(a: str, b: str):
    """('engine/x/fused', 'engine/x/unfused') -> ('engine/x', 'fused')."""
    pa, pb = a.split("/"), b.split("/")
    i = 0
    while i < min(len(pa), len(pb)) and pa[i] == pb[i]:
        i += 1
    return "/".join(pa[:i]), "/".join(pa[i:])


def check_pairs(pr, pair_specs, default_ratio, md=None) -> list:
    """Intra-run pair gates: returns the list of failing (spec, ratio) pairs."""
    if not pair_specs:
        return []
    metrics, plans = pr.get("metrics", {}), pr.get("plans", {})
    failures = []
    print(f"\n{'pair gate':60s} {'ratio':>7s} {'limit':>7s}")
    _md(md, "\n### Pair gates (intra-run invariants)\n")
    _md(md, "| A | B | ratio | limit | status |")
    _md(md, "|---|---|---:|---:|---|")
    for spec in pair_specs:
        parts = spec.split(":")
        if len(parts) not in (2, 3):
            raise SystemExit(f"--pair {spec!r}: expected A:B or A:B:RATIO")
        a, b = parts[0], parts[1]
        limit = float(parts[2]) if len(parts) == 3 else default_ratio
        pa, pb = metrics.get(a), metrics.get(b)
        if pa is None or pb is None or float(pb) <= 0:
            print(f"{spec:60s} {'-':>7s} {limit:6.2f}x  (metric missing; skipped)")
            _md(md, f"| {a} | {b} | – | {limit:.2f}x | metric missing |")
            continue
        ratio = float(pa) / float(pb)
        prefix, leaf = _split_common(a, b)
        selected = (plans.get(prefix) or {}).get("selected")
        escaped = selected is not None and selected != leaf
        if ratio > limit and not escaped:
            failures.append((spec, ratio))
            note = "  << PAIR REGRESSION"
            status = "**FAIL**"
        elif ratio > limit:
            note = f"  (escaped: plan[{prefix}] selected {selected!r}, not {leaf!r})"
            status = f"escaped (plan→{selected})"
        else:
            note = ""
            status = "ok"
        print(f"{a + ' : ' + b:60s} {ratio:6.2f}x {limit:6.2f}x{note}")
        _md(md, f"| {a} | {b} | {ratio:.2f}x | {limit:.2f}x | {status} |")
    if failures:
        print(f"\nFAIL: {len(failures)} pair gate(s) exceeded:")
        for spec, ratio in failures:
            print(f"  {spec}: {ratio:.2f}x")
    else:
        print("\nOK: all pair gates hold")
    return failures


def _read_history(path: str) -> list:
    entries = []
    if os.path.exists(path):
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    try:
                        entries.append(json.loads(line))
                    except json.JSONDecodeError:
                        pass  # a torn line must not break CI
    return entries


def check_trend(pr, history_path, runs=3, min_total=1.10, md=None) -> list:
    """Warn-only: metrics monotonically degrading over the last ``runs`` runs
    (history tail + this run) with a total slowdown > ``min_total``x."""
    history = _read_history(history_path)
    pr_m = pr.get("metrics", {})
    warnings = []
    for name, value in sorted(pr_m.items()):
        tail = [
            float(e["metrics"][name])
            for e in history[-(runs - 1) :]
            if isinstance(e.get("metrics"), dict) and name in e["metrics"]
        ]
        seq = tail + [float(value)]
        if len(seq) < runs:
            continue  # not enough history yet
        monotone = all(seq[i] < seq[i + 1] for i in range(len(seq) - 1))
        if monotone and seq[0] > 0 and seq[-1] / seq[0] > min_total:
            warnings.append((name, seq))
    if warnings:
        print(f"\nTREND WARNING ({len(warnings)} metric(s) degrading over {runs} runs):")
        _md(md, f"\n### ⚠ Trend warnings ({runs}-run monotone degradation)\n")
        _md(md, "| metric | trajectory (µs) | total |")
        _md(md, "|---|---|---:|")
        for name, seq in warnings:
            traj = " -> ".join(f"{v:.1f}" for v in seq)
            print(f"  {name}: {traj}  ({seq[-1] / seq[0]:.2f}x, warn-only)")
            _md(md, f"| {name} | {traj} | {seq[-1] / seq[0]:.2f}x |")
    elif history:
        print(f"\ntrend: no metric degraded monotonically over the last {runs} runs")
    return warnings


def append_history(pr, history_path, sha) -> None:
    entry = {
        "sha": sha or "unknown",
        "date": datetime.now(timezone.utc).strftime("%Y-%m-%dT%H:%M:%SZ"),
        "calibration_us": pr.get("calibration_us"),
        "metrics": pr.get("metrics", {}),
        "plans": pr.get("plans", {}),
    }
    os.makedirs(os.path.dirname(os.path.abspath(history_path)), exist_ok=True)
    with open(history_path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    print(f"\nappended run {entry['sha'][:12]} to {history_path}")


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("pr_json")
    parser.add_argument("baseline_json")
    parser.add_argument("--max-slowdown", type=float, default=2.0)
    parser.add_argument(
        "--gate-floor-us",
        type=float,
        default=50_000.0,
        help="gate only metrics at least this large in one run (smaller ones "
        "are too noisy on shared runners and are reported info-only)",
    )
    parser.add_argument(
        "--pair",
        action="append",
        default=[],
        metavar="A:B[:RATIO]",
        help="intra-run gate: metric A must be <= RATIO x metric B in the PR "
        "run (repeatable; RATIO defaults to --max-ratio); escaped when the "
        "run's plan for the common prefix selected a different leaf",
    )
    parser.add_argument(
        "--max-ratio",
        type=float,
        default=1.0,
        help="default RATIO for --pair gates without an explicit one",
    )
    parser.add_argument(
        "--trend",
        metavar="HISTORY_JSONL",
        help="warn (never fail) on metrics degrading monotonically over the "
        "last 3 runs recorded in this history file",
    )
    parser.add_argument(
        "--append-history",
        metavar="HISTORY_JSONL",
        help="append this run's metrics+plans as one JSONL line (main only)",
    )
    parser.add_argument("--sha", default=os.environ.get("GITHUB_SHA", ""),
                        help="commit sha recorded with --append-history")
    parser.add_argument(
        "--summary-out",
        metavar="MD_PATH",
        help="append a markdown report (CI passes $GITHUB_STEP_SUMMARY)",
    )
    parser.add_argument(
        "--skip-gate",
        action="store_true",
        help="report everything but always exit 0 (post-merge history runs)",
    )
    args = parser.parse_args(argv)

    pr, base = load(args.pr_json), load(args.baseline_json)
    md = [] if args.summary_out else None
    _md(md, "## bench-smoke comparison\n")
    regressions = compare(pr, base, args.max_slowdown, args.gate_floor_us, md=md)
    pair_failures = check_pairs(pr, args.pair, args.max_ratio, md=md)
    if args.trend:
        check_trend(pr, args.trend, md=md)
    if args.append_history:
        append_history(pr, args.append_history, args.sha)
    if md is not None:
        with open(args.summary_out, "a") as f:
            f.write("\n".join(md) + "\n")
    failed = bool(regressions or pair_failures)
    if failed and args.skip_gate:
        print("\n(--skip-gate: failures reported above are not enforced here)")
    sys.exit(1 if failed and not args.skip_gate else 0)


if __name__ == "__main__":
    main()
