"""Paper Fig. 3b: eigenvector orthogonality + L2 reconstruction error vs K,
with and without re-orthogonalization (aggregated over matrices)."""

import jax.numpy as jnp
import numpy as np

from .common import emit, ensure_x64, save_artifact


def run(kset=(8, 16, 24), matrices=("WB-TA", "FL", "PA", "WK"), scale=0.25):
    ensure_x64()
    from repro.api import eigsh
    from repro.core import make_operator
    from repro.core.metrics import pairwise_orthogonality_deg, reconstruction_error
    from repro.sparse import suite_matrix

    rows = []
    for k in kset:
        for mode in ("none", "half", "full"):
            orths, errs = [], []
            for mid in matrices:
                csr = suite_matrix(mid, values="normalized", scale=scale)
                op = make_operator(csr, "coo", dtype=jnp.float32)
                r = eigsh(op, k, policy="FDF", reorth=mode, num_iters=2 * k)
                orths.append(pairwise_orthogonality_deg(r.eigenvectors))
                errs.append(
                    reconstruction_error(op, r.eigenvalues, r.eigenvectors, accum_dtype=jnp.float64)
                )
            rows.append(dict(k=k, reorth=mode,
                             mean_orth_deg=float(np.mean(orths)),
                             mean_l2_err=float(np.mean(errs))))
            emit(f"fig3b/k{k}/{mode}", 0.0,
                 f"orth={np.mean(orths):.2f}deg l2={np.mean(errs):.2e}")
    save_artifact("fig3b_reorth.json", rows)
    return rows


if __name__ == "__main__":
    run()
