"""Paper Table I: the evaluation matrix suite (structure-matched synthetic
replicas at testbed scale — see sparse/generate.py)."""

import numpy as np

from .common import emit, save_artifact


def run(scale=0.25):
    from repro.sparse import SUITE, suite_matrix

    rows = []
    for mid, entry in SUITE.items():
        csr = suite_matrix(mid, values="unit", scale=scale)
        sparsity = csr.nnz / (csr.n ** 2)
        size_gb = (csr.nnz * (8 + 4 + 4)) / 1e9  # COO f64 + 2 x int32 per paper
        rows.append(dict(id=mid, paper_name=entry.paper_id, family=entry.kind,
                         rows=csr.n, nnz=csr.nnz, sparsity=sparsity, coo_gb=size_gb))
        emit(f"table1/{mid}", 0.0,
             f"{entry.paper_id} n={csr.n} nnz={csr.nnz} sparsity={sparsity:.2e}")
    save_artifact("table1_suite.json", rows)
    return rows


if __name__ == "__main__":
    run()
